"""Two-process ``jax.distributed`` smoke for the 2-D mesh scale-out path.

Run with no arguments, the driver re-executes itself as two coordinated
worker processes (``--process-id 0|1``), each given two forced host
devices, and checks the multi-process story end to end as far as the CPU
backend permits:

  1. ``jax.distributed.initialize`` handshake: both workers join one
     coordinator and each sees the OTHER's devices in the global world
     (4 global / 2 local) -- the topology a real multi-host TPU mesh
     starts from.
  2. Per-worker 2-D parity: each worker runs the row-sharded fused
     dispatch (``MeshSpec(rows=2)``, halo exchange and all) over its two
     local devices and asserts bitwise equality with its single-device
     run.  This is exactly the per-host slice of a multi-host rollout.
  3. Truthful degradation across the process boundary: a spec spanning
     the whole 4-device *global* world exceeds each worker's 2
     *addressable* devices, so the fleet must degrade to the bitwise
     single-device fallback AND stamp ``mesh_degraded`` -- never
     silently pretend to the global shape.

Cross-process collectives themselves are NOT exercised: XLA:CPU raises
``Multiprocess computations aren't implemented on the CPU backend``
(verified empirically on jax 0.4.x), so a CPU CI can validate the
handshake, the world assembly, and the per-host shard math, while the
collective seam exchange across hosts needs a real TPU/GPU runner.
Exits 0 on success, 1 with the failing worker's log on any mismatch.
"""

from __future__ import annotations

import argparse
import os
import subprocess
import sys

COORD = "127.0.0.1:12357"
N_PROCS = 2
LOCAL_DEVICES = 2


def worker(process_id: int) -> None:
    import jax

    jax.distributed.initialize(
        coordinator_address=COORD, num_processes=N_PROCS,
        process_id=process_id,
    )
    import numpy as np

    assert len(jax.local_devices()) == LOCAL_DEVICES, jax.local_devices()
    assert jax.device_count() == N_PROCS * LOCAL_DEVICES, jax.devices()
    assert jax.process_count() == N_PROCS
    print(f"[worker {process_id}] joined: {len(jax.local_devices())} local "
          f"/ {jax.device_count()} global devices", flush=True)

    from repro.core import MeshSpec, sobel_grid
    from repro.runtime.fleet import FleetRequest, PixieFleet

    grid = sobel_grid()
    rng = np.random.default_rng(process_id)
    names = ("sobel_x", "threshold", "sobel_y", "identity")
    frames = [rng.integers(0, 256, hw).astype(np.int32)
              for hw in ((13, 17), (8, 8), (21, 9), (5, 30))]

    def run(spec):
        fleet = PixieFleet(default_grid=grid, mesh=spec, batch_tile=1)
        tickets = [fleet.submit(FleetRequest(app=n, image=f))
                   for n, f in zip(names, frames)]
        res = fleet.flush()
        return [np.asarray(res[t]) for t in tickets], fleet

    base, _ = run(MeshSpec())
    got, fleet = run(MeshSpec(rows=LOCAL_DEVICES))
    for b, g in zip(base, got):
        np.testing.assert_array_equal(b, g)
    assert not fleet.stats.mesh_degraded, fleet.stats
    print(f"[worker {process_id}] row-sharded parity over "
          f"{LOCAL_DEVICES} local devices: bitwise OK", flush=True)

    _, global_fleet = run(MeshSpec(app=N_PROCS, rows=LOCAL_DEVICES))
    assert global_fleet.stats.mesh_degraded, global_fleet.stats
    assert global_fleet.stats.mesh_granted == (1, 1)
    print(f"[worker {process_id}] global-world spec "
          f"{N_PROCS}x{LOCAL_DEVICES} degraded truthfully "
          f"(granted 1x1, stamped)", flush=True)


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--process-id", type=int, default=None)
    a = p.parse_args(argv)
    if a.process_id is not None:
        worker(a.process_id)
        return 0
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={LOCAL_DEVICES}"
    )
    procs = [
        subprocess.Popen(
            [sys.executable, os.path.abspath(__file__),
             "--process-id", str(i)],
            env=env, stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
        )
        for i in range(N_PROCS)
    ]
    rc = 0
    for i, proc in enumerate(procs):
        try:
            out, _ = proc.communicate(timeout=300)
        except subprocess.TimeoutExpired:
            proc.kill()
            out, _ = proc.communicate()
            rc = 1
        sys.stdout.write(out.decode(errors="replace"))
        if proc.returncode != 0:
            rc = 1
    print("mesh_distributed_smoke:", "PASS" if rc == 0 else "FAIL")
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
