"""Benchmark 4 — the (arch x shape) roofline table from dry-run artifacts.

Reads artifacts/dryrun/*.json (produced by repro.launch.dryrun) and prints
the 3-term roofline per cell: compute / memory / collective seconds,
dominant bottleneck, MODEL_FLOPS/HLO_FLOPs ratio, roofline-MFU.  Does not
compile anything itself (run `python -m repro.launch.dryrun --all` first).
"""

from __future__ import annotations

import glob
import json
import os

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")

COLS = [
    ("arch", 18), ("shape", 11), ("mesh", 6), ("attn_mode", 9),
    ("t_compute_s", 11), ("t_memory_s", 11), ("t_collective_s", 11),
    ("bottleneck", 10), ("useful_flops_ratio", 9), ("mfu_at_roofline", 8),
    ("mem_GiB", 8),
]


def load_rows(pattern: str = "*.json"):
    rows = []
    for path in sorted(glob.glob(os.path.join(ARTIFACTS, pattern))):
        with open(path) as f:
            d = json.load(f)
        if d.get("error"):
            rows.append({"arch": d["arch"], "shape": d["shape"],
                         "mesh": d.get("mesh", "?"), "bottleneck": "ERROR"})
            continue
        if d.get("skipped"):
            rows.append({"arch": d["arch"], "shape": d["shape"],
                         "mesh": d.get("mesh", "?"), "bottleneck": "SKIP"})
            continue
        pm = d.get("peak_memory_per_device") or 0
        rows.append({
            "arch": d["arch"], "shape": d["shape"], "mesh": d["mesh"],
            "attn_mode": d.get("attn_mode", "-"),
            "t_compute_s": d["t_compute_s"], "t_memory_s": d["t_memory_s"],
            "t_collective_s": d["t_collective_s"],
            "bottleneck": d["bottleneck"],
            "useful_flops_ratio": d["useful_flops_ratio"],
            "mfu_at_roofline": d["mfu_at_roofline"],
            "mem_GiB": pm / 2**30,
            "variant": d.get("variant", "baseline"),
        })
    return rows


def _fmt(v, width):
    if isinstance(v, float):
        s = f"{v:.3e}" if (v and abs(v) < 1e-2) else f"{v:.3f}"
    else:
        s = str(v)
    return s.ljust(width)[:max(width, len(s))]


def main():
    rows = load_rows()
    if not rows:
        print("no dry-run artifacts found; run: "
              "PYTHONPATH=src python -m repro.launch.dryrun --all")
        return []
    print(" | ".join(name.ljust(w) for name, w in COLS))
    print("-" * (sum(w for _, w in COLS) + 3 * len(COLS)))
    for r in rows:
        print(" | ".join(_fmt(r.get(name, "-"), w) for name, w in COLS))
    return rows


if __name__ == "__main__":
    main()
