"""Benchmark 2 — Sec. V-E analogue: the compilation gap.

Paper: mapping an app onto the overlay takes < 1 s, compiling the
overlay itself ~1200 s, and micro-reconfiguration costs ms.  Our
analogues, measured wall-clock:

  overlay_compile   XLA jit of the generic interpreter (once per grid)
  map               synthesis + place + route + settings generation
  reconfig_conv     settings-array swap on the conventional overlay
                    (must NOT recompile -- asserted via the jit cache)
  reconfig_param    re-jit of the specialized executor
  exec              one overlay execution of a 512x512 image
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Pixie, for_dfg, map_app, sobel_grid
from repro.core import applications as apps

IMAGE = (512, 512)


def run():
    rows = []
    img = jnp.asarray(
        np.random.default_rng(0).integers(0, 256, IMAGE).astype(np.int32)
    )
    batch = img.size
    grid = sobel_grid()

    pix = Pixie(grid, mode="conventional")
    t_overlay = pix.compile_overlay(batch=batch)

    dfg_a, dfg_b = apps.sobel_x(), apps.sobel_y()
    cfg_a = pix.map(dfg_a)
    t_map = pix.timings["map_s"]

    t_reconf_conv = pix.load(cfg_a, batch=batch)
    pix.run_image(img)  # warm
    n_exec = 5
    t0 = time.perf_counter()
    for _ in range(n_exec):
        pix.run_image(img).block_until_ready()
    t_exec = (time.perf_counter() - t0) / n_exec

    cache_before = pix._overlay_fn._cache_size()
    t_swap = pix.load(pix.map(dfg_b), batch=batch)
    pix.run_image(img)
    assert pix._overlay_fn._cache_size() == cache_before, "reconfig recompiled!"

    pix_p = Pixie(grid, mode="parameterized")
    t_reconf_param = pix_p.load(cfg_a, batch=batch)

    rows = [
        {"stage": "overlay_compile (jit, once per grid)", "seconds": t_overlay,
         "paper_analogue": "~1200 s FPGA compile"},
        {"stage": "map application (synth+place+route)", "seconds": t_map,
         "paper_analogue": "< 1 s"},
        {"stage": "reconfig conventional (settings swap)", "seconds": t_swap,
         "paper_analogue": "settings-bus write"},
        {"stage": "reconfig parameterized (re-jit)", "seconds": t_reconf_param,
         "paper_analogue": "156 ms + 18.4 ms micro-reconfig (Sobel)"},
        {"stage": f"execute {IMAGE[0]}x{IMAGE[1]} image", "seconds": t_exec,
         "paper_analogue": "-"},
    ]
    return rows


def main():
    rows = run()
    for r in rows:
        print(f"{r['stage']:45s} {r['seconds']*1e3:10.2f} ms   ({r['paper_analogue']})")
    return rows


if __name__ == "__main__":
    main()
