"""Benchmark 7 — pipeline throughput: device-resident multi-stage chains.

A chained image pipeline (gauss3 -> sobel_x -> threshold) can run two ways
on the overlay fleet:

  staged      one fleet flush PER STAGE -- each stage's output leaves the
              device, lands on the host, and is re-submitted as the next
              stage's input frame (canvas embed + tap-bank formation paid
              again).  This is the pre-pipeline serving reality: chains
              are just sequences of single-stage jobs with host hops.
  fused       ONE flush of pipeline requests -- `compile_plan` folds the
              whole chain into a single `OverlayExecutable`; the
              intermediate is re-tapped on device (no unpack/repack, no
              host hop) and the stage loop runs inside one jit (XLA) /
              one megakernel over the same VMEM slabs (pallas).

Identical inputs, bitwise-identical outputs (asserted against the staged
oracle BEFORE timing, on both backends).  Emits a machine-readable
``BENCH {json}`` line; ``--out`` MERGES the result as a ``"pipeline"``
block into the (existing) fleet BENCH JSON so the trend artifact stays a
single file, and ``--check`` enforces the fused >= 1.5x staged floor.

Usage:
  python benchmarks/pipeline_throughput.py                # full: 256^2 x 8
  python benchmarks/pipeline_throughput.py --smoke        # CI-sized (<30 s)
  python benchmarks/pipeline_throughput.py --check        # exit 1 on floors
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.core import MeshSpec
from repro.core import applications as apps
from repro.core.grid import custom
from repro.core.place import level_demand
from repro.kernels.vcgra import default_interpret
from repro.runtime.fleet import FleetRequest, PixieFleet

# The depth-3 chain of the acceptance run: radii 1/1/0, so the fused
# executable carries total pad 2 while the staged path pays three full
# ingest/unpack round trips.
CHAIN = ["gauss3", "sobel_x", "threshold"]

# Fused must beat the staged-sequential oracle end to end by this factor
# (the measured margin is ~50x at 256^2 -- the floor guards regressions,
# e.g. an accidental host hop sneaking back between stages).
FUSED_FLOOR_VS_STAGED = 1.5

# Same rationale as fleet_throughput.PALLAS_FLOOR_VS_XLA: the megakernel
# interprets on CPU CI, so the floor only catches catastrophic breakage.
PALLAS_FLOOR_VS_XLA = 0.05


def chain_grid(name: str = "pipe_shared", slack: int = 1):
    """One grid big enough for every stage of CHAIN (per-level width =
    max demand across the stage DFGs + slack) -- the same shared-overlay
    construction the fleet test suites use, so every stage of the chain
    maps onto ONE overlay executable."""
    dfgs = [apps.ALL_APPS[n]() for n in CHAIN]
    demands = [level_demand(g) for g in dfgs]
    depth = max(len(d) for d in demands)
    demands = [list(d) + [1] * (depth - len(d)) for d in demands]
    widths = [max(d[lvl] for d in demands) + slack for lvl in range(depth)]
    return custom(name, max(len(g.inputs) for g in dfgs), widths, 1)


def _time(fn, reps: int) -> float:
    fn()  # warm / compile (fleet outputs are host arrays: already forced)
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps


def run(n_apps: int, image_hw: int, reps: int) -> dict:
    rng = np.random.default_rng(0)
    grid = chain_grid()
    frames = [
        rng.integers(0, 256, (image_hw, image_hw)).astype(np.int32)
        for _ in range(n_apps)
    ]

    # -- staged-sequential oracle: one flush per stage, host hop between --
    staged_fleet = PixieFleet(default_grid=grid, batch_tile=n_apps)

    def staged():
        cur = frames
        for stage in CHAIN:
            cur = [
                np.asarray(y)
                for y in staged_fleet.run_many(
                    [FleetRequest(app=stage, image=f, grid=grid) for f in cur]
                )
            ]
        return cur

    # -- fused chain: ONE flush of pipeline requests ----------------------
    fused_fleet = PixieFleet(default_grid=grid, batch_tile=n_apps)
    requests = [
        FleetRequest(pipeline=CHAIN, image=f, grid=grid) for f in frames
    ]

    def fused():
        return fused_fleet.run_many(requests)

    # bitwise parity BEFORE timing: fused chain == staged per-stage oracle
    staged_out = staged()
    fused_out = fused()
    for i in range(n_apps):
        np.testing.assert_array_equal(np.asarray(fused_out[i]), staged_out[i])
    assert fused_fleet.stats.pipeline_dispatches >= 1, \
        fused_fleet.stats.as_dict()
    # compile-once invariant: the whole chain is ONE plan-cache entry.
    assert fused_fleet._overlays.misses == 1, fused_fleet.stats.as_dict()

    t_staged = _time(staged, reps)
    t_fused = _time(fused, reps)

    # -- pallas backend: the stage loop inside the DMA megakernel ---------
    pallas_fleet = PixieFleet(default_grid=grid, batch_tile=n_apps,
                              backend="pallas")
    def pallas_fused():
        return pallas_fleet.run_many(requests)

    pallas_out = pallas_fused()
    for i in range(n_apps):
        np.testing.assert_array_equal(np.asarray(pallas_out[i]), staged_out[i])
    t_pallas = _time(pallas_fused, max(1, reps // 3))

    # -- row-sharded fused chain: per-stage halo exchange between stages --
    # Requested unconditionally; a single-device host degrades to the
    # bitwise fallback and the stamp records requested vs granted (same
    # truthfulness contract as fleet_throughput's mesh block).
    n_dev = len(jax.local_devices())
    mesh_spec = MeshSpec(rows=2) if n_dev >= 2 else MeshSpec()
    mesh_fleet = PixieFleet(default_grid=grid, batch_tile=n_apps,
                            mesh=mesh_spec)

    def mesh_fused():
        return mesh_fleet.run_many(requests)

    mesh_out = mesh_fused()
    for i in range(n_apps):
        np.testing.assert_array_equal(np.asarray(mesh_out[i]), staged_out[i])
    t_mesh = _time(mesh_fused, max(1, reps // 3))

    pixels = image_hw * image_hw * n_apps
    return {
        "bench": "pipeline_throughput",
        "chain": CHAIN,
        "depth": len(CHAIN),
        "n_apps": n_apps,
        "image": [image_hw, image_hw],
        "grid": grid.name,
        "device_count": n_dev,
        "staged_s_per_round": t_staged,
        "fused_s_per_round": t_fused,
        "staged_chains_per_s": n_apps / t_staged,
        "fused_chains_per_s": n_apps / t_fused,
        "staged_mpixels_per_s": pixels / t_staged / 1e6,
        "fused_mpixels_per_s": pixels / t_fused / 1e6,
        "fused_vs_staged": t_staged / t_fused,
        "fused_floor_vs_staged": FUSED_FLOOR_VS_STAGED,
        "pipeline_dispatches": fused_fleet.stats.pipeline_dispatches,
        "fleet_stats": fused_fleet.stats.as_dict(),
        "backends": {
            "xla": {"fused_s_per_round": t_fused,
                    "fused_chains_per_s": n_apps / t_fused},
            "pallas": {"fused_s_per_round": t_pallas,
                       "fused_chains_per_s": n_apps / t_pallas,
                       "interpret_mode": default_interpret()},
        },
        "pallas_vs_xla_fused": t_fused / t_pallas,
        "pallas_floor_vs_xla": PALLAS_FLOOR_VS_XLA,
        "mesh": {
            "requested": list(mesh_fleet.stats.mesh_requested),
            "granted": list(mesh_fleet.stats.mesh_granted),
            "degraded": mesh_fleet.stats.mesh_degraded,
            "fused_s_per_round": t_mesh,
            "fused_chains_per_s": n_apps / t_mesh,
        },
    }


def main(argv=None) -> dict:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--smoke", action="store_true", help="CI-sized quick run")
    p.add_argument("--n-apps", type=int, default=None)
    p.add_argument("--image", type=int, default=None, help="square image side")
    p.add_argument("--reps", type=int, default=None)
    p.add_argument("--out", type=str, default=None,
                   help="merge a 'pipeline' block into this BENCH JSON "
                        "(read-update-write; created if missing)")
    p.add_argument("--check", action="store_true",
                   help="exit nonzero unless fused >= "
                        f"{FUSED_FLOOR_VS_STAGED}x staged e2e and pallas "
                        ">= floor vs xla")
    a = p.parse_args(argv)

    # The acceptance configuration is the full run: depth 3 at 256^2 with
    # 8 tenants.  Smoke keeps the same depth and tenant count on a
    # smaller frame so CI still exercises every code path.
    n_apps = a.n_apps or 8
    image = a.image or (64 if a.smoke else 256)
    reps = a.reps or (3 if a.smoke else 5)

    result = run(n_apps, image, reps)
    mode = "interpret" if result["backends"]["pallas"]["interpret_mode"] \
        else "compiled"
    print(f"pipeline throughput: {'+'.join(CHAIN)} on {result['grid']}, "
          f"{n_apps} chains, {image}x{image} px, {reps} reps")
    print(f"  staged       {result['staged_chains_per_s']:10.1f} chains/s   "
          f"{result['staged_mpixels_per_s']:8.2f} Mpx/s   "
          f"({len(CHAIN)} flushes, host hop between stages)")
    print(f"  fused        {result['fused_chains_per_s']:10.1f} chains/s   "
          f"{result['fused_mpixels_per_s']:8.2f} Mpx/s   "
          f"(1 flush, device-resident intermediates)")
    print(f"  pallas       "
          f"{result['backends']['pallas']['fused_chains_per_s']:10.1f} "
          f"chains/s   (megakernel stage loop, {mode}; "
          f"x{result['pallas_vs_xla_fused']:.2f} vs xla)")
    m = result["mesh"]
    state = "DEGRADED to" if m["degraded"] else "granted"
    print(f"  mesh         {m['fused_chains_per_s']:10.1f} chains/s   "
          f"(requested {m['requested'][0]}x{m['requested'][1]}, {state} "
          f"{m['granted'][0]}x{m['granted'][1]})")
    print(f"  speedup      x{result['fused_vs_staged']:.2f} fused vs staged "
          f"(floor x{FUSED_FLOOR_VS_STAGED})")

    print("BENCH " + json.dumps(result))
    if a.out:
        os.makedirs(os.path.dirname(a.out) or ".", exist_ok=True)
        merged = {}
        if os.path.exists(a.out):
            with open(a.out) as f:
                merged = json.load(f)
        merged["pipeline"] = result
        with open(a.out, "w") as f:
            json.dump(merged, f, indent=2)
        print(f"wrote {a.out} (pipeline block)")

    if a.check:
        fails = []
        if result["fused_vs_staged"] < FUSED_FLOOR_VS_STAGED:
            fails.append(
                f"fused chain x{result['fused_vs_staged']:.2f} < "
                f"x{FUSED_FLOOR_VS_STAGED} vs staged"
            )
        if result["pallas_vs_xla_fused"] < PALLAS_FLOOR_VS_XLA:
            fails.append(
                f"pallas pipeline x{result['pallas_vs_xla_fused']:.2f} < "
                f"x{PALLAS_FLOOR_VS_XLA} vs xla"
            )
        if fails:
            raise SystemExit("FAIL: " + "; ".join(fails))
        print(f"CHECK OK: fused >= x{FUSED_FLOOR_VS_STAGED} staged, "
              f"pallas >= x{PALLAS_FLOOR_VS_XLA} xla")
    return result


if __name__ == "__main__":
    main()
