"""Benchmark runner: one function per paper table/figure.

  resource_table     Table I analogue (conventional vs parameterized HLO resources)
  compile_time       Sec. V-E analogue (overlay compile / map / reconfig gap)
  sobel_throughput   Sec. IV demo (four execution paths of the same Sobel)
  roofline_table     arch x shape roofline from dry-run artifacts (§Roofline)
  fleet_throughput   multi-tenant batched overlay vs sequential dispatch
  serving_latency    streaming front-end latency percentiles at offered load
  pipeline_throughput  device-resident fused chains vs staged per-stage flushes
  chaos_soak         fault-injected self-healing serving vs a fault-free oracle

Prints ``name,us_per_call,derived`` CSV rows at the end for machine
consumption, after the human-readable tables.

``--check`` additionally enforces the fleet-throughput floors (batched
dispatch and fused e2e both >= 2x), the serving-latency floors (p99
bounded at smoke load, zero deadline misses, partial tiles under deadline
pressure), and the pipeline floor (fused chain >= 1.5x the staged
per-stage oracle, merged as a ``pipeline`` block into the fleet JSON),
and writes the BENCH JSONs to the stable
``artifacts/bench/BENCH_fleet.json`` / ``artifacts/bench/BENCH_serving.json``
paths so CI runs accumulate trajectories under one artifact name each.
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback

BENCH_FLEET_JSON = "artifacts/bench/BENCH_fleet.json"
BENCH_SERVING_JSON = "artifacts/bench/BENCH_serving.json"
BENCH_CHAOS_JSON = "artifacts/bench/BENCH_chaos.json"


def main(argv=None) -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--check", action="store_true",
                   help="enforce fleet speedup floors and write the BENCH "
                        f"JSON to {BENCH_FLEET_JSON}")
    args = p.parse_args(argv)

    # Each benchmark imports INSIDE its own try block: a single broken
    # module (or a missing optional dep) must fail that one benchmark
    # loudly -- counted in `failures`, nonzero exit -- instead of an
    # import error here silently killing the whole runner before any
    # floor is checked.
    csv_rows = [("name", "us_per_call", "derived")]
    failures = []

    print("=" * 72)
    print("Benchmark 1: resource table (paper Table I analogue)")
    print("=" * 72)
    try:
        from benchmarks import resource_table

        rows = resource_table.main()
        for r in rows:
            csv_rows.append((
                f"resource/{r['component']}",
                "",
                f"total_ops_reduction={r['total_ops_reduction_pct']:.1f}%",
            ))
    except Exception as e:
        traceback.print_exc()
        failures.append(("resource_table", e))

    print()
    print("=" * 72)
    print("Benchmark 2: compilation gap (paper Sec. V-E analogue)")
    print("=" * 72)
    try:
        from benchmarks import compile_time

        rows = compile_time.main()
        for r in rows:
            csv_rows.append((f"compile/{r['stage']}", f"{r['seconds']*1e6:.1f}", ""))
    except Exception as e:
        traceback.print_exc()
        failures.append(("compile_time", e))

    print()
    print("=" * 72)
    print("Benchmark 3: Sobel execution paths (paper Sec. IV demo)")
    print("=" * 72)
    try:
        from benchmarks import sobel_throughput

        rows = sobel_throughput.main()
        for r in rows:
            csv_rows.append((
                f"sobel/{r['impl']}", f"{r['us_per_image']:.1f}",
                f"speedup={r['speedup_vs_conv']:.2f}",
            ))
    except Exception as e:
        traceback.print_exc()
        failures.append(("sobel_throughput", e))

    print()
    print("=" * 72)
    print("Benchmark 4: roofline table (arch x shape, from dry-run artifacts)")
    print("=" * 72)
    try:
        from benchmarks import roofline_table

        rows = roofline_table.main()
        for r in rows:
            if r.get("bottleneck") not in ("SKIP", "ERROR", None):
                csv_rows.append((
                    f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
                    f"{r['t_compute_s']*1e6 if isinstance(r.get('t_compute_s'), float) else 0:.1f}",
                    f"bottleneck={r['bottleneck']};mfu={r.get('mfu_at_roofline', 0):.4f}",
                ))
    except Exception as e:
        traceback.print_exc()
        failures.append(("roofline_table", e))

    print()
    print("=" * 72)
    print("Benchmark 5: fleet throughput (multi-tenant batched overlay)")
    print("=" * 72)
    try:
        from benchmarks import fleet_throughput

        fleet_args = ["--smoke"]
        if args.check:
            # Mirror CI's smoke-bench job: the --frames sweep adds the
            # per-size tiled/async numbers (and their floors) to the JSON.
            fleet_args += ["--check", "--frames", "--out", BENCH_FLEET_JSON]
        r = fleet_throughput.main(fleet_args)
        csv_rows.append((
            "fleet/batched_vs_sequential",
            f"{1e6 / r['batched_apps_per_s']:.1f}",
            f"speedup={r['speedup']:.2f};apps={r['n_apps']}",
        ))
        csv_rows.append((
            "fleet/fused_vs_unfused_e2e",
            f"{1e6 / r['fused_e2e_apps_per_s']:.1f}",
            f"speedup_e2e={r['speedup_e2e']:.2f};"
            f"pack_fraction={r['pack_fraction_fused']:.3f}",
        ))
    except (Exception, SystemExit) as e:
        traceback.print_exc()
        failures.append(("fleet_throughput", e))

    print()
    print("=" * 72)
    print("Benchmark 6: serving latency (streaming front-end, offered load)")
    print("=" * 72)
    try:
        from benchmarks import serving_latency

        serving_args = ["--smoke"]
        if args.check:
            serving_args += ["--check", "--out", BENCH_SERVING_JSON]
        r = serving_latency.main(serving_args)
        lat = r["loaded"]["latency"]
        csv_rows.append((
            "serving/p99_total",
            f"{1e6 * lat['total_s']['p99']:.1f}",
            f"p50={1e3*lat['total_s']['p50']:.2f}ms;"
            f"misses={lat['deadline_misses']};"
            f"partial_tiles={r['deadline']['partial_tile_dispatches']}",
        ))
    except (Exception, SystemExit) as e:
        traceback.print_exc()
        failures.append(("serving_latency", e))

    print()
    print("=" * 72)
    print("Benchmark 7: pipeline throughput (fused chains vs staged flushes)")
    print("=" * 72)
    try:
        from benchmarks import pipeline_throughput

        pipe_args = ["--smoke"]
        if args.check:
            # Runs AFTER Benchmark 5 so the 'pipeline' block merges into
            # the fleet JSON that fleet_throughput already wrote -- CI
            # uploads ONE artifact covering both.
            pipe_args += ["--check", "--out", BENCH_FLEET_JSON]
        r = pipeline_throughput.main(pipe_args)
        csv_rows.append((
            "pipeline/fused_vs_staged",
            f"{1e6 / r['fused_chains_per_s']:.1f}",
            f"speedup={r['fused_vs_staged']:.2f};depth={r['depth']};"
            f"chains={r['n_apps']}",
        ))
    except (Exception, SystemExit) as e:
        traceback.print_exc()
        failures.append(("pipeline_throughput", e))

    print()
    print("=" * 72)
    print("Benchmark 8: chaos soak (fault-injected self-healing serving)")
    print("=" * 72)
    try:
        from benchmarks import chaos_soak

        chaos_args = ["--smoke"]
        if args.check:
            chaos_args += ["--check", "--out", BENCH_CHAOS_JSON]
        r = chaos_soak.main(chaos_args)
        s = r["soak"]
        csv_rows.append((
            "chaos/availability",
            f"{1e6 * s['latency']['total_s']['p99']:.1f}",
            f"availability={s['availability_nonpoisoned']:.4f};"
            f"quarantined={s['quarantined']};hung={s['hung_handles']};"
            f"restarts={s['worker_restarts']};"
            f"breaker_recovered={s['breaker']['recovered']}",
        ))
    except (Exception, SystemExit) as e:
        traceback.print_exc()
        failures.append(("chaos_soak", e))

    print()
    print("name,us_per_call,derived")
    for name, us, derived in csv_rows[1:]:
        print(f"{name},{us},{derived}")

    if failures:
        print(f"\n{len(failures)} benchmark(s) FAILED: {[f[0] for f in failures]}")
        sys.exit(1)


if __name__ == "__main__":
    main()
