"""Benchmark 1 — Table I analogue: conventional vs parameterized resources.

For each VCGRA component (single VC, fixed-point PE, floating-point PE,
the 4x4 grid, the Sobel grid) compile both executor variants and census
the optimized HLO: total/routing/mux/arith op counts + FLOPs + bytes,
with reduction percentages.  The paper's corresponding numbers: 82 % LUT
reduction per VC, 5 % per fixed PE, 24 % per FP PE, 6 % for the grid.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DFG, Op, OverlayPlan, compile_plan, for_dfg, map_app, paper_4x4, sobel_grid
from repro.core import applications as apps
from repro.core.analysis import compile_and_census, format_table, reduction_row
from repro.core.grid import custom
from repro.core.specialize import build_specialized_fn

BATCH = 4096


def _census_pair(grid, config, batch=BATCH):
    x = jnp.zeros((grid.num_inputs, batch), grid.dtype)
    conv = compile_and_census(
        lambda c, xx: compile_plan(OverlayPlan(grid=grid))(c, xx),
        config.to_jax(), x
    )
    spec = compile_and_census(build_specialized_fn(grid, config), x)
    return conv, spec


def bench_vc():
    """A single virtual channel in isolation: one level of BUF PEs routing
    8 inputs to 4 outputs (pure routing fabric)."""
    g = DFG("vc_only")
    ins = [g.input(f"i{k}") for k in range(8)]
    for k in (3, 1, 6, 3):      # fan-out + permutation, like a real VC config
        g.output(g.buf(ins[k]))
    grid = custom("vc1", 8, [4], num_outputs=4)
    return _census_pair(grid, map_app(g, grid))


def bench_pe(float_pe: bool):
    g = DFG("pe_only")
    a, b = g.input("a"), g.input("b")
    g.output(g.mul(a, b))
    grid = custom("pe1", 2, [1], num_outputs=1, float_pe=float_pe)
    return _census_pair(grid, map_app(g, grid))


def bench_grid_4x4():
    """The paper's fully parameterized 4x4 grid running an 8-input
    reduction tree."""
    g = DFG("reduce8")
    ins = [g.input(f"i{k}") for k in range(8)]
    terms = [g.add(ins[i], ins[i + 1]) for i in range(0, 8, 2)]
    terms = [g.add(terms[0], terms[1]), g.add(terms[2], terms[3])]
    g.output(g.add(terms[0], terms[1]))
    grid = paper_4x4()
    return _census_pair(grid, map_app(g, grid))


def bench_sobel_grid():
    g = apps.sobel_x()
    grid = sobel_grid()
    return _census_pair(grid, map_app(g, grid))


def run():
    rows = []
    for name, fn in [
        ("VC (8->4 routing)", bench_vc),
        ("PE fixed-point", lambda: bench_pe(False)),
        ("PE floating-point", lambda: bench_pe(True)),
        ("4x4 grid (reduce8)", bench_grid_4x4),
        ("Sobel grid (45 PE, Fig.5)", bench_sobel_grid),
    ]:
        conv, spec = fn()
        rows.append(reduction_row(name, conv, spec))
    return rows


def main():
    rows = run()
    cols = ["component", "total_ops_conv", "total_ops_param",
            "total_ops_reduction_pct", "routing_ops_conv", "routing_ops_param",
            "mux_ops_conv", "mux_ops_param", "flops_reduction_pct",
            "bytes_reduction_pct"]
    print(format_table(rows, cols))
    return rows


if __name__ == "__main__":
    main()
