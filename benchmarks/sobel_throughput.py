"""Benchmark 3 — Sobel execution-path comparison (paper Sec. IV demo).

Four implementations of the same Sobel magnitude, identical outputs:

  overlay-conventional   compile-once generic interpreter (paper baseline)
  overlay-parameterized  constant-specialized executor (paper's optimization)
  pallas-vcgra           specialized grid as a Pallas TPU kernel (interpret
                         mode on CPU; VMEM-tiled on real TPU)
  fused-stencil          beyond-paper fully-fused kernel (roofline target)

Reports us/image and relative speedups (CPU wall-clock is a proxy; the
structural comparison -- ops and bytes -- comes from benchmark 1).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Pixie, for_dfg, map_app
from repro.core import applications as apps
from repro.kernels.stencil import sobel_magnitude_fused, stencil_ref
from repro.kernels.vcgra import vcgra_apply_image

IMAGE = (256, 256)
REPS = 5


def _time(fn):
    fn()  # warm / compile
    t0 = time.perf_counter()
    for _ in range(REPS):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / REPS


def run():
    img = jnp.asarray(
        np.random.default_rng(0).integers(0, 256, IMAGE).astype(np.int32)
    )
    dfg = apps.sobel_magnitude()
    grid = for_dfg(dfg, shape="exact")
    cfg = map_app(dfg, grid)

    pix_c = Pixie(grid, mode="conventional")
    pix_c.load(cfg)
    pix_p = Pixie(grid, mode="parameterized")
    pix_p.load(cfg, batch=img.size)

    ref = np.asarray(stencil_ref(img, (apps.SOBEL_X, apps.SOBEL_Y)))

    impls = {
        "overlay-conventional": lambda: pix_c.run_image(img),
        "overlay-parameterized": lambda: pix_p.run_image(img),
        "pallas-vcgra": lambda: vcgra_apply_image(grid, cfg, img, block_n=2048),
        "fused-stencil": lambda: sobel_magnitude_fused(img),
    }
    rows = []
    base = None
    for name, fn in impls.items():
        out = np.asarray(fn())
        np.testing.assert_array_equal(out, ref)  # all paths identical
        us = _time(fn) * 1e6
        base = base or us
        rows.append({"impl": name, "us_per_image": us, "speedup_vs_conv": base / us})
    return rows


def main():
    rows = run()
    for r in rows:
        print(f"{r['impl']:24s} {r['us_per_image']:12.1f} us/img   "
              f"x{r['speedup_vs_conv']:.2f} vs conventional")
    return rows


if __name__ == "__main__":
    main()
