"""Benchmark 5 — fleet throughput: multi-tenant batched overlay dispatch,
with and without fused device-side ingest.

The overlay's compile-once economics (paper Sec. V-E) amortize the FPGA
compile across applications *in time* (sequential reconfiguration); the
fleet runtime amortizes it *in space*: N different applications stacked
into one vmapped dispatch of the same executable.  Every measured path is
one cell of the `OverlayPlan` axis product, compiled by the single
entrypoint `repro.core.plan.compile_plan` (PR 1 measured that the batched
dispatch got ~2.6x faster while end-to-end serving was capped at ~1.7x by
per-request input packing -- ~20 host-issued device ops per frame; the
fused plans below are what closed that gap):

  sequential     one conventional `Pixie`, N per-app dispatches of the
                 compiled overlay (settings swap between calls)
  batched        ONE dispatch of the batched (pre-packed channels)
                 `OverlayPlan` over the N stacked configs
  unfused e2e    per-request `stencil_inputs` + `pack_inputs` + dispatch
                 (the PR 1 serving path, kept as the oracle)
  fused e2e      `PixieFleet.run_many` on raw frames -- a fused batched
                 `OverlayPlan`: pack + dispatch + unpack as ONE
                 executable per grid
  pallas e2e     the same fused fleet plan on `backend="pallas"`: the
                 batched fused-ingest megakernel (interpret mode off-TPU),
                 measured so the BENCH trajectory covers both backends

`--frames` additionally sweeps frame sizes (default 32^2/128^2/256^2) and
records, per size, the row-tiled vs untiled fused plans (`tile_rows`) and
the sync vs async double-buffered ingest pipelines (`ingest`) -- the two
PR 5 plan axes -- into a `frames` block of the BENCH JSON.

Identical inputs, bitwise-identical outputs (asserted), compile-once
invariants asserted via the fleet's cache counters.  Emits a machine-
readable ``BENCH {json}`` line (incl. the pack fraction of both e2e
paths) plus a JSON artifact for CI trend tracking (``--out``).

Usage:
  python benchmarks/fleet_throughput.py                 # full run
  python benchmarks/fleet_throughput.py --smoke         # CI-sized (<30 s)
  python benchmarks/fleet_throughput.py --frames        # + size sweep
  python benchmarks/fleet_throughput.py --check         # exit 1 on floors
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import MeshSpec, Pixie, sobel_grid
from repro.core import applications as apps
from repro.core.bitstream import VCGRAConfig
from repro.core.interpreter import pack_inputs, pad_channels
from repro.core.tiling import TILE_AUTO, hbm_read_model, resolve_tile_rows
from repro.kernels.vcgra import default_interpret
from repro.runtime.fleet import FleetRequest, PixieFleet

# Library apps that fit the paper's 18-input Sobel grid.
FLEET_APPS = ["sobel_x", "sobel_y", "sharpen", "laplace", "threshold", "identity"]

# The pallas megakernel runs in *interpret mode* on CPU CI, so it is not
# expected to beat the hand-lowered XLA path there -- the floor only guards
# against catastrophic regressions (a broken kernel, an accidental
# per-frame retrace).  Measured ~0.5x of the XLA fused path on CPU.
PALLAS_FLOOR_VS_XLA = 0.05


def _time(fn, reps: int) -> float:
    jax.block_until_ready(fn())  # warm / compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / reps


def run(n_apps: int, image_hw: int, reps: int) -> dict:
    rng = np.random.default_rng(0)
    grid = sobel_grid()
    img = jnp.asarray(rng.integers(0, 256, (image_hw, image_hw)).astype(np.int32))
    taps = apps.stencil_inputs(img)

    names = [FLEET_APPS[i % len(FLEET_APPS)] for i in range(n_apps)]
    fleet = PixieFleet(default_grid=grid, batch_tile=n_apps)
    configs = [fleet.config_for(n, grid) for n in names]
    xs = [
        pad_channels(pack_inputs(c, {k: v for k, v in taps.items()
                                     if k in c.input_order}, grid.dtype),
                     grid.num_inputs)
        for c in configs
    ]

    # -- sequential baseline: N per-app dispatches of the compiled overlay --
    pix = Pixie(grid, mode="conventional")
    pix.compile_overlay(batch=img.size)
    overlay = pix._overlay_fn
    cfg_jax = [c.to_jax() for c in configs]

    def sequential():
        return [overlay(cj, x) for cj, x in zip(cfg_jax, xs)]

    # -- batched fleet dispatch: ONE dispatch for all N tenants --------------
    batched_fn = fleet.overlay_for(grid)
    stacked = VCGRAConfig.stack(configs)
    xstack = jnp.stack(xs)

    def batched():
        return batched_fn(stacked, xstack)

    # bitwise-identical outputs
    seq_out = [np.asarray(y) for y in sequential()]
    bat_out = np.asarray(batched())
    for i in range(n_apps):
        np.testing.assert_array_equal(bat_out[i], seq_out[i])

    t_seq = _time(sequential, reps)
    t_bat = _time(batched, reps)

    # -- end-to-end service paths --------------------------------------------
    # unfused: the PR 1 serving cost -- per-request host-side tap formation
    # and packing (~20 device ops/frame) + one dispatch per app.
    def unfused_e2e():
        outs = []
        for c in configs:
            t = apps.stencil_inputs(img)
            feed = {k: v for k, v in t.items() if k in c.input_order}
            x = pad_channels(pack_inputs(c, feed, grid.dtype), grid.num_inputs)
            outs.append(overlay(c.to_jax(), x))
        return outs

    # fused: raw frames into the fleet; line buffers form inside the ONE
    # batched dispatch per grid.
    requests = [FleetRequest(app=n, image=img) for n in names]

    def fused_e2e():
        return fleet.run_many(requests)

    # fused outputs == unfused outputs, bitwise
    fused_out = fused_e2e()
    for i in range(n_apps):
        np.testing.assert_array_equal(
            np.asarray(fused_out[i]).reshape(-1), seq_out[i].reshape(-1)
        )

    t_unfused_e2e = _time(unfused_e2e, reps)
    fused_e2e()  # warm (compiles happened above, but keep windows aligned)
    pack0, disp0 = fleet.timings["pack_s"], fleet.timings["dispatch_s"]
    t0 = time.perf_counter()
    for _ in range(reps):
        fused_e2e()
    t_fused_e2e = (time.perf_counter() - t0) / reps
    # pack_s/dispatch_s deltas cover exactly the `reps` timed rounds.
    pack_s = fleet.timings["pack_s"] - pack0
    dispatch_s = fleet.timings["dispatch_s"] - disp0

    # -- pallas backend: the batched fused-ingest megakernel ------------------
    # Same fleet contract, backend="pallas"; bitwise-asserted against the
    # sequential oracle, then timed (fewer reps -- interpret mode is the
    # expected-slower path on CPU; on TPU this is the compiled path).
    pallas_fleet = PixieFleet(default_grid=grid, batch_tile=n_apps,
                              backend="pallas")
    for n in names:
        pallas_fleet.config_for(n, grid)  # warm the config cache like `fleet`

    def pallas_e2e():
        return pallas_fleet.run_many(requests)

    pallas_out = pallas_e2e()
    for i in range(n_apps):
        np.testing.assert_array_equal(
            np.asarray(pallas_out[i]).reshape(-1), seq_out[i].reshape(-1)
        )
    pallas_reps = max(2, reps // 3)
    t_pallas_e2e = _time(pallas_e2e, pallas_reps)
    assert pallas_fleet.stats.overlay_builds == 1, pallas_fleet.stats.as_dict()
    assert pallas_fleet.stats.backend == "pallas"

    # -- mesh-sharded fused e2e: the 2-D (app x rows) scale-out axis ----------
    # The spec is requested unconditionally; hosts with too few local
    # devices degrade to the bitwise single-device fallback, and the
    # BENCH stamp records requested vs granted truthfully -- a dashboard
    # reading this JSON can never mistake a degraded fleet for a sharded
    # one.  (CI's mesh2d-parity job forces four host devices, so there
    # the 2x2 mesh is actually granted.)
    n_dev = len(jax.local_devices())
    mesh_spec = MeshSpec(app=2, rows=2) if n_dev >= 4 else MeshSpec(app=2)
    mesh_fleet = PixieFleet(default_grid=grid, batch_tile=n_apps,
                            mesh=mesh_spec)
    for n in names:
        mesh_fleet.config_for(n, grid)

    def mesh_e2e():
        return mesh_fleet.run_many(requests)

    mesh_out = mesh_e2e()
    for i in range(n_apps):
        np.testing.assert_array_equal(
            np.asarray(mesh_out[i]).reshape(-1), seq_out[i].reshape(-1)
        )
    t_mesh_e2e = _time(mesh_e2e, max(2, reps // 3))

    # pack fraction: share of the e2e cost spent *outside* the dispatch.
    pack_fraction_unfused = max(0.0, (t_unfused_e2e - t_seq) / t_unfused_e2e)
    pack_fraction_fused = pack_s / (pack_s + dispatch_s) if pack_s + dispatch_s else 0.0

    # compile-once invariant: ONE fused overlay build for the grid, and
    # canvas tiling kept it at ONE XLA executable (-1 = this jax version
    # has no jit-cache introspection; overlay_builds is the stable counter).
    assert fleet.stats.overlay_builds == 2, fleet.stats.as_dict()  # fused + unfused
    assert fleet.overlay_executable_count(grid) in (2, -1), fleet.stats.as_dict()
    assert fleet.stats.fused_dispatches >= 1, fleet.stats.as_dict()
    assert fleet.stats.config_cache_hits >= n_apps, fleet.stats.as_dict()
    assert fleet.stats.stack_bank_hits >= 1, fleet.stats.as_dict()

    # plan-cache behavior of the fleet's overlay LRU (keyed by OverlayPlan):
    # hit rate ~1 after warmup is the compile-once contract at fleet scale.
    plan_lookups = fleet._overlays.hits + fleet._overlays.misses
    plan_cache = {
        "hits": fleet._overlays.hits,
        "misses": fleet._overlays.misses,
        "hit_rate": fleet._overlays.hits / plan_lookups if plan_lookups else 0.0,
        "plans": sorted(p.key() for p in fleet._overlays._d),
    }

    pixels = img.size * n_apps
    return {
        "bench": "fleet_throughput",
        "n_apps": n_apps,
        "image": [image_hw, image_hw],
        "grid": grid.name,
        "apps": names,
        "device_count": len(jax.local_devices()),
        "plan_cache": plan_cache,
        "sequential_s_per_round": t_seq,
        "batched_s_per_round": t_bat,
        "unfused_e2e_s_per_round": t_unfused_e2e,
        "fused_e2e_s_per_round": t_fused_e2e,
        "sequential_apps_per_s": n_apps / t_seq,
        "batched_apps_per_s": n_apps / t_bat,
        "unfused_e2e_apps_per_s": n_apps / t_unfused_e2e,
        "fused_e2e_apps_per_s": n_apps / t_fused_e2e,
        "sequential_mpixels_per_s": pixels / t_seq / 1e6,
        "batched_mpixels_per_s": pixels / t_bat / 1e6,
        "fused_e2e_mpixels_per_s": pixels / t_fused_e2e / 1e6,
        "speedup": t_seq / t_bat,
        "speedup_e2e": t_unfused_e2e / t_fused_e2e,
        "pack_fraction_unfused": pack_fraction_unfused,
        "pack_fraction_fused": pack_fraction_fused,
        "fleet_pack_s_per_round": pack_s / reps,
        "fleet_dispatch_s_per_round": dispatch_s / reps,
        "fleet_stats": fleet.stats.as_dict(),
        "overlay_executables": fleet.overlay_executable_count(grid),
        # per-backend fused e2e numbers, stable keys for the trajectory
        "backends": {
            "xla": {"fused_e2e_s_per_round": t_fused_e2e,
                    "fused_e2e_apps_per_s": n_apps / t_fused_e2e},
            "pallas": {"fused_e2e_s_per_round": t_pallas_e2e,
                       "fused_e2e_apps_per_s": n_apps / t_pallas_e2e,
                       "interpret_mode": default_interpret()},
        },
        "pallas_fused_e2e_apps_per_s": n_apps / t_pallas_e2e,
        "pallas_vs_xla_fused_e2e": t_fused_e2e / t_pallas_e2e,
        "pallas_floor_vs_xla": PALLAS_FLOOR_VS_XLA,
        "pallas_fleet_stats": pallas_fleet.stats.as_dict(),
        # Truthful mesh stamp (requested vs granted placement + the
        # degraded flag) -- serving dashboards read THIS, not the spec.
        "mesh": {
            "requested": list(mesh_fleet.stats.mesh_requested),
            "granted": list(mesh_fleet.stats.mesh_granted),
            "degraded": mesh_fleet.stats.mesh_degraded,
            "fused_e2e_s_per_round": t_mesh_e2e,
            "fused_e2e_apps_per_s": n_apps / t_mesh_e2e,
        },
        "mesh_fleet_stats": mesh_fleet.stats.as_dict(),
    }


def run_frames(n_apps: int, sizes, reps: int) -> dict:
    """The PR 5 plan-axes sweep: per frame size, fused e2e throughput of

      sync_untiled    tile_rows=None, ingest="sync"  (the PR 4 baseline)
      sync_tiled      tile_rows=side//4 (a real multi-tile split at every
                      size, unlike TILE_AUTO which stays untiled at smoke
                      sizes), ingest="sync"
      async_tiled     same tiling + the double-buffered ingest pipeline
                      (pooled donated canvases, lazy outputs)
      pallas_tiled    the same row tiling on backend="pallas": the tiled
                      megakernel with the PR 7 in-kernel double-buffered
                      HBM->VMEM DMA pipeline (interpret mode off-TPU; on
                      a TPU runner this measures the compiled
                      pallas/xla fused-e2e ratio the ISSUE asks for)

    All are bitwise-asserted against each other before timing.  Timed
    rounds call ``jax.block_until_ready`` on the outputs, so the async
    path's laziness is charged honestly -- its win must come from real
    pack/execute overlap, not deferred work escaping the clock.  The
    pallas variant is timed after the interleaved loop with its own
    (smaller) rep count: in interpret mode it is orders of magnitude off
    and would starve the interleaving.

    Each size also records an ``hbm_model`` column: the modelled
    per-frame HBM traffic (``tiling.hbm_read_model``) of the old
    host-pre-sliced slab layout vs the in-kernel DMA pipeline -- the
    ``1 + 2r/tile_rows`` read amplification (paid twice: slabs written,
    then streamed back) collapsing to ~1x seam re-reads and zero halo
    writes.
    """
    rng = np.random.default_rng(1)
    grid = sobel_grid()
    names = [FLEET_APPS[i % len(FLEET_APPS)] for i in range(n_apps)]
    frames = {}
    for side in sizes:
        img = rng.integers(0, 256, (side, side)).astype(np.int32)
        requests = [FleetRequest(app=n, image=img) for n in names]
        tile = max(8, side // 4)
        variants = {
            "sync_untiled": dict(ingest="sync", tile_rows=None),
            "sync_tiled": dict(ingest="sync", tile_rows=tile),
            "async_tiled": dict(ingest="async", tile_rows=tile),
        }
        # Larger frames amortize per-round overhead: fewer reps suffice
        # (but keep enough for the best-of estimator to settle).
        reps_side = max(8, reps // max(1, side // 32))
        itemsize = jnp.dtype(grid.dtype).itemsize
        entry = {
            "n_apps": n_apps,
            "tile_rows": tile,
            "auto_tile_rows": resolve_tile_rows(TILE_AUTO, side, side, 1, grid),
            "reps": reps_side,
            # Modelled per-frame HBM traffic of the two tiled lowerings
            # at this (side, tile): the old host-pre-sliced slab tensor
            # vs the PR 7 in-kernel DMA (seam re-reads only, no halo
            # writes).  ``hbm_bytes_read`` / ``read_amplification`` are
            # the trajectory columns.
            "hbm_model": {
                "presliced": hbm_read_model(side, side, 1, tile, itemsize,
                                            presliced=True),
                "dma": hbm_read_model(side, side, 1, tile, itemsize,
                                      presliced=False),
            },
        }
        # Warm every variant (compile + bitwise-assert), then time them
        # INTERLEAVED round-robin with a best-of estimator: scheduler load
        # on shared CI hosts drifts over seconds, so timing the variants
        # one after another would hand whichever ran during a quiet spell
        # a spurious win -- interleaving exposes all three to the same
        # noise and the min filters it.
        fleets, e2es, best = {}, {}, {}
        ref = None
        for key, axes in variants.items():
            fleet = PixieFleet(default_grid=grid, batch_tile=n_apps, **axes)

            def e2e(fleet=fleet):
                return jax.block_until_ready(fleet.run_many(requests))

            outs = e2e()   # warm + compile
            if ref is None:
                ref = [np.asarray(o) for o in outs]
            else:
                for a, b in zip(ref, outs):
                    np.testing.assert_array_equal(a, np.asarray(b))
            e2e()          # second warm round settles the canvas pool
            fleets[key], e2es[key], best[key] = fleet, e2e, float("inf")
        for _ in range(reps_side):
            for key, e2e in e2es.items():
                t0 = time.perf_counter()
                e2e()
                best[key] = min(best[key], time.perf_counter() - t0)
        for key, axes in variants.items():
            fleet, t = fleets[key], best[key]
            entry[key] = {
                "e2e_s_per_round": t,
                "e2e_apps_per_s": n_apps / t,
                "e2e_mpixels_per_s": n_apps * side * side / t / 1e6,
            }
            # compile-once must hold per variant (one fused plan each)
            assert fleet.stats.overlay_builds == 1, fleet.stats.as_dict()
            if axes["ingest"] == "async":
                entry[key]["ingest_overlap_s"] = fleet.stats.ingest_overlap_s
                entry[key]["canvas_pool_hits"] = fleet.stats.canvas_pool_hits
        entry["tiled_vs_untiled"] = (
            entry["sync_tiled"]["e2e_apps_per_s"]
            / entry["sync_untiled"]["e2e_apps_per_s"]
        )
        entry["async_vs_sync"] = (
            entry["async_tiled"]["e2e_apps_per_s"]
            / entry["sync_tiled"]["e2e_apps_per_s"]
        )

        # -- pallas tiled: the in-kernel DMA megakernel at this size ------
        # Bitwise-asserted, then timed on its own (fewer reps, not
        # interleaved): interpret mode off-TPU is the expected-slower
        # path; on a TPU runner this IS the compiled fused-e2e ratio.
        pallas_fleet = PixieFleet(default_grid=grid, batch_tile=n_apps,
                                  backend="pallas", tile_rows=tile)

        def pallas_e2e():
            return jax.block_until_ready(pallas_fleet.run_many(requests))

        for a, b in zip(ref, pallas_e2e()):
            np.testing.assert_array_equal(a, np.asarray(b))
        t_pallas = float("inf")
        for _ in range(max(2, reps_side // 8)):
            t0 = time.perf_counter()
            pallas_e2e()
            t_pallas = min(t_pallas, time.perf_counter() - t0)
        assert pallas_fleet.stats.overlay_builds == 1, \
            pallas_fleet.stats.as_dict()
        entry["pallas_tiled"] = {
            "e2e_s_per_round": t_pallas,
            "e2e_apps_per_s": n_apps / t_pallas,
            "e2e_mpixels_per_s": n_apps * side * side / t_pallas / 1e6,
            "interpret_mode": default_interpret(),
            "hbm_bytes_read": entry["hbm_model"]["dma"]["hbm_bytes_read"],
        }
        entry["pallas_vs_xla_tiled"] = (
            entry["pallas_tiled"]["e2e_apps_per_s"]
            / entry["sync_tiled"]["e2e_apps_per_s"]
        )
        frames[str(side)] = entry
    return frames


def main(argv=None) -> dict:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--smoke", action="store_true", help="CI-sized quick run")
    p.add_argument("--n-apps", type=int, default=None)
    p.add_argument("--image", type=int, default=None, help="square image side")
    p.add_argument("--reps", type=int, default=None)
    p.add_argument("--out", type=str, default=None, help="write BENCH JSON here")
    p.add_argument("--frames", type=int, nargs="*", default=None,
                   help="sweep these square frame sides (bare flag: 32 128 "
                        "256) recording tiled-vs-untiled and sync-vs-async "
                        "fused e2e per size")
    p.add_argument("--check", action="store_true",
                   help="exit nonzero unless batched >= 2x sequential, fused "
                        "e2e >= 2x unfused e2e, pallas >= floor -- and, with "
                        "--frames, tiled >= 0.8x untiled at 32^2 and async "
                        ">= sync at 256^2")
    a = p.parse_args(argv)

    # Many small frames is the fleet's target regime (per-dispatch overhead
    # dominates); at large frames both paths converge on the same
    # compute-bound Mpx/s and batching only saves the dispatch tax.
    n_apps = a.n_apps or (8 if a.smoke else 16)
    image = a.image or 32
    reps = a.reps or (5 if a.smoke else 30)

    result = run(n_apps, image, reps)
    if a.frames is not None:
        result["frames"] = run_frames(n_apps, a.frames or [32, 128, 256], reps)
    print(f"fleet throughput: {n_apps} apps on {result['grid']}, "
          f"{image}x{image} px, {reps} reps")
    print(f"  sequential   {result['sequential_apps_per_s']:10.1f} apps/s   "
          f"{result['sequential_mpixels_per_s']:8.2f} Mpx/s   (dispatch only)")
    print(f"  batched      {result['batched_apps_per_s']:10.1f} apps/s   "
          f"{result['batched_mpixels_per_s']:8.2f} Mpx/s   (dispatch only)")
    print(f"  unfused e2e  {result['unfused_e2e_apps_per_s']:10.1f} apps/s   "
          f"(pack fraction {100*result['pack_fraction_unfused']:.0f}%)")
    print(f"  fused e2e    {result['fused_e2e_apps_per_s']:10.1f} apps/s   "
          f"(pack fraction {100*result['pack_fraction_fused']:.0f}%)")
    mode = "interpret" if result["backends"]["pallas"]["interpret_mode"] else "compiled"
    print(f"  pallas e2e   {result['pallas_fused_e2e_apps_per_s']:10.1f} apps/s   "
          f"(megakernel, {mode}; x{result['pallas_vs_xla_fused_e2e']:.2f} vs xla)")
    print(f"  speedup      x{result['speedup']:.2f} dispatch, "
          f"x{result['speedup_e2e']:.2f} e2e   "
          f"(overlay builds={result['fleet_stats']['overlay_builds']}, "
          f"xla executables={result['overlay_executables']})")
    print(f"  plan cache   hit rate {result['plan_cache']['hit_rate']:.2f} "
          f"over {len(result['plan_cache']['plans'])} plans, "
          f"{result['device_count']} device(s)")
    m = result["mesh"]
    state = "DEGRADED to" if m["degraded"] else "granted"
    print(f"  mesh e2e     {m['fused_e2e_apps_per_s']:10.1f} apps/s   "
          f"(requested {m['requested'][0]}x{m['requested'][1]}, {state} "
          f"{m['granted'][0]}x{m['granted'][1]})")
    for side, e in result.get("frames", {}).items():
        print(f"  {side:>4}^2 px    "
              f"untiled {e['sync_untiled']['e2e_apps_per_s']:8.1f}  "
              f"tiled(r{e['tile_rows']}) {e['sync_tiled']['e2e_apps_per_s']:8.1f}  "
              f"async {e['async_tiled']['e2e_apps_per_s']:8.1f}  "
              f"pallas {e['pallas_tiled']['e2e_apps_per_s']:8.1f} apps/s  "
              f"(x{e['tiled_vs_untiled']:.2f} tiled, "
              f"x{e['async_vs_sync']:.2f} async, "
              f"x{e['pallas_vs_xla_tiled']:.2f} pallas, "
              f"auto tile {e['auto_tile_rows']}, "
              f"hbm reads x{e['hbm_model']['dma']['read_amplification']:.2f} "
              f"dma vs "
              f"x{e['hbm_model']['presliced']['read_amplification']:.2f} "
              f"presliced)")

    print("BENCH " + json.dumps(result))
    if a.out:
        os.makedirs(os.path.dirname(a.out) or ".", exist_ok=True)
        with open(a.out, "w") as f:
            json.dump(result, f, indent=2)
        print(f"wrote {a.out}")

    if a.check:
        fails = []
        if result["speedup"] < 2.0:
            fails.append(f"batched dispatch x{result['speedup']:.2f} < x2")
        if result["speedup_e2e"] < 2.0:
            fails.append(f"fused e2e x{result['speedup_e2e']:.2f} < x2")
        if result["pallas_vs_xla_fused_e2e"] < PALLAS_FLOOR_VS_XLA:
            fails.append(
                f"pallas fused e2e x{result['pallas_vs_xla_fused_e2e']:.3f} "
                f"of xla < floor x{PALLAS_FLOOR_VS_XLA}"
            )
        frames = result.get("frames", {})
        if "32" in frames and frames["32"]["tiled_vs_untiled"] < 0.8:
            # Tiling buys nothing at smoke sizes (the auto heuristic stays
            # untiled there); the floor only guards against the tiled
            # executors regressing catastrophically.
            fails.append(
                f"tiled fused e2e x{frames['32']['tiled_vs_untiled']:.2f} "
                f"of untiled at 32^2 < floor x0.8"
            )
        for side, e in frames.items():
            # The DMA pipeline's whole point, as a model invariant: fewer
            # modelled HBM bytes read than the pre-sliced slab layout at
            # every measured (side, tile), and ~1x frame-size reads.
            dma = e["hbm_model"]["dma"]
            pre = e["hbm_model"]["presliced"]
            if not (dma["hbm_bytes_read"] < pre["hbm_bytes_read"]
                    and dma["hbm_halo_bytes_written"] == 0
                    and dma["read_amplification"] < 1.5):
                fails.append(
                    f"hbm model at {side}^2: dma reads "
                    f"x{dma['read_amplification']:.2f} not < presliced "
                    f"x{pre['read_amplification']:.2f} (or halo writes "
                    f"nonzero)"
                )
        if "32" in frames and frames["32"]["pallas_vs_xla_tiled"] < PALLAS_FLOOR_VS_XLA:
            fails.append(
                f"pallas tiled fused e2e x"
                f"{frames['32']['pallas_vs_xla_tiled']:.3f} of xla tiled at "
                f"32^2 < floor x{PALLAS_FLOOR_VS_XLA}"
            )
        if "256" in frames:
            if frames["256"]["async_vs_sync"] < 1.0:
                fails.append(
                    f"async fused e2e x{frames['256']['async_vs_sync']:.2f} "
                    f"of sync at 256^2 < floor x1.0"
                )
            beats = (frames["256"]["async_tiled"]["e2e_apps_per_s"]
                     / frames["256"]["sync_untiled"]["e2e_apps_per_s"])
            if beats < 1.0:
                fails.append(
                    f"async+tiled fused e2e x{beats:.2f} of the sync "
                    f"untiled path at 256^2 < floor x1.0"
                )
        if fails:
            raise SystemExit("FAIL: " + "; ".join(fails))
    return result


if __name__ == "__main__":
    main()
