"""Benchmark 5 — fleet throughput: multi-tenant batched overlay dispatch,
with and without fused device-side ingest.

The overlay's compile-once economics (paper Sec. V-E) amortize the FPGA
compile across applications *in time* (sequential reconfiguration); the
fleet runtime amortizes it *in space*: N different applications stacked
into one vmapped dispatch of the same executable.  PR 1 measured that the
dispatch itself got ~2.6x faster while end-to-end serving was capped at
~1.7x by per-request input packing (~20 host-issued device ops per frame);
this benchmark additionally measures the fused-ingest path (line-buffer
formation *inside* the dispatch, `make_batched_fused_overlay_fn`) that
closes that gap:

  sequential     one conventional `Pixie`, N per-app dispatches of the
                 compiled overlay (settings swap between calls)
  batched        one `make_batched_overlay_fn` dispatch over the N stacked
                 configs (pre-packed inputs)
  unfused e2e    per-request `stencil_inputs` + `pack_inputs` + dispatch
                 (the PR 1 serving path, kept as the oracle)
  fused e2e      `PixieFleet.run_many` on raw frames: pack + dispatch +
                 unpack as ONE executable per grid
  pallas e2e     the same fused fleet path on `backend="pallas"`: the
                 batched fused-ingest megakernel (interpret mode off-TPU),
                 measured so the BENCH trajectory covers both backends

Identical inputs, bitwise-identical outputs (asserted), compile-once
invariants asserted via the fleet's cache counters.  Emits a machine-
readable ``BENCH {json}`` line (incl. the pack fraction of both e2e
paths) plus a JSON artifact for CI trend tracking (``--out``).

Usage:
  python benchmarks/fleet_throughput.py            # full run
  python benchmarks/fleet_throughput.py --smoke    # CI-sized (<30 s)
  python benchmarks/fleet_throughput.py --check    # exit 1 if < 2x
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Pixie, sobel_grid
from repro.core import applications as apps
from repro.core.bitstream import VCGRAConfig
from repro.core.interpreter import pack_inputs, pad_channels
from repro.kernels.vcgra import default_interpret
from repro.runtime.fleet import FleetRequest, PixieFleet

# Library apps that fit the paper's 18-input Sobel grid.
FLEET_APPS = ["sobel_x", "sobel_y", "sharpen", "laplace", "threshold", "identity"]

# The pallas megakernel runs in *interpret mode* on CPU CI, so it is not
# expected to beat the hand-lowered XLA path there -- the floor only guards
# against catastrophic regressions (a broken kernel, an accidental
# per-frame retrace).  Measured ~0.5x of the XLA fused path on CPU.
PALLAS_FLOOR_VS_XLA = 0.05


def _time(fn, reps: int) -> float:
    jax.block_until_ready(fn())  # warm / compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / reps


def run(n_apps: int, image_hw: int, reps: int) -> dict:
    rng = np.random.default_rng(0)
    grid = sobel_grid()
    img = jnp.asarray(rng.integers(0, 256, (image_hw, image_hw)).astype(np.int32))
    taps = apps.stencil_inputs(img)

    names = [FLEET_APPS[i % len(FLEET_APPS)] for i in range(n_apps)]
    fleet = PixieFleet(default_grid=grid, batch_tile=n_apps)
    configs = [fleet.config_for(n, grid) for n in names]
    xs = [
        pad_channels(pack_inputs(c, {k: v for k, v in taps.items()
                                     if k in c.input_order}, grid.dtype),
                     grid.num_inputs)
        for c in configs
    ]

    # -- sequential baseline: N per-app dispatches of the compiled overlay --
    pix = Pixie(grid, mode="conventional")
    pix.compile_overlay(batch=img.size)
    overlay = pix._overlay_fn
    cfg_jax = [c.to_jax() for c in configs]

    def sequential():
        return [overlay(cj, x) for cj, x in zip(cfg_jax, xs)]

    # -- batched fleet dispatch: ONE dispatch for all N tenants --------------
    batched_fn = fleet.overlay_for(grid)
    stacked = VCGRAConfig.stack(configs)
    xstack = jnp.stack(xs)

    def batched():
        return batched_fn(stacked, xstack)

    # bitwise-identical outputs
    seq_out = [np.asarray(y) for y in sequential()]
    bat_out = np.asarray(batched())
    for i in range(n_apps):
        np.testing.assert_array_equal(bat_out[i], seq_out[i])

    t_seq = _time(sequential, reps)
    t_bat = _time(batched, reps)

    # -- end-to-end service paths --------------------------------------------
    # unfused: the PR 1 serving cost -- per-request host-side tap formation
    # and packing (~20 device ops/frame) + one dispatch per app.
    def unfused_e2e():
        outs = []
        for c in configs:
            t = apps.stencil_inputs(img)
            feed = {k: v for k, v in t.items() if k in c.input_order}
            x = pad_channels(pack_inputs(c, feed, grid.dtype), grid.num_inputs)
            outs.append(overlay(c.to_jax(), x))
        return outs

    # fused: raw frames into the fleet; line buffers form inside the ONE
    # batched dispatch per grid.
    requests = [FleetRequest(app=n, image=img) for n in names]

    def fused_e2e():
        return fleet.run_many(requests)

    # fused outputs == unfused outputs, bitwise
    fused_out = fused_e2e()
    for i in range(n_apps):
        np.testing.assert_array_equal(
            np.asarray(fused_out[i]).reshape(-1), seq_out[i].reshape(-1)
        )

    t_unfused_e2e = _time(unfused_e2e, reps)
    fused_e2e()  # warm (compiles happened above, but keep windows aligned)
    pack0, disp0 = fleet.timings["pack_s"], fleet.timings["dispatch_s"]
    t0 = time.perf_counter()
    for _ in range(reps):
        fused_e2e()
    t_fused_e2e = (time.perf_counter() - t0) / reps
    # pack_s/dispatch_s deltas cover exactly the `reps` timed rounds.
    pack_s = fleet.timings["pack_s"] - pack0
    dispatch_s = fleet.timings["dispatch_s"] - disp0

    # -- pallas backend: the batched fused-ingest megakernel ------------------
    # Same fleet contract, backend="pallas"; bitwise-asserted against the
    # sequential oracle, then timed (fewer reps -- interpret mode is the
    # expected-slower path on CPU; on TPU this is the compiled path).
    pallas_fleet = PixieFleet(default_grid=grid, batch_tile=n_apps,
                              backend="pallas")
    for n in names:
        pallas_fleet.config_for(n, grid)  # warm the config cache like `fleet`

    def pallas_e2e():
        return pallas_fleet.run_many(requests)

    pallas_out = pallas_e2e()
    for i in range(n_apps):
        np.testing.assert_array_equal(
            np.asarray(pallas_out[i]).reshape(-1), seq_out[i].reshape(-1)
        )
    pallas_reps = max(2, reps // 3)
    t_pallas_e2e = _time(pallas_e2e, pallas_reps)
    assert pallas_fleet.stats.overlay_builds == 1, pallas_fleet.stats.as_dict()
    assert pallas_fleet.stats.backend == "pallas"

    # pack fraction: share of the e2e cost spent *outside* the dispatch.
    pack_fraction_unfused = max(0.0, (t_unfused_e2e - t_seq) / t_unfused_e2e)
    pack_fraction_fused = pack_s / (pack_s + dispatch_s) if pack_s + dispatch_s else 0.0

    # compile-once invariant: ONE fused overlay build for the grid, and
    # canvas tiling kept it at ONE XLA executable (-1 = this jax version
    # has no jit-cache introspection; overlay_builds is the stable counter).
    assert fleet.stats.overlay_builds == 2, fleet.stats.as_dict()  # fused + unfused
    assert fleet.overlay_executable_count(grid) in (2, -1), fleet.stats.as_dict()
    assert fleet.stats.fused_dispatches >= 1, fleet.stats.as_dict()
    assert fleet.stats.config_cache_hits >= n_apps, fleet.stats.as_dict()
    assert fleet.stats.stack_bank_hits >= 1, fleet.stats.as_dict()

    # plan-cache behavior of the fleet's overlay LRU (keyed by OverlayPlan):
    # hit rate ~1 after warmup is the compile-once contract at fleet scale.
    plan_lookups = fleet._overlays.hits + fleet._overlays.misses
    plan_cache = {
        "hits": fleet._overlays.hits,
        "misses": fleet._overlays.misses,
        "hit_rate": fleet._overlays.hits / plan_lookups if plan_lookups else 0.0,
        "plans": sorted(p.key() for p in fleet._overlays._d),
    }

    pixels = img.size * n_apps
    return {
        "bench": "fleet_throughput",
        "n_apps": n_apps,
        "image": [image_hw, image_hw],
        "grid": grid.name,
        "apps": names,
        "device_count": len(jax.local_devices()),
        "plan_cache": plan_cache,
        "sequential_s_per_round": t_seq,
        "batched_s_per_round": t_bat,
        "unfused_e2e_s_per_round": t_unfused_e2e,
        "fused_e2e_s_per_round": t_fused_e2e,
        "sequential_apps_per_s": n_apps / t_seq,
        "batched_apps_per_s": n_apps / t_bat,
        "unfused_e2e_apps_per_s": n_apps / t_unfused_e2e,
        "fused_e2e_apps_per_s": n_apps / t_fused_e2e,
        "sequential_mpixels_per_s": pixels / t_seq / 1e6,
        "batched_mpixels_per_s": pixels / t_bat / 1e6,
        "fused_e2e_mpixels_per_s": pixels / t_fused_e2e / 1e6,
        "speedup": t_seq / t_bat,
        "speedup_e2e": t_unfused_e2e / t_fused_e2e,
        "pack_fraction_unfused": pack_fraction_unfused,
        "pack_fraction_fused": pack_fraction_fused,
        "fleet_pack_s_per_round": pack_s / reps,
        "fleet_dispatch_s_per_round": dispatch_s / reps,
        "fleet_stats": fleet.stats.as_dict(),
        "overlay_executables": fleet.overlay_executable_count(grid),
        # per-backend fused e2e numbers, stable keys for the trajectory
        "backends": {
            "xla": {"fused_e2e_s_per_round": t_fused_e2e,
                    "fused_e2e_apps_per_s": n_apps / t_fused_e2e},
            "pallas": {"fused_e2e_s_per_round": t_pallas_e2e,
                       "fused_e2e_apps_per_s": n_apps / t_pallas_e2e,
                       "interpret_mode": default_interpret()},
        },
        "pallas_fused_e2e_apps_per_s": n_apps / t_pallas_e2e,
        "pallas_vs_xla_fused_e2e": t_fused_e2e / t_pallas_e2e,
        "pallas_floor_vs_xla": PALLAS_FLOOR_VS_XLA,
        "pallas_fleet_stats": pallas_fleet.stats.as_dict(),
    }


def main(argv=None) -> dict:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--smoke", action="store_true", help="CI-sized quick run")
    p.add_argument("--n-apps", type=int, default=None)
    p.add_argument("--image", type=int, default=None, help="square image side")
    p.add_argument("--reps", type=int, default=None)
    p.add_argument("--out", type=str, default=None, help="write BENCH JSON here")
    p.add_argument("--check", action="store_true",
                   help="exit nonzero unless batched >= 2x sequential AND "
                        "fused e2e >= 2x unfused e2e")
    a = p.parse_args(argv)

    # Many small frames is the fleet's target regime (per-dispatch overhead
    # dominates); at large frames both paths converge on the same
    # compute-bound Mpx/s and batching only saves the dispatch tax.
    n_apps = a.n_apps or (8 if a.smoke else 16)
    image = a.image or 32
    reps = a.reps or (5 if a.smoke else 30)

    result = run(n_apps, image, reps)
    print(f"fleet throughput: {n_apps} apps on {result['grid']}, "
          f"{image}x{image} px, {reps} reps")
    print(f"  sequential   {result['sequential_apps_per_s']:10.1f} apps/s   "
          f"{result['sequential_mpixels_per_s']:8.2f} Mpx/s   (dispatch only)")
    print(f"  batched      {result['batched_apps_per_s']:10.1f} apps/s   "
          f"{result['batched_mpixels_per_s']:8.2f} Mpx/s   (dispatch only)")
    print(f"  unfused e2e  {result['unfused_e2e_apps_per_s']:10.1f} apps/s   "
          f"(pack fraction {100*result['pack_fraction_unfused']:.0f}%)")
    print(f"  fused e2e    {result['fused_e2e_apps_per_s']:10.1f} apps/s   "
          f"(pack fraction {100*result['pack_fraction_fused']:.0f}%)")
    mode = "interpret" if result["backends"]["pallas"]["interpret_mode"] else "compiled"
    print(f"  pallas e2e   {result['pallas_fused_e2e_apps_per_s']:10.1f} apps/s   "
          f"(megakernel, {mode}; x{result['pallas_vs_xla_fused_e2e']:.2f} vs xla)")
    print(f"  speedup      x{result['speedup']:.2f} dispatch, "
          f"x{result['speedup_e2e']:.2f} e2e   "
          f"(overlay builds={result['fleet_stats']['overlay_builds']}, "
          f"xla executables={result['overlay_executables']})")
    print(f"  plan cache   hit rate {result['plan_cache']['hit_rate']:.2f} "
          f"over {len(result['plan_cache']['plans'])} plans, "
          f"{result['device_count']} device(s)")

    print("BENCH " + json.dumps(result))
    if a.out:
        os.makedirs(os.path.dirname(a.out) or ".", exist_ok=True)
        with open(a.out, "w") as f:
            json.dump(result, f, indent=2)
        print(f"wrote {a.out}")

    if a.check:
        fails = []
        if result["speedup"] < 2.0:
            fails.append(f"batched dispatch x{result['speedup']:.2f} < x2")
        if result["speedup_e2e"] < 2.0:
            fails.append(f"fused e2e x{result['speedup_e2e']:.2f} < x2")
        if result["pallas_vs_xla_fused_e2e"] < PALLAS_FLOOR_VS_XLA:
            fails.append(
                f"pallas fused e2e x{result['pallas_vs_xla_fused_e2e']:.3f} "
                f"of xla < floor x{PALLAS_FLOOR_VS_XLA}"
            )
        if fails:
            raise SystemExit("FAIL: " + "; ".join(fails))
    return result


if __name__ == "__main__":
    main()
