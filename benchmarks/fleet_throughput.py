"""Benchmark 5 — fleet throughput: multi-tenant batched overlay dispatch.

The overlay's compile-once economics (paper Sec. V-E) amortize the FPGA
compile across applications *in time* (sequential reconfiguration); the
fleet runtime amortizes it *in space*: N different applications stacked
into one vmapped dispatch of the same executable.  This benchmark measures
what that buys:

  sequential   one conventional `Pixie`, N per-app dispatches of the
               compiled overlay (settings swap between calls)
  batched      one `make_batched_overlay_fn` dispatch over the N stacked
               configs (the `PixieFleet` execution path)

Identical inputs, bitwise-identical outputs (asserted), same single XLA
executable per path.  Reports apps/sec and pixels/sec, asserts the
compile-once invariant via the fleet's cache counters, and emits a
machine-readable ``BENCH {json}`` line plus a JSON artifact for CI trend
tracking (``--out``).

Usage:
  python benchmarks/fleet_throughput.py            # full run
  python benchmarks/fleet_throughput.py --smoke    # CI-sized (<30 s)
  python benchmarks/fleet_throughput.py --check    # exit 1 if speedup < 2x
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Pixie, sobel_grid
from repro.core import applications as apps
from repro.core.bitstream import VCGRAConfig
from repro.core.interpreter import pack_inputs, pad_channels
from repro.runtime.fleet import FleetRequest, PixieFleet

# Library apps that fit the paper's 18-input Sobel grid.
FLEET_APPS = ["sobel_x", "sobel_y", "sharpen", "laplace", "threshold", "identity"]


def _time(fn, reps: int) -> float:
    jax.block_until_ready(fn())  # warm / compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / reps


def run(n_apps: int, image_hw: int, reps: int) -> dict:
    rng = np.random.default_rng(0)
    grid = sobel_grid()
    img = jnp.asarray(rng.integers(0, 256, (image_hw, image_hw)).astype(np.int32))
    taps = apps.stencil_inputs(img)

    names = [FLEET_APPS[i % len(FLEET_APPS)] for i in range(n_apps)]
    fleet = PixieFleet(default_grid=grid, batch_tile=n_apps)
    configs = [fleet.config_for(n, grid) for n in names]
    xs = [
        pad_channels(pack_inputs(c, {k: v for k, v in taps.items()
                                     if k in c.input_order}, grid.dtype),
                     grid.num_inputs)
        for c in configs
    ]

    # -- sequential baseline: N per-app dispatches of the compiled overlay --
    pix = Pixie(grid, mode="conventional")
    pix.compile_overlay(batch=img.size)
    overlay = pix._overlay_fn
    cfg_jax = [c.to_jax() for c in configs]

    def sequential():
        return [overlay(cj, x) for cj, x in zip(cfg_jax, xs)]

    # -- batched fleet path: ONE dispatch for all N tenants ------------------
    batched_fn = fleet.overlay_for(grid)
    stacked = VCGRAConfig.stack(configs)
    xstack = jnp.stack(xs)

    def batched():
        return batched_fn(stacked, xstack)

    # bitwise-identical outputs
    seq_out = [np.asarray(y) for y in sequential()]
    bat_out = np.asarray(batched())
    for i in range(n_apps):
        np.testing.assert_array_equal(bat_out[i], seq_out[i])

    t_seq = _time(sequential, reps)
    t_bat = _time(batched, reps)

    # -- end-to-end service paths: per-request input packing included on
    # BOTH sides (it dominates either path at small frames).  t_seq/t_bat
    # above isolate the dispatch, these measure the full serving cost.
    def sequential_e2e():
        outs = []
        for c in configs:
            pix.config = c
            pix._config_jax = c.to_jax()   # settings-register swap
            outs.append(pix.run_image(img))
        return outs

    def fleet_e2e():
        return fleet.run_many([FleetRequest(app=n, image=img) for n in names])

    t_seq_e2e = _time(sequential_e2e, reps)
    t_e2e = _time(fleet_e2e, reps)

    # compile-once invariant: the fleet built ONE batched overlay for the
    # grid, and tiling kept it at ONE XLA executable (-1 = this jax version
    # has no jit-cache introspection; overlay_builds is the stable counter).
    assert fleet.stats.overlay_builds == 1, fleet.stats.as_dict()
    assert fleet.overlay_executable_count(grid) in (1, -1), fleet.stats.as_dict()
    assert fleet.stats.config_cache_hits >= n_apps, fleet.stats.as_dict()
    assert fleet.stats.stack_bank_hits >= 1, fleet.stats.as_dict()

    pixels = img.size * n_apps
    return {
        "bench": "fleet_throughput",
        "n_apps": n_apps,
        "image": [image_hw, image_hw],
        "grid": grid.name,
        "apps": names,
        "sequential_s_per_round": t_seq,
        "batched_s_per_round": t_bat,
        "fleet_e2e_s_per_round": t_e2e,
        "sequential_e2e_s_per_round": t_seq_e2e,
        "sequential_apps_per_s": n_apps / t_seq,
        "batched_apps_per_s": n_apps / t_bat,
        "fleet_e2e_apps_per_s": n_apps / t_e2e,
        "sequential_e2e_apps_per_s": n_apps / t_seq_e2e,
        "sequential_mpixels_per_s": pixels / t_seq / 1e6,
        "batched_mpixels_per_s": pixels / t_bat / 1e6,
        "speedup": t_seq / t_bat,
        "speedup_e2e": t_seq_e2e / t_e2e,
        "fleet_stats": fleet.stats.as_dict(),
        "overlay_executables": fleet.overlay_executable_count(grid),
    }


def main(argv=None) -> dict:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--smoke", action="store_true", help="CI-sized quick run")
    p.add_argument("--n-apps", type=int, default=None)
    p.add_argument("--image", type=int, default=None, help="square image side")
    p.add_argument("--reps", type=int, default=None)
    p.add_argument("--out", type=str, default=None, help="write BENCH JSON here")
    p.add_argument("--check", action="store_true",
                   help="exit nonzero unless speedup >= 2x")
    a = p.parse_args(argv)

    # Many small frames is the fleet's target regime (per-dispatch overhead
    # dominates); at large frames both paths converge on the same
    # compute-bound Mpx/s and batching only saves the dispatch tax.
    n_apps = a.n_apps or (8 if a.smoke else 16)
    image = a.image or 32
    reps = a.reps or (5 if a.smoke else 30)

    result = run(n_apps, image, reps)
    print(f"fleet throughput: {n_apps} apps on {result['grid']}, "
          f"{image}x{image} px, {reps} reps")
    print(f"  sequential  {result['sequential_apps_per_s']:10.1f} apps/s   "
          f"{result['sequential_mpixels_per_s']:8.2f} Mpx/s")
    print(f"  batched     {result['batched_apps_per_s']:10.1f} apps/s   "
          f"{result['batched_mpixels_per_s']:8.2f} Mpx/s")
    print(f"  e2e         {result['sequential_e2e_apps_per_s']:10.1f} -> "
          f"{result['fleet_e2e_apps_per_s']:.1f} apps/s   "
          f"(x{result['speedup_e2e']:.2f} with per-request packing included)")
    print(f"  speedup     x{result['speedup']:.2f}   "
          f"(overlay builds={result['fleet_stats']['overlay_builds']}, "
          f"xla executables={result['overlay_executables']})")

    print("BENCH " + json.dumps(result))
    if a.out:
        os.makedirs(os.path.dirname(a.out) or ".", exist_ok=True)
        with open(a.out, "w") as f:
            json.dump(result, f, indent=2)
        print(f"wrote {a.out}")

    if a.check and result["speedup"] < 2.0:
        raise SystemExit(
            f"FAIL: batched speedup x{result['speedup']:.2f} < x2 target"
        )
    return result


if __name__ == "__main__":
    main()
