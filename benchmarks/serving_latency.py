"""Benchmark 6 — serving latency percentiles at fixed offered load.

The throughput bench (fleet_throughput) measures how fast the fleet can
chew a closed-loop batch; this bench measures what a *user* of the
streaming service experiences: an open-loop arrival process at a fixed
offered load is replayed against the continuous-batching
``StreamingFrontend`` and per-request queue/flush/total latency
percentiles (p50/p95/p99), deadline-miss counts and shed counts are
recorded -- the "millions of users" axis the ROADMAP said nothing in the
repo measured.

Three measured sections:

  loaded      N requests arriving at ``--rate`` req/s with a generous SLO:
              the p50/p95/p99 of queue_s / flush_s / total_s under
              continuous batching.  ``--check`` bounds p99 total at smoke
              load and requires ZERO deadline misses (the SLO is trivial
              by construction -- missing it means the scheduler sat on
              work).
  deadline    a deadline-constrained trickle (fewer requests than the
              batch tile, linger effectively disabled): the scheduler
              MUST launch partially-filled tiles to meet the SLO --
              asserted via ``FleetStats.partial_tile_dispatches``.
  parity      the same request trace through the streaming and the
              synchronous front-ends must be bitwise identical (batch
              composition is a latency decision, never a values one).

Emits a ``BENCH {json}`` line and (``--out``) the JSON artifact CI
uploads as ``BENCH_serving.json``.

Usage:
  python benchmarks/serving_latency.py                  # full run
  python benchmarks/serving_latency.py --smoke          # CI-sized (<60 s)
  python benchmarks/serving_latency.py --smoke --check  # enforce floors
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.core import sobel_grid
from repro.runtime.fleet import PixieFleet
from repro.serve import FleetFrontend, StreamingFrontend

MIX = ["sobel_x", "sobel_y", "sharpen", "laplace", "threshold", "identity"]

# --check floors.  Smoke load is far below saturation and the overlay is
# pre-compiled before measuring, so p99 total latency is queue wait + a
# few small-frame flushes; 1.5 s only guards against the scheduler
# sitting on work (a lost wakeup, a starved linger) on a noisy CI host.
SMOKE_P99_TOTAL_S = 1.5
SMOKE_DEADLINE_S = 30.0     # trivial SLO: any miss is a scheduler bug


def _trace(n: int, side: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return [
        (MIX[i % len(MIX)],
         rng.integers(0, 256, (side, side)).astype(np.int32))
        for i in range(n)
    ]


def run_loaded(n_requests: int, rate_hz: float, side: int,
               target_batch: int) -> dict:
    """Open-loop replay at fixed offered load against a warmed streaming
    front-end; returns the LatencyStats summary plus fleet counters."""
    trace = _trace(n_requests, side)
    fleet = PixieFleet(default_grid=sobel_grid(), batch_tile=target_batch)
    with StreamingFrontend(fleet=fleet, target_batch=target_batch,
                           max_queue=4 * n_requests) as svc:
        svc.process(MIX[0], trace[0][1])          # compile outside the clock
        svc.latency.reset()
        handles = []
        t0 = time.perf_counter()
        for i, (name, img) in enumerate(trace):
            # open loop: arrivals are scheduled by the load generator,
            # not by service completions
            target = t0 + i / rate_hz
            delay = target - time.perf_counter()
            if delay > 0:
                time.sleep(delay)
            handles.append(svc.submit(name, img, deadline_s=SMOKE_DEADLINE_S))
        for h in handles:
            h.result(timeout=300)
        makespan = time.perf_counter() - t0
        summary = svc.latency.summary()
    return {
        "n_requests": n_requests,
        "offered_load_req_per_s": rate_hz,
        "achieved_req_per_s": n_requests / makespan,
        "frame": [side, side],
        "target_batch": target_batch,
        "makespan_s": makespan,
        "latency": summary,
        "est_flush_s": svc.est_flush_s,
        "fleet": {
            "dispatches": fleet.stats.dispatches,
            "partial_tile_dispatches": fleet.stats.partial_tile_dispatches,
            "padded_app_slots": fleet.stats.padded_app_slots,
        },
    }


def run_deadline(side: int, target_batch: int) -> dict:
    """Deadline-constrained trickle: fewer requests than the tile, linger
    long enough that only the deadline trigger can fire -- the scheduler
    must launch partial tiles, and they must not miss the (loose) SLO."""
    trace = _trace(3, side, seed=1)
    fleet = PixieFleet(default_grid=sobel_grid(), batch_tile=target_batch)
    with StreamingFrontend(fleet=fleet, target_batch=target_batch,
                           max_linger_s=60.0) as svc:
        svc.process(MIX[0], trace[0][1])
        svc.latency.reset()
        partial0 = fleet.stats.partial_tile_dispatches
        handles = [svc.submit(n, img, deadline_s=1.0) for n, img in trace]
        jobs = [h.job(timeout=300) for h in handles]
        summary = svc.latency.summary()
    partial = fleet.stats.partial_tile_dispatches - partial0
    return {
        "n_requests": len(trace),
        "deadline_s": 1.0,
        "partial_tile_dispatches": partial,
        "deadline_misses": summary["deadline_misses"],
        "latency": summary,
        "flush_seqs": sorted({j.flush_seq for j in jobs}),
    }


def run_parity(side: int) -> dict:
    """Same trace through both front-ends: outputs must be bitwise equal."""
    trace = _trace(8, side, seed=2)
    sync = FleetFrontend(fleet=PixieFleet(default_grid=sobel_grid()))
    ref = sync.process_batch(trace)
    with StreamingFrontend(
        fleet=PixieFleet(default_grid=sobel_grid()), target_batch=3,
    ) as svc:
        handles = [svc.submit(n, img, priority=i % 2)
                   for i, (n, img) in enumerate(trace)]
        outs = [h.result(timeout=300) for h in handles]
        dispatches = svc.stats.dispatches
    for a, b in zip(ref, outs):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    return {
        "n_requests": len(trace),
        "streaming_dispatches": dispatches,
        "bitwise_equal": True,
    }


def main(argv=None) -> dict:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--smoke", action="store_true", help="CI-sized quick run")
    p.add_argument("--n-requests", type=int, default=None)
    p.add_argument("--rate", type=float, default=None,
                   help="offered load in requests/s")
    p.add_argument("--image", type=int, default=32, help="square frame side")
    p.add_argument("--target-batch", type=int, default=8)
    p.add_argument("--out", type=str, default=None, help="write BENCH JSON here")
    p.add_argument("--check", action="store_true",
                   help="exit nonzero unless p99 total <= "
                        f"{SMOKE_P99_TOTAL_S}s at smoke load, zero deadline "
                        "misses at trivial load, partial tiles launched "
                        "under deadline pressure, and streaming == sync "
                        "bitwise")
    a = p.parse_args(argv)

    n_requests = a.n_requests or (48 if a.smoke else 256)
    rate = a.rate or (200.0 if a.smoke else 400.0)

    loaded = run_loaded(n_requests, rate, a.image, a.target_batch)
    deadline = run_deadline(a.image, a.target_batch)
    parity = run_parity(a.image)

    result = {
        "bench": "serving_latency",
        "grid": sobel_grid().name,
        "loaded": loaded,
        "deadline": deadline,
        "parity": parity,
        "floors": {
            "p99_total_s": SMOKE_P99_TOTAL_S,
            "deadline_misses": 0,
        },
    }

    lat = loaded["latency"]
    print(f"serving latency: {n_requests} requests @ {rate:.0f} req/s offered, "
          f"{a.image}x{a.image} px, tile {a.target_batch}")
    for key in ("queue_s", "flush_s", "total_s"):
        q = lat[key]
        print(f"  {key:8s}  p50 {1e3*q['p50']:7.2f} ms   "
              f"p95 {1e3*q['p95']:7.2f} ms   p99 {1e3*q['p99']:7.2f} ms   "
              f"max {1e3*q['max']:7.2f} ms")
    print(f"  achieved   {loaded['achieved_req_per_s']:.1f} req/s over "
          f"{loaded['fleet']['dispatches']} dispatches "
          f"({loaded['fleet']['partial_tile_dispatches']} partial tiles); "
          f"misses {lat['deadline_misses']}/{lat['with_deadline']}, "
          f"shed {lat['shed']}")
    print(f"  deadline   {deadline['partial_tile_dispatches']} partial-tile "
          f"launch(es) under a {deadline['deadline_s']}s SLO, "
          f"{deadline['deadline_misses']} miss(es)")
    print(f"  parity     streaming == sync bitwise over "
          f"{parity['n_requests']} ragged requests "
          f"({parity['streaming_dispatches']} streaming dispatches)")

    print("BENCH " + json.dumps(result))
    if a.out:
        os.makedirs(os.path.dirname(a.out) or ".", exist_ok=True)
        with open(a.out, "w") as f:
            json.dump(result, f, indent=2)
        print(f"wrote {a.out}")

    if a.check:
        fails = []
        p99 = lat["total_s"]["p99"]
        if p99 > SMOKE_P99_TOTAL_S:
            fails.append(f"p99 total {p99:.3f}s > {SMOKE_P99_TOTAL_S}s floor")
        if lat["deadline_misses"] != 0:
            fails.append(
                f"{lat['deadline_misses']} deadline miss(es) at a trivial "
                f"{SMOKE_DEADLINE_S}s SLO"
            )
        if lat["shed"] != 0:
            fails.append(f"{lat['shed']} request(s) shed below saturation")
        if deadline["partial_tile_dispatches"] < 1:
            fails.append("deadline pressure launched no partial tiles")
        if deadline["deadline_misses"] != 0:
            fails.append(
                f"{deadline['deadline_misses']} miss(es) of the "
                f"{deadline['deadline_s']}s deadline-section SLO"
            )
        if fails:
            raise SystemExit("FAIL: " + "; ".join(fails))
    return result


if __name__ == "__main__":
    main()
