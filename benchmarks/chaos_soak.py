"""Benchmark 8 — chaos soak: fault-injected self-healing serving.

The serving-latency bench measures the happy path; this bench measures
what the resilience stack (PR 10) delivers when the path is NOT happy.
An open-loop request trace is replayed against the streaming front-end
with a seeded :class:`~repro.runtime.chaos.FaultInjector` armed across
every hook point, and the run is graded against a fault-free ORACLE of
the same trace:

  faults      * transient dispatch faults against the primary (tiled)
                plan, enough consecutive failures to OPEN its circuit
                breaker; the fault then burns out so the recovery phase
                must observe a half-open probe CLOSE it again
  (seeded)    * a persistently poisoned tenant (every ``threshold``
                request): bisection quarantine must isolate EXACTLY
                those requests, each failing typed, zero collateral
              * low-rate NaN output corruption: the output guard must
                catch it and re-dispatch clean, bitwise
              * low-rate transfer stalls (the straggler source)
              * one injected worker death: the supervisor must restart
                the worker thread and strand no handle

  floors      * availability >= 99% over NON-poisoned requests (in a
    (--check)   seeded smoke run it is 100%: every non-poisoned request
                is served)
              * every served output bitwise-equal to the fault-free
                oracle (self-healing must never change values)
              * every poisoned request quarantined (raises typed), and
                ONLY those
              * zero hung handles: every result(timeout=) resolves
              * breaker opened AND recovered (close event after open)
              * the worker restarted at least once
              * p99 total latency bounded (retries/backoff/stalls cost
                latency, not correctness -- but not unbounded latency)

Emits a ``BENCH {json}`` line and (``--out``) the JSON artifact CI
uploads as ``BENCH_chaos.json``.

Usage:
  python benchmarks/chaos_soak.py                  # full soak
  python benchmarks/chaos_soak.py --smoke          # CI-sized (<60 s)
  python benchmarks/chaos_soak.py --smoke --check  # enforce floors
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.core import sobel_grid
from repro.runtime.chaos import FaultInjector
from repro.runtime.fleet import PixieFleet
from repro.runtime.resilience import (
    BreakerBoard, JobTimeout, QuarantinedError, RetryPolicy, ServiceError,
)
from repro.serve import StreamingFrontend

# The app mix: float PEs so NaN corruption is expressible in the fabric
# dtype; `threshold` is the poisoned tenant.
MIX = ["sobel_x", "sobel_y", "sharpen", "laplace", "threshold", "identity"]
POISONED_APP = "threshold"
TILE_ROWS = 8            # explicit row tiling => the plan key has a
                         # "tile:8" token to match faults on, and the
                         # fallback chain has an untiled sibling

AVAILABILITY_FLOOR = 0.99
P99_TOTAL_S = 5.0        # generous: backoff sleeps + stalls are latency,
                         # not failures; this only guards runaway retries
RESULT_WAIT_S = 300.0    # per-handle bound; a hang is a FINDING, not a
                         # test timeout


def _grid():
    return sobel_grid(float_pe=True)


def _trace(n: int, side: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return [
        (MIX[i % len(MIX)],
         rng.integers(0, 256, (side, side)).astype(np.float32))
        for i in range(n)
    ]


def _fleet(faults=None, breakers=None):
    return PixieFleet(default_grid=_grid(), tile_rows=TILE_ROWS,
                      faults=faults, breakers=breakers,
                      retry=RetryPolicy(backoff_base_s=0.002,
                                        backoff_max_s=0.02))


def _injector(seed: int, breaker_threshold: int) -> FaultInjector:
    return (
        FaultInjector(seed=seed)
        # Trip the tiled primary's breaker, then burn out so the
        # recovery phase can close it via a half-open probe.
        .inject("dispatch", transient=False, match=("tile:8",),
                max_fires=breaker_threshold, detail="primary-plan outage")
        # Persistent poison pill: every threshold request, forever.
        .inject("dispatch", transient=False, match=(f"<app:{POISONED_APP}>",),
                detail="poisoned tenant")
        # Low-rate transient flakiness on everything else.
        .inject("dispatch", rate=0.05, transient=True)
        # Low-rate NaN corruption: the output guard must catch it.
        .inject("nan_output", rate=0.05)
        # Low-rate stalls: the straggler source HeartbeatMonitor sees.
        .inject("transfer_stall", rate=0.05, delay_s=0.02)
        # One worker kill: the supervisor must restart and lose nothing.
        .inject("worker_death", max_fires=1)
    )


def _replay(trace, rate_hz: float, target_batch: int,
            faults=None, breakers=None) -> dict:
    """Open-loop replay; returns per-request outcomes + service stats."""
    fleet = _fleet(faults=faults, breakers=breakers)
    outcomes = []
    with StreamingFrontend(fleet=fleet, target_batch=target_batch,
                           max_queue=4 * len(trace)) as svc:
        svc.process(MIX[0], trace[0][1])          # compile outside the clock
        svc.latency.reset()
        handles = []
        t0 = time.perf_counter()
        for i, (name, img) in enumerate(trace):
            target = t0 + i / rate_hz
            delay = target - time.perf_counter()
            if delay > 0:
                time.sleep(delay)
            handles.append(svc.submit(name, img))
        for (name, _), h in zip(trace, handles):
            try:
                out = np.asarray(h.result(timeout=RESULT_WAIT_S))
                outcomes.append((name, "served", out))
            except QuarantinedError as exc:
                outcomes.append((name, "quarantined", exc))
            except JobTimeout as exc:
                outcomes.append((name, "hung", exc))
            except ServiceError as exc:
                outcomes.append((name, "failed", exc))
        makespan = time.perf_counter() - t0
        summary = svc.latency.summary()
        restarts = svc.worker_restarts
    return {
        "outcomes": outcomes,
        "latency": summary,
        "makespan_s": makespan,
        "worker_restarts": restarts,
        "stats": fleet.stats,
    }


def run_soak(n_requests: int, rate_hz: float, side: int, target_batch: int,
             seed: int) -> dict:
    trace = _trace(n_requests, side, seed=seed)

    # Fault-free oracle first: the grading key for bitwise comparison.
    oracle = _replay(trace, rate_hz, target_batch)
    oracle_outs = [o for _, _, o in oracle["outcomes"]]
    assert all(kind == "served" for _, kind, _ in oracle["outcomes"])

    # The chaos run: same trace, same arrival schedule, faults armed.
    breakers = BreakerBoard(failure_threshold=3, cooldown_s=0.3)
    faults = _injector(seed=seed + 1, breaker_threshold=3)
    chaos = _replay(trace, rate_hz, target_batch,
                    faults=faults, breakers=breakers)

    poisoned_total = sum(1 for name, _ in trace if name == POISONED_APP)
    served = quarantined = hung = failed = mismatched = 0
    collateral = 0          # non-poisoned requests that did not serve
    for (name, kind, payload), want in zip(chaos["outcomes"], oracle_outs):
        if kind == "served":
            served += 1
            if not np.array_equal(payload, want):
                mismatched += 1
        elif kind == "quarantined":
            quarantined += 1
            if name != POISONED_APP:
                collateral += 1
        elif kind == "hung":
            hung += 1
        else:
            failed += 1
    nonpoisoned = n_requests - poisoned_total
    availability = served / nonpoisoned if nonpoisoned else 1.0

    stats = chaos["stats"]
    events = [e["event"] for e in stats.breaker_events]
    opened = sum(1 for e in events if e.startswith(("open:", "reopen:")))
    closed_after_open = "close" in events and (
        events.index("close") > next(
            (i for i, e in enumerate(events) if e.startswith("open:")), -1))

    return {
        "n_requests": n_requests,
        "offered_load_req_per_s": rate_hz,
        "frame": [side, side],
        "target_batch": target_batch,
        "seed": seed,
        "oracle_makespan_s": oracle["makespan_s"],
        "chaos_makespan_s": chaos["makespan_s"],
        "served": served,
        "poisoned_requests": poisoned_total,
        "quarantined": quarantined,
        "collateral_quarantines": collateral,
        "hung_handles": hung,
        "other_failures": failed,
        "bitwise_mismatches": mismatched,
        "availability_nonpoisoned": availability,
        "worker_restarts": chaos["worker_restarts"],
        "fault_fires": dict(faults.fired),
        "fleet": {
            "dispatches": stats.dispatches,
            "retries": stats.retries,
            "fallback_dispatches": stats.fallback_dispatches,
            "quarantined_requests": stats.quarantined_requests,
            "guard_failures": stats.guard_failures,
            "straggler_flushes": stats.straggler_flushes,
        },
        "breaker": {
            "events": events,
            "opened": opened,
            "recovered": closed_after_open,
            "final_states": breakers.states(),
        },
        "latency": chaos["latency"],
    }


def main(argv=None) -> dict:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--smoke", action="store_true", help="CI-sized quick run")
    p.add_argument("--n-requests", type=int, default=None)
    p.add_argument("--rate", type=float, default=None,
                   help="offered load in requests/s")
    p.add_argument("--image", type=int, default=32, help="square frame side")
    p.add_argument("--target-batch", type=int, default=6)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--out", type=str, default=None, help="write BENCH JSON here")
    p.add_argument("--check", action="store_true",
                   help="exit nonzero unless the resilience floors hold "
                        "(availability, bitwise survivors, exact "
                        "quarantine, zero hangs, breaker recovery, worker "
                        "restart, bounded p99)")
    a = p.parse_args(argv)

    n_requests = a.n_requests or (60 if a.smoke else 240)
    rate = a.rate or (150.0 if a.smoke else 300.0)

    soak = run_soak(n_requests, rate, a.image, a.target_batch, a.seed)

    result = {
        "bench": "chaos_soak",
        "grid": _grid().name,
        "soak": soak,
        "floors": {
            "availability_nonpoisoned": AVAILABILITY_FLOOR,
            "hung_handles": 0,
            "bitwise_mismatches": 0,
            "collateral_quarantines": 0,
            "p99_total_s": P99_TOTAL_S,
        },
    }

    lat = soak["latency"]
    print(f"chaos soak: {n_requests} requests @ {rate:.0f} req/s offered, "
          f"{a.image}x{a.image} px, tile {a.target_batch}, seed {a.seed}")
    print(f"  served     {soak['served']}/{n_requests} "
          f"(availability {100 * soak['availability_nonpoisoned']:.2f}% of "
          f"{n_requests - soak['poisoned_requests']} non-poisoned; "
          f"{soak['bitwise_mismatches']} bitwise mismatch(es))")
    print(f"  quarantine {soak['quarantined']} of {soak['poisoned_requests']} "
          f"poisoned ({soak['collateral_quarantines']} collateral), "
          f"{soak['hung_handles']} hung, {soak['other_failures']} other")
    print(f"  healing    {soak['fleet']['retries']} retries, "
          f"{soak['fleet']['fallback_dispatches']} fallback dispatches, "
          f"{soak['fleet']['guard_failures']} guard catches, "
          f"{soak['worker_restarts']} worker restart(s)")
    print(f"  breaker    {soak['breaker']['opened']} open event(s), "
          f"recovered={soak['breaker']['recovered']}, "
          f"final={soak['breaker']['final_states']}")
    print(f"  latency    p50 {1e3 * lat['total_s']['p50']:7.2f} ms   "
          f"p99 {1e3 * lat['total_s']['p99']:7.2f} ms   "
          f"max {1e3 * lat['total_s']['max']:7.2f} ms "
          f"(oracle makespan {soak['oracle_makespan_s']:.2f}s, "
          f"chaos {soak['chaos_makespan_s']:.2f}s)")

    print("BENCH " + json.dumps(result))
    if a.out:
        os.makedirs(os.path.dirname(a.out) or ".", exist_ok=True)
        with open(a.out, "w") as f:
            json.dump(result, f, indent=2)
        print(f"wrote {a.out}")

    if a.check:
        fails = []
        if soak["availability_nonpoisoned"] < AVAILABILITY_FLOOR:
            fails.append(
                f"availability {soak['availability_nonpoisoned']:.4f} < "
                f"{AVAILABILITY_FLOOR} over non-poisoned requests")
        if soak["hung_handles"]:
            fails.append(f"{soak['hung_handles']} hung handle(s)")
        if soak["bitwise_mismatches"]:
            fails.append(
                f"{soak['bitwise_mismatches']} served output(s) differ "
                f"from the fault-free oracle")
        if soak["collateral_quarantines"]:
            fails.append(
                f"{soak['collateral_quarantines']} non-poisoned request(s) "
                f"quarantined (bisection collateral)")
        if soak["quarantined"] < soak["poisoned_requests"]:
            fails.append(
                f"only {soak['quarantined']}/{soak['poisoned_requests']} "
                f"poisoned requests were quarantined")
        if not soak["breaker"]["opened"]:
            fails.append("the primary plan's breaker never opened")
        if not soak["breaker"]["recovered"]:
            fails.append("the breaker never recovered (no close after open)")
        if soak["worker_restarts"] < 1:
            fails.append("the injected worker death caused no restart")
        p99 = lat["total_s"]["p99"]
        if p99 > P99_TOTAL_S:
            fails.append(f"p99 total {p99:.3f}s > {P99_TOTAL_S}s floor")
        if fails:
            raise SystemExit("FAIL: " + "; ".join(fails))
    return result


if __name__ == "__main__":
    main()
