"""Attention tests: masks, GQA/MQA, chunk invariance, banding, caches."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (
    _make_dynamic_mask, _mask, attention_decode, attention_decode_ring,
    attention_train, init_attention, pick_chunk,
)

KW = dict(num_heads=4, num_kv_heads=2, head_dim=16, rope_theta=1e4)


def _x(rng, B=2, S=64, D=32):
    return jnp.asarray(rng.standard_normal((B, S, D)).astype(np.float32))


def _params(D=32):
    return init_attention(jax.random.PRNGKey(0), D, 4, 2, 16)


def test_pick_chunk():
    assert pick_chunk(4096, 512) == 512
    assert pick_chunk(4224, 512) == 384       # meta-token raggedness
    assert pick_chunk(7, 512) == 7


def test_mask_causal():
    m = _mask(jnp.arange(4), jnp.arange(4), 0, 0)
    assert (np.asarray(m) == np.tril(np.ones((4, 4), bool))).all()


def test_mask_window():
    m = np.asarray(_mask(jnp.arange(6), jnp.arange(6), 2, 0))
    for i in range(6):
        for j in range(6):
            assert m[i, j] == (j <= i and i - j < 2)


def test_mask_prefix_bidirectional():
    m = np.asarray(_mask(jnp.arange(5), jnp.arange(5), 0, 3))
    assert m[0, 2] and m[1, 2]        # within-prefix bidirectional
    assert not m[0, 4]                # prefix cannot see the future suffix
    assert m[4, 0] and m[4, 3]        # suffix is causal over everything


def test_dynamic_mask_matches_static():
    a = _mask(jnp.arange(8), jnp.arange(8), 3, 2)
    b = _make_dynamic_mask(jnp.arange(8), jnp.arange(8), 3, 2)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_chunk_invariance(rng):
    x = _x(rng)
    p = _params()
    y1 = attention_train(p, x, chunk_q=64, **KW)
    y2 = attention_train(p, x, chunk_q=16, **KW)
    y3 = attention_train(p, x, chunk_q=8, **KW)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-5)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y3), atol=2e-5)


def test_banded_equals_masked(rng):
    x = _x(rng, S=128)
    p = _params()
    y_full = attention_train(p, x, window=16, chunk_q=128, **KW)  # mask path
    y_band = attention_train(p, x, window=16, chunk_q=8, **KW)    # band path
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_band), atol=2e-5)


def test_decode_matches_train_last_token(rng):
    """Cached decode of token t == full attention at position t."""
    x = _x(rng, S=16)
    p = _params()
    y_full, (k, v) = attention_train(p, x, chunk_q=16, return_kv=True, **KW)
    # cache holds the first 15 tokens; decode token 15
    cache_k = jnp.zeros((2, 16, 2, 16), jnp.float32).at[:, :15].set(k[:, :15])
    cache_v = jnp.zeros((2, 16, 2, 16), jnp.float32).at[:, :15].set(v[:, :15])
    lengths = jnp.full((2,), 15, jnp.int32)
    y_dec, _ = attention_decode(
        p, x[:, 15:16], (cache_k, cache_v), lengths, **KW
    )
    np.testing.assert_allclose(
        np.asarray(y_dec[:, 0]), np.asarray(y_full[:, 15]), atol=2e-4
    )


def test_ring_decode_matches_windowed_train(rng):
    """Ring-buffer decode == windowed attention at the last position."""
    W = 8
    S = 24
    x = _x(rng, S=S)
    p = _params()
    y_full, (k, v) = attention_train(
        p, x, window=W, chunk_q=S, return_kv=True, **KW
    )
    # build the ring exactly as block_prefill does for the first S-1 tokens
    from repro.models.blocks import _store_kv

    ring_k = _store_kv(k[:, : S - 1], W, W).astype(jnp.float32)
    ring_v = _store_kv(v[:, : S - 1], W, W).astype(jnp.float32)
    lengths = jnp.full((2,), S - 1, jnp.int32)
    y_dec, _ = attention_decode_ring(
        p, x[:, S - 1 :], (ring_k, ring_v), lengths, **KW
    )
    # ring cache is bf16 (production layout) vs the f32 K/V of the train
    # path: tolerance covers the quantisation, not the masking semantics
    np.testing.assert_allclose(
        np.asarray(y_dec[:, 0]), np.asarray(y_full[:, S - 1]), atol=3e-2
    )


def test_gqa_vs_mha_shapes(rng):
    x = _x(rng)
    for G in (1, 2, 4):
        p = init_attention(jax.random.PRNGKey(0), 32, 4, G, 16)
        y = attention_train(
            p, x, num_heads=4, num_kv_heads=G, head_dim=16, rope_theta=1e4
        )
        assert y.shape == x.shape
