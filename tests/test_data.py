"""Data pipeline tests: determinism, sharding, Pixie preprocessing."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import (
    PixiePreprocessor, TokenPipeline, patch_embed_stub, synthetic_images,
)
from repro.core import applications as apps


def test_pipeline_deterministic_and_step_dependent():
    p = TokenPipeline(vocab_size=1000, seq_len=16, global_batch=4, seed=1)
    a1, a2 = p.batch_at(3), p.batch_at(3)
    np.testing.assert_array_equal(a1, a2)
    assert not np.array_equal(p.batch_at(3), p.batch_at(4))
    assert a1.shape == (4, 16) and a1.dtype == np.int32
    assert a1.min() >= 0 and a1.max() < 1000


def test_pipeline_host_shards_partition_batch():
    p = TokenPipeline(vocab_size=50, seq_len=8, global_batch=8, seed=0)
    full = p.batch_at(7)
    parts = [p.host_shard_at(7, h, 4) for h in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts, axis=0), full)


def test_pipeline_different_seeds_differ():
    a = TokenPipeline(vocab_size=50, seq_len=8, global_batch=2, seed=0).batch_at(0)
    b = TokenPipeline(vocab_size=50, seq_len=8, global_batch=2, seed=1).batch_at(0)
    assert not np.array_equal(a, b)


def test_pixie_preprocessor_filters_match_oracles():
    pre = PixiePreprocessor(filters=("sobel_mag", "gauss3"))
    img = jnp.asarray(synthetic_images(1, (16, 24))[0])
    out = np.asarray(pre(img))
    np.testing.assert_allclose(
        out, apps.sobel_magnitude_reference(np.asarray(img)), rtol=1e-4, atol=1e-3
    )
    pre.reconfigure("gauss3")
    out2 = np.asarray(pre(img))
    np.testing.assert_allclose(
        out2,
        apps.conv2d_reference(np.asarray(img), apps.GAUSS3, divisor=16.0),
        rtol=1e-4, atol=1e-3,
    )


def test_pixie_preprocessor_reconfigure_no_recompile():
    pre = PixiePreprocessor(filters=("sobel_mag", "sharpen", "laplace"))
    img = jnp.asarray(synthetic_images(1, (12, 12))[0])
    pre(img)
    n = pre.overlay._cache_size()
    for f in ("sharpen", "laplace", "sobel_mag"):
        pre.reconfigure(f)
        pre(img)
    assert pre.overlay._cache_size() == n  # settings swap, same executable


def test_patch_embed_stub_shapes():
    imgs = synthetic_images(3, (32, 32), seed=5)
    pe = patch_embed_stub(imgs, num_patches=16, d_model=64)
    assert pe.shape == (3, 16, 64)
    assert np.isfinite(pe).all()
