"""Kernel tests: VCGRA Pallas executor (specialized + conventional) vs the
pure-jnp oracle, swept over applications, shapes and dtypes."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import for_dfg, map_app, sobel_grid
from repro.core import applications as apps
from repro.core.interpreter import pack_inputs
from repro.kernels.vcgra import vcgra_apply, vcgra_apply_image, vcgra_ref
from repro.kernels.vcgra.vcgra_kernel import _pack_settings


def _setup(app_name, data_bits=32, float_pe=False, shape="exact"):
    dfg = apps.ALL_APPS[app_name]()
    grid = for_dfg(dfg, shape=shape, data_bits=data_bits, float_pe=float_pe)
    cfg = map_app(dfg, grid)
    return dfg, grid, cfg


@pytest.mark.parametrize("app_name", ["sobel_x", "sobel_mag", "gauss3", "threshold"])
@pytest.mark.parametrize("mode", ["specialized", "conventional"])
@pytest.mark.parametrize(
    "hw", [(8, 16), (16, 128), (30, 67)]  # aligned and ragged image shapes
)
def test_kernel_matches_ref_int(app_name, mode, hw, rng):
    dfg, grid, cfg = _setup(app_name)
    img = jnp.asarray(rng.integers(0, 256, hw).astype(np.int32))
    taps = apps.stencil_inputs(img)
    feed = {k: v for k, v in taps.items() if k in cfg.input_order}
    x = pack_inputs(cfg, feed, grid.dtype)
    ref = np.asarray(vcgra_ref(grid, cfg, x))
    out = np.asarray(vcgra_apply(grid, cfg, x, mode=mode, block_n=256))
    np.testing.assert_array_equal(out, ref)


@pytest.mark.parametrize("mode", ["specialized", "conventional"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_matches_ref_float(mode, dtype, rng):
    dfg = apps.sobel_magnitude()
    grid = for_dfg(dfg, shape="exact", float_pe=True, data_bits=32)
    cfg = map_app(dfg, grid)
    img = jnp.asarray(rng.random((16, 32)).astype(np.float32) * 100).astype(dtype)
    taps = apps.stencil_inputs(img)
    x = pack_inputs(cfg, taps, dtype)
    ref = np.asarray(vcgra_ref(grid, cfg, x).astype(jnp.float32))
    out = np.asarray(
        vcgra_apply(grid, cfg, x, mode=mode, block_n=128).astype(jnp.float32)
    )
    tol = 1e-6 if dtype == jnp.float32 else 0.5
    np.testing.assert_allclose(out, ref, rtol=tol, atol=tol)


@pytest.mark.parametrize("block_n", [128, 256, 1024])
def test_kernel_block_size_sweep(block_n, rng):
    dfg, grid, cfg = _setup("sobel_x")
    img = jnp.asarray(rng.integers(0, 256, (24, 53)).astype(np.int32))
    out = np.asarray(vcgra_apply_image(grid, cfg, img, block_n=block_n))
    np.testing.assert_array_equal(out, apps.conv2d_reference(np.asarray(img), apps.SOBEL_X))


def test_kernel_on_rect_grid_with_none_pes(rng):
    """Fig. 5 mapping (45-PE rect grid, 25 NONE PEs) through the kernel."""
    dfg = apps.sobel_x()
    grid = sobel_grid()
    cfg = map_app(dfg, grid)
    img = jnp.asarray(rng.integers(0, 256, (12, 12)).astype(np.int32))
    out = np.asarray(vcgra_apply_image(grid, cfg, img, mode="specialized", block_n=128))
    np.testing.assert_array_equal(out, apps.conv2d_reference(np.asarray(img), apps.SOBEL_X))
    out_c = np.asarray(
        vcgra_apply_image(grid, cfg, img, mode="conventional", block_n=128)
    )
    np.testing.assert_array_equal(out_c, out)


def test_conventional_settings_pack_roundtrip():
    dfg, grid, cfg = _setup("sobel_mag")
    ops_arr, sel_arr, out_sel, max_w = _pack_settings(grid, cfg)
    assert ops_arr.shape == (grid.num_levels, max_w)
    assert sel_arr.shape == (grid.num_levels, max_w, 2)
    for lvl in range(grid.num_levels):
        w = grid.pes_per_level[lvl]
        np.testing.assert_array_equal(np.asarray(ops_arr)[lvl, :w], cfg.opcodes[lvl])
        np.testing.assert_array_equal(np.asarray(sel_arr)[lvl, :w], cfg.selects[lvl])
