"""Kernel tests: VCGRA Pallas executor (specialized + conventional) vs the
pure-jnp oracle, swept over applications, shapes and dtypes -- plus the
batched fused-ingest megakernel (N tenants, raw frames, one pallas_call)
vs the batched interpreter oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import shared_app_grid

from repro.core import for_dfg, map_app, sobel_grid
from repro.core import applications as apps
from repro.core.bitstream import VCGRAConfig
from repro.core.ingest import IngestPlan
from repro.core.interpreter import (
    batched_fused_overlay_step,
    batched_overlay_step,
    pack_inputs,
    pad_channels,
)
from repro.kernels.vcgra import (
    default_interpret,
    make_batched_fused_pallas_fn,
    make_batched_pallas_fn,
    pack_settings_batched,
    vcgra_apply,
    vcgra_apply_image,
    vcgra_ref,
)
from repro.kernels.vcgra.vcgra_kernel import _pack_settings


def _setup(app_name, data_bits=32, float_pe=False, shape="exact"):
    dfg = apps.ALL_APPS[app_name]()
    grid = for_dfg(dfg, shape=shape, data_bits=data_bits, float_pe=float_pe)
    cfg = map_app(dfg, grid)
    return dfg, grid, cfg


@pytest.mark.parametrize("app_name", ["sobel_x", "sobel_mag", "gauss3", "threshold"])
@pytest.mark.parametrize("mode", ["specialized", "conventional"])
@pytest.mark.parametrize(
    "hw", [(8, 16), (16, 128), (30, 67)]  # aligned and ragged image shapes
)
def test_kernel_matches_ref_int(app_name, mode, hw, rng):
    dfg, grid, cfg = _setup(app_name)
    img = jnp.asarray(rng.integers(0, 256, hw).astype(np.int32))
    taps = apps.stencil_inputs(img)
    feed = {k: v for k, v in taps.items() if k in cfg.input_order}
    x = pack_inputs(cfg, feed, grid.dtype)
    ref = np.asarray(vcgra_ref(grid, cfg, x))
    out = np.asarray(vcgra_apply(grid, cfg, x, mode=mode, block_n=256))
    np.testing.assert_array_equal(out, ref)


@pytest.mark.parametrize("mode", ["specialized", "conventional"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_matches_ref_float(mode, dtype, rng):
    dfg = apps.sobel_magnitude()
    grid = for_dfg(dfg, shape="exact", float_pe=True, data_bits=32)
    cfg = map_app(dfg, grid)
    img = jnp.asarray(rng.random((16, 32)).astype(np.float32) * 100).astype(dtype)
    taps = apps.stencil_inputs(img)
    x = pack_inputs(cfg, taps, dtype)
    ref = np.asarray(vcgra_ref(grid, cfg, x).astype(jnp.float32))
    out = np.asarray(
        vcgra_apply(grid, cfg, x, mode=mode, block_n=128).astype(jnp.float32)
    )
    tol = 1e-6 if dtype == jnp.float32 else 0.5
    np.testing.assert_allclose(out, ref, rtol=tol, atol=tol)


@pytest.mark.parametrize("block_n", [128, 256, 1024])
def test_kernel_block_size_sweep(block_n, rng):
    dfg, grid, cfg = _setup("sobel_x")
    img = jnp.asarray(rng.integers(0, 256, (24, 53)).astype(np.int32))
    out = np.asarray(vcgra_apply_image(grid, cfg, img, block_n=block_n))
    np.testing.assert_array_equal(out, apps.conv2d_reference(np.asarray(img), apps.SOBEL_X))


def test_kernel_on_rect_grid_with_none_pes(rng):
    """Fig. 5 mapping (45-PE rect grid, 25 NONE PEs) through the kernel."""
    dfg = apps.sobel_x()
    grid = sobel_grid()
    cfg = map_app(dfg, grid)
    img = jnp.asarray(rng.integers(0, 256, (12, 12)).astype(np.int32))
    out = np.asarray(vcgra_apply_image(grid, cfg, img, mode="specialized", block_n=128))
    np.testing.assert_array_equal(out, apps.conv2d_reference(np.asarray(img), apps.SOBEL_X))
    out_c = np.asarray(
        vcgra_apply_image(grid, cfg, img, mode="conventional", block_n=128)
    )
    np.testing.assert_array_equal(out_c, out)


def test_conventional_settings_pack_roundtrip():
    dfg, grid, cfg = _setup("sobel_mag")
    ops_arr, sel_arr, out_sel, max_w = _pack_settings(grid, cfg)
    assert ops_arr.shape == (grid.num_levels, max_w)
    assert sel_arr.shape == (grid.num_levels, max_w, 2)
    for lvl in range(grid.num_levels):
        w = grid.pes_per_level[lvl]
        np.testing.assert_array_equal(np.asarray(ops_arr)[lvl, :w], cfg.opcodes[lvl])
        np.testing.assert_array_equal(np.asarray(sel_arr)[lvl, :w], cfg.selects[lvl])


# -- batched fused-ingest megakernel ------------------------------------------

MEGA_NAMES = sorted(apps.ALL_APPS)
MEGA_GRID = shared_app_grid(MEGA_NAMES, name="megakernel-shared")


def test_default_interpret_is_platform_aware():
    """interpret=None auto-detects: interpreted everywhere except real TPU
    (the satellite fix for the unconditional interpret=True default)."""
    on_tpu = jax.default_backend() == "tpu"
    assert default_interpret() is (not on_tpu)


def test_pack_settings_batched_dense_banks():
    """Dense SMEM banks agree with the per-app `_pack_settings` rows and
    zero-fill (Op.NONE) the pad slots beyond each level's true width."""
    configs = [map_app(apps.ALL_APPS[n](), MEGA_GRID) for n in ["sobel_x", "gauss3"]]
    ops_d, sel_d, out_d = pack_settings_batched(
        MEGA_GRID, VCGRAConfig.stack(configs)
    )
    max_w = max(MEGA_GRID.pes_per_level)
    n, L = len(configs), MEGA_GRID.num_levels
    assert ops_d.shape == (n, L, max_w) and sel_d.shape == (n, L, max_w, 2)
    assert out_d.shape == (n, MEGA_GRID.num_outputs)
    for i, cfg in enumerate(configs):
        ref_ops, ref_sel, ref_out, _ = _pack_settings(MEGA_GRID, cfg)
        np.testing.assert_array_equal(np.asarray(ops_d)[i], np.asarray(ref_ops))
        np.testing.assert_array_equal(np.asarray(sel_d)[i], np.asarray(ref_sel))
        np.testing.assert_array_equal(np.asarray(out_d)[i], np.asarray(ref_out))
        for lvl in range(L):
            w = MEGA_GRID.pes_per_level[lvl]
            assert not np.asarray(ops_d)[i, lvl, w:].any()


def test_megakernel_fused_batched_matches_interpreter_all_apps(rng):
    """The tentpole invariant: every library app stacked into ONE fused
    megakernel dispatch over ragged non-square frames is bitwise equal to
    the XLA batched fused interpreter (itself the tested oracle)."""
    images = [
        rng.integers(0, 256, (6 + 2 * i, 19 - i)).astype(np.int32)
        for i in range(len(MEGA_NAMES))
    ]
    configs = [map_app(apps.ALL_APPS[n](), MEGA_GRID) for n in MEGA_NAMES]
    Hb = max(i.shape[0] for i in images)
    Wb = max(i.shape[1] for i in images)
    canvas = np.zeros((len(MEGA_NAMES), Hb, Wb), dtype=np.int32)
    for i, img in enumerate(images):
        canvas[i, : img.shape[0], : img.shape[1]] = img

    stacked = VCGRAConfig.stack(configs)
    ingests = IngestPlan.stack([c.ingest for c in configs], MEGA_GRID.dtype)
    ref = batched_fused_overlay_step(
        MEGA_GRID, 1, stacked, ingests, jnp.asarray(canvas)
    )
    got = make_batched_fused_pallas_fn(MEGA_GRID, radius=1)(
        stacked, ingests, jnp.asarray(canvas)
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_megakernel_batched_matches_interpreter_unaligned_batch(rng):
    """Pre-packed channel path: the pallas wrapper pads the pixel axis to a
    lane multiple internally and slices back, so lane-unaligned batches
    keep the XLA contract bitwise."""
    grid = sobel_grid()
    names = ["sobel_x", "sobel_y", "sharpen", "laplace"]
    configs = [map_app(apps.ALL_APPS[n](), grid) for n in names]
    x = rng.integers(0, 256, (len(names), grid.num_inputs, 45)).astype(np.int32)
    stacked = VCGRAConfig.stack(configs)
    ref = batched_overlay_step(grid, stacked, jnp.asarray(x))
    got = make_batched_pallas_fn(grid)(stacked, jnp.asarray(x))
    assert got.shape == ref.shape
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_megakernel_casts_frames_to_grid_dtype_like_oracle(rng):
    """Frames arriving in another dtype (float32 with fractional values on
    an int32 grid) must be cast at ingest exactly like the XLA path's
    ``form_tap_bank``, or the backends diverge in dtype AND values."""
    grid = sobel_grid()
    imgs = (rng.random((2, 6, 6)) * 256 + 0.5).astype(np.float32)
    configs = [map_app(apps.ALL_APPS[n](), grid) for n in ["sobel_x", "threshold"]]
    stacked = VCGRAConfig.stack(configs)
    ingests = IngestPlan.stack([c.ingest for c in configs], grid.dtype)
    ref = batched_fused_overlay_step(grid, 1, stacked, ingests, jnp.asarray(imgs))
    got = make_batched_fused_pallas_fn(grid, radius=1)(stacked, ingests,
                                                       jnp.asarray(imgs))
    assert got.dtype == ref.dtype == grid.dtype
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_megakernel_settings_are_runtime_data(rng):
    """Compile-once: swapping which app runs in which slot must reuse the
    jitted megakernel executable (settings are SMEM operands, not trace
    constants)."""
    grid = sobel_grid()
    img = rng.integers(0, 256, (2, 8, 8)).astype(np.int32)
    fn = make_batched_fused_pallas_fn(grid, radius=1)
    pair_a = [map_app(apps.ALL_APPS[n](), grid) for n in ["sobel_x", "laplace"]]
    pair_b = [map_app(apps.ALL_APPS[n](), grid) for n in ["sobel_y", "identity"]]
    for pair in (pair_a, pair_b):
        got = fn(
            VCGRAConfig.stack(pair),
            IngestPlan.stack([c.ingest for c in pair], grid.dtype),
            jnp.asarray(img),
        )
        ref = batched_fused_overlay_step(
            grid, 1, VCGRAConfig.stack(pair),
            IngestPlan.stack([c.ingest for c in pair], grid.dtype),
            jnp.asarray(img),
        )
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    # The compile-once assert is the point of this test; if jax ever drops
    # the private _cache_size introspection, skip loudly rather than let
    # the test silently degrade to a plain parity check.
    sizer = getattr(fn, "_cache_size", None)
    if not callable(sizer):
        pytest.skip("this jax version has no jit _cache_size introspection")
    assert sizer() == 1
