"""Optimizer tests: AdamW semantics, schedule, clipping, compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (
    AdamWConfig, adamw_update, compress, decompress, global_norm,
    init_opt_state, init_error_state, schedule_lr,
)


def _params():
    return {
        "w_up": jnp.ones((4, 8)) * 0.5,
        "ln": {"scale": jnp.zeros((8,))},
    }


def test_adamw_moves_against_gradient():
    p = _params()
    g = jax.tree_util.tree_map(jnp.ones_like, p)
    st = init_opt_state(p)
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, schedule="constant", weight_decay=0.0)
    p2, st2, m = adamw_update(cfg, p, g, st)
    assert float(p2["w_up"][0, 0]) < float(p["w_up"][0, 0])
    assert int(st2["count"]) == 1
    assert float(m["lr"]) == pytest.approx(0.1)


def test_weight_decay_only_on_matrices():
    p = _params()
    g = jax.tree_util.tree_map(jnp.zeros_like, p)
    st = init_opt_state(p)
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, schedule="constant", weight_decay=0.5)
    p2, _, _ = adamw_update(cfg, p, g, st)
    # matrix decayed toward zero, norm scale untouched
    assert float(jnp.abs(p2["w_up"]).max()) < 0.5
    np.testing.assert_array_equal(np.asarray(p2["ln"]["scale"]), 0.0)


def test_grad_clipping():
    p = {"w": jnp.zeros((4, 4))}
    g = {"w": jnp.full((4, 4), 100.0)}
    st = init_opt_state(p)
    cfg = AdamWConfig(lr=1.0, clip_norm=1.0, warmup_steps=0, schedule="constant")
    _, _, m = adamw_update(cfg, p, g, st)
    assert float(m["grad_norm"]) == pytest.approx(400.0)
    assert float(m["clip_scale"]) == pytest.approx(1.0 / 400.0)


def test_schedule_warmup_and_cosine():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110, min_lr_ratio=0.1)
    assert float(schedule_lr(cfg, jnp.asarray(0))) == pytest.approx(0.0)
    assert float(schedule_lr(cfg, jnp.asarray(5))) == pytest.approx(0.5)
    assert float(schedule_lr(cfg, jnp.asarray(10))) == pytest.approx(1.0)
    end = float(schedule_lr(cfg, jnp.asarray(110)))
    assert end == pytest.approx(0.1, rel=1e-3)


def test_global_norm():
    t = {"a": jnp.ones((3,)), "b": jnp.ones((4,)) * 2}
    assert float(global_norm(t)) == pytest.approx(np.sqrt(3 + 16))


def test_compression_error_feedback_roundtrip():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.standard_normal((32, 32)).astype(np.float32))}
    err = init_error_state(g)
    comp, err2 = compress(g, err)
    deq = decompress(comp)
    # int8 quantisation: bounded error, int8 payload
    assert comp["q"]["w"].dtype == jnp.int8
    scale = float(comp["scale"]["w"])
    assert float(jnp.abs(deq["w"] - g["w"]).max()) <= scale * 0.5 + 1e-6
    # error feedback carries exactly the residual
    np.testing.assert_allclose(
        np.asarray(err2["w"]), np.asarray(g["w"] - deq["w"]), atol=1e-6
    )
    # second round: dequant(sum of q) + err converges toward true sum
    comp2, err3 = compress(g, err2)
    deq2 = decompress(comp2)
    total = np.asarray(deq["w"] + deq2["w"])
    np.testing.assert_allclose(total, 2 * np.asarray(g["w"]), atol=2.1 * scale)
