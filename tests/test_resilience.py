"""Self-healing serving tests (PR 10).

Covers the resilience stack end to end: the deterministic retry/backoff
policy, per-plan circuit-breaker transitions (fake clock, no sleeps), the
bitwise-safe fallback chain, fault-injected fleet dispatch (transient
retry, persistent quarantine-by-bisection, NaN/Inf output guard), the
straggler->breaker coupling, and the supervised streaming worker (crash
restart with no hung JobHandle, worker_death injection, per-request hard
timeouts, surrender after max restarts, and the close/submit race
regression).

Every blocking call carries an explicit timeout: a supervisor bug must
fail the test, not hang the suite (CI adds pytest-timeout as a second
belt).
"""

import threading
import time

import numpy as np
import pytest

from repro.core import applications as apps
from repro.core import sobel_grid
from repro.core.plan import OverlayPlan, fallback_chain
from repro.parallel.axes import MeshSpec
from repro.runtime.chaos import FaultInjector, InjectedFault
from repro.runtime.fleet import FleetRequest, PixieFleet
from repro.runtime.fault_tolerance import HeartbeatMonitor
from repro.runtime.resilience import (
    BreakerBoard, CircuitBreaker, RetryPolicy, TransientError,
)
from repro.serve import (
    DispatchError, FleetFrontend, JobTimeout, QuarantinedError,
    StreamingFrontend,
)

WAIT = 120.0       # generous per-call bound; loaded CI hosts compile slowly
BACKENDS = ["xla", "pallas"]


def _fleet(backend="xla", float_pe=False, **kw):
    return PixieFleet(default_grid=sobel_grid(float_pe=float_pe),
                      backend=backend, **kw)


def _img(rng, shape=(8, 10), float_pe=False):
    a = rng.integers(0, 256, shape)
    return a.astype(np.float32) if float_pe else a.astype(np.int32)


def _oracle(backend, images, names, float_pe=False):
    fleet = _fleet(backend, float_pe=float_pe)
    return fleet.run_many([FleetRequest(app=n, image=im)
                           for n, im in zip(names, images)])


# -- retry policy -------------------------------------------------------------


def test_backoff_schedule_is_deterministic_and_capped():
    r = RetryPolicy(max_attempts=5, backoff_base_s=0.01,
                    backoff_multiplier=2.0, backoff_max_s=0.05)
    assert r.schedule() == (0.01, 0.02, 0.04, 0.05)   # capped at max
    assert r.schedule() == r.schedule()               # pure, no jitter
    assert r.backoff_s(10) == 0.05


def test_retry_policy_transient_classification():
    r = RetryPolicy()

    class Flaky(Exception):
        transient = True

    class Fatal(Exception):
        transient = False

    assert r.should_retry(TransientError("x"))
    assert r.should_retry(Flaky())
    assert r.should_retry(InjectedFault("dispatch", transient=True))
    assert not r.should_retry(InjectedFault("dispatch", transient=False))
    assert not r.should_retry(Fatal())
    assert not r.should_retry(ValueError("deterministic"))


def test_retry_policy_validates():
    with pytest.raises(ValueError):
        RetryPolicy(max_attempts=0)
    with pytest.raises(ValueError):
        RetryPolicy(backoff_base_s=-1.0)


# -- circuit breaker (fake clock, no sleeps) ----------------------------------


def test_breaker_opens_after_consecutive_failures_and_recovers():
    t = [0.0]
    br = CircuitBreaker("plan-a", failure_threshold=3, cooldown_s=1.0,
                        clock=lambda: t[0])
    assert br.allow()
    br.record_failure()
    br.record_failure()
    assert br.state == "closed"      # below threshold
    br.record_failure()
    assert br.state == "open"
    assert not br.allow()            # still cooling down
    t[0] = 0.5
    assert not br.allow()
    t[0] = 1.0                       # cooldown elapsed: one half-open probe
    assert br.allow()
    assert br.state == "half_open"
    assert not br.allow()            # the single probe is in flight
    br.record_success()
    assert br.state == "closed"
    assert [e["event"] for e in br.events] == ["open:dispatch", "half_open",
                                               "close"]


def test_breaker_reopens_on_failed_probe():
    t = [0.0]
    br = CircuitBreaker("plan-a", failure_threshold=1, cooldown_s=1.0,
                        clock=lambda: t[0])
    br.record_failure("boom")
    assert br.state == "open"
    t[0] = 1.0
    assert br.allow()
    br.record_failure("boom")
    assert br.state == "open"        # re-opened, new cooldown window
    t[0] = 1.5
    assert not br.allow()
    events = [e["event"] for e in br.events]
    assert events == ["open:boom", "half_open", "reopen:boom"]


def test_breaker_success_resets_consecutive_count():
    br = CircuitBreaker("plan-a", failure_threshold=2)
    br.record_failure()
    br.record_success()
    br.record_failure()
    assert br.state == "closed"      # never 2 consecutive


def test_breaker_board_shares_one_event_log():
    t = [0.0]
    board = BreakerBoard(failure_threshold=1, cooldown_s=1.0,
                         clock=lambda: t[0])
    board.breaker("a").record_failure()
    board.breaker("b").record_failure()
    assert board.states() == {"a": "open", "b": "open"}
    assert not board.all_closed()
    assert [e["plan"] for e in board.events] == ["a", "b"]
    assert board.breaker("a") is board.breaker("a")


# -- fallback chain -----------------------------------------------------------


def test_fallback_chain_degrades_every_axis_in_order():
    plan = OverlayPlan(grid=sobel_grid(), batched=True, fused=True, radius=1,
                       backend="pallas", mesh=MeshSpec(app=2, rows=2),
                       tile_rows=8, ingest="async")
    chain = fallback_chain(plan)
    assert len(chain) == 4
    # step 1: backend falls to the XLA oracle, everything else kept
    assert chain[0].backend == "xla" and chain[0].mesh == plan.mesh
    # step 2: row banding dropped (app-only mesh)
    assert chain[1].mesh == MeshSpec(app=2)
    # step 3: single device
    assert chain[2].mesh == MeshSpec()
    # step 4 (most degraded): untiled single-device XLA
    last = chain[-1]
    assert (last.backend, last.mesh, last.tile_rows) == ("xla", MeshSpec(), None)
    # every step keeps the work axes that define the computed values
    assert all(c.grid == plan.grid and c.fused and c.radius == 1
               for c in chain)


def test_fallback_chain_empty_for_already_degraded_plan():
    plan = OverlayPlan(grid=sobel_grid(), batched=True, fused=True, radius=1,
                       backend="xla", mesh=MeshSpec(), tile_rows=None)
    assert fallback_chain(plan) == ()


# -- fleet: transient retry ---------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
def test_transient_dispatch_faults_are_retried_bitwise(rng, backend):
    imgs = [_img(rng), _img(rng, (6, 7))]
    names = ["sobel_x", "laplace"]
    oracle = _oracle(backend, imgs, names)
    faults = FaultInjector(seed=11).inject("dispatch", max_fires=2)
    fleet = _fleet(backend, faults=faults,
                   retry=RetryPolicy(backoff_base_s=1e-4))
    outs = fleet.run_many([FleetRequest(app=n, image=im)
                           for n, im in zip(names, imgs)])
    for got, want in zip(outs, oracle):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert fleet.stats.retries == 2
    assert fleet.stats.quarantined_requests == 0


def test_nontransient_fault_skips_retries_and_uses_fallback(rng):
    # A persistent pallas-plan fault: no retry burn, straight down the
    # chain to the XLA sibling, bitwise.
    img = _img(rng)
    oracle = _oracle("xla", [img], ["sobel_x"])[0]
    faults = FaultInjector(seed=0).inject(
        "dispatch", transient=False, match=("|pallas|",))
    fleet = _fleet("pallas", faults=faults)
    out = fleet.run_many([FleetRequest(app="sobel_x", image=img)])[0]
    np.testing.assert_array_equal(np.asarray(out), np.asarray(oracle))
    assert fleet.stats.retries == 0
    assert fleet.stats.fallback_dispatches == 1


# -- fleet: quarantine by bisection -------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
def test_poisoned_tickets_are_exactly_isolated(rng, backend):
    names = ["sobel_x", "sobel_y", "laplace", "sharpen", "identity",
             "threshold"]
    imgs = [_img(rng, (5 + i, 7)) for i in range(len(names))]
    oracle = _oracle(backend, imgs, names)
    # Tickets 1 and 4 are poisoned persistently: every plan fails any
    # batch containing them, so bisection must quarantine exactly those
    # two and serve the other four bitwise.
    faults = FaultInjector(seed=3).inject(
        "dispatch", transient=False, match=("<ticket:1>", "<ticket:4>"))
    fleet = _fleet(backend, faults=faults,
                   retry=RetryPolicy(max_attempts=1))
    tickets = [fleet.submit(FleetRequest(app=n, image=im))
               for n, im in zip(names, imgs)]
    fleet.flush()
    for i, t in enumerate(tickets):
        if i in (1, 4):
            with pytest.raises(QuarantinedError) as ei:
                fleet.result(t)
            assert ei.value.ticket == t
            assert ei.value.app == names[i]
        else:
            np.testing.assert_array_equal(
                np.asarray(fleet.result(t)), np.asarray(oracle[i]))
    assert fleet.stats.quarantined_requests == 2


def test_quarantined_error_carries_cause():
    rng = np.random.default_rng(0)
    faults = FaultInjector(seed=0).inject(
        "dispatch", transient=False, match=("<app:threshold>",),
        detail="poison pill")
    fleet = _fleet(faults=faults, retry=RetryPolicy(max_attempts=1))
    t = fleet.submit(FleetRequest(app="threshold", image=_img(rng)))
    fleet.flush()
    with pytest.raises(QuarantinedError) as ei:
        fleet.result(t)
    assert isinstance(ei.value.cause, InjectedFault)
    assert "poison pill" in str(ei.value.cause)


# -- fleet: NaN/Inf output guard ----------------------------------------------


def test_output_guard_retries_transient_nan_bitwise(rng):
    img = _img(rng, float_pe=True)
    oracle = _oracle("xla", [img], ["sobel_x"], float_pe=True)[0]
    faults = FaultInjector(seed=5).inject(
        "nan_output", max_fires=1, match=("<app:sobel_x>",))
    fleet = _fleet(float_pe=True, faults=faults,
                   retry=RetryPolicy(backoff_base_s=1e-4))
    out = fleet.run_many([FleetRequest(app="sobel_x", image=img)])[0]
    arr = np.asarray(out)
    assert np.isfinite(arr).all()
    np.testing.assert_array_equal(arr, np.asarray(oracle))
    assert fleet.stats.guard_failures == 1


def test_output_guard_quarantines_persistent_nan_and_serves_batchmate(rng):
    imgs = [_img(rng, float_pe=True), _img(rng, (6, 7), float_pe=True)]
    names = ["sobel_x", "laplace"]
    oracle = _oracle("xla", imgs, names, float_pe=True)
    faults = FaultInjector(seed=5).inject(
        "nan_output", match=("<app:laplace>",))
    fleet = _fleet(float_pe=True, faults=faults,
                   retry=RetryPolicy(max_attempts=1))
    t_ok = fleet.submit(FleetRequest(app="sobel_x", image=imgs[0]))
    t_bad = fleet.submit(FleetRequest(app="laplace", image=imgs[1]))
    fleet.flush()
    np.testing.assert_array_equal(
        np.asarray(fleet.result(t_ok)), np.asarray(oracle[0]))
    with pytest.raises(QuarantinedError):
        fleet.result(t_bad)
    assert fleet.stats.quarantined_requests == 1


# -- fleet: breaker integration -----------------------------------------------


def test_breaker_opens_then_recovers_through_fallback(rng):
    # A pallas primary that fails 3 consecutive flushes opens its
    # breaker; traffic then goes straight to the XLA fallback without
    # even offering the primary.  Once the fault burns out and the
    # cooldown (fake clock) elapses, a half-open probe closes it again.
    img = _img(rng)
    t = [0.0]
    board = BreakerBoard(failure_threshold=3, cooldown_s=10.0,
                         clock=lambda: t[0])
    faults = FaultInjector(seed=0).inject(
        "dispatch", transient=False, match=("|pallas|",), max_fires=3)
    fleet = _fleet("pallas", faults=faults, breakers=board)
    pallas_key = None
    for _ in range(3):
        fleet.run_many([FleetRequest(app="sobel_x", image=img)])
    opened = [e for e in fleet.stats.breaker_events
              if e["event"].startswith("open:")]
    assert len(opened) == 1
    pallas_key = opened[0]["plan"]
    assert "pallas" in pallas_key
    assert board.states()[pallas_key] == "open"
    assert fleet.stats.fallback_dispatches == 3

    # Open breaker: the primary is not offered (fault is exhausted, so a
    # dispatch attempt would have SUCCEEDED -- the skip proves the
    # breaker, not the fault, routed traffic).
    fleet.run_many([FleetRequest(app="sobel_x", image=img)])
    assert fleet.stats.fallback_dispatches == 4

    # Cooldown elapses: half-open probe on the primary succeeds, closes.
    t[0] = 10.0
    fleet.run_many([FleetRequest(app="sobel_x", image=img)])
    assert board.states()[pallas_key] == "closed"
    events = [e["event"] for e in fleet.stats.breaker_events
              if e["plan"] == pallas_key]
    assert events == ["open:dispatch", "half_open", "close"]
    assert fleet.stats.fallback_dispatches == 4   # primary served it


def test_open_breaker_with_no_fallback_still_serves_as_last_resort(rng):
    # A fully-degraded plan has an empty chain; even with its breaker
    # open the fleet must dispatch it rather than fail available work.
    img = _img(rng)
    oracle = _oracle("xla", [img], ["sobel_x"])[0]
    board = BreakerBoard(failure_threshold=1, cooldown_s=1e9)
    faults = FaultInjector(seed=0).inject("dispatch", max_fires=1)
    fleet = _fleet("xla", faults=faults, breakers=board,
                   retry=RetryPolicy(max_attempts=1))
    out1 = fleet.run_many([FleetRequest(app="sobel_x", image=img)])
    assert not board.all_closed()        # single failure opened it
    out2 = fleet.run_many([FleetRequest(app="sobel_x", image=img)])
    np.testing.assert_array_equal(np.asarray(out2[0]), np.asarray(oracle))
    np.testing.assert_array_equal(np.asarray(out1[0]), np.asarray(oracle))


def test_straggler_flush_counts_against_the_breaker(rng):
    # An armed fleet (heartbeat explicitly installed) converts a flagged
    # straggler flush into breaker failures for the plans it dispatched.
    img = _img(rng)
    mon = HeartbeatMonitor(window=16, factor=1.0)
    mon.durations.extend([1e-9] * 8)     # any real flush is >> 1x median
    board = BreakerBoard(failure_threshold=1, cooldown_s=1e9)
    fleet = _fleet("xla", heartbeat=mon, breakers=board)
    fleet.run_many([FleetRequest(app="sobel_x", image=img)])
    assert fleet.stats.straggler_flushes == 1
    assert any(e["event"] == "open:straggler"
               for e in fleet.stats.breaker_events)


def test_unarmed_fleet_never_trips_breakers_on_stragglers(rng):
    # Default construction (no faults/breakers/heartbeat passed) keeps
    # the straggler->breaker coupling off: a slow first flush after
    # compile must not poison plans for a plain batch user.
    img = _img(rng)
    fleet = _fleet("xla")
    fleet.heartbeat.durations.extend([1e-9] * 8)
    fleet.run_many([FleetRequest(app="sobel_x", image=img)])
    assert fleet.stats.breaker_events == []
    assert fleet.breakers.all_closed()


# -- fleet: compile-time faults -----------------------------------------------


def test_compile_fault_falls_back_and_does_not_cache_failure(rng):
    img = _img(rng)
    oracle = _oracle("xla", [img], ["sobel_x"])[0]
    faults = FaultInjector(seed=0).inject(
        "compile", transient=False, match=("|pallas|",), max_fires=1)
    fleet = _fleet("pallas", faults=faults)
    out = fleet.run_many([FleetRequest(app="sobel_x", image=img)])[0]
    np.testing.assert_array_equal(np.asarray(out), np.asarray(oracle))
    assert fleet.stats.fallback_dispatches == 1
    # The failed build was never cached: the next flush compiles the
    # pallas primary cleanly and serves from it.
    fleet.run_many([FleetRequest(app="sobel_x", image=img)])
    assert fleet.stats.fallback_dispatches == 1


# -- sync front-end routing ---------------------------------------------------


def test_sync_frontend_routes_quarantine_to_the_handle(rng):
    faults = FaultInjector(seed=0).inject(
        "dispatch", transient=False, match=("<app:threshold>",))
    svc = FleetFrontend(fleet=_fleet(faults=faults,
                                     retry=RetryPolicy(max_attempts=1)))
    h_ok = svc.submit("sobel_x", _img(rng))
    h_bad = svc.submit("threshold", _img(rng))
    out = h_ok.result(timeout=WAIT)      # drives the flush
    assert np.asarray(out).shape == (8, 10)
    with pytest.raises(QuarantinedError):
        h_bad.result(timeout=WAIT)
    assert svc.latency.failed == 1


# -- streaming: supervised worker ---------------------------------------------


class Boom(BaseException):
    """A worker-killing failure below Exception (like SystemExit from a
    wedged extension): only the supervisor may catch it."""


def test_streaming_worker_crash_strands_no_handle(rng):
    svc = StreamingFrontend(backend="xla", autostart=False)
    orig_flush = svc.fleet.flush
    calls = {"n": 0}

    def crashing_flush(*a, **kw):
        calls["n"] += 1
        if calls["n"] == 1:
            raise Boom("simulated hard crash mid-dispatch")
        return orig_flush(*a, **kw)

    svc.fleet.flush = crashing_flush
    svc.start()
    h1 = svc.submit("sobel_x", _img(rng))
    with pytest.raises(DispatchError, match="crashed"):
        h1.result(timeout=WAIT)
    # The restarted worker keeps serving.
    h2 = svc.submit("sobel_x", _img(rng))
    assert np.asarray(h2.result(timeout=WAIT)).shape == (8, 10)
    assert svc.worker_restarts == 1
    assert svc.latency.failed == 1
    svc.close(timeout=WAIT)


def test_streaming_worker_death_injection_restarts_and_serves(rng):
    img = _img(rng)
    with StreamingFrontend(backend="xla") as oracle_svc:
        want = oracle_svc.submit("sobel_x", img).result(timeout=WAIT)
    faults = FaultInjector(seed=3).inject("worker_death", max_fires=1)
    with StreamingFrontend(backend="xla", faults=faults) as svc:
        out = svc.submit("sobel_x", img).result(timeout=WAIT)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(want))
        assert svc.worker_restarts == 1
        assert faults.fired.get("worker_death") == 1


def test_streaming_supervisor_surrenders_after_max_restarts(rng):
    # max_worker_restarts=0: the first crash exceeds the budget, so the
    # supervisor surrenders -- every accepted handle fails typed (the
    # in-flight batch AND anything still pending/queued), the front-end
    # closes itself, and close() must not hang on the dead worker.
    svc = StreamingFrontend(backend="xla", autostart=False,
                            max_worker_restarts=0)

    def always_boom(*a, **kw):
        raise Boom("persistent crash")

    svc.fleet.flush = always_boom
    handles = [svc.submit("sobel_x", _img(rng)) for _ in range(3)]
    svc.start()
    for h in handles:
        with pytest.raises(DispatchError):
            h.result(timeout=WAIT)
    svc.close(timeout=WAIT)              # must not hang on a dead worker
    assert svc.worker_restarts == 1      # the crash that broke the budget
    with pytest.raises(RuntimeError, match="closed"):
        svc.submit("sobel_x", _img(rng))


def test_streaming_quarantine_fails_only_its_handle(rng):
    img = _img(rng)
    with StreamingFrontend(backend="xla") as oracle_svc:
        want = oracle_svc.submit("sobel_x", img).result(timeout=WAIT)
    faults = FaultInjector(seed=5).inject(
        "dispatch", transient=False, match=("<app:threshold>",))
    with StreamingFrontend(backend="xla", faults=faults) as svc:
        h_ok = svc.submit("sobel_x", img)
        h_bad = svc.submit("threshold", img)
        np.testing.assert_array_equal(
            np.asarray(h_ok.result(timeout=WAIT)), np.asarray(want))
        with pytest.raises(QuarantinedError):
            h_bad.result(timeout=WAIT)
        assert svc.stats.quarantined_requests == 1
        assert svc.latency.failed == 1


def test_streaming_request_hard_timeout_expires_queued_work(rng):
    # The worker is held stopped while a request ages past its hard
    # timeout; on start the sweep must fail it with JobTimeout (which is
    # also a TimeoutError) and keep serving fresh work.
    svc = StreamingFrontend(backend="xla", autostart=False,
                            request_timeout_s=0.05)
    h = svc.submit("sobel_x", _img(rng))
    time.sleep(0.1)
    svc.start()
    with pytest.raises(JobTimeout):
        h.result(timeout=WAIT)
    assert isinstance(JobTimeout("x"), TimeoutError)
    h2 = svc.submit("sobel_x", _img(rng))
    assert np.asarray(h2.result(timeout=WAIT)).shape == (8, 10)
    assert svc.latency.failed == 1
    svc.close(timeout=WAIT)


# -- streaming: close/submit race regression ----------------------------------


def test_submit_close_race_strands_no_handle(rng):
    # Regression for the pre-PR 10 race: submit() checked _closed, then
    # enqueued -- a close() between the two could insert the _STOP
    # sentinel first and strand the late request behind it, hanging its
    # handle forever.  Both now run under one lifecycle lock, so every
    # accepted handle resolves (served before shutdown) and late submits
    # are rejected loudly.  Run several rounds to give a regressed race
    # real chances to interleave.
    img = _img(rng, (4, 6))
    for round_ in range(5):
        svc = StreamingFrontend(backend="xla", max_linger_s=1e-4)
        svc.submit("sobel_x", img).result(timeout=WAIT)   # warm compile
        accepted = []
        rejected = []
        barrier = threading.Barrier(2)

        def submitter():
            barrier.wait()
            for _ in range(50):
                try:
                    accepted.append(svc.submit("sobel_x", img))
                except RuntimeError:     # closed (AdmissionError also OK)
                    rejected.append(1)
                    break

        th = threading.Thread(target=submitter)
        th.start()
        barrier.wait()
        svc.close(timeout=WAIT)
        th.join(WAIT)
        assert not th.is_alive()
        for h in accepted:               # accepted => served, never stuck
            assert np.asarray(h.result(timeout=WAIT)).shape == img.shape
