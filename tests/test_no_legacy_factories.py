"""Grep-style lint: deprecated surfaces must have zero call sites under
``src/`` or ``benchmarks/``.

Two deprecations are pinned here:

* PR 4 collapsed the ``make_*_overlay_fn`` factory matrix into
  ``OverlayPlan`` + ``compile_plan`` and left the factories as
  DeprecationWarning shims -- production and benchmark code must build
  plans, never call the shims.
* PR 6 replaced the image front-ends' three-call ``submit``/``tick``/
  ``take`` protocol with the futures API (``submit`` returns a
  ``JobHandle``); ``tick``/``take`` survive only as DeprecationWarning
  shims on ``FleetFrontend``, and nothing in production/bench code may
  call them.

(``tests/`` is exempt: the shim-parity tests call both on purpose.)
"""

import re
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
SCOPES = ("src", "benchmarks")
# A call site: the factory name followed by an open paren.  The negative
# lookbehind exempts the shim *definitions* in core/interpreter.py; bare
# name mentions (docstrings, deprecation messages) carry no paren and
# never match.
FACTORY_CALL = re.compile(r"(?<!def )\bmake_(?:batched_)?(?:fused_)?overlay_fn\s*\(")
# Attribute calls of the deprecated front-end protocol.  The dot keeps
# ``def tick(``/``def take(`` (the shim definitions) out; the ``np``
# lookbehind exempts ``jnp.take(``/``np.take(`` (array gathers, a
# different thing entirely).  The LM SlotServer keeps its own ``tick`` --
# it has no call sites under the scanned scopes, which this lint also
# guarantees stays true.
PROTOCOL_CALL = re.compile(r"(?<!np)\.(?:tick|take)\s*\(")


def _offenders(pattern) -> list:
    found = []
    for scope in SCOPES:
        for path in sorted((REPO / scope).rglob("*.py")):
            text = path.read_text(encoding="utf-8")
            for m in pattern.finditer(text):
                line = text.count("\n", 0, m.start()) + 1
                found.append(f"{path.relative_to(REPO)}:{line}")
    return found


def test_no_legacy_factory_call_sites():
    offenders = _offenders(FACTORY_CALL)
    assert not offenders, (
        "deprecated make_*_overlay_fn shims called from production/bench "
        "code -- build an OverlayPlan and call compile_plan instead: "
        + ", ".join(offenders)
    )


def test_no_legacy_tick_take_call_sites():
    offenders = _offenders(PROTOCOL_CALL)
    assert not offenders, (
        "deprecated tick/take front-end protocol called from production/"
        "bench code -- submit() returns a JobHandle; use .result() / "
        "flush(): " + ", ".join(offenders)
    )
