"""Grep-style lint: the deprecated ``make_*_overlay_fn`` factories must
have zero call sites under ``src/`` or ``benchmarks/``.

PR 4 collapsed the factory matrix into ``OverlayPlan`` + ``compile_plan``
and left the factories as DeprecationWarning shims; this test keeps that
deprecation from regressing -- production and benchmark code must build
plans, never call the shims.  (``tests/`` is exempt: the shim-parity
tests in test_plan.py/test_ingest.py call them on purpose.)
"""

import re
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
SCOPES = ("src", "benchmarks")
# A call site: the factory name followed by an open paren.  The negative
# lookbehind exempts the shim *definitions* in core/interpreter.py; bare
# name mentions (docstrings, deprecation messages) carry no paren and
# never match.
CALL_SITE = re.compile(r"(?<!def )\bmake_(?:batched_)?(?:fused_)?overlay_fn\s*\(")


def test_no_legacy_factory_call_sites():
    offenders = []
    for scope in SCOPES:
        for path in sorted((REPO / scope).rglob("*.py")):
            text = path.read_text(encoding="utf-8")
            for m in CALL_SITE.finditer(text):
                line = text.count("\n", 0, m.start()) + 1
                offenders.append(f"{path.relative_to(REPO)}:{line}")
    assert not offenders, (
        "deprecated make_*_overlay_fn shims called from production/bench "
        "code -- build an OverlayPlan and call compile_plan instead: "
        + ", ".join(offenders)
    )
