"""Grep-style lint: deprecated surfaces must have zero call sites under
``src/`` or ``benchmarks/``.

Three deprecations are pinned here:

* PR 4 collapsed the ``make_*_overlay_fn`` factory matrix into
  ``OverlayPlan`` + ``compile_plan`` and left the factories as
  DeprecationWarning shims -- production and benchmark code must build
  plans, never call the shims.
* PR 6 replaced the image front-ends' three-call ``submit``/``tick``/
  ``take`` protocol with the futures API (``submit`` returns a
  ``JobHandle``); ``tick``/``take`` survive only as DeprecationWarning
  shims on ``FleetFrontend``, and nothing in production/bench code may
  call them.
* PR 8 replaced the bare device-count kwarg threaded through
  ``OverlayPlan`` / ``PixieFleet`` / ``Pixie`` / both front-ends with the
  structured ``MeshSpec(app=k, rows=m)`` placement; the old spelling
  survives only as a DeprecationWarning shim, and nothing in
  production/bench code (including docstrings and error messages, which
  must name the MeshSpec spelling) may use it.
* PR 9 made chained overlays a plan axis (``PipelineSpec`` -> ONE
  device-resident executable); production/bench code must never run a
  chain as a per-stage ``run_image``/``run_raw`` loop with host hops
  between stages (pass ``pipeline=`` / ``run_pipeline`` instead).

(``tests/`` is exempt: the shim-parity tests call both on purpose.)
"""

import re
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
SCOPES = ("src", "benchmarks")
# A call site: the factory name followed by an open paren.  The negative
# lookbehind exempts the shim *definitions* in core/interpreter.py; bare
# name mentions (docstrings, deprecation messages) carry no paren and
# never match.
FACTORY_CALL = re.compile(r"(?<!def )\bmake_(?:batched_)?(?:fused_)?overlay_fn\s*\(")
# Attribute calls of the deprecated front-end protocol.  The dot keeps
# ``def tick(``/``def take(`` (the shim definitions) out; the ``np``
# lookbehind exempts ``jnp.take(``/``np.take(`` (array gathers, a
# different thing entirely).  The LM SlotServer keeps its own ``tick`` --
# it has no call sites under the scanned scopes, which this lint also
# guarantees stays true.
PROTOCOL_CALL = re.compile(r"(?<!np)\.(?:tick|take)\s*\(")
# The deprecated bare device-count kwarg, ANYWHERE in production/bench
# sources -- call sites, docstrings, error text alike (new code must name
# the MeshSpec spelling, so even prose mentions are pinned to zero).  The
# shim *parameter declarations* use annotation syntax (``devices:``) and
# never match; ``!=``/``==`` comparisons are excluded by the negative
# lookahead.
DEVICES_KWARG = re.compile(r"\bdevices=(?!=)")
# A staged chain: a loop over stages/pipeline/chain followed (within a
# few lines) by a per-stage ``run_image``/``run_raw`` call -- the host-hop
# pattern the pipeline plans replace.  Loops that feed stage outputs to
# batched/fleet entry points (``run_many``, ``flush``) are the sanctioned
# staged ORACLES in benchmarks and never match.
PIPELINE_LOOP_CALL = re.compile(
    r"for\s+\w+\s+in\s+[^\n]*(?i:stages|pipeline|chain)[^\n]*:"
    r"\s*\n(?:[^\n]*\n){0,4}?[^\n]*\.run_(?:image|raw)\s*\("
)
# PR 10: serving-layer exception discipline.  A broad ``except
# [Base]Exception`` in the runtime/serve packages may only exist where
# the failure is ROUTED somewhere a client can observe it (a JobHandle,
# a per-ticket failure record, a retry/fallback/quarantine path, a
# supervised restart) -- and the line must SAY so in a trailing comment
# naming the route.  A bare swallow hides exactly the faults the
# resilience stack exists to surface.
BROAD_EXCEPT = re.compile(r"except\s+(?:Base)?Exception\b[^\n]*")
ROUTED_WORDS = re.compile(
    r"#[^\n]*(?:handle|ticket|retr|fallback|quarantin|breaker|restart)",
    re.IGNORECASE,
)
EXCEPT_SCOPES = ("src/repro/runtime", "src/repro/serve")


def _offenders(pattern) -> list:
    found = []
    for scope in SCOPES:
        for path in sorted((REPO / scope).rglob("*.py")):
            text = path.read_text(encoding="utf-8")
            for m in pattern.finditer(text):
                line = text.count("\n", 0, m.start()) + 1
                found.append(f"{path.relative_to(REPO)}:{line}")
    return found


def test_no_legacy_factory_call_sites():
    offenders = _offenders(FACTORY_CALL)
    assert not offenders, (
        "deprecated make_*_overlay_fn shims called from production/bench "
        "code -- build an OverlayPlan and call compile_plan instead: "
        + ", ".join(offenders)
    )


def test_no_legacy_tick_take_call_sites():
    offenders = _offenders(PROTOCOL_CALL)
    assert not offenders, (
        "deprecated tick/take front-end protocol called from production/"
        "bench code -- submit() returns a JobHandle; use .result() / "
        "flush(): " + ", ".join(offenders)
    )


def test_no_bare_devices_kwarg_sites():
    offenders = _offenders(DEVICES_KWARG)
    assert not offenders, (
        "deprecated bare device-count kwarg used in production/bench "
        "code -- pass mesh=MeshSpec(app=k, rows=m) instead: "
        + ", ".join(offenders)
    )


def test_broad_excepts_route_to_a_client_visible_path():
    offenders = []
    for scope in EXCEPT_SCOPES:
        for path in sorted((REPO / scope).rglob("*.py")):
            text = path.read_text(encoding="utf-8")
            for m in BROAD_EXCEPT.finditer(text):
                if not ROUTED_WORDS.search(m.group(0)):
                    line = text.count("\n", 0, m.start()) + 1
                    offenders.append(f"{path.relative_to(REPO)}:{line}")
    assert not offenders, (
        "broad `except Exception` in the serving/runtime layers without a "
        "routing comment -- broad catches there may only exist where the "
        "failure reaches a client (JobHandle, per-ticket failure, retry/"
        "fallback/quarantine, supervised restart), and the line must say "
        "which in a trailing comment: " + ", ".join(offenders)
    )


def test_no_per_stage_run_image_loop_sites():
    offenders = _offenders(PIPELINE_LOOP_CALL)
    assert not offenders, (
        "chained overlay run as a per-stage run_image/run_raw loop in "
        "production/bench code -- chains are a plan axis: pass "
        "pipeline= to the fleet / front-ends or call Pixie.run_pipeline "
        "so intermediates stay on device: " + ", ".join(offenders)
    )
