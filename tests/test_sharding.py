"""Sharding-plan tests: spec correctness, divisibility handling, ZeRO-1,
and a real pjit execution on a tiny host mesh."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import ARCHS, reduced
from repro.models import LM
from repro.parallel.sharding import choose_attn_mode, make_plan

# Long-running suite: excluded from tier-1 (-m "not slow"), run nightly.
pytestmark = pytest.mark.slow

MESH_16x16 = None  # built lazily if enough devices; CPU tests use 1x1


def _mesh11():
    return jax.make_mesh((1, 1), ("data", "model"))


class _FakeMesh:
    """Shape-only stand-in so plan rules can be tested without devices."""

    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


FAKE = _FakeMesh({"data": 16, "model": 16})
FAKE_MULTI = _FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_attn_mode_selection():
    assert choose_attn_mode(ARCHS["deepseek-moe-16b"], FAKE) == "heads"
    assert choose_attn_mode(ARCHS["qwen2-moe-a2.7b"], FAKE) == "heads"
    assert choose_attn_mode(ARCHS["glm4-9b"], FAKE) == "qheads"      # Hg=16
    assert choose_attn_mode(ARCHS["gemma-2b"], FAKE) == "seq"        # MQA
    assert choose_attn_mode(ARCHS["gemma-2b"], FAKE, "decode") == "head_dim"
    # starcoder2: H=36, G=4 -> Hg=9, 9 % 16 != 0 -> seq at train
    assert choose_attn_mode(ARCHS["starcoder2-7b"], FAKE) == "seq"


def test_param_specs_embed_and_mlp_sharded():
    cfg = ARCHS["gemma-2b"]
    plan = make_plan(cfg, FAKE)
    lm = LM(cfg)
    abstract = lm.abstract_params()
    specs = plan.param_specs(abstract)
    # embedding vocab-sharded (256000 % 16 == 0)
    assert specs["embed"]["table"] == P("model", None)
    # scanned blocks: leading superblock dim unsharded, F sharded
    blk = specs["blocks"]["0:dense"]
    assert blk["mlp"]["w_gate"] == P(None, None, "model")
    assert blk["mlp"]["w_down"] == P(None, "model", None)
    # MQA 'seq' plan: no model-axis TP on attention; the FSDP fallback
    # shards the first divisible dim (D=2048) over 'data' instead
    assert blk["attn"]["wq"] == P(None, "data", None, None, None)
    assert "model" not in str(blk["attn"]["wq"])


def test_param_specs_moe_expert_sharding():
    cfg = ARCHS["deepseek-moe-16b"]
    plan = make_plan(cfg, FAKE)
    specs = plan.param_specs(LM(cfg).abstract_params())
    moe = specs["blocks"]["0:moe"]["moe"]
    assert moe["w_gate"] == P(None, "model", None, None)   # 64 experts / 16
    assert moe["w_down"] == P(None, "model", None, None)
    attn = specs["blocks"]["0:moe"]["attn"]
    assert attn["wq"] == P(None, None, "model", None, None)  # heads mode, G=16


def test_param_specs_qwen_expert_fallback():
    """60 experts don't divide 16: falls back to F-dim sharding."""
    cfg = ARCHS["qwen2-moe-a2.7b"]
    plan = make_plan(cfg, FAKE)
    specs = plan.param_specs(LM(cfg).abstract_params())
    moe = specs["blocks"]["0:moe"]["moe"]
    assert moe["w_gate"] == P(None, None, None, "model")    # F=1408 % 16 == 0
    assert moe["w_down"] == P(None, None, "model", None)


def test_hymba_vocab_not_shardable():
    """vocab 32001 is odd: no model-axis shard; FSDP shards d_model over
    'data' instead of crashing or replicating 51M params."""
    cfg = ARCHS["hymba-1.5b"]
    plan = make_plan(cfg, FAKE)
    specs = plan.param_specs(LM(cfg).abstract_params())
    assert specs["embed"]["table"] == P(None, "data")


def test_zero1_adds_data_axis():
    cfg = ARCHS["gemma-2b"]
    plan = make_plan(cfg, FAKE)
    abstract = LM(cfg).abstract_params()
    ospecs = plan.opt_specs(abstract)
    # embedding moment: model on dim0 (from param spec) + data on dim1
    assert ospecs["m"]["embed"]["table"] == P("model", "data")
    assert ospecs["count"] == P()


def test_cache_specs_seq_sharding():
    cfg = ARCHS["glm4-9b"]
    plan = make_plan(cfg, FAKE, kind="decode")
    lm = LM(cfg)
    cache = lm.abstract_cache(128, 32768)
    specs = plan.cache_specs(cache)
    kspec = specs["blocks"]["0:dense"]["k"]
    assert kspec == P(None, "data", "model", None, None)  # B:data, S:model


def test_cache_specs_ring_not_seq_sharded():
    cfg = ARCHS["gemma3-12b"]
    plan = make_plan(cfg, FAKE, kind="decode")
    cache = LM(cfg).abstract_cache(128, 32768)
    specs = plan.cache_specs(cache)
    local = specs["blocks"]["0:local"]["k"]       # ring buffer of 1024
    assert local == P(None, "data", None, None, None)
    glob = specs["blocks"]["5:global"]["k"]       # full 32k cache
    assert glob == P(None, "data", "model", None, None)


def test_multipod_batch_spec():
    cfg = ARCHS["gemma-2b"]
    plan = make_plan(cfg, FAKE_MULTI)
    assert plan.batch_spec(2) == P(("pod", "data"), None)


def test_pjit_train_step_runs_on_host_mesh(rng):
    """End-to-end sharded train step on a 1x1 mesh (semantics only)."""
    from repro.optim import AdamWConfig, init_opt_state
    from repro.train.step import make_train_step

    cfg = reduced(ARCHS["gemma-2b"])
    lm = LM(cfg, remat="none", chunk_q=16, loss_chunk=16)
    mesh = _mesh11()
    plan = make_plan(cfg, mesh)
    step, _ = make_train_step(lm, plan, AdamWConfig(lr=1e-3, warmup_steps=0))
    params = lm.init(jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 32)))
    with mesh:
        p2, o2, m = step(params, opt, tokens)
    assert bool(jnp.isfinite(m["loss"]))
