"""Streaming front-end + futures service API tests.

Covers the PR 6 service surface: JobHandle semantics (done/result/timeout),
the synchronous front-end's queue_s/flush_s latency split, the deprecated
tick/take shims, and the threaded continuous-batching scheduler --
deadline-triggered partial-tile launches, priority ordering under
contention, admission-control shedding, linger-based starvation avoidance,
and bitwise parity with the synchronous front-end on ragged mixed-app
traces over both backends.

Every blocking call carries an explicit timeout: a scheduler bug must fail
the test, not hang the suite (CI adds pytest-timeout as a second belt).
"""

import time
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import applications as apps
from repro.core import sobel_grid
from repro.core.ingest import ReadinessProbe
from repro.runtime.fleet import PixieFleet
from repro.serve import (
    AdmissionError, FleetFrontend, JobHandle, StreamingFrontend,
)

WAIT = 120.0       # generous per-call bound; loaded CI hosts compile slowly
MIX = ["sobel_x", "sobel_y", "sharpen", "laplace", "threshold", "identity"]


def ragged_trace(rng, n=6, sizes=((6, 9), (11, 5), (3, 8), (8, 8))):
    return [
        (MIX[i % len(MIX)],
         rng.integers(0, 256, sizes[i % len(sizes)]).astype(np.int32))
        for i in range(n)
    ]


# -- futures API on the synchronous front-end ---------------------------------


def test_handle_result_drives_sync_flush(rng):
    img = rng.integers(0, 256, (4, 6)).astype(np.int32)
    svc = FleetFrontend(fleet=PixieFleet(default_grid=sobel_grid()))
    h = svc.submit("laplace", img)
    assert isinstance(h, JobHandle) and not h.done()
    np.testing.assert_array_equal(
        h.result(timeout=WAIT), apps.conv2d_reference(img, apps.LAPLACE)
    )
    assert h.done()
    # repeat reads are free and identical (a future, not a one-shot take)
    np.testing.assert_array_equal(h.result(), h.result())


def test_sync_latency_split_queue_vs_flush(rng):
    """The PR 6 bugfix: per-job latency separates queue wait (submit ->
    flush start) from flush duration, instead of stamping one shared
    post-flush 'now' that conflated the two for every job in the batch."""
    img = rng.integers(0, 256, (4, 6)).astype(np.int32)
    svc = FleetFrontend(fleet=PixieFleet(default_grid=sobel_grid()))
    h1 = svc.submit("sobel_x", img)
    time.sleep(0.05)
    h2 = svc.submit("sobel_y", img)
    jobs = {j.ticket: j for j in svc.flush()}
    j1, j2 = jobs[h1.ticket], jobs[h2.ticket]
    # same flush serves both: identical flush_s, differing queue_s
    assert j1.flush_s == j2.flush_s > 0
    assert j1.queue_s >= j2.queue_s + 0.04
    for j in (j1, j2):
        assert j.latency_s == pytest.approx(j.queue_s + j.flush_s)
    s = svc.latency.summary()
    assert s["completed"] == 2 and s["deadline_misses"] == 0
    assert s["queue_s"]["max"] >= 0.04


def test_process_batch_on_handles_single_dispatch(rng):
    img = rng.integers(0, 256, (8, 8)).astype(np.int32)
    svc = FleetFrontend(fleet=PixieFleet(default_grid=sobel_grid()))
    names = ["sobel_y", "identity", "sobel_x"]
    outs = svc.process_batch([(n, img) for n in names])
    assert svc.stats.dispatches == 1        # one dispatch drained them all
    for n, y in zip(names, outs):
        np.testing.assert_array_equal(y, svc.process(n, img))


def test_tick_take_shims_warn_and_match(rng):
    img = rng.integers(0, 256, (4, 6)).astype(np.int32)
    svc = FleetFrontend(fleet=PixieFleet(default_grid=sobel_grid()))
    h = svc.submit("laplace", img)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        jobs = svc.tick()
        y = svc.take(h)                     # accepts handle or bare ticket
    assert {x.category for x in w} == {DeprecationWarning}
    assert [j.ticket for j in jobs] == [h.ticket]
    np.testing.assert_array_equal(y, h.result(timeout=WAIT))


def test_sync_submit_rejects_streaming_options(rng):
    img = rng.integers(0, 256, (4, 6)).astype(np.int32)
    svc = FleetFrontend(fleet=PixieFleet(default_grid=sobel_grid()))
    with pytest.raises(TypeError, match="streaming front-end"):
        svc.submit("laplace", img, deadline_s=0.1)


# -- streaming scheduler ------------------------------------------------------


def _warmed(svc, img) -> StreamingFrontend:
    """Compile the fused overlay once so scheduler-timing tests measure
    flushes, not jit."""
    svc.process("sobel_x", img)
    svc.latency.reset()
    return svc


def test_streaming_deadline_triggers_partial_tile(rng):
    """3 requests against a tile of 8 with a tight SLO and a huge linger:
    only the deadline trigger can launch, and it must launch a PARTIAL
    tile rather than wait for 5 more requests that never come."""
    img = rng.integers(0, 256, (8, 8)).astype(np.int32)
    fleet = PixieFleet(default_grid=sobel_grid(), batch_tile=8)
    with StreamingFrontend(fleet=fleet, max_linger_s=30.0) as svc:
        _warmed(svc, img)
        partial0 = fleet.stats.partial_tile_dispatches
        t0 = time.perf_counter()
        hs = [svc.submit(n, img, deadline_s=0.25)
              for n in ["sobel_x", "sobel_y", "sharpen"]]
        jobs = [h.job(timeout=WAIT) for h in hs]
        waited = time.perf_counter() - t0
    assert fleet.stats.partial_tile_dispatches > partial0
    assert waited < 5.0                       # nowhere near the 30 s linger
    for h, j in zip(hs, jobs):
        np.testing.assert_array_equal(
            np.asarray(j.output), np.asarray(h.result())
        )
    assert {j.deadline_s for j in jobs} == {0.25}


def test_streaming_priority_under_contention(rng):
    """Queue 4 requests against a stopped worker (deterministic
    contention); on start, the high-priority pair must ride the first
    flush and the low-priority pair the second."""
    img = rng.integers(0, 256, (8, 8)).astype(np.int32)
    svc = StreamingFrontend(
        fleet=PixieFleet(default_grid=sobel_grid()),
        target_batch=2, autostart=False,
    )
    low = [svc.submit(n, img, priority=0) for n in ["sobel_x", "sobel_y"]]
    high = [svc.submit(n, img, priority=5) for n in ["sharpen", "laplace"]]
    svc.start()
    jobs_high = [h.job(timeout=WAIT) for h in high]
    jobs_low = [h.job(timeout=WAIT) for h in low]
    svc.close(timeout=WAIT)
    assert {j.flush_seq for j in jobs_high} == {0}
    assert {j.flush_seq for j in jobs_low} == {1}
    for j in jobs_high:
        assert j.priority == 5


def test_streaming_admission_control_sheds(rng):
    img = rng.integers(0, 256, (8, 8)).astype(np.int32)
    svc = StreamingFrontend(
        fleet=PixieFleet(default_grid=sobel_grid()),
        max_queue=2, autostart=False,
    )
    hs = [svc.submit("sobel_x", img) for _ in range(2)]
    with pytest.raises(AdmissionError, match="max_queue=2"):
        svc.submit("sobel_y", img)
    assert svc.latency.shed == 1
    svc.start()
    for h in hs:                              # accepted work still served
        assert h.result(timeout=WAIT).shape == img.shape
    svc.close(timeout=WAIT)
    assert svc.latency.summary()["shed"] == 1


def test_handle_result_timeout_semantics(rng):
    img = rng.integers(0, 256, (8, 8)).astype(np.int32)
    svc = StreamingFrontend(
        fleet=PixieFleet(default_grid=sobel_grid()), autostart=False,
    )
    h = svc.submit("sobel_x", img)
    assert not h.done()
    with pytest.raises(TimeoutError, match="sobel_x"):
        h.result(timeout=0.05)                # worker stopped: must expire
    svc.start()
    assert h.result(timeout=WAIT).shape == img.shape
    assert h.done()
    h.result(timeout=0)                       # done: zero timeout succeeds
    svc.close(timeout=WAIT)


def test_streaming_linger_serves_deadline_less_traffic(rng):
    """No deadline, no full tile: the linger trigger must still dispatch
    promptly instead of starving deadline-less requests."""
    img = rng.integers(0, 256, (8, 8)).astype(np.int32)
    fleet = PixieFleet(default_grid=sobel_grid(), batch_tile=8)
    with StreamingFrontend(fleet=fleet, max_linger_s=0.01) as svc:
        _warmed(svc, img)
        h = svc.submit("laplace", img)
        np.testing.assert_array_equal(
            h.result(timeout=WAIT), apps.conv2d_reference(img, apps.LAPLACE)
        )
        assert svc.latency.summary()["completed"] == 1


def test_streaming_bad_request_fails_only_its_handle(rng):
    img = rng.integers(0, 256, (8, 8)).astype(np.int32)
    with StreamingFrontend(fleet=PixieFleet(default_grid=sobel_grid())) as svc:
        with pytest.raises(KeyError, match="unknown app"):
            svc.submit("not_an_app", img)     # caller-side validation
        with pytest.raises(ValueError, match=r"\[H, W\]"):
            svc.submit("sobel_x", np.zeros((2, 3, 4)))
        with pytest.raises(ValueError, match="deadline_s"):
            svc.submit("sobel_x", img, deadline_s=0.0)
        # worker-side failure (config/grid mismatch) fails ONLY its handle
        from repro.core.grid import custom
        bad = svc.submit("sobel_x", img, grid=custom("tiny", 2, [1], 1))
        good = svc.submit("identity", img)
        with pytest.raises(Exception):
            bad.result(timeout=WAIT)
        np.testing.assert_array_equal(good.result(timeout=WAIT), img)


def test_streaming_close_drains_and_rejects(rng):
    img = rng.integers(0, 256, (8, 8)).astype(np.int32)
    svc = StreamingFrontend(fleet=PixieFleet(default_grid=sobel_grid()))
    hs = [svc.submit(n, img) for n in MIX]
    svc.close(timeout=WAIT)
    for h in hs:                              # close() drains, never drops
        assert h.done() or h.result(timeout=WAIT) is not None
    with pytest.raises(RuntimeError, match="closed"):
        svc.submit("sobel_x", img)
    svc.close(timeout=WAIT)                   # idempotent


def test_per_bucket_flush_estimates_isolated(rng):
    """PR 7 satellite: the deadline trigger's flush-duration EWMA is
    keyed per (grid, frame-bucket) -- a slow big-frame population must
    not inflate urgency for small-frame traffic, and vice versa."""
    from repro.serve.streaming import _PendingRequest

    svc = StreamingFrontend(fleet=PixieFleet(default_grid=sobel_grid()),
                            est_flush_s=0.05, autostart=False)

    def pending(shape):
        return _PendingRequest(
            seq=0, name="sobel_x", work="sobel_x",
            image=np.zeros(shape, np.int32), grid=None, priority=0,
            t_arrival=0.0, deadline_at=None, deadline_s=None,
            handle=JobHandle(0, "sobel_x"),
        )

    small, big = pending((8, 8)), pending((256, 256))
    # same grid, different pow-2 canvas buckets -> different populations
    assert svc._flush_key(small) != svc._flush_key(big)
    # frames sharing a bucket share an estimate (17 and 30 both pad to 32)
    assert svc._flush_key(pending((17, 30))) == svc._flush_key(pending((30, 17)))
    # before any flush, both fall back to the pessimistic seed
    assert svc._estimate(small) == svc._estimate(big) == 0.05
    # teach the big population it is slow: the small one is untouched
    svc._est_flush[svc._flush_key(big)] = 0.5
    assert svc._estimate(big) == 0.5
    assert svc._estimate(small) == 0.05
    # the bench-facing scalar reports the most pessimistic population
    assert svc.est_flush_s == 0.5
    # urgency is judged per request: with 0.1 s to spare, the small
    # request has slack (est 0.05) while the big one is already urgent
    small.deadline_at = big.deadline_at = 0.1 + svc.deadline_margin_s
    assert svc._deadline_urgent([big], now=0.0)
    assert not svc._deadline_urgent([small], now=0.0)
    svc.close(timeout=WAIT)


def test_streaming_learns_estimates_per_bucket(rng):
    """Live smoke: after serving one small-frame trace, the server has a
    real EWMA entry for exactly that (grid, bucket) population."""
    svc = StreamingFrontend(fleet=PixieFleet(default_grid=sobel_grid()))
    img = rng.integers(0, 256, (8, 8)).astype(np.int32)
    hs = [svc.submit(n, img) for n in MIX]
    for h in hs:
        h.result(timeout=WAIT)
    svc.close(timeout=WAIT)
    assert len(svc._est_flush) == 1
    ((grid, Hb, Wb), est), = svc._est_flush.items()
    assert (Hb, Wb) == (16, 16) and est > 0.0   # 8 pads to the 16 floor


# -- streaming == synchronous, bitwise ----------------------------------------


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_streaming_matches_sync_ragged(backend, rng):
    """Bitwise parity on a ragged mixed-app trace: batch composition is a
    latency decision, never a values decision."""
    trace = ragged_trace(rng, n=6)
    sync = FleetFrontend(fleet=PixieFleet(default_grid=sobel_grid(),
                                          backend=backend))
    ref = sync.process_batch(trace)
    with StreamingFrontend(
        fleet=PixieFleet(default_grid=sobel_grid(), backend=backend),
        target_batch=2,                       # forces multiple partial flushes
    ) as svc:
        hs = [svc.submit(n, img, deadline_s=10.0, priority=i % 3)
              for i, (n, img) in enumerate(trace)]
        outs = [h.result(timeout=WAIT) for h in hs]
        assert svc.stats.dispatches >= 2      # genuinely continuous batching
    for a, b in zip(ref, outs):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_streaming_matches_sync_async_ingest(backend, rng):
    """The double-buffered ingest pipeline under the streaming scheduler
    stays bitwise-equal to the sync-ingest synchronous front-end."""
    trace = ragged_trace(rng, n=4)
    ref = FleetFrontend(
        fleet=PixieFleet(default_grid=sobel_grid(), backend=backend)
    ).process_batch(trace)
    with StreamingFrontend(
        fleet=PixieFleet(default_grid=sobel_grid(), backend=backend,
                         ingest="async"),
        target_batch=2,
    ) as svc:
        outs = [svc.submit(n, img).result(timeout=WAIT) for n, img in trace]
    for a, b in zip(ref, outs):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.slow
@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_streaming_matches_sync_256(backend, rng):
    """256^2 frames: the large-frame tiled path under the streaming
    scheduler (slow tier; the serving-latency CI job runs it)."""
    imgs = [rng.integers(0, 256, (256, 256)).astype(np.int32) for _ in range(3)]
    trace = list(zip(["sobel_x", "sharpen", "laplace"], imgs))
    ref = FleetFrontend(
        fleet=PixieFleet(default_grid=sobel_grid(), backend=backend)
    ).process_batch(trace)
    with StreamingFrontend(
        fleet=PixieFleet(default_grid=sobel_grid(), backend=backend),
        target_batch=2,
    ) as svc:
        outs = [svc.submit(n, i, deadline_s=60.0).result(timeout=600)
                for n, i in trace]
    for a, b in zip(ref, outs):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# -- truthful readiness probe -------------------------------------------------


def test_readiness_probe_completes():
    x = jnp.arange(4096) * 2
    p = ReadinessProbe(x)
    assert p.wait(timeout=30.0)
    assert p.ready()


def test_readiness_probe_trusted_path_skips_thread():
    x = jnp.arange(16)
    jnp.asarray(x).block_until_ready()
    p = ReadinessProbe(x, trust_is_ready=True)
    assert p._event is None                   # no watcher thread spawned
    assert p.ready()


def test_readiness_probe_untrusted_on_cpu():
    """On CPU the probe must NOT take jax's optimistic is_ready at its
    word: a watcher thread provides the truthful signal."""
    if jnp.zeros(1).devices() and all(
        d.platform == "cpu" for d in jnp.zeros(1).devices()
    ):
        p = ReadinessProbe(jnp.arange(16))
        assert p._event is not None           # watcher thread in play
        assert p.wait(timeout=30.0)


def test_probe_overlap_accounting_async_fleet(rng):
    """The async fleet's ingest_overlap_s rides the truthful probe and
    stays a finite, non-negative number across repeated flushes."""
    from repro.runtime.fleet import FleetRequest
    img = rng.integers(0, 256, (16, 16)).astype(np.int32)
    fleet = PixieFleet(default_grid=sobel_grid(), ingest="async")
    reqs = [FleetRequest(app=n, image=img) for n in ["sobel_x", "sharpen"]]
    for _ in range(4):
        fleet.run_many(reqs)
    assert fleet.stats.ingest_overlap_s >= 0.0
    assert np.isfinite(fleet.stats.ingest_overlap_s)
    assert fleet.stats.canvas_pool_hits >= 1


def test_urgent_request_preempts_staged_batch(rng):
    """An urgent-deadline request preempts a staged higher-priority batch
    mid-selection: with the worker stopped, two deadline-less
    high-priority requests stage first; a low-priority request whose
    deadline cannot survive a second flush (est_flush_s is seeded huge)
    flips to urgent and must ride the first batch instead -- counted in
    FleetStats.preempted_batches."""
    img = rng.integers(0, 256, (8, 8)).astype(np.int32)
    fleet = PixieFleet(default_grid=sobel_grid(), batch_tile=2)
    svc = StreamingFrontend(
        fleet=fleet, target_batch=2, autostart=False,
        est_flush_s=5.0,  # every pending deadline looks unservable later
        max_linger_s=0.01,
    )
    high = [svc.submit(n, img, priority=10) for n in ["sobel_x", "sharpen"]]
    urgent = svc.submit("laplace", img, priority=0, deadline_s=0.001)
    time.sleep(0.01)  # deadline expires relative to est_flush_s regardless
    svc.start()
    j_urgent = urgent.job(timeout=WAIT)
    jobs_high = [h.job(timeout=WAIT) for h in high]
    svc.close(timeout=WAIT)
    # the urgent request jumped the staged (priority-sorted) order
    assert fleet.stats.preempted_batches >= 1
    assert j_urgent.flush_seq == 0
    assert max(j.flush_seq for j in jobs_high) >= 1
    for j in jobs_high:
        assert j.output is not None
