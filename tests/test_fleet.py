"""Multi-tenant batched overlay tests: N stacked configs must be bitwise
identical to N sequential `Pixie` runs -- including ragged/padded batches,
tile padding on the app axis, config-cache hits, and the compile-once-per-
GridSpec invariant.  The bitwise-equivalence tests are parametrized over
``backend=xla|pallas`` so drift between the jnp interpreter and the
batched Pallas megakernels (interpret mode off-TPU) fails PRs."""

import jax.numpy as jnp
import numpy as np
import pytest

from conftest import shared_app_grid

from repro.core import Pixie, map_app, sobel_grid
from repro.core import applications as apps
from repro.core.bitstream import VCGRAConfig
from repro.core.interpreter import (
    make_batched_overlay_fn, make_overlay_fn, pack_inputs, pad_channels,
)
from repro.runtime.fleet import FleetRequest, LRUCache, PixieFleet
from repro.serve.fleet_frontend import FleetFrontend

# The ISSUE's demonstrator trio: Sobel + threshold + blur.  gauss3 needs 19
# memory channels (9 taps + 9 coeffs + divisor), more than the paper's
# 18-input Sobel grid, so the shared fleet grid is generated from the
# union of the three apps' demands (the paper's "application specific grid
# designs", Sec. III-C).
TRIO = ["sobel_x", "threshold", "gauss3"]


def shared_grid(app_names):
    return shared_app_grid(app_names, name="fleet-shared")


def sequential_reference(grid, app_names, images):
    outs = []
    for name, img in zip(app_names, images):
        pix = Pixie(grid, mode="conventional")
        pix.load(map_app(apps.ALL_APPS[name](), grid))
        outs.append(np.asarray(pix.run_image(jnp.asarray(img))))
    return outs


# -- core: stacked configs through the batched interpreter --------------------


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_stacked_configs_match_sequential_bitwise(backend, rng):
    grid = shared_grid(TRIO)
    img = rng.integers(0, 256, (11, 14)).astype(np.int32)
    ref = sequential_reference(grid, TRIO, [img] * len(TRIO))

    configs, xs = [], []
    taps = apps.stencil_inputs(jnp.asarray(img))
    for name in TRIO:
        cfg = map_app(apps.ALL_APPS[name](), grid)
        feed = {k: v for k, v in taps.items() if k in cfg.input_order}
        configs.append(cfg)
        xs.append(pad_channels(pack_inputs(cfg, feed, grid.dtype), grid.num_inputs))

    fn = make_batched_overlay_fn(grid, backend=backend)
    ys = fn(VCGRAConfig.stack(configs), jnp.stack(xs))
    for i in range(len(TRIO)):
        np.testing.assert_array_equal(
            np.asarray(ys[i, 0]).reshape(img.shape), ref[i]
        )


def test_make_batched_overlay_fn_rejects_unknown_backend():
    with pytest.raises(ValueError, match="unknown backend"):
        make_batched_overlay_fn(sobel_grid(), backend="cuda")
    with pytest.raises(ValueError, match="unknown backend"):
        PixieFleet(backend="cuda")


def test_batched_equals_unbatched_overlay(rng):
    """The batched executor is exactly vmap(overlay): per-app slices agree
    with the sequential compile-once interpreter on the same grid."""
    grid = sobel_grid()
    names = ["sobel_x", "sobel_y", "sharpen", "laplace"]
    img = rng.integers(0, 256, (9, 9)).astype(np.int32)
    taps = apps.stencil_inputs(jnp.asarray(img))
    overlay = make_overlay_fn(grid)

    configs, xs = [], []
    for name in names:
        cfg = map_app(apps.ALL_APPS[name](), grid)
        feed = {k: v for k, v in taps.items() if k in cfg.input_order}
        configs.append(cfg)
        xs.append(pad_channels(pack_inputs(cfg, feed, grid.dtype), grid.num_inputs))

    ys = make_batched_overlay_fn(grid)(VCGRAConfig.stack(configs), jnp.stack(xs))
    for cfg, x, y in zip(configs, xs, ys):
        np.testing.assert_array_equal(np.asarray(y), np.asarray(overlay(cfg.to_jax(), x)))


def test_stack_rejects_mismatched_grids():
    g_small = apps.threshold()
    cfg_a = map_app(apps.sobel_x(), sobel_grid())
    from repro.core import for_dfg

    cfg_b = map_app(g_small, for_dfg(g_small, shape="exact"))
    with pytest.raises(ValueError, match="does not match"):
        VCGRAConfig.stack([cfg_a, cfg_b])
    with pytest.raises(ValueError, match="empty"):
        VCGRAConfig.stack([])


def test_stack_shapes():
    grid = sobel_grid()
    configs = [map_app(apps.sobel_x(), grid), map_app(apps.sobel_y(), grid)]
    opcodes, selects, out_sel = VCGRAConfig.stack(configs)
    assert len(opcodes) == grid.num_levels
    for lvl in range(grid.num_levels):
        assert opcodes[lvl].shape == (2, grid.pes_per_level[lvl])
        assert selects[lvl].shape == (2, grid.pes_per_level[lvl], 2)
    assert out_sel.shape == (2, grid.num_outputs)


# -- Pixie.run_many -----------------------------------------------------------


def test_run_many_matches_sequential_ragged(rng):
    """Ragged pixel batches (different image sizes) padded to one tile must
    slice back to exactly the sequential outputs."""
    grid = sobel_grid()
    names = ["sobel_x", "sobel_y", "laplace"]
    images = [
        rng.integers(0, 256, hw).astype(np.int32)
        for hw in [(7, 9), (12, 5), (4, 4)]
    ]
    ref = sequential_reference(grid, names, images)

    pix = Pixie(grid, mode="conventional")
    requests = []
    for name, img in zip(names, images):
        dfg = apps.ALL_APPS[name]()
        taps = apps.stencil_inputs(jnp.asarray(img))
        feed = {k: v for k, v in taps.items() if k in dfg.inputs}
        requests.append((dfg, feed))
    outs = pix.run_many(requests)
    for img, y, r in zip(images, outs, ref):
        assert y.shape == (1, img.size)
        np.testing.assert_array_equal(np.asarray(y[0]).reshape(img.shape), r)

    # explicit batch_pad beyond the largest request is also exact
    outs = pix.run_many(requests, batch_pad=256)
    for img, y, r in zip(images, outs, ref):
        np.testing.assert_array_equal(np.asarray(y[0]).reshape(img.shape), r)

    with pytest.raises(ValueError, match="batch_pad"):
        pix.run_many(requests, batch_pad=3)


def test_run_many_requires_conventional():
    pix = Pixie(sobel_grid(), mode="parameterized")
    with pytest.raises(RuntimeError, match="conventional"):
        pix.run_many([(apps.sobel_x(), {})])
    assert Pixie(sobel_grid()).run_many([]) == []


# -- the fleet scheduler ------------------------------------------------------


def test_fleet_trio_bitwise_and_cache_counters(rng):
    grid = shared_grid(TRIO)
    img = rng.integers(0, 256, (10, 13)).astype(np.int32)
    ref = sequential_reference(grid, TRIO, [img] * len(TRIO))

    fleet = PixieFleet(default_grid=grid, batch_tile=4)
    outs = fleet.run_many([FleetRequest(app=n, image=img) for n in TRIO])
    for y, r in zip(outs, ref):
        np.testing.assert_array_equal(y, r)

    s = fleet.stats
    assert s.map_calls == 3 and s.config_cache_hits == 0
    assert s.overlay_builds == 1
    assert s.padded_app_slots == 1  # 3 requests -> tile of 4

    # repeat tenants: no new place/route, no new overlay, no new executable
    outs2 = fleet.run_many([FleetRequest(app=n, image=img) for n in TRIO])
    for y, r in zip(outs2, ref):
        np.testing.assert_array_equal(y, r)
    s = fleet.stats
    assert s.map_calls == 3 and s.config_cache_hits == 3
    assert s.overlay_builds == 1 and s.overlay_cache_hits == 1
    assert s.stack_bank_hits == 1  # settings bank reused, not re-stacked
    # compile-once per GridSpec (-1 = jax without jit-cache introspection)
    assert fleet.overlay_executable_count(grid) in (1, -1)
    # run_many redeems everything: nothing retained, nothing leaked
    assert len(fleet._results) == 0


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_fleet_ragged_images_one_flush(backend, rng):
    grid = sobel_grid()
    names = ["sobel_x", "sharpen", "identity"]
    images = [
        rng.integers(0, 256, hw).astype(np.int32)
        for hw in [(6, 8), (11, 11), (3, 5)]
    ]
    ref = sequential_reference(grid, names, images)
    fleet = PixieFleet(default_grid=grid, backend=backend)
    outs = fleet.run_many(
        [FleetRequest(app=n, image=i) for n, i in zip(names, images)]
    )
    assert fleet.stats.dispatches == 1
    assert fleet.stats.backend == backend
    for y, r in zip(outs, ref):
        np.testing.assert_array_equal(y, r)


def test_fleet_groups_by_grid(rng):
    """Requests on different grids execute in separate dispatches but one
    flush; per-request grid override routes around the default."""
    img = rng.integers(0, 256, (5, 7)).astype(np.int32)
    g3 = apps.gaussian_blur()
    from repro.core import for_dfg

    gg = for_dfg(g3, shape="exact")
    fleet = PixieFleet(default_grid=sobel_grid())
    outs = fleet.run_many([
        FleetRequest(app="sobel_x", image=img),
        FleetRequest(app=g3, image=img, grid=gg),
    ])
    assert fleet.stats.dispatches == 2 and fleet.stats.overlay_builds == 2
    np.testing.assert_array_equal(outs[0], apps.conv2d_reference(img, apps.SOBEL_X))
    np.testing.assert_array_equal(
        outs[1], apps.conv2d_reference(img, apps.GAUSS3, divisor=16.0)
    )


def test_fleet_channel_requests_and_validation(rng):
    grid = sobel_grid()
    dfg = apps.threshold()
    x = rng.integers(0, 256, (17,)).astype(np.int32)
    fleet = PixieFleet(default_grid=grid)
    (out,) = fleet.run_many([FleetRequest(app=dfg, inputs={"p11": x})])
    np.testing.assert_array_equal(out[0], (x > 128).astype(np.int32))

    with pytest.raises(ValueError, match="exactly one"):
        fleet.submit(FleetRequest(app=dfg))
    with pytest.raises(ValueError, match="exactly one"):
        fleet.submit(FleetRequest(app=dfg, inputs={"p11": x}, image=x.reshape(1, -1)))


def test_bad_submit_cannot_poison_queued_peers(rng):
    """An unmappable app (or missing input) raises at submit() and must
    leave previously queued tenants untouched."""
    grid = sobel_grid()
    img = rng.integers(0, 256, (6, 6)).astype(np.int32)
    fleet = PixieFleet(default_grid=grid)
    t = fleet.submit(FleetRequest(app="sobel_x", image=img))
    from repro.core.place import PlacementError

    with pytest.raises(PlacementError):  # gauss3 needs 19 inputs, grid has 18
        fleet.submit(FleetRequest(app="gauss3", image=img))
    with pytest.raises(KeyError):        # missing channel input
        fleet.submit(FleetRequest(app="threshold", inputs={"wrong": img.ravel()}))
    outs = fleet.flush()
    np.testing.assert_array_equal(
        outs[t], apps.conv2d_reference(img, apps.SOBEL_X)
    )


def test_wrong_grid_config_rejected_at_submit(rng):
    """A pre-mapped config for ANOTHER grid must be rejected at submit()
    (it would otherwise blow up VCGRAConfig.stack at flush time and drop
    queued peers)."""
    from repro.core import for_dfg

    grid = sobel_grid()
    img = rng.integers(0, 256, (5, 5)).astype(np.int32)
    thr = apps.threshold()
    foreign_cfg = map_app(thr, for_dfg(thr, shape="exact"))
    fleet = PixieFleet(default_grid=grid)
    t = fleet.submit(FleetRequest(app="sobel_x", image=img))
    with pytest.raises(ValueError, match="does not match"):
        fleet.submit(FleetRequest(app=foreign_cfg, inputs={"p11": img.ravel()}))
    outs = fleet.flush()
    np.testing.assert_array_equal(outs[t], apps.conv2d_reference(img, apps.SOBEL_X))


def test_run_many_larger_than_retention_cap(rng):
    """run_many consumes flush()'s return value directly, so batches larger
    than max_retained_results must still return every output."""
    img = rng.integers(0, 256, (4, 4)).astype(np.int32)
    fleet = PixieFleet(default_grid=sobel_grid(), max_retained_results=2)
    outs = fleet.run_many([FleetRequest(app="identity", image=img)] * 6)
    assert len(outs) == 6
    for y in outs:
        np.testing.assert_array_equal(y, img)
    assert len(fleet._results) == 0


def test_lru_cache_eviction_and_counters():
    c = LRUCache(2)
    c.put("a", 1); c.put("b", 2)
    assert c.get("a") == 1 and c.hits == 1
    c.put("c", 3)               # evicts "b" (LRU)
    assert "b" not in c and "a" in c
    assert c.get("b") is None and c.misses == 1
    assert c.evictions == 1
    with pytest.raises(ValueError):
        LRUCache(0)


def test_structural_hash_keys_repeat_tenants():
    assert apps.sobel_x().structural_hash() == apps.sobel_x().structural_hash()
    assert apps.sobel_x().structural_hash() != apps.sobel_y().structural_hash()
    # coefficient values are part of the identity (threshold level matters)
    assert (
        apps.threshold(100.0).structural_hash()
        != apps.threshold(200.0).structural_hash()
    )


# -- serve front-end ----------------------------------------------------------


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_frontend_process_batch_order_and_stats(backend, rng):
    img = rng.integers(0, 256, (8, 8)).astype(np.int32)
    svc = FleetFrontend(fleet=PixieFleet(default_grid=sobel_grid(),
                                         backend=backend))
    names = ["sobel_y", "identity", "sobel_x"]
    outs = svc.process_batch([(n, img) for n in names])
    ref = sequential_reference(sobel_grid(), names, [img] * 3)
    for y, r in zip(outs, ref):
        np.testing.assert_array_equal(y, r)
    assert svc.stats.dispatches == 1
    assert svc.backend == backend

    with pytest.raises(KeyError, match="unknown app"):
        svc.submit("not_an_app", img)
    assert "sobel_x" in svc.available_apps()


def test_frontend_backend_kwarg_and_conflict(rng):
    svc = FleetFrontend(backend="pallas")
    assert svc.backend == "pallas" and svc.fleet.backend == "pallas"
    with pytest.raises(ValueError, match="conflicts"):
        FleetFrontend(fleet=PixieFleet(backend="xla"), backend="pallas")
    # invalid names fail with the shared unknown-backend error, not a
    # misleading conflict message (and "" is rejected, not coerced to xla)
    with pytest.raises(ValueError, match="unknown backend"):
        FleetFrontend(fleet=PixieFleet(), backend="cuda")
    with pytest.raises(ValueError, match="unknown backend"):
        FleetFrontend(backend="")


def test_frontend_flush_latency_accounting(rng):
    img = rng.integers(0, 256, (4, 6)).astype(np.int32)
    svc = FleetFrontend(fleet=PixieFleet(default_grid=sobel_grid()))
    h = svc.submit("laplace", img)
    jobs = svc.flush()
    assert [j.ticket for j in jobs] == [h.ticket]
    assert jobs[0].app == "laplace"
    # the PR 6 latency split: queue wait and flush time are separate
    assert jobs[0].queue_s >= 0 and jobs[0].flush_s > 0
    assert jobs[0].latency_s == pytest.approx(jobs[0].queue_s + jobs[0].flush_s)
    np.testing.assert_array_equal(
        h.result(), apps.conv2d_reference(img, apps.LAPLACE)
    )


# -- async (double-buffered) ingest -------------------------------------------


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_async_ingest_bitwise_mixed_flushes(backend, rng):
    """ingest="async" == ingest="sync", bitwise, under repeated mixed
    fused/channel flushes -- the double-buffered pipeline (pooled donated
    canvases, lazy output slicing) changes buffer lifetime only, never
    values.  The repeat flushes exercise the canvas pool rotation while
    the previous dispatch's lazy outputs may still be in flight."""
    grid = sobel_grid()
    images = [rng.integers(0, 256, hw).astype(np.int32)
              for hw in [(6, 9), (11, 5), (3, 8)]]
    x = rng.integers(0, 256, (23,)).astype(np.int32)
    reqs = [FleetRequest(app=n, image=i)
            for n, i in zip(["sobel_x", "sharpen", "identity"], images)]
    reqs.append(FleetRequest(app="threshold", inputs={"p11": x}))

    ref = PixieFleet(default_grid=grid, backend=backend).run_many(reqs)
    fleet = PixieFleet(default_grid=grid, backend=backend, ingest="async")
    for _ in range(3):
        got = fleet.run_many(reqs)
        for a, b in zip(ref, got):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert fleet.stats.ingest == "async"
    # round 2+ reuse the pooled canvas instead of allocating
    assert fleet.stats.canvas_pool_hits >= 1
    # every dispatch is stamped with the async plan key segment
    assert all("async" in k for k in fleet.stats.dispatch_plans)


def test_frontend_ingest_kwarg_and_conflict(rng):
    svc = FleetFrontend(ingest="async")
    assert svc.ingest == "async" and svc.fleet.ingest == "async"
    img = rng.integers(0, 256, (4, 6)).astype(np.int32)
    np.testing.assert_array_equal(
        np.asarray(svc.process("laplace", img)),
        apps.conv2d_reference(img, apps.LAPLACE),
    )
    with pytest.raises(ValueError, match="conflicts"):
        FleetFrontend(fleet=PixieFleet(ingest="sync"), ingest="async")
    with pytest.raises(ValueError, match="unknown ingest"):
        FleetFrontend(ingest="dma")
