"""Pixel-axis row tiling + async ingest: the two PR 5 OverlayPlan axes.

The row-tiled fused executors (the ``lax.dynamic_slice``-based XLA twin
and the slab-tiled Pallas megakernel) must be *bitwise* identical to the
untiled sync XLA oracle -- across tile heights that do not divide H,
tile_rows >= H, radius-0 tap grids, ragged non-square multi-tenant
stacks, and both backends.  The async double-buffered ingest pipeline
must likewise be bitwise-equal to sync (only buffer lifetime and
laziness differ).  The ``slow``-marked 256x256 suites are the
large-frame-parity CI gate: tiling + async at real frame sizes,
composing with the PR 4 sharded path under two forced host devices.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import OverlayPlan, compile_plan, map_app, sobel_grid
from repro.core import applications as apps
from repro.core import interpreter
from repro.core.bitstream import VCGRAConfig
from repro.core.ingest import IngestPlan, check_ingest, tap_offsets
from repro.core.tiling import (
    DEFAULT_VMEM_BUDGET_BYTES,
    TILE_AUTO,
    num_row_tiles,
    resolve_tile_rows,
    slab_rows_per_budget,
)
from repro.kernels.vcgra.ops import _batched_fused_pallas_fn
from repro.runtime.fleet import FleetRequest, PixieFleet

GRID = sobel_grid()
MULTI_DEVICE = len(jax.local_devices()) >= 2
needs_two_devices = pytest.mark.skipif(
    not MULTI_DEVICE,
    reason="needs >= 2 local devices (CI large-frame-parity job forces 2 "
    "via XLA_FLAGS=--xla_force_host_platform_device_count=2)",
)

FLEET_APPS = ["sobel_x", "sobel_y", "sharpen", "laplace", "threshold", "identity"]
# Place/route once per app; every test below only swaps settings arrays.
CONFIGS = {n: map_app(apps.ALL_APPS[n](), GRID) for n in FLEET_APPS}


def _stacked_workload(rng, names, hws):
    """Ragged non-square frames embedded on one canvas + stacked settings
    (same construction as the fleet's fused dispatch)."""
    images = [rng.integers(0, 256, hw).astype(np.int32) for hw in hws]
    configs = [CONFIGS[n] for n in names]
    Hb, Wb = max(h for h, _ in hws), max(w for _, w in hws)
    canvas = np.zeros((len(names), Hb, Wb), dtype=np.int32)
    for i, img in enumerate(images):
        canvas[i, : img.shape[0], : img.shape[1]] = img
    return (
        VCGRAConfig.stack(configs),
        IngestPlan.stack([c.ingest for c in configs], GRID.dtype),
        jnp.asarray(canvas),
    )


# -- plan axis validation ------------------------------------------------------


def test_tile_rows_plan_validation():
    with pytest.raises(ValueError, match="unfused"):
        OverlayPlan(grid=GRID, batched=True, tile_rows=8)
    with pytest.raises(ValueError, match="tile_rows"):
        OverlayPlan(grid=GRID, fused=True, tile_rows=0)
    with pytest.raises(ValueError, match="unknown ingest"):
        OverlayPlan(grid=GRID, ingest="dma")
    with pytest.raises(ValueError, match="unknown ingest"):
        check_ingest("eager")
    # canonicalization: explicit heights become ints, auto survives
    assert OverlayPlan(grid=GRID, fused=True, tile_rows="7").tile_rows == 7
    assert OverlayPlan(grid=GRID, fused=True, tile_rows=TILE_AUTO).tile_rows == TILE_AUTO
    # the fleet validates eagerly at construction, not on the first flush
    for bad in (0, -3, "bogus"):
        with pytest.raises(ValueError, match="tile_rows"):
            PixieFleet(tile_rows=bad)
    with pytest.raises(ValueError, match="unknown ingest"):
        PixieFleet(ingest="dma")


def test_tile_and_ingest_axes_distinguish_plan_keys():
    base = OverlayPlan(grid=GRID, batched=True, fused=True)
    variants = [
        base,
        OverlayPlan(grid=GRID, batched=True, fused=True, tile_rows=8),
        OverlayPlan(grid=GRID, batched=True, fused=True, tile_rows=16),
        OverlayPlan(grid=GRID, batched=True, fused=True, tile_rows=TILE_AUTO),
        OverlayPlan(grid=GRID, batched=True, fused=True, ingest="async"),
        OverlayPlan(grid=GRID, batched=True, fused=True, tile_rows=8,
                    ingest="async"),
    ]
    assert len({hash(p) for p in variants}) == len(variants)
    assert len({p.key() for p in variants}) == len(variants)
    # PR 4-era keys are stable: default tile/ingest add no segments
    assert base.key().endswith("dev1")
    assert "tile:8" in variants[1].key() and "async" in variants[4].key()


def test_resolve_tile_rows_and_budget_heuristic():
    # None = untiled (one slab covering the frame); ints clamp to [1, H]
    assert resolve_tile_rows(None, 33, 5, 1, GRID) == 33
    assert resolve_tile_rows(64, 10, 5, 1, GRID) == 10
    assert resolve_tile_rows(3, 10, 5, 1, GRID) == 3
    # auto: smoke-sized frames fit the budget whole (degenerates untiled) ...
    assert resolve_tile_rows(TILE_AUTO, 32, 32, 1, GRID) == 32
    # ... 1080p-class frames do not: the heuristic actually tiles
    auto_1080 = resolve_tile_rows(TILE_AUTO, 1080, 1920, 1, GRID)
    assert 1 <= auto_1080 < 1080
    # the working set the pick implies respects the budget, INCLUDING both
    # in-flight DMA slabs of the double buffer (+2 rows per output row plus
    # the constant 2 * 2r * W halo rows)
    itemsize = jnp.dtype(GRID.dtype).itemsize
    taps = (2 * 1 + 1) ** 2 + 1
    per_row = (taps + GRID.num_inputs + max(GRID.pes_per_level) + 2) * 1920 * itemsize
    halo = 2 * (2 * 1) * 1920 * itemsize
    assert auto_1080 * per_row + halo <= DEFAULT_VMEM_BUDGET_BYTES
    # budget monotonicity + floor of one row
    assert slab_rows_per_budget(1 << 20, 2, num_inputs=64, max_level_width=32,
                                itemsize=4) == 1
    assert num_row_tiles(13, 4) == 4 and num_row_tiles(12, 4) == 3


# -- bitwise parity vs the untiled sync XLA oracle -----------------------------


@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("tile_rows", [1, 3, 5, 8, 64, TILE_AUTO])
def test_tiled_matches_untiled_oracle_bitwise(backend, tile_rows, rng):
    """compile_plan(tile_rows=...) == the untiled XLA step, bitwise, on a
    ragged non-square stack with H=13 (so 3, 5 and 8 do not divide H and
    64 exceeds it)."""
    names = ["sobel_x", "sharpen", "identity", "laplace"]
    hws = [(13, 11), (9, 4), (7, 7), (3, 10)]
    stacked, ingests, canvas = _stacked_workload(rng, names, hws)
    oracle = np.asarray(
        interpreter.batched_fused_overlay_step(GRID, 1, stacked, ingests, canvas)
    )
    exe = compile_plan(OverlayPlan(grid=GRID, batched=True, fused=True,
                                   backend=backend, tile_rows=tile_rows))
    np.testing.assert_array_equal(
        np.asarray(exe(stacked, ingests, canvas)), oracle
    )


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_fleet_tiled_bitwise(backend, rng):
    """PixieFleet(tile_rows=4) == PixieFleet(tile_rows=None) on ragged
    frames; the tiled fleet stamps the tile segment into its plan keys."""
    names = ["sobel_x", "sharpen", "identity"]
    images = [rng.integers(0, 256, hw).astype(np.int32)
              for hw in [(6, 8), (11, 5), (3, 9)]]
    reqs = [FleetRequest(app=n, image=i) for n, i in zip(names, images)]
    ref = PixieFleet(default_grid=GRID, backend=backend,
                     tile_rows=None).run_many(reqs)
    fleet = PixieFleet(default_grid=GRID, backend=backend, tile_rows=4)
    got = fleet.run_many(reqs)
    for a, b in zip(ref, got):
        np.testing.assert_array_equal(a, b)
    assert all("tile:4" in k for k in fleet.stats.dispatch_plans)


def test_async_ingest_single_flush_bitwise(rng):
    img = rng.integers(0, 256, (16, 16)).astype(np.int32)
    reqs = [FleetRequest(app=n, image=img) for n in FLEET_APPS]
    ref = PixieFleet(default_grid=GRID).run_many(reqs)
    fleet = PixieFleet(default_grid=GRID, ingest="async")
    got = fleet.run_many(reqs)
    for a, b in zip(ref, got):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert fleet.stats.ingest == "async"
    assert all("async" in k for k in fleet.stats.dispatch_plans)


# -- deterministic edge-case sweep (the hypothesis twin lives in
#    test_tiling_property.py, gated on the dev dependency) --------------------


def random_fused_workload(H, W, radius, n, seed):
    """Random frames + random *runtime* ingest settings: tap selects drawn
    over the whole radius-``radius`` bank (zero row included) and random
    const values -- the tiled executors must agree with the oracle for any
    settings, not just the library apps' plans.  Shared with the
    hypothesis suite (test_tiling_property.py)."""
    rng = np.random.default_rng(seed)
    configs = [CONFIGS[FLEET_APPS[i % len(FLEET_APPS)]] for i in range(n)]
    stacked = VCGRAConfig.stack(configs)
    taps = len(tap_offsets(radius))
    tap_sel = jnp.asarray(
        rng.integers(0, taps + 1, (n, GRID.num_inputs)).astype(np.int32)
    )
    const_vals = jnp.asarray(
        rng.integers(-8, 9, (n, GRID.num_inputs)), GRID.dtype
    )
    images = jnp.asarray(rng.integers(0, 256, (n, H, W)).astype(np.int32))
    return stacked, (tap_sel, const_vals), images


def assert_tiled_equals_untiled(H, W, radius, tile_rows, n, seed, backend):
    """One tiled-vs-untiled bitwise check over random runtime settings;
    the body of both the deterministic sweep and the hypothesis suite."""
    stacked, ingests, images = random_fused_workload(H, W, radius, n, seed)
    oracle = np.asarray(interpreter.batched_fused_overlay_step(
        GRID, radius, stacked, ingests, images))
    if backend == "xla":
        tiled = interpreter.tiled_batched_fused_overlay_step(
            GRID, radius, tile_rows, stacked, ingests, images)
    else:
        tiled = _batched_fused_pallas_fn(
            GRID, radius, tile_rows=tile_rows)(stacked, ingests, images)
    np.testing.assert_array_equal(np.asarray(tiled), oracle)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize(
    "H,W,radius,tile_rows",
    [
        (1, 1, 0, 1),     # degenerate frame, radius-0 single-tap bank
        (7, 5, 0, 3),     # radius-0, tile does not divide H
        (13, 9, 1, 5),    # classic ragged tiling
        (6, 11, 1, 6),    # tile_rows == H (single tile, exact)
        (4, 7, 2, 3),     # radius exceeds tile_rows: halo > tile body
        (9, 3, 2, 64),    # tile_rows >> H clamps to untiled
    ],
)
def test_tiled_edge_cases_bitwise(H, W, radius, tile_rows, backend):
    assert_tiled_equals_untiled(H, W, radius, tile_rows, n=3, seed=7,
                                backend=backend)


# -- large-frame parity (the CI gate at 256x256) -------------------------------


@pytest.mark.slow
def test_large_frame_tiled_async_parity_256(rng):
    """256x256 frames: auto-tiled async fleet == untiled sync fleet,
    bitwise, on both dispatch paths of a mixed flush."""
    side = 256
    names = ["sobel_x", "sharpen", "identity"]
    reqs = [FleetRequest(app=n, image=rng.integers(0, 256, (side, side))
                         .astype(np.int32)) for n in names]
    reqs.append(FleetRequest(
        app="threshold",
        inputs={"p11": rng.integers(0, 256, (257,)).astype(np.int32)},
    ))
    ref = PixieFleet(default_grid=GRID, tile_rows=None).run_many(reqs)
    fleet = PixieFleet(default_grid=GRID, tile_rows=TILE_AUTO, ingest="async")
    # Async pool depth is 2 (double buffer): the third flush is the first
    # to rotate back onto a pooled canvas.
    for _ in range(3):
        got = fleet.run_many(reqs)
    for a, b in zip(ref, got):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert fleet.stats.canvas_pool_hits >= 1
    assert fleet.stats.ingest_overlap_s >= 0.0


@pytest.mark.slow
@needs_two_devices
def test_large_frame_tiled_sharded_parity_256(rng):
    """Tiling + async ingest compose with the PR 4 app-axis sharding:
    devices=2 tiled async == single-device untiled sync at 256x256."""
    side = 256
    names = ["sobel_x", "laplace"]
    reqs = [FleetRequest(app=n, image=rng.integers(0, 256, (side, side))
                         .astype(np.int32)) for n in names]
    ref = PixieFleet(default_grid=GRID, tile_rows=None).run_many(reqs)
    fleet = PixieFleet(default_grid=GRID, devices=2, tile_rows=64,
                       ingest="async")
    got = fleet.run_many(reqs)
    for a, b in zip(ref, got):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert all("dev2" in k and "tile:64" in k and "async" in k
               for k in fleet.stats.dispatch_plans)
