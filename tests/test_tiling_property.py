"""Property-based tests (hypothesis): the row-tiled fused executors must
be bitwise identical to the untiled XLA oracle for *random*
``(H, W, radius, tile_rows)`` -- including ``tile_rows`` that do not
divide H, ``tile_rows >= H``, and radius-0 (single-tap) bank layouts --
over random runtime ingest settings, on both backends.

The deterministic edge-case sweep twin (same assertion body, fixed
corners) lives in test_tiling.py and runs even without the dev
dependency.
"""

import pytest

# Gate rather than hard-import: hypothesis is a dev dependency
# (requirements-dev.txt), absent from minimal runtime installs.
pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (see requirements-dev.txt)"
)
from hypothesis import given, settings, strategies as st  # noqa: E402

from test_tiling import assert_tiled_equals_untiled  # noqa: E402


@st.composite
def tiled_cases(draw):
    """Random (H, W, radius, tile_rows, n, seed) covering tile_rows that
    do not divide H, tile_rows >= H, and radius-0 grids by construction
    of the ranges."""
    H = draw(st.integers(1, 18))
    W = draw(st.integers(1, 18))
    radius = draw(st.integers(0, 2))
    tile_rows = draw(st.integers(1, H + 4))
    n = draw(st.integers(1, 3))
    seed = draw(st.integers(0, 2**31 - 1))
    return H, W, radius, tile_rows, n, seed


@settings(max_examples=30, deadline=None)
@given(tiled_cases())
def test_property_tiled_equals_untiled_xla(case):
    H, W, radius, tile_rows, n, seed = case
    assert_tiled_equals_untiled(H, W, radius, tile_rows, n, seed, "xla")


# The pallas megakernel runs in interpret mode on CPU CI (slower per
# example); fewer examples, same strategy space.
@settings(max_examples=8, deadline=None)
@given(tiled_cases())
def test_property_tiled_equals_untiled_pallas(case):
    H, W, radius, tile_rows, n, seed = case
    assert_tiled_equals_untiled(H, W, radius, tile_rows, n, seed, "pallas")
