"""Roofline machinery tests: shape parsing, collective census, and the
trip-count-aware HLO analysis validated against known-FLOP programs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline import (
    PEAK_FLOPS, RooflineReport, collective_bytes, model_flops_estimate,
    shape_bytes,
)
from repro.roofline.hlo_analysis import analyze


def test_shape_bytes():
    assert shape_bytes("f32[16,128]") == 16 * 128 * 4
    assert shape_bytes("bf16[8]") == 16
    assert shape_bytes("pred[4,4]") == 16
    assert shape_bytes("(f32[2,2], s8[4])") == 16 + 4
    assert shape_bytes("f32[]") == 4


def test_collective_regex():
    hlo = """
  %ar = f32[16,1408]{1,0} all-reduce(f32[16,1408]{1,0} %x), replica_groups={}
  %ag.1 = bf16[32,64]{1,0} all-gather(bf16[16,64]{1,0} %y), dimensions={0}
  %nope = f32[4]{0} add(f32[4]{0} %a, f32[4]{0} %b)
"""
    c = collective_bytes(hlo)
    assert c["all-reduce"] == 16 * 1408 * 4
    assert c["all-gather"] == 32 * 64 * 2
    assert c["total"] == c["all-reduce"] + c["all-gather"]


def test_hlo_census_scan_trip_counts():
    def f(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=5)
        return y.sum()

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    txt = jax.jit(f).lower(x, w).compile().as_text()
    c = analyze(txt)
    assert c.flops == pytest.approx(5 * 2 * 64 ** 3)
    assert 5 in c.while_trips.values()
    assert c.hbm_bytes > 0


def test_hlo_census_nested_scans_multiply():
    def f(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            ci, _ = jax.lax.scan(inner, c, None, length=3)
            return ci, None
        y, _ = jax.lax.scan(outer, x, None, length=4)
        return y.sum()

    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    w = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    txt = jax.jit(f).lower(x, w).compile().as_text()
    c = analyze(txt)
    assert c.flops == pytest.approx(4 * 3 * 2 * 32 ** 3)


def test_hlo_census_no_loops():
    def f(a, b):
        return (a @ b).sum()

    a = jax.ShapeDtypeStruct((16, 64), jnp.float32)
    b = jax.ShapeDtypeStruct((64, 8), jnp.float32)
    txt = jax.jit(f).lower(a, b).compile().as_text()
    c = analyze(txt)
    assert c.flops == pytest.approx(2 * 16 * 64 * 8)
    assert c.collective_bytes == 0


def test_roofline_report_terms():
    r = RooflineReport(
        arch="a", shape="train_4k", mesh="single", chips=256,
        flops_per_device=197e12,        # exactly 1 second of compute
        bytes_per_device=819e9,         # exactly 1 second of HBM
        coll_bytes_per_device=25e9,     # 0.5 s of ICI
        model_flops=197e12 * 256,
    )
    assert r.t_compute == pytest.approx(1.0)
    assert r.t_memory == pytest.approx(1.0)
    assert r.t_collective == pytest.approx(0.5)
    assert r.bottleneck in ("compute", "memory")
    assert r.useful_flops_ratio == pytest.approx(1.0)
    assert r.mfu == pytest.approx(1.0)


def test_model_flops_estimate_kinds():
    from repro.configs import ARCHS, SHAPES

    cfg = ARCHS["gemma-2b"]
    n = 2.5e9
    train = model_flops_estimate(cfg, SHAPES["train_4k"], n)
    assert train == pytest.approx(6 * n * 256 * 4096)
    dec = model_flops_estimate(cfg, SHAPES["decode_32k"], n)
    assert dec == pytest.approx(2 * n * 128)


def test_production_mesh_shapes():
    """Mesh constructor contract (actual 512-device build happens only in
    the dry-run process; here we check the spec without touching devices)."""
    import inspect
    from repro.launch.mesh import make_production_mesh

    src = inspect.getsource(make_production_mesh)
    assert "(2, 16, 16)" in src and "(16, 16)" in src
    assert '"pod", "data", "model"' in src
