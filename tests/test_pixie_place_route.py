"""Unit tests: mapper/placer, router, bitstream, grid generator."""

import numpy as np
import pytest

from repro.core import (
    DFG, Op, PlacementError, RoutingError, VCGRAConfig,
    for_dfg, level_demand, map_app, paper_4x4, place, rectangular,
    route, sobel_grid,
)
from repro.core import applications as apps
from repro.core.grid import custom


def test_sobel_placement_matches_paper():
    """Paper Sec. IV/V-D: Sobel = 45 PEs + 4 inter-level VCs; the majority
    of PEs on the rectangular grid end up configured NONE."""
    g = apps.sobel_x()
    grid = sobel_grid()
    assert grid.num_pes == 45
    assert grid.num_levels == 5
    pl = place(g, grid)
    st = pl.stats()
    assert st["op_pes"] == 17            # 9 MUL + 8 ADD
    assert st["buf_pes"] == 3            # leftover product carried 3 stages
    assert st["none_pes"] == 25          # majority NONE, as the paper notes
    assert st["none_pes"] > grid.num_pes // 2


def test_buf_chain_for_level_skipping_edge():
    g = DFG("skip")
    x, y = g.input("x"), g.input("y")
    a = g.mul(x, y)        # L0
    b = g.add(a, a)        # L1
    c = g.add(b, b)        # L2
    d = g.add(c, a)        # L3: 'a' (L0) must be buffered through L1, L2
    g.output(d)
    demand = level_demand(g)
    assert demand == [1, 2, 2, 1]  # BUF carriers at L1 and L2
    grid = for_dfg(g, shape="exact")
    pl = place(g, grid)
    assert pl.num_buf == 2


def test_inputs_buffered_down_from_level0():
    g = DFG("late_input")
    x, y, z = g.input("x"), g.input("y"), g.input("z")
    a = g.mul(x, y)     # L0
    b = g.add(a, z)     # L1: input z needs a BUF at L0
    g.output(b)
    assert level_demand(g) == [2, 1]


def test_outputs_buffered_to_bottom():
    """Paper: 'an output value has to be buffered in every stage until it
    reaches the data output channel at the bottom'."""
    g = DFG("t")
    x, y = g.input("x"), g.input("y")
    g.output(g.add(x, y))   # depth 1
    deep = rectangular("deep", 2, levels=4, width=2, num_outputs=1)
    pl = place(g, deep)
    assert pl.num_buf == 3  # carried through 3 extra levels
    cfg = map_app(g, deep)
    assert [int(o[0]) for o in cfg.opcodes] == [
        int(Op.ADD), int(Op.BUF), int(Op.BUF), int(Op.BUF)
    ]


def test_capacity_overflow_raises():
    g = apps.sobel_x()
    tiny = rectangular("tiny", 18, levels=5, width=4, num_outputs=1)
    with pytest.raises(PlacementError, match="level 0 needs 9"):
        place(g, tiny)


def test_too_few_memory_inputs_raises():
    g = apps.sobel_x()
    narrow = rectangular("narrow", 4, levels=5, width=9, num_outputs=1)
    with pytest.raises(PlacementError, match="memory inputs"):
        place(g, narrow)


def test_too_shallow_grid_raises():
    g = apps.sobel_x()
    shallow = rectangular("shallow", 18, levels=3, width=16, num_outputs=1)
    with pytest.raises(PlacementError, match="depth"):
        place(g, shallow)


def test_route_selects_in_range():
    g = apps.sobel_magnitude()
    grid = for_dfg(g, shape="exact")
    pl = place(g, grid)
    rt = route(pl, grid)
    for lvl, sel in enumerate(rt.sel):
        assert sel.min() >= 0
        assert sel.max() < grid.vc_in_width(lvl)
    assert rt.out_sel.max() < grid.pes_per_level[-1]


def test_grid_generator_shapes():
    g = apps.sobel_x()
    exact = for_dfg(g, shape="exact")
    rect = for_dfg(g, shape="rect")
    tri = for_dfg(g, shape="triangular")
    assert exact.pes_per_level == (9, 5, 3, 2, 1)
    assert rect.pes_per_level == (9,) * 5
    # triangular: monotonically non-increasing, fits demand
    assert all(a >= b for a, b in zip(tri.pes_per_level, tri.pes_per_level[1:]))
    for spec in (exact, rect, tri):
        place(g, spec)  # must all fit


def test_resource_model_eq1_to_eq3():
    grid = paper_4x4()
    p = grid.channel_params(0)
    assert p["M_valid_vector"] == 8             # Eq. (2): #predecessors
    assert p["bw_mux_config_word"] == 3         # Eq. (3): ceil(log2(8))
    p1 = grid.channel_params(1)
    assert p1["M_valid_vector"] == 4
    assert p1["bw_mux_config_word"] == 2
    rm = grid.resource_model()
    assert rm["pes"] == 16
    assert rm["vcs"] == 5
    assert rm["total_bits"] == rm["pe_bits"] + rm["vc_bits"]


def test_bitstream_roundtrip_json():
    g = apps.gaussian_blur()
    grid = for_dfg(g, shape="exact")
    cfg = map_app(g, grid)
    cfg2 = VCGRAConfig.from_json(cfg.to_json())
    assert cfg2.app_name == cfg.app_name
    assert cfg2.input_order == cfg.input_order
    for a, b in zip(cfg.opcodes, cfg2.opcodes):
        assert (a == b).all()
    for a, b in zip(cfg.selects, cfg2.selects):
        assert (a == b).all()
    assert (cfg.out_sel == cfg2.out_sel).all()
    assert cfg2.const_values == cfg.const_values


def test_custom_grid_per_level_widths():
    spec = custom("c", 4, [3, 1, 2], num_outputs=2)
    assert spec.num_pes == 6
    assert spec.vc_in_width(0) == 4
    assert spec.vc_in_width(2) == 1
    assert spec.vc_out_ports(1) == 2
