"""Kernel tests: fused 3x3 stencil vs oracle, and equivalence with the
overlay path (the beyond-paper optimization computes the same function)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import applications as apps
from repro.kernels.stencil import conv3x3_fused, sobel_magnitude_fused, stencil_ref


@pytest.mark.parametrize("hw", [(8, 128), (16, 126), (33, 200), (7, 9)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.int32])
def test_fused_sobel_matches_ref(hw, dtype, rng):
    img = jnp.asarray(rng.integers(0, 255, hw)).astype(dtype)
    out = np.asarray(sobel_magnitude_fused(img))
    ref = np.asarray(stencil_ref(img, (apps.SOBEL_X, apps.SOBEL_Y)))
    np.testing.assert_allclose(out, ref, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("name", ["sobel_x", "gauss3", "sharpen", "laplace"])
def test_fused_single_kernels(name, rng):
    img = jnp.asarray(rng.random((20, 40)).astype(np.float32) * 255)
    kq = {
        "sobel_x": apps.SOBEL_X,
        "gauss3": apps.GAUSS3,
        "sharpen": apps.SHARPEN,
        "laplace": apps.LAPLACE,
    }[name]
    out = np.asarray(conv3x3_fused(img, name))
    ref = np.asarray(stencil_ref(img, (kq,)))
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("block_h", [4, 8, 16])
def test_fused_block_sweep(block_h, rng):
    img = jnp.asarray(rng.random((30, 70)).astype(np.float32))
    out = np.asarray(sobel_magnitude_fused(img, block_h=block_h))
    ref = np.asarray(stencil_ref(img, (apps.SOBEL_X, apps.SOBEL_Y)))
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


def test_fused_equals_overlay_path(rng):
    """Paper-faithful overlay and the optimized fusion compute the same
    Sobel magnitude -- the §Perf comparison is apples-to-apples."""
    from repro.core import Pixie, for_dfg, map_app

    img32 = rng.integers(0, 256, (14, 22)).astype(np.int32)
    dfg = apps.sobel_magnitude()
    grid = for_dfg(dfg, shape="exact")
    pix = Pixie(grid, mode="parameterized")
    pix.load(map_app(dfg, grid), batch=img32.size)
    overlay_out = np.asarray(pix.run_image(jnp.asarray(img32)))
    fused_out = np.asarray(sobel_magnitude_fused(jnp.asarray(img32)))
    np.testing.assert_array_equal(overlay_out, fused_out)
