"""Property-based resilience tests (hypothesis): for RANDOM poison
subsets, bisection quarantine isolates EXACTLY the poisoned tickets --
every survivor is served bitwise-equal to the fault-free oracle, every
poisoned ticket raises a typed QuarantinedError, never more, never fewer
-- on both backends.  The deterministic backoff schedule is pinned as a
pure function of its policy parameters (no jitter, monotone, capped).

Deterministic twins of the core cases live in tests/test_resilience.py;
this module is nightly/CI-only where hypothesis is installed.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed (see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import sobel_grid
from repro.runtime.chaos import FaultInjector
from repro.runtime.fleet import FleetRequest, PixieFleet
from repro.runtime.resilience import QuarantinedError, RetryPolicy

NAMES = ["sobel_x", "sobel_y", "laplace", "sharpen", "identity", "threshold"]
RNG = np.random.default_rng(1234)
IMAGES = [RNG.integers(0, 256, (5 + i, 7)).astype(np.int32)
          for i in range(len(NAMES))]
ORACLE = {}


def _oracle(backend):
    if backend not in ORACLE:
        fleet = PixieFleet(default_grid=sobel_grid(), backend=backend)
        ORACLE[backend] = [
            np.asarray(y) for y in fleet.run_many(
                [FleetRequest(app=n, image=im)
                 for n, im in zip(NAMES, IMAGES)])
        ]
    return ORACLE[backend]


@settings(max_examples=8, deadline=None)
@given(
    poison=st.sets(st.integers(min_value=0, max_value=len(NAMES) - 1),
                   min_size=1, max_size=len(NAMES) - 1),
    backend=st.sampled_from(["xla", "pallas"]),
)
def test_bisection_isolates_exactly_the_poisoned_subset(poison, backend):
    oracle = _oracle(backend)
    faults = FaultInjector(seed=7).inject(
        "dispatch", transient=False,
        match=tuple(f"<ticket:{i}>" for i in sorted(poison)))
    fleet = PixieFleet(default_grid=sobel_grid(), backend=backend,
                       faults=faults, retry=RetryPolicy(max_attempts=1))
    tickets = [fleet.submit(FleetRequest(app=n, image=im))
               for n, im in zip(NAMES, IMAGES)]
    fleet.flush()
    for i, t in enumerate(tickets):
        if i in poison:
            with pytest.raises(QuarantinedError) as ei:
                fleet.result(t)
            assert ei.value.ticket == t and ei.value.app == NAMES[i]
        else:
            np.testing.assert_array_equal(np.asarray(fleet.result(t)),
                                          oracle[i])
    assert fleet.stats.quarantined_requests == len(poison)


@settings(max_examples=50, deadline=None)
@given(
    attempts=st.integers(min_value=1, max_value=8),
    base_ms=st.floats(min_value=0.1, max_value=50.0),
    mult=st.floats(min_value=1.0, max_value=4.0),
    cap_ms=st.floats(min_value=0.1, max_value=200.0),
)
def test_backoff_schedule_is_pure_monotone_and_capped(attempts, base_ms,
                                                      mult, cap_ms):
    r = RetryPolicy(max_attempts=attempts, backoff_base_s=base_ms / 1e3,
                    backoff_multiplier=mult, backoff_max_s=cap_ms / 1e3)
    sched = r.schedule()
    assert len(sched) == attempts - 1
    assert sched == r.schedule()                      # pure: no jitter
    assert all(b <= r.backoff_max_s + 1e-12 for b in sched)
    assert all(b2 >= b1 - 1e-12 for b1, b2 in zip(sched, sched[1:]))
    for i, b in enumerate(sched):
        assert b == min(r.backoff_base_s * mult ** i, r.backoff_max_s)
