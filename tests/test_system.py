"""End-to-end behaviour tests for the paper's system.

The paper's operational story, as executable assertions:

1. an application written at the dataflow level maps onto the overlay in
   well under a second;
2. the overlay compiles ONCE; any mapped application then runs by writing
   settings (no recompilation) and produces oracle-exact pixels;
3. the parameterized (constant-specialized) implementation computes the
   same function with measurably fewer resources (HLO ops);
4. the whole stack -- overlay in the data pipeline, LM substrate, serving
   -- composes.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Pixie, SOBEL_SOURCE, for_dfg, map_app, sobel_grid, synthesize
from repro.core import applications as apps
from repro.core.analysis import compile_and_census
from repro.core.interpreter import make_overlay_fn
from repro.core.specialize import build_specialized_fn


def test_map_under_one_second():
    """Paper Sec. V-E: 'The time taken to map the Sobel edge detection
    application is less than one second.'"""
    dfg = synthesize("sobel", SOBEL_SOURCE)
    grid = for_dfg(dfg, shape="rect")
    pix = Pixie(grid)
    t0 = time.perf_counter()
    pix.map(dfg)
    assert time.perf_counter() - t0 < 1.0


def test_compile_once_run_many(rng):
    """One overlay executable serves sobel_x, sobel_y, sharpen, laplace."""
    grid = sobel_grid()
    pix = Pixie(grid, mode="conventional")
    img = jnp.asarray(rng.integers(0, 256, (24, 24)).astype(np.int32))
    pix.compile_overlay(batch=img.size)
    n0 = None
    oracles = {
        "sobel_x": lambda i: apps.conv2d_reference(i, apps.SOBEL_X),
        "sobel_y": lambda i: apps.conv2d_reference(i, apps.SOBEL_Y),
        "sharpen": lambda i: apps.conv2d_reference(i, apps.SHARPEN),
        "laplace": lambda i: apps.conv2d_reference(i, apps.LAPLACE),
    }
    for name, oracle in oracles.items():
        pix.load(pix.map(apps.ALL_APPS[name]()))
        out = np.asarray(pix.run_image(img))
        np.testing.assert_array_equal(out, oracle(np.asarray(img)))
        if n0 is None:
            n0 = pix._overlay_fn._cache_size()  # after the first execution
    assert pix._overlay_fn._cache_size() == n0, "reconfiguration recompiled"


def test_parameterized_uses_fewer_resources():
    """The Table-I claim, system-level: specialized executor emits fewer
    HLO ops (and no more routing ops) than the conventional."""
    dfg = apps.sobel_x()
    grid = sobel_grid()
    cfg = map_app(dfg, grid)
    x = jnp.zeros((grid.num_inputs, 1024), grid.dtype)
    conv = compile_and_census(
        lambda c, xx: make_overlay_fn(grid)(c, xx), cfg.to_jax(), x
    )
    spec = compile_and_census(build_specialized_fn(grid, cfg), x)
    assert spec["total_ops"] < conv["total_ops"]
    assert spec["routing_ops"] <= conv["routing_ops"]
    assert spec["flops"] < conv["flops"]


def test_full_stack_composes(rng):
    """Overlay preprocessing -> patch stub -> VLM forward: one pipeline."""
    from repro.configs import ARCHS, reduced
    from repro.data import PixiePreprocessor, patch_embed_stub, synthetic_images
    from repro.models import LM

    cfg = reduced(ARCHS["paligemma-3b"])
    pre = PixiePreprocessor(filters=("sobel_mag",))
    images = synthetic_images(2, (16, 16))
    filtered = np.asarray(pre.batch(jnp.asarray(images)))
    pe = jnp.asarray(patch_embed_stub(filtered, cfg.prefix_tokens, cfg.d_model))

    lm = LM(cfg, remat="none", chunk_q=16, loss_chunk=16)
    params = lm.init(jax.random.PRNGKey(0))
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)))
    loss, _ = lm.loss(params, tokens, pe)
    assert bool(jnp.isfinite(loss))
