"""Kernel tests: flash decode attention vs oracle over shape/dtype/GQA sweeps."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import decode_attention, decode_ref


def _mk(rng, B, H, G, D, S, dtype):
    q = jnp.asarray(rng.standard_normal((B, H, D)).astype(np.float32)).astype(dtype)
    k = jnp.asarray(rng.standard_normal((B, S, G, D)).astype(np.float32)).astype(dtype)
    v = jnp.asarray(rng.standard_normal((B, S, G, D)).astype(np.float32)).astype(dtype)
    return q, k, v


@pytest.mark.parametrize("B,H,G,D,S", [
    (2, 8, 8, 64, 512),    # MHA
    (2, 8, 2, 64, 512),    # GQA 4:1
    (1, 8, 1, 128, 1024),  # MQA
    (3, 25, 5, 64, 512),   # hymba-like ragged head count
])
def test_decode_matches_ref_full_cache(B, H, G, D, S, rng):
    q, k, v = _mk(rng, B, H, G, D, S, jnp.float32)
    lengths = jnp.full((B,), S, jnp.int32)
    out = np.asarray(decode_attention(q, k, v, lengths, chunk=256))
    ref = np.asarray(decode_ref(q, k, v, lengths))
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("chunk", [128, 256, 512])
def test_decode_chunk_sweep(chunk, rng):
    q, k, v = _mk(rng, 2, 4, 2, 64, 1024, jnp.float32)
    lengths = jnp.array([700, 1024], jnp.int32)
    out = np.asarray(decode_attention(q, k, v, lengths, chunk=chunk))
    ref = np.asarray(decode_ref(q, k, v, lengths))
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


def test_decode_partial_lengths_mask(rng):
    """Entries past each sequence's valid length must not influence output."""
    B, H, G, D, S = 2, 4, 2, 64, 512
    q, k, v = _mk(rng, B, H, G, D, S, jnp.float32)
    lengths = jnp.array([100, 257], jnp.int32)
    out1 = np.asarray(decode_attention(q, k, v, lengths, chunk=128))
    # poison the invalid tail; result must be identical
    poison = jnp.full_like(k, 1e9)
    mask = (jnp.arange(S)[None, :, None, None] < lengths[:, None, None, None])
    k2 = jnp.where(mask, k, poison)
    v2 = jnp.where(mask, v, poison)
    out2 = np.asarray(decode_attention(q, k2, v2, lengths, chunk=128))
    np.testing.assert_allclose(out1, out2, rtol=1e-6, atol=1e-6)


def test_decode_bf16_cache(rng):
    q, k, v = _mk(rng, 2, 8, 4, 64, 512, jnp.bfloat16)
    lengths = jnp.full((2,), 512, jnp.int32)
    out = np.asarray(decode_attention(q, k, v, lengths, chunk=256).astype(jnp.float32))
    ref = np.asarray(decode_ref(q, k, v, lengths).astype(jnp.float32))
    np.testing.assert_allclose(out, ref, rtol=5e-2, atol=5e-2)


def test_decode_matches_softmax_oracle_exactly_one_chunk(rng):
    """Single-chunk case degenerates to plain softmax attention."""
    q, k, v = _mk(rng, 1, 2, 2, 32, 128, jnp.float32)
    lengths = jnp.full((1,), 128, jnp.int32)
    out = np.asarray(decode_attention(q, k, v, lengths, chunk=128))
    ref = np.asarray(decode_ref(q, k, v, lengths))
    np.testing.assert_allclose(out, ref, rtol=1e-6, atol=1e-6)
