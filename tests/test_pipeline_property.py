"""Property-based pipeline tests (hypothesis): for RANDOM chains -- depth
1-4, mixed stage radii including re-planned radius-0 pointwise stages,
ragged non-square multi-app stacks -- the fused device-resident chain is
BITWISE equal to the staged per-stage oracle (one single-stage fleet
flush per stage, host hop between), on both backends.

Plan-key compatibility is pinned here too: depth-1 "chains" must hash
and key identically to the existing single-stage fused plans, so the new
pipeline axis cannot orphan any pre-pipeline cache entry.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed (see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from conftest import shared_app_grid

from repro.core import OverlayPlan, map_app
from repro.core import applications as apps
from repro.core.plan import PipelineSpec, PipelineStage
from repro.runtime.fleet import FleetRequest, PixieFleet

STAGE_NAMES = ["gauss3", "sobel_x", "threshold", "identity", "sharpen"]
GRID = shared_app_grid(STAGE_NAMES, name="pipe-prop")
# Pointwise stages (single center tap) re-plan to a radius-0 bank; the
# mixed-radii chain then pads each stage by ITS radius, not a global one.
POINTWISE = ("threshold", "identity")


def _cfg(name):
    cfg = map_app(apps.ALL_APPS[name](), GRID)
    cfg.cache_key = f"{name}@{GRID.name}"  # fleet settings-bank identity
    return cfg


CFGS = {n: _cfg(n) for n in STAGE_NAMES}
AT0 = {n: PipelineStage(CFGS[n]).at_radius(0).config for n in POINTWISE}

# Module-level fleets: the overlay LRU persists across hypothesis
# examples, so repeated chain shapes reuse executables (keeps the suite
# inside tier-1 time); the oracle fleet runs plain single-stage flushes.
FLEETS = {b: PixieFleet(default_grid=GRID, backend=b)
          for b in ("xla", "pallas")}
ORACLE = PixieFleet(default_grid=GRID)


@st.composite
def chain_cases(draw):
    depth = draw(st.integers(1, 4))
    cfgs = []
    for _ in range(depth):
        name = draw(st.sampled_from(STAGE_NAMES))
        if name in POINTWISE and draw(st.booleans()):
            cfgs.append(AT0[name])  # radius-0 stage in the mix
        else:
            cfgs.append(CFGS[name])
    n_apps = draw(st.integers(1, 3))
    hws = [
        (draw(st.integers(4, 12)), draw(st.integers(4, 12)))
        for _ in range(n_apps)
    ]
    seed = draw(st.integers(0, 2**31 - 1))
    return cfgs, hws, seed


@pytest.mark.parametrize("backend", ["xla", "pallas"])
@given(case=chain_cases())
@settings(max_examples=12, deadline=None)
def test_random_chains_match_staged_oracle(backend, case):
    cfgs, hws, seed = case
    rng = np.random.default_rng(seed)
    images = [rng.integers(0, 256, hw).astype(np.int32) for hw in hws]

    fused = FLEETS[backend].run_many(
        [FleetRequest(pipeline=cfgs, image=im) for im in images]
    )
    # staged oracle: one single-stage flush per stage, host hop between
    cur = images
    for cfg in cfgs:
        cur = [
            np.asarray(y)
            for y in ORACLE.run_many(
                [FleetRequest(app=cfg, image=c) for c in cur]
            )
        ]
    for got, want in zip(fused, cur):
        np.testing.assert_array_equal(np.asarray(got), want)


@given(case=chain_cases())
@settings(max_examples=20, deadline=None)
def test_depth1_chain_plans_hash_like_single_stage_plans(case):
    """EVERY depth-1 pipeline plan canonicalizes onto the pre-pipeline
    fused-plan population: equal key, equal hash, no pipe segment."""
    cfgs, _, _ = case
    cfg = cfgs[0]
    spec = PipelineSpec.chain([cfg])
    p_pipe = OverlayPlan(grid=GRID, batched=True, pipeline=(spec,))
    p_plain = OverlayPlan(
        grid=GRID, batched=True, fused=True,
        radius=int(cfg.ingest.radius),
    )
    assert p_pipe.pipeline is None
    assert p_pipe.key() == p_plain.key()
    assert p_pipe == p_plain and hash(p_pipe) == hash(p_plain)
    assert "|pipe" not in p_pipe.key()
