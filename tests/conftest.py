# NOTE: do NOT set XLA_FLAGS / host-device-count here -- smoke tests and
# benches must see the single real CPU device; only launch/dryrun.py forces
# 512 placeholder devices (and does so before any jax import).
import os

import numpy as np
import pytest

os.environ.setdefault("JAX_ENABLE_X64", "0")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
