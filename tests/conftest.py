# NOTE: do NOT set XLA_FLAGS / host-device-count here -- smoke tests and
# benches must see the single real CPU device; only launch/dryrun.py forces
# 512 placeholder devices (and does so before any jax import).
import os

import numpy as np
import pytest

os.environ.setdefault("JAX_ENABLE_X64", "0")

# Test tiers (registered in pyproject.toml [tool.pytest.ini_options]):
#   tier-1 (CI gate, < 5 min):  pytest            (addopts apply -m "not slow")
#   full / nightly:             pytest -m ""      (marker filter disabled)
#   TPU-only:                   pytest -m tpu     (skipped off-TPU below)


def pytest_collection_modifyitems(config, items):
    tpu_items = [item for item in items if "tpu" in item.keywords]
    if not tpu_items:
        return  # don't pay jax backend init when nothing is tpu-marked
    import jax

    if any(d.platform == "tpu" for d in jax.devices()):
        return
    skip_tpu = pytest.mark.skip(reason="requires a TPU device")
    for item in tpu_items:
        item.add_marker(skip_tpu)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def shared_app_grid(app_names, name="shared", slack=1):
    """One grid big enough for every named library app (the paper's
    "application specific grid designs", Sec. III-C): per-level width =
    max demand across the apps + slack.  Shared by the fleet/ingest/
    property suites so multi-tenant tests stack different apps on one
    overlay.  (Imports deferred: see the jax note at the top.)"""
    from repro.core import applications as apps
    from repro.core.grid import custom
    from repro.core.place import level_demand

    dfgs = [apps.ALL_APPS[n]() for n in app_names]
    demands = [level_demand(g) for g in dfgs]
    depth = max(len(d) for d in demands)
    demands = [list(d) + [1] * (depth - len(d)) for d in demands]
    widths = [max(d[lvl] for d in demands) + slack for lvl in range(depth)]
    return custom(name, max(len(g.inputs) for g in dfgs), widths, 1)
