"""Property-based tests (hypothesis): for *random* dataflow graphs the
three execution paths agree exactly --

    numpy oracle == conventional overlay == parameterized/specialized

and the auto-generated grid always fits the mapped graph.  Integer data is
used so equality is exact (int32 wraparound semantics match between numpy
and XLA).
"""

import jax.numpy as jnp
import numpy as np
import pytest

# Gate rather than hard-import: hypothesis is a dev dependency
# (requirements-dev.txt); environments without it skip this module instead
# of breaking collection for the whole suite.
pytest.importorskip("hypothesis", reason="hypothesis not installed (see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from conftest import shared_app_grid

from repro.core import DFG, Op, for_dfg, map_app, place, route
from repro.core import applications as apps
from repro.core.dfg import reference_eval
from repro.core.interpreter import (
    make_overlay_fn, pack_inputs, pad_channels,
)
from repro.core.specialize import build_specialized_fn
from repro.runtime.fleet import FleetRequest, PixieFleet

OPS = [Op.ADD, Op.SUB, Op.MUL, Op.DIV, Op.GT, Op.EQ, Op.BUF, Op.MAX, Op.MIN, Op.ABS]


@st.composite
def dfgs(draw):
    g = DFG("prop")
    n_inputs = draw(st.integers(1, 5))
    refs = [g.input(f"x{i}") for i in range(n_inputs)]
    for c in range(draw(st.integers(0, 3))):
        refs.append(g.const(f"c{c}", draw(st.integers(-8, 8))))
    n_nodes = draw(st.integers(1, 20))
    for _ in range(n_nodes):
        op = draw(st.sampled_from(OPS))
        a = draw(st.sampled_from(refs))
        b = draw(st.sampled_from(refs))
        refs.append(g.add_node(op, a, b))
    for _ in range(draw(st.integers(1, 3))):
        g.output(draw(st.sampled_from(refs)))
    return g


@st.composite
def dfg_and_data(draw):
    g = draw(dfgs())
    batch = draw(st.integers(1, 17))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    data = {
        name: rng.integers(-9, 9, size=(batch,)).astype(np.int32)
        for name in g.inputs
        if name not in g.const_values
    }
    return g, data, batch


@settings(max_examples=60, deadline=None)
@given(dfg_and_data())
def test_three_paths_agree(case):
    g, data, batch = case
    ref = reference_eval(
        g,
        {**data, **{k: np.int32(v) for k, v in g.const_values.items()}},
    )
    ref = np.stack([np.broadcast_to(np.asarray(r), (batch,)) for r in ref])

    grid = for_dfg(g, shape="exact", data_bits=32)
    cfg = map_app(g, grid)

    x = pack_inputs(cfg, {k: jnp.asarray(v) for k, v in data.items()}, jnp.int32)

    conventional = np.asarray(make_overlay_fn(grid)(cfg.to_jax(), x))
    specialized = np.asarray(build_specialized_fn(grid, cfg)(x))
    baked = np.asarray(build_specialized_fn(grid, cfg, bake_consts=True)(x))

    np.testing.assert_array_equal(conventional, ref)
    np.testing.assert_array_equal(specialized, ref)
    np.testing.assert_array_equal(baked, ref)


@settings(max_examples=60, deadline=None)
@given(dfgs())
def test_exact_grid_always_fits_and_routes(g):
    grid = for_dfg(g, shape="exact")
    pl = place(g, grid)  # must not raise
    rt = route(pl, grid)
    for lvl, sel in enumerate(rt.sel):
        assert sel.min() >= 0 and sel.max() < grid.vc_in_width(lvl)
    # every level fully utilised by construction of shape='exact'
    for lvl, cells in enumerate(pl.cells):
        assert len(cells) == grid.pes_per_level[lvl]


# -- fused device-side ingest == host-side two-step path ----------------------

ALL_NAMES = sorted(apps.ALL_APPS)
_FUSED_GRID = shared_app_grid(ALL_NAMES, name="prop-fused")
_FUSED_OVERLAY = make_overlay_fn(_FUSED_GRID)
_FUSED_FLEET = PixieFleet(default_grid=_FUSED_GRID, batch_tile=4)


@st.composite
def fused_batches(draw):
    """A ragged multi-tenant batch: apps from the whole library, each on
    its own non-square frame."""
    n = draw(st.integers(1, 4))
    names = [draw(st.sampled_from(ALL_NAMES)) for _ in range(n)]
    hws = [
        (draw(st.integers(1, 13)), draw(st.integers(1, 13)))
        for _ in range(n)
    ]
    seed = draw(st.integers(0, 2**31 - 1))
    return names, hws, seed


@settings(max_examples=25, deadline=None)
@given(fused_batches())
def test_fused_ingest_bitwise_identical_to_two_step(case):
    """Fused line-buffer formation inside the batched dispatch must equal
    stencil_inputs + pack_inputs + overlay BITWISE for every library app,
    non-square frames, and ragged multi-tenant batches (zero canvas
    padding sliced back)."""
    names, hws, seed = case
    rng = np.random.default_rng(seed)
    images = [rng.integers(0, 256, hw).astype(np.int32) for hw in hws]
    outs = _FUSED_FLEET.run_many(
        [FleetRequest(app=n, image=i) for n, i in zip(names, images)]
    )
    for name, img, got in zip(names, images, outs):
        cfg = map_app(apps.ALL_APPS[name](), _FUSED_GRID)
        taps = apps.stencil_inputs(jnp.asarray(img))
        feed = {k: v for k, v in taps.items() if k in cfg.input_order}
        x = pad_channels(
            pack_inputs(cfg, feed, _FUSED_GRID.dtype), _FUSED_GRID.num_inputs
        )
        ref = np.asarray(_FUSED_OVERLAY(cfg.to_jax(), x))
        ref = ref.reshape((-1,) + img.shape)
        got = got if got.ndim == 3 else got[None]
        np.testing.assert_array_equal(got, ref)


@settings(max_examples=30, deadline=None)
@given(dfgs(), st.integers(0, 3))
def test_deeper_rect_grid_is_equivalent(g, extra_levels):
    """Mapping onto a deeper/wider grid (outputs buffered to the bottom)
    must not change semantics -- paper Sec. IV."""
    data = {
        name: np.arange(1, 6, dtype=np.int32)
        for name in g.inputs
        if name not in g.const_values
    }
    ref = reference_eval(
        g, {**data, **{k: np.int32(v) for k, v in g.const_values.items()}}
    )
    ref = np.stack([np.broadcast_to(np.asarray(r), (5,)) for r in ref])

    from repro.core.grid import custom
    from repro.core.place import level_demand

    demand = level_demand(g)
    # output values buffered through extra levels need one PE each
    widths = list(demand) + [max(len(g.outputs), 1)] * extra_levels
    widths = [w + 2 for w in widths]  # slack => NONE PEs in every level
    grid = custom("deep", len(g.inputs), widths, num_outputs=len(g.outputs))
    cfg = map_app(g, grid)
    x = pack_inputs(cfg, {k: jnp.asarray(v) for k, v in data.items()}, jnp.int32)
    out = np.asarray(build_specialized_fn(grid, cfg)(x))
    np.testing.assert_array_equal(out, ref)
