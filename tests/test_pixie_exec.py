"""Execution tests: conventional overlay == parameterized == numpy oracle,
for every library application, in fixed- and floating-point; compile-once
reconfiguration behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Pixie, for_dfg, map_app, sobel_grid
from repro.core import applications as apps
from repro.core.dfg import reference_eval
from repro.core.interpreter import make_overlay_fn, pack_inputs

APP_ORACLES = {
    "sobel_x": lambda img: apps.conv2d_reference(img, apps.SOBEL_X),
    "sobel_y": lambda img: apps.conv2d_reference(img, apps.SOBEL_Y),
    "sobel_mag": apps.sobel_magnitude_reference,
    "gauss3": lambda img: apps.conv2d_reference(img, apps.GAUSS3, divisor=16.0),
    "sharpen": lambda img: apps.conv2d_reference(img, apps.SHARPEN),
    "laplace": lambda img: apps.conv2d_reference(img, apps.LAPLACE),
    "box3": lambda img: apps.conv2d_reference(img, apps.BOX3, divisor=9.0),
    "threshold": lambda img: (img > 128).astype(img.dtype),
    "identity": lambda img: img,
}


@pytest.mark.parametrize("app_name", sorted(apps.ALL_APPS))
@pytest.mark.parametrize("mode", ["conventional", "parameterized"])
def test_app_matches_oracle_fixed_point(app_name, mode, rng):
    img = rng.integers(0, 256, (12, 17)).astype(np.int32)
    dfg = apps.ALL_APPS[app_name]()
    grid = for_dfg(dfg, shape="exact", data_bits=32)
    pix = Pixie(grid, mode=mode)
    pix.load(map_app(dfg, grid), batch=img.size)
    out = np.asarray(pix.run_image(jnp.asarray(img)))
    ref = APP_ORACLES[app_name](img)
    np.testing.assert_array_equal(out, ref)


@pytest.mark.parametrize("app_name", ["sobel_mag", "gauss3", "threshold"])
@pytest.mark.parametrize("mode", ["conventional", "parameterized"])
def test_app_matches_oracle_float(app_name, mode, rng):
    img = rng.random((9, 11)).astype(np.float32) * 255.0
    dfg = apps.ALL_APPS[app_name]()
    grid = for_dfg(dfg, shape="exact", data_bits=32, float_pe=True)
    pix = Pixie(grid, mode=mode)
    pix.load(map_app(dfg, grid), batch=img.size)
    out = np.asarray(pix.run_image(jnp.asarray(img)))
    ref = APP_ORACLES[app_name](img)
    np.testing.assert_allclose(out, ref, rtol=1e-6, atol=1e-4)


def test_rect_grid_with_none_pes_still_correct(rng):
    """Fig. 5 style: map Sobel on the rectangular 45-PE grid (25 NONE PEs)."""
    img = rng.integers(0, 256, (8, 9)).astype(np.int32)
    dfg = apps.sobel_x()
    grid = sobel_grid()
    pix = Pixie(grid, mode="conventional")
    pix.load(map_app(dfg, grid))
    out = np.asarray(pix.run_image(jnp.asarray(img)))
    np.testing.assert_array_equal(out, apps.conv2d_reference(img, apps.SOBEL_X))


def test_conventional_reconfig_does_not_recompile(rng):
    """The overlay's central claim: swapping the application = swapping
    settings arrays; the jitted interpreter executable is reused."""
    img = rng.integers(0, 256, (10, 10)).astype(np.int32)
    dfg_a, dfg_b = apps.sobel_x(), apps.sobel_y()
    grid = sobel_grid()
    pix = Pixie(grid, mode="conventional")
    pix.compile_overlay(batch=img.size)
    pix.load(map_app(dfg_a, grid))
    out_a = np.asarray(pix.run_image(jnp.asarray(img)))
    n_compiles_after_first = pix._overlay_fn._cache_size()
    pix.load(map_app(dfg_b, grid))
    out_b = np.asarray(pix.run_image(jnp.asarray(img)))
    assert pix._overlay_fn._cache_size() == n_compiles_after_first
    np.testing.assert_array_equal(out_a, apps.conv2d_reference(img, apps.SOBEL_X))
    np.testing.assert_array_equal(out_b, apps.conv2d_reference(img, apps.SOBEL_Y))


def test_multiple_graph_instances_on_one_grid(rng):
    """Paper Sec. III: 'If the grid is big enough, multiple instances of
    the same graph can be implemented' -- sobel_mag runs two convolution
    trees on one grid."""
    img = rng.integers(0, 256, (6, 7)).astype(np.int32)
    dfg = apps.sobel_magnitude()
    grid = for_dfg(dfg, shape="rect")  # one rectangular grid, both trees
    pix = Pixie(grid, mode="parameterized")
    pix.load(map_app(dfg, grid), batch=img.size)
    out = np.asarray(pix.run_image(jnp.asarray(img)))
    np.testing.assert_array_equal(out, apps.sobel_magnitude_reference(img))


def test_bake_consts_specialization(rng):
    """Second-level specialization: coefficients burned into the datapath."""
    img = rng.integers(0, 256, (5, 8)).astype(np.int32)
    dfg = apps.sobel_x()
    grid = for_dfg(dfg, shape="exact")
    pix = Pixie(grid, mode="parameterized", bake_consts=True)
    pix.load(map_app(dfg, grid), batch=img.size)
    out = np.asarray(pix.run_image(jnp.asarray(img)))
    np.testing.assert_array_equal(out, apps.conv2d_reference(img, apps.SOBEL_X))


def test_pack_inputs_const_defaults(rng):
    dfg = apps.sobel_x()
    grid = for_dfg(dfg, shape="exact")
    cfg = map_app(dfg, grid)
    taps = apps.stencil_inputs(jnp.ones((4, 4), jnp.int32))
    x = pack_inputs(cfg, taps, jnp.int32)
    assert x.shape == (len(cfg.input_order), 16)
    # coefficient rows carry their const defaults
    for i, name in enumerate(cfg.input_order):
        if name in cfg.const_values:
            assert np.all(np.asarray(x[i]) == cfg.const_values[name])


def test_missing_input_raises(rng):
    dfg = apps.sobel_x()
    grid = for_dfg(dfg, shape="exact")
    pix = Pixie(grid, mode="conventional")
    pix.load(map_app(dfg, grid))
    with pytest.raises(KeyError):
        pix(p00=jnp.zeros((4,), jnp.int32))  # taps missing

    fresh = Pixie(grid, mode="conventional")
    with pytest.raises(RuntimeError, match="no application loaded"):
        fresh(p00=jnp.zeros((4,), jnp.int32))


def test_reference_eval_agrees_with_overlay_on_raw_graph(rng):
    dfg = apps.laplace()
    grid = for_dfg(dfg, shape="exact")
    cfg = map_app(dfg, grid)
    img = rng.integers(0, 64, (6, 6)).astype(np.int32)
    taps = {k: np.asarray(v) for k, v in apps.stencil_inputs(jnp.asarray(img)).items()}
    feed = {k: taps[k] for k in dfg.inputs if k in taps}
    (ref_out,) = reference_eval(dfg, feed)
    pix = Pixie(grid, mode="conventional")
    pix.load(cfg)
    out = np.asarray(pix(**feed))[0]
    np.testing.assert_array_equal(out, ref_out)
