"""Property-based tests (hypothesis): 2-D (app x rows) mesh-sharded fused
dispatch must be bitwise identical to the single-device run for *random*
``(H, W, radius, app, rows)`` -- including rows that do not divide H,
bands shorter than the radius, and radius-0 (no halo exchange at all).

The deterministic edge-case matrix twin lives in test_mesh2d.py and runs
even without the dev dependency.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# Gate rather than hard-import: hypothesis is a dev dependency
# (requirements-dev.txt), absent from minimal runtime installs.
pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (see requirements-dev.txt)"
)
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import MeshSpec, OverlayPlan, compile_plan, map_app, sobel_grid  # noqa: E402
from repro.core import applications as apps  # noqa: E402
from repro.core.bitstream import VCGRAConfig  # noqa: E402
from repro.core.ingest import IngestPlan  # noqa: E402

GRID = sobel_grid()
N_DEVICES = len(jax.local_devices())
needs_two_devices = pytest.mark.skipif(
    N_DEVICES < 2, reason="needs >= 2 local devices"
)
# Mapped settings are shape-independent; build them once for the sweep.
_CONFIGS = None


def _workload(H, W, seed):
    global _CONFIGS
    if _CONFIGS is None:
        configs = [map_app(apps.ALL_APPS[n](), GRID)
                   for n in ("sobel_x", "threshold")]
        _CONFIGS = (VCGRAConfig.stack(configs),
                    IngestPlan.stack([c.ingest for c in configs], GRID.dtype))
    rng = np.random.default_rng(seed)
    canvas = rng.integers(0, 256, (2, H, W)).astype(np.int32)
    return _CONFIGS[0], _CONFIGS[1], jnp.asarray(canvas)


@st.composite
def mesh_cases(draw):
    """Random (H, W, radius, app, rows, seed), capped to the host's
    device budget; covers rows not dividing H, H < rows bands, and
    radius-0 layouts by construction of the ranges."""
    H = draw(st.integers(2, 20))
    W = draw(st.integers(2, 20))
    radius = draw(st.integers(1, 2))
    app = draw(st.integers(1, 2))
    rows = draw(st.integers(1, max(1, N_DEVICES // app)))
    seed = draw(st.integers(0, 2**31 - 1))
    return H, W, radius, app, rows, seed


@needs_two_devices
@settings(max_examples=15, deadline=None)
@given(mesh_cases())
def test_property_2d_parity(case):
    H, W, radius, app, rows, seed = case
    stacked, ingests, canvas = _workload(H, W, seed)
    outs = []
    for spec in (MeshSpec(), MeshSpec(app=app, rows=rows)):
        plan = OverlayPlan(grid=GRID, batched=True, fused=True,
                           radius=radius, mesh=spec)
        outs.append(np.asarray(compile_plan(plan)(stacked, ingests, canvas)))
    np.testing.assert_array_equal(outs[0], outs[1])
