"""Per-architecture smoke tests: REDUCED same-family configs, one forward
+ one train step on CPU, asserting output shapes and finiteness (the FULL
configs are exercised only via the dry-run, per the brief)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, param_count, reduced
from repro.models import LM
from repro.optim import AdamWConfig, init_opt_state
from repro.train import train_step

# Long-running suite: excluded from tier-1 (-m "not slow"), run nightly.
pytestmark = pytest.mark.slow

ALL = sorted(ARCHS)


def _inputs(cfg, rng, B=2, S=32):
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)))
    pe = None
    if cfg.modality == "vision_stub":
        pe = jnp.asarray(
            rng.standard_normal((B, cfg.prefix_tokens, cfg.d_model)).astype(np.float32)
            * 0.02
        )
    return tokens, pe


@pytest.mark.parametrize("name", ALL)
def test_forward_shapes_and_finite(name, rng):
    cfg = reduced(ARCHS[name])
    lm = LM(cfg, remat="none", chunk_q=16, loss_chunk=16)
    params = lm.init(jax.random.PRNGKey(0))
    tokens, pe = _inputs(cfg, rng)
    h, aux, n_prefix = lm.forward(params, tokens, pe)
    B, S = tokens.shape
    assert h.shape == (B, S + n_prefix, cfg.d_model)
    assert bool(jnp.isfinite(h).all())
    assert n_prefix == cfg.prefix_tokens + cfg.meta_tokens


@pytest.mark.parametrize("name", ALL)
def test_one_train_step_improves_nothing_breaks(name, rng):
    cfg = reduced(ARCHS[name])
    lm = LM(cfg, remat="none", chunk_q=16, loss_chunk=16)
    params = lm.init(jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    tokens, pe = _inputs(cfg, rng)
    p2, o2, m = train_step(lm, AdamWConfig(lr=1e-3, warmup_steps=0), params, opt, tokens, pe)
    assert bool(jnp.isfinite(m["loss"]))
    assert bool(jnp.isfinite(m["grad_norm"]))
    assert float(m["grad_norm"]) > 0.0
    # params actually moved
    moved = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), params, p2
    )
    assert max(jax.tree_util.tree_leaves(moved)) > 0.0
    # second step with updated params: loss finite again (stability)
    _, _, m2 = train_step(lm, AdamWConfig(lr=1e-3, warmup_steps=0), p2, o2, tokens, pe)
    assert bool(jnp.isfinite(m2["loss"]))


@pytest.mark.parametrize("name", ALL)
def test_remat_matches_no_remat(name, rng):
    cfg = reduced(ARCHS[name])
    tokens, pe = _inputs(cfg, rng)
    lm0 = LM(cfg, remat="none", chunk_q=16, loss_chunk=16)
    lm1 = LM(cfg, remat="full", chunk_q=16, loss_chunk=16)
    params = lm0.init(jax.random.PRNGKey(0))
    l0, _ = lm0.loss(params, tokens, pe)
    l1, _ = lm1.loss(params, tokens, pe)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-5)


@pytest.mark.parametrize("name", ALL)
def test_full_config_param_count_estimate(name):
    """Closed-form param estimate (used for MODEL_FLOPS) vs real init --
    validated on the reduced config where init is affordable."""
    cfg = reduced(ARCHS[name])
    lm = LM(cfg)
    params = jax.eval_shape(lambda: lm.init(jax.random.PRNGKey(0)))
    real = sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(params))
    est = param_count(cfg)["total"]
    # estimate intentionally coarse for ssm/hybrid blocks: keep within 40%
    tol = 0.4 if cfg.family in ("ssm", "hybrid") else 0.15
    assert abs(est - real) / real < tol, (est, real)


def test_full_configs_match_assignment():
    """The exact assigned hyperparameters are encoded."""
    a = ARCHS
    ds = a["deepseek-moe-16b"]
    assert (ds.num_layers, ds.d_model, ds.num_heads, ds.d_ff, ds.vocab_size) == (
        28, 2048, 16, 1408, 102400)
    assert (ds.moe.num_experts, ds.moe.top_k, ds.moe.num_shared) == (64, 6, 2)
    qw = a["qwen2-moe-a2.7b"]
    assert (qw.num_layers, qw.vocab_size, qw.moe.num_experts, qw.moe.top_k,
            qw.moe.num_shared) == (24, 151936, 60, 4, 4)
    pg = a["paligemma-3b"]
    assert (pg.num_layers, pg.d_model, pg.num_heads, pg.num_kv_heads,
            pg.d_ff, pg.vocab_size) == (18, 2048, 8, 1, 16384, 257216)
    g2 = a["gemma-2b"]
    assert (g2.num_layers, g2.num_kv_heads, g2.head_dim, g2.vocab_size) == (
        18, 1, 256, 256000)
    sc = a["starcoder2-7b"]
    assert (sc.num_layers, sc.d_model, sc.num_heads, sc.num_kv_heads,
            sc.d_ff, sc.vocab_size) == (32, 4608, 36, 4, 18432, 49152)
    gl = a["glm4-9b"]
    assert (gl.num_layers, gl.d_model, gl.num_heads, gl.num_kv_heads,
            gl.d_ff, gl.vocab_size) == (40, 4096, 32, 2, 13696, 151552)
    g3 = a["gemma3-12b"]
    assert (g3.num_layers, g3.d_model, g3.num_heads, g3.num_kv_heads,
            g3.d_ff, g3.vocab_size) == (48, 3840, 16, 8, 15360, 262144)
    assert g3.pattern.count("local") == 5 and g3.pattern.count("global") == 1
    mg = a["musicgen-medium"]
    assert (mg.num_layers, mg.d_model, mg.num_heads, mg.d_ff, mg.vocab_size) == (
        48, 1536, 24, 6144, 2048)
    xl = a["xlstm-1.3b"]
    assert (xl.num_layers, xl.d_model, xl.vocab_size, xl.d_ff) == (
        48, 2048, 50304, 0)
    assert "slstm" in xl.pattern and "mlstm" in xl.pattern
    hy = a["hymba-1.5b"]
    assert (hy.num_layers, hy.d_model, hy.num_heads, hy.num_kv_heads,
            hy.d_ff, hy.vocab_size, hy.ssm.state_dim) == (
        32, 1600, 25, 5, 5504, 32001, 16)
