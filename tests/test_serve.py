"""Serving tests: engine generation, slot server continuous batching,
decode==prefill consistency at the engine level."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.models import LM
from repro.serve import ServeConfig, ServeEngine, SlotServer

# Long-running suite: excluded from tier-1 (-m "not slow"), run nightly.
pytestmark = pytest.mark.slow


def _lm(name="gemma-2b"):
    cfg = reduced(ARCHS[name])
    lm = LM(cfg, remat="none", chunk_q=16, loss_chunk=16)
    params = lm.init(jax.random.PRNGKey(0))
    return cfg, lm, params


def test_engine_greedy_deterministic(rng):
    cfg, lm, params = _lm()
    eng = ServeEngine(lm, params, ServeConfig(max_batch=2, max_seq=64))
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 8)))
    out1 = eng.generate(prompts, 6)
    out2 = eng.generate(prompts, 6)
    np.testing.assert_array_equal(out1, out2)
    assert out1.shape == (2, 6)
    assert (out1 >= 0).all() and (out1 < cfg.vocab_size).all()


def test_engine_matches_stepwise_prefill(rng):
    """Engine's decode chain == repeated prefill from scratch (greedy)."""
    cfg, lm, params = _lm()
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 8)))
    eng = ServeEngine(lm, params, ServeConfig(max_batch=1, max_seq=64))
    gen = eng.generate(prompts, 4)[0]

    seq = np.asarray(prompts[0]).tolist()
    for t in range(4):
        logits, _, _ = lm.prefill(params, jnp.asarray([seq]), cache_len=64)
        nxt = int(jnp.argmax(logits[0]))
        assert nxt == int(gen[t]), f"divergence at step {t}"
        seq.append(nxt)


def test_engine_temperature_sampling_seeded(rng):
    cfg, lm, params = _lm()
    eng = ServeEngine(
        lm, params, ServeConfig(max_batch=2, max_seq=64, temperature=1.0, seed=7)
    )
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 8)))
    out1 = eng.generate(prompts, 5)
    out2 = eng.generate(prompts, 5)
    np.testing.assert_array_equal(out1, out2)  # same seed => same samples


def test_slot_server_matches_engine(rng):
    cfg, lm, params = _lm()
    prompts = rng.integers(0, cfg.vocab_size, (2, 8))
    eng = ServeEngine(lm, params, ServeConfig(max_batch=2, max_seq=64))
    ref = eng.generate(jnp.asarray(prompts), 4)

    srv = SlotServer(lm, params, ServeConfig(max_batch=2, max_seq=64))
    srv.add_request(0, prompts[0])
    srv.add_request(1, prompts[1])
    for _ in range(3):
        srv.tick()
    out0 = srv.finish(0)
    out1 = srv.finish(1)
    np.testing.assert_array_equal(np.asarray(out0), ref[0])
    np.testing.assert_array_equal(np.asarray(out1), ref[1])


def test_slot_server_staggered_requests(rng):
    """Second request arrives mid-decode of the first; both must produce
    the same tokens as isolated generation."""
    cfg, lm, params = _lm()
    prompts = rng.integers(0, cfg.vocab_size, (2, 8))
    eng = ServeEngine(lm, params, ServeConfig(max_batch=1, max_seq=64))
    ref0 = eng.generate(jnp.asarray(prompts[0:1]), 5)[0]
    ref1 = eng.generate(jnp.asarray(prompts[1:2]), 3)[0]

    srv = SlotServer(lm, params, ServeConfig(max_batch=2, max_seq=64))
    srv.add_request(0, prompts[0])
    srv.tick()
    srv.tick()
    srv.add_request(1, prompts[1])   # joins after 2 ticks
    srv.tick()
    srv.tick()
    out0 = srv.finish(0)             # 1 prefill + 4 ticks = 5 tokens
    out1 = srv.finish(1)             # 1 prefill + 2 ticks = 3 tokens
    np.testing.assert_array_equal(np.asarray(out0), np.asarray(ref0))
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(ref1))


@pytest.mark.parametrize("name", ["gemma3-12b", "hymba-1.5b", "xlstm-1.3b"])
def test_engine_subquadratic_archs(name, rng):
    """Ring-cache / state-cache archs generate without error."""
    cfg, lm, params = _lm(name)
    eng = ServeEngine(
        lm, params,
        ServeConfig(max_batch=2, max_seq=64 + cfg.meta_tokens),
    )
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 8)))
    out = eng.generate(prompts, 4)
    assert out.shape == (2, 4)
