"""Checkpointer tests: atomicity, async, GC, torn-checkpoint fallback."""

import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer


def _tree(x=1.0):
    return {
        "params": {"w": jnp.full((4, 4), x), "b": jnp.full((4,), 2 * x)},
        "opt": {"m": {"w": jnp.zeros((4, 4)), "b": jnp.zeros((4,))},
                "count": jnp.asarray(7, jnp.int32)},
    }


def test_save_restore_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    t = _tree(3.0)
    ck.save(10, t)
    step, t2 = ck.restore_latest(jax.tree_util.tree_map(np.asarray, t))
    assert step == 10
    for a, b in zip(jax.tree_util.tree_leaves(t), jax.tree_util.tree_leaves(t2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _tree(1.0), blocking=False)
    ck.wait()
    assert ck.committed_steps() == [1]


def test_gc_keeps_newest(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _tree(float(s)))
    assert ck.committed_steps() == [3, 4]


def test_torn_checkpoint_ignored_and_fallback(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(5, _tree(5.0))
    ck.save(6, _tree(6.0))
    # corrupt the newest: truncate arrays file
    with open(os.path.join(str(tmp_path), "step_6", "arrays.npz"), "wb") as f:
        f.write(b"garbage")
    step, t = ck.restore_latest(_tree())
    assert step == 5
    assert float(np.asarray(t["params"]["w"]).reshape(-1)[0]) == 5.0


def test_tmp_dir_is_not_a_checkpoint(tmp_path):
    ck = Checkpointer(str(tmp_path))
    os.makedirs(os.path.join(str(tmp_path), "step_9.tmp"))
    assert ck.committed_steps() == []
    assert ck.cleanup_tmp() == 1
    step, t = ck.restore_latest(_tree())
    assert step is None and t is None


def test_restore_mismatched_structure_raises(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _tree())
    with pytest.raises(ValueError, match="leaves"):
        ck.restore(1, {"just_one": np.zeros((2,))})
