"""Unit tests: DFG IR, ASAP levelization, reference oracle, synthesis."""

import numpy as np
import pytest

from repro.core import DFG, Op, reference_eval, synthesize, SOBEL_SOURCE
from repro.core import applications as apps
from repro.core.synthesis import SynthesisError


def test_builder_and_levels():
    g = DFG("t")
    x, y = g.input("x"), g.input("y")
    m = g.mul(x, y)          # level 0
    s = g.add(m, x)          # level 1 (x buffered by mapper later)
    g.output(s)
    g.validate()
    assert g.asap_levels() == [0, 1]
    assert g.depth() == 2
    assert g.op_histogram() == {"MUL": 1, "ADD": 1}


def test_builder_rejects_bad_refs():
    g = DFG("t")
    x = g.input("x")
    with pytest.raises(ValueError):
        g.add(x, None)  # binary op needs two operands
    g2 = DFG("t2")
    with pytest.raises(ValueError):
        g2.add_node(Op.ADD, x, x)  # x belongs to another graph
    with pytest.raises(ValueError):
        g.input("x")  # duplicate
    with pytest.raises(ValueError):
        g.add_node(Op.MAC, x, x)  # MAC not schedulable (paper Sec III-A)


def test_validate_requires_outputs():
    g = DFG("t")
    g.input("x")
    with pytest.raises(ValueError):
        g.validate()


def test_reference_eval_basic():
    g = DFG("t")
    x, y = g.input("x"), g.input("y")
    g.output(g.add(g.mul(x, x), y))
    (out,) = reference_eval(g, {"x": np.array([1, 2, 3]), "y": np.array([10, 10, 10])})
    assert (out == np.array([11, 14, 19])).all()


def test_reference_eval_div_guard():
    g = DFG("t")
    x, y = g.input("x"), g.input("y")
    g.output(g.div(x, y))
    (out,) = reference_eval(g, {"x": np.array([7, 8]), "y": np.array([2, 0])})
    assert (out == np.array([3, 0])).all()


def test_const_inputs_defaulted():
    g = DFG("t")
    x = g.input("x")
    k = g.const("k", 3.0)
    g.output(g.mul(x, k))
    (out,) = reference_eval(g, {"x": np.array([1.0, 2.0])})
    assert (out == np.array([3.0, 6.0])).all()


def test_sobel_graph_matches_paper_shape():
    g = apps.sobel_x()
    # 9 muls + 8 adds, depth 5 => fits the 45-PE 5x9 grid of Fig. 5
    assert g.num_ops() == 17
    assert g.depth() == 5
    assert g.op_histogram() == {"MUL": 9, "ADD": 8}


def test_synthesis_sobel_equals_reference():
    g = synthesize("s", SOBEL_SOURCE)
    img = np.arange(25, dtype=np.int32).reshape(5, 5)
    taps = {k: np.asarray(v) for k, v in apps.stencil_inputs(img).items()}
    feed = {k: taps[k] for k in g.inputs if k in taps}
    (out,) = reference_eval(g, feed)
    ref = apps.sobel_magnitude_reference(img).reshape(-1)
    assert (out == ref).all()


def test_synthesis_rejects_garbage():
    with pytest.raises(SynthesisError):
        synthesize("bad", "out = foo(x)")
    with pytest.raises(SynthesisError):
        synthesize("bad", "out = x ** 2")
    with pytest.raises(SynthesisError):
        synthesize("bad", "for i in x: pass")


def test_synthesis_unary_minus_and_compare():
    g = synthesize("t", "out = (-x > y) + (x == y)")
    (out,) = reference_eval(g, {"x": np.array([-5, 2]), "y": np.array([1, 2])})
    assert (out == np.array([1, 1])).all()
