"""Fault-tolerance tests: crash-restart, straggler detection, elastic plan."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.runtime import ElasticPlan, HeartbeatMonitor, resume_or_init


def _init():
    return {"params": {"w": jnp.zeros((2, 2))}, "opt": {"count": jnp.asarray(0)}}


def test_resume_fresh_run(tmp_path):
    ck = Checkpointer(str(tmp_path))
    st = resume_or_init(ck, _init)
    assert st.step == 0 and not st.resumed


def test_resume_after_crash(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = _init()
    tree["params"]["w"] = jnp.full((2, 2), 9.0)
    ck.save(42, tree)
    # simulate crash mid-write of the next checkpoint
    import os
    os.makedirs(str(tmp_path) + "/step_43.tmp")
    st = resume_or_init(ck, _init)
    assert st.resumed and st.step == 42
    assert float(st.tree["params"]["w"][0, 0]) == 9.0
    # and the torn tmp dir was cleaned
    assert not os.path.exists(str(tmp_path) + "/step_43.tmp")


def test_straggler_detection():
    mon = HeartbeatMonitor(window=16, factor=3.0)
    for s in range(10):
        assert not mon.record(s, 1.0)
    assert mon.record(10, 10.0)       # 10x the median -> straggler
    assert mon.stragglers[-1][0] == 10
    assert not mon.record(11, 1.1)


def test_straggler_needs_history():
    mon = HeartbeatMonitor()
    assert not mon.record(0, 100.0)   # no baseline yet -> not flagged


def test_heartbeat_timer():
    mon = HeartbeatMonitor()
    mon.start()
    dt = mon.stop(0)
    assert dt >= 0.0
    assert len(mon.durations) == 1


# ElasticPlan is deprecated (PR 10): constructing one warns, pointing at
# core.plan.fallback_chain / MeshSpec degradation.  The math stays tested
# until the class is removed.


def test_elastic_plan_shrinks_data_axis():
    with pytest.warns(DeprecationWarning, match="fallback_chain"):
        ep = ElasticPlan(old_shape=(16, 16), new_devices=192,
                         axis_names=("data", "model"))
    assert ep.plan() == (12, 16)
    assert ep.can_restore()


def test_elastic_plan_multipod_folds_pods():
    with pytest.warns(DeprecationWarning):
        ep = ElasticPlan(
            old_shape=(2, 16, 16), new_devices=256 + 128,
            axis_names=("pod", "data", "model"),
        )
    pods, data, model = ep.plan()
    assert model == 16 and pods * data * model <= 384


def test_elastic_plan_impossible_below_tp():
    with pytest.warns(DeprecationWarning):
        ep = ElasticPlan(old_shape=(16, 16), new_devices=8,
                         axis_names=("data", "model"))
    assert ep.plan() is None
    assert not ep.can_restore()
