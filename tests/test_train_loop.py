"""Training-loop integration: loss goes down, checkpoints resume exactly,
straggler hook fires, grad compression composes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.data import TokenPipeline
from repro.models import LM
from repro.optim import AdamWConfig, init_opt_state, init_error_state
from repro.train import LoopConfig, train_loop, train_step

# Long-running suite: excluded from tier-1 (-m "not slow"), run nightly.
pytestmark = pytest.mark.slow


def _setup(vocab=256):
    cfg = reduced(ARCHS["gemma-2b"])
    lm = LM(cfg, remat="none", chunk_q=16, loss_chunk=16)
    pipe = TokenPipeline(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4)
    return cfg, lm, pipe


def test_loss_decreases_over_short_run():
    """Memorisation check: repeated batch => CE must fall materially."""
    cfg, lm, pipe = _setup()
    params = lm.init(jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    tokens = jnp.asarray(pipe.batch_at(0))
    ocfg = AdamWConfig(lr=3e-3, warmup_steps=0, schedule="constant")
    losses = []
    for _ in range(20):
        params, opt, m = train_step(lm, ocfg, params, opt, tokens)
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0] - 0.1, losses


def test_checkpoint_resume_is_exact(tmp_path):
    """Run 10 steps straight vs 5 + crash + resume 5: identical final loss."""
    cfg, lm, pipe = _setup()
    opt = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10, schedule="constant")

    h_full = train_loop(
        lm, LoopConfig(steps=10, log_every=0), opt, pipe,
    )

    d = str(tmp_path / "ck")
    train_loop(lm, LoopConfig(steps=5, ckpt_every=5, ckpt_dir=d, log_every=0),
               opt, pipe)
    h_resumed = train_loop(
        lm, LoopConfig(steps=10, ckpt_every=5, ckpt_dir=d, log_every=0),
        opt, pipe,
    )
    # resumed run starts at step 5 and must match the straight run exactly
    np.testing.assert_allclose(
        h_resumed["loss"], h_full["loss"][5:], rtol=1e-5
    )


def test_straggler_hook_called():
    cfg, lm, pipe = _setup()
    calls = []

    # monkeypatch the monitor to treat every step as slow after a baseline
    from repro.runtime import HeartbeatMonitor

    class Spiky(HeartbeatMonitor):
        def stop(self, step):
            dt = super().stop(step)
            if step == 9:
                self.record(step, dt * 100)  # inject a spike
            return dt

    import repro.train.loop as loop_mod

    orig = loop_mod.HeartbeatMonitor
    loop_mod.HeartbeatMonitor = Spiky
    try:
        train_loop(
            lm,
            LoopConfig(steps=12, log_every=0,
                       straggler_hook=lambda s, dt: calls.append(s)),
            AdamWConfig(lr=1e-3, warmup_steps=0), pipe,
        )
    finally:
        loop_mod.HeartbeatMonitor = orig
    assert calls, "straggler hook never fired"


def test_grad_compression_step_trains():
    cfg, lm, pipe = _setup()
    params = lm.init(jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    err = init_error_state(params)
    tokens = jnp.asarray(pipe.batch_at(0))
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=0)
    p2, o2, err2, m = train_step(
        lm, ocfg, params, opt, tokens, grad_compress=True, err_state=err
    )
    assert bool(jnp.isfinite(m["loss"]))
    # error state now nonzero (quantisation residual carried)
    assert max(
        float(jnp.abs(l).max()) for l in jax.tree_util.tree_leaves(err2)
    ) > 0.0


def test_determinism_same_seed():
    cfg, lm, pipe = _setup()
    opt = AdamWConfig(lr=1e-3, warmup_steps=0, schedule="constant")
    h1 = train_loop(lm, LoopConfig(steps=5, log_every=0), opt, pipe)
    h2 = train_loop(lm, LoopConfig(steps=5, log_every=0), opt, pipe)
    np.testing.assert_allclose(h1["loss"], h2["loss"], rtol=1e-6)
