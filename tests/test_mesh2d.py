"""2-D (app x rows) mesh scale-out: MeshSpec API + halo-exchange parity.

The row axis shards a fused frame into contiguous pixel-row bands; the
radius-wide seam halo is exchanged with ``jax.lax.ppermute`` inside
``shard_map`` (``parallel.axes.shard_apps_rows``) and the unchanged
per-shard executor runs on the haloed band as if it were a short frame,
so every sharded output must be BITWISE equal to the single-device run.
The parity matrix here covers ragged, non-square, mixed-app stacks for
``backend=xla|pallas`` x ``ingest=sync|async``, rows that do not divide
the padded tile height, and radius 0 (no collective emitted -- asserted
on the jaxpr).  CI's mesh2d-parity job forces four host devices with
``XLA_FLAGS=--xla_force_host_platform_device_count=4``; on fewer devices
the mesh tests skip and the MeshSpec API tests still run.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    MeshSpec, OverlayPlan, Pixie, compile_plan, map_app, sobel_grid,
)
from repro.core import applications as apps
from repro.core.bitstream import VCGRAConfig
from repro.core.ingest import IngestPlan
from repro.core.tiling import row_band
from repro.parallel.axes import build_mesh, halo_exchange_rows
from repro.runtime.fleet import FleetRequest, PixieFleet
from repro.serve import FleetFrontend, StreamingFrontend

GRID = sobel_grid()
N_DEVICES = len(jax.local_devices())
needs_two_devices = pytest.mark.skipif(
    N_DEVICES < 2, reason="needs >= 2 local devices"
)
needs_four_devices = pytest.mark.skipif(
    N_DEVICES < 4,
    reason="needs >= 4 local devices (CI mesh2d-parity job forces 4 via "
    "XLA_FLAGS=--xla_force_host_platform_device_count=4)",
)

# Ragged, non-square, mixed-app: the canonical 2-D parity workload.
NAMES = ("sobel_x", "threshold", "sobel_y", "identity")
HWS = ((13, 17), (8, 8), (21, 9), (5, 30))


def _stacked_workload(rng, names=NAMES, hws=HWS):
    images = [rng.integers(0, 256, hw).astype(np.int32) for hw in hws]
    configs = [map_app(apps.ALL_APPS[n](), GRID) for n in names]
    Hb, Wb = max(h for h, _ in hws), max(w for _, w in hws)
    canvas = np.zeros((len(names), Hb, Wb), dtype=np.int32)
    for i, img in enumerate(images):
        canvas[i, : img.shape[0], : img.shape[1]] = img
    return (
        VCGRAConfig.stack(configs),
        IngestPlan.stack([c.ingest for c in configs], GRID.dtype),
        jnp.asarray(canvas),
    )


# -- MeshSpec API -------------------------------------------------------------


def test_meshspec_validation_and_identity():
    assert MeshSpec() == MeshSpec(app=1, rows=1)
    assert MeshSpec(app=2, rows=3).size == 6
    assert MeshSpec(app=2, rows=3).shape() == (2, 3)
    assert MeshSpec(app=2, rows=3).app_only() == MeshSpec(app=2)
    assert str(MeshSpec(app=2, rows=3)) == "2x3"
    # frozen + hashable: usable directly as a cache-key component
    assert len({MeshSpec(), MeshSpec(app=1), MeshSpec(rows=2)}) == 2
    with pytest.raises(ValueError, match="app"):
        MeshSpec(app=0)
    with pytest.raises(ValueError, match="rows"):
        MeshSpec(rows=-1)
    with pytest.raises(ValueError, match="rows"):
        MeshSpec(rows=True)
    with pytest.raises(Exception):
        MeshSpec(app=2).app = 3  # frozen


def test_row_band_floors():
    assert row_band(16, 4) == 4
    assert row_band(13, 4) == 4          # ceil
    assert row_band(2, 4) == 1           # H < rows still gives bands
    assert row_band(16, 4, radius=7) == 7  # radius floor: one-hop halo
    assert row_band(1, 1) == 1


def test_plan_key_backward_compat_and_cache_identity():
    """MeshSpec(app=k) keys exactly like the pre-2-D device count: old
    dev2 executable populations are reused, and the deprecated spelling
    IS the new plan (one hash, one LRU entry)."""
    via_mesh = OverlayPlan(grid=GRID, batched=True, fused=True,
                           mesh=MeshSpec(app=2))
    with pytest.warns(DeprecationWarning, match="MeshSpec"):
        via_devices = OverlayPlan(grid=GRID, batched=True, fused=True,
                                  devices=2)
    assert via_mesh == via_devices
    assert hash(via_mesh) == hash(via_devices)
    assert via_mesh.key() == via_devices.key()
    assert "dev2" in via_mesh.key() and "rows" not in via_mesh.key()
    # the rows axis is a NEW key segment, appended only when active
    plan2d = OverlayPlan(grid=GRID, batched=True, fused=True,
                         mesh=MeshSpec(app=2, rows=2))
    assert "dev2" in plan2d.key() and "rows2" in plan2d.key()
    assert plan2d != via_mesh


def test_plan_mesh_validation():
    with pytest.raises(ValueError, match="MeshSpec"):
        OverlayPlan(grid=GRID, batched=True, mesh=2)
    with pytest.raises(ValueError, match="batched"):
        OverlayPlan(grid=GRID, mesh=MeshSpec(app=2))
    with pytest.raises(ValueError, match="fused"):
        OverlayPlan(grid=GRID, batched=True, fused=False,
                    mesh=MeshSpec(rows=2))
    with pytest.raises(ValueError, match="not both"):
        OverlayPlan(grid=GRID, batched=True, mesh=MeshSpec(app=2), devices=2)


def test_deprecated_devices_shims_warn_everywhere():
    with pytest.warns(DeprecationWarning, match="MeshSpec"):
        fleet = PixieFleet(default_grid=GRID, devices=1)
    assert fleet.mesh == MeshSpec()
    with pytest.warns(DeprecationWarning, match="MeshSpec"):
        pix = Pixie(GRID, devices=1)
    assert pix.devices == 1 and pix.mesh == MeshSpec()
    with pytest.warns(DeprecationWarning, match="MeshSpec"):
        svc = FleetFrontend(devices=1)
    assert svc.devices == 1 and svc.mesh == MeshSpec()
    with pytest.raises(ValueError, match="not both"):
        PixieFleet(default_grid=GRID, mesh=MeshSpec(), devices=1)
    with pytest.raises(ValueError, match="rows"):
        Pixie(GRID, mesh=MeshSpec(rows=2))


# -- halo exchange ------------------------------------------------------------


def test_radius_zero_emits_no_collective():
    """Radius-0 row sharding is pure data parallelism: the halo helper is
    the identity and no ppermute appears in the lowered jaxpr."""
    slab = jnp.ones((2, 4, 8), jnp.int32)
    assert halo_exchange_rows(slab, 0, rows=4) is slab
    jaxpr = str(jax.make_jaxpr(
        lambda s: halo_exchange_rows(s, 0, rows=4))(slab))
    assert "ppermute" not in jaxpr
    # and radius > 0 DOES exchange (the negative control)
    mesh = build_mesh(MeshSpec(rows=2))
    if mesh is not None:
        from repro.parallel.axes import _shard_map_impl
        from jax.sharding import PartitionSpec as P
        fn = _shard_map_impl()(
            lambda s: halo_exchange_rows(s, 1, rows=2),
            mesh=mesh, in_specs=P(None, "rows"), out_specs=P(None, "rows"),
        )
        assert "ppermute" in str(jax.make_jaxpr(fn)(slab))


@needs_two_devices
def test_halo_exchange_matches_neighbor_rows():
    """Each shard's halo is literally its neighbours' edge rows (zeros at
    the frame border), i.e. form_tap_bank's zero-pad semantics."""
    from jax.sharding import PartitionSpec as P
    from repro.parallel.axes import _shard_map_impl

    mesh = build_mesh(MeshSpec(rows=2))
    full = jnp.arange(2 * 8 * 4, dtype=jnp.int32).reshape(2, 8, 4)
    r = 2
    fn = _shard_map_impl()(
        lambda s: halo_exchange_rows(s, r, rows=2),
        mesh=mesh, in_specs=P(None, "rows"), out_specs=P(None, "rows"),
    )
    haloed = np.asarray(jax.jit(fn)(full))
    # output is [2, 2*(band+2r), 4] reassembled along the rows axis
    band = 4
    top, bot = (haloed[:, : band + 2 * r, :],
                haloed[:, band + 2 * r:, :])
    np.testing.assert_array_equal(top[:, :r], 0)           # frame border
    np.testing.assert_array_equal(top[:, r:r + band], full[:, :band])
    np.testing.assert_array_equal(top[:, r + band:], full[:, band:band + r])
    np.testing.assert_array_equal(bot[:, :r], full[:, band - r:band])
    np.testing.assert_array_equal(bot[:, r:r + band], full[:, band:])
    np.testing.assert_array_equal(bot[:, r + band:], 0)    # frame border


# -- compiled-plan parity -----------------------------------------------------


def _plan_outputs(workload, spec, backend, tile_rows=None):
    stacked, ingests, canvas = workload
    plan = OverlayPlan(grid=GRID, batched=True, fused=True, radius=1,
                       backend=backend, mesh=spec, tile_rows=tile_rows)
    return np.asarray(compile_plan(plan)(stacked, ingests, canvas))


@needs_four_devices
@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("spec", [
    MeshSpec(app=2, rows=2),
    MeshSpec(rows=4),
    MeshSpec(rows=3),      # rows does not divide the 21-row canvas
    MeshSpec(app=4),
], ids=str)
def test_plan_parity_2d_vs_single_device(backend, spec):
    workload = _stacked_workload(np.random.default_rng(0))
    base = _plan_outputs(workload, MeshSpec(), backend)
    got = _plan_outputs(workload, spec, backend)
    np.testing.assert_array_equal(base, got)


@needs_four_devices
def test_plan_parity_with_row_tiling():
    """Row sharding composes with in-shard row tiling (PR 7's pipeline
    runs unchanged within each band)."""
    workload = _stacked_workload(np.random.default_rng(1))
    base = _plan_outputs(workload, MeshSpec(), "pallas", tile_rows=3)
    got = _plan_outputs(workload, MeshSpec(app=2, rows=2), "pallas",
                        tile_rows=3)
    np.testing.assert_array_equal(base, got)


# -- fleet-level parity (the serving path) ------------------------------------


def _fleet_results(rng, spec, backend, ingest):
    frames = [rng.integers(0, 256, hw).astype(np.int32) for hw in HWS]
    fleet = PixieFleet(default_grid=GRID, backend=backend, mesh=spec,
                       ingest=ingest, batch_tile=1)
    tickets = [fleet.submit(FleetRequest(app=n, image=f))
               for n, f in zip(NAMES, frames)]
    res = fleet.flush()
    return [np.asarray(res[t]) for t in tickets], fleet


@needs_four_devices
@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("ingest", ["sync", "async"])
def test_fleet_parity_2d(backend, ingest):
    base, _ = _fleet_results(np.random.default_rng(0), MeshSpec(),
                             backend, ingest)
    got, fleet = _fleet_results(np.random.default_rng(0),
                                MeshSpec(app=2, rows=2), backend, ingest)
    for b, g in zip(base, got):
        np.testing.assert_array_equal(b, g)
    assert fleet.stats.mesh_granted == (2, 2)
    assert not fleet.stats.mesh_degraded
    assert any("rows2" in k for k in fleet.stats.dispatch_plans)


@needs_two_devices
def test_fleet_parity_deprecated_devices_path():
    """The deprecated bare-count spelling warns but stays bitwise-equal
    and reuses the SAME plan population as MeshSpec(app=k)."""
    rng = np.random.default_rng(0)
    base, _ = _fleet_results(rng, MeshSpec(), "xla", "sync")
    rng = np.random.default_rng(0)
    got, fleet_mesh = _fleet_results(rng, MeshSpec(app=2), "xla", "sync")
    rng = np.random.default_rng(0)
    frames = [rng.integers(0, 256, hw).astype(np.int32) for hw in HWS]
    with pytest.warns(DeprecationWarning, match="MeshSpec"):
        fleet_legacy = PixieFleet(default_grid=GRID, backend="xla",
                                  devices=2, batch_tile=1)
    tickets = [fleet_legacy.submit(FleetRequest(app=n, image=f))
               for n, f in zip(NAMES, frames)]
    res = fleet_legacy.flush()
    legacy = [np.asarray(res[t]) for t in tickets]
    for b, g, l in zip(base, got, legacy):
        np.testing.assert_array_equal(b, g)
        np.testing.assert_array_equal(b, l)
    assert fleet_legacy.mesh == MeshSpec(app=2)
    assert set(fleet_legacy.stats.dispatch_plans) == set(
        fleet_mesh.stats.dispatch_plans
    )


def test_fleet_mesh_degradation_is_recorded():
    """A spec the host cannot honor degrades to the bitwise single-device
    fallback AND says so in the stats (truthful dashboards)."""
    spec = MeshSpec(app=N_DEVICES + 1, rows=4)
    fleet = PixieFleet(default_grid=GRID, mesh=spec)
    assert fleet.stats.mesh_requested == spec.shape()
    assert fleet.stats.mesh_granted == (1, 1)
    assert fleet.stats.mesh_degraded
    img = np.arange(64, dtype=np.int32).reshape(8, 8)
    t = fleet.submit(FleetRequest(app="sobel_x", image=img))
    ref = PixieFleet(default_grid=GRID)
    t_ref = ref.submit(FleetRequest(app="sobel_x", image=img))
    np.testing.assert_array_equal(fleet.flush()[t], ref.flush()[t_ref])
    granted = PixieFleet(default_grid=GRID, mesh=MeshSpec())
    assert not granted.stats.mesh_degraded


@needs_four_devices
def test_streaming_frontend_on_2d_mesh(rng):
    img = rng.integers(0, 256, (16, 16)).astype(np.int32)
    ref = np.asarray(FleetFrontend().submit("sobel_x", img).result())
    with StreamingFrontend(mesh=MeshSpec(app=2, rows=2)) as svc:
        assert svc.mesh == MeshSpec(app=2, rows=2)
        got = np.asarray(svc.submit("sobel_x", img).result(timeout=60.0))
    np.testing.assert_array_equal(ref, got)


def test_frontend_mesh_conflict_and_shim():
    fleet = PixieFleet(default_grid=GRID, mesh=MeshSpec())
    with pytest.raises(ValueError, match="conflicts"):
        FleetFrontend(fleet=fleet, mesh=MeshSpec(app=2))
    with pytest.raises(ValueError, match="not both"):
        FleetFrontend(mesh=MeshSpec(), devices=1)


# The hypothesis property sweep over (H, W, radius, app, rows) lives in
# test_mesh2d_property.py, gated on the dev dependency (repo idiom: the
# deterministic matrix above runs even without hypothesis installed).
