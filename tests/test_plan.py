"""The unified OverlayPlan compile/dispatch pipeline.

Plan identity IS the cache key: the same app stack on xla vs pallas, or
1-device vs mesh-sharded, must produce distinct ``OverlayPlan`` keys and
hit the fleet's LRU independently.  The deprecated ``make_*_overlay_fn``
shims must stay bitwise-equal to ``compile_plan`` while warning.  The
sharded tests (active when >= 2 local devices are present -- CI's
sharded-parity job forces two with
``XLA_FLAGS=--xla_force_host_platform_device_count=2``) assert that
app-axis ``shard_map`` dispatch is bitwise identical to the
single-device run on ragged, non-square app stacks for both backends.
"""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    OverlayExecutable, OverlayPlan, Pixie, compile_plan, map_app, sobel_grid,
)
from repro.core import applications as apps
from repro.core import interpreter
from repro.core.bitstream import VCGRAConfig
from repro.core.ingest import IngestPlan
from repro.core.tiling import pad_batches, pad_channels, pow2_bucket, round_up
from repro.parallel.axes import app_mesh
from repro.runtime.fleet import FleetRequest, PixieFleet

GRID = sobel_grid()
MULTI_DEVICE = len(jax.local_devices()) >= 2
needs_two_devices = pytest.mark.skipif(
    not MULTI_DEVICE,
    reason="needs >= 2 local devices (CI sharded-parity job forces 2 via "
    "XLA_FLAGS=--xla_force_host_platform_device_count=2)",
)


def _stacked_workload(rng, names, hws):
    """Ragged non-square frames embedded on one canvas + stacked settings."""
    images = [rng.integers(0, 256, hw).astype(np.int32) for hw in hws]
    configs = [map_app(apps.ALL_APPS[n](), GRID) for n in names]
    Hb, Wb = max(h for h, _ in hws), max(w for _, w in hws)
    canvas = np.zeros((len(names), Hb, Wb), dtype=np.int32)
    for i, img in enumerate(images):
        canvas[i, : img.shape[0], : img.shape[1]] = img
    return (
        VCGRAConfig.stack(configs),
        IngestPlan.stack([c.ingest for c in configs], GRID.dtype),
        jnp.asarray(canvas),
    )


# -- plan identity ------------------------------------------------------------


def test_plan_axes_produce_distinct_hashable_keys():
    """Every axis of the matrix distinguishes the plan; plans are usable
    as dict/LRU keys directly."""
    base = OverlayPlan(grid=GRID, batched=True, fused=True)
    variants = [
        base,
        OverlayPlan(grid=GRID, batched=True, fused=True, backend="pallas"),
        OverlayPlan(grid=GRID, batched=True, fused=True, devices=2),
        OverlayPlan(grid=GRID, batched=True, fused=True, radius=2),
        OverlayPlan(grid=GRID, batched=True, fused=False),
        OverlayPlan(grid=GRID, batched=False, fused=True),
    ]
    assert len({hash(p) for p in variants}) == len(variants)
    assert len({p.key() for p in variants}) == len(variants)
    assert len(dict.fromkeys(variants)) == len(variants)
    # equal plans are one key
    assert OverlayPlan(grid=GRID, batched=True, fused=True) == base
    assert hash(OverlayPlan(grid=GRID, batched=True, fused=True)) == hash(base)


def test_plan_validation_and_canonicalization():
    with pytest.raises(ValueError, match="unknown backend"):
        OverlayPlan(grid=GRID, backend="cuda")
    with pytest.raises(ValueError, match="devices"):
        OverlayPlan(grid=GRID, batched=True, devices=0)
    with pytest.raises(ValueError, match="batched"):
        OverlayPlan(grid=GRID, batched=False, devices=2)
    with pytest.raises(ValueError, match="radius"):
        OverlayPlan(grid=GRID, fused=False, radius=1)
    with pytest.raises(ValueError, match="radius"):
        OverlayPlan(grid=GRID, fused=True, radius=-1)
    # radius 0 is a VALID fused plan since PR 9: a depth-1 pointwise
    # pipeline stage (threshold at radius 0) canonicalizes onto it
    assert OverlayPlan(grid=GRID, fused=True, radius=0).radius == 0
    # fused plans canonicalize a missing radius to 1 (one key per bank)
    assert OverlayPlan(grid=GRID, fused=True).radius == 1
    assert OverlayPlan(grid=GRID, fused=True) == OverlayPlan(
        grid=GRID, fused=True, radius=1
    )


def test_compile_plan_returns_executable_with_plan():
    plan = OverlayPlan(grid=GRID, batched=True, fused=True)
    exe = compile_plan(plan)
    assert isinstance(exe, OverlayExecutable)
    assert exe.plan == plan and exe.mesh is None
    assert GRID.name in repr(exe)


# -- fleet LRU keyed on plans -------------------------------------------------


def test_fleet_lru_hits_independently_per_plan(rng):
    """Same app stack on xla vs pallas fleets: distinct plan keys, each
    LRU built exactly once and hit on the repeat flush."""
    img = rng.integers(0, 256, (7, 7)).astype(np.int32)
    reqs = [FleetRequest(app=n, image=img) for n in ("sobel_x", "identity")]
    fleets = {b: PixieFleet(default_grid=GRID, backend=b)
              for b in ("xla", "pallas")}
    plans = {}
    for b, fleet in fleets.items():
        fleet.run_many(reqs)
        fleet.run_many(reqs)
        assert fleet.stats.overlay_builds == 1
        assert fleet.stats.overlay_cache_hits == 1
        (plan,) = fleet._overlays._d.keys()
        plans[b] = plan
        assert plan.backend == b and plan.fused and plan.batched
    assert plans["xla"] != plans["pallas"]
    # 1-device vs sharded is a distinct key too (even off-mesh)
    sharded = PixieFleet(default_grid=GRID, devices=2)
    assert sharded.plan_for_dispatch(GRID, fused=True, radius=1) != plans["xla"]
    assert len({plans["xla"], plans["pallas"],
                sharded.plan_for_dispatch(GRID, fused=True, radius=1)}) == 3


def test_fleet_stats_stamp_full_plan_key(rng):
    img = rng.integers(0, 256, (5, 5)).astype(np.int32)
    fleet = PixieFleet(default_grid=GRID, backend="xla")
    fleet.run_many([FleetRequest(app="sobel_x", image=img)])
    assert fleet.stats.devices == 1
    (key,) = fleet.stats.dispatch_plans
    # the stamp names grid, fusion+radius, backend, devices and the tile
    for part in (GRID.name, "fused:r1", "xla", "dev1", "n8x"):
        assert part in key, (part, key)
    assert fleet.stats.as_dict()["dispatch_plans"][key] == 1


# -- deprecated shims ---------------------------------------------------------


def _sobel_operands(rng):
    img = rng.integers(0, 256, (8, 11)).astype(np.int32)
    cfg = map_app(apps.sobel_x(), GRID)
    taps = apps.stencil_inputs(jnp.asarray(img))
    feed = {k: v for k, v in taps.items() if k in cfg.input_order}
    x = pad_channels(interpreter.pack_inputs(cfg, feed, GRID.dtype),
                     GRID.num_inputs)
    return img, cfg, x


def test_deprecated_shims_warn_and_match_compile_plan_bitwise(rng):
    img, cfg, x = _sobel_operands(rng)
    stacked = VCGRAConfig.stack([cfg, cfg])
    ingests = IngestPlan.stack([cfg.ingest, cfg.ingest], GRID.dtype)
    xs = jnp.stack([x, x])
    imgs = jnp.stack([jnp.asarray(img)] * 2)

    cases = [
        (lambda: interpreter.make_overlay_fn(GRID),
         OverlayPlan(grid=GRID), (cfg.to_jax(), x)),
        (lambda: interpreter.make_batched_overlay_fn(GRID),
         OverlayPlan(grid=GRID, batched=True), (stacked, xs)),
        (lambda: interpreter.make_fused_overlay_fn(GRID),
         OverlayPlan(grid=GRID, fused=True),
         (cfg.to_jax(), cfg.ingest.to_jax(GRID.dtype), jnp.asarray(img))),
        (lambda: interpreter.make_batched_fused_overlay_fn(GRID),
         OverlayPlan(grid=GRID, batched=True, fused=True),
         (stacked, ingests, imgs)),
    ]
    for make, plan, operands in cases:
        with pytest.warns(DeprecationWarning, match="compile_plan"):
            shim = make()
        assert isinstance(shim, OverlayExecutable) and shim.plan == plan
        np.testing.assert_array_equal(
            np.asarray(shim(*operands)),
            np.asarray(compile_plan(plan)(*operands)),
        )


def test_deprecated_shims_still_reject_unknown_backend():
    with pytest.raises(ValueError, match="unknown backend"):
        interpreter.make_batched_fused_overlay_fn(GRID, backend="cuda")


# -- single-app backend threading (Pixie facade) ------------------------------


def test_pixie_facade_backend_pallas_bitwise(rng):
    """Single-app users exercise the pallas path without a fleet: the
    facade's plans carry backend= and stay bitwise-equal to xla."""
    img = rng.integers(0, 256, (9, 6)).astype(np.int32)
    cfg = map_app(apps.sobel_x(), GRID)
    outs = {}
    for backend in ("xla", "pallas"):
        pix = Pixie(GRID, backend=backend)
        pix.load(cfg)
        outs[backend] = np.asarray(pix.run_image(jnp.asarray(img)))
    np.testing.assert_array_equal(outs["xla"], outs["pallas"])
    np.testing.assert_array_equal(
        outs["xla"], apps.conv2d_reference(img, apps.SOBEL_X)
    )


def test_pixie_run_many_backend_pallas_bitwise(rng):
    img = rng.integers(0, 256, (6, 10)).astype(np.int32)
    taps = apps.stencil_inputs(jnp.asarray(img))
    reqs = []
    for n in ("sobel_x", "laplace"):
        dfg = apps.ALL_APPS[n]()
        reqs.append((dfg, {k: v for k, v in taps.items() if k in dfg.inputs}))
    ref = Pixie(GRID).run_many(reqs)
    got = Pixie(GRID, backend="pallas").run_many(reqs)
    for a, b in zip(ref, got):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_pixie_parameterized_rejects_plan_axes():
    with pytest.raises(ValueError, match="conventional"):
        Pixie(GRID, mode="parameterized", backend="pallas")
    with pytest.raises(ValueError, match="conventional"):
        Pixie(GRID, mode="parameterized", devices=2)
    # devices=1 is the documented no-mesh default, identical to omitting it
    assert Pixie(GRID, mode="parameterized", devices=1).devices == 1


def test_devices_zero_rejected_everywhere():
    """devices=0 must raise like every sibling API, not silently coerce
    to the single-device default."""
    for bad in (0, -1):
        with pytest.raises(ValueError, match="devices"):
            PixieFleet(devices=bad)
        with pytest.raises(ValueError, match="devices"):
            Pixie(GRID, devices=bad)
        with pytest.raises(ValueError, match="devices"):
            OverlayPlan(grid=GRID, batched=True, devices=bad)


# -- tiling single source of truth --------------------------------------------


def test_tiling_helpers_single_source():
    assert round_up(5, 4) == 8 and round_up(8, 4) == 8
    assert pow2_bucket(17, 16) == 32 and pow2_bucket(3, 16) == 16
    # the interpreter re-exports the same objects, not copies
    assert interpreter.pad_channels is pad_channels
    assert interpreter.pad_batches is pad_batches


# -- mesh-sharded dispatch ----------------------------------------------------


def test_app_mesh_single_device_fallback():
    assert app_mesh(1) is None
    assert app_mesh(10_000) is None  # more than any host: fall back, not raise
    exe = compile_plan(OverlayPlan(grid=GRID, batched=True, fused=True,
                                   devices=10_000))
    assert exe.mesh is None  # single-device bitwise fallback


@needs_two_devices
@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_sharded_compile_plan_bitwise_ragged_nonsquare(backend, rng):
    """devices=2 batched fused dispatch == single-device, bitwise, on a
    ragged non-square app stack -- including N=5 (not divisible by the
    mesh, exercising the internal app-axis padding)."""
    names = ["sobel_x", "sobel_y", "sharpen", "laplace", "identity"]
    hws = [(5, 9), (12, 4), (7, 7), (3, 11), (10, 6)]
    stacked, ingests, canvas = _stacked_workload(rng, names, hws)
    one = compile_plan(OverlayPlan(grid=GRID, batched=True, fused=True,
                                   backend=backend))
    two = compile_plan(OverlayPlan(grid=GRID, batched=True, fused=True,
                                   backend=backend, devices=2))
    assert two.mesh is not None and two.mesh.shape["app"] == 2
    np.testing.assert_array_equal(
        np.asarray(one(stacked, ingests, canvas)),
        np.asarray(two(stacked, ingests, canvas)),
    )


@needs_two_devices
@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_sharded_fleet_bitwise_ragged(backend, rng):
    """PixieFleet(devices=2) == PixieFleet() on ragged non-square frames,
    both backends; the sharded fleet stamps dev2 plan keys."""
    names = ["sobel_x", "sharpen", "identity"]
    images = [rng.integers(0, 256, hw).astype(np.int32)
              for hw in [(6, 8), (11, 5), (3, 9)]]
    reqs = [FleetRequest(app=n, image=i) for n, i in zip(names, images)]
    ref = PixieFleet(default_grid=GRID, backend=backend).run_many(reqs)
    fleet = PixieFleet(default_grid=GRID, backend=backend, devices=2)
    got = fleet.run_many(reqs)
    for a, b in zip(ref, got):
        np.testing.assert_array_equal(a, b)
    assert fleet.stats.devices == 2
    assert all("dev2" in k for k in fleet.stats.dispatch_plans)


@needs_two_devices
def test_sharded_mixed_fused_and_channel_requests(rng):
    """A sharded flush mixing fused frames and named channels: both
    dispatch paths shard and stay bitwise-exact."""
    img = rng.integers(0, 256, (6, 9)).astype(np.int32)
    x = rng.integers(0, 256, (23,)).astype(np.int32)
    reqs = [
        FleetRequest(app="sobel_x", image=img),
        FleetRequest(app="threshold", inputs={"p11": x}),
    ]
    ref = PixieFleet(default_grid=GRID).run_many(reqs)
    got = PixieFleet(default_grid=GRID, devices=2).run_many(reqs)
    np.testing.assert_array_equal(ref[0], got[0])
    np.testing.assert_array_equal(ref[1], got[1])


@needs_two_devices
def test_sharded_pixie_run_many_bitwise(rng):
    img = rng.integers(0, 256, (6, 10)).astype(np.int32)
    taps = apps.stencil_inputs(jnp.asarray(img))
    reqs = []
    for n in ("sobel_x", "laplace", "identity"):
        dfg = apps.ALL_APPS[n]()
        reqs.append((dfg, {k: v for k, v in taps.items() if k in dfg.inputs}))
    ref = Pixie(GRID).run_many(reqs)  # N=3: internal pad to the mesh
    got = Pixie(GRID, devices=2).run_many(reqs)
    for a, b in zip(ref, got):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# -- front-end plumbing -------------------------------------------------------


def test_frontend_devices_kwarg_and_conflict():
    from repro.serve.fleet_frontend import FleetFrontend

    svc = FleetFrontend(devices=1)
    assert svc.devices == 1
    with pytest.raises(ValueError, match="conflicts"):
        FleetFrontend(fleet=PixieFleet(devices=1), devices=2)


def test_no_spurious_deprecation_warnings_on_plan_paths(rng):
    """The rewired production paths (fleet, facade) must not route through
    the deprecated shims."""
    img = rng.integers(0, 256, (5, 5)).astype(np.int32)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        PixieFleet(default_grid=GRID).run_many(
            [FleetRequest(app="sobel_x", image=img)]
        )
        pix = Pixie(GRID)
        pix.load(map_app(apps.sobel_x(), GRID))
        pix.run_image(jnp.asarray(img))
