"""The in-kernel double-buffered HBM->VMEM DMA pipeline (PR 7).

The tiled Pallas megakernel no longer receives host-pre-sliced halo
slabs: the pallas grid walks row tiles over the ONE zero-row-padded
frame stack and the kernel's own ``make_async_copy`` double buffer
streams each ``[tile_rows + 2r, W]`` halo window HBM->VMEM, prefetching
tile t+1 while tile t computes.  This suite pins the contract:

* bitwise parity with the untiled XLA oracle in interpret mode, over a
  hypothesis sweep of (H, W, radius, tile_rows) covering radius=0,
  tile_rows >= H, tile_rows not dividing H and non-square frames (the
  deterministic corner sweep rides test_tiling.py, which routes the same
  DMA kernel);
* the grep-lint acceptance criterion: ``halo_row_slabs`` has NO call
  site in the kernel package -- the pre-slice survives only as the XLA
  twin's layout (``core/interpreter.py``);
* plan-compatibility: the DMA lowering is the compiled-TPU realization
  of the EXISTING ``tile_rows`` plan axis -- same plan keys and hashes,
  no new axis, so every PR 5-era cache entry stays valid and repeat
  dispatches hit the fleet's overlay LRU;
* the lane-alignment rounding lives in ``tiling.resolve_tile_rows``
  (one definition with the heuristic and the XLA twin);
* per-device canvas pooling for sharded async fleets (the PR 5 pointer
  satellite): devices=2 async flushes fill and ship one pooled buffer
  per mesh device, counted in ``FleetStats.canvas_pool_device_hits``,
  bitwise-equal to the single-device sync run;
* a ``tpu``-marked compiled perf/parity test (auto-skipped off-TPU):
  the compiled kernel must match the XLA twin bitwise and the measured
  pallas/xla fused-e2e ratio is reported against a loose floor.
"""

import re
import time
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.core import OverlayPlan, compile_plan, sobel_grid
from repro.core import interpreter
from repro.core.tiling import (
    LANE,
    TILE_AUTO,
    lane_aligned_tile_rows,
    resolve_tile_rows,
)
from repro.kernels.vcgra.ops import _batched_fused_pallas_fn
from repro.runtime.fleet import FleetRequest, PixieFleet

from test_tiling import (
    assert_tiled_equals_untiled,
    needs_two_devices,
    random_fused_workload,
)

GRID = sobel_grid()
REPO = Path(__file__).resolve().parent.parent


# -- acceptance grep-lint: no host-side halo pre-slice on the pallas path ------


def test_no_halo_row_slabs_call_in_kernel_package():
    """``halo_row_slabs`` must have zero call sites under
    ``src/repro/kernels/`` -- the megakernel's halo windows are sliced by
    the in-kernel DMA, never materialized in HBM.  The XLA tiled twin
    (core/interpreter.py) legitimately keeps the pre-slice: on CPU there
    is no VMEM and the duplicated slab tensor buys XLA fusion."""
    call = re.compile(r"\bhalo_row_slabs\s*\(")
    offenders = []
    for path in sorted((REPO / "src" / "repro" / "kernels").rglob("*.py")):
        text = path.read_text(encoding="utf-8")
        for m in call.finditer(text):
            line = text.count("\n", 0, m.start()) + 1
            offenders.append(f"{path.relative_to(REPO)}:{line}")
    assert not offenders, (
        "host-side halo pre-slice called from the kernel package -- the "
        "pallas path streams halo windows with the in-kernel DMA double "
        "buffer: " + ", ".join(offenders)
    )


# -- plan-axis compatibility: same keys, same cache entries --------------------


def test_dma_path_reuses_tile_rows_plan_entries():
    """The DMA lowering changed the kernel, not the plan: pallas tiled
    plans keep their PR 5 keys (no new axis segment) and a fleet's repeat
    tiled dispatches hit the SAME overlay LRU entry."""
    plan = OverlayPlan(grid=GRID, batched=True, fused=True,
                       backend="pallas", tile_rows=8)
    # PR 5-era key shape: the tile segment, nothing DMA-specific.
    assert plan.key() == f"{GRID.name}|batched|fused:r1|pallas|dev1|tile:8"
    assert plan == OverlayPlan(grid=GRID, batched=True, fused=True,
                               backend="pallas", tile_rows=8)
    fleet = PixieFleet(default_grid=GRID, backend="pallas", tile_rows=8)
    img = np.arange(48, dtype=np.int32).reshape(6, 8)
    fleet.run_many([FleetRequest(app="sobel_x", image=img)])
    fleet.run_many([FleetRequest(app="sharpen", image=img)])
    assert fleet.stats.overlay_builds == 1
    assert fleet.stats.overlay_cache_hits >= 1
    assert all("tile:8" in k for k in fleet.stats.dispatch_plans)


def test_lane_alignment_is_resolved_in_tiling():
    """One rounding definition: an AUTO pick that actually tiles, asked
    with ``lane_align=LANE``, satisfies the compiled kernel's layout
    constraint and equals ``lane_aligned_tile_rows`` of the unaligned
    pick -- and the interpret path (lane_align=None) is untouched."""
    H, W = 4096, 1920
    raw = resolve_tile_rows(TILE_AUTO, H, W, 1, GRID)
    aligned = resolve_tile_rows(TILE_AUTO, H, W, 1, GRID, lane_align=LANE)
    assert 1 <= aligned < H and (aligned * W) % LANE == 0
    assert aligned == lane_aligned_tile_rows(raw, W)
    assert aligned <= raw
    # degenerate-untiled AUTO picks are not rounded (single slab == whole
    # frame needs no tiling machinery, and H*W is the caller's canvas)
    assert resolve_tile_rows(TILE_AUTO, 32, 32, 1, GRID, lane_align=LANE) == 32
    # explicit tile heights are never silently rewritten
    assert resolve_tile_rows(5, 100, 7, 1, GRID, lane_align=LANE) == 5


# -- hypothesis sweep: DMA kernel (interpret) vs the untiled XLA oracle --------

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - dev dependency absent
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @st.composite
    def dma_cases(draw):
        """Random (H, W, radius, tile_rows) hitting the DMA corner cases
        by construction: radius 0 (single-tap bank, pure-body windows),
        tile_rows >= H (single tile, warm-up DMA only), tile_rows not
        dividing H (ragged bottom tile reads the zero pad as halo), and
        non-square frames (W != H exercises the column axis of the
        windows); odd tile counts stress the linearized-step slot
        rotation at app boundaries."""
        H = draw(st.integers(1, 16))
        W = draw(st.integers(1, 16))
        radius = draw(st.integers(0, 2))
        tile_rows = draw(st.integers(1, H + 3))
        n = draw(st.integers(1, 3))
        seed = draw(st.integers(0, 2**31 - 1))
        return H, W, radius, tile_rows, n, seed

    @settings(max_examples=10, deadline=None)
    @given(dma_cases())
    def test_property_dma_kernel_bitwise_vs_oracle(case):
        H, W, radius, tile_rows, n, seed = case
        assert_tiled_equals_untiled(H, W, radius, tile_rows, n, seed,
                                    backend="pallas")

else:  # pragma: no cover - dev dependency absent

    def test_property_dma_kernel_bitwise_vs_oracle():
        pytest.skip("hypothesis not installed (see requirements-dev.txt)")


def test_dma_multi_tile_multi_app_odd_tiles_bitwise():
    """The regression corner the double buffer is most likely to break:
    several apps x an ODD number of row tiles per app, where a slot
    rotation keyed on the tile index alone (instead of the linearized
    step) desynchronizes the prefetch at every app boundary."""
    # H=15, tile_rows=5 -> 3 tiles/app; 4 apps -> 12 steps, odd per-app.
    assert_tiled_equals_untiled(15, 6, 1, 5, n=4, seed=11, backend="pallas")


# -- per-device canvas pool (sharded async fleets) -----------------------------


@needs_two_devices
def test_sharded_async_per_device_canvas_pool_bitwise(rng):
    """devices=2 async fused flushes pool and ship one canvas per mesh
    device; after the depth-2 rotation warms up, BOTH devices count
    reuse hits, and outputs stay bitwise-equal to the single-device sync
    fleet."""
    names = ["sobel_x", "sharpen", "laplace", "identity"]
    reqs = [FleetRequest(app=n, image=rng.integers(0, 256, (16, 16))
                         .astype(np.int32)) for n in names]
    ref = PixieFleet(default_grid=GRID).run_many(reqs)
    fleet = PixieFleet(default_grid=GRID, devices=2, ingest="async")
    # Per-device pool depth is 2: the third flush is the first to rotate
    # every device back onto a pooled buffer.
    for _ in range(3):
        got = fleet.run_many(reqs)
    for a, b in zip(ref, got):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    hits = fleet.stats.canvas_pool_device_hits
    assert sorted(hits) == ["0", "1"], hits
    assert all(v >= 1 for v in hits.values())
    assert fleet.stats.canvas_pool_hits >= sum(hits.values())


def test_unsharded_fleet_has_no_device_hits(rng):
    """The per-device counters stay empty off-mesh: the unsharded async
    path keeps the single whole-batch canvas."""
    fleet = PixieFleet(default_grid=GRID, ingest="async")
    reqs = [FleetRequest(app="sobel_x",
                         image=rng.integers(0, 256, (8, 8)).astype(np.int32))]
    for _ in range(3):
        fleet.run_many(reqs)
    assert fleet.stats.canvas_pool_device_hits == {}
    assert fleet.stats.canvas_pool_hits >= 1


# -- compiled TPU perf/parity (auto-skipped off-TPU) ---------------------------


@pytest.mark.tpu
def test_compiled_dma_megakernel_parity_and_ratio():
    """On a real TPU: the compiled (interpret=False) DMA megakernel must
    match the XLA tiled twin bitwise at 256^2 with a lane-aligned tile,
    and the measured pallas/xla fused-e2e ratio is asserted against a
    deliberately loose floor (the honest number lands in
    BENCH_fleet.json via fleet_throughput.py --frames)."""
    H = W = 256
    tile_rows = 64                      # (64 * 256) % 128 == 0
    stacked, ingests, images = random_fused_workload(H, W, 1, 4, seed=3)
    xla_fn = jax.jit(lambda s, i, x: interpreter.tiled_batched_fused_overlay_step(
        GRID, 1, tile_rows, s, i, x))
    dma_fn = jax.jit(_batched_fused_pallas_fn(GRID, 1, interpret=False,
                                              tile_rows=tile_rows))
    ref = np.asarray(xla_fn(stacked, ingests, images))
    got = np.asarray(dma_fn(stacked, ingests, images))
    np.testing.assert_array_equal(got, ref)

    def bench(fn):
        fn(stacked, ingests, images).block_until_ready()     # warm
        t0 = time.perf_counter()
        for _ in range(10):
            y = fn(stacked, ingests, images)
        y.block_until_ready()
        return (time.perf_counter() - t0) / 10

    ratio = bench(xla_fn) / bench(dma_fn)   # >1 means pallas is faster
    # Loose floor: the compiled DMA pipeline must not be catastrophically
    # slower than the XLA lowering on the hardware it was built for.
    assert ratio > 0.25, f"compiled pallas/xla fused-e2e ratio {ratio:.2f}"
