"""Linear-RNN core tests: chunked GLA == step-by-step recurrence == naive
oracle; sLSTM scan/step equivalence; causal conv correctness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.linear_rnn import (
    causal_conv1d, causal_conv1d_step, gla_chunked, gla_step,
    init_slstm, slstm_scan, slstm_step,
)


def _gla_naive(q, k, v, log_f, i_gate, normalize):
    """Direct per-step recurrence in float64-ish numpy (the oracle)."""
    q, k, v = (np.asarray(t, np.float64) for t in (q, k, v))
    log_f, i_gate = np.asarray(log_f, np.float64), np.asarray(i_gate, np.float64)
    B, L, H, dk = q.shape
    dv = v.shape[-1]
    S = np.zeros((B, H, dk, dv))
    n = np.zeros((B, H, dk))
    ys = np.zeros((B, L, H, dv))
    for t in range(L):
        f = np.exp(log_f[:, t])[..., None, None]
        S = f * S + (i_gate[:, t][..., None] * k[:, t])[..., None] * v[:, t][..., None, :]
        n = f[..., 0] * n + i_gate[:, t][..., None] * k[:, t]
        y = np.einsum("bhd,bhdv->bhv", q[:, t], S)
        if normalize:
            den = np.maximum(np.abs(np.einsum("bhd,bhd->bh", q[:, t], n)), 1.0)
            y = y / den[..., None]
        ys[:, t] = y
    return ys, (S, n)


def _mk(rng, B=2, L=32, H=3, dk=8, dv=5):
    q = rng.standard_normal((B, L, H, dk)).astype(np.float32)
    k = rng.standard_normal((B, L, H, dk)).astype(np.float32)
    v = rng.standard_normal((B, L, H, dv)).astype(np.float32)
    log_f = np.log(rng.uniform(0.5, 0.99, (B, L, H))).astype(np.float32)
    i_gate = rng.uniform(0.1, 1.0, (B, L, H)).astype(np.float32)
    return map(jnp.asarray, (q, k, v, log_f, i_gate))


@pytest.mark.parametrize("normalize", [False, True])
@pytest.mark.parametrize("chunk", [4, 8, 32])
def test_gla_chunked_matches_naive(normalize, chunk, rng):
    q, k, v, log_f, i_gate = _mk(rng)
    y, (S, n) = gla_chunked(q, k, v, log_f, i_gate, normalize=normalize, chunk=chunk)
    y_ref, (S_ref, n_ref) = _gla_naive(q, k, v, log_f, i_gate, normalize)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(S), S_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(n), n_ref, rtol=2e-4, atol=2e-4)


def test_gla_chunked_chunk_invariance(rng):
    q, k, v, log_f, i_gate = _mk(rng, L=24)
    y1, _ = gla_chunked(q, k, v, log_f, i_gate, chunk=4)
    y2, _ = gla_chunked(q, k, v, log_f, i_gate, chunk=24)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4, atol=1e-4)


def test_gla_step_continues_chunked(rng):
    """chunked(L) then step == chunked(L+1) at the last position."""
    q, k, v, log_f, i_gate = _mk(rng, L=17)
    y_all, _ = gla_chunked(q, k, v, log_f, i_gate, normalize=True, chunk=17)
    _, state = gla_chunked(
        q[:, :16], k[:, :16], v[:, :16], log_f[:, :16], i_gate[:, :16],
        normalize=True, chunk=8,
    )
    y_last, _ = gla_step(
        q[:, 16], k[:, 16], v[:, 16], log_f[:, 16], i_gate[:, 16],
        state, normalize=True,
    )
    np.testing.assert_allclose(
        np.asarray(y_last), np.asarray(y_all[:, 16]), rtol=2e-4, atol=2e-4
    )


def test_causal_conv_matches_numpy(rng):
    x = jnp.asarray(rng.standard_normal((2, 10, 3)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((4, 3)).astype(np.float32))
    y = np.asarray(causal_conv1d(x, w))
    xp = np.pad(np.asarray(x), ((0, 0), (3, 0), (0, 0)))
    ref = sum(xp[:, j : j + 10] * np.asarray(w)[j] for j in range(4))
    np.testing.assert_allclose(y, ref, rtol=1e-5, atol=1e-6)


def test_causal_conv_step_continues(rng):
    x = jnp.asarray(rng.standard_normal((2, 9, 3)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((4, 3)).astype(np.float32))
    full = np.asarray(causal_conv1d(x, w))
    buf = jnp.asarray(np.asarray(x)[:, 5:8])  # last K-1 inputs before t=8
    y, buf2 = causal_conv1d_step(x[:, 8], w, buf)
    np.testing.assert_allclose(np.asarray(y), full[:, 8], rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(buf2), np.asarray(x)[:, 6:9])


def test_slstm_step_matches_scan(rng):
    params = init_slstm(jax.random.PRNGKey(0), 12, 3)
    x = jnp.asarray(rng.standard_normal((2, 7, 12)).astype(np.float32))
    y_scan, state_scan = slstm_scan(params, x, 3)
    state = None
    ys = []
    for t in range(7):
        y, state = slstm_step(params, x[:, t], 3, state) if state is not None else (
            slstm_scan(params, x[:, t : t + 1], 3)[0][:, 0],
            slstm_scan(params, x[:, t : t + 1], 3)[1],
        )
        ys.append(np.asarray(y))
    np.testing.assert_allclose(
        np.stack(ys, axis=1), np.asarray(y_scan), rtol=1e-5, atol=1e-5
    )


def test_gla_stability_long_sequence(rng):
    """Bounded gates => no overflow over long sequences."""
    q, k, v, log_f, i_gate = _mk(rng, L=512, H=2, dk=16, dv=16)
    y, (S, n) = gla_chunked(q, k, v, log_f, i_gate, normalize=True, chunk=64)
    assert bool(jnp.isfinite(y).all())
    assert bool(jnp.isfinite(S).all())
