"""Device-resident pipeline plans: a chained overlay (stage i's selected
output channel feeds stage i+1's ingest taps) compiles to ONE
`OverlayExecutable` whose intermediates never leave the device.  Every
fused chain here is asserted BITWISE equal to the staged per-stage oracle
(one single-stage run per stage with a host hop between), on both
backends, through every layer: the plan/key algebra, the compiled
executors, the fleet (sync + async ingest, mixed flushes, depth-1
demotion), `Pixie.run_pipeline`, both serving front-ends, and the
row-sharded mesh path (device-gated)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import shared_app_grid

from repro.core import MeshSpec, OverlayPlan, Pixie, compile_plan, map_app
from repro.core import applications as apps
from repro.core.bitstream import VCGRAConfig
from repro.core.ingest import IngestPlan
from repro.core.plan import PipelineSpec, PipelineStage, pipeline_digest
from repro.runtime.fleet import FleetRequest, PixieFleet
from repro.serve import FleetFrontend, StreamingFrontend

N_DEVICES = len(jax.local_devices())
needs_two_devices = pytest.mark.skipif(
    N_DEVICES < 2,
    reason="needs >= 2 local devices (CI pipeline-parity job forces 2 via "
    "XLA_FLAGS=--xla_force_host_platform_device_count=2)",
)

# The canonical depth-3 chain: blur -> edge -> binarize (radii 1/1/1; the
# threshold stage is pointwise and re-plans to radius 0 in the mixed-radii
# tests).  One shared grid fits every stage (Sec. III-C's "application
# specific grid designs" at the union of demands).
CHAIN = ["gauss3", "sobel_x", "threshold"]
GRID = shared_app_grid(CHAIN, name="pipe-shared")
WAIT = 30.0


def chain_configs(grid=GRID, names=CHAIN):
    return [map_app(apps.ALL_APPS[n](), grid) for n in names]


def staged_oracle(cfgs, image, grid=GRID, out_channels=None):
    """Per-stage host-hop reference: stage i runs alone, its [H, W]
    output (selected channel) is re-submitted as stage i+1's frame."""
    chans = list(out_channels) if out_channels else [0] * len(cfgs)
    pix = Pixie(grid, mode="conventional")
    cur = np.asarray(image)
    for cfg, ch in zip(cfgs, chans):
        pix.load(cfg)
        y = np.asarray(pix.run_image(jnp.asarray(cur)))
        cur = y if y.ndim == 2 else y[ch]
    return cur


# -- spec construction + validation -------------------------------------------


def test_stage_requires_ingest_plan():
    cfg = chain_configs()[0]
    bare = dataclasses.replace(cfg, ingest=None)
    with pytest.raises(ValueError, match="no ingest"):
        PipelineStage(bare)


def test_stage_out_channel_range():
    cfg = chain_configs()[0]
    with pytest.raises(ValueError, match="out_channel"):
        PipelineStage(cfg, out_channel=len(cfg.out_sel))


def test_spec_needs_at_least_one_stage():
    with pytest.raises(ValueError, match="at least one stage"):
        PipelineSpec(())


def test_spec_rejects_mixed_grids():
    other = shared_app_grid(CHAIN, name="pipe-other")
    a = map_app(apps.ALL_APPS["gauss3"](), GRID)
    b = map_app(apps.ALL_APPS["sobel_x"](), other)
    with pytest.raises(ValueError, match="ONE overlay grid"):
        PipelineSpec((PipelineStage(a), PipelineStage(b)))


def test_at_radius_replans_pointwise_stage():
    thr = map_app(apps.ALL_APPS["threshold"](), GRID)
    thr.cache_key = "thr@pipe-shared"  # as the fleet's config_for would set
    stage = PipelineStage(thr)
    assert stage.radius == 1
    r0 = stage.at_radius(0)
    assert r0.radius == 0 and r0 != stage
    # the radius-keyed settings banks must never alias the original
    assert r0.config.cache_key == "thr@pipe-shared@r0"
    assert stage.at_radius(1) is stage


def test_spec_digest_is_content_addressed():
    cfgs = chain_configs()
    assert PipelineSpec.chain(cfgs) == PipelineSpec.chain(chain_configs())
    assert hash(PipelineSpec.chain(cfgs)) == hash(PipelineSpec.chain(cfgs))
    assert PipelineSpec.chain(cfgs) != PipelineSpec.chain(cfgs[:2])
    spec = PipelineSpec.chain(cfgs)
    assert spec.depth == 3 and spec.radii == (1, 1, 1)
    assert spec.total_radius == 3


# -- plan algebra: canonicalization + key compatibility -----------------------


def test_depth1_pipeline_canonicalizes_to_plain_fused_plan():
    """A single-stage "chain" IS the existing batched fused plan: same
    key, same hash, same cache entry -- every pre-pipeline executable
    population survives the new axis."""
    cfg = chain_configs()[:1]
    spec = PipelineSpec.chain(cfg)
    p_pipe = OverlayPlan(grid=GRID, batched=True, pipeline=(spec, spec))
    p_plain = OverlayPlan(grid=GRID, batched=True, fused=True, radius=1)
    assert p_pipe.pipeline is None
    assert p_pipe.radius == 1 and p_pipe.fused
    assert p_pipe.key() == p_plain.key()
    assert p_pipe == p_plain and hash(p_pipe) == hash(p_plain)


def test_deep_pipeline_key_appends_pipe_segment_only():
    spec = PipelineSpec.chain(chain_configs())
    p = OverlayPlan(grid=GRID, batched=True, pipeline=(spec,))
    plain = OverlayPlan(grid=GRID, batched=True, fused=True, radius=1)
    assert "|pipe" in p.key() and "|pipe" not in plain.key()
    assert p.key() == plain.key() + f"|pipe{pipeline_digest(p.pipeline)[:12]}"
    # identity: same chain -> same plan; different chain -> different key
    p2 = OverlayPlan(grid=GRID, batched=True, pipeline=(spec,))
    assert p == p2 and p.key() == p2.key()
    p3 = OverlayPlan(
        grid=GRID, batched=True,
        pipeline=(PipelineSpec.chain(chain_configs()[:2]),),
    )
    assert p3.key() != p.key()


def test_pipeline_plan_validation():
    spec = PipelineSpec.chain(chain_configs())
    with pytest.raises(ValueError, match="batched"):
        OverlayPlan(grid=GRID, pipeline=(spec,))
    with pytest.raises(ValueError, match="radius is derived"):
        OverlayPlan(grid=GRID, batched=True, radius=1, pipeline=(spec,))
    other = shared_app_grid(CHAIN, name="pipe-other2")
    with pytest.raises(ValueError, match="cannot run on plan grid"):
        OverlayPlan(grid=other, batched=True, pipeline=(spec,))
    short = PipelineSpec.chain(chain_configs()[:2])
    with pytest.raises(ValueError, match="stage structure"):
        OverlayPlan(grid=GRID, batched=True, pipeline=(spec, short))
    # plan radius of a chain = max stage radius (rows-band floor)
    p = OverlayPlan(grid=GRID, batched=True, pipeline=(spec,))
    assert p.radius == 1 and p.fused


# -- compiled executors: fused chain == staged oracle, both backends ----------


def _stage_settings(specs):
    return tuple(
        (
            VCGRAConfig.stack([s.stages[si].config for s in specs]),
            IngestPlan.stack(
                [s.stages[si].config.ingest for s in specs], GRID.dtype
            ),
            jnp.asarray([s.stages[si].out_channel for s in specs], jnp.int32),
        )
        for si in range(specs[0].depth)
    )


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_executor_parity_ragged_stack(backend, rng):
    """Depth-3 chain over a ragged 3-frame stack: the single executable's
    per-app crops match the per-stage oracle bitwise.  Raggedness is the
    hard case -- the executor must re-mask each intermediate to the app's
    true [h, w] region or zero-canvas taps poison the next stage."""
    cfgs = chain_configs()
    spec = PipelineSpec.chain(cfgs)
    hws = [(24, 16), (20, 13), (17, 16)]
    images = [rng.integers(0, 256, hw).astype(np.int32) for hw in hws]
    canvas = np.zeros((3, 24, 16), np.int32)
    for i, im in enumerate(images):
        canvas[i, : im.shape[0], : im.shape[1]] = im

    fn = compile_plan(OverlayPlan(
        grid=GRID, batched=True, pipeline=(spec,) * 3, backend=backend,
    ))
    ys = fn(_stage_settings([spec] * 3),
            jnp.asarray(np.asarray(hws, np.int32)), jnp.asarray(canvas))
    for i, (h, w) in enumerate(hws):
        want = staged_oracle(cfgs, images[i])
        got = np.asarray(ys[i]).reshape(-1, 24, 16)[0, :h, :w]
        np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_executor_parity_mixed_radii_with_zero(backend, rng):
    """gauss3 (r=1) -> threshold re-planned at r=0: radius-0 stages ride
    the same chain executable (1-tap bank, no column pad)."""
    g = map_app(apps.ALL_APPS["gauss3"](), GRID)
    t = PipelineStage(map_app(apps.ALL_APPS["threshold"](), GRID)).at_radius(0)
    spec = PipelineSpec((PipelineStage(g), t))
    assert spec.radii == (1, 0)
    img = rng.integers(0, 256, (15, 11)).astype(np.int32)

    fn = compile_plan(OverlayPlan(
        grid=GRID, batched=True, pipeline=(spec,), backend=backend,
    ))
    ys = fn(_stage_settings([spec]), jnp.asarray([[15, 11]], jnp.int32),
            jnp.asarray(img)[None])
    want = staged_oracle([g, t.config], img)
    np.testing.assert_array_equal(
        np.asarray(ys[0]).reshape(-1, 15, 11)[0], want
    )


@pytest.mark.parametrize("tile_rows", [None, 8, 5])
def test_pallas_chain_tile_rows_bitwise(tile_rows, rng):
    """The megakernel's trapezoid stage loop is tiling-invariant -- ragged
    last tiles (5 does not divide 24) included."""
    cfgs = chain_configs()
    spec = PipelineSpec.chain(cfgs)
    img = rng.integers(0, 256, (24, 16)).astype(np.int32)
    fn = compile_plan(OverlayPlan(
        grid=GRID, batched=True, pipeline=(spec,), backend="pallas",
        tile_rows=tile_rows,
    ))
    ys = fn(_stage_settings([spec]), jnp.asarray([[24, 16]], jnp.int32),
            jnp.asarray(img)[None])
    want = staged_oracle(cfgs, img)
    np.testing.assert_array_equal(
        np.asarray(ys[0]).reshape(-1, 24, 16)[0], want
    )


# -- fleet: chained requests batch/tile/cache like single-stage ones ----------


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_fleet_pipeline_bitwise(backend, rng):
    cfgs = chain_configs()
    images = [rng.integers(0, 256, (13, 17)).astype(np.int32)
              for _ in range(3)]
    fleet = PixieFleet(default_grid=GRID, backend=backend)
    outs = fleet.run_many(
        [FleetRequest(pipeline=CHAIN, image=im) for im in images]
    )
    for im, got in zip(images, outs):
        np.testing.assert_array_equal(np.asarray(got),
                                      staged_oracle(cfgs, im))
    assert fleet.stats.pipeline_dispatches == 1
    assert fleet.stats.dispatches == 1  # the chain is ONE device operation


def test_fleet_depth1_chain_demotes_to_plain_fused(rng):
    """pipeline=["sobel_x"] batches, caches, and stamps EXACTLY like
    app="sobel_x" -- no pipe segment, no new executable."""
    img = rng.integers(0, 256, (9, 9)).astype(np.int32)
    fleet = PixieFleet(default_grid=GRID)
    a = fleet.run_many([FleetRequest(app="sobel_x", image=img)])[0]
    b = fleet.run_many([FleetRequest(pipeline=["sobel_x"], image=img)])[0]
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert fleet.stats.pipeline_dispatches == 0
    assert fleet._overlays.misses == 1  # ONE plan serves both spellings
    assert all("|pipe" not in k for k in fleet.stats.dispatch_plans)


def test_fleet_mixed_flush_chains_and_singles(rng):
    """Chains and single-stage requests share a flush: grouped into one
    pipeline dispatch + one fused dispatch, all outputs bitwise."""
    cfgs = chain_configs()
    img = rng.integers(0, 256, (12, 10)).astype(np.int32)
    fleet = PixieFleet(default_grid=GRID)
    t_chain = fleet.submit(FleetRequest(pipeline=CHAIN, image=img))
    t_single = fleet.submit(FleetRequest(app="sobel_x", image=img))
    t_depth1 = fleet.submit(FleetRequest(pipeline=["gauss3"], image=img))
    outs = fleet.flush()
    assert fleet.stats.dispatches == 2
    assert fleet.stats.pipeline_dispatches == 1
    np.testing.assert_array_equal(
        np.asarray(outs[t_chain]), staged_oracle(cfgs, img)
    )
    np.testing.assert_array_equal(
        np.asarray(outs[t_single]), staged_oracle(cfgs[1:2], img)
    )
    np.testing.assert_array_equal(
        np.asarray(outs[t_depth1]), staged_oracle(cfgs[:1], img)
    )


def test_fleet_pipeline_async_ingest_bitwise(rng):
    cfgs = chain_configs()
    images = [rng.integers(0, 256, (11, 9)).astype(np.int32)
              for _ in range(2)]
    fleet = PixieFleet(default_grid=GRID, ingest="async")
    for _ in range(3):  # canvas-pool rotation across flushes
        outs = fleet.run_many(
            [FleetRequest(pipeline=CHAIN, image=im) for im in images]
        )
        for im, got in zip(images, outs):
            np.testing.assert_array_equal(np.asarray(got),
                                          staged_oracle(cfgs, im))


def test_fleet_pipeline_out_channels_and_plan_reuse(rng):
    img = rng.integers(0, 256, (8, 8)).astype(np.int32)
    fleet = PixieFleet(default_grid=GRID)
    fleet.run_many([FleetRequest(pipeline=CHAIN, image=img,
                                 out_channels=[0, 0, 0])])
    fleet.run_many([FleetRequest(pipeline=CHAIN, image=img)])
    # explicit default out_channels are the same spec: one plan compile
    assert fleet._overlays.misses == 1 and fleet._overlays.hits == 1
    assert any("|pipe" in k for k in fleet.stats.dispatch_plans)


def test_fleet_pipeline_submit_validation(rng):
    img = rng.integers(0, 256, (8, 8)).astype(np.int32)
    fleet = PixieFleet(default_grid=GRID)
    with pytest.raises(ValueError, match="not both"):
        fleet.submit(FleetRequest(app="sobel_x", pipeline=CHAIN, image=img))
    with pytest.raises(ValueError, match="app= or pipeline="):
        fleet.submit(FleetRequest(image=img))
    with pytest.raises(ValueError, match="image"):
        fleet.submit(FleetRequest(pipeline=CHAIN,
                                  inputs={"x": np.zeros(4, np.int32)}))
    with pytest.raises(ValueError, match="at least one stage"):
        fleet.submit(FleetRequest(pipeline=[], image=img))


# -- Pixie facade -------------------------------------------------------------


def test_pixie_run_pipeline_bitwise(rng):
    cfgs = chain_configs()
    img = rng.integers(0, 256, (14, 12)).astype(np.int32)
    pix = Pixie(GRID, mode="conventional")
    got = np.asarray(pix.run_pipeline(CHAIN, jnp.asarray(img)))
    np.testing.assert_array_equal(got, staged_oracle(cfgs, img))
    assert "run_pipeline_s" in pix.timings
    # compiled once per distinct chain
    assert len(pix._pipeline_fns) == 1
    pix.run_pipeline(CHAIN, jnp.asarray(img))
    assert len(pix._pipeline_fns) == 1


def test_pixie_run_pipeline_depth1_is_run_image(rng):
    img = rng.integers(0, 256, (9, 7)).astype(np.int32)
    pix = Pixie(GRID, mode="conventional")
    a = np.asarray(pix.run_pipeline(["sobel_x"], jnp.asarray(img)))
    pix.load(map_app(apps.ALL_APPS["sobel_x"](), GRID))
    b = np.asarray(pix.run_image(jnp.asarray(img)))
    np.testing.assert_array_equal(a, b)
    assert not pix._pipeline_fns  # no chain executable was built


def test_pixie_run_pipeline_requires_conventional(rng):
    img = rng.integers(0, 256, (8, 8)).astype(np.int32)
    pix = Pixie(GRID, mode="parameterized")
    with pytest.raises(RuntimeError, match="conventional"):
        pix.run_pipeline(CHAIN, jnp.asarray(img))


# -- serving front-ends -------------------------------------------------------


def test_frontend_chain_submit_bitwise(rng):
    cfgs = chain_configs()
    img = rng.integers(0, 256, (10, 12)).astype(np.int32)
    svc = FleetFrontend(fleet=PixieFleet(default_grid=GRID))
    h = svc.submit(CHAIN, img)
    np.testing.assert_array_equal(
        np.asarray(h.result()), staged_oracle(cfgs, img)
    )
    assert h.job().app == "gauss3+sobel_x+threshold"
    assert svc.stats.pipeline_dispatches == 1


def test_streaming_chain_submit_bitwise(rng):
    cfgs = chain_configs()
    img = rng.integers(0, 256, (10, 12)).astype(np.int32)
    with StreamingFrontend(fleet=PixieFleet(default_grid=GRID),
                           max_linger_s=0.01) as svc:
        h_chain = svc.submit(CHAIN, img)
        h_single = svc.submit("sobel_x", img)
        np.testing.assert_array_equal(
            np.asarray(h_chain.result(timeout=WAIT)),
            staged_oracle(cfgs, img),
        )
        np.testing.assert_array_equal(
            np.asarray(h_single.result(timeout=WAIT)),
            staged_oracle(cfgs[1:2], img),
        )
        assert h_chain.job().app == "gauss3+sobel_x+threshold"


# -- mesh row sharding (device-gated; CI forces host devices) -----------------


@needs_two_devices
@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_fleet_pipeline_rows2_bitwise(backend, rng):
    cfgs = chain_configs()
    images = [rng.integers(0, 256, hw).astype(np.int32)
              for hw in [(24, 16), (17, 13)]]
    fleet = PixieFleet(default_grid=GRID, backend=backend,
                       mesh=MeshSpec(rows=2))
    outs = fleet.run_many(
        [FleetRequest(pipeline=CHAIN, image=im) for im in images]
    )
    assert not fleet.stats.mesh_degraded
    for im, got in zip(images, outs):
        np.testing.assert_array_equal(np.asarray(got),
                                      staged_oracle(cfgs, im))


@pytest.mark.skipif(N_DEVICES < 4, reason="needs >= 4 local devices")
def test_fleet_pipeline_mesh2x2_bitwise(rng):
    cfgs = chain_configs()
    images = [rng.integers(0, 256, (21, 15)).astype(np.int32)
              for _ in range(4)]
    fleet = PixieFleet(default_grid=GRID, mesh=MeshSpec(app=2, rows=2))
    outs = fleet.run_many(
        [FleetRequest(pipeline=CHAIN, image=im) for im in images]
    )
    assert not fleet.stats.mesh_degraded
    for im, got in zip(images, outs):
        np.testing.assert_array_equal(np.asarray(got),
                                      staged_oracle(cfgs, im))
