"""MoE tests: routing/capacity semantics, shared experts, and the
shard_map-EP path vs the GSPMD path (run on forced multi-device meshes
in a subprocess to keep the main test process single-device)."""

import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import MoEConfig
from repro.models.moe import init_moe, moe_ffn


def _setup(E=8, k=2, shared=1, cf=8.0, d=16, f=32):
    moe = MoEConfig(num_experts=E, top_k=k, num_shared=shared, capacity_factor=cf)
    params = init_moe(jax.random.PRNGKey(0), d, f, moe, "swiglu")
    return moe, params, d


def test_output_shape_and_aux(rng):
    moe, params, d = _setup()
    x = jnp.asarray(rng.standard_normal((2, 8, d)).astype(np.float32))
    y, aux = moe_ffn(params, x, moe, "swiglu")
    assert y.shape == x.shape
    assert float(aux) > 0.0  # load-balance loss strictly positive


def test_dropless_differs_from_tight_capacity(rng):
    """With capacity_factor ~0, most tokens drop; dropless must differ."""
    moe, params, d = _setup(cf=0.01, shared=0)
    x = jnp.asarray(rng.standard_normal((2, 16, d)).astype(np.float32))
    y_tight, _ = moe_ffn(params, x, moe, "swiglu")
    y_free, _ = moe_ffn(params, x, moe, "swiglu", dropless=True)
    assert not np.allclose(np.asarray(y_tight), np.asarray(y_free))
    # tight capacity: C=1 per expert => almost all routed outputs are zero
    routed_norm = float(jnp.abs(y_tight).sum())
    assert routed_norm < float(jnp.abs(y_free).sum())


def test_shared_expert_always_active(rng):
    """With routed expert weights zeroed, output == shared-expert MLP."""
    moe, params, d = _setup(shared=2)
    params = dict(params)
    for kk in ("w_gate", "w_up", "w_down"):
        params[kk] = jnp.zeros_like(params[kk])
    x = jnp.asarray(rng.standard_normal((1, 8, d)).astype(np.float32))
    y, _ = moe_ffn(params, x, moe, "swiglu")
    from repro.models.layers import mlp

    shared_only = mlp(params["shared"], x.reshape(-1, d), "swiglu").reshape(x.shape)
    np.testing.assert_allclose(np.asarray(y), np.asarray(shared_only), atol=1e-6)


def test_grad_flows_through_router(rng):
    moe, params, d = _setup()
    x = jnp.asarray(rng.standard_normal((2, 8, d)).astype(np.float32))

    def loss(p):
        y, aux = moe_ffn(p, x, moe, "swiglu")
        return (y ** 2).mean() + aux

    g = jax.grad(loss)(params)
    assert float(jnp.abs(g["router"]).max()) > 0.0
    assert float(jnp.abs(g["w_gate"]).max()) > 0.0


_SUBPROCESS = textwrap.dedent("""
    import os, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import MoEConfig
    from repro.models.moe import init_moe, moe_ffn, moe_ffn_ep

    E = int(sys.argv[1])
    moe = MoEConfig(num_experts=E, top_k=2, num_shared=1, capacity_factor=8.0)
    params = init_moe(jax.random.PRNGKey(0), 32, 64, moe, "swiglu")
    x = jnp.asarray(np.random.default_rng(0).standard_normal((4, 16, 32)).astype(np.float32))
    y_ref, aux_ref = moe_ffn(params, x, moe, "swiglu")
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    with mesh:
        y, aux = jax.jit(lambda p, xx: moe_ffn_ep(p, xx, moe, "swiglu"))(params, x)
    assert np.allclose(np.asarray(y), np.asarray(y_ref), atol=2e-5), "outputs diverge"
    assert abs(float(aux) - float(aux_ref)) < 1e-6, "aux diverges"
    print("OK")
""")


@pytest.mark.parametrize("E", [8, 6])  # EP path (8%4==0) and F-fallback (6%4!=0)
def test_shardmap_ep_matches_plain(E):
    r = subprocess.run(
        [sys.executable, "-c", _SUBPROCESS, str(E)],
        capture_output=True, text=True, cwd=".", timeout=420,
    )
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout
