"""Fused device-side ingest: line-buffer formation inside the dispatch
must be *bitwise* identical to the host-side two-step oracle
(``applications.stencil_inputs`` + ``interpreter.pack_inputs`` + overlay)
-- across every library app, non-square frames, ragged multi-tenant
batches, and both the single-app and fleet entry points.  The batched
equivalence tests are parametrized over ``backend=xla|pallas`` so the
fused-ingest megakernel (interpret mode off-TPU) cannot drift from the
interpreter oracle without failing PRs."""

import jax.numpy as jnp
import numpy as np
import pytest

from conftest import shared_app_grid

from repro.core import map_app, sobel_grid
from repro.core import applications as apps
from repro.core.bitstream import VCGRAConfig
from repro.core.ingest import IngestError, IngestPlan, plan_for, tap_offsets
from repro.core.interpreter import (
    make_batched_fused_overlay_fn,
    make_overlay_fn,
    pack_inputs,
    pad_channels,
    run_app_fused,
)
from repro.runtime.fleet import FleetRequest, PixieFleet

ALL_NAMES = sorted(apps.ALL_APPS)
GRID_ALL = shared_app_grid(ALL_NAMES, name="ingest-shared")


def unfused_reference(grid, cfg, img):
    """The host-side two-step oracle the fused path must match bitwise."""
    taps = apps.stencil_inputs(jnp.asarray(img))
    feed = {k: v for k, v in taps.items() if k in cfg.input_order}
    x = pad_channels(pack_inputs(cfg, feed, grid.dtype), grid.num_inputs)
    y = make_overlay_fn(grid)(cfg.to_jax(), x)
    return np.asarray(y)


# -- plan construction --------------------------------------------------------


def test_plan_layout_and_assemble_attaches_it():
    cfg = map_app(apps.sobel_x(), sobel_grid())
    plan = cfg.ingest
    assert plan is not None and plan.radius == 1
    assert plan.num_taps == 9 and plan.tap_sel.shape == (18,)
    # 9 taps selected, 9 coefficient consts + 0 padding on the 18-wide VC
    assert int((plan.tap_sel < plan.num_taps).sum()) == 9
    offsets = tap_offsets(1)
    for c, name in enumerate(cfg.input_order):
        if name.startswith("p"):
            dj, di = int(name[1]) - 1, int(name[2]) - 1
            assert offsets[plan.tap_sel[c]] == (dj, di)
        else:
            assert plan.tap_sel[c] == plan.zero_row
            assert plan.const_vals[c] == cfg.const_values[name]


def test_plan_rejects_unfeedable_channels_and_overwide_apps():
    with pytest.raises(IngestError, match="neither"):
        plan_for(("p11", "weird"), {}, 4)
    with pytest.raises(ValueError, match="grid has"):
        plan_for(("p11", "p12"), {}, 1)


def test_plan_survives_config_json_roundtrip():
    cfg = map_app(apps.gaussian_blur(), GRID_ALL)
    back = VCGRAConfig.from_json(cfg.to_json())
    assert back.ingest is not None
    np.testing.assert_array_equal(back.ingest.tap_sel, cfg.ingest.tap_sel)
    np.testing.assert_array_equal(back.ingest.const_vals, cfg.ingest.const_vals)
    assert back.ingest.radius == cfg.ingest.radius


def test_plan_stack_rejects_mismatched():
    a = plan_for(("p11",), {}, 4)
    b = plan_for(("p11",), {}, 5)
    with pytest.raises(ValueError, match="does not match"):
        IngestPlan.stack([a, b], jnp.int32)
    with pytest.raises(ValueError, match="empty"):
        IngestPlan.stack([], jnp.int32)


# -- fused == unfused, bitwise ------------------------------------------------


@pytest.mark.parametrize("name", ALL_NAMES)
def test_fused_overlay_matches_unfused_all_apps(name, rng):
    """Every library app, non-square frame: single fused dispatch output
    == stencil_inputs + pack_inputs + overlay, bitwise."""
    img = rng.integers(0, 256, (13, 7)).astype(np.int32)
    cfg = map_app(apps.ALL_APPS[name](), GRID_ALL)
    ref = unfused_reference(GRID_ALL, cfg, img)
    got = np.asarray(run_app_fused(GRID_ALL, cfg, jnp.asarray(img)))
    np.testing.assert_array_equal(got, ref)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_batched_fused_matches_unfused_ragged(backend, rng):
    """Ragged multi-tenant non-square frames on one zero canvas: each
    [H, W] output slice is bitwise identical to the per-app unfused path,
    on both the XLA interpreter and the Pallas megakernel backends."""
    names = ["sobel_mag", "gauss3", "threshold", "identity", "laplace"]
    hws = [(5, 9), (12, 4), (7, 7), (3, 11), (10, 6)]
    images = [rng.integers(0, 256, hw).astype(np.int32) for hw in hws]
    configs = [map_app(apps.ALL_APPS[n](), GRID_ALL) for n in names]

    Hb = max(h for h, _ in hws)
    Wb = max(w for _, w in hws)
    canvas = np.zeros((len(names), Hb, Wb), dtype=np.int32)
    for i, img in enumerate(images):
        canvas[i, : img.shape[0], : img.shape[1]] = img

    fn = make_batched_fused_overlay_fn(GRID_ALL, backend=backend)
    ys = fn(
        VCGRAConfig.stack(configs),
        IngestPlan.stack([c.ingest for c in configs], GRID_ALL.dtype),
        jnp.asarray(canvas),
    )
    for i, (cfg, img) in enumerate(zip(configs, images)):
        H, W = img.shape
        got = np.asarray(ys[i]).reshape((-1, Hb, Wb))[:, :H, :W]
        ref = unfused_reference(GRID_ALL, cfg, img).reshape((-1, H, W))
        np.testing.assert_array_equal(got, ref)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_fleet_fused_all_apps_one_flush(backend, rng):
    """The full fleet path (submit raw frames, one fused dispatch) vs the
    sequential unfused oracle, all library apps, ragged non-square sizes,
    on both backends."""
    fleet = PixieFleet(default_grid=GRID_ALL, backend=backend)
    images = [
        rng.integers(0, 256, (5 + 2 * i, 17 - i)).astype(np.int32)
        for i in range(len(ALL_NAMES))
    ]
    outs = fleet.run_many(
        [FleetRequest(app=n, image=i) for n, i in zip(ALL_NAMES, images)]
    )
    assert fleet.stats.dispatches == 1 and fleet.stats.fused_dispatches == 1
    for name, img, y in zip(ALL_NAMES, images, outs):
        cfg = map_app(apps.ALL_APPS[name](), GRID_ALL)
        ref = unfused_reference(GRID_ALL, cfg, img).reshape((-1,) + img.shape)
        np.testing.assert_array_equal(np.atleast_3d(y if y.ndim == 3 else y[None]), ref)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_fleet_mixed_fused_and_channel_requests(backend, rng):
    """A flush mixing raw-frame (fused) and named-channel (packed) requests
    serves both, in two dispatches, all bitwise-exact -- exercising both
    the fused megakernel and the packed batched kernel under pallas."""
    grid = sobel_grid()
    img = rng.integers(0, 256, (6, 9)).astype(np.int32)
    x = rng.integers(0, 256, (23,)).astype(np.int32)
    fleet = PixieFleet(default_grid=grid, backend=backend)
    outs = fleet.run_many([
        FleetRequest(app="sobel_x", image=img),
        FleetRequest(app="threshold", inputs={"p11": x}),
    ])
    assert fleet.stats.dispatches == 2 and fleet.stats.fused_dispatches == 1
    np.testing.assert_array_equal(outs[0], apps.conv2d_reference(img, apps.SOBEL_X))
    np.testing.assert_array_equal(outs[1][0], (x > 128).astype(np.int32))


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_fused_compile_once_across_apps_and_shapes(backend, rng):
    """One fused executable serves every app (plans are runtime settings);
    pow-2 canvas bucketing keeps repeat flushes on it -- the compile-once
    contract holds identically for the pallas megakernel backend."""
    fleet = PixieFleet(default_grid=GRID_ALL, batch_tile=4, backend=backend)
    img = rng.integers(0, 256, (9, 9)).astype(np.int32)
    for names in (["sobel_x", "gauss3"], ["laplace", "identity"], ["sharpen"]):
        fleet.run_many([FleetRequest(app=n, image=img) for n in names])
    assert fleet.stats.overlay_builds == 1
    assert fleet.overlay_executable_count(GRID_ALL) in (1, -1)
    # a repeat tenant set also reuses the stacked settings+ingest bank
    fleet.run_many([FleetRequest(app=n, image=img) for n in ["sobel_x", "gauss3"]])
    assert fleet.stats.stack_bank_hits >= 1


def test_fused_timings_split(rng):
    fleet = PixieFleet(default_grid=sobel_grid())
    img = rng.integers(0, 256, (8, 8)).astype(np.int32)
    fleet.run_many([FleetRequest(app="sobel_x", image=img)])
    assert fleet.timings["pack_s"] >= 0 and fleet.timings["dispatch_s"] > 0
    assert fleet.timings["flush_s"] >= fleet.timings["dispatch_s"]


# -- satellite regressions ----------------------------------------------------


def test_pack_inputs_all_const_raises_or_takes_batch_shape():
    """An all-const channel set used to silently produce a scalar () batch
    (which the fleet then rejected with an unrelated shape error); now it
    raises a clear error unless the caller pins the batch shape."""
    from repro.core import DFG, for_dfg

    g = DFG("allconst")
    g.output(g.add(g.const("a", 3), g.const("b", 4)))
    grid = for_dfg(g, shape="exact")
    cfg = map_app(g, grid)
    with pytest.raises(ValueError, match="batch_shape"):
        pack_inputs(cfg, {}, grid.dtype)
    x = pack_inputs(cfg, {}, grid.dtype, batch_shape=(4,))
    assert x.shape == (len(cfg.input_order), 4)
    np.testing.assert_array_equal(np.asarray(x[0]), np.full((4,), 3))
    # the fleet surfaces the same clear error at submit time
    fleet = PixieFleet(default_grid=grid)
    with pytest.raises(ValueError, match="batch_shape"):
        fleet.submit(FleetRequest(app=g, inputs={}))


def test_fleet_result_eviction_error_names_ticket_and_bound(rng):
    img = rng.integers(0, 256, (4, 4)).astype(np.int32)
    fleet = PixieFleet(default_grid=sobel_grid(), max_retained_results=1)
    t0 = fleet.submit(FleetRequest(app="identity", image=img))
    t1 = fleet.submit(FleetRequest(app="identity", image=img))
    fleet.flush()  # retains only t1; t0 evicted by the bound
    with pytest.raises(KeyError, match=rf"ticket {t0}.*max_retained_results=1"):
        fleet.result(t0)
    np.testing.assert_array_equal(fleet.result(t1), img)
    with pytest.raises(KeyError, match="already redeemed"):
        fleet.result(t1)
