"""Per-architecture sharding plans over the production mesh.

Mesh axes: ``("data", "model")`` single-pod (16 x 16) or
``("pod", "data", "model")`` multi-pod (2 x 16 x 16).  Roles:

  batch        -> ("pod", "data")   pure DP across pods + within pod
  tensor/TP    -> "model"           heads, mlp hidden, vocab, experts (EP)
  KV seq (serve) -> "model"         long caches sequence-sharded
  ZeRO-1       -> optimizer moments additionally sharded over "data"

Attention TP picks per arch (divisibility against |model| = 16):
  * head-sharding (Megatron) when q AND kv head counts divide,
  * head_dim-sharding (contraction TP, psum per attention) otherwise,
  * replicate as last resort.

The plan is computed from the *abstract* parameter tree (path + shape
rules), so it drives both the dry-run lowering and real training.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig

MODEL_AXIS = "model"


def data_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def model_size(mesh: Mesh) -> int:
    return mesh.shape[MODEL_AXIS]


def _div(n: int, m: int) -> bool:
    return n % m == 0


@dataclasses.dataclass(frozen=True)
class ShardingPlan:
    cfg: ArchConfig
    mesh: Mesh
    attn_mode: str           # heads | qheads | seq | head_dim | replicate
    zero1: bool = True
    fsdp: bool = True        # shard otherwise-replicated big weights over
    #                          'data' (ZeRO-3-lite: AG at use, RS on grads)
    fsdp_min_size: int = 65536

    # -- parameter specs ----------------------------------------------------

    def _rule(self, pathstr: str, shape: Tuple[int, ...]) -> P:
        m = model_size(self.mesh)
        cfg = self.cfg

        def mdl(n: int):
            return MODEL_AXIS if _div(n, m) else None

        # embeddings -----------------------------------------------------
        if pathstr.endswith("embed/table"):
            return P(mdl(shape[0]), None)
        if pathstr.endswith("embed/unembed"):
            return P(None, mdl(shape[1]))
        if pathstr.endswith("meta"):
            return P(None, None)

        # attention (3D/4D weights) ---------------------------------------
        if "/attn/" in pathstr:
            name = pathstr.rsplit("/", 1)[-1]
            if self.attn_mode == "heads":
                if name == "wq":   # [D, G, Hg, hd]
                    return P(None, MODEL_AXIS, None, None)
                if name in ("wk", "wv"):  # [D, G, hd]
                    return P(None, MODEL_AXIS, None)
                if name == "wo":   # [G, Hg, hd, D]
                    return P(MODEL_AXIS, None, None, None)
            if self.attn_mode == "qheads":
                # Megatron on query heads only; tiny K/V projs replicated
                if name == "wq":
                    return P(None, None, MODEL_AXIS, None)
                if name == "wo":
                    return P(None, MODEL_AXIS, None, None)
                return P(*([None] * len(shape)))
            if self.attn_mode == "head_dim":
                if name == "wq":
                    return P(None, None, None, MODEL_AXIS)
                if name in ("wk", "wv"):
                    return P(None, None, MODEL_AXIS)
                if name == "wo":
                    return P(None, None, MODEL_AXIS, None)
            # 'seq' / 'replicate': weights replicated (seq mode parallelises
            # over the sequence via activation constraints instead)
            return P(*([None] * len(shape)))

        # MoE ---------------------------------------------------------------
        if "/moe/" in pathstr and "/shared/" not in pathstr:
            name = pathstr.rsplit("/", 1)[-1]
            E = cfg.moe.num_experts
            if name == "router":
                return P(None, None)
            if name in ("w_gate", "w_up") and len(shape) == 3:  # [E, D, F]
                return P(mdl(E), None, None if _div(E, m) else mdl(shape[2]))
            if name == "w_down" and len(shape) == 3:            # [E, F, D]
                return P(mdl(E), None if _div(E, m) else mdl(shape[1]), None)
        # shared-expert MLP falls through to the dense mlp rules below

        # dense MLP (also shared experts) -----------------------------------
        name = pathstr.rsplit("/", 1)[-1]
        if name in ("w_gate", "w_up") and len(shape) == 2:  # [D, F]
            return P(None, mdl(shape[1]))
        if name == "w_down" and len(shape) == 2:            # [F, D]
            return P(mdl(shape[0]), None)

        # xLSTM / hymba recurrent mixers: column-TP fights the head-grouped
        # reshapes (GSPMD shards the chunk-scan axis -> per-step involuntary
        # full remat, measured 310 TB/device HBM traffic on xlstm train_4k;
        # §Perf).  Replicate over 'model' (FSDP fallback shards over 'data');
        # the model axis is reused as extra batch parallelism inside the
        # mixers (axes.constrain_time_mixer).
        if ":mlstm/" in pathstr or ":slstm/" in pathstr:
            return P(*([None] * len(shape)))
        if name in ("ssm_in", "ssm_out"):
            return P(None, None)

        return P(*([None] * len(shape)))

    def _fsdp_fallback(self, spec: P, shape: Tuple[int, ...]) -> P:
        """Large fully-replicated weights -> shard one dim over 'data'."""
        if not self.fsdp or any(a is not None for a in spec):
            return spec
        if int(np.prod(shape)) < self.fsdp_min_size or len(shape) < 2:
            return spec
        dsize = _dtotal(self.mesh)
        daxes = data_axes(self.mesh)
        parts = list(spec)
        for i, dim in enumerate(shape):
            if _div(dim, dsize):
                parts[i] = daxes if len(daxes) > 1 else daxes[0]
                return P(*parts)
        return spec

    def param_specs(self, abstract_params):
        def spec(path, leaf):
            pathstr = "/".join(
                str(getattr(p, "key", getattr(p, "name", p))) for p in path
            )
            shape = leaf.shape
            if "blocks/" in pathstr:  # scan-stacked: leading n_superblocks dim
                body = shape[1:]
                inner = self._fsdp_fallback(self._rule(pathstr, body), body)
                return P(None, *inner)
            return self._fsdp_fallback(self._rule(pathstr, shape), shape)

        return jax.tree_util.tree_map_with_path(spec, abstract_params)

    def param_shardings(self, abstract_params):
        return jax.tree_util.tree_map(
            lambda s: NamedSharding(self.mesh, s),
            self.param_specs(abstract_params),
            is_leaf=lambda x: isinstance(x, P),
        )

    # -- optimizer (ZeRO-1): moments get an extra 'data' dim where free ------

    def opt_specs(self, abstract_params):
        pspecs = self.param_specs(abstract_params)
        dsize = int(np.prod([self.mesh.shape[a] for a in data_axes(self.mesh)]))
        daxes = data_axes(self.mesh)

        def zero1(path, leaf, ps):
            if not self.zero1:
                return ps
            parts = list(ps) + [None] * (len(leaf.shape) - len(ps))
            # 'data' may appear at most once in a spec (FSDP may have used it)
            used = set()
            for a in parts:
                for ax in (a if isinstance(a, tuple) else (a,)):
                    if ax is not None:
                        used.add(ax)
            if set(daxes) & used:
                return P(*parts)
            for i, (dim, cur) in enumerate(zip(leaf.shape, parts)):
                if cur is None and _div(dim, dsize) and dim >= dsize:
                    parts[i] = daxes if len(daxes) > 1 else daxes[0]
                    break
            return P(*parts)

        moment = jax.tree_util.tree_map(
            lambda l, ps: zero1((), l, ps), abstract_params, pspecs
        )
        return {"m": moment, "v": moment, "count": P()}

    # -- activations / inputs -------------------------------------------------

    def batch_spec(self, ndim: int) -> P:
        da = data_axes(self.mesh)
        lead = da if len(da) > 1 else da[0]
        return P(lead, *([None] * (ndim - 1)))

    def token_sharding(self):
        return NamedSharding(self.mesh, self.batch_spec(2))

    # -- decode cache ----------------------------------------------------------

    def cache_specs(self, abstract_cache, seq_shard_min: int = 8192):
        """KV caches: batch -> data, long sequence dims -> model;
        GLA/SSM states: batch -> data, state dv -> model where divisible."""
        m = model_size(self.mesh)
        da = data_axes(self.mesh)
        lead = da if len(da) > 1 else da[0]

        def spec(path, leaf):
            pathstr = "/".join(
                str(getattr(p, "key", getattr(p, "name", p))) for p in path
            )
            shape = leaf.shape
            stacked = "blocks/" in pathstr
            body = shape[1:] if stacked else shape
            name = pathstr.rsplit("/", 1)[-1]
            if name in ("k", "v"):        # [B, S, G, hd]
                B, S = body[0], body[1]
                bspec = lead if B % _dtotal(self.mesh) == 0 else None
                sspec = MODEL_AXIS if (S >= seq_shard_min and _div(S, m)) else None
                inner = P(bspec, sspec, None, None)
            elif name == "S":             # [B, H, dk, dv]
                B = body[0]
                bspec = lead if B % _dtotal(self.mesh) == 0 else None
                dv = body[-1]
                inner = P(bspec, None, None, MODEL_AXIS if _div(dv, m) else None)
            elif name in ("n", "c", "h"):  # [B, H, d]
                B = body[0]
                bspec = lead if B % _dtotal(self.mesh) == 0 else None
                inner = P(bspec, None, None)
            elif name == "conv":          # [B, K-1, inner]
                B = body[0]
                bspec = lead if B % _dtotal(self.mesh) == 0 else None
                inner = P(bspec, None, None)
            else:
                inner = P(*([None] * len(body)))
            return P(None, *inner) if stacked else inner

        return jax.tree_util.tree_map_with_path(spec, abstract_cache)

    def cache_shardings(self, abstract_cache):
        return jax.tree_util.tree_map(
            lambda s: NamedSharding(self.mesh, s),
            self.cache_specs(abstract_cache),
            is_leaf=lambda x: isinstance(x, P),
        )


def _dtotal(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in data_axes(mesh)]))


def choose_attn_mode(cfg: ArchConfig, mesh: Mesh, kind: str = "train") -> str:
    """Per-arch attention TP selection (measured trade-offs in
    EXPERIMENTS.md §Perf):

    * heads     KV-head Megatron TP -- only when q AND kv heads divide;
    * qheads    query-head Megatron TP, K/V projections replicated --
                when queries-per-group divides (e.g. glm4 Hg=16);
    * seq       sequence-parallel attention (replicated weights, queries
                sharded along S) -- train/prefill fallback; avoids both
                the 16x replicated compute of 'replicate' and the
                [Sq,Sk]-score all-reduce of 'head_dim' (544 GB/device on
                gemma-2b train_4k);
    * head_dim  contraction TP -- decode only (scores are [.., 1, S]);
    * replicate last resort.
    """
    m = model_size(mesh)
    if _div(cfg.num_heads, m) and _div(cfg.num_kv_heads, m):
        return "heads"
    if _div(cfg.num_heads // cfg.num_kv_heads, m):
        return "qheads"
    if kind == "decode":
        return "head_dim" if _div(cfg.head_dim, m) else "replicate"
    return "seq"


def make_plan(cfg: ArchConfig, mesh: Mesh, zero1: bool = True,
              attn_mode: Optional[str] = None, kind: str = "train") -> ShardingPlan:
    return ShardingPlan(
        cfg, mesh, attn_mode or choose_attn_mode(cfg, mesh, kind), zero1=zero1
    )


# -- overlay-mesh operand shardings (the VCGRA dispatch pipeline) --------------

def frame_sharding(mesh: Mesh) -> NamedSharding:
    """The :class:`NamedSharding` of a fused dispatch's frame operand on
    an overlay mesh (``parallel.axes.build_mesh``): app-sharded on the 1-D
    ``("app",)`` mesh, app x row-band sharded on the 2-D
    ``("app", "rows")`` mesh.  The fleet's sharded async ship path
    assembles per-device canvases into one global array under exactly this
    sharding -- the layout the shard_map executable's in-spec names, so
    jit inserts no boundary reshard copy."""
    from repro.parallel.axes import APP_AXIS, ROW_AXIS

    spec = (P(APP_AXIS, ROW_AXIS) if ROW_AXIS in mesh.axis_names
            else P(APP_AXIS))
    return NamedSharding(mesh, spec)
