from repro.parallel.axes import (
    APP_AXIS, ROW_AXIS, MeshSpec, app_mesh, build_mesh, constrain,
    halo_exchange_rows, shard_apps, shard_apps_rows,
)
from repro.parallel.sharding import (
    ShardingPlan, choose_attn_mode, data_axes, frame_sharding, make_plan,
    model_size,
)

__all__ = [
    "APP_AXIS", "MeshSpec", "ROW_AXIS", "ShardingPlan", "app_mesh",
    "build_mesh", "choose_attn_mode", "constrain", "data_axes",
    "frame_sharding", "halo_exchange_rows", "make_plan", "model_size",
    "shard_apps", "shard_apps_rows",
]
