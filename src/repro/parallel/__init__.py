from repro.parallel.sharding import (
    ShardingPlan, choose_attn_mode, data_axes, make_plan, model_size,
)

__all__ = [
    "ShardingPlan", "choose_attn_mode", "data_axes", "make_plan", "model_size",
]
