from repro.parallel.axes import app_mesh, constrain, shard_apps
from repro.parallel.sharding import (
    ShardingPlan, choose_attn_mode, data_axes, make_plan, model_size,
)

__all__ = [
    "ShardingPlan", "app_mesh", "choose_attn_mode", "constrain", "data_axes",
    "make_plan", "model_size", "shard_apps",
]
