"""Logical-axis sharding constraints that degrade to no-ops off-mesh.

``constrain(x, "batch", None, "model")`` applies a
``with_sharding_constraint`` against the ambient mesh (the ``with mesh:``
context used by the dry-run and the real launcher); under no mesh (CPU
unit tests) it is the identity, so model code can sprinkle constraints
freely."""

from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P


def _ambient_mesh():
    try:
        from jax._src import mesh as mesh_lib

        m = mesh_lib.thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    return None


def _resolve(logical: Optional[str], mesh) -> Optional[object]:
    if logical is None:
        return None
    if logical == "batch":
        axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        if not axes:
            return None
        return axes if len(axes) > 1 else axes[0]
    return logical if logical in mesh.axis_names else None


def constrain(x, *logical_axes: Optional[str]):
    mesh = _ambient_mesh()
    if mesh is None:
        return x
    if len(logical_axes) != x.ndim:
        raise ValueError(f"spec {logical_axes} vs rank {x.ndim}")
    spec = P(*(_resolve(a, mesh) for a in logical_axes))
    return jax.lax.with_sharding_constraint(x, spec)


def constrain_time_mixer(x):
    """Batch-split a recurrent mixer's input over EVERY divisible mesh axis.

    Recurrent scans (sLSTM steps, GLA chunks) cannot parallelise over
    'model', so the model axis would sit idle computing replicas; instead
    the batch dim absorbs it as extra data parallelism where divisibility
    allows (xlstm train: 16x per-device compute cut; §Perf)."""
    mesh = _ambient_mesh()
    if mesh is None:
        return x
    axes = []
    prod = 1
    for a in ("pod", "data", "model"):
        if a in mesh.axis_names and x.shape[0] % (prod * mesh.shape[a]) == 0:
            axes.append(a)
            prod *= mesh.shape[a]
    if not axes:
        return x
    spec = P(tuple(axes) if len(axes) > 1 else axes[0],
             *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, spec)
