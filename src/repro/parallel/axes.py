"""Logical-axis sharding constraints that degrade to no-ops off-mesh.

``constrain(x, "batch", None, "model")`` applies a
``with_sharding_constraint`` against the ambient mesh (the ``with mesh:``
context used by the dry-run and the real launcher); under no mesh (CPU
unit tests) it is the identity, so model code can sprinkle constraints
freely.

The overlay dispatch pipeline (``core/plan.py``) uses the app-axis
helpers below: ``app_mesh`` builds a 1-D mesh over local devices (None
when the host cannot honor it -- the single-device bitwise fallback) and
``shard_apps`` wraps a batched overlay executor in ``shard_map`` over the
leading app (N) axis of every operand and output."""

from __future__ import annotations

import functools
from typing import Callable, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P


def _ambient_mesh():
    try:
        from jax._src import mesh as mesh_lib

        m = mesh_lib.thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    return None


def _resolve(logical: Optional[str], mesh) -> Optional[object]:
    if logical is None:
        return None
    if logical == "batch":
        axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        if not axes:
            return None
        return axes if len(axes) > 1 else axes[0]
    return logical if logical in mesh.axis_names else None


def constrain(x, *logical_axes: Optional[str]):
    mesh = _ambient_mesh()
    if mesh is None:
        return x
    if len(logical_axes) != x.ndim:
        raise ValueError(f"spec {logical_axes} vs rank {x.ndim}")
    spec = P(*(_resolve(a, mesh) for a in logical_axes))
    return jax.lax.with_sharding_constraint(x, spec)


# -- app-axis sharding for the overlay dispatch pipeline ----------------------

APP_AXIS = "app"


def _shard_map_impl():
    """Version-compat shard_map (same dance as models/moe.py): jax>=0.6
    exposes jax.shard_map (check_vma), older jax ships it under
    jax.experimental (check_rep)."""
    if hasattr(jax, "shard_map"):
        return functools.partial(jax.shard_map, check_vma=False)
    from jax.experimental.shard_map import shard_map as _shard_map

    return functools.partial(_shard_map, check_rep=False)


def app_mesh(devices: int, axis: str = APP_AXIS) -> Optional[Mesh]:
    """A 1-D mesh over the first ``devices`` local devices, for sharding
    the app (N) axis of batched overlay dispatch.

    Returns ``None`` when ``devices <= 1`` or the host has fewer local
    devices than requested -- callers fall back to the single-device
    path, which is bitwise identical (the app axis is embarrassingly
    parallel), so a plan asking for more parallelism than the host offers
    degrades instead of erroring, mirroring :func:`constrain`.
    """
    if devices <= 1:
        return None
    avail = jax.local_devices()
    if len(avail) < devices:
        return None
    return Mesh(np.asarray(avail[:devices]), (axis,))


def shard_apps(fn: Callable, mesh: Mesh, num_args: int,
               axis: str = APP_AXIS) -> Callable:
    """shard_map ``fn`` over the leading app axis of all ``num_args``
    operands (pytrees whose every leaf carries a leading N) and of the
    output.  The per-app computation of the batched overlay executors is
    independent along N (the flat-gather offsets are local to each app),
    so sharded outputs are bitwise identical to the single-device run.
    Callers must pad N to a multiple of the mesh size first
    (``plan._with_app_padding``)."""
    spec = P(axis)
    return _shard_map_impl()(
        fn, mesh=mesh, in_specs=(spec,) * num_args, out_specs=spec
    )


def constrain_time_mixer(x):
    """Batch-split a recurrent mixer's input over EVERY divisible mesh axis.

    Recurrent scans (sLSTM steps, GLA chunks) cannot parallelise over
    'model', so the model axis would sit idle computing replicas; instead
    the batch dim absorbs it as extra data parallelism where divisibility
    allows (xlstm train: 16x per-device compute cut; §Perf)."""
    mesh = _ambient_mesh()
    if mesh is None:
        return x
    axes = []
    prod = 1
    for a in ("pod", "data", "model"):
        if a in mesh.axis_names and x.shape[0] % (prod * mesh.shape[a]) == 0:
            axes.append(a)
            prod *= mesh.shape[a]
    if not axes:
        return x
    spec = P(tuple(axes) if len(axes) > 1 else axes[0],
             *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, spec)
