"""Logical-axis sharding constraints that degrade to no-ops off-mesh.

``constrain(x, "batch", None, "model")`` applies a
``with_sharding_constraint`` against the ambient mesh (the ``with mesh:``
context used by the dry-run and the real launcher); under no mesh (CPU
unit tests) it is the identity, so model code can sprinkle constraints
freely.

The overlay dispatch pipeline (``core/plan.py``) uses the mesh helpers
below.  :class:`MeshSpec` is the structured device-placement axis of an
``OverlayPlan``: ``app`` shards the leading app (N) axis -- embarrassingly
parallel, PR 4 -- and ``rows`` shards the pixel-row axis of fused frames
into contiguous bands whose radius-wide seam halos are exchanged with a
``ppermute`` collective (:func:`halo_exchange_rows`), so one huge frame
can span devices.  ``build_mesh`` realizes a spec against the local
devices (None when the host cannot honor it -- the single-device bitwise
fallback); ``shard_apps`` / ``shard_apps_rows`` wrap a batched overlay
executor in ``shard_map`` over the 1-D / 2-D mesh."""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _ambient_mesh():
    try:
        from jax._src import mesh as mesh_lib

        m = mesh_lib.thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    return None


def _resolve(logical: Optional[str], mesh) -> Optional[object]:
    if logical is None:
        return None
    if logical == "batch":
        axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        if not axes:
            return None
        return axes if len(axes) > 1 else axes[0]
    return logical if logical in mesh.axis_names else None


def constrain(x, *logical_axes: Optional[str]):
    mesh = _ambient_mesh()
    if mesh is None:
        return x
    if len(logical_axes) != x.ndim:
        raise ValueError(f"spec {logical_axes} vs rank {x.ndim}")
    spec = P(*(_resolve(a, mesh) for a in logical_axes))
    return jax.lax.with_sharding_constraint(x, spec)


# -- mesh sharding for the overlay dispatch pipeline ---------------------------

APP_AXIS = "app"
ROW_AXIS = "rows"


@dataclasses.dataclass(frozen=True)
class MeshSpec:
    """The device-placement axis of an ``OverlayPlan``, as structured data.

    ``app``  how many ways the leading app (N) axis of a batched dispatch
             is sharded (the PR 4 axis, formerly a bare int kwarg);
    ``rows`` how many contiguous pixel-row bands a fused frame is split
             into across devices -- each shard owns ``band = H / rows``
             output rows and receives its seam neighbours' ``radius`` edge
             rows via :func:`halo_exchange_rows` before running the
             *unchanged* per-shard executor (the PR 7 in-kernel DMA
             pipeline composes per shard; the slab it sees is just a
             shorter frame).

    Frozen and hashable: the spec lives inside the plan, so it IS part of
    THE cache key.  ``MeshSpec()`` is the single-device identity;
    ``MeshSpec(app=k)`` is exactly the placement the deprecated
    bare-int device kwarg used to mean, and produces the same plan key,
    so pre-2-D executable populations are reused unchanged.
    """

    app: int = 1
    rows: int = 1

    def __post_init__(self):
        for name in ("app", "rows"):
            v = getattr(self, name)
            if not isinstance(v, int) or isinstance(v, bool) or v < 1:
                raise ValueError(
                    f"MeshSpec.{name} must be an int >= 1, got {v!r}"
                )

    @property
    def size(self) -> int:
        """Total devices the spec asks for (``app * rows``)."""
        return self.app * self.rows

    def app_only(self) -> "MeshSpec":
        """The 1-D projection of this spec: same app-axis width, no row
        sharding.  Unfused dispatches use it (pre-packed channels carry no
        row structure to band-shard)."""
        return MeshSpec(app=self.app)

    def shape(self) -> Tuple[int, int]:
        """``(app, rows)`` -- the stats/bench stamp of the spec."""
        return (self.app, self.rows)

    def __str__(self) -> str:
        return f"{self.app}x{self.rows}"


def build_mesh(spec: MeshSpec) -> Optional[Mesh]:
    """Realize a :class:`MeshSpec` against the local devices.

    ``MeshSpec(app=k)`` yields the same 1-D ``("app",)`` mesh as the
    historical app-axis path; ``rows > 1`` yields a 2-D
    ``("app", "rows")`` mesh where consecutive devices form one app
    shard's row band (row neighbours adjacent, so seam ``ppermute``
    traffic stays between nearby devices).  Returns ``None`` when the
    spec is the single-device identity or the host has fewer local
    devices than ``spec.size`` -- callers fall back to the single-device
    path, which is bitwise identical; the fleet records the degradation
    in ``FleetStats`` so dashboards see the parallelism actually granted.
    """
    if spec.size <= 1:
        return None
    avail = jax.local_devices()
    if len(avail) < spec.size:
        return None
    devs = np.asarray(avail[: spec.size])
    if spec.rows == 1:
        return Mesh(devs, (APP_AXIS,))
    return Mesh(devs.reshape(spec.app, spec.rows), (APP_AXIS, ROW_AXIS))


def _shard_map_impl():
    """Version-compat shard_map (same dance as models/moe.py): jax>=0.6
    exposes jax.shard_map (check_vma), older jax ships it under
    jax.experimental (check_rep)."""
    if hasattr(jax, "shard_map"):
        return functools.partial(jax.shard_map, check_vma=False)
    from jax.experimental.shard_map import shard_map as _shard_map

    return functools.partial(_shard_map, check_rep=False)


def app_mesh(devices: int, axis: str = APP_AXIS) -> Optional[Mesh]:
    """A 1-D mesh over the first ``devices`` local devices, for sharding
    the app (N) axis of batched overlay dispatch.

    Returns ``None`` when ``devices <= 1`` or the host has fewer local
    devices than requested -- callers fall back to the single-device
    path, which is bitwise identical (the app axis is embarrassingly
    parallel), so a plan asking for more parallelism than the host offers
    degrades instead of erroring, mirroring :func:`constrain`.
    """
    if devices <= 1:
        return None
    avail = jax.local_devices()
    if len(avail) < devices:
        return None
    return Mesh(np.asarray(avail[:devices]), (axis,))


def shard_apps(fn: Callable, mesh: Mesh, num_args: int,
               axis: str = APP_AXIS) -> Callable:
    """shard_map ``fn`` over the leading app axis of all ``num_args``
    operands (pytrees whose every leaf carries a leading N) and of the
    output.  The per-app computation of the batched overlay executors is
    independent along N (the flat-gather offsets are local to each app),
    so sharded outputs are bitwise identical to the single-device run.
    Callers must pad N to a multiple of the mesh size first
    (``plan._with_app_padding``)."""
    spec = P(axis)
    return _shard_map_impl()(
        fn, mesh=mesh, in_specs=(spec,) * num_args, out_specs=spec
    )


def halo_exchange_rows(slab: jnp.ndarray, radius: int, rows: int,
                       axis: str = ROW_AXIS) -> jnp.ndarray:
    """Exchange the radius-wide seam halos of a row-band shard.

    Inside a ``shard_map`` over ``rows`` row shards, each shard holds a
    contiguous band ``[n, band, W]`` of frame rows.  A stencil of tap
    ``radius`` r needs r rows above and below the band: mid-frame those
    are the *neighbour shard's* edge rows, at the frame border they are
    zeros (``form_tap_bank``'s zero-pad semantics).  ``jax.lax.ppermute``
    gives both for free -- each shard sends its bottom r rows down and its
    top r rows up, and a shard named as nobody's destination receives
    zeros -- so the concatenated ``[n, band + 2r, W]`` slab reads exactly
    like a ``band + 2r``-row frame whose borders happen to be real
    neighbour pixels.  Radius 0 is the identity: no collective is emitted
    (jaxpr-checkable), so radius-0 row sharding costs no communication.
    """
    r = int(radius)
    if r <= 0:
        return slab
    down = [(i, i + 1) for i in range(rows - 1)]   # my bottom rows -> next
    up = [(i + 1, i) for i in range(rows - 1)]     # my top rows -> previous
    above = jax.lax.ppermute(slab[:, -r:, :], axis, down)
    below = jax.lax.ppermute(slab[:, :r, :], axis, up)
    return jnp.concatenate([above, slab, below], axis=1)


def shard_apps_rows(fn: Callable, mesh: Mesh, radius: int,
                    app_axis: str = APP_AXIS,
                    row_axis: str = ROW_AXIS) -> Callable:
    """shard_map a batched *fused* overlay executor over a 2-D
    ``(app, rows)`` mesh: apps shard the leading N axis (as
    :func:`shard_apps`), rows shard the frame's pixel-row axis into
    contiguous bands.

    Each shard runs the UNCHANGED inner executor on its haloed band --
    after :func:`halo_exchange_rows` the ``[n, band + 2r, W]`` slab is
    indistinguishable from a short frame, so row tiling and the in-kernel
    DMA pipeline lower per shard exactly as they would per frame -- and
    keeps the middle ``band`` output rows: the discarded first/last r
    rows are the ones whose taps read the slab's *synthetic* zero border
    instead of rows two shards away, and every kept row's taps land on
    real band/halo rows, which is why sharded output is bitwise equal to
    the single-device run.  Callers pad H to ``band * rows`` with
    ``band >= radius`` first (``plan._with_mesh_padding``) so one
    single-hop exchange always suffices.

    The flat pixel axis of the output ``[N, K, H * W]`` is row-major, so
    each shard's ``band * W`` pixels are one contiguous block and the
    out-spec ``P(app, None, rows)`` reassembles frames with no data
    movement.
    """
    rows = mesh.shape[row_axis]
    r = int(radius)

    def banded(configs, ingests, slab):
        haloed = halo_exchange_rows(slab, r, rows, axis=row_axis)
        ys = fn(configs, ingests, haloed)
        n, band, W = slab.shape
        ys = ys.reshape(n, -1, band + 2 * r, W)[:, :, r:r + band, :]
        return ys.reshape(n, ys.shape[1], band * W)

    sharded = _shard_map_impl()(
        banded, mesh=mesh,
        in_specs=(P(app_axis), P(app_axis), P(app_axis, row_axis)),
        out_specs=P(app_axis, None, row_axis),
    )
    replicated = NamedSharding(mesh, P())

    def constrained(configs, ingests, images):
        # Partitioner workaround (jax 0.4.37): resharding an operand that
        # the compiler left device-sharded into a *partially replicated*
        # 2-D-mesh in_spec (settings banks ride P(app), replicated over
        # the rows axis) miscompiles into a sum over the unnamed axis --
        # padded settings arrive doubled per row shard.  Pinning the
        # banks fully replicated first makes the boundary reshard a plain
        # local slice; the banks are KB-scale settings, so replication is
        # the intended layout anyway (every row shard needs its app's
        # whole config).  Frames are fully specified by their in_spec and
        # unaffected.
        configs, ingests = jax.tree_util.tree_map(
            lambda a: jax.lax.with_sharding_constraint(a, replicated),
            (configs, ingests),
        )
        return sharded(configs, ingests, images)

    return constrained


def shard_pipeline_rows(stage_fn, mesh: Mesh, radii,
                        app_axis: str = APP_AXIS,
                        row_axis: str = ROW_AXIS) -> Callable:
    """Row-band sharding for PIPELINE plans: the 2-D mesh twin of
    :func:`shard_apps_rows` with a per-stage seam halo exchange *between*
    stages, so a whole chain's intermediates never leave their shard.

    Each stage re-runs :func:`halo_exchange_rows` at its own radius on the
    current band (the chain's intermediate), executes the unchanged
    batched fused stage on the haloed slab, crops the synthetic-border
    rows back off, then zeroes everything outside each app's true frame
    region (``hw``) before feeding the next stage -- without the mask,
    stage outputs on canvas/band padding (nonzero: their taps read real
    rows) would poison the next stage's border, which the staged oracle
    reads as zeros.  The mask needs each band row's GLOBAL row index,
    recovered from ``axis_index(rows) * band``.  Callers pad H to
    ``band * rows`` with ``band >= max(radii)`` first
    (``plan._with_pipeline_mesh_padding``) so every exchange is
    single-hop.

    Operands: ``(stage_settings, hw, images)`` -- per-stage
    ``(configs, ingests, out_ch)`` triples plus the int32 ``[N, 2]``
    valid-region sizes, all leaves leading with N.
    """
    rows = mesh.shape[row_axis]
    depth = len(radii)

    def banded(stage_settings, hw, slab):
        n, band, W = slab.shape
        row0 = jax.lax.axis_index(row_axis) * band
        rows_in = (
            (row0 + jnp.arange(band, dtype=jnp.int32))[None, :, None]
            < hw[:, 0][:, None, None]
        )
        cols_in = (
            jnp.arange(W, dtype=jnp.int32)[None, None, :]
            < hw[:, 1][:, None, None]
        )
        valid = jnp.logical_and(rows_in, cols_in)
        x = slab
        ys = None
        for si, r in enumerate(radii):
            r = int(r)
            haloed = halo_exchange_rows(x, r, rows, axis=row_axis)
            ys = stage_fn(r, stage_settings[si][0], stage_settings[si][1],
                          haloed)
            ys = ys.reshape(n, -1, band + 2 * r, W)[:, :, r:r + band, :]
            if si < depth - 1:
                out_ch = stage_settings[si][2]
                y = jnp.take_along_axis(
                    ys, out_ch.astype(jnp.int32)[:, None, None, None], axis=1
                )[:, 0]
                x = jnp.where(valid, y, 0)
        return ys.reshape(n, ys.shape[1], band * W)

    sharded = _shard_map_impl()(
        banded, mesh=mesh,
        in_specs=(P(app_axis), P(app_axis), P(app_axis, row_axis)),
        out_specs=P(app_axis, None, row_axis),
    )
    replicated = NamedSharding(mesh, P())

    def constrained(stage_settings, hw, images):
        # Same jax-0.4.37 partitioner workaround as shard_apps_rows: pin
        # the KB-scale settings banks (incl. hw) fully replicated so the
        # boundary reshard into the partially-replicated in_spec is a
        # plain local slice, not a miscompiled cross-row sum.
        stage_settings, hw = jax.tree_util.tree_map(
            lambda a: jax.lax.with_sharding_constraint(a, replicated),
            (stage_settings, hw),
        )
        return sharded(stage_settings, hw, images)

    return constrained


def constrain_time_mixer(x):
    """Batch-split a recurrent mixer's input over EVERY divisible mesh axis.

    Recurrent scans (sLSTM steps, GLA chunks) cannot parallelise over
    'model', so the model axis would sit idle computing replicas; instead
    the batch dim absorbs it as extra data parallelism where divisibility
    allows (xlstm train: 16x per-device compute cut; §Perf)."""
    mesh = _ambient_mesh()
    if mesh is None:
        return x
    axes = []
    prod = 1
    for a in ("pod", "data", "model"):
        if a in mesh.axis_names and x.shape[0] % (prod * mesh.shape[a]) == 0:
            axes.append(a)
            prod *= mesh.shape[a]
    if not axes:
        return x
    spec = P(tuple(axes) if len(axes) > 1 else axes[0],
             *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, spec)
