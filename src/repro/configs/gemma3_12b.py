"""gemma3-12b [hf:google/gemma-3 family; unverified tier]: 48L d3840 16H
GQA(kv=8) head_dim 256 d_ff 15360 vocab 262144; 5:1 local:global
attention pattern (window 1024), 128k context.

Eligible for long_500k: only 1/6 of layers see the full context; local
layers keep an O(window) ring cache (see DESIGN.md).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-12b",
    family="dense",
    num_layers=48,
    d_model=3840,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=15_360,
    vocab_size=262_144,
    pattern=("local", "local", "local", "local", "local", "global"),
    window=1024,
    mlp_type="geglu",
    scale_embed=True,
    tie_embeddings=True,
    sub_quadratic=True,
    notes="5:1 local:global; long_500k runs (mostly-local attention)",
)
