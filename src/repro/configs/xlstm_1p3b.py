"""xlstm-1.3b [arXiv:2405.04517; unverified tier]: 48 blocks d2048,
4 mLSTM heads, no separate FFN (d_ff=0 — the mLSTM block carries a
projection factor 2), vocab 50304; sLSTM blocks interleaved 7:1.

mLSTM runs as chunked gated linear attention (matrix state per head);
sLSTM is the sequential scalar recurrence (not parallelizable by design).
Constant-size state => eligible for long_500k.
"""

from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    head_dim=512,          # nominal; mLSTM uses inner=2*d, dh=inner/heads
    d_ff=0,
    vocab_size=50_304,
    pattern=("mlstm",) * 7 + ("slstm",),
    ssm=SSMConfig(state_dim=16, num_heads=4, head_dim=1024, chunk=256),
    tie_embeddings=True,
    sub_quadratic=True,
    notes="7:1 mLSTM:sLSTM; O(1) state per layer",
)
