"""paligemma-3b [arXiv:2407.07726]: SigLIP vision frontend (STUB — the
dry-run feeds precomputed patch embeddings per the brief) + gemma-2b
text backbone: 18L d2048 8H MQA(kv=1) head_dim 256 d_ff 16384 GeGLU
vocab 257216.  Prefix-LM masking: image patches attend bidirectionally.
"""

from repro.configs.base import ArchConfig

NUM_PATCHES = 256  # 224x224 / 14px SigLIP stub

CONFIG = ArchConfig(
    name="paligemma-3b",
    family="vlm",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16_384,
    vocab_size=257_216,
    pattern=("dense",),
    mlp_type="geglu",
    scale_embed=True,
    tie_embeddings=True,
    modality="vision_stub",
    prefix_tokens=NUM_PATCHES,
    sub_quadratic=False,
    notes="SigLIP frontend stubbed: input_specs provides patch embeddings",
)
