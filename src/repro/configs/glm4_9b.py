"""glm4-9b [hf:THUDM/glm-4-9b]: 40L d4096 32H GQA(kv=2) head_dim 128
d_ff 13696 vocab 151552; SwiGLU, RoPE."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="glm4-9b",
    family="dense",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    head_dim=128,
    d_ff=13_696,
    vocab_size=151_552,
    pattern=("dense",),
    mlp_type="swiglu",
    tie_embeddings=False,
    sub_quadratic=False,
)
