"""qwen2-moe-a2.7b [hf:Qwen/Qwen1.5-MoE-A2.7B]: 24L d2048 16H(MHA)
d_ff 1408 vocab 151936; 4 shared + 60 routed experts, top-4."""

from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=151_936,
    pattern=("moe",),
    moe=MoEConfig(num_experts=60, top_k=4, num_shared=4),
    mlp_type="swiglu",
    tie_embeddings=False,
    sub_quadratic=False,
)
