"""hymba-1.5b [arXiv:2411.13676]: 32L d1600 25H GQA(kv=5) head_dim 64
d_ff 5504 vocab 32001, ssm_state 16; parallel attention + mamba heads in
every layer, 128 learned meta tokens, sliding-window attention with
periodic global layers (here: layer 0 of each 8-layer superblock, i.e.
layers 0/8/16/24 -- an 8-layer scan body also keeps the remat working
set bounded; see EXPERIMENTS.md §Perf).

Hybrid constant-state + windowed attention => eligible for long_500k.
"""

from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32_001,
    pattern=("hymba_g",) + ("hymba",) * 7,
    window=1024,
    ssm=SSMConfig(state_dim=16, num_heads=25, head_dim=128, chunk=256),
    mlp_type="swiglu",
    meta_tokens=128,
    tie_embeddings=True,
    sub_quadratic=True,
    notes="global attention at layers 0/8/16/24; rest sliding-window 1024",
)
