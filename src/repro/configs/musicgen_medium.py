"""musicgen-medium [arXiv:2306.05284]: 48L d1536 24H(MHA) head_dim 64
d_ff 6144 vocab 2048; decoder-only over EnCodec tokens.

The EnCodec tokenizer/decoder (the audio modality frontend) is a STUB per
the brief: input_specs provides the token stream (and training batches are
synthetic codes); the text-conditioning cross-attention of the original is
simplified away (documented in DESIGN.md).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    pattern=("dense",),
    mlp_type="gelu",
    tie_embeddings=False,
    modality="audio_stub",
    sub_quadratic=False,
)
