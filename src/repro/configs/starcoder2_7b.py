"""starcoder2-7b [arXiv:2402.19173]: 32L d4608 36H GQA(kv=4) head_dim 128
d_ff 18432 vocab 49152; non-gated GELU FFN, RoPE."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-7b",
    family="dense",
    num_layers=32,
    d_model=4608,
    num_heads=36,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18_432,
    vocab_size=49_152,
    pattern=("dense",),
    mlp_type="gelu",
    tie_embeddings=False,
    sub_quadratic=False,
)
