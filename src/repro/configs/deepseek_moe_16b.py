"""deepseek-moe-16b [arXiv:2401.06066]: 28L d2048 16H(MHA) d_ff 1408
vocab 102400; fine-grained MoE: 2 shared + 64 routed experts, top-6.

Layer pattern: DeepSeek-MoE keeps its first layer dense (d_ff-sized here
per the assigned config) and all remaining 27 layers MoE.
"""

from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=102_400,
    prefix_pattern=("dense",),
    pattern=("moe",),
    moe=MoEConfig(num_experts=64, top_k=6, num_shared=2),
    mlp_type="swiglu",
    tie_embeddings=False,
    sub_quadratic=False,
    notes="fine-grained MoE; first layer dense (prefix)",
)
