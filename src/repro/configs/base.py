"""Architecture and shape configuration for the assigned workload matrix.

Every architecture is expressed as a *layer pattern*: an optional unrolled
prefix (e.g. DeepSeek's first dense layer) followed by ``n_superblocks``
repetitions of a per-superblock kind tuple, scanned with ``lax.scan`` so
the compiled HLO stays one-superblock-sized regardless of depth (48-layer
models compile like 1-layer models; essential for the 80-compile dry-run
matrix and for real-TPU compile latency alike).

Layer kinds understood by ``models/blocks.py``:
  dense    GQA attention + (Ge/Swi)GLU MLP
  local    like dense but sliding-window attention (cfg.window)
  global   explicit full attention (used inside mixed patterns)
  moe      GQA attention + (shared + routed top-k) MoE FFN
  mlstm    xLSTM matrix-LSTM block (chunked gated linear attention)
  slstm    xLSTM scalar-LSTM block (sequential recurrence)
  hymba    parallel attention + SSM heads in one layer (hybrid)
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    num_shared: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 16
    num_heads: int = 8
    head_dim: int = 64        # SSM channel dim per head
    chunk: int = 256


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # dense | moe | vlm | audio | ssm | hybrid
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # layer stacking
    prefix_pattern: Tuple[str, ...] = ()
    pattern: Tuple[str, ...] = ("dense",)
    # derived: n_superblocks = (num_layers - len(prefix)) // len(pattern)

    # attention
    rope_theta: float = 10_000.0
    window: int = 0                   # sliding-window size for 'local' kind
    mlp_type: str = "swiglu"          # swiglu | geglu
    scale_embed: bool = False         # gemma-style sqrt(d_model) embed scale
    tie_embeddings: bool = True

    # mixtures / ssm
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None

    # modality stubs
    modality: str = "text"            # text | vision_stub | audio_stub
    prefix_tokens: int = 0            # precomputed patch/frame/meta embeddings
    meta_tokens: int = 0              # hymba-style learned meta tokens

    # capability flags for the shape matrix
    sub_quadratic: bool = False       # eligible for long_500k
    notes: str = ""

    @property
    def n_superblocks(self) -> int:
        rem = self.num_layers - len(self.prefix_pattern)
        assert rem % len(self.pattern) == 0, (
            f"{self.name}: {rem} layers not divisible by pattern "
            f"{self.pattern}"
        )
        return rem // len(self.pattern)

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """The brief's applicability rule: long_500k only for sub-quadratic
    archs (SSM / hybrid / mostly-local attention); decoders run all else."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, (
            f"{cfg.name} is pure full-attention; long_500k requires "
            "sub-quadratic attention (see DESIGN.md)"
        )
    return True, ""


def reduced(cfg: ArchConfig, **overrides) -> ArchConfig:
    """A tiny same-family config for CPU smoke tests: same layer pattern
    and code paths, small dims."""
    pat_len = len(cfg.pattern)
    n_sb_red = 2 if pat_len <= 4 else 1
    small = dict(
        num_layers=len(cfg.prefix_pattern) + n_sb_red * pat_len,
        d_model=64,
        num_heads=4,
        num_kv_heads=max(1, min(cfg.num_kv_heads, 2)),
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        prefix_tokens=min(cfg.prefix_tokens, 4),
        meta_tokens=min(cfg.meta_tokens, 4),
        window=min(cfg.window, 16) if cfg.window else 0,
    )
    if cfg.moe is not None:
        small["moe"] = MoEConfig(
            num_experts=8,
            top_k=min(cfg.moe.top_k, 2),
            num_shared=min(cfg.moe.num_shared, 1),
            capacity_factor=4.0,  # ~dropless: keeps smoke tests deterministic
        )
    if cfg.ssm is not None:
        small["ssm"] = SSMConfig(state_dim=8, num_heads=2, head_dim=16, chunk=16)
    small.update(overrides)
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **small)


def param_count(cfg: ArchConfig) -> Dict[str, float]:
    """Closed-form parameter estimate (used by roofline MODEL_FLOPS and
    checked against the real init in tests)."""
    D, F, V = cfg.d_model, cfg.d_ff, cfg.vocab_size
    emb = V * D * (1 if cfg.tie_embeddings else 2)
    per_layer: Dict[str, float] = {}

    def attn_params() -> float:
        return D * cfg.q_dim + 2 * D * cfg.kv_dim + cfg.q_dim * D

    def mlp_params(width=None) -> float:
        f = width or F
        mats = 2 if cfg.mlp_type == "gelu" else 3  # gated: gate+up+down
        return mats * D * f

    kinds = list(cfg.prefix_pattern) + list(cfg.pattern) * cfg.n_superblocks
    total = float(emb)
    for kind in kinds:
        if kind in ("dense", "local", "global"):
            p = attn_params() + mlp_params() + 2 * D
        elif kind == "moe":
            m = cfg.moe
            p = attn_params() + 2 * D
            p += m.num_experts * mlp_params() + D * m.num_experts  # routed + router
            p += mlp_params(F * max(m.num_shared, 0)) if m.num_shared else 0
        elif kind == "mlstm":
            dh = 2 * D  # proj factor 2
            p = 2 * D * dh + dh * D + 3 * dh * dh // 4 + 4 * dh + 2 * D
        elif kind == "slstm":
            p = 4 * D * D + 4 * D + (D * int(4 * D / 3) * 2) + 2 * D
        elif kind in ("hymba", "hymba_g"):
            s = cfg.ssm
            ssm_inner = s.num_heads * s.head_dim
            p = attn_params() + 2 * D
            p += D * ssm_inner * 2 + ssm_inner * D          # in/out proj
            p += ssm_inner * (2 * s.state_dim + 2)          # B,C,dt,A
            p += mlp_params()
        else:
            raise ValueError(kind)
        per_layer[kind] = per_layer.get(kind, 0.0) + p
        total += p
    # active params (MoE: only top_k + shared experts count)
    active = total
    if cfg.moe is not None:
        m = cfg.moe
        n_moe = sum(1 for k in kinds if k == "moe")
        inactive = n_moe * (m.num_experts - m.top_k) * 3 * D * F
        active = total - inactive
    return {"total": total, "active": active, "embedding": float(emb)}
