"""Architecture registry: ``--arch <id>`` resolution for every launcher."""

from repro.configs.base import (
    ArchConfig, MoEConfig, SSMConfig, ShapeConfig, SHAPES,
    param_count, reduced, shape_applicable,
)
from repro.configs.deepseek_moe_16b import CONFIG as _deepseek
from repro.configs.qwen2_moe_a2p7b import CONFIG as _qwen2
from repro.configs.paligemma_3b import CONFIG as _paligemma
from repro.configs.gemma_2b import CONFIG as _gemma2b
from repro.configs.starcoder2_7b import CONFIG as _starcoder2
from repro.configs.glm4_9b import CONFIG as _glm4
from repro.configs.gemma3_12b import CONFIG as _gemma3
from repro.configs.musicgen_medium import CONFIG as _musicgen
from repro.configs.xlstm_1p3b import CONFIG as _xlstm
from repro.configs.hymba_1p5b import CONFIG as _hymba

ARCHS = {
    c.name: c
    for c in (
        _deepseek, _qwen2, _paligemma, _gemma2b, _starcoder2,
        _glm4, _gemma3, _musicgen, _xlstm, _hymba,
    )
}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


__all__ = [
    "ARCHS", "ArchConfig", "MoEConfig", "SSMConfig", "ShapeConfig",
    "SHAPES", "get_arch", "param_count", "reduced", "shape_applicable",
]
