"""gemma-2b [arXiv:2403.08295]: 18L d2048 8H MQA(kv=1) head_dim 256
d_ff 16384 GeGLU vocab 256000; sqrt(d)-scaled tied embeddings."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma-2b",
    family="dense",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16_384,
    vocab_size=256_000,
    pattern=("dense",),
    mlp_type="geglu",
    scale_embed=True,
    tie_embeddings=True,
    sub_quadratic=False,
)
