"""Jitted wrappers for the fused stencil kernel."""

from __future__ import annotations

import functools

import jax

from repro.core import applications as apps
from repro.kernels.stencil.stencil_kernel import stencil_fused


@functools.partial(jax.jit, static_argnames=("interpret", "block_h"))
def sobel_magnitude_fused(image, interpret: bool = True, block_h: int = 8):
    """Fully fused |Gx|+|Gy| Sobel magnitude (the beyond-paper fast path)."""
    return stencil_fused(
        image, (apps.SOBEL_X, apps.SOBEL_Y), block_h=block_h, interpret=interpret
    )


@functools.partial(jax.jit, static_argnames=("kernel_name", "interpret", "block_h"))
def conv3x3_fused(image, kernel_name: str, interpret: bool = True, block_h: int = 8):
    kq = {
        "sobel_x": apps.SOBEL_X,
        "sobel_y": apps.SOBEL_Y,
        "gauss3": apps.GAUSS3,
        "sharpen": apps.SHARPEN,
        "laplace": apps.LAPLACE,
        "box3": apps.BOX3,
    }[kernel_name]
    return stencil_fused(image, (kq,), block_h=block_h, interpret=interpret)
