"""Pure-jnp oracle for the fused stencil kernel."""

from __future__ import annotations

from typing import Sequence, Tuple

import jax.numpy as jnp


def stencil_ref(
    image: jnp.ndarray,
    kernels: Tuple[Tuple[Tuple[float, ...], ...], ...],
) -> jnp.ndarray:
    H, W = image.shape
    pad = jnp.pad(image, 1)
    outs = []
    for kq in kernels:
        acc = jnp.zeros((H, W), image.dtype)
        for r, dj in enumerate((-1, 0, 1)):
            for c, di in enumerate((-1, 0, 1)):
                coeff = float(kq[r][c])
                if coeff == 0.0:
                    continue
                acc = acc + coeff * pad[1 + dj : 1 + dj + H, 1 + di : 1 + di + W]
        outs.append(acc)
    if len(outs) == 2:
        return jnp.abs(outs[0]) + jnp.abs(outs[1])
    return outs[0]
