"""Pallas TPU kernel: fused 3x3 stencil (beyond-paper optimized path).

The Pixie overlay executes a stencil as ~20 PE ops with 18 channel-major
input rows (one per tap+coefficient).  A TPU does not need the overlay's
generality for a *fixed* filter: this kernel fuses the whole 3x3
convolution (optionally two of them + |.|+|.| for Sobel magnitude) into a
single VMEM pass with the coefficients in VREGs — the roofline-optimal
formulation the §Perf log compares the overlay against.

Halo handling: the caller passes three row-shifted views of the
zero-padded image (top/mid/bot).  Each view is blocked ``(block_h, Wp)``
with full padded width per block, so horizontal taps are VREG-local
static slices; only the row halo costs the 3x read amplification (a real
HBM-resident implementation would use overlapped DMA; noted in DESIGN.md).
"""

from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128


def _taps(rows, W: int):
    """rows: (top, mid, bot) blocks [bh, Wp]; yields the 9 taps [bh, W]."""
    for r, row in enumerate(rows):
        for di in range(3):
            yield r, di, row[:, di : di + W]


def _stencil_body(kernels, W, x_t, x_m, x_b, o_ref):
    rows = (x_t[...], x_m[...], x_b[...])
    outs = []
    for kq in kernels:
        acc = None
        for r, di, tap in _taps(rows, W):
            c = float(kq[r][di])
            if c == 0.0:
                continue
            term = tap * c
            acc = term if acc is None else acc + term
        outs.append(acc)
    if len(outs) == 2:  # Sobel magnitude fusion: |gx| + |gy|
        res = jnp.abs(outs[0]) + jnp.abs(outs[1])
    else:
        res = outs[0]
    o_ref[...] = jnp.pad(res, ((0, 0), (0, o_ref.shape[1] - W))).astype(o_ref.dtype)


def stencil_fused(
    image: jnp.ndarray,
    kernels: Tuple[Tuple[Tuple[float, ...], ...], ...],
    block_h: int = 8,
    interpret: bool = True,
) -> jnp.ndarray:
    """Fused stencil over a [H, W] image; one kernel -> conv output,
    two kernels -> |k0*img| + |k1*img| (Sobel magnitude)."""
    H, W = image.shape
    Hp = H + (-H) % block_h
    Wp = W + 2
    Wp = Wp + (-Wp) % LANE
    pad = jnp.zeros((Hp + 2, Wp), image.dtype)
    pad = pad.at[1 : H + 1, 1 : W + 1].set(image)
    top = pad[0:Hp, :]
    mid = pad[1 : Hp + 1, :]
    bot = pad[2 : Hp + 2, :]

    body = functools.partial(_stencil_body, kernels, W)
    out = pl.pallas_call(
        body,
        out_shape=jax.ShapeDtypeStruct((Hp, Wp), image.dtype),
        grid=(Hp // block_h,),
        in_specs=[
            pl.BlockSpec((block_h, Wp), lambda i: (i, 0)),
            pl.BlockSpec((block_h, Wp), lambda i: (i, 0)),
            pl.BlockSpec((block_h, Wp), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_h, Wp), lambda i: (i, 0)),
        interpret=interpret,
    )(top, mid, bot)
    return out[:H, :W]
