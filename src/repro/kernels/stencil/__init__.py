from repro.kernels.stencil.ops import conv3x3_fused, sobel_magnitude_fused
from repro.kernels.stencil.ref import stencil_ref

__all__ = ["conv3x3_fused", "sobel_magnitude_fused", "stencil_ref"]
