"""Pallas TPU kernel: the VCGRA grid executor.

TPU-native adaptation of the Pixie pipeline (see DESIGN.md): the pixel
stream is tiled HBM -> VMEM in lane-aligned blocks, and the PE-level
pipeline of the overlay executes per tile entirely in VMEM/VREGs.  Two
variants mirror the paper's two implementations:

* **specialized** (parameterized configuration): the settings are trace-
  time constants; each PE emits exactly its configured functional unit and
  every VC mux folds into direct SSA wiring.  This is the TLUT/TCON
  analogue and the fast path.

* **conventional**: the settings live in SMEM (scalar-prefetched, the
  settings-register analogue); every PE evaluates the full functional-unit
  mux chain and routing is performed with dynamic row selects against the
  previous level's VMEM value matrix.  Same executable serves every
  application mapped on the grid -- at the cost the paper's Table I
  quantifies.

Block layout: inputs are stacked channel-major ``[num_inputs, N]`` where N
is the flattened pixel batch; blocks are ``(num_inputs, block_n)`` with
``block_n`` a multiple of 128 (lane width).  The level pipeline is fully
unrolled inside the kernel: VMEM working set is
``O(max_level_width * block_n)`` elements.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import ops as pe_ops
from repro.core.bitstream import VCGRAConfig
from repro.core.grid import GridSpec
from repro.core.ops import Op
from repro.core.specialize import _live_slots

LANE = 128


# -- specialized kernel --------------------------------------------------------


def _specialized_body(grid: GridSpec, config: VCGRAConfig, x_ref, o_ref):
    """Kernel body with config burned in: a pure unrolled dataflow pipeline."""
    x = x_ref[...]
    dtype = x.dtype
    live = _live_slots(grid, config)
    const_idx = {}
    prev = {}
    for lvl in range(grid.num_levels):
        cur = {}
        for slot in sorted(live[lvl]):
            op = Op(int(config.opcodes[lvl][slot]))
            if op == Op.NONE:
                cur[slot] = jnp.zeros(x.shape[1:], dtype)
                continue
            sa = int(config.selects[lvl][slot, 0])
            sb = int(config.selects[lvl][slot, 1])
            a = x[sa] if lvl == 0 else prev[sa]
            b = a if op in pe_ops.UNARY_OPS else (x[sb] if lvl == 0 else prev[sb])
            cur[slot] = pe_ops.apply_op(op, a, b)
        prev = cur
    rows = [prev[int(s)] for s in config.out_sel]
    o_ref[...] = jnp.stack(rows, axis=0)


def vcgra_specialized(
    grid: GridSpec,
    config: VCGRAConfig,
    x: jnp.ndarray,
    block_n: int = 1024,
    interpret: bool = True,
) -> jnp.ndarray:
    """Specialized-path pallas executor.  x: [num_inputs, N] (N % block_n == 0)."""
    n_in, n = x.shape
    assert n % block_n == 0, f"N={n} not a multiple of block_n={block_n}"
    assert block_n % LANE == 0, f"block_n must be lane-aligned (x{LANE})"
    body = functools.partial(_specialized_body, grid, config)
    return pl.pallas_call(
        body,
        out_shape=jax.ShapeDtypeStruct((grid.num_outputs, n), x.dtype),
        grid=(n // block_n,),
        in_specs=[pl.BlockSpec((n_in, block_n), lambda i: (0, i))],
        out_specs=pl.BlockSpec((grid.num_outputs, block_n), lambda i: (0, i)),
        interpret=interpret,
    )(x)


# -- conventional kernel ---------------------------------------------------------


def _conventional_body(grid: GridSpec, max_w: int, op_ref, sel_ref, out_ref, x_ref, o_ref):
    """Settings in SMEM; generic PEs; dynamic routing selects.

    op_ref:  SMEM int32 [num_levels, max_w]
    sel_ref: SMEM int32 [num_levels, max_w, 2]
    out_ref: SMEM int32 [num_outputs]
    """
    x = x_ref[...]                      # [num_inputs, block_n]
    dtype = x.dtype
    prev = x
    for lvl in range(grid.num_levels):  # grid structure static, settings not
        width = grid.pes_per_level[lvl]
        a_rows = []
        b_rows = []
        for slot in range(width):
            sa = sel_ref[lvl, slot, 0]
            sb = sel_ref[lvl, slot, 1]
            a_rows.append(jax.lax.dynamic_index_in_dim(prev, sa, 0, keepdims=False))
            b_rows.append(jax.lax.dynamic_index_in_dim(prev, sb, 0, keepdims=False))
        a = jnp.stack(a_rows, axis=0)
        b = jnp.stack(b_rows, axis=0)
        opcodes = jnp.stack([op_ref[lvl, s] for s in range(width)])
        prev = pe_ops.apply_generic(opcodes, a, b)
    rows = [
        jax.lax.dynamic_index_in_dim(prev, out_ref[k], 0, keepdims=False)
        for k in range(grid.num_outputs)
    ]
    o_ref[...] = jnp.stack(rows, axis=0).astype(dtype)


def _pack_settings(grid: GridSpec, config: VCGRAConfig):
    import numpy as np

    max_w = max(grid.pes_per_level)
    ops_arr = np.zeros((grid.num_levels, max_w), np.int32)
    sel_arr = np.zeros((grid.num_levels, max_w, 2), np.int32)
    for lvl in range(grid.num_levels):
        w = grid.pes_per_level[lvl]
        ops_arr[lvl, :w] = config.opcodes[lvl]
        sel_arr[lvl, :w] = config.selects[lvl]
    return jnp.asarray(ops_arr), jnp.asarray(sel_arr), jnp.asarray(config.out_sel), max_w


def vcgra_conventional(
    grid: GridSpec,
    config_arrays: Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray],
    x: jnp.ndarray,
    block_n: int = 1024,
    interpret: bool = True,
) -> jnp.ndarray:
    """Conventional-path pallas executor: one executable per *grid*, any
    application's packed settings arrays accepted at runtime."""
    ops_arr, sel_arr, out_sel = config_arrays
    n_in, n = x.shape
    assert n % block_n == 0 and block_n % LANE == 0
    max_w = ops_arr.shape[1]
    body = functools.partial(_conventional_body, grid, max_w)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(n // block_n,),
        in_specs=[pl.BlockSpec((n_in, block_n), lambda i, *_: (0, i))],
        out_specs=pl.BlockSpec(
            (grid.num_outputs, block_n), lambda i, *_: (0, i)
        ),
    )
    return pl.pallas_call(
        body,
        out_shape=jax.ShapeDtypeStruct((grid.num_outputs, n), x.dtype),
        grid_spec=grid_spec,
        interpret=interpret,
    )(ops_arr, sel_arr, out_sel, x)
