"""Pallas TPU kernel: the VCGRA grid executor.

TPU-native adaptation of the Pixie pipeline (see DESIGN.md): the pixel
stream is tiled HBM -> VMEM in lane-aligned blocks, and the PE-level
pipeline of the overlay executes per tile entirely in VMEM/VREGs.  Two
variants mirror the paper's two implementations:

* **specialized** (parameterized configuration): the settings are trace-
  time constants; each PE emits exactly its configured functional unit and
  every VC mux folds into direct SSA wiring.  This is the TLUT/TCON
  analogue and the fast path.

* **conventional**: the settings live in SMEM (scalar-prefetched, the
  settings-register analogue); every PE evaluates the full functional-unit
  mux chain and routing is performed with dynamic row selects against the
  previous level's VMEM value matrix.  Same executable serves every
  application mapped on the grid -- at the cost the paper's Table I
  quantifies.

Block layout: inputs are stacked channel-major ``[num_inputs, N]`` where N
is the flattened pixel batch; blocks are ``(num_inputs, block_n)`` with
``block_n`` a multiple of 128 (lane width).  The level pipeline is fully
unrolled inside the kernel: VMEM working set is
``O(max_level_width * block_n)`` elements.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import ops as pe_ops
from repro.core.bitstream import VCGRAConfig
from repro.core.grid import GridSpec
from repro.core.ingest import tap_offsets
from repro.core.ops import Op
from repro.core.specialize import _live_slots

# LANE is defined in core/tiling.py (the tile-height resolver and the
# kernel must agree on one constant); re-exported here, its historical
# home, for the callers that import it from the kernel package.
from repro.core.tiling import LANE, num_row_tiles, resolve_tile_rows  # noqa: F401


def default_interpret() -> bool:
    """Pallas interpret-mode default: compiled on a real TPU, interpreted
    everywhere else (CPU/GPU CI).  Callers can always override."""
    return jax.default_backend() != "tpu"


def _resolve_interpret(interpret: Optional[bool]) -> bool:
    return default_interpret() if interpret is None else bool(interpret)


# -- specialized kernel --------------------------------------------------------


def _specialized_body(grid: GridSpec, config: VCGRAConfig, x_ref, o_ref):
    """Kernel body with config burned in: a pure unrolled dataflow pipeline."""
    x = x_ref[...]
    dtype = x.dtype
    live = _live_slots(grid, config)
    const_idx = {}
    prev = {}
    for lvl in range(grid.num_levels):
        cur = {}
        for slot in sorted(live[lvl]):
            op = Op(int(config.opcodes[lvl][slot]))
            if op == Op.NONE:
                cur[slot] = jnp.zeros(x.shape[1:], dtype)
                continue
            sa = int(config.selects[lvl][slot, 0])
            sb = int(config.selects[lvl][slot, 1])
            a = x[sa] if lvl == 0 else prev[sa]
            b = a if op in pe_ops.UNARY_OPS else (x[sb] if lvl == 0 else prev[sb])
            cur[slot] = pe_ops.apply_op(op, a, b)
        prev = cur
    rows = [prev[int(s)] for s in config.out_sel]
    o_ref[...] = jnp.stack(rows, axis=0)


def vcgra_specialized(
    grid: GridSpec,
    config: VCGRAConfig,
    x: jnp.ndarray,
    block_n: int = 1024,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Specialized-path pallas executor.  x: [num_inputs, N] (N % block_n == 0).

    ``interpret=None`` auto-detects the platform (compiled on TPU,
    interpreted elsewhere)."""
    interpret = _resolve_interpret(interpret)
    n_in, n = x.shape
    assert n % block_n == 0, f"N={n} not a multiple of block_n={block_n}"
    assert block_n % LANE == 0, f"block_n must be lane-aligned (x{LANE})"
    body = functools.partial(_specialized_body, grid, config)
    return pl.pallas_call(
        body,
        out_shape=jax.ShapeDtypeStruct((grid.num_outputs, n), x.dtype),
        grid=(n // block_n,),
        in_specs=[pl.BlockSpec((n_in, block_n), lambda i: (0, i))],
        out_specs=pl.BlockSpec((grid.num_outputs, block_n), lambda i: (0, i)),
        interpret=interpret,
    )(x)


# -- conventional kernel ---------------------------------------------------------


def _level_pipeline(grid: GridSpec, idx: Tuple, op_ref, sel_ref,
                    x: jnp.ndarray) -> jnp.ndarray:
    """The conventional PE-level pipeline, shared by the single-app and
    batched kernel bodies.

    ``idx`` prefixes every SMEM read: ``()`` for per-app settings refs
    (``op_ref [L, max_w]``), ``(i,)`` for batched banks with a leading app
    axis (``op_ref [N, L, max_w]``).  ``x``: [num_inputs, pixels] ->
    [last_level_width, pixels].  Dense settings are padded to max_w but
    only the grid's true per-level width is ever read, so pad slots cost
    nothing.
    """
    prev = x
    for lvl in range(grid.num_levels):  # grid structure static, settings not
        width = grid.pes_per_level[lvl]
        a_rows = []
        b_rows = []
        for slot in range(width):
            sa = sel_ref[idx + (lvl, slot, 0)]
            sb = sel_ref[idx + (lvl, slot, 1)]
            a_rows.append(jax.lax.dynamic_index_in_dim(prev, sa, 0, keepdims=False))
            b_rows.append(jax.lax.dynamic_index_in_dim(prev, sb, 0, keepdims=False))
        a = jnp.stack(a_rows, axis=0)
        b = jnp.stack(b_rows, axis=0)
        opcodes = jnp.stack([op_ref[idx + (lvl, s)] for s in range(width)])
        prev = pe_ops.apply_generic(opcodes, a, b)
    return prev


def _gather_outputs(grid: GridSpec, idx: Tuple, outsel_ref, prev: jnp.ndarray, dtype):
    rows = [
        jax.lax.dynamic_index_in_dim(prev, outsel_ref[idx + (k,)], 0, keepdims=False)
        for k in range(grid.num_outputs)
    ]
    return jnp.stack(rows, axis=0).astype(dtype)


def _conventional_body(grid: GridSpec, op_ref, sel_ref, out_ref, x_ref, o_ref):
    """Settings in SMEM; generic PEs; dynamic routing selects.

    op_ref:  SMEM int32 [num_levels, max_w]
    sel_ref: SMEM int32 [num_levels, max_w, 2]
    out_ref: SMEM int32 [num_outputs]
    """
    x = x_ref[...]                      # [num_inputs, block_n]
    prev = _level_pipeline(grid, (), op_ref, sel_ref, x)
    o_ref[...] = _gather_outputs(grid, (), out_ref, prev, x.dtype)


def _pack_settings(grid: GridSpec, config: VCGRAConfig):
    import numpy as np

    max_w = max(grid.pes_per_level)
    ops_arr = np.zeros((grid.num_levels, max_w), np.int32)
    sel_arr = np.zeros((grid.num_levels, max_w, 2), np.int32)
    for lvl in range(grid.num_levels):
        w = grid.pes_per_level[lvl]
        ops_arr[lvl, :w] = config.opcodes[lvl]
        sel_arr[lvl, :w] = config.selects[lvl]
    return jnp.asarray(ops_arr), jnp.asarray(sel_arr), jnp.asarray(config.out_sel), max_w


def vcgra_conventional(
    grid: GridSpec,
    config_arrays: Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray],
    x: jnp.ndarray,
    block_n: int = 1024,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Conventional-path pallas executor: one executable per *grid*, any
    application's packed settings arrays accepted at runtime.
    ``interpret=None`` auto-detects the platform."""
    interpret = _resolve_interpret(interpret)
    ops_arr, sel_arr, out_sel = config_arrays
    n_in, n = x.shape
    assert n % block_n == 0 and block_n % LANE == 0
    body = functools.partial(_conventional_body, grid)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(n // block_n,),
        in_specs=[pl.BlockSpec((n_in, block_n), lambda i, *_: (0, i))],
        out_specs=pl.BlockSpec(
            (grid.num_outputs, block_n), lambda i, *_: (0, i)
        ),
    )
    return pl.pallas_call(
        body,
        out_shape=jax.ShapeDtypeStruct((grid.num_outputs, n), x.dtype),
        grid_spec=grid_spec,
        interpret=interpret,
    )(ops_arr, sel_arr, out_sel, x)


# -- batched megakernels -------------------------------------------------------
#
# The multi-tenant twins of the interpreter's batched paths
# (``interpreter.batched_overlay_step`` / ``batched_fused_overlay_step``):
# ONE pallas_call whose grid iterates the app axis, with every tenant's
# settings bank (PE opcodes, VC mux selects, output selects -- and for the
# fused variant the ingest plan's tap selects) scalar-prefetched into SMEM.
# The kernel instance for app ``i`` indexes its own settings rows with
# ``pl.program_id(0)``, so N different applications execute through one
# compiled kernel -- the settings-register analogue at fleet scale.


def _batched_body(grid: GridSpec, op_ref, sel_ref, outsel_ref, x_ref, o_ref):
    """One app per grid step over pre-packed channels [1, C, block_n]."""
    i = pl.program_id(0)
    x = x_ref[0]
    prev = _level_pipeline(grid, (i,), op_ref, sel_ref, x)
    o_ref[0] = _gather_outputs(grid, (i,), outsel_ref, prev, x.dtype)


def vcgra_batched(
    grid: GridSpec,
    settings: Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray],
    x: jnp.ndarray,
    block_n: int = LANE,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Batched conventional executor: N tenants in ONE pallas_call.

    ``settings``: dense-packed banks (ops [N, L, max_w], sel [N, L, max_w, 2],
    out_sel [N, K]) -- see ``ops.pack_settings_batched``.
    ``x``: [N, num_inputs, B] with ``B % block_n == 0``.
    """
    interpret = _resolve_interpret(interpret)
    ops_arr, sel_arr, out_sel = settings
    n_apps, n_in, b = x.shape
    assert b % block_n == 0, f"B={b} not a multiple of block_n={block_n}"
    assert block_n % LANE == 0, f"block_n must be lane-aligned (x{LANE})"
    body = functools.partial(_batched_body, grid)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(n_apps, b // block_n),
        in_specs=[pl.BlockSpec((1, n_in, block_n), lambda i, j, *_: (i, 0, j))],
        out_specs=pl.BlockSpec(
            (1, grid.num_outputs, block_n), lambda i, j, *_: (i, 0, j)
        ),
    )
    return pl.pallas_call(
        body,
        out_shape=jax.ShapeDtypeStruct((n_apps, grid.num_outputs, b), x.dtype),
        grid_spec=grid_spec,
        interpret=interpret,
    )(ops_arr, sel_arr, out_sel, x)


def _fused_batched_body(
    grid: GridSpec, radius: int, tile_rows: int,
    tap_sel_ref, op_ref, sel_ref, outsel_ref, const_ref, frames_ref, o_ref,
    slabs_ref, dma_sems_ref,
):
    """Fused-ingest megakernel body: one row-haloed slab -> outputs, per
    (app, row-tile) grid step, with the slab streamed HBM->VMEM by an
    in-kernel double-buffered DMA.

    ``frames_ref`` is the whole zero-row-padded frame stack
    ``[N, T*tile_rows + 2r, W]`` left in HBM (``memory_space=ANY`` -- the
    block pipeline never copies it); each grid step DMAs its own
    ``[tile_rows + 2r, W]`` halo window straight out of the un-duplicated
    frame into one of two VMEM slab buffers (``slabs_ref``) and *starts
    the next step's window into the other buffer before computing*, so
    tile t+1 streams in while tile t's PE pipeline executes.  The buffer
    slot rotates on the LINEARIZED step index ``i*T + t`` (rotating on the
    tile index alone desynchronizes producer and consumer at app
    boundaries whenever T is odd).  Halo rows are re-read from HBM only at
    tile seams (``2r`` rows per interior seam) -- never duplicated into an
    HBM-resident slab tensor like the old host-side pre-slice.

    The rest is the whole Pixie data path inside the kernel instance: the
    slab is column-padded and sliced into the tap bank (line-buffer
    formation; offsets are trace-time constants), each memory-VC channel
    *selects* its producer from the bank via the SMEM tap_sel row (ingest
    plans are runtime settings, like VC muxes), then the conventional PE
    pipeline executes on the channels -- all without the slab ever leaving
    VMEM.  The untiled layout is simply T == 1: one window covering the
    whole padded frame, same body, no second buffer ever filled.
    """
    i = pl.program_id(0)
    t = pl.program_id(1)
    n_tiles = pl.num_programs(1)
    step = i * n_tiles + t
    slot = jax.lax.rem(step, 2)
    r = radius
    tr = tile_rows

    def slab_dma(slot, app, tile):
        return pltpu.make_async_copy(
            frames_ref.at[app, pl.ds(tile * tr, tr + 2 * r), :],
            slabs_ref.at[slot],
            dma_sems_ref.at[slot],
        )

    @pl.when(step == 0)
    def _():
        slab_dma(0, 0, 0).start()        # warm-up: first window, slot 0

    # Start the NEXT step's window into the other buffer, then block on
    # this step's own DMA: the prefetch is in flight across the wait and
    # the whole PE pipeline below.  Next step's (app, tile) wraps the tile
    # axis so the app boundary prefetches tile 0 of app i+1.
    next_t = jax.lax.rem(t + 1, n_tiles)
    next_i = i + jax.lax.div(t + 1, n_tiles)

    @pl.when(step + 1 < pl.num_programs(0) * n_tiles)
    def _():
        slab_dma(1 - slot, next_i, next_t).start()

    slab_dma(slot, i, t).wait()
    slab = slabs_ref[slot]               # [tile_rows + 2r, W] haloed rows
    W = slab.shape[1]
    dtype = slab.dtype
    padded = jnp.pad(slab, ((0, 0), (r, r)))   # columns only; rows came in
    taps = [
        padded[r + dj : r + dj + tr, r + di : r + di + W].reshape(tr * W)
        for dj, di in tap_offsets(radius)
    ]
    taps.append(jnp.zeros((tr * W,), dtype))   # const/padding producer row
    bank = jnp.stack(taps, axis=0)             # [T+1, tile_rows*W]
    zero_row = len(taps) - 1
    consts = const_ref[0]                      # [C] in grid dtype
    chans = []
    for c in range(grid.num_inputs):
        tap = tap_sel_ref[i, c]
        row = jax.lax.dynamic_index_in_dim(bank, tap, 0, keepdims=False)
        chans.append(jnp.where(tap == zero_row, consts[c], row))
    x = jnp.stack(chans, axis=0)               # [C, tile_rows*W] channels
    prev = _level_pipeline(grid, (i,), op_ref, sel_ref, x)
    o_ref[0] = _gather_outputs(grid, (i,), outsel_ref, prev, dtype)


def vcgra_fused_batched(
    grid: GridSpec,
    radius: int,
    settings: Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray],
    ingests: Tuple[jnp.ndarray, jnp.ndarray],
    images: jnp.ndarray,
    interpret: Optional[bool] = None,
    tile_rows=None,
) -> jnp.ndarray:
    """Batched fused-ingest megakernel: N raw frames, N tenants, ONE
    pallas_call -- the Pallas twin of
    ``interpreter.batched_fused_overlay_step`` (and of its row-tiled twin
    when ``tile_rows`` is set).

    ``settings``: dense banks (ops [N, L, max_w], sel [N, L, max_w, 2],
    out_sel [N, K]); ``ingests``: (tap_sel int32 [N, C], const_vals [N, C]
    in grid dtype); ``images``: [N, H, W], cast to the grid dtype at entry
    exactly like the XLA path's ``form_tap_bank`` (so parity holds even
    for frames arriving in another dtype).  Returns [N, num_outputs, H*W]
    in the grid dtype.

    Blocking: the pallas grid iterates (app, row-tile) over the ONE
    zero-row-padded frame stack ``[N, T*tile_rows + 2r, W]``, which stays
    in HBM (``memory_space=ANY``) -- no host-side halo slab tensor is ever
    materialized.  ``tile_rows`` (int, ``tiling.TILE_AUTO`` or None =
    whole frame) fixes the tile height; each grid step's
    ``[tile_rows + 2*radius, W]`` halo window is streamed HBM->VMEM by the
    kernel's own double-buffered ``make_async_copy`` pipeline (see
    ``_fused_batched_body``): tile t+1's window is in flight while tile
    t's PE pipeline executes, each frame row crosses HBM->VMEM once, and
    halo rows are re-read only at tile seams.  VMEM holds
    ``O((T+1 + max_level_width + 2) * tile_rows * W)`` elements at a time
    (the +2 is both DMA slabs) instead of the whole frame + tap bank; the
    budget heuristic (``tiling.slab_rows_per_budget``) accounts for
    exactly this set.  ``tile_rows`` not dividing H is padded with zero
    rows and sliced back -- bitwise-exact, the padding is read only as the
    bottom halo.

    (Why not ``pltpu.emit_pipeline``: its BlockSpec grids express
    *disjoint* blocks -- index maps are multiplied by the block shape --
    while halo windows overlap by ``2*radius`` rows; the manual
    two-slab/two-semaphore rotation is the same schedule emit_pipeline
    would build, with the overlapping source windows it cannot express.)
    """
    interpret = _resolve_interpret(interpret)
    ops_arr, sel_arr, out_sel = settings
    tap_sel, const_vals = ingests
    images = jnp.asarray(images, grid.dtype)
    n_apps, H, W = images.shape
    r = radius
    # ONE tile-height definition for the heuristic, the XLA twin and this
    # kernel (tiling.resolve_tile_rows); the compiled path asks it for a
    # lane-aligned AUTO pick, so the loud assert below fires with the
    # already-rounded value.
    tr = resolve_tile_rows(tile_rows, H, W, r, grid,
                           lane_align=None if interpret else LANE)
    n_tiles = num_row_tiles(H, tr)
    Hp = n_tiles * tr
    # The compiled (real-TPU) path needs a lane-aligned pixel block; fail
    # with a clear message instead of an obscure Mosaic lowering error.
    # The fleet's pow-2 canvas bucketing (min side 16) satisfies this for
    # the untiled layout and, with resolve_tile_rows' lane_align rounding,
    # for AUTO tiling; explicit tiled callers must pick lane-friendly tile
    # heights themselves.  Interpret mode (CPU/GPU CI) has no layout
    # constraint.
    assert interpret or (tr * W) % LANE == 0, (
        f"compiled megakernel needs a lane-aligned pixel block: "
        f"tile_rows*W={tr}*{W}={tr * W} is not a multiple of {LANE}; pad "
        f"the canvas (the fleet's pow-2 bucketing does), pick another "
        f"tile_rows, or pass interpret=True"
    )
    # Host side of the pallas_call: ONLY the zero-row pad (radius rows of
    # border top, radius + ragged-tile remainder bottom) -- the halo
    # windows themselves are sliced by the in-kernel DMA, never
    # materialized in HBM.
    frames = jnp.pad(images, ((0, 0), (r, Hp - H + r), (0, 0)))
    body = functools.partial(_fused_batched_body, grid, radius, tr)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,          # tap_sel, ops, sel, out_sel -> SMEM
        grid=(n_apps, n_tiles),
        in_specs=[
            pl.BlockSpec((1, grid.num_inputs), lambda i, t, *_: (i, 0)),
            # The padded frame stack stays in HBM; the kernel's DMA
            # pipeline owns the HBM->VMEM movement.
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=pl.BlockSpec(
            # Row-major flattening makes tile t's pixels contiguous: block
            # t of the pixel axis IS the tile's [tile_rows, W] rows.
            (1, grid.num_outputs, tr * W), lambda i, t, *_: (i, 0, t)
        ),
        scratch_shapes=[
            # The double buffer: two in-flight halo slabs + their DMA
            # completion semaphores.
            pltpu.VMEM((2, tr + 2 * r, W), images.dtype),
            pltpu.SemaphoreType.DMA((2,)),
        ],
    )
    y = pl.pallas_call(
        body,
        out_shape=jax.ShapeDtypeStruct(
            (n_apps, grid.num_outputs, Hp * W), images.dtype
        ),
        grid_spec=grid_spec,
        interpret=interpret,
    )(tap_sel, ops_arr, sel_arr, out_sel, const_vals, frames)
    return y[:, :, : H * W] if Hp != H else y


# -- multi-stage pipeline megakernel -------------------------------------------
#
# The device-resident chain executor (``core/plan.py`` pipeline axis): a
# depth-S application chain runs as ONE pallas_call whose per-(app, tile)
# instance executes every stage back to back over the same VMEM slab --
# the PR 7 DMA pipeline amortizes across the whole chain instead of
# paying one HBM round trip per stage.


def _pipeline_batched_body(
    grid: GridSpec, radii: Tuple[int, ...], tile_rows: int,
    tap_ref, op_ref, sel_ref, outsel_ref, outch_ref, hw_ref,
    const_ref, frames_ref, o_ref, slabs_ref, dma_sems_ref,
):
    """Multi-stage trapezoid body: one haloed slab -> final-stage outputs.

    The DMA schedule is exactly ``_fused_batched_body``'s double buffer,
    but the halo radius is the chain's TOTAL ``R = sum(radii)``: to emit
    ``tile_rows`` final rows, stage 0 must consume ``tile_rows + 2R`` input
    rows, and each stage shaves its own ``2 * r_i`` -- a trapezoid of
    working regions narrowing toward the output tile.  Stage *i* therefore
    computes ``tile_rows + 2 * reach_i`` rows where ``reach_i`` is the sum
    of the *downstream* radii (rows later stages still need as halo).

    Between stages the selected output channel (``outch_ref``, a runtime
    setting like every mux select) is re-masked against the app's true
    frame extent (``hw_ref``): slab rows outside ``[0, h)`` and columns
    outside ``[0, w)`` are canvas/halo padding whose *stage outputs* are
    generally nonzero (a threshold PE emits GT(0, c) there), but the next
    stage's line buffers must read zeros -- the same invariant the XLA
    chain keeps with ``interpreter.valid_pixel_mask``, which is what makes
    fused-vs-staged bitwise parity hold.  The global row of local row
    ``j`` in stage *i*'s output region is ``t * tile_rows - reach_i + j``.

    Settings banks carry a leading stage axis (``[S, N, ...]``; the
    ``(si, i)`` SMEM index prefix reuses the shared ``_level_pipeline`` /
    ``_gather_outputs`` helpers), so one compiled kernel serves every
    depth-S chain on the grid -- the settings-register contract at chain
    scale.  The final stage writes straight to the output block, unmasked,
    like the single-stage kernel (callers slice the canvas).
    """
    i = pl.program_id(0)
    t = pl.program_id(1)
    n_tiles = pl.num_programs(1)
    step = i * n_tiles + t
    slot = jax.lax.rem(step, 2)
    R = sum(radii)
    tr = tile_rows

    def slab_dma(slot, app, tile):
        return pltpu.make_async_copy(
            frames_ref.at[app, pl.ds(tile * tr, tr + 2 * R), :],
            slabs_ref.at[slot],
            dma_sems_ref.at[slot],
        )

    @pl.when(step == 0)
    def _():
        slab_dma(0, 0, 0).start()

    next_t = jax.lax.rem(t + 1, n_tiles)
    next_i = i + jax.lax.div(t + 1, n_tiles)

    @pl.when(step + 1 < pl.num_programs(0) * n_tiles)
    def _():
        slab_dma(1 - slot, next_i, next_t).start()

    slab_dma(slot, i, t).wait()
    x = slabs_ref[slot]                  # [tile_rows + 2R, W] haloed rows
    W = x.shape[1]
    dtype = x.dtype
    for si, r in enumerate(radii):       # chain static; settings runtime
        reach = sum(radii[si + 1:])
        h_out = tr + 2 * reach
        padded = jnp.pad(x, ((0, 0), (r, r)))   # columns only
        taps = [
            padded[r + dj : r + dj + h_out, r + di : r + di + W].reshape(
                h_out * W
            )
            for dj, di in tap_offsets(r)
        ]
        taps.append(jnp.zeros((h_out * W,), dtype))
        bank = jnp.stack(taps, axis=0)
        zero_row = len(taps) - 1
        consts = const_ref[si, 0]        # [C] in grid dtype
        chans = []
        for c in range(grid.num_inputs):
            tap = tap_ref[si, i, c]
            row = jax.lax.dynamic_index_in_dim(bank, tap, 0, keepdims=False)
            chans.append(jnp.where(tap == zero_row, consts[c], row))
        xc = jnp.stack(chans, axis=0)    # [C, h_out*W] stage channels
        prev = _level_pipeline(grid, (si, i), op_ref, sel_ref, xc)
        if si == len(radii) - 1:
            o_ref[0] = _gather_outputs(grid, (si, i), outsel_ref, prev, dtype)
        else:
            y = jax.lax.dynamic_index_in_dim(
                prev, outch_ref[si, i], 0, keepdims=False
            ).reshape(h_out, W).astype(dtype)
            grow = (t * tr - reach) + jax.lax.broadcasted_iota(
                jnp.int32, (h_out, W), 0
            )
            gcol = jax.lax.broadcasted_iota(jnp.int32, (h_out, W), 1)
            valid = jnp.logical_and(
                jnp.logical_and(grow >= 0, grow < hw_ref[i, 0]),
                gcol < hw_ref[i, 1],
            )
            x = jnp.where(valid, y, jnp.zeros_like(y))


def vcgra_pipeline_batched(
    grid: GridSpec,
    radii: Tuple[int, ...],
    settings: Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray],
    ingests: Tuple[jnp.ndarray, jnp.ndarray],
    out_chs: jnp.ndarray,
    hw: jnp.ndarray,
    images: jnp.ndarray,
    interpret: Optional[bool] = None,
    tile_rows=None,
) -> jnp.ndarray:
    """Depth-S pipeline megakernel: N chained tenants, ONE pallas_call --
    the Pallas twin of the plan layer's fused pipeline executors.

    ``settings``: stage-stacked dense banks (ops [S, N, L, max_w], sel
    [S, N, L, max_w, 2], out_sel [S, N, K]); ``ingests``: per-stage tap
    plans (tap_sel int32 [S, N, C], const_vals [S, N, C] in grid dtype;
    stage *i*'s selects index a radius-``radii[i]`` bank); ``out_chs``:
    int32 [S, N], the channel stage *i* feeds forward (the last stage's
    row is carried for shape uniformity but never read); ``hw``: int32
    [N, 2] true (rows, cols) of each app's frame inside the canvas;
    ``images``: [N, H, W].  Returns [N, num_outputs, H*W] in grid dtype.

    The frame stack is zero-row-padded by the chain's TOTAL radius
    ``R = sum(radii)`` and stays in HBM; each (app, row-tile) step DMAs
    one ``[tile_rows + 2R, W]`` window into the 2-slot VMEM double buffer
    and runs the whole stage trapezoid on it (see
    ``_pipeline_batched_body``), so every frame row crosses HBM->VMEM
    once *per chain*, not once per stage.
    """
    interpret = _resolve_interpret(interpret)
    radii = tuple(int(r) for r in radii)
    ops_arr, sel_arr, out_sel = settings
    tap_sel, const_vals = ingests
    images = jnp.asarray(images, grid.dtype)
    n_apps, H, W = images.shape
    R = sum(radii)
    tr = resolve_tile_rows(tile_rows, H, W, R, grid,
                           lane_align=None if interpret else LANE)
    n_tiles = num_row_tiles(H, tr)
    Hp = n_tiles * tr
    assert interpret or (tr * W) % LANE == 0, (
        f"compiled pipeline megakernel needs a lane-aligned pixel block: "
        f"tile_rows*W={tr}*{W}={tr * W} is not a multiple of {LANE}; pad "
        f"the canvas (the fleet's pow-2 bucketing does), pick another "
        f"tile_rows, or pass interpret=True"
    )
    frames = jnp.pad(images, ((0, 0), (R, Hp - H + R), (0, 0)))
    n_stages = len(radii)
    body = functools.partial(_pipeline_batched_body, grid, radii, tr)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=6,   # tap_sel, ops, sel, out_sel, out_ch, hw
        grid=(n_apps, n_tiles),
        in_specs=[
            pl.BlockSpec(
                (n_stages, 1, grid.num_inputs), lambda i, t, *_: (0, i, 0)
            ),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=pl.BlockSpec(
            (1, grid.num_outputs, tr * W), lambda i, t, *_: (i, 0, t)
        ),
        scratch_shapes=[
            pltpu.VMEM((2, tr + 2 * R, W), images.dtype),
            pltpu.SemaphoreType.DMA((2,)),
        ],
    )
    y = pl.pallas_call(
        body,
        out_shape=jax.ShapeDtypeStruct(
            (n_apps, grid.num_outputs, Hp * W), images.dtype
        ),
        grid_spec=grid_spec,
        interpret=interpret,
    )(
        jnp.asarray(tap_sel, jnp.int32), ops_arr, sel_arr, out_sel,
        jnp.asarray(out_chs, jnp.int32), jnp.asarray(hw, jnp.int32),
        const_vals, frames,
    )
    return y[:, :, : H * W] if Hp != H else y
