from repro.kernels.vcgra.ops import vcgra_apply, vcgra_apply_image
from repro.kernels.vcgra.ref import vcgra_ref

__all__ = ["vcgra_apply", "vcgra_apply_image", "vcgra_ref"]
