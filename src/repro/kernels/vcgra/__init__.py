from repro.kernels.vcgra.ops import (
    make_batched_fused_pallas_fn,
    make_batched_pallas_fn,
    pack_settings_batched,
    vcgra_apply,
    vcgra_apply_image,
)
from repro.kernels.vcgra.ref import vcgra_ref
from repro.kernels.vcgra.vcgra_kernel import (
    default_interpret,
    vcgra_batched,
    vcgra_fused_batched,
)

__all__ = [
    "default_interpret",
    "make_batched_fused_pallas_fn",
    "make_batched_pallas_fn",
    "pack_settings_batched",
    "vcgra_apply",
    "vcgra_apply_image",
    "vcgra_batched",
    "vcgra_fused_batched",
    "vcgra_ref",
]
