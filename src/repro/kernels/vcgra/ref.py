"""Pure-jnp oracle for the VCGRA grid-executor kernel.

Semantics: identical to the conventional overlay interpreter
(`repro.core.interpreter.overlay_step`) -- gather-routed, generic-PE,
level-pipelined execution of a mapped application over a pixel batch.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.bitstream import VCGRAConfig
from repro.core.grid import GridSpec
from repro.core.interpreter import overlay_step


def vcgra_ref(grid: GridSpec, config: VCGRAConfig, x: jnp.ndarray) -> jnp.ndarray:
    """x: [num_inputs, batch] -> y: [num_outputs, batch]."""
    return overlay_step(grid, config.to_jax(), x)
