"""Jitted public wrappers for the VCGRA Pallas kernels.

Handles batch padding to lane-aligned blocks, image packing/unpacking, and
exposes the same (grid, config, inputs) contract as the core interpreter so
the kernel drops into the Pixie facade transparently.  Image entry points
use the fused device-side ingest (``core/ingest.py``): the stencil tap
bank + channel production run as ONE jitted function instead of ~20
host-issued shift/stack ops per frame.
"""

from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp

from repro.core import applications as apps
from repro.core.bitstream import VCGRAConfig
from repro.core.grid import GridSpec
from repro.core.interpreter import apply_ingest, form_tap_bank, pack_inputs
from repro.kernels.vcgra.vcgra_kernel import (
    LANE,
    _pack_settings,
    vcgra_conventional,
    vcgra_specialized,
)


@functools.lru_cache(maxsize=None)
def _ingest_fn(radius: int, dtype):
    """Jit-once fused frame ingest: [H, W] raw image -> [C, H*W] channels
    (tap offsets trace-time constants, plan arrays runtime settings)."""

    def ingest(tap_sel, const_vals, image):
        bank = form_tap_bank(image[None], radius, dtype)[0]
        return apply_ingest(bank, (tap_sel, const_vals))

    return jax.jit(ingest)


def _pad_batch(x: jnp.ndarray, block_n: int):
    n = x.shape[-1]
    rem = (-n) % block_n
    if rem:
        x = jnp.pad(x, ((0, 0), (0, rem)))
    return x, n


def vcgra_apply(
    grid: GridSpec,
    config: VCGRAConfig,
    x: jnp.ndarray,
    mode: str = "specialized",
    block_n: int = 1024,
    interpret: bool = True,
) -> jnp.ndarray:
    """Run a mapped application over a channel-major batch [num_inputs, N]."""
    xp, n = _pad_batch(x, block_n)
    if mode == "specialized":
        fn = jax.jit(
            functools.partial(
                vcgra_specialized, grid, config, block_n=block_n, interpret=interpret
            )
        )
        y = fn(xp)
    elif mode == "conventional":
        ops_arr, sel_arr, out_sel, _ = _pack_settings(grid, config)
        fn = jax.jit(
            functools.partial(
                vcgra_conventional, grid, block_n=block_n, interpret=interpret
            )
        )
        y = fn((ops_arr, sel_arr, out_sel), xp)
    else:
        raise ValueError(f"unknown mode {mode!r}")
    return y[:, :n]


def vcgra_apply_image(
    grid: GridSpec,
    config: VCGRAConfig,
    image: jnp.ndarray,
    mode: str = "specialized",
    block_n: int = 1024,
    interpret: bool = True,
) -> jnp.ndarray:
    """Stencil-app convenience: [H, W] image -> [H, W] (or [K, H, W]) output.

    Takes the fused ingest path whenever the config carries an
    :class:`~repro.core.ingest.IngestPlan` (one jitted tap-bank + select
    per frame); falls back to the host-side two-step oracle otherwise.
    """
    H, W = image.shape
    if config.ingest is not None:
        plan = config.ingest
        x = _ingest_fn(plan.radius, grid.dtype)(
            *plan.to_jax(grid.dtype), jnp.asarray(image)
        )
    else:
        taps = apps.stencil_inputs(image)
        feed = {k: v for k, v in taps.items() if k in config.input_order}
        x = pack_inputs(config, feed, grid.dtype)
    y = vcgra_apply(grid, config, x, mode=mode, block_n=block_n, interpret=interpret)
    y = y.reshape((-1, H, W))
    return y[0] if y.shape[0] == 1 else y
