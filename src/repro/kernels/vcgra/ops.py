"""Jitted public wrappers for the VCGRA Pallas kernels.

Handles batch padding to lane-aligned blocks, image packing/unpacking, and
exposes the same (grid, config, inputs) contract as the core interpreter so
the kernel drops into the Pixie facade transparently.  Image entry points
use the fused device-side ingest (``core/ingest.py``): the stencil tap
bank + channel production run as ONE jitted function instead of ~20
host-issued shift/stack ops per frame.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import applications as apps
from repro.core.bitstream import VCGRAConfig
from repro.core.grid import GridSpec
from repro.core.interpreter import apply_ingest, form_tap_bank, pack_inputs
from repro.core.plan import OverlayPlan, register_executor
from repro.kernels.vcgra.vcgra_kernel import (
    LANE,
    _pack_settings,
    default_interpret,
    vcgra_batched,
    vcgra_conventional,
    vcgra_fused_batched,
    vcgra_pipeline_batched,
    vcgra_specialized,
)


@functools.lru_cache(maxsize=None)
def _ingest_fn(radius: int, dtype):
    """Jit-once fused frame ingest: [H, W] raw image -> [C, H*W] channels
    (tap offsets trace-time constants, plan arrays runtime settings)."""

    def ingest(tap_sel, const_vals, image):
        bank = form_tap_bank(image[None], radius, dtype)[0]
        return apply_ingest(bank, (tap_sel, const_vals))

    return jax.jit(ingest)


def _pad_batch(x: jnp.ndarray, block_n: int):
    n = x.shape[-1]
    rem = (-n) % block_n
    if rem:
        x = jnp.pad(x, ((0, 0), (0, rem)))
    return x, n


def pack_settings_batched(grid: GridSpec, stacked_configs):
    """Interpreter-style stacked settings (``VCGRAConfig.stack``: per-level
    tuples of [N, w] / [N, w, 2] plus out_sel [N, K]) -> the dense
    rectangular SMEM banks the batched megakernels prefetch:
    ``(ops int32 [N, L, max_w], sel int32 [N, L, max_w, 2], out int32 [N, K])``.
    Pad slots hold Op.NONE / select 0 and are never read (the kernel loops
    the grid's true per-level widths)."""
    opcodes, selects, out_sel = stacked_configs
    max_w = max(grid.pes_per_level)
    ops_d = jnp.stack(
        [
            jnp.pad(jnp.asarray(o, jnp.int32), ((0, 0), (0, max_w - o.shape[1])))
            for o in opcodes
        ],
        axis=1,
    )
    sel_d = jnp.stack(
        [
            jnp.pad(
                jnp.asarray(s, jnp.int32),
                ((0, 0), (0, max_w - s.shape[1]), (0, 0)),
            )
            for s in selects
        ],
        axis=1,
    )
    return ops_d, sel_d, jnp.asarray(out_sel, jnp.int32)


def _batched_fused_pallas_fn(grid: GridSpec, radius: int = 1, interpret=None,
                             tile_rows=None):
    """Unjitted batched fused-ingest *megakernel* executor (the plan
    builders return this so ``compile_plan`` applies the single outer
    jit; :func:`make_batched_fused_pallas_fn` is the jitted standalone).

    Signature twin of the XLA batched fused-ingest plan executors
    (``interpreter.batched_fused_overlay_step`` and its row-tiled twin):
    ``fn(stacked_configs, stacked_ingests, images) -> ys`` with
    ``images: [N, H, W] -> ys: [N, num_outputs, H*W]``.  Settings and
    ingest plans are runtime operands (scalar-prefetched to SMEM), so one
    executable per (grid, radius, tile_rows, N, H, W) serves every
    application -- the same compile-once contract as the XLA path,
    bitwise-equal outputs.  ``tile_rows`` (int / ``tiling.TILE_AUTO`` /
    None) selects the pixel-axis row tiling of the kernel grid.
    """

    def fn(stacked_configs, stacked_ingests, images):
        settings = pack_settings_batched(grid, stacked_configs)
        tap_sel, const_vals = stacked_ingests
        return vcgra_fused_batched(
            grid, radius, settings,
            (jnp.asarray(tap_sel, jnp.int32), const_vals),
            images, interpret=interpret, tile_rows=tile_rows,
        )

    return fn


def make_batched_fused_pallas_fn(grid: GridSpec, radius: int = 1,
                                 interpret=None, tile_rows=None):
    """Jit-once standalone form of :func:`_batched_fused_pallas_fn`."""
    return jax.jit(_batched_fused_pallas_fn(grid, radius, interpret, tile_rows))


def pallas_pipeline_fn(grid: GridSpec, radii, tile_rows=None, interpret=None):
    """Unjitted pipeline-chain megakernel executor for ``compile_plan``
    (single-device pipeline plans, backend="pallas").

    Signature twin of the XLA pipeline executors:
    ``fn(stage_settings, hw, images) -> ys`` where ``stage_settings`` is a
    tuple over stages of ``(stacked_configs, stacked_ingests, out_ch)``
    exactly as the plan layer stacks them.  Each stage's interpreter-style
    settings are dense-packed (:func:`pack_settings_batched`) and stacked
    along a leading stage axis so the whole chain rides one
    scalar-prefetch bank set into :func:`vcgra_pipeline_batched`.
    """
    radii = tuple(int(r) for r in radii)

    def fn(stage_settings, hw, images):
        ops_s, sel_s, outsel_s, tap_s, const_s, outch_s = [], [], [], [], [], []
        for configs, ingests, out_ch in stage_settings:
            ops_arr, sel_arr, out_sel = pack_settings_batched(grid, configs)
            ops_s.append(ops_arr)
            sel_s.append(sel_arr)
            outsel_s.append(out_sel)
            tap_s.append(jnp.asarray(ingests[0], jnp.int32))
            const_s.append(jnp.asarray(ingests[1], grid.dtype))
            outch_s.append(jnp.asarray(out_ch, jnp.int32))
        return vcgra_pipeline_batched(
            grid, radii,
            (jnp.stack(ops_s), jnp.stack(sel_s), jnp.stack(outsel_s)),
            (jnp.stack(tap_s), jnp.stack(const_s)),
            jnp.stack(outch_s), hw, images,
            interpret=interpret, tile_rows=tile_rows,
        )

    return fn


def pallas_pipeline_stage_fn(grid: GridSpec, tile_rows=None, interpret=None):
    """Per-stage pallas executor ``stage_fn(radius, configs, ingests, x)``
    for the mesh-sharded pipeline chain drivers (``parallel/axes.py``):
    each stage runs the single-stage fused megakernel on its shard band,
    with the generic driver owning inter-stage halo exchange and masking.
    (Under shard_map the stage loop cannot fold into one kernel -- halo
    rows live on neighbor devices between stages.)"""

    def stage_fn(radius, stacked_configs, stacked_ingests, images):
        return _batched_fused_pallas_fn(
            grid, int(radius), interpret, tile_rows
        )(stacked_configs, stacked_ingests, images)

    return stage_fn


def _batched_pallas_fn(grid: GridSpec, block_n: int = LANE, interpret=None):
    """Unjitted batched (pre-packed channels) kernel executor -- the
    Pallas twin of ``interpreter.batched_overlay_step``:
    ``fn(stacked_configs, xs) -> ys`` with ``xs: [N, num_inputs, B]``.
    The pixel axis is padded to a ``block_n`` multiple inside the
    function and sliced back, so callers keep the XLA path's contract."""

    def fn(stacked_configs, xs):
        settings = pack_settings_batched(grid, stacked_configs)
        b = xs.shape[-1]
        rem = (-b) % block_n
        if rem:
            xs = jnp.pad(xs, ((0, 0), (0, 0), (0, rem)))
        ys = vcgra_batched(grid, settings, xs, block_n=block_n,
                           interpret=interpret)
        return ys[:, :, :b]

    return fn


def make_batched_pallas_fn(grid: GridSpec, block_n: int = LANE, interpret=None):
    """Jit-once standalone form of :func:`_batched_pallas_fn`."""
    return jax.jit(_batched_pallas_fn(grid, block_n, interpret))


# -- plan executors ------------------------------------------------------------
# The kernel package registers its own cells of the OverlayPlan matrix
# (instead of being special-cased inside core/interpreter.py):
# ``compile_plan`` imports this module lazily for backend="pallas".


@register_executor("pallas", batched=True, fused=True)
def _plan_batched_fused(plan: OverlayPlan):
    return _batched_fused_pallas_fn(plan.grid, plan.radius,
                                    tile_rows=plan.tile_rows)


@register_executor("pallas", batched=True, fused=False)
def _plan_batched(plan: OverlayPlan):
    return _batched_pallas_fn(plan.grid)


def _lift_app_axis(tree):
    """Add a leading N=1 app axis to every leaf (single-app adapter)."""
    return jax.tree_util.tree_map(lambda a: a[None], tree)


@register_executor("pallas", batched=False, fused=False)
def _plan_single(plan: OverlayPlan):
    """Single-app pallas execution rides the batched kernel with N=1 (the
    megakernels are the only settings-as-runtime-data pallas path; a
    dedicated single-app kernel would re-specialize per app)."""
    batched = _batched_pallas_fn(plan.grid)

    def fn(config, x):
        return batched(_lift_app_axis(config), x[None])[0]

    return fn


@register_executor("pallas", batched=False, fused=True)
def _plan_single_fused(plan: OverlayPlan):
    batched = _batched_fused_pallas_fn(plan.grid, plan.radius,
                                       tile_rows=plan.tile_rows)

    def fn(config, ingest, image):
        return batched(_lift_app_axis(config), _lift_app_axis(ingest),
                       image[None])[0]

    return fn


def vcgra_apply(
    grid: GridSpec,
    config: VCGRAConfig,
    x: jnp.ndarray,
    mode: str = "specialized",
    block_n: int = 1024,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Run a mapped application over a channel-major batch [num_inputs, N].
    ``interpret=None`` auto-detects the platform (compiled on TPU,
    interpreted elsewhere)."""
    xp, n = _pad_batch(x, block_n)
    if mode == "specialized":
        fn = jax.jit(
            functools.partial(
                vcgra_specialized, grid, config, block_n=block_n, interpret=interpret
            )
        )
        y = fn(xp)
    elif mode == "conventional":
        ops_arr, sel_arr, out_sel, _ = _pack_settings(grid, config)
        fn = jax.jit(
            functools.partial(
                vcgra_conventional, grid, block_n=block_n, interpret=interpret
            )
        )
        y = fn((ops_arr, sel_arr, out_sel), xp)
    else:
        raise ValueError(f"unknown mode {mode!r}")
    return y[:, :n]


def vcgra_apply_image(
    grid: GridSpec,
    config: VCGRAConfig,
    image: jnp.ndarray,
    mode: str = "specialized",
    block_n: int = 1024,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Stencil-app convenience: [H, W] image -> [H, W] (or [K, H, W]) output.

    Takes the fused ingest path whenever the config carries an
    :class:`~repro.core.ingest.IngestPlan` (one jitted tap-bank + select
    per frame); falls back to the host-side two-step oracle otherwise.
    """
    H, W = image.shape
    if config.ingest is not None:
        plan = config.ingest
        x = _ingest_fn(plan.radius, grid.dtype)(
            *plan.to_jax(grid.dtype), jnp.asarray(image)
        )
    else:
        taps = apps.stencil_inputs(image)
        feed = {k: v for k, v in taps.items() if k in config.input_order}
        x = pack_inputs(config, feed, grid.dtype)
    y = vcgra_apply(grid, config, x, mode=mode, block_n=block_n, interpret=interpret)
    y = y.reshape((-1, H, W))
    return y[0] if y.shape[0] == 1 else y
