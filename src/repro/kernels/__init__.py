"""Pallas TPU kernels for the compute hot-spots (validated interpret=True):

  vcgra/            the paper's PE-grid executor, VMEM-tiled
                    (specialized + conventional/scalar-prefetch variants)
  stencil/          fused 3x3 stencil -- the beyond-paper roofline target
  flash_attention/  chunked GQA decode attention for long-context serving

Each package: <name>_kernel.py (pl.pallas_call + BlockSpec), ops.py
(jitted wrappers), ref.py (pure-jnp oracle).
"""
