"""Pure-jnp oracle for GQA flash decode attention."""

from __future__ import annotations

import jax.numpy as jnp


def decode_ref(
    q: jnp.ndarray,        # [B, H, D]
    k: jnp.ndarray,        # [B, S, G, D]
    v: jnp.ndarray,        # [B, S, G, D]
    lengths: jnp.ndarray,  # [B]
) -> jnp.ndarray:
    B, H, D = q.shape
    _, S, G, _ = k.shape
    Hg = H // G
    qg = q.reshape(B, G, Hg, D).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bghd,bsgd->bghs", qg, kf) * (D ** -0.5)   # [B,G,Hg,S]
    mask = jnp.arange(S)[None, :] < lengths[:, None]               # [B,S]
    scores = jnp.where(mask[:, None, None, :], scores, -1e30)
    p = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    p = jnp.where(mask[:, None, None, :], p, 0.0)
    p = p / jnp.maximum(p.sum(axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bghs,bsgd->bghd", p, vf)
    return out.reshape(B, H, D).astype(q.dtype)
