"""Pallas TPU kernel: chunked (flash) decode attention with GQA.

Used by the serving engine for the ``decode_32k`` / ``long_500k`` shapes:
one new query token per sequence attends over a long KV cache.  The cache
is streamed HBM -> VMEM in ``chunk`` slices with an online-softmax
accumulator in VMEM scratch, so VMEM holds O(chunk * head_dim) instead of
the full cache -- the standard flash-decoding structure, laid out for the
TPU memory hierarchy (sublane = chunk, lane = head_dim; accumulation in
f32 regardless of cache dtype).

Grid: (batch, kv_heads, seq_chunks); the chunk axis is 'arbitrary'
(sequential) so the scratch carries across chunks.  Per-sequence valid
lengths arrive via scalar prefetch (SMEM), masking trailing cache slots.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_body(
    chunk: int,
    lengths_ref,   # SMEM int32 [B]
    q_ref,         # [1, 1, Hg, D]
    k_ref,         # [1, chunk, 1, D]
    v_ref,         # [1, chunk, 1, D]
    o_ref,         # [1, 1, Hg, D]
    m_ref,         # VMEM f32 [Hg, 1]   running max
    l_ref,         # VMEM f32 [Hg, 1]   running denominator
    acc_ref,       # VMEM f32 [Hg, D]   running numerator
):
    b = pl.program_id(0)
    s = pl.program_id(2)
    n_chunks = pl.num_programs(2)

    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # [Hg, D]
    k = k_ref[0, :, 0].astype(jnp.float32)       # [chunk, D]
    v = v_ref[0, :, 0].astype(jnp.float32)       # [chunk, D]

    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                            # [Hg, chunk]
    scores *= q.shape[-1] ** -0.5

    pos = s * chunk + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    valid = pos < lengths_ref[b]
    scores = jnp.where(valid, scores, NEG_INF)

    m_prev = m_ref[...]                          # [Hg, 1]
    m_cur = jnp.max(scores, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)              # rescale of old accumulator
    p = jnp.exp(scores - m_new)                  # [Hg, chunk]
    p = jnp.where(valid, p, 0.0)

    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(s == n_chunks - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_decode(
    q: jnp.ndarray,        # [B, H, D]
    k: jnp.ndarray,        # [B, S, G, D]
    v: jnp.ndarray,        # [B, S, G, D]
    lengths: jnp.ndarray,  # [B] int32 valid cache lengths
    chunk: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    """GQA flash decode: one query per sequence over an [S]-long cache."""
    B, H, D = q.shape
    _, S, G, _ = k.shape
    assert H % G == 0, f"{H} query heads not divisible into {G} KV groups"
    Hg = H // G
    assert S % chunk == 0, f"cache len {S} not a multiple of chunk {chunk}"
    qg = q.reshape(B, G, Hg, D)

    body = functools.partial(_decode_body, chunk)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, G, S // chunk),
        in_specs=[
            pl.BlockSpec((1, 1, Hg, D), lambda b, g, s, *_: (b, g, 0, 0)),
            pl.BlockSpec((1, chunk, 1, D), lambda b, g, s, *_: (b, s, g, 0)),
            pl.BlockSpec((1, chunk, 1, D), lambda b, g, s, *_: (b, s, g, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, Hg, D), lambda b, g, s, *_: (b, g, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Hg, 1), jnp.float32),
            pltpu.VMEM((Hg, 1), jnp.float32),
            pltpu.VMEM((Hg, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        body,
        out_shape=jax.ShapeDtypeStruct((B, G, Hg, D), q.dtype),
        grid_spec=grid_spec,
        interpret=interpret,
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        )
        if hasattr(pltpu, "CompilerParams")
        else None,
    )(lengths.astype(jnp.int32), qg, k, v)
    return out.reshape(B, H, D)
