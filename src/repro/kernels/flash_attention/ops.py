"""Jitted wrapper for the flash decode attention kernel."""

from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention.flash_kernel import flash_decode


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def decode_attention(q, k, v, lengths, chunk: int = 512, interpret: bool = True):
    """GQA decode attention: q [B,H,D] over cache k/v [B,S,G,D]."""
    return flash_decode(q, k, v, lengths, chunk=chunk, interpret=interpret)
