from repro.kernels.flash_attention.ops import decode_attention
from repro.kernels.flash_attention.ref import decode_ref

__all__ = ["decode_attention", "decode_ref"]
