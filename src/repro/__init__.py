"""Pixie-JAX: the Pixie VCGRA overlay (Kulkarni, Stroobandt et al., 2017)
reproduced in JAX, inside a multi-pod TPU training/inference framework.

Subpackages: core (the paper), kernels (Pallas), models, configs,
parallel, data, optim, checkpoint, runtime, train, serve, launch,
roofline.  See README.md / DESIGN.md.
"""

__version__ = "1.0.0"
