"""Roofline analysis from compiled dry-run artifacts (TPU v5e targets).

Three terms per (arch x shape x mesh), all in seconds per step:

    compute    = HLO_FLOPs / (chips * 197e12)         [bf16 MXU peak]
    memory     = HLO_bytes / (chips * 819e9)          [HBM bandwidth]
    collective = collective_bytes / (chips * 50e9)    [per-link ICI]

``compiled.cost_analysis()`` gives per-device FLOPs / bytes (the SPMD
module is per-device; multiply by chips to get the global numbers the
formulas divide back down).  Collective bytes are NOT in cost_analysis:
we parse the optimized HLO text and sum output-shape bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
(per-device bytes crossing the links).

MODEL_FLOPS = 6 * N_active * tokens (the classic transformer estimate);
the ratio MODEL_FLOPS / HLO_FLOPs exposes remat recompute and dispatch
overhead (for MoE, top-k + shared experts count as active).
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, Iterable, Optional

# -- hardware constants (TPU v5e) ---------------------------------------------

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# e.g. "  %ag = bf16[16,1408]{1,0} all-gather(...)" or tuple outputs
_OP_LINE = re.compile(
    r"=\s*(\(?[a-z0-9\[\],{}\s]*\)?)\s+(" + "|".join(_COLLECTIVES) + r")(-start|-done)?\("
)
_SHAPE = re.compile(r"(pred|[suf]\d+|bf16|c64|c128)\[([\d,]*)\]")


def shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE.findall(shape_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-type output bytes (per device) from optimized HLO.

    ``-start``-suffixed async forms are counted; their ``-done`` halves are
    not (same buffer).
    """
    out = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_LINE.search(line)
        if not m:
            continue
        if m.group(3) == "-done":
            continue
        out[m.group(2)] += shape_bytes(m.group(1))
    out["total"] = sum(out[c] for c in _COLLECTIVES)
    return out


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    model_flops: float                  # global, 6*N_active*tokens
    peak_memory_per_device: Optional[float] = None
    coll_breakdown: Optional[Dict[str, int]] = None

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_device / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """Roofline step time: max of the three overlappable engines."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        hlo_global = self.flops_per_device * self.chips
        return self.model_flops / hlo_global if hlo_global else 0.0

    @property
    def mfu(self) -> float:
        """Model-FLOPs utilisation at the roofline step time."""
        t = self.step_time
        if t <= 0:
            return 0.0
        return self.model_flops / (self.chips * PEAK_FLOPS * t)

    def to_dict(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "coll_bytes_per_device": self.coll_bytes_per_device,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "step_time_s": self.step_time,
            "useful_flops_ratio": self.useful_flops_ratio,
            "mfu_at_roofline": self.mfu,
            "peak_memory_per_device": self.peak_memory_per_device,
            "coll_breakdown": self.coll_breakdown,
        }


def model_flops_estimate(cfg, shape, n_active: float) -> float:
    """6*N*D for train (fwd+bwd), 2*N*D for inference shapes."""
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_active * tokens


def format_roofline_rows(reports: Iterable[RooflineReport]) -> str:
    rows = [r.to_dict() for r in reports]
    if not rows:
        return "(empty)"
    cols = [
        "arch", "shape", "mesh", "t_compute_s", "t_memory_s",
        "t_collective_s", "bottleneck", "useful_flops_ratio", "mfu_at_roofline",
    ]
    def fmt(v):
        if isinstance(v, float):
            return f"{v:.3e}" if (abs(v) < 1e-2 and v) else f"{v:.3f}"
        return str(v)
    widths = {c: max(len(c), *(len(fmt(r[c])) for r in rows)) for c in cols}
    head = " | ".join(c.ljust(widths[c]) for c in cols)
    sep = "-+-".join("-" * widths[c] for c in cols)
    body = "\n".join(
        " | ".join(fmt(r[c]).ljust(widths[c]) for c in cols) for r in rows
    )
    return f"{head}\n{sep}\n{body}"
