"""Trip-count-aware HLO cost census.

XLA's ``compiled.cost_analysis()`` counts ``while`` bodies ONCE -- for a
scan-over-layers program that undercounts FLOPs/bytes/collectives by a
factor of ~num_layers (verified empirically; see EXPERIMENTS.md §Dry-run
notes).  This module re-derives the three roofline numerators directly
from the optimized HLO text, multiplying every instruction by the product
of the ``known_trip_count`` of the while loops enclosing it:

  * flops            2 * |out| * |contracted|, for every dot (fusion
                     bodies included);
  * hbm bytes        sum of (result + operand) bytes per *top-level*
                     instruction of sequential computations -- fusions
                     count as one instruction (params + result), matching
                     the fused-HBM-traffic model;
  * collective bytes result bytes of all-gather/all-reduce/reduce-scatter/
                     all-to-all/collective-permute ops.

The text is the per-device SPMD module, so all numbers are per device.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Set, Tuple

from repro.roofline.model import _COLLECTIVES, shape_bytes

_COMP_START = re.compile(r"^(ENTRY\s+)?%?([\w\.\-_]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_OP_LINE = re.compile(
    r"^\s*(ROOT\s+)?%?([\w\.\-_]+)\s*=\s*((?:\([^)]*\)|[a-z0-9_]+\[[^\]]*\]\S*|\S+))\s+([a-z][a-z0-9\-_]*)\((.*)$"
)
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_ATTR_COMP = re.compile(r"(?:body|condition|calls|to_apply)=%?([\w\.\-_]+)")
_OPERAND = re.compile(r"%([\w\.\-_]+)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_DIMS = re.compile(r"\[([\d,]*)\]")

SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "after-all", "iota", "partition-id",
    "replica-id",
    # layout-free on TPU (folded into neighbouring fusions); CPU HLO keeps
    # them standalone, which would inflate the HBM term ~2-3x:
    "reshape", "broadcast", "copy-start", "copy-done",
}


@dataclasses.dataclass
class Instr:
    name: str
    kind: str
    result: str          # result type string
    operands: List[str]
    rest: str            # everything after '(' (operand list + attrs)
    trip: int = 1        # while only


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool
    instrs: List[Instr]


def parse_module(text: str) -> Tuple[Dict[str, Computation], Dict[str, str], str]:
    comps: Dict[str, Computation] = {}
    shapes: Dict[str, str] = {}
    entry = ""
    cur: Optional[Computation] = None
    for line in text.splitlines():
        m = _COMP_START.match(line)
        if m:
            name = m.group(2)
            cur = Computation(name, bool(m.group(1)), [])
            comps[name] = cur
            if m.group(1):
                entry = name
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        mo = _OP_LINE.match(line)
        if not mo:
            continue
        _, name, result, kind, rest = mo.groups()
        # operand names: inside the first paren group, before attrs
        operands = _OPERAND.findall(rest.split("),", 1)[0])
        inst = Instr(name, kind, result, operands, rest)
        if kind == "while":
            t = _TRIP.search(line)
            inst.trip = int(t.group(1)) if t else 1
        cur.instrs.append(inst)
        shapes[name] = result
    return comps, shapes, entry


def _called(inst: Instr) -> List[str]:
    return _ATTR_COMP.findall(inst.rest)


def _dot_flops(inst: Instr, shapes: Dict[str, str]) -> float:
    out_elems = 1
    md = _DIMS.search(inst.result)
    if md and md.group(1):
        for d in md.group(1).split(","):
            out_elems *= int(d)
    lhs = shapes.get(inst.operands[0], "") if inst.operands else ""
    mc = _CONTRACT.search(inst.rest)
    contracted = 1
    if mc and lhs:
        ml = _DIMS.search(lhs)
        if ml and ml.group(1):
            dims = [int(d) for d in ml.group(1).split(",")]
            for idx in (mc.group(1) or "").split(","):
                if idx != "" and int(idx) < len(dims):
                    contracted *= dims[int(idx)]
    return 2.0 * out_elems * contracted


RESIDENT_RATIO = 64  # operand >64x result => slice-like / loop-resident


def _instr_bytes(inst: Instr, shapes: Dict[str, str], trip: int = 1) -> float:
    """Result + operand bytes; inside a while body (trip > 1), an operand
    vastly larger than the result is either a dynamic-slice view of a
    loop-wide buffer (scan xs: the buffer is read ~once per loop
    execution, not once per step) or a loop-resident weight (VMEM on TPU)
    -- both are charged once per loop execution, i.e. bytes/trip."""
    rb = shape_bytes(inst.result)
    total = float(rb)
    for op in inst.operands:
        if op not in shapes:
            continue
        ob = shape_bytes(shapes[op])
        if trip > 1 and ob > RESIDENT_RATIO * max(rb, 1):
            total += ob / trip
        else:
            total += ob
    return total


@dataclasses.dataclass
class HloCensus:
    flops: float
    hbm_bytes: float
    collective_bytes: float
    coll_breakdown: Dict[str, float]
    while_trips: Dict[str, int]

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)


def analyze(text: str) -> HloCensus:
    comps, shapes, entry = parse_module(text)
    if entry not in comps:
        return HloCensus(0.0, 0.0, 0.0, {}, {})

    # Propagate multipliers through the call graph.
    mult: Dict[str, float] = {name: 0.0 for name in comps}
    fused: Set[str] = set()
    trips: Dict[str, int] = {}
    comp_trip: Dict[str, int] = {}   # immediate enclosing-loop trip count

    stack = [(entry, 1.0)]
    while stack:
        name, m = stack.pop()
        if name not in comps:
            continue
        mult[name] += m
        comp = comps[name]
        for inst in comp.instrs:
            if inst.kind == "while":
                trips[inst.name] = inst.trip
                for callee in _called(inst):
                    comp_trip[callee] = max(comp_trip.get(callee, 1), inst.trip)
                    stack.append((callee, m * inst.trip))
            elif inst.kind == "fusion":
                for callee in _called(inst):
                    fused.add(callee)
                    stack.append((callee, m))
            elif inst.kind in ("conditional", "call", "custom-call", "sort",
                               "reduce", "map", "scatter", "select-and-scatter",
                               "reduce-window", "all-reduce"):
                # to_apply bodies are tiny scalar computations: propagate for
                # flops completeness, but they contain no dots in practice.
                for callee in _called(inst):
                    fused.add(callee)
                    stack.append((callee, m))

    flops = 0.0
    hbm = 0.0
    coll = {c: 0.0 for c in _COLLECTIVES}
    for name, comp in comps.items():
        m = mult.get(name, 0.0)
        if m == 0.0:
            continue
        sequential = name == entry or name not in fused
        for inst in comp.instrs:
            kind = inst.kind
            if kind in ("dot", "convolution"):
                flops += m * _dot_flops(inst, shapes)
            ckind = None
            for c in _COLLECTIVES:
                if kind == c or kind == c + "-start":
                    ckind = c
            if ckind and sequential:
                coll[ckind] += m * shape_bytes(inst.result)
            if sequential and kind not in SKIP_BYTES_OPS and not kind.endswith("-done"):
                hbm += m * _instr_bytes(inst, shapes, comp_trip.get(name, 1))
    total_coll = sum(coll.values())
    return HloCensus(
        flops=flops,
        hbm_bytes=hbm,
        collective_bytes=total_coll,
        coll_breakdown={**coll, "total": total_coll},
        while_trips=trips,
    )
