from repro.roofline.model import (
    HBM_BW, ICI_BW, PEAK_FLOPS, RooflineReport, collective_bytes,
    format_roofline_rows, model_flops_estimate, shape_bytes,
)

__all__ = [
    "HBM_BW", "ICI_BW", "PEAK_FLOPS", "RooflineReport", "collective_bytes",
    "format_roofline_rows", "model_flops_estimate", "shape_bytes",
]
