"""Settings ("bitstream") assembly for the Pixie overlay.

The specialization stage of the paper's tool flow combines the PaR result
with the parameterized components into reconfiguration bitstreams.  Our
configuration is the exact software analogue: per-level PE opcode vectors
plus per-level VC mux-select tables.  In the *conventional* path these are
runtime arrays (settings registers updated over a bus -> swapping them
never recompiles anything); in the *parameterized* path they are baked
constants (micro-reconfiguration -> re-specialization = re-jit).
"""

from __future__ import annotations

import dataclasses
import json
import math
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.grid import GridSpec
from repro.core.ingest import IngestError, IngestPlan, plan_for
from repro.core.place import Placement
from repro.core.route import Routing


@dataclasses.dataclass
class VCGRAConfig:
    """The full settings of one application mapped on one grid."""

    app_name: str
    grid_name: str
    opcodes: List[np.ndarray]        # per level: int32 [pes_in_level]
    selects: List[np.ndarray]        # per level: int32 [pes_in_level, 2]
    out_sel: np.ndarray              # int32 [num_outputs]
    input_order: Tuple[str, ...]     # memory-VC channel ordering
    const_values: Dict[str, float]   # default coefficient values
    # Stable identity set by caching layers (runtime/fleet.py): the DFG
    # structural hash + grid.  None for configs assembled outside a cache.
    cache_key: Optional[str] = None
    # How each memory-VC channel is produced from a raw image frame
    # (core/ingest.py); None when the app is not image-feedable (a channel
    # is neither a stencil tap nor a const) and needs named inputs.
    ingest: Optional[IngestPlan] = None

    # -- conventional-path form (settings registers as device arrays) ------

    def to_jax(self):
        return (
            tuple(jnp.asarray(o) for o in self.opcodes),
            tuple(jnp.asarray(s) for s in self.selects),
            jnp.asarray(self.out_sel),
        )

    # -- multi-tenant form (stacked settings registers) ----------------------

    def config_shapes(self) -> Tuple:
        """Shape signature of the settings arrays.  Two configs with equal
        signatures were mapped on structurally identical grids and can be
        stacked into one batched settings bank."""
        return (
            tuple(o.shape for o in self.opcodes),
            tuple(s.shape for s in self.selects),
            tuple(self.out_sel.shape),
        )

    @staticmethod
    def stack(configs: Sequence["VCGRAConfig"]):
        """Stack N same-grid configs into batched settings arrays.

        Every application mapped on one grid yields identically-shaped
        config arrays (the invariant the overlay executors exploit for
        their compile-once claim); stacking them along a new leading axis
        is the multi-tenant extension: one vmapped overlay executable then
        runs N *different* applications in a single dispatch (a batched
        ``OverlayPlan``, see ``core/plan.py``).

        Returns ``(opcodes, selects, out_sel)`` with per-level leaves of
        shape ``[N, pes]`` / ``[N, pes, 2]`` and ``out_sel: [N, num_outputs]``.
        """
        if not configs:
            raise ValueError("cannot stack an empty config list")
        sig = configs[0].config_shapes()
        for c in configs[1:]:
            if c.config_shapes() != sig:
                raise ValueError(
                    f"config {c.app_name!r} (grid {c.grid_name!r}) does not "
                    f"match the stack's grid {configs[0].grid_name!r}: "
                    f"{c.config_shapes()} != {sig}"
                )
        num_levels = len(configs[0].opcodes)
        return (
            tuple(
                jnp.stack([jnp.asarray(c.opcodes[lvl]) for c in configs])
                for lvl in range(num_levels)
            ),
            tuple(
                jnp.stack([jnp.asarray(c.selects[lvl]) for c in configs])
                for lvl in range(num_levels)
            ),
            jnp.stack([jnp.asarray(c.out_sel) for c in configs]),
        )

    # -- size accounting (the "bitstream size" analogue) --------------------

    def settings_words(self) -> int:
        return int(
            sum(o.size for o in self.opcodes)
            + sum(s.size for s in self.selects)
            + self.out_sel.size
        )

    def settings_bits(self, grid: GridSpec) -> int:
        bits = 4 * sum(int(o.size) for o in self.opcodes)
        for lvl, s in enumerate(self.selects):
            bw = max(1, math.ceil(math.log2(max(grid.vc_in_width(lvl), 2))))
            bits += bw * int(s.size)
        out_bw = max(1, math.ceil(math.log2(max(grid.pes_per_level[-1], 2))))
        bits += out_bw * int(self.out_sel.size)
        return bits

    # -- (de)serialization ---------------------------------------------------

    def to_json(self) -> str:
        return json.dumps(
            {
                "app_name": self.app_name,
                "grid_name": self.grid_name,
                "opcodes": [o.tolist() for o in self.opcodes],
                "selects": [s.tolist() for s in self.selects],
                "out_sel": self.out_sel.tolist(),
                "input_order": list(self.input_order),
                "const_values": self.const_values,
                "ingest": self.ingest.to_dict() if self.ingest else None,
            }
        )

    @staticmethod
    def from_json(text: str) -> "VCGRAConfig":
        d = json.loads(text)
        return VCGRAConfig(
            app_name=d["app_name"],
            grid_name=d["grid_name"],
            opcodes=[np.asarray(o, dtype=np.int32) for o in d["opcodes"]],
            selects=[np.asarray(s, dtype=np.int32).reshape(-1, 2) for s in d["selects"]],
            out_sel=np.asarray(d["out_sel"], dtype=np.int32),
            input_order=tuple(d["input_order"]),
            const_values={k: float(v) for k, v in d["const_values"].items()},
            ingest=IngestPlan.from_dict(d["ingest"]) if d.get("ingest") else None,
        )


def assemble(placement: Placement, routing: Routing, grid: GridSpec) -> VCGRAConfig:
    """PaR result + grid -> settings (paper's specialization-stage input)."""
    opcodes: List[np.ndarray] = []
    for lvl, cells in enumerate(placement.cells):
        ops = np.zeros((grid.pes_per_level[lvl],), dtype=np.int32)  # NONE fill
        for slot, c in enumerate(cells):
            ops[slot] = int(c.op)
        opcodes.append(ops)
    input_order = tuple(placement.dfg.inputs)
    const_values = dict(placement.dfg.const_values)
    try:
        ingest = plan_for(input_order, const_values, grid.num_inputs)
    except IngestError:
        ingest = None  # not image-feedable; unfused named-channel path only
    return VCGRAConfig(
        app_name=placement.dfg.name,
        grid_name=grid.name,
        opcodes=opcodes,
        selects=[s.copy() for s in routing.sel],
        out_sel=routing.out_sel.copy(),
        input_order=input_order,
        const_values=const_values,
        ingest=ingest,
    )
