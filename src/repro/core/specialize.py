"""Parameterized VCGRA execution: constant-propagated specialization.

The paper's headline optimization: treat the infrequently-changing settings
as *parameters*, implement them as constants, and re-optimize the design
for new constant values by (micro-)reconfiguration.  On FPGA this is the
TLUT/TCON tool flow (constant propagation through LUTs, routing mapped on
tunable connections); the XLA-native analogue is **trace-time constant
binding**: the config is closed over as Python/numpy constants, so

* each PE traces only its configured functional unit (dead units gone --
  the 24% PE resource cut of Table I),
* each VC mux select becomes direct SSA wiring (gathers gone -- the 82% VC
  resource cut),
* NONE PEs and BUF chains that feed nothing are never emitted at all,

and "micro-reconfiguration" = re-jitting the specialized function, whose
latency we measure and report as the reconfiguration-time analogue.

Optionally the coefficient inputs (`dfg.const`) are baked too -- a second
specialization level the paper leaves implicit (its red coefficient nodes
are data), exposed here as ``bake_consts=True``.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ops as pe_ops
from repro.core.bitstream import VCGRAConfig
from repro.core.grid import GridSpec
from repro.core.ops import Op


def _live_slots(grid: GridSpec, config: VCGRAConfig) -> List[Set[int]]:
    """Backward liveness over the grid: which PE slots contribute to any
    output.  The hardware analogue: frames never touched by the app's
    bitstream.  XLA's DCE would find this too; doing it at trace time keeps
    the emitted HLO (and our resource census) honest."""
    nl = grid.num_levels
    live: List[Set[int]] = [set() for _ in range(nl)]
    live[nl - 1].update(int(s) for s in config.out_sel)
    for lvl in range(nl - 1, 0, -1):
        for slot in live[lvl]:
            op = Op(int(config.opcodes[lvl][slot]))
            if op == Op.NONE:
                continue
            live[lvl - 1].add(int(config.selects[lvl][slot, 0]))
            if op not in pe_ops.UNARY_OPS:
                live[lvl - 1].add(int(config.selects[lvl][slot, 1]))
    return live


def build_specialized_fn(
    grid: GridSpec,
    config: VCGRAConfig,
    bake_consts: bool = False,
):
    """Emit the app-specific executor with the settings burned in.

    Returns ``fn(x) -> y`` (same [num_inputs, batch] -> [num_outputs,
    batch] contract as the conventional overlay, so the two paths are
    drop-in interchangeable and directly comparable).
    """
    live = _live_slots(grid, config)
    const_idx: Dict[int, float] = {}
    if bake_consts:
        for i, name in enumerate(config.input_order):
            if name in config.const_values:
                const_idx[i] = config.const_values[name]

    def fn(x: jnp.ndarray) -> jnp.ndarray:
        dtype = x.dtype
        # Value environment for the previous level, indexed by slot.
        prev: Dict[int, jnp.ndarray] = {}
        for lvl in range(grid.num_levels):
            cur: Dict[int, jnp.ndarray] = {}
            for slot in sorted(live[lvl]):
                op = Op(int(config.opcodes[lvl][slot]))
                if op == Op.NONE:
                    # A live select pointing at a NONE PE only happens for
                    # padded outputs; emit zero like the idle PE.
                    cur[slot] = jnp.zeros(x.shape[1:], dtype)
                    continue
                sa = int(config.selects[lvl][slot, 0])
                sb = int(config.selects[lvl][slot, 1])
                unary = op in pe_ops.UNARY_OPS  # port b not live for these

                def fetch(idx: int):
                    if lvl == 0:
                        if idx in const_idx:
                            return jnp.asarray(const_idx[idx], dtype)
                        return x[idx]
                    return prev[idx]

                a = fetch(sa)
                b = a if unary else fetch(sb)
                cur[slot] = pe_ops.apply_op(op, a, b)
            prev = cur
        outs = [prev[int(s)] for s in config.out_sel]
        return jnp.stack(
            [jnp.broadcast_to(o, x.shape[1:]) for o in outs], axis=0
        )

    return fn


def jit_specialized(
    grid: GridSpec, config: VCGRAConfig, bake_consts: bool = False
):
    """jit of the specialized executor.  Re-invoking this for a new config
    is the micro-reconfiguration step; its wall time is the reconfiguration
    cost reported in the benchmarks."""
    return jax.jit(build_specialized_fn(grid, config, bake_consts=bake_consts))
