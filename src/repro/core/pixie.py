"""Pixie: the top-level VCGRA overlay accelerator facade.

Mirrors the paper's operational model end to end:

  overlay compile (once)      <->  XLA jit of the generic interpreter
  map application (<1 s)      <->  synthesis + place + route + settings gen
  reconfigure (ms)            <->  conventional: swap settings arrays
                                   parameterized: re-jit specialized fn
  execute                     <->  run the pipelined PE grid on pixel batch

All stages are wall-clock timed; the timings feed the compilation-gap
benchmark (paper Sec. V-E: <1 s mapping vs ~1200 s FPGA compile).
"""

from __future__ import annotations

import time
import warnings
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import applications as apps
from repro.core import grid as gridlib
from repro.core import interpreter, specialize
from repro.core.bitstream import VCGRAConfig, assemble
from repro.core.dfg import DFG
from repro.core.grid import GridSpec
from repro.core.ingest import IngestPlan
from repro.core.place import place
from repro.core.plan import (
    OverlayExecutable, OverlayPlan, PipelineSpec, compile_plan,
)
from repro.core.route import route
from repro.parallel.axes import MeshSpec


def map_app(dfg: DFG, grid: GridSpec) -> VCGRAConfig:
    """The full VCGRA tool flow: netlist -> placement -> routing -> settings."""
    placement = place(dfg, grid)
    routing = route(placement, grid)
    return assemble(placement, routing, grid)


class Pixie:
    """A virtual CGRA instance.

    mode='conventional'  settings are runtime arrays; reconfiguration is a
                         buffer swap and never recompiles (compile-once
                         overlay).
    mode='parameterized' settings are baked constants; reconfiguration
                         re-specializes (re-jits) but executes a leaner
                         datapath (paper's TLUT/TCON flow).

    ``backend`` ("xla" | "pallas") and ``mesh`` (a
    :class:`~repro.parallel.axes.MeshSpec`) select the execution backend
    and app-axis device sharding of every conventional-mode dispatch --
    the same plan axes the fleet exposes, so single-app users can
    exercise the pallas megakernels (or a mesh) without constructing a
    ``PixieFleet``.  Only conventional mode takes them (the parameterized
    path bakes one app into one XLA executable by construction), and only
    the app axis: row sharding needs the fleet's frame-canvas dispatch
    (``PixieFleet``), so ``rows > 1`` is rejected here.  The bare
    device-count kwarg survives as a DeprecationWarning shim for
    ``mesh=MeshSpec(app=k)``.
    """

    def __init__(
        self,
        grid: GridSpec,
        mode: str = "conventional",
        bake_consts: bool = False,
        backend: str = "xla",
        mesh: Optional[MeshSpec] = None,
        devices: Optional[int] = None,
    ):
        if mode not in ("conventional", "parameterized"):
            raise ValueError(f"unknown mode {mode!r}")
        interpreter.check_backend(backend)
        if devices is not None:
            d = int(devices)
            if d < 1:
                raise ValueError(f"devices must be >= 1, got {devices}")
            if mesh is not None:
                raise ValueError(
                    "pass mesh=MeshSpec(...) or the deprecated bare device "
                    "count, not both"
                )
            warnings.warn(
                "the bare device-count kwarg of Pixie is deprecated: pass "
                f"mesh=MeshSpec(app={d}) instead",
                DeprecationWarning, stacklevel=2,
            )
            mesh = MeshSpec(app=d)
        mesh = mesh or MeshSpec()
        if not isinstance(mesh, MeshSpec):
            raise ValueError(f"mesh must be a MeshSpec, got {mesh!r}")
        if mesh.rows > 1:
            raise ValueError(
                "Pixie shards the app axis only; row sharding needs the "
                "fleet's frame-canvas dispatch -- use PixieFleet with "
                f"mesh=MeshSpec(app={mesh.app}, rows={mesh.rows})"
            )
        if mode == "parameterized" and (backend != "xla" or mesh != MeshSpec()):
            raise ValueError(
                "backend/mesh apply to the conventional overlay plans "
                "only; the parameterized path specializes per app"
            )
        self.grid = grid
        self.mode = mode
        self.bake_consts = bake_consts
        self.backend = backend
        self.mesh = mesh
        self.config: Optional[VCGRAConfig] = None
        self._overlay_fn: Optional[OverlayExecutable] = None
        self._batched_overlay_fn: Optional[OverlayExecutable] = None
        self._fused_fns: Dict[int, OverlayExecutable] = {}  # radius -> executable
        self._pipeline_fns: Dict[PipelineSpec, OverlayExecutable] = {}
        self._config_jax = None
        self._ingest_jax = None
        self._spec_fn: Optional[Callable] = None
        self.timings: Dict[str, float] = {}

    @property
    def devices(self) -> int:
        """App-axis mesh width (the reading side of the deprecated bare
        device-count surface)."""
        return self.mesh.app

    def _plan(self, *, batched: bool = False, fused: bool = False,
              radius: Optional[int] = None) -> OverlayPlan:
        """This instance's corner of the plan matrix (the mesh only shards
        batched dispatch -- single-app plans have no app axis)."""
        return OverlayPlan(
            grid=self.grid, batched=batched, fused=fused, radius=radius,
            backend=self.backend, mesh=self.mesh if batched else MeshSpec(),
        )

    # -- stage 1: overlay compile (the "1200 s" FPGA-compile analogue) ------

    def compile_overlay(self, batch: int = 1024) -> float:
        """AOT-compile the generic interpreter for this grid structure.
        Only meaningful (and only needed) in conventional mode."""
        t0 = time.perf_counter()
        self._overlay_fn = compile_plan(self._plan())
        if self.mode == "conventional":
            dummy_cfg = self._dummy_config().to_jax()
            x = jnp.zeros((self.grid.num_inputs, batch), self.grid.dtype)
            self._overlay_fn.lower(dummy_cfg, x).compile()
        dt = time.perf_counter() - t0
        self.timings["overlay_compile_s"] = dt
        return dt

    def _dummy_config(self) -> VCGRAConfig:
        g = self.grid
        return VCGRAConfig(
            app_name="<dummy>",
            grid_name=g.name,
            opcodes=[np.zeros((p,), np.int32) for p in g.pes_per_level],
            selects=[np.zeros((p, 2), np.int32) for p in g.pes_per_level],
            out_sel=np.zeros((g.num_outputs,), np.int32),
            input_order=tuple(f"i{k}" for k in range(g.num_inputs)),
            const_values={},
        )

    # -- stage 2: map an application (the "<1 s" analogue) -------------------

    def map(self, dfg: DFG) -> VCGRAConfig:
        t0 = time.perf_counter()
        config = map_app(dfg, self.grid)
        self.timings["map_s"] = time.perf_counter() - t0
        return config

    # -- stage 3: (micro-)reconfiguration ------------------------------------

    def load(self, config: VCGRAConfig, batch: int = 1024) -> float:
        """Install `config`; returns the reconfiguration wall time."""
        t0 = time.perf_counter()
        self.config = config
        self._ingest_jax = (
            config.ingest.to_jax(self.grid.dtype) if config.ingest else None
        )
        if self.mode == "conventional":
            self._config_jax = config.to_jax()  # settings-register write
        else:
            self._spec_fn = specialize.jit_specialized(
                self.grid, config, bake_consts=self.bake_consts
            )
            x = jnp.zeros((self.grid.num_inputs, batch), self.grid.dtype)
            self._spec_fn.lower(x).compile()    # micro-reconfiguration
        dt = time.perf_counter() - t0
        self.timings["reconfig_s"] = dt
        return dt

    def run_dfg(self, dfg: DFG, **inputs) -> jnp.ndarray:
        """map + load + run in one call (convenience)."""
        self.load(self.map(dfg))
        return self(**inputs)

    # -- stage 4: execution ----------------------------------------------------

    def run_raw(self, x: jnp.ndarray) -> jnp.ndarray:
        """x: [num_inputs, batch] -> y: [num_outputs, batch]."""
        if self.config is None:
            raise RuntimeError("no application loaded; call load() first")
        if self.mode == "conventional":
            if self._overlay_fn is None:
                self.compile_overlay(batch=x.shape[-1])
            return self._overlay_fn(self._config_jax, x)
        return self._spec_fn(x)

    def __call__(self, **inputs) -> jnp.ndarray:
        if self.config is None:
            raise RuntimeError("no application loaded; call load() first")
        x = interpreter.pack_inputs(self.config, inputs, self.grid.dtype)
        return self.run_raw(x)

    # -- stage 4b: multi-tenant execution --------------------------------------

    def run_many(
        self,
        requests: Sequence[Tuple[Union[DFG, VCGRAConfig], Dict[str, jnp.ndarray]]],
        batch_pad: Optional[int] = None,
    ) -> List[jnp.ndarray]:
        """Execute N applications on this overlay in ONE batched dispatch.

        ``requests``: (application, named-inputs) pairs; each application is
        a :class:`DFG` (mapped here, <1 s) or a pre-mapped
        :class:`VCGRAConfig` for the same grid.  The configs are stacked and
        the vmapped overlay runs all of them at once -- N tenants resident
        in one physical overlay instead of N sequential reconfigurations.
        Only meaningful in conventional mode (the parameterized path bakes
        one app into the executable by construction).

        ``batch_pad``: pad every app's pixel batch to this length (>= the
        largest request) so repeated calls reuse one compiled executable;
        defaults to the largest batch in this call.  Ragged requests are
        zero-padded and the outputs sliced back, so results are bitwise
        identical to N sequential runs.  The dispatch runs on this
        instance's ``backend`` and, when ``mesh.app > 1``, shards the app
        axis over a local device mesh (bitwise-equal either way).

        Returns one ``[num_outputs, batch_i]`` array per request, in order.
        """
        if self.mode != "conventional":
            raise RuntimeError(
                "run_many requires mode='conventional' (the parameterized "
                "path specializes a single application per executable)"
            )
        if not requests:
            return []
        configs: List[VCGRAConfig] = []
        xs: List[jnp.ndarray] = []
        for app, inputs in requests:
            cfg = app if isinstance(app, VCGRAConfig) else self.map(app)
            x = interpreter.pack_inputs(cfg, inputs, self.grid.dtype)
            if x.ndim != 2:
                raise ValueError(
                    f"run_many needs flat [channels, batch] inputs, got {x.shape}"
                )
            configs.append(cfg)
            xs.append(interpreter.pad_channels(x, self.grid.num_inputs))
        stacked, xstack, batches = interpreter.stack_for_dispatch(
            configs, xs, batch_pad
        )
        if self._batched_overlay_fn is None:
            self._batched_overlay_fn = compile_plan(self._plan(batched=True))
        t0 = time.perf_counter()
        ys = jax.block_until_ready(self._batched_overlay_fn(stacked, xstack))
        self.timings["run_many_s"] = time.perf_counter() - t0
        return [ys[i, :, : batches[i]] for i in range(len(requests))]

    def run_image(self, image: jnp.ndarray) -> jnp.ndarray:
        """Run a loaded stencil application over a full [H, W] image.

        Conventional mode takes the fused-ingest path: line-buffer
        formation (tap slices) + pack + dispatch are one jitted executable
        (a fused ``OverlayPlan`` on this instance's backend), shared by
        every app mapped on the grid.  The parameterized mode (and apps without an ingest
        plan) falls back to the host-side two-step path, which stays
        available as the oracle the fused path is tested against.
        """
        if self.config is None:
            raise RuntimeError("no application loaded; call load() first")
        H, W = image.shape
        if self.mode == "conventional" and self.config.ingest is not None:
            radius = self.config.ingest.radius
            if radius not in self._fused_fns:
                self._fused_fns[radius] = compile_plan(
                    self._plan(fused=True, radius=radius)
                )
            # Settings were converted to device arrays once at load();
            # per-frame cost is the single fused dispatch, nothing else.
            y = self._fused_fns[radius](
                self._config_jax, self._ingest_jax, jnp.asarray(image)
            )
        else:
            taps = apps.stencil_inputs(image)
            feed = {k: v for k, v in taps.items() if k in self.config.input_order}
            y = self(**feed)
        return y.reshape((-1, H, W))[0] if y.shape[0] == 1 else y.reshape((-1, H, W))


    def run_pipeline(
        self,
        chain: Sequence[Union[DFG, VCGRAConfig, str]],
        image: jnp.ndarray,
        out_channels: Optional[Sequence[int]] = None,
    ) -> jnp.ndarray:
        """Run a multi-stage application chain over one [H, W] frame as
        ONE device-resident executable.

        ``chain``: ordered stages (DFGs mapped here, pre-mapped configs,
        or library app names); stage i's ``out_channels[i]`` output
        (default channel 0) feeds stage i+1's ingest taps without the
        intermediate ever leaving the device -- a pipeline
        :class:`~repro.core.plan.OverlayPlan` compiled once per distinct
        chain and cached on this instance.  A single-stage chain is just
        :meth:`run_image` (same plan, same caches).  Conventional mode
        only; every stage needs an ingest plan (fused ingest end to end).
        Returns [H, W] (or [num_outputs, H, W]) of the final stage.
        """
        if self.mode != "conventional":
            raise RuntimeError(
                "run_pipeline requires mode='conventional' (the "
                "parameterized path specializes a single application per "
                "executable)"
            )
        cfgs = []
        for stage in chain:
            if isinstance(stage, str):
                stage = apps.ALL_APPS[stage]()
            cfgs.append(stage if isinstance(stage, VCGRAConfig)
                        else self.map(stage))
        if not cfgs:
            raise ValueError("chain must name at least one stage")
        for cfg in cfgs:
            if cfg.ingest is None:
                raise ValueError(
                    f"pipeline stage {cfg.app_name!r} has no ingest plan; "
                    f"chains need fused-ingest stages end to end"
                )
        spec = PipelineSpec.chain(cfgs, out_channels)
        if spec.depth == 1:
            self.load(cfgs[0])
            return self.run_image(image)
        fn = self._pipeline_fns.get(spec)
        if fn is None:
            fn = compile_plan(OverlayPlan(
                grid=self.grid, batched=True, pipeline=(spec,),
                backend=self.backend, mesh=self.mesh,
            ))
            self._pipeline_fns[spec] = fn
        H, W = image.shape
        settings = tuple(
            (
                VCGRAConfig.stack([st.config]),
                IngestPlan.stack([st.config.ingest], self.grid.dtype),
                jnp.asarray([st.out_channel], jnp.int32),
            )
            for st in spec.stages
        )
        hw = jnp.asarray([[H, W]], jnp.int32)
        t0 = time.perf_counter()
        y = fn(settings, hw, jnp.asarray(image)[None])[0]
        self.timings["run_pipeline_s"] = time.perf_counter() - t0
        return y.reshape((-1, H, W))[0] if y.shape[0] == 1 else y.reshape((-1, H, W))


def sobel_pixie(mode: str = "conventional", data_bits: int = 32,
                backend: str = "xla") -> Pixie:
    """The paper's demonstrator: Sobel on the 45-PE/4-VC grid (Sec. IV)."""
    pix = Pixie(gridlib.sobel_grid(data_bits=data_bits), mode=mode,
                backend=backend)
    return pix
