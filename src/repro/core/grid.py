"""VCGRA grid specification and the grid-generator tool.

Paper Sec. III-C: "Describing the whole VCGRA grid in VHDL is a time
consuming task. Therefore we developed a tool that automatically creates
the VHDL top-level description of a VCGRA from a description of the
hardware structure. The only inputs needed are the number of input
elements from memory and the structure of the grid ... All other
parameters (e.g. for the channels) are automatically derived."

Our generator emits a :class:`GridSpec` (consumed by the interpreter, the
specializer and the Pallas kernel) instead of VHDL; the derived channel
parameters follow the paper's Eqs. (1)-(3):

  N  = max{A, B, C, D, ...}                  (internal channel bitwidth)
  M  = #predecessors                         (valid-vector width)
  bw = ceil(log2(#predecessors))             (mux config-word width)

Shapes: in addition to the rectangular style the generator supports an
arbitrary number of PEs per level ("application specific grid designs"),
e.g. the inverted-triangular shape the paper suggests for reduction trees.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Sequence, Tuple

import jax.numpy as jnp

from repro.core.dfg import DFG


@dataclasses.dataclass(frozen=True)
class GridSpec:
    """Static structure of a VCGRA overlay instance.

    The structure (like the FPGA overlay bitstream's *shape*) is fixed at
    overlay-compile time; only the settings (opcodes, routing selects) are
    reconfigurable afterwards.
    """

    name: str
    num_inputs: int                      # memory-interface VC width (top)
    pes_per_level: Tuple[int, ...]       # PEs in each pipeline level
    num_outputs: int                     # bottom (memory-interface) VC width
    data_bits: int = 32                  # PE data bitwidth (paper: configurable)
    float_pe: bool = False               # fixed-point vs FloPoCo-float PE flavour

    # -- derived structure -------------------------------------------------

    @property
    def num_levels(self) -> int:
        return len(self.pes_per_level)

    @property
    def num_pes(self) -> int:
        return sum(self.pes_per_level)

    def vc_in_width(self, level: int) -> int:
        """#predecessor signals entering the VC above `level` (M in Eq. 2)."""
        if level == 0:
            return self.num_inputs
        return self.pes_per_level[level - 1]

    def vc_out_ports(self, level: int) -> int:
        """#mux outputs of the VC above `level` = 2 ports per PE."""
        return 2 * self.pes_per_level[level]

    @property
    def num_vcs(self) -> int:
        # One VC above each PE level plus the bottom output VC.
        return self.num_levels + 1

    @property
    def dtype(self):
        if self.float_pe:
            return jnp.float32 if self.data_bits > 16 else jnp.bfloat16
        return jnp.int32 if self.data_bits > 16 else jnp.int16

    # -- paper Eq. (1)-(3) resource model -----------------------------------

    def channel_params(self, level: int) -> Dict[str, int]:
        preds = self.vc_in_width(level)
        return {
            "N_internal_bitwidth": self.data_bits,          # Eq. (1), uniform bw here
            "M_valid_vector": preds,                        # Eq. (2)
            "bw_mux_config_word": max(1, math.ceil(math.log2(max(preds, 2)))),  # Eq. (3)
        }

    def settings_bits(self) -> Dict[str, int]:
        """Total settings-register ("bitstream") size of the overlay."""
        op_bits = 4  # 12 opcodes
        pe_bits = self.num_pes * op_bits
        vc_bits = 0
        for lvl in range(self.num_levels):
            bw = self.channel_params(lvl)["bw_mux_config_word"]
            vc_bits += bw * self.vc_out_ports(lvl)
        out_bw = max(1, math.ceil(math.log2(max(self.pes_per_level[-1], 2))))
        vc_bits += out_bw * self.num_outputs
        return {"pe_bits": pe_bits, "vc_bits": vc_bits, "total_bits": pe_bits + vc_bits}

    def resource_model(self) -> Dict[str, int]:
        """Structural resource counts (mux instances, buffer registers):
        the architecture-level analogue of the paper's LUT/TCON budget."""
        muxes = sum(self.vc_out_ports(l) for l in range(self.num_levels)) + self.num_outputs
        mux_inputs = sum(
            self.vc_in_width(l) * self.vc_out_ports(l) for l in range(self.num_levels)
        ) + self.pes_per_level[-1] * self.num_outputs
        buffers = self.num_inputs + 2 * self.num_pes + self.num_outputs
        return {
            "pes": self.num_pes,
            "vcs": self.num_vcs,
            "muxes": muxes,
            "mux_input_legs": mux_inputs,
            "data_buffers": buffers,
            **self.settings_bits(),
        }

    def __str__(self) -> str:  # pragma: no cover - debugging aid
        shape = "x".join(str(p) for p in self.pes_per_level)
        kind = "float" if self.float_pe else "fixed"
        return f"GridSpec({self.name}: in={self.num_inputs} [{shape}] out={self.num_outputs} {kind}{self.data_bits})"


# -- the generator tool ------------------------------------------------------


def rectangular(
    name: str,
    num_inputs: int,
    levels: int,
    width: int,
    num_outputs: int = 1,
    data_bits: int = 32,
    float_pe: bool = False,
) -> GridSpec:
    """The paper's default rectangular style: every level has `width` PEs."""
    return GridSpec(name, num_inputs, (width,) * levels, num_outputs, data_bits, float_pe)


def custom(
    name: str,
    num_inputs: int,
    pes_per_level: Sequence[int],
    num_outputs: int = 1,
    data_bits: int = 32,
    float_pe: bool = False,
) -> GridSpec:
    """Arbitrary per-level PE counts ("application specific grid designs")."""
    return GridSpec(name, num_inputs, tuple(int(p) for p in pes_per_level), num_outputs, data_bits, float_pe)


def paper_4x4(data_bits: int = 32, float_pe: bool = False) -> GridSpec:
    """The fully parameterized 4x4 grid of paper Sec. V-C."""
    return rectangular("paper-4x4", 8, 4, 4, num_outputs=4, data_bits=data_bits, float_pe=float_pe)


def sobel_grid(data_bits: int = 32, float_pe: bool = False) -> GridSpec:
    """The Sobel demonstration grid of paper Sec. IV / Fig. 5:
    45 PEs in 5 levels of 9, 4 inter-level VCs, 18 memory inputs
    (9 pixels + 9 coefficients)."""
    return rectangular(
        "sobel-5x9", 18, 5, 9, num_outputs=1, data_bits=data_bits, float_pe=float_pe
    )


def for_dfg(
    dfg: DFG,
    name: str | None = None,
    shape: str = "exact",
    data_bits: int = 32,
    float_pe: bool = False,
) -> GridSpec:
    """Auto-generate a grid that fits `dfg` ("Automatic generation of these
    grids for a specific application class is currently work in progress"
    -- here it is implemented).

    shape='exact'       per-level PE count = per-level demand incl. buffers
    shape='rect'        rectangular, width = max level demand (paper default;
                        yields the many-NONE-PEs effect of Fig. 5)
    shape='triangular'  monotonically non-increasing widths (the paper's
                        suggested optimization for reduction trees)
    """
    from repro.core.place import level_demand  # local import to avoid cycle

    demand = level_demand(dfg)
    if shape == "exact":
        pes = tuple(demand)
    elif shape == "rect":
        pes = (max(demand),) * len(demand)
    elif shape == "triangular":
        pes: List[int] = []
        cur = max(demand)
        for d in demand:
            cur = max(d, min(cur, d if not pes else pes[-1]))
            pes.append(cur)
        pes = tuple(pes)
    else:
        raise ValueError(f"unknown shape {shape!r}")
    return GridSpec(
        name or f"{dfg.name}-{shape}",
        num_inputs=len(dfg.inputs),
        pes_per_level=pes,
        num_outputs=len(dfg.outputs),
        data_bits=data_bits,
        float_pe=float_pe,
    )
