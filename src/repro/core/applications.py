"""Pixie application library: image-processing task graphs.

The paper demonstrates a 3x3 Sobel convolution (Fig. 4: blue pixel nodes,
red coefficient nodes, gray op nodes, green output; Fig. 5: mapped on a
45-PE / 4-VC grid).  This module builds that graph and a family of other
stencil/math applications, plus the memory-interface helpers that feed a
stencil's shifted pixel views into the top VC (the line-buffer analogue).
"""

from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.dfg import DFG, Ref

# 3x3 kernels -----------------------------------------------------------------

SOBEL_X = ((-1, 0, 1), (-2, 0, 2), (-1, 0, 1))
SOBEL_Y = ((-1, -2, -1), (0, 0, 0), (1, 2, 1))
GAUSS3 = ((1, 2, 1), (2, 4, 2), (1, 2, 1))       # / 16
SHARPEN = ((0, -1, 0), (-1, 5, -1), (0, -1, 0))
LAPLACE = ((0, 1, 0), (1, -4, 1), (0, 1, 0))
BOX3 = ((1, 1, 1), (1, 1, 1), (1, 1, 1))         # / 9


def tap_name(dj: int, di: int) -> str:
    """Pixel-tap input name for offset (dj, di) relative to the setpoint."""
    return f"p{dj + 1}{di + 1}"


def _sum_tree(g: DFG, terms: List[Ref]) -> Ref:
    """Left-paired adder tree with the odd element carried: reproduces the
    paper's mapping where 'the weighted pixel value of the multiplication
    on the right border of the array is buffered in every stage of the
    array until it is used in the last addition' (the mapper inserts the
    BUF carriers)."""
    while len(terms) > 1:
        nxt: List[Ref] = []
        for i in range(0, len(terms) - 1, 2):
            nxt.append(g.add(terms[i], terms[i + 1]))
        if len(terms) % 2:
            nxt.append(terms[-1])
        terms = nxt
    return terms[0]


def conv3x3(
    name: str,
    kernel: Sequence[Sequence[float]],
    skip_zero: bool = False,
    divisor: float | None = None,
) -> DFG:
    """The paper's inner-loop task graph (Algorithm 1 / Fig. 4):
    sum_{j,i} sobel[c+j][c+i] * pixel[pos-j][pos-i].

    With ``skip_zero`` the zero-coefficient taps are not instantiated (an
    application-level optimization the paper's rectangular grid leaves to
    NONE PEs).  ``divisor`` appends a final DIV by a constant (for
    normalized kernels such as the Gaussian).
    """
    g = DFG(name)
    taps = {}
    for dj in (-1, 0, 1):
        for di in (-1, 0, 1):
            taps[(dj, di)] = g.input(tap_name(dj, di))
    prods: List[Ref] = []
    for r, dj in enumerate((-1, 0, 1)):
        for c, di in enumerate((-1, 0, 1)):
            kval = float(kernel[r][c])
            if skip_zero and kval == 0.0:
                continue
            k = g.const(f"k{r}{c}", kval)
            prods.append(g.mul(taps[(dj, di)], k))
    acc = _sum_tree(g, prods)
    if divisor is not None:
        acc = g.div(acc, g.const("norm", float(divisor)))
    g.output(acc)
    return g


def sobel_x(**kw) -> DFG:
    return conv3x3("sobel_x", SOBEL_X, **kw)


def sobel_y(**kw) -> DFG:
    return conv3x3("sobel_y", SOBEL_Y, **kw)


def gaussian_blur(**kw) -> DFG:
    return conv3x3("gauss3", GAUSS3, divisor=16.0, **kw)


def sharpen(**kw) -> DFG:
    return conv3x3("sharpen", SHARPEN, **kw)


def laplace(**kw) -> DFG:
    return conv3x3("laplace", LAPLACE, **kw)


def box_blur(**kw) -> DFG:
    return conv3x3("box3", BOX3, divisor=9.0, **kw)


def sobel_magnitude() -> DFG:
    """|Gx| + |Gy| on a single grid: two convolution trees joined at the
    bottom -- our demonstration that 'multiple instances of the same graph
    can be implemented' if the grid is big enough (paper Sec. III)."""
    g = DFG("sobel_mag")
    taps = {}
    for dj in (-1, 0, 1):
        for di in (-1, 0, 1):
            taps[(dj, di)] = g.input(tap_name(dj, di))

    def tree(kernel, tag) -> Ref:
        prods: List[Ref] = []
        for r, dj in enumerate((-1, 0, 1)):
            for c, di in enumerate((-1, 0, 1)):
                k = g.const(f"{tag}{r}{c}", float(kernel[r][c]))
                prods.append(g.mul(taps[(dj, di)], k))
        return _sum_tree(g, prods)

    gx = tree(SOBEL_X, "kx")
    gy = tree(SOBEL_Y, "ky")
    g.output(g.add(g.absolute(gx), g.absolute(gy)))
    return g


def threshold(t: float = 128.0) -> DFG:
    """Binary threshold: 1 if pixel > t else 0 (uses the GT comparator PE)."""
    g = DFG("threshold")
    p = g.input(tap_name(0, 0))
    g.output(g.gt(p, g.const("t", t)))
    return g


def identity() -> DFG:
    g = DFG("identity")
    g.output(g.buf(g.input(tap_name(0, 0))))
    return g


ALL_APPS = {
    "sobel_x": sobel_x,
    "sobel_y": sobel_y,
    "sobel_mag": sobel_magnitude,
    "gauss3": gaussian_blur,
    "sharpen": sharpen,
    "laplace": laplace,
    "box3": box_blur,
    "threshold": threshold,
    "identity": identity,
}


# Memory-interface helpers ----------------------------------------------------


def stencil_inputs(image: jnp.ndarray, radius: int = 1) -> Dict[str, jnp.ndarray]:
    """Produce the shifted pixel views feeding the top memory VC.

    The hardware would stream these from line buffers; here it is a
    zero-padded shift per tap.  ``image``: [H, W] -> each tap: [H*W]
    flattened, tap (dj, di) holding image[y+dj, x+di].

    This host-side path is the *oracle* for the fused device-side ingest
    (``core/ingest.py`` + ``interpreter.form_tap_bank``), which forms the
    same taps inside the jitted overlay dispatch; tier-1 asserts they are
    bitwise identical.  Production paths should prefer the fused one.
    """
    img = jnp.asarray(image)
    H, W = img.shape
    pad = jnp.pad(img, radius)
    out: Dict[str, jnp.ndarray] = {}
    for dj in range(-radius, radius + 1):
        for di in range(-radius, radius + 1):
            view = pad[radius + dj : radius + dj + H, radius + di : radius + di + W]
            out[tap_name(dj, di)] = view.reshape(-1)
    return out


def conv2d_reference(
    image: np.ndarray, kernel: Sequence[Sequence[float]], divisor: float = 1.0
) -> np.ndarray:
    """Pure-numpy oracle of Algorithm 1: zero-padded 3x3 convolution in the
    tap convention ``sum kernel[j+1][i+1] * image[y+j, x+i]`` used
    consistently by this oracle and the DFG builder (for the paper's
    symmetric kernels this equals correlation with the flipped kernel)."""
    img = np.asarray(image)
    H, W = img.shape
    pad = np.pad(img, 1)
    kq = np.asarray(kernel, dtype=img.dtype)
    acc = np.zeros((H, W), dtype=np.result_type(img.dtype, kq.dtype))
    for r, dj in enumerate((-1, 0, 1)):
        for c, di in enumerate((-1, 0, 1)):
            acc = acc + kq[r, c] * pad[1 + dj : 1 + dj + H, 1 + di : 1 + di + W]
    if divisor != 1.0:
        if np.issubdtype(acc.dtype, np.integer):
            acc = acc // int(divisor)
        else:
            acc = acc / divisor
    return acc


def sobel_magnitude_reference(image: np.ndarray) -> np.ndarray:
    gx = conv2d_reference(image, SOBEL_X)
    gy = conv2d_reference(image, SOBEL_Y)
    return np.abs(gx) + np.abs(gy)
