"""Router: resolve placed operands onto Virtual-Channel mux selects.

Paper Sec. III-B: every input port of a succeeding PE has one multiplexer
whose inputs are *all* outputs of the predecessor level (plus, for level 0,
all memory-interface inputs); the select line of that mux is exactly the
configuration word the router produces here (bit-width per Eq. (3)).  A
channel input may fan out to several outputs; in-level connections are
impossible by construction (levelized placement).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

from repro.core.grid import GridSpec
from repro.core.ops import Op
from repro.core.place import Placement, PlacementError, VKey


class RoutingError(ValueError):
    pass


@dataclasses.dataclass
class Routing:
    """Per-level mux selects. ``sel[l][slot, port]`` indexes the VC-above-
    level-l channel inputs; ``out_sel[k]`` indexes last-level PE outputs."""

    sel: List[np.ndarray]          # per level: int32 [pes_in_level, 2]
    out_sel: np.ndarray            # int32 [num_outputs]
    fanout: Dict[int, int]         # per level: max fan-out observed (stats)


def route(placement: Placement, grid: GridSpec) -> Routing:
    dfg = placement.dfg
    input_index = {name: i for i, name in enumerate(dfg.inputs)}

    def channel_source(v: VKey, level: int) -> int:
        """Index of value `v` among the channel inputs of the VC above
        `level`: memory inputs for level 0, predecessor PE outputs else."""
        if level == 0:
            if v[0] != "in":
                raise RoutingError(f"level-0 operand {v} is not a memory input")
            return input_index[v[1]]
        try:
            return placement.avail[(v, level - 1)]
        except KeyError:
            raise RoutingError(
                f"value {v} not available at level {level - 1} "
                f"(mapper must insert a BUF carrier)"
            ) from None

    sel: List[np.ndarray] = []
    fanout: Dict[int, int] = {}
    for lvl, cells in enumerate(placement.cells):
        width = grid.pes_per_level[lvl]
        table = np.zeros((width, 2), dtype=np.int32)  # NONE PEs: select 0
        counts: Dict[int, int] = {}
        for slot, c in enumerate(cells):
            if c.op == Op.NONE:
                continue
            sa = channel_source(c.a, lvl)
            sb = channel_source(c.b, lvl)
            table[slot, 0] = sa
            table[slot, 1] = sb
            counts[sa] = counts.get(sa, 0) + 1
            counts[sb] = counts.get(sb, 0) + 1
        # Validate select ranges against the physical mux width.
        if table.size and table.max(initial=0) >= grid.vc_in_width(lvl):
            raise RoutingError(f"select out of range at level {lvl}")
        sel.append(table)
        fanout[lvl] = max(counts.values(), default=0)

    last = grid.num_levels - 1
    out_sel = np.zeros((grid.num_outputs,), dtype=np.int32)
    for k, ref in enumerate(dfg.outputs):
        v: VKey = ("in", ref.name) if hasattr(ref, "name") else ("node", ref.idx)
        try:
            out_sel[k] = placement.avail[(v, last)]
        except KeyError:
            raise RoutingError(f"output {k} value {v} not at bottom level") from None
    return Routing(sel, out_sel, fanout)
