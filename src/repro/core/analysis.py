"""Resource analysis: the XLA-native analogue of the paper's Table I.

The paper reports LUT/TCON/wire-length/channel-width deltas between the
conventional and the parameterized implementation of each VCGRA component.
Those are FPGA place-and-route artefacts; the resources XLA has are HLO
ops, FLOPs and bytes.  We therefore compile both executor variants and
census the optimized HLO:

  routing ops   (gather/dynamic-slice/...)  <->  VC connection muxes / TCONs
  mux/select ops (select/clamp/compare-for-mux) <-> generic-PE output muxes
  arith ops     (add/mul/div/...)           <->  PE functional-unit LUTs
  flops/bytes   (cost_analysis)             <->  overall datapath cost

Reduction percentages between the two variants are the direct analogue of
the paper's 82 % (VC) / 24 % (FP PE) / 6 % (grid) resource cuts.
"""

from __future__ import annotations

import re
from typing import Callable, Dict, Iterable, Tuple

import jax

_OP_RE = re.compile(r"=\s*(?:\([^)]*\)|\S+)\s+([a-z][a-z0-9_\-]*)\(")

ROUTING_OPS = {
    "gather", "dynamic-slice", "dynamic-update-slice", "scatter",
    "concatenate", "slice", "pad", "reverse",
}
MUX_OPS = {"select", "clamp"}
ARITH_OPS = {
    "add", "subtract", "multiply", "divide", "compare", "maximum", "minimum",
    "abs", "negate", "sign", "floor", "power", "remainder", "and", "or",
    "xor", "not",
}
MOVE_OPS = {
    "copy", "transpose", "reshape", "broadcast", "bitcast", "convert",
    "iota", "tuple", "get-tuple-element",
}


def hlo_op_census(hlo_text: str) -> Dict[str, int]:
    """Count optimized-HLO ops by category (fusion bodies included: they
    appear as separate computations in the module text)."""
    counts: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        counts[m.group(1)] = counts.get(m.group(1), 0) + 1
    total = sum(counts.values())
    summary = {
        "total_ops": total,
        "routing_ops": sum(v for k, v in counts.items() if k in ROUTING_OPS),
        "mux_ops": sum(v for k, v in counts.items() if k in MUX_OPS),
        "arith_ops": sum(v for k, v in counts.items() if k in ARITH_OPS),
        "move_ops": sum(v for k, v in counts.items() if k in MOVE_OPS),
    }
    summary["other_ops"] = total - sum(
        summary[k] for k in ("routing_ops", "mux_ops", "arith_ops", "move_ops")
    )
    return summary


def compile_and_census(fn: Callable, *args) -> Dict[str, float]:
    """Lower+compile `fn(*args)` and return the resource census."""
    jitted = fn if isinstance(fn, jax.stages.Wrapped) else jax.jit(fn)
    lowered = jitted.lower(*args)
    compiled = lowered.compile()
    census = hlo_op_census(compiled.as_text())
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # older jax returns [dict]
        cost = cost[0] if cost else {}
    census["flops"] = float(cost.get("flops", 0.0))
    census["bytes"] = float(cost.get("bytes accessed", 0.0))
    return census


def reduction_row(
    name: str, conventional: Dict[str, float], parameterized: Dict[str, float]
) -> Dict[str, object]:
    """One Table-I row: conventional vs parameterized + reduction %."""
    row: Dict[str, object] = {"component": name}
    for key in ("total_ops", "routing_ops", "mux_ops", "arith_ops", "flops", "bytes"):
        c, p = float(conventional.get(key, 0)), float(parameterized.get(key, 0))
        row[f"{key}_conv"] = c
        row[f"{key}_param"] = p
        row[f"{key}_reduction_pct"] = (100.0 * (c - p) / c) if c else 0.0
    return row


def format_table(rows: Iterable[Dict[str, object]], keys=None) -> str:
    rows = list(rows)
    if not rows:
        return "(empty)"
    keys = keys or list(rows[0].keys())
    widths = {k: max(len(str(k)), *(len(_fmt(r.get(k))) for r in rows)) for k in keys}
    head = " | ".join(str(k).ljust(widths[k]) for k in keys)
    sep = "-+-".join("-" * widths[k] for k in keys)
    body = "\n".join(
        " | ".join(_fmt(r.get(k)).ljust(widths[k]) for k in keys) for r in rows
    )
    return f"{head}\n{sep}\n{body}"


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:,.1f}"
    return str(v)
