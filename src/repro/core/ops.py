"""Processing-Element opcodes and semantics for the Pixie VCGRA.

The paper's PE is a small FSM (AWAIT_DATA -> PROCESS_DATA -> VALID_DATA)
that applies one configured operation to its two (equal-bitwidth) inputs:
arithmetic (Add, Sub, Mul, Div), comparison (Gt, Eq), plus a BUF mode
(copy input to output, used to carry values across pipeline levels because
level bypassing is unsupported) and a NONE/idle mode (PE produces nothing).

On TPU the valid/start handshake discipline of the FSM is subsumed by data
dependence (JAX is a synchronous dataflow IR); what remains is the opcode
semantics, implemented here in two forms:

* ``apply_op``      -- *specialized* form: the opcode is a Python constant,
                       only that functional unit is emitted (the analogue of
                       the paper's parameterized configuration / constant
                       propagation through TLUTs).
* ``apply_generic`` -- *conventional* form: the opcode is a traced array,
                       every functional unit is computed and the result is
                       selected by a mux chain (the analogue of the generic
                       settings-register-driven PE).

Extension opcodes beyond the paper's set (MAX, MIN, ABS) follow the paper's
note that "the functionality of the processing elements is extendable"; the
MAC mode is modelled like the paper treats it: the PE semantics exist but
the mapper does not schedule it ("we do not support graph mapping for that
operation yet").
"""

from __future__ import annotations

import enum

import jax.numpy as jnp


class Op(enum.IntEnum):
    """PE opcodes. Values are the settings-register encoding."""

    NONE = 0   # idle: PE produces no output, does not raise valid
    ADD = 1
    SUB = 2
    MUL = 3
    DIV = 4
    GT = 5     # a > b  -> 1/0 in the data type
    EQ = 6     # a == b -> 1/0 in the data type
    BUF = 7    # copy: both inputs carry the same value (paper Sec III-A)
    MAX = 8    # extension op
    MIN = 9    # extension op
    ABS = 10   # extension op (unary; port b ignored)
    MAC = 11   # experimental, not schedulable by the mapper (paper Sec III-A)


#: Opcodes the place-and-route flow may schedule onto the grid.
SCHEDULABLE_OPS = frozenset(
    {Op.ADD, Op.SUB, Op.MUL, Op.DIV, Op.GT, Op.EQ, Op.BUF, Op.MAX, Op.MIN, Op.ABS}
)

#: Opcodes whose second input port is ignored.
UNARY_OPS = frozenset({Op.ABS, Op.BUF, Op.NONE})


def _safe_div(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Division with a guarded divisor (hardware would saturate; we define 0).

    Integer ("fixed point") grids use floor division, float grids true
    division; both return 0 where the divisor is 0 so that NONE/unused PE
    lanes can never poison the array with NaN/Inf in the conventional path.
    """
    if jnp.issubdtype(a.dtype, jnp.integer):
        denom = jnp.where(b == 0, jnp.ones_like(b), b)
        return jnp.where(b == 0, jnp.zeros_like(a), a // denom)
    denom = jnp.where(b == 0, jnp.ones_like(b), b)
    return jnp.where(b == 0, jnp.zeros_like(a), a / denom)


def apply_op(op: Op, a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Specialized PE: ``op`` is a Python constant; emit only its unit."""
    op = Op(op)
    if op == Op.ADD:
        return a + b
    if op == Op.SUB:
        return a - b
    if op == Op.MUL:
        return a * b
    if op == Op.DIV:
        return _safe_div(a, b)
    if op == Op.GT:
        return (a > b).astype(a.dtype)
    if op == Op.EQ:
        return (a == b).astype(a.dtype)
    if op == Op.BUF:
        return a
    if op == Op.MAX:
        return jnp.maximum(a, b)
    if op == Op.MIN:
        return jnp.minimum(a, b)
    if op == Op.ABS:
        return jnp.abs(a)
    if op == Op.NONE:
        return jnp.zeros_like(a)
    raise ValueError(f"opcode {op!r} has no combinational semantics")


def apply_generic(opcode: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Conventional PE: every functional unit computed, mux selects output.

    ``opcode`` has shape ``a.shape[:1]`` (one opcode per PE lane) or is a
    scalar; it broadcasts against ``a``/``b`` of shape ``[n_pes, batch]``.
    This deliberately mirrors the generic hardware PE: all units are live
    because the settings register is runtime data, exactly why the
    conventional implementation costs more resources (paper Table I).
    """
    if opcode.ndim == a.ndim - 1:
        opcode = opcode[..., None]
    # Plain-int comparisons: enum members would become captured scalar
    # constants inside pallas kernel bodies, which pallas_call rejects.
    out = jnp.zeros_like(a)
    out = jnp.where(opcode == int(Op.ADD), a + b, out)
    out = jnp.where(opcode == int(Op.SUB), a - b, out)
    out = jnp.where(opcode == int(Op.MUL), a * b, out)
    out = jnp.where(opcode == int(Op.DIV), _safe_div(a, b), out)
    out = jnp.where(opcode == int(Op.GT), (a > b).astype(a.dtype), out)
    out = jnp.where(opcode == int(Op.EQ), (a == b).astype(a.dtype), out)
    out = jnp.where(opcode == int(Op.BUF), a, out)
    out = jnp.where(opcode == int(Op.MAX), jnp.maximum(a, b), out)
    out = jnp.where(opcode == int(Op.MIN), jnp.minimum(a, b), out)
    out = jnp.where(opcode == int(Op.ABS), jnp.abs(a), out)
    return out


def op_name(op: int) -> str:
    return Op(op).name
