"""Shared padding/bucketing primitives for overlay dispatch tiling.

Every layer that shapes a dispatch -- the plan compiler
(``core/plan.py``), the fleet scheduler (``runtime/fleet.py``) and the
interpreter's pack helpers -- rounds to the same tiles from the same
module, so the compile-once contract ("one executable per padded tile
shape") has a single source of truth.  All padding here is *exact* by
construction: padded channels are never referenced by mux selects,
padded pixel columns are sliced off, and padded app slots replay an
already-valid config whose outputs are discarded.
"""

from __future__ import annotations

from typing import List, Sequence, Union

import jax
import jax.numpy as jnp

#: Default on-chip working-set budget for the pixel-tiled fused executors
#: (bytes).  Half of a TPU core's ~16 MiB VMEM is left for double-buffered
#: HBM->VMEM pipelining and the settings banks; the resident slab working
#: set (tap bank + memory-VC channels + widest PE level, all
#: ``[_, tile_rows + 2*radius, W]``-shaped) must fit in the rest.
DEFAULT_VMEM_BUDGET_BYTES = 8 * 1024 * 1024

#: Sentinel ``OverlayPlan.tile_rows`` value: resolve the row-tile height
#: from the VMEM budget heuristic at trace time (shapes are static under
#: jit, so the pick is a trace-time constant and compile-once still holds
#: per frame shape).
TILE_AUTO = "auto"


def check_tile_rows(tile_rows: Union[int, str, None]) -> Union[int, str, None]:
    """Validate (and canonicalize) a ``tile_rows`` axis value -- ``None``
    (untiled), :data:`TILE_AUTO`, or an int >= 1.  Shared by the plan and
    the fleet so a misconfigured service fails at construction, not on
    its first fused flush."""
    if tile_rows is None or tile_rows == TILE_AUTO:
        return tile_rows
    try:
        tr = int(tile_rows)
    except (TypeError, ValueError):
        raise ValueError(
            f"tile_rows must be None, {TILE_AUTO!r} or an int >= 1, "
            f"got {tile_rows!r}"
        ) from None
    if tr < 1:
        raise ValueError(f"tile_rows must be >= 1 or {TILE_AUTO!r}, got {tr}")
    return tr


def slab_rows_per_budget(
    W: int,
    radius: int,
    *,
    num_inputs: int,
    max_level_width: int,
    itemsize: int,
    budget_bytes: int = DEFAULT_VMEM_BUDGET_BYTES,
) -> int:
    """How many *output* rows of a fused row-tile fit the VMEM budget.

    The fused megakernel's resident working set per kernel instance is
    the tap bank (``(2r+1)^2 + 1`` producer rows), the memory-VC channel
    matrix (``num_inputs`` rows) and the widest PE level
    (``max_level_width`` rows), each ``tile_rows * W`` elements, plus the
    ``(tile_rows + 2*radius) * W`` input slab itself.  Solving
    ``bytes_per_output_row * tile_rows + halo_bytes <= budget`` for
    ``tile_rows`` (the constant ``2*radius*W`` slab halo comes off the
    budget up front, so the pick never exceeds it) gives the heuristic.
    """
    taps = (2 * radius + 1) ** 2 + 1
    width = max(W, 1)
    per_row = (taps + num_inputs + max_level_width + 1) * width * itemsize
    budget = int(budget_bytes) - 2 * radius * width * itemsize
    return max(1, budget // per_row)


def resolve_tile_rows(
    tile_rows: Union[int, str, None],
    H: int,
    W: int,
    radius: int,
    grid,
    budget_bytes: int = DEFAULT_VMEM_BUDGET_BYTES,
) -> int:
    """Resolve a plan's ``tile_rows`` axis against one frame shape.

    ``None`` means untiled (one slab = the whole frame); :data:`TILE_AUTO`
    asks the VMEM budget heuristic (:func:`slab_rows_per_budget`); an int
    is taken verbatim.  The result is always clamped to ``[1, H]`` --
    ``tile_rows >= H`` degenerates to the untiled single-slab layout, so
    small frames pay no tiling machinery under the auto default.
    """
    if tile_rows is None:
        return max(int(H), 1)
    if tile_rows == TILE_AUTO:
        picked = slab_rows_per_budget(
            W, radius,
            num_inputs=grid.num_inputs,
            max_level_width=max(grid.pes_per_level),
            itemsize=jnp.dtype(grid.dtype).itemsize,
            budget_bytes=budget_bytes,
        )
        return max(1, min(picked, int(H)))
    return max(1, min(int(tile_rows), int(H)))


def num_row_tiles(H: int, tile_rows: int) -> int:
    """Row-tile count for one frame: ``ceil(H / tile_rows)``."""
    return -(-int(H) // int(tile_rows))


def halo_row_slabs(images: jnp.ndarray, tile_rows: int, radius: int) -> jnp.ndarray:
    """Overlapping row slabs for the tiled fused executors:
    ``[N, H, W] -> [N, T, tile_rows + 2*radius, W]``.

    The ONE definition of the halo math, shared by the XLA tiled twin and
    the Pallas megakernel so their slabs cannot drift apart (the bitwise
    parity contract between the two backends rides on it).  Rows are
    zero-padded by ``radius`` top and bottom plus the ragged-tile
    remainder; each slab is a ``lax.dynamic_slice`` window whose first and
    last ``radius`` rows are the halo -- real neighbour rows mid-frame,
    zeros at the frame border, exactly ``form_tap_bank``'s border.  The
    untiled case (T == 1) is the padded frame itself: no overlapping-slab
    materialization on the small-frame path.
    """
    n, H, W = images.shape
    r = int(radius)
    tr = int(tile_rows)
    T = num_row_tiles(H, tr)
    padded = jnp.pad(images, ((0, 0), (r, T * tr - H + r), (0, 0)))
    if T == 1:
        return padded[:, None]
    return jnp.stack(
        [
            jax.lax.dynamic_slice_in_dim(padded, t * tr, tr + 2 * r, axis=1)
            for t in range(T)
        ],
        axis=1,
    )


def round_up(n: int, tile: int) -> int:
    """Smallest multiple of ``tile`` that is >= ``n``."""
    return ((n + tile - 1) // tile) * tile


def pow2_bucket(n: int, floor: int) -> int:
    """Smallest power-of-two multiple of ``floor`` that is >= ``n``
    (``floor`` itself for small ``n``) -- the fleet's pixel/canvas bucket
    rule, bounding distinct compiled shapes to O(log max_size)."""
    b = max(floor, 1)
    while b < n:
        b *= 2
    return b


def pad_channels(x: jnp.ndarray, num_inputs: int) -> jnp.ndarray:
    """Zero-pad the channel axis of ``x: [k, batch]`` up to the grid's
    memory-VC width.  Applications rarely use every memory channel; mux
    selects never reference the padded rows, so batching apps with
    different input counts on one grid stays exact."""
    k = x.shape[0]
    if k > num_inputs:
        raise ValueError(f"app uses {k} input channels, grid has {num_inputs}")
    if k == num_inputs:
        return x
    return jnp.concatenate(
        [x, jnp.zeros((num_inputs - k,) + x.shape[1:], x.dtype)], axis=0
    )


def pad_batches(xs: Sequence[jnp.ndarray], pad_to: int) -> List[jnp.ndarray]:
    """Zero-pad every ``[channels, batch]`` input to ``pad_to`` columns."""
    return [
        jnp.pad(x, ((0, 0), (0, pad_to - x.shape[-1]))) if x.shape[-1] < pad_to else x
        for x in xs
    ]
