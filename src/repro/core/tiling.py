"""Shared padding/bucketing primitives for overlay dispatch tiling.

Every layer that shapes a dispatch -- the plan compiler
(``core/plan.py``), the fleet scheduler (``runtime/fleet.py``) and the
interpreter's pack helpers -- rounds to the same tiles from the same
module, so the compile-once contract ("one executable per padded tile
shape") has a single source of truth.  All padding here is *exact* by
construction: padded channels are never referenced by mux selects,
padded pixel columns are sliced off, and padded app slots replay an
already-valid config whose outputs are discarded.
"""

from __future__ import annotations

from typing import List, Sequence

import jax.numpy as jnp


def round_up(n: int, tile: int) -> int:
    """Smallest multiple of ``tile`` that is >= ``n``."""
    return ((n + tile - 1) // tile) * tile


def pow2_bucket(n: int, floor: int) -> int:
    """Smallest power-of-two multiple of ``floor`` that is >= ``n``
    (``floor`` itself for small ``n``) -- the fleet's pixel/canvas bucket
    rule, bounding distinct compiled shapes to O(log max_size)."""
    b = max(floor, 1)
    while b < n:
        b *= 2
    return b


def pad_channels(x: jnp.ndarray, num_inputs: int) -> jnp.ndarray:
    """Zero-pad the channel axis of ``x: [k, batch]`` up to the grid's
    memory-VC width.  Applications rarely use every memory channel; mux
    selects never reference the padded rows, so batching apps with
    different input counts on one grid stays exact."""
    k = x.shape[0]
    if k > num_inputs:
        raise ValueError(f"app uses {k} input channels, grid has {num_inputs}")
    if k == num_inputs:
        return x
    return jnp.concatenate(
        [x, jnp.zeros((num_inputs - k,) + x.shape[1:], x.dtype)], axis=0
    )


def pad_batches(xs: Sequence[jnp.ndarray], pad_to: int) -> List[jnp.ndarray]:
    """Zero-pad every ``[channels, batch]`` input to ``pad_to`` columns."""
    return [
        jnp.pad(x, ((0, 0), (0, pad_to - x.shape[-1]))) if x.shape[-1] < pad_to else x
        for x in xs
    ]
