"""Shared padding/bucketing primitives for overlay dispatch tiling.

Every layer that shapes a dispatch -- the plan compiler
(``core/plan.py``), the fleet scheduler (``runtime/fleet.py``) and the
interpreter's pack helpers -- rounds to the same tiles from the same
module, so the compile-once contract ("one executable per padded tile
shape") has a single source of truth.  All padding here is *exact* by
construction: padded channels are never referenced by mux selects,
padded pixel columns are sliced off, and padded app slots replay an
already-valid config whose outputs are discarded.
"""

from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp

#: Default on-chip working-set budget for the pixel-tiled fused executors
#: (bytes).  Half of a TPU core's ~16 MiB VMEM; the resident working set
#: -- BOTH in-flight DMA slabs of the double buffer plus the ``(T+1)``-row
#: tap bank, the memory-VC channels and the widest PE level, all
#: ``[_, tile_rows(+2*radius), W]``-shaped -- must fit in it (the other
#: half is headroom for the settings banks and compiler temporaries).
DEFAULT_VMEM_BUDGET_BYTES = 8 * 1024 * 1024

#: Lane width of the TPU vector unit: the compiled megakernel needs its
#: flattened pixel block (``tile_rows * W``) to be a multiple of this.
#: Re-exported by ``kernels/vcgra/vcgra_kernel.py``; defined here so the
#: tile-height resolver (:func:`resolve_tile_rows`) and the kernel agree
#: on one constant.
LANE = 128

#: Sentinel ``OverlayPlan.tile_rows`` value: resolve the row-tile height
#: from the VMEM budget heuristic at trace time (shapes are static under
#: jit, so the pick is a trace-time constant and compile-once still holds
#: per frame shape).
TILE_AUTO = "auto"


def check_tile_rows(tile_rows: Union[int, str, None]) -> Union[int, str, None]:
    """Validate (and canonicalize) a ``tile_rows`` axis value -- ``None``
    (untiled), :data:`TILE_AUTO`, or an int >= 1.  Shared by the plan and
    the fleet so a misconfigured service fails at construction, not on
    its first fused flush."""
    if tile_rows is None or tile_rows == TILE_AUTO:
        return tile_rows
    try:
        tr = int(tile_rows)
    except (TypeError, ValueError):
        raise ValueError(
            f"tile_rows must be None, {TILE_AUTO!r} or an int >= 1, "
            f"got {tile_rows!r}"
        ) from None
    if tr < 1:
        raise ValueError(f"tile_rows must be >= 1 or {TILE_AUTO!r}, got {tr}")
    return tr


def slab_rows_per_budget(
    W: int,
    radius: int,
    *,
    num_inputs: int,
    max_level_width: int,
    itemsize: int,
    budget_bytes: int = DEFAULT_VMEM_BUDGET_BYTES,
) -> int:
    """How many *output* rows of a fused row-tile fit the VMEM budget.

    The fused megakernel's resident working set per kernel instance is
    the tap bank (``(2r+1)^2 + 1`` producer rows), the memory-VC channel
    matrix (``num_inputs`` rows) and the widest PE level
    (``max_level_width`` rows), each ``tile_rows * W`` elements, plus
    BOTH ``(tile_rows + 2*radius) * W`` slabs of the in-kernel DMA double
    buffer (tile t computes out of one while tile t+1 streams HBM->VMEM
    into the other).  Solving ``bytes_per_output_row * tile_rows +
    halo_bytes <= budget`` for ``tile_rows`` (the constant ``2 * 2*radius
    * W`` double-buffer halo comes off the budget up front, so the pick
    never exceeds it) gives the heuristic.
    """
    taps = (2 * radius + 1) ** 2 + 1
    width = max(W, 1)
    per_row = (taps + num_inputs + max_level_width + 2) * width * itemsize
    budget = int(budget_bytes) - 2 * (2 * radius) * width * itemsize
    return max(1, budget // per_row)


def lane_aligned_tile_rows(tile_rows: int, W: int, lane: int = LANE) -> int:
    """Round a tile height DOWN to the largest multiple of
    ``lane / gcd(W, lane)`` that is <= ``tile_rows`` (and at least that
    granule), which guarantees ``(tile_rows * W) % lane == 0`` -- the
    pixel-block layout constraint of the compiled megakernel -- while
    only ever shrinking the working set.  THE one definition of the
    rounding, shared by the AUTO-tile heuristic (:func:`resolve_tile_rows`
    with ``lane_align=``) and any caller that wants to pre-check an
    explicit tile height."""
    g = lane // math.gcd(max(int(W), 1), lane)
    tr = int(tile_rows)
    return max(g, tr - tr % g)


def resolve_tile_rows(
    tile_rows: Union[int, str, None],
    H: int,
    W: int,
    radius: int,
    grid,
    budget_bytes: int = DEFAULT_VMEM_BUDGET_BYTES,
    lane_align: Optional[int] = None,
) -> int:
    """Resolve a plan's ``tile_rows`` axis against one frame shape.

    ``None`` means untiled (one slab = the whole frame); :data:`TILE_AUTO`
    asks the VMEM budget heuristic (:func:`slab_rows_per_budget`); an int
    is taken verbatim.  The result is clamped to ``[1, H]`` --
    ``tile_rows >= H`` degenerates to the untiled single-slab layout, so
    small frames pay no tiling machinery under the auto default.

    ``lane_align`` (the compiled megakernel passes its LANE width; the
    XLA twin and interpret mode pass None -- no layout constraint there)
    rounds an AUTO pick that actually tiles down to a lane-aligned tile
    height via :func:`lane_aligned_tile_rows`, so the heuristic, the XLA
    tiled twin and the compiled DMA path all resolve through this ONE
    definition and the kernel's loud lane-align assert fires with the
    already-rounded value.  Explicit int tile heights are the caller's
    choice and are never silently rewritten.
    """
    if tile_rows is None:
        return max(int(H), 1)
    if tile_rows == TILE_AUTO:
        picked = slab_rows_per_budget(
            W, radius,
            num_inputs=grid.num_inputs,
            max_level_width=max(grid.pes_per_level),
            itemsize=jnp.dtype(grid.dtype).itemsize,
            budget_bytes=budget_bytes,
        )
        picked = max(1, min(picked, int(H)))
        if lane_align and picked < int(H):
            picked = lane_aligned_tile_rows(picked, W, lane_align)
        return picked
    return max(1, min(int(tile_rows), int(H)))


def num_row_tiles(H: int, tile_rows: int) -> int:
    """Row-tile count for one frame: ``ceil(H / tile_rows)``."""
    return -(-int(H) // int(tile_rows))


def halo_row_slabs(images: jnp.ndarray, tile_rows: int, radius: int) -> jnp.ndarray:
    """Overlapping row slabs for the tiled fused executors:
    ``[N, H, W] -> [N, T, tile_rows + 2*radius, W]``.

    The ONE definition of the halo math, shared by the XLA tiled twin and
    the Pallas megakernel so their slabs cannot drift apart (the bitwise
    parity contract between the two backends rides on it).  Rows are
    zero-padded by ``radius`` top and bottom plus the ragged-tile
    remainder; each slab is a ``lax.dynamic_slice`` window whose first and
    last ``radius`` rows are the halo -- real neighbour rows mid-frame,
    zeros at the frame border, exactly ``form_tap_bank``'s border.  The
    untiled case (T == 1) is the padded frame itself: no overlapping-slab
    materialization on the small-frame path.
    """
    n, H, W = images.shape
    r = int(radius)
    tr = int(tile_rows)
    T = num_row_tiles(H, tr)
    padded = jnp.pad(images, ((0, 0), (r, T * tr - H + r), (0, 0)))
    if T == 1:
        return padded[:, None]
    return jnp.stack(
        [
            jax.lax.dynamic_slice_in_dim(padded, t * tr, tr + 2 * r, axis=1)
            for t in range(T)
        ],
        axis=1,
    )


def hbm_read_model(
    H: int, W: int, radius: int, tile_rows: Union[int, None], itemsize: int,
    *, presliced: bool,
) -> Dict[str, float]:
    """Modelled per-frame HBM traffic of the two row-tiled fused
    lowerings, for the bench JSON's ``hbm_bytes_read`` column.

    ``presliced`` (the old Pallas lowering, still the XLA twin's layout):
    the host side of the call materializes overlapping halo slabs
    ``[T, tile_rows + 2r, W]`` in HBM -- the frame is read once to build
    them, the duplicated tensor is written, and the kernel then streams
    the whole duplicated tensor back in.  ``bytes_read`` is therefore
    ``frame + slabs = (2 + 2r*T/H) x`` the frame size, plus a
    ``(1 + 2r*T/H) x`` write that the un-duplicated path never pays.

    In-kernel DMA (``presliced=False``): the kernel DMAs overlapping
    windows straight out of the ONE zero-row-padded frame -- each frame
    row crosses HBM->VMEM once, halo rows are re-read only at the
    ``T - 1`` tile seams (``2r`` rows each), and nothing halo-shaped is
    ever written to HBM.  ``read_amplification`` is bytes_read over the
    raw frame size: ``~1x`` for real tile heights vs the pre-sliced
    path's ``>= 2x`` (the ``1 + 2r/tile_rows`` duplication, paid twice:
    once written, once read).
    """
    frame = int(H) * int(W) * int(itemsize)
    tr = max(int(H), 1) if tile_rows is None else min(int(tile_rows), int(H))
    T = num_row_tiles(H, tr)
    slab_bytes = T * (tr + 2 * int(radius)) * int(W) * int(itemsize)
    if presliced:
        read = frame + slab_bytes          # frame (to slice) + slab stream
        written = slab_bytes               # the duplicated halo tensor
    else:
        read = slab_bytes                  # seam halos only; no duplication
        written = 0
    return {
        "frame_bytes": frame,
        "tile_rows": tr,
        "n_tiles": T,
        "hbm_bytes_read": read,
        "hbm_halo_bytes_written": written,
        "read_amplification": read / frame if frame else 0.0,
    }


def row_band(H: int, rows: int, radius: int = 0) -> int:
    """Rows per shard band for 2-D ``(app, rows)`` mesh sharding:
    ``ceil(H / rows)``, floored at ``radius`` (and 1).

    The floor is what keeps the seam halo exchange single-hop: each row
    shard's stencil taps reach at most ``radius`` rows past its band, and
    :func:`repro.parallel.axes.halo_exchange_rows` fetches exactly the
    neighbour's ``radius`` edge rows -- legal only while every band holds
    at least ``radius`` rows, so a shard never needs pixels from two
    shards away.  Frames are padded to ``row_band(...) * rows`` total
    rows (``plan._with_mesh_padding``); the zero pad rows are read only
    as bottom-border zeros and their outputs sliced off, so the padding
    is exact in the same sense as :func:`halo_row_slabs`'s.
    """
    return max(-(-int(H) // int(rows)), int(radius), 1)


def round_up(n: int, tile: int) -> int:
    """Smallest multiple of ``tile`` that is >= ``n``."""
    return ((n + tile - 1) // tile) * tile


def pow2_bucket(n: int, floor: int) -> int:
    """Smallest power-of-two multiple of ``floor`` that is >= ``n``
    (``floor`` itself for small ``n``) -- the fleet's pixel/canvas bucket
    rule, bounding distinct compiled shapes to O(log max_size)."""
    b = max(floor, 1)
    while b < n:
        b *= 2
    return b


def pad_channels(x: jnp.ndarray, num_inputs: int) -> jnp.ndarray:
    """Zero-pad the channel axis of ``x: [k, batch]`` up to the grid's
    memory-VC width.  Applications rarely use every memory channel; mux
    selects never reference the padded rows, so batching apps with
    different input counts on one grid stays exact."""
    k = x.shape[0]
    if k > num_inputs:
        raise ValueError(f"app uses {k} input channels, grid has {num_inputs}")
    if k == num_inputs:
        return x
    return jnp.concatenate(
        [x, jnp.zeros((num_inputs - k,) + x.shape[1:], x.dtype)], axis=0
    )


def pad_batches(xs: Sequence[jnp.ndarray], pad_to: int) -> List[jnp.ndarray]:
    """Zero-pad every ``[channels, batch]`` input to ``pad_to`` columns."""
    return [
        jnp.pad(x, ((0, 0), (0, pad_to - x.shape[-1]))) if x.shape[-1] < pad_to else x
        for x in xs
    ]
