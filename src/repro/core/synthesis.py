"""Synthesis front-end: textual application description -> PE netlist.

Paper Sec. II: "the textual description of the application design is
parsed and converted into a netlist of Processing Elements (PEs)".

We accept a tiny expression language (one assignment per line, C-like
operators) and emit a :class:`repro.core.dfg.DFG`:

    # comments allowed
    gx  = (p22 - p20) + 2*(p12 - p10) + (p02 - p00)
    gy  = (p22 - p02) + 2*(p21 - p01) + (p20 - p00)
    out = abs(gx) + abs(gy)

* identifiers that are never assigned become external inputs;
* numeric literals become coefficient (const) inputs;
* ``out``-prefixed targets (or the last assignment) become outputs;
* supported: ``+ - * / > ==``, ``abs(x) max(a,b) min(a,b) buf(x)``.

This is the programming-model claim of the paper: the user writes at the
abstraction level of the dataflow, not of the fabric.
"""

from __future__ import annotations

import ast
from typing import Dict, List

from repro.core.dfg import DFG, Ref

_FUNCS = {"abs": "absolute", "max": "maximum", "min": "minimum", "buf": "buf"}


class SynthesisError(ValueError):
    pass


def synthesize(name: str, source: str) -> DFG:
    """Parse `source` and return the equivalent DFG netlist."""
    g = DFG(name)
    env: Dict[str, Ref] = {}
    n_const = 0

    def const_ref(value: float) -> Ref:
        nonlocal n_const
        cname = f"c{n_const}"
        n_const += 1
        return g.const(cname, value)

    def input_ref(ident: str) -> Ref:
        if ident not in env:
            env[ident] = g.input(ident)
        return env[ident]

    def emit(node: ast.expr) -> Ref:
        if isinstance(node, ast.Name):
            return env[node.id] if node.id in env else input_ref(node.id)
        if isinstance(node, ast.Constant):
            if not isinstance(node.value, (int, float)):
                raise SynthesisError(f"bad literal {node.value!r}")
            return const_ref(float(node.value))
        if isinstance(node, ast.UnaryOp):
            if isinstance(node.op, ast.USub):
                return g.sub(const_ref(0.0), emit(node.operand))
            raise SynthesisError(f"unsupported unary op {ast.dump(node.op)}")
        if isinstance(node, ast.BinOp):
            a, b = emit(node.left), emit(node.right)
            if isinstance(node.op, ast.Add):
                return g.add(a, b)
            if isinstance(node.op, ast.Sub):
                return g.sub(a, b)
            if isinstance(node.op, ast.Mult):
                return g.mul(a, b)
            if isinstance(node.op, ast.Div):
                return g.div(a, b)
            raise SynthesisError(f"unsupported operator {ast.dump(node.op)}")
        if isinstance(node, ast.Compare):
            if len(node.ops) != 1:
                raise SynthesisError("chained comparisons unsupported")
            a, b = emit(node.left), emit(node.comparators[0])
            if isinstance(node.ops[0], ast.Gt):
                return g.gt(a, b)
            if isinstance(node.ops[0], ast.Eq):
                return g.eq(a, b)
            raise SynthesisError(f"unsupported comparison {ast.dump(node.ops[0])}")
        if isinstance(node, ast.Call):
            if not isinstance(node.func, ast.Name) or node.func.id not in _FUNCS:
                raise SynthesisError(f"unknown function {ast.dump(node.func)}")
            meth = getattr(g, _FUNCS[node.func.id])
            args = [emit(a) for a in node.args]
            return meth(*args)
        raise SynthesisError(f"unsupported syntax {ast.dump(node)}")

    try:
        tree = ast.parse(source, mode="exec")
    except SyntaxError as e:
        raise SynthesisError(str(e)) from e

    targets: List[str] = []
    for stmt in tree.body:
        if not isinstance(stmt, ast.Assign) or len(stmt.targets) != 1:
            raise SynthesisError("only single-target assignments allowed")
        tgt = stmt.targets[0]
        if not isinstance(tgt, ast.Name):
            raise SynthesisError("assignment target must be a name")
        env[tgt.id] = emit(stmt.value)
        targets.append(tgt.id)

    outs = [t for t in targets if t.startswith("out")]
    if not outs and targets:
        outs = [targets[-1]]
    for t in outs:
        g.output(env[t])
    return g


SOBEL_SOURCE = """
gx  = (p22 - p20) + 2*(p12 - p10) + (p02 - p00)
gy  = (p22 - p02) + 2*(p21 - p01) + (p20 - p00)
out = abs(gx) + abs(gy)
"""
