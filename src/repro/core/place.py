"""Mapper/placer: DFG netlist -> PE slots on the grid.

Implements the paper's mapping rules (Sec. III/IV):

* data flows strictly top-to-bottom; every PE level is one pipeline stage;
* **level bypassing is not supported** -- a value produced at level ``p``
  and consumed at level ``c > p + 1`` is carried by PEs configured as BUF
  in every intermediate level ("The weighted pixel value ... is buffered in
  every stage of the array until it is used in the last addition");
* external inputs enter only through the top memory-interface VC, so an
  input consumed below level 0 is buffered down from level 0;
* outputs leave only through the bottom VC, so "for bigger arrays with more
  stages than necessary, an output value has to be buffered in every stage
  until it reaches the data output channel at the bottom";
* unused PEs are configured NONE.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

from repro.core.dfg import DFG, InRef, NodeRef, Ref
from repro.core.grid import GridSpec
from repro.core.ops import Op, UNARY_OPS

# A value key: ("in", input_name) or ("node", node_idx).
VKey = Tuple[str, object]


class PlacementError(ValueError):
    pass


def _key(r: Ref) -> VKey:
    if isinstance(r, InRef):
        return ("in", r.name)
    return ("node", r.idx)


@dataclasses.dataclass
class Cell:
    """One occupied PE slot before routing: opcode + symbolic operands."""

    op: Op
    a: VKey
    b: VKey
    produces: VKey
    is_buf_fill: bool = False  # True for mapper-inserted BUF carriers


@dataclasses.dataclass
class Placement:
    dfg: DFG
    grid: GridSpec
    cells: List[List[Cell]]                  # per level, in slot order
    avail: Dict[Tuple[VKey, int], int]       # (value, level) -> slot
    num_buf: int
    num_none: int

    @property
    def used_pes(self) -> int:
        return sum(len(c) for c in self.cells)

    def stats(self) -> Dict[str, int]:
        return {
            "levels": self.grid.num_levels,
            "grid_pes": self.grid.num_pes,
            "used_pes": self.used_pes,
            "op_pes": self.used_pes - self.num_buf,
            "buf_pes": self.num_buf,
            "none_pes": self.num_none,
        }


def expand(dfg: DFG, num_levels: int) -> List[List[Cell]]:
    """Expand a DFG into per-level cells with BUF carriers inserted.

    Deterministic: original nodes first (by node index), then BUF carriers
    (by value key).  Raises PlacementError if the graph is deeper than the
    grid.
    """
    dfg.validate()
    levels = dfg.asap_levels()
    depth = dfg.depth()
    if num_levels < max(depth, 1):
        raise PlacementError(
            f"DFG {dfg.name!r} has depth {depth}, grid has only {num_levels} levels"
        )

    prod: Dict[VKey, int] = {("in", n): -1 for n in dfg.inputs}
    for i, lvl in enumerate(levels):
        prod[("node", i)] = lvl

    # Deepest level at which each value must exist as a *cell output*.
    maxneed: Dict[VKey, int] = {}

    def need(v: VKey, lvl: int) -> None:
        if lvl > prod[v]:
            maxneed[v] = max(maxneed.get(v, prod[v]), lvl)

    for i, n in enumerate(dfg.nodes):
        for r in (n.a, n.b):
            need(_key(r), levels[i] - 1)
    for r in dfg.outputs:
        need(_key(r), num_levels - 1)

    cells: List[List[Cell]] = [[] for _ in range(num_levels)]
    for i, n in enumerate(dfg.nodes):
        cells[levels[i]].append(Cell(n.op, _key(n.a), _key(n.b), ("node", i)))
    for v in sorted(maxneed, key=lambda k: (k[0], str(k[1]))):
        for lvl in range(prod[v] + 1, maxneed[v] + 1):
            # A BUF PE gets the same value on both ports (paper Sec. III-A).
            cells[lvl].append(Cell(Op.BUF, v, v, v, is_buf_fill=True))
    return cells


def level_demand(dfg: DFG) -> List[int]:
    """Per-level PE demand including BUF carriers, for the minimal-depth
    grid -- consumed by the grid-generator tool (`grid.for_dfg`)."""
    cells = expand(dfg, max(dfg.depth(), 1))
    return [len(c) for c in cells]


def place(dfg: DFG, grid: GridSpec) -> Placement:
    """Assign every cell a (level, slot) on `grid`; fail on overflow."""
    if len(dfg.inputs) > grid.num_inputs:
        raise PlacementError(
            f"DFG {dfg.name!r} needs {len(dfg.inputs)} memory inputs, "
            f"grid provides {grid.num_inputs}"
        )
    if len(dfg.outputs) > grid.num_outputs:
        raise PlacementError(
            f"DFG {dfg.name!r} needs {len(dfg.outputs)} outputs, "
            f"grid provides {grid.num_outputs}"
        )
    cells = expand(dfg, grid.num_levels)
    for lvl, cs in enumerate(cells):
        cap = grid.pes_per_level[lvl]
        if len(cs) > cap:
            raise PlacementError(
                f"level {lvl} needs {len(cs)} PEs but grid {grid.name!r} "
                f"provides {cap}; regenerate the grid with core.grid.for_dfg"
            )

    avail: Dict[Tuple[VKey, int], int] = {}
    num_buf = 0
    for lvl, cs in enumerate(cells):
        for slot, c in enumerate(cs):
            avail[(c.produces, lvl)] = slot
            if c.is_buf_fill:
                num_buf += 1
    num_none = grid.num_pes - sum(len(c) for c in cells)
    return Placement(dfg, grid, cells, avail, num_buf, num_none)
