"""Conventional VCGRA execution: the compile-once overlay interpreter.

This is the software analogue of the *conventional* VCGRA implementation:
a generic datapath whose settings registers (PE opcodes, VC mux selects)
are runtime data.  The interpreter is jitted **once per grid structure**;
afterwards any application mapped on that grid runs by swapping config
arrays -- no retrace, no recompile.  That reproduces the overlay's central
claim (paper Sec. V-E): implementing a new image-processing application
costs only mapping (<1 s) + reconfiguration, not a full hardware compile
(~1200 s).

Costs faithfully mirrored from the hardware:

* every PE computes *all* functional units and muxes the result
  (``ops.apply_generic``) -- like the settings-register-driven generic PE;
* every VC routing is a gather (``jnp.take``) over all predecessor outputs
  -- like the per-port connection multiplexers;

both of which the parameterized path (``specialize.py``) folds away.
"""

from __future__ import annotations

from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import ops as pe_ops
from repro.core.bitstream import VCGRAConfig
from repro.core.grid import GridSpec

ConfigArrays = Tuple[Tuple[jnp.ndarray, ...], Tuple[jnp.ndarray, ...], jnp.ndarray]


def pack_inputs(
    config: VCGRAConfig, inputs: Dict[str, jnp.ndarray], dtype
) -> jnp.ndarray:
    """Order named inputs into the memory-interface channel layout
    ``[num_inputs, batch]``; missing names fall back to const defaults."""
    cols = []
    batch_shape = None
    for name in config.input_order:
        if name in inputs:
            v = jnp.asarray(inputs[name], dtype=dtype)
            batch_shape = v.shape
            cols.append(v)
        elif name in config.const_values:
            cols.append(None)  # fill after batch shape known
        else:
            raise KeyError(f"missing input {name!r}")
    if batch_shape is None:
        batch_shape = ()
    cols = [
        jnp.full(batch_shape, config.const_values[name], dtype=dtype)
        if c is None
        else jnp.broadcast_to(c, batch_shape)
        for c, name in zip(cols, config.input_order)
    ]
    return jnp.stack(cols, axis=0)


def overlay_step(
    grid: GridSpec, config: ConfigArrays, x: jnp.ndarray
) -> jnp.ndarray:
    """One full pass of the batch through the PE-level pipeline.

    ``x``: [num_inputs, batch] channel values at the top memory VC.
    The loop over levels is a *Python* loop: the grid structure is static
    (it is the overlay), only the settings are traced arrays.
    """
    opcodes, selects, out_sel = config
    assert len(opcodes) == grid.num_levels
    for lvl in range(grid.num_levels):
        # VC above level `lvl`: one mux per PE input port.
        a = jnp.take(x, selects[lvl][:, 0], axis=0)
        b = jnp.take(x, selects[lvl][:, 1], axis=0)
        # Generic PE: all functional units + output mux.
        x = pe_ops.apply_generic(opcodes[lvl], a, b)
    # Bottom memory-interface VC.
    return jnp.take(x, out_sel, axis=0)


def make_overlay_fn(grid: GridSpec):
    """Build the jit-once overlay executor for a grid structure.

    Returns ``fn(config_arrays, x) -> y`` with
    ``x: [num_inputs, batch] -> y: [num_outputs, batch]``.
    Different applications = different `config_arrays` of identical shapes
    => a single XLA executable serves them all.
    """
    return jax.jit(partial(overlay_step, grid))


def run_app(
    grid: GridSpec,
    config: VCGRAConfig,
    inputs: Dict[str, jnp.ndarray],
    overlay_fn=None,
) -> Dict[int, jnp.ndarray]:
    """Convenience one-shot execution (packs inputs, runs, unpacks)."""
    dtype = grid.dtype
    fn = overlay_fn or make_overlay_fn(grid)
    x = pack_inputs(config, inputs, dtype)
    y = fn(config.to_jax(), x)
    return {k: y[k] for k in range(y.shape[0])}
