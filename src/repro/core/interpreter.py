"""Conventional VCGRA execution: the compile-once overlay interpreter.

This is the software analogue of the *conventional* VCGRA implementation:
a generic datapath whose settings registers (PE opcodes, VC mux selects)
are runtime data.  The interpreter is jitted **once per grid structure**;
afterwards any application mapped on that grid runs by swapping config
arrays -- no retrace, no recompile.  That reproduces the overlay's central
claim (paper Sec. V-E): implementing a new image-processing application
costs only mapping (<1 s) + reconfiguration, not a full hardware compile
(~1200 s).

Costs faithfully mirrored from the hardware:

* every PE computes *all* functional units and muxes the result
  (``ops.apply_generic``) -- like the settings-register-driven generic PE;
* every VC routing is a gather (``jnp.take``) over all predecessor outputs
  -- like the per-port connection multiplexers;

both of which the parameterized path (``specialize.py``) folds away.
"""

from __future__ import annotations

import warnings
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import ops as pe_ops
from repro.core.bitstream import VCGRAConfig
from repro.core.grid import GridSpec
from repro.core.ingest import IngestPlan, tap_offsets

# Padding/bucketing primitives live in core/tiling.py (one source of truth
# shared with the plan compiler and the fleet scheduler); re-exported here
# because this module is their historical home.
from repro.core.tiling import (  # noqa: F401
    halo_row_slabs,
    num_row_tiles,
    pad_batches,
    pad_channels,
    resolve_tile_rows,
)

ConfigArrays = Tuple[Tuple[jnp.ndarray, ...], Tuple[jnp.ndarray, ...], jnp.ndarray]
IngestArrays = Tuple[jnp.ndarray, jnp.ndarray]  # (tap_sel, const_vals)

#: Execution backends for the batched overlay executors.  "xla" is the
#: hand-lowered jnp interpreter (the bitwise oracle); "pallas" routes the
#: same stacked settings through the batched VCGRA megakernels
#: (``repro.kernels.vcgra``), interpreted off-TPU and compiled on TPU.
BACKENDS = ("xla", "pallas")


def check_backend(backend: str) -> str:
    """Validate (and return) a backend name; shared by every layer that
    takes the backend axis (interpreter, fleet, front-end)."""
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; expected one of {BACKENDS}")
    return backend


def pack_inputs(
    config: VCGRAConfig,
    inputs: Dict[str, jnp.ndarray],
    dtype,
    batch_shape: Optional[Tuple[int, ...]] = None,
) -> jnp.ndarray:
    """Order named inputs into the memory-interface channel layout
    ``[num_inputs, batch]``; missing names fall back to const defaults.

    When *every* channel is const-valued the batch shape cannot be
    inferred from the inputs -- pass ``batch_shape`` explicitly, otherwise
    this raises instead of silently producing a scalar ``()`` batch.
    """
    cols = []
    for name in config.input_order:
        if name in inputs:
            v = jnp.asarray(inputs[name], dtype=dtype)
            if batch_shape is None:
                batch_shape = v.shape
            cols.append(v)
        elif name in config.const_values:
            cols.append(None)  # fill after batch shape known
        else:
            raise KeyError(f"missing input {name!r}")
    if batch_shape is None:
        raise ValueError(
            f"every channel of {config.app_name!r} is const-valued, so the "
            "pixel batch shape cannot be inferred; pass batch_shape= "
            "explicitly (e.g. batch_shape=(n,))"
        )
    cols = [
        jnp.full(batch_shape, config.const_values[name], dtype=dtype)
        if c is None
        else jnp.broadcast_to(c, batch_shape)
        for c, name in zip(cols, config.input_order)
    ]
    return jnp.stack(cols, axis=0)


def overlay_step(
    grid: GridSpec, config: ConfigArrays, x: jnp.ndarray
) -> jnp.ndarray:
    """One full pass of the batch through the PE-level pipeline.

    ``x``: [num_inputs, batch] channel values at the top memory VC.
    The loop over levels is a *Python* loop: the grid structure is static
    (it is the overlay), only the settings are traced arrays.
    """
    opcodes, selects, out_sel = config
    assert len(opcodes) == grid.num_levels
    for lvl in range(grid.num_levels):
        # VC above level `lvl`: one mux per PE input port.
        a = jnp.take(x, selects[lvl][:, 0], axis=0)
        b = jnp.take(x, selects[lvl][:, 1], axis=0)
        # Generic PE: all functional units + output mux.
        x = pe_ops.apply_generic(opcodes[lvl], a, b)
    # Bottom memory-interface VC.
    return jnp.take(x, out_sel, axis=0)


def _deprecated_factory(name: str, plan) -> "object":
    """Shared body of the legacy ``make_*_overlay_fn`` shims: warn, then
    delegate to the unified plan pipeline.  The returned
    ``OverlayExecutable`` is callable with the exact legacy signature and
    bitwise-identical (it wraps the very same step function)."""
    from repro.core.plan import compile_plan

    warnings.warn(
        f"{name} is deprecated; build an OverlayPlan and call "
        "repro.core.plan.compile_plan(plan) instead (one entrypoint for "
        "the whole fusion x batching x backend x devices matrix)",
        DeprecationWarning,
        stacklevel=3,
    )
    return compile_plan(plan)


def make_overlay_fn(grid: GridSpec):
    """Deprecated: use ``compile_plan(OverlayPlan(grid=grid))``.

    Returns ``fn(config_arrays, x) -> y`` with
    ``x: [num_inputs, batch] -> y: [num_outputs, batch]``.
    Different applications = different `config_arrays` of identical shapes
    => a single XLA executable serves them all.
    """
    from repro.core.plan import OverlayPlan

    return _deprecated_factory("make_overlay_fn", OverlayPlan(grid=grid))


def batched_overlay_step(
    grid: GridSpec, configs: ConfigArrays, xs: jnp.ndarray
) -> jnp.ndarray:
    """N applications through one overlay in a single dispatch.

    ``configs``: stacked settings (``VCGRAConfig.stack``), leaves carrying a
    leading app axis N; ``xs``: [N, num_inputs, batch].  Semantically this
    is ``jax.vmap(overlay_step)`` over the app axis -- the software
    analogue of N tenant bitstreams resident in one physical overlay -- but
    the VC muxes are lowered by hand: per-app selects are offset into one
    flat [N*rows, batch] value bank so each level is a single plain gather
    (identical to the sequential path's ``jnp.take``), not a
    batched-indices gather, which XLA:CPU lowers an order of magnitude
    slower.
    """
    opcodes, selects, out_sel = configs
    assert len(opcodes) == grid.num_levels
    n = xs.shape[0]
    x = xs
    for lvl in range(grid.num_levels):
        rows = x.shape[1]
        flat = x.reshape((n * rows,) + x.shape[2:])
        offs = (jnp.arange(n, dtype=selects[lvl].dtype) * rows)[:, None]
        a = jnp.take(flat, (selects[lvl][:, :, 0] + offs).reshape(-1), axis=0)
        b = jnp.take(flat, (selects[lvl][:, :, 1] + offs).reshape(-1), axis=0)
        shape = (n, -1) + x.shape[2:]
        x = pe_ops.apply_generic(opcodes[lvl], a.reshape(shape), b.reshape(shape))
    rows = x.shape[1]
    flat = x.reshape((n * rows,) + x.shape[2:])
    offs = (jnp.arange(n, dtype=out_sel.dtype) * rows)[:, None]
    y = jnp.take(flat, (out_sel + offs).reshape(-1), axis=0)
    return y.reshape((n, -1) + x.shape[2:])


def make_batched_overlay_fn(grid: GridSpec, backend: str = "xla"):
    """Deprecated: use ``compile_plan(OverlayPlan(grid=grid, batched=True,
    backend=backend))``.

    Returns ``fn(stacked_configs, xs) -> ys`` with
    ``xs: [N, num_inputs, batch] -> ys: [N, num_outputs, batch]``.
    Like :func:`make_overlay_fn` the executable depends only on the grid
    structure and the (N, batch) shape -- any N applications mapped on the
    grid share it, so a fleet scheduler that pads to fixed (N, batch) tiles
    compiles exactly once per (grid, backend).
    """
    from repro.core.plan import OverlayPlan

    return _deprecated_factory(
        "make_batched_overlay_fn",
        OverlayPlan(grid=grid, batched=True, backend=backend),
    )


# -- fused device-side ingest (line buffers inside the dispatch) --------------


def form_tap_bank(images: jnp.ndarray, radius: int, dtype) -> jnp.ndarray:
    """Line-buffer formation: raw frames -> the stencil tap bank.

    ``images``: [N, H, W] -> bank [N, T+1, H*W] where row ``t`` holds tap
    ``tap_offsets(radius)[t]`` (zero-padded shift, exactly
    ``applications.stencil_inputs``) and the trailing row is zeros (the
    const/padding producer).  The offsets are trace-time constants, so each
    tap is a *static* slice of one padded buffer -- the whole bank lowers
    to cheap views, no batched-indices gather (see DESIGN.md).
    """
    imgs = jnp.asarray(images, dtype)
    n, H, W = imgs.shape
    r = radius
    padded = jnp.pad(imgs, ((0, 0), (r, r), (r, r)))
    rows = [
        padded[:, r + dj : r + dj + H, r + di : r + di + W].reshape(n, H * W)
        for dj, di in tap_offsets(radius)
    ]
    rows.append(jnp.zeros((n, H * W), dtype))
    return jnp.stack(rows, axis=1)


def form_tap_bank_slab(slabs: jnp.ndarray, radius: int, dtype) -> jnp.ndarray:
    """Line-buffer formation for one row tile: a row-haloed slab -> bank.

    ``slabs``: [N, tile_rows + 2*radius, W] where the first and last
    ``radius`` rows are the halo (real neighbour rows mid-frame, zeros at
    the frame border -- the caller slices them from the zero-row-padded
    frame).  Returns [N, T+1, tile_rows*W]: rows are only *column*-padded
    here because the row halo already travels with the slab; every bank row
    is bitwise the ``form_tap_bank`` row restricted to the tile's pixels.
    """
    s = jnp.asarray(slabs, dtype)
    n, S, W = s.shape
    r = radius
    tr = S - 2 * r
    padded = jnp.pad(s, ((0, 0), (0, 0), (r, r)))
    rows = [
        padded[:, r + dj : r + dj + tr, r + di : r + di + W].reshape(n, tr * W)
        for dj, di in tap_offsets(radius)
    ]
    rows.append(jnp.zeros((n, tr * W), dtype))
    return jnp.stack(rows, axis=1)


def apply_ingest(bank: jnp.ndarray, ingest: IngestArrays) -> jnp.ndarray:
    """Produce the memory-VC channels of ONE app from its tap bank.

    ``bank``: [T+1, pixels]; ``ingest``: (tap_sel [C], const_vals [C]).
    Channels selecting the zero row take their const value verbatim (0 for
    grid-padding channels), so the result needs no further ``pad_channels``.
    """
    tap_sel, const_vals = ingest
    zero_row = bank.shape[0] - 1
    gathered = jnp.take(bank, tap_sel, axis=0)
    return jnp.where((tap_sel == zero_row)[:, None], const_vals[:, None], gathered)


def fused_overlay_step(
    grid: GridSpec, radius: int, config: ConfigArrays,
    ingest: IngestArrays, image: jnp.ndarray,
) -> jnp.ndarray:
    """pack + dispatch fused: one raw [H, W] frame -> [num_outputs, H*W]
    inside a single executable.  The ingest arrays are runtime settings
    (like the VC mux selects), so any app mapped on the grid reuses the
    same compiled function."""
    bank = form_tap_bank(image[None], radius, grid.dtype)[0]
    x = apply_ingest(bank, ingest)
    return overlay_step(grid, config, x)


def make_fused_overlay_fn(grid: GridSpec, radius: int = 1):
    """Deprecated: use ``compile_plan(OverlayPlan(grid=grid, fused=True,
    radius=radius))``.

    Returns ``fn(config_arrays, ingest_arrays, image) -> y`` with
    ``image: [H, W] -> y: [num_outputs, H*W]``.  Like
    :func:`make_overlay_fn` the executable depends only on the grid
    structure (plus the stencil radius and frame shape): tap offsets are
    trace-time constants, tap *selection* is runtime data."""
    from repro.core.plan import OverlayPlan

    return _deprecated_factory(
        "make_fused_overlay_fn",
        OverlayPlan(grid=grid, fused=True, radius=radius),
    )


def batched_fused_overlay_step(
    grid: GridSpec, radius: int, configs: ConfigArrays,
    ingests: IngestArrays, images: jnp.ndarray,
) -> jnp.ndarray:
    """N apps on N raw frames in ONE dispatch, line buffers included.

    ``images``: [N, H, W]; ``ingests``: stacked plan arrays
    (``IngestPlan.stack``), tap_sel [N, C] / const_vals [N, C].  The
    per-app tap selection uses the same flat-bank offset trick as the VC
    muxes in :func:`batched_overlay_step`: one plain gather over a
    [N*(T+1), pixels] bank, never a batched-indices gather.
    """
    bank = form_tap_bank(images, radius, grid.dtype)
    return batched_overlay_step(grid, configs, select_channels_batched(bank, ingests))


def select_channels_batched(bank: jnp.ndarray, ingests: IngestArrays) -> jnp.ndarray:
    """Produce every app's memory-VC channels from a batched tap bank
    [N, T+1, pixels] -- the flat-bank offset gather shared by the untiled
    and row-tiled fused executors."""
    tap_sel, const_vals = ingests
    n, t1, pixels = bank.shape
    flat = bank.reshape(n * t1, pixels)
    offs = (jnp.arange(n, dtype=tap_sel.dtype) * t1)[:, None]
    gathered = jnp.take(flat, (tap_sel + offs).reshape(-1), axis=0)
    gathered = gathered.reshape(n, -1, pixels)
    return jnp.where((tap_sel == t1 - 1)[..., None], const_vals[..., None], gathered)


def tiled_batched_fused_overlay_step(
    grid: GridSpec, radius: int, tile_rows, configs: ConfigArrays,
    ingests: IngestArrays, images: jnp.ndarray,
) -> jnp.ndarray:
    """Row-tiled twin of :func:`batched_fused_overlay_step`: bitwise-equal
    outputs with the tap bank formed per ``[tile_rows + 2*radius, W]``
    slab -- the XLA *oracle* for the tiled Pallas megakernel.  Note that
    only the Pallas grid actually bounds residency (one slab in VMEM at a
    time); this twin trades peak memory for fusion (all slabs, the full
    bank and T-replicated settings live at once -- slightly *more* than
    untiled), which is the right trade for the oracle/CPU role it plays.

    ``tile_rows``: rows per tile, ``tiling.TILE_AUTO`` (VMEM budget
    heuristic) or an int; resolved against the static frame shape at trace
    time, so compile-once per (grid, radius, N, H, W) still holds.  The
    frame's row axis is zero-padded up to ``T * tile_rows`` -- the padding
    is read only as bottom halo (exactly ``form_tap_bank``'s zero border)
    and the padded output rows are sliced back off, so any ``tile_rows``,
    including ones that do not divide H, is exact.

    Lowering note: the T row tiles ride the *app* axis (every operand
    replicated/tiled to N*T leading rows) rather than a Python loop over
    tiles -- one pipeline pass over all slabs keeps XLA:CPU's fusion
    intact, where a per-tile loop fragments the program into T small op
    islands (~25% slower at smoke sizes).  The per-(app, tile) grid loop
    lives in the Pallas megakernel, where it is the whole point (VMEM
    residency); here the tile axis is just more embarrassing parallelism.
    """
    imgs = jnp.asarray(images, grid.dtype)
    n, H, W = imgs.shape
    r = radius
    tr = resolve_tile_rows(tile_rows, H, W, r, grid)
    if tr >= H:
        return batched_fused_overlay_step(grid, radius, configs, ingests, imgs)
    T = num_row_tiles(H, tr)
    slabs = halo_row_slabs(imgs, tr, r).reshape(n * T, tr + 2 * r, W)
    bank = form_tap_bank_slab(slabs, radius, grid.dtype)   # [N*T, taps+1, tr*W]
    rep = partial(jnp.repeat, repeats=T, axis=0)
    xs = select_channels_batched(bank, jax.tree_util.tree_map(rep, ingests))
    ys = batched_overlay_step(grid, jax.tree_util.tree_map(rep, configs), xs)
    # [N*T, K, tr*W] -> per-app tile concat along the pixel axis (row-major
    # flattening makes each tile's pixels contiguous), minus the pad rows.
    y = ys.reshape(n, T, -1, tr * W).swapaxes(1, 2).reshape(n, -1, T * tr * W)
    return y[:, :, : H * W]


def valid_pixel_mask(hw: jnp.ndarray, H: int, W: int) -> jnp.ndarray:
    """``[N, H, W]`` bool mask of each app's true frame region inside a
    padded canvas: ``hw`` is int32 ``[N, 2]`` of per-app ``(rows, cols)``.

    The pipeline executors zero everything outside it between stages: a
    stage's output on canvas padding is NOT zero (its taps read real frame
    pixels), but the next stage's border must read zeros -- exactly what
    the staged oracle sees when each intermediate is re-embedded into a
    fresh zero canvas.  Masking is what keeps the fused chain bitwise
    equal to the per-stage dispatch sequence on bucketed canvases.
    """
    hw = jnp.asarray(hw, jnp.int32)
    rows_in = jnp.arange(H, dtype=jnp.int32)[None, :, None] < hw[:, 0][:, None, None]
    cols_in = jnp.arange(W, dtype=jnp.int32)[None, None, :] < hw[:, 1][:, None, None]
    return jnp.logical_and(rows_in, cols_in)


def forward_stage_output(ys: jnp.ndarray, out_ch: jnp.ndarray,
                         valid: jnp.ndarray) -> jnp.ndarray:
    """Select each app's forwarded output channel from a stage's
    ``[N, K, H*W]`` result and zero it outside the app's true frame
    region: the inter-stage hop of the operand-settings pipeline chain.
    ``out_ch`` is int32 ``[N]`` (runtime data, like every other setting);
    ``valid`` is :func:`valid_pixel_mask`'s ``[N, H, W]``."""
    n, H, W = valid.shape
    y = jnp.take_along_axis(
        ys, out_ch.astype(jnp.int32)[:, None, None], axis=1
    )[:, 0]
    return jnp.where(valid, y.reshape(n, H, W), 0)


def pipeline_batched_fused_step(
    grid: GridSpec, radii, stage_fn, stage_settings, hw, images,
) -> jnp.ndarray:
    """Operand-settings pipeline chain: N per-app stage chains on N raw
    frames in ONE dispatch, every intermediate staying a device-resident
    ``[N, H, W]`` frame.

    ``radii`` are the trace-time per-stage tap radii (executable shape);
    ``stage_settings`` is runtime data -- one ``(stacked_configs,
    stacked_ingests, out_ch)`` triple per stage, leaves carrying the
    leading app axis N -- so this variant shard_maps over an app/rows mesh
    (SPMD traces once; per-shard constants are impossible there).  The
    single-device XLA path instead bakes the chain at trace time
    (``plan._pipeline_specialized_fn``); both are bitwise equal to the
    staged per-stage oracle.  ``stage_fn(radius, configs, ingests, x)``
    runs one stage (the plan supplies the backend's batched fused step,
    tiled or not); the last stage returns its full ``[N, K, H*W]`` output
    -- its ``out_ch`` entry is forwarding metadata with nothing to feed.
    """
    x = jnp.asarray(images, grid.dtype)
    n, H, W = x.shape
    valid = valid_pixel_mask(hw, H, W)
    ys = None
    for si, r in enumerate(radii):
        configs, ingests, out_ch = stage_settings[si]
        ys = stage_fn(r, configs, ingests, x)
        if si < len(radii) - 1:
            x = forward_stage_output(ys, out_ch, valid)
    return ys


def make_batched_fused_overlay_fn(grid: GridSpec, radius: int = 1,
                                  backend: str = "xla"):
    """Deprecated: use ``compile_plan(OverlayPlan(grid=grid, batched=True,
    fused=True, radius=radius, backend=backend))``.

    Returns ``fn(stacked_configs, stacked_ingests, images) -> ys`` with
    ``images: [N, H, W] -> ys: [N, num_outputs, H*W]``.  One executable
    per (grid, radius, backend, N, H, W); a fleet that pads N and the
    frame canvas to fixed tiles compiles exactly once per grid."""
    from repro.core.plan import OverlayPlan

    return _deprecated_factory(
        "make_batched_fused_overlay_fn",
        OverlayPlan(grid=grid, batched=True, fused=True, radius=radius,
                    backend=backend),
    )


def run_app_fused(
    grid: GridSpec,
    config: VCGRAConfig,
    image: jnp.ndarray,
    fused_fn=None,
) -> jnp.ndarray:
    """Convenience one-shot fused execution: raw frame in, [num_outputs,
    H*W] out.  Requires ``config.ingest`` (set by ``assemble`` whenever the
    app is image-feedable)."""
    if config.ingest is None:
        raise ValueError(
            f"app {config.app_name!r} has no ingest plan (a channel is "
            "neither a stencil tap nor a const); use the named-channel path"
        )
    if fused_fn is None:
        from repro.core.plan import OverlayPlan, compile_plan

        fused_fn = compile_plan(
            OverlayPlan(grid=grid, fused=True, radius=config.ingest.radius)
        )
    return fused_fn(
        config.to_jax(), config.ingest.to_jax(grid.dtype), jnp.asarray(image)
    )


def stack_for_dispatch(configs, xs, batch_pad=None):
    """Pad-and-stack step of a multi-tenant dispatch (`Pixie.run_many`):
    zero-pad ragged pixel batches to one length, stack configs and inputs
    along the app axis.  `runtime.fleet.PixieFleet.flush` shares the same
    primitives (`pad_batches` + `VCGRAConfig.stack`) but routes the config
    stack through its cross-flush bank cache instead of calling this.

    Returns ``(stacked_configs, xstack, batches)`` where ``batches`` are
    the original per-app batch lengths for slicing the outputs back.
    """
    batches = [x.shape[-1] for x in xs]
    pad_to = batch_pad if batch_pad is not None else max(batches)
    if pad_to < max(batches):
        raise ValueError(f"batch_pad={pad_to} < largest request {max(batches)}")
    return VCGRAConfig.stack(configs), jnp.stack(pad_batches(xs, pad_to)), batches


def run_app(
    grid: GridSpec,
    config: VCGRAConfig,
    inputs: Dict[str, jnp.ndarray],
    overlay_fn=None,
) -> Dict[int, jnp.ndarray]:
    """Convenience one-shot execution (packs inputs, runs, unpacks)."""
    dtype = grid.dtype
    if overlay_fn is None:
        from repro.core.plan import OverlayPlan, compile_plan

        overlay_fn = compile_plan(OverlayPlan(grid=grid))
    fn = overlay_fn
    x = pack_inputs(config, inputs, dtype)
    y = fn(config.to_jax(), x)
    return {k: y[k] for k in range(y.shape[0])}
