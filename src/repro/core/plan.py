"""OverlayPlan: the unified compile/dispatch pipeline for the overlay.

The paper's value proposition is ONE virtual overlay that many
applications reconfigure cheaply at runtime; the runtime realizes it, but
the "compile an overlay" surface had grown into a 2x2x2 matrix of factory
functions (``make_*_overlay_fn`` x ``backend``) that every layer
re-plumbed by hand.  This module collapses that matrix into a single
plan -> compile -> execute pipeline:

  OverlayPlan      a frozen, hashable description of one dispatch: grid
                   structure, fused-vs-channel ingest (+ tap radius),
                   single-vs-batched app axis, execution backend, device
                   placement.  It is THE cache key: the fleet's overlay
                   LRU, benchmark JSON and stats all name executables by
                   their plan.
  compile_plan     the one entrypoint: plan -> OverlayExecutable.  Looks
                   the executor builder up in a registry (XLA builders
                   registered here; the Pallas megakernels register
                   themselves from ``kernels/vcgra/ops.py``), wraps it
                   with app-axis mesh sharding when the plan asks for
                   devices > 1, and jits once.
  OverlayExecutable  the compiled artifact: callable with the plan-shaped
                   operands, carries its plan and (when sharded) mesh.

Device placement is a structured :class:`repro.parallel.axes.MeshSpec`:
``MeshSpec(app=k)`` shards the app (N) axis of a *batched* plan across k
local devices via shard_map (``parallel/axes.build_mesh`` /
``shard_apps``) -- the app axis is embarrassingly parallel (each tenant's
flat-gather offsets are local to its own rows), so the sharded result is
bitwise identical to the single-device run.  ``MeshSpec(app=k, rows=m)``
additionally shards fused frames *spatially* over a 2-D ``(app, rows)``
mesh: each row shard owns a contiguous band of pixel rows and exchanges
the radius-wide seam halo with its neighbours
(``parallel/axes.shard_apps_rows``), then runs the unchanged per-shard
executor -- still bitwise identical, because a haloed band reads exactly
like a short frame whose border pixels are real neighbour rows.  When
the host has fewer devices than the spec asks for, compilation falls
back to the single-device executable (same bits, same plan key).  N not
divisible by the app width -- and H not divisible into radius-deep row
bands -- is padded inside the executable and sliced back off.  The
deprecated bare device-count kwarg survives as a DeprecationWarning shim
meaning ``MeshSpec(app=k)``.

The legacy ``interpreter.make_*_overlay_fn`` factories survive as thin
deprecated shims delegating here.
"""

from __future__ import annotations

import dataclasses
import warnings
from functools import partial
from typing import Callable, Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core import interpreter
from repro.core.grid import GridSpec
from repro.core.ingest import INGEST_MODES, check_ingest  # noqa: F401
from repro.core.tiling import TILE_AUTO, check_tile_rows, row_band
from repro.parallel.axes import (
    MeshSpec, build_mesh, shard_apps, shard_apps_rows,
)

#: Execution backends a plan may name (re-exported from the interpreter,
#: which owns the validation shared with the fleet and the front-end).
BACKENDS = interpreter.BACKENDS



@dataclasses.dataclass(frozen=True)
class OverlayPlan:
    """A frozen, hashable description of one overlay dispatch.

    Axes (the former factory-function matrix, now data):

    * ``grid``     the overlay structure (trace-time constants);
    * ``batched``  single app (``[C, batch]`` channels / ``[H, W]``
      frame) vs N stacked tenants (leading app axis on every operand);
    * ``fused``    raw-frame ingest (line buffers formed inside the
      dispatch, tap bank of ``radius``) vs pre-packed channels;
    * ``backend``  "xla" (the hand-lowered interpreter, the bitwise
      oracle) or "pallas" (the VCGRA megakernels);
    * ``mesh``     the :class:`MeshSpec` device placement --
      ``MeshSpec()`` is single-device, ``app`` > 1 shards the app axis
      (requires ``batched``), ``rows`` > 1 row-bands fused frames with
      seam halo exchange (requires ``batched`` AND ``fused``; unfused
      channels carry no row structure to band).  The deprecated bare
      device-count kwarg still constructs (with a DeprecationWarning) and
      means ``MeshSpec(app=k)`` -- same plan, same key, same cache entry;
    * ``tile_rows``  pixel-axis row tiling of the fused executors: None
      (untiled -- the whole padded frame and tap bank are resident at
      once), an int (rows per tile, each tile carrying a radius-wide row
      halo) or ``tiling.TILE_AUTO`` (the VMEM budget heuristic picks at
      trace time from the static frame shape).  Fused plans only --
      the unfused path has no tap bank and already tiles its flat pixel
      axis.  All values are bitwise-identical.  On ``backend="pallas"``
      the tiling lowers to the in-kernel double-buffered HBM->VMEM DMA
      pipeline (kernels/vcgra/vcgra_kernel.py) -- a kernel-internal
      lowering choice, NOT a plan axis: keys and cache entries are
      unchanged from the pre-DMA layout;
    * ``ingest``   "sync" (pack, dispatch, materialize in order) or
      "async" (the dispatch's frame/channel operand is *donated*, so the
      fleet's double-buffered pipeline can ship pooled canvases with
      ``jax.device_put`` and overlap packing flush k+1 with executing
      flush k).  Bitwise-identical; only buffer lifetime differs.

    Two dispatches with equal plans share one compiled executable; any
    layer that caches executables keys on the plan itself.
    """

    grid: GridSpec
    batched: bool = False
    fused: bool = False
    radius: Optional[int] = None     # tap-bank radius; fused plans only
    backend: str = "xla"
    mesh: MeshSpec = MeshSpec()
    tile_rows: Union[int, str, None] = None  # fused plans only
    ingest: str = "sync"
    #: Deprecated spelling of ``mesh=MeshSpec(app=k)`` (the pre-2-D bare
    #: device-count kwarg).  Not a field: it maps onto ``mesh`` at
    #: construction, so both spellings are ONE plan and ONE cache entry.
    devices: dataclasses.InitVar[Optional[int]] = None

    def __post_init__(self, devices):
        if devices is not None:
            d = int(devices)
            if d < 1:
                raise ValueError(f"devices must be >= 1, got {devices}")
            if self.mesh != MeshSpec():
                raise ValueError(
                    "pass mesh=MeshSpec(...) or the deprecated bare device "
                    "count, not both"
                )
            warnings.warn(
                "the bare device-count kwarg of OverlayPlan is deprecated: "
                f"pass mesh=MeshSpec(app={d}) instead",
                DeprecationWarning,
                stacklevel=3,
            )
            object.__setattr__(self, "mesh", MeshSpec(app=d))
        interpreter.check_backend(self.backend)
        check_ingest(self.ingest)
        if self.fused:
            # Canonical key: a fused plan always names its radius.
            object.__setattr__(
                self, "radius", 1 if self.radius is None else int(self.radius)
            )
            if self.radius < 1:
                raise ValueError(f"fused plan needs radius >= 1, got {self.radius}")
        elif self.radius is not None:
            raise ValueError(
                f"radius={self.radius} is meaningless for an unfused plan "
                "(the tap bank only exists on the fused ingest path)"
            )
        if self.tile_rows is not None:
            if not self.fused:
                raise ValueError(
                    f"tile_rows={self.tile_rows!r} is meaningless for an "
                    "unfused plan (pre-packed channels carry no row "
                    "structure to halo-tile; the pixel axis is already "
                    "block-tiled by the executors)"
                )
            # Canonical key: explicit tile heights are ints.
            object.__setattr__(self, "tile_rows", check_tile_rows(self.tile_rows))
        if not isinstance(self.mesh, MeshSpec):
            raise ValueError(
                f"mesh must be a MeshSpec, got {self.mesh!r}"
            )
        if self.mesh.app > 1 and not self.batched:
            raise ValueError(
                "an app-axis mesh width > 1 shards the app (N) axis, which "
                "only batched plans have; set batched=True or app=1"
            )
        if self.mesh.rows > 1 and not (self.batched and self.fused):
            raise ValueError(
                "a rows-axis mesh width > 1 band-shards the pixel rows of "
                "fused frames, which only batched fused plans have (pre-"
                "packed channels carry no row structure); set fused=True "
                "or rows=1"
            )

    def key(self) -> str:
        """Compact human-readable identity, used by stats stamping and
        bench JSON (``FleetStats.dispatch_plans``).  The tile/ingest
        segments appear only off their defaults, and the rows segment only
        when the mesh is 2-D, so PR 4-era keys are stable --
        ``MeshSpec(app=2)`` stamps the exact old ``dev2`` key and reuses
        that executable population."""
        parts = [
            self.grid.name,
            "batched" if self.batched else "single",
            f"fused:r{self.radius}" if self.fused else "channels",
            self.backend,
            f"dev{self.mesh.app}",
        ]
        if self.mesh.rows > 1:
            parts.append(f"rows{self.mesh.rows}")
        if self.tile_rows is not None:
            parts.append(f"tile:{self.tile_rows}")
        if self.ingest != "sync":
            parts.append(self.ingest)
        return "|".join(parts)


class OverlayExecutable:
    """The compiled artifact of one :class:`OverlayPlan`.

    Callable with the plan-shaped operands:

      batched=False, fused=False   fn(config_arrays, x)
      batched=False, fused=True    fn(config_arrays, ingest_arrays, image)
      batched=True,  fused=False   fn(stacked_configs, xs)
      batched=True,  fused=True    fn(stacked_configs, stacked_ingests, images)

    ``mesh`` is the device mesh the dispatch is sharded over (1-D for
    app-only specs, 2-D for row-banded ones), or None for the
    single-device path (including the fallback when the host could not
    honor ``plan.mesh``).
    """

    def __init__(self, plan: OverlayPlan, fn: Callable, mesh=None):
        self.plan = plan
        self._fn = fn
        self.mesh = mesh
        # Forward jit-cache introspection when the running jax has it
        # (fleet.overlay_executable_count uses it for compile-once asserts).
        sizer = getattr(fn, "_cache_size", None)
        if callable(sizer):
            self._cache_size = sizer

    def __call__(self, *args):
        return self._fn(*args)

    def lower(self, *args):
        """AOT lowering passthrough (``Pixie.compile_overlay`` times it)."""
        return self._fn.lower(*args)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"OverlayExecutable({self.plan.key()})"


# -- executor registry ---------------------------------------------------------

ExecutorBuilder = Callable[[OverlayPlan], Callable]
_EXECUTOR_BUILDERS: Dict[Tuple[str, bool, bool], ExecutorBuilder] = {}


def register_executor(backend: str, *, batched: bool, fused: bool):
    """Register the executor builder for one (backend, batched, fused)
    cell of the plan matrix.  The builder takes the plan and returns an
    (unjitted or jitted) callable with the plan-shaped operands;
    ``compile_plan`` applies sharding and the outer jit.  The XLA cells
    are registered below; ``kernels/vcgra/ops.py`` registers the pallas
    cells on import so the kernel package owns its own dispatch wiring
    instead of being special-cased here."""

    def deco(builder: ExecutorBuilder) -> ExecutorBuilder:
        _EXECUTOR_BUILDERS[(interpreter.check_backend(backend), batched, fused)] = builder
        return builder

    return deco


@register_executor("xla", batched=False, fused=False)
def _xla_single(plan: OverlayPlan) -> Callable:
    return partial(interpreter.overlay_step, plan.grid)


@register_executor("xla", batched=False, fused=True)
def _xla_single_fused(plan: OverlayPlan) -> Callable:
    if plan.tile_rows is not None:
        # Single-app tiled execution rides the batched tiled twin with N=1
        # (mirrors the pallas single-app adapters in kernels/vcgra/ops.py).
        batched = partial(
            interpreter.tiled_batched_fused_overlay_step,
            plan.grid, plan.radius, plan.tile_rows,
        )

        def fn(config, ingest, image):
            lift = partial(jax.tree_util.tree_map, lambda a: a[None])
            return batched(lift(config), lift(ingest), image[None])[0]

        return fn
    return partial(interpreter.fused_overlay_step, plan.grid, plan.radius)


@register_executor("xla", batched=True, fused=False)
def _xla_batched(plan: OverlayPlan) -> Callable:
    return partial(interpreter.batched_overlay_step, plan.grid)


@register_executor("xla", batched=True, fused=True)
def _xla_batched_fused(plan: OverlayPlan) -> Callable:
    if plan.tile_rows is not None:
        return partial(
            interpreter.tiled_batched_fused_overlay_step,
            plan.grid, plan.radius, plan.tile_rows,
        )
    return partial(interpreter.batched_fused_overlay_step, plan.grid, plan.radius)


# -- the compile pipeline ------------------------------------------------------


def _with_app_padding(fn: Callable, devices: int) -> Callable:
    """Pad the app axis of every operand to a multiple of the mesh size
    (replaying the last app -- always a valid config on valid inputs, so
    no NaN/garbage risk) and slice the output back.  Shapes are static
    under jit, so the pad amount is a trace-time constant and the padded
    executable is still compile-once per operand shape."""

    def padded(*args):
        n = jax.tree_util.tree_leaves(args[-1])[0].shape[0]
        pad = (-n) % devices
        if not pad:
            return fn(*args)
        args = jax.tree_util.tree_map(
            lambda a: jnp.concatenate(
                [a, jnp.broadcast_to(a[-1:], (pad,) + a.shape[1:])], axis=0
            ),
            args,
        )
        return fn(*args)[:n]

    return padded


def _with_mesh_padding(fn: Callable, spec: MeshSpec, radius: int) -> Callable:
    """The 2-D twin of :func:`_with_app_padding` for row-banded fused
    dispatch: pad the app axis to a multiple of ``spec.app`` (replaying
    the last app) AND the frame's row axis to ``row_band(H, rows, radius)
    * rows`` zero rows, then slice both back off the output.

    The row floor at ``radius`` guarantees every shard's band is at least
    as deep as the stencil reach, so the single-hop seam exchange of
    ``halo_exchange_rows`` is always sufficient.  Zero pad rows are read
    only as bottom-border zeros -- exactly ``form_tap_bank``'s border --
    and their outputs are discarded, so padding is bitwise exact.  Shapes
    are static under jit: both pad amounts are trace-time constants."""
    app, rows = spec.app, spec.rows

    def padded(configs, ingests, images):
        n, H, W = images.shape
        pad_n = (-n) % app
        if pad_n:
            configs, ingests, images = jax.tree_util.tree_map(
                lambda a: jnp.concatenate(
                    [a, jnp.broadcast_to(a[-1:], (pad_n,) + a.shape[1:])],
                    axis=0,
                ),
                (configs, ingests, images),
            )
        band = row_band(H, rows, radius)
        pad_h = band * rows - H
        if pad_h:
            images = jnp.pad(images, ((0, 0), (0, pad_h), (0, 0)))
        ys = fn(configs, ingests, images)
        if pad_h:
            ys = ys.reshape(ys.shape[0], ys.shape[1], band * rows, W)
            ys = ys[:, :, :H, :].reshape(ys.shape[0], ys.shape[1], H * W)
        return ys[:n] if pad_n else ys

    return padded


def compile_plan(plan: OverlayPlan) -> OverlayExecutable:
    """THE overlay compile entrypoint: plan -> jitted executable.

    Subsumes the former ``make_overlay_fn`` / ``make_batched_overlay_fn``
    / ``make_fused_overlay_fn`` / ``make_batched_fused_overlay_fn`` x
    backend matrix (those survive as deprecated shims delegating here).
    Builds the backend's executor, wraps it in ``shard_map`` over the
    plan's mesh when ``plan.mesh`` asks for more than one device and the
    host can grant it (single-device bitwise fallback otherwise -- 1-D
    app sharding via ``shard_apps``, 2-D app x rows sharding with seam
    halo exchange via ``shard_apps_rows``), and jits exactly once.
    """
    if plan.backend == "pallas":
        # Importing the kernel package registers its plan executors.
        import repro.kernels.vcgra.ops  # noqa: F401

    builder = _EXECUTOR_BUILDERS.get((plan.backend, plan.batched, plan.fused))
    if builder is None:  # pragma: no cover - registry covers the full matrix
        raise ValueError(f"no executor registered for plan {plan.key()}")
    fn = builder(plan)

    num_args = 3 if plan.fused else 2
    mesh = None
    if plan.mesh.size > 1:
        mesh = build_mesh(plan.mesh)
        if mesh is not None and plan.mesh.rows > 1:
            fn = _with_mesh_padding(
                shard_apps_rows(fn, mesh, plan.radius), plan.mesh, plan.radius
            )
        elif mesh is not None:
            fn = _with_app_padding(
                shard_apps(fn, mesh, num_args), plan.mesh.app
            )
    # Async-ingest plans donate the trailing operand (the frames canvas /
    # channel stack): the double-buffered pipeline ships a fresh
    # device_put buffer per dispatch, so XLA may reuse its memory for the
    # outputs instead of holding both live.  The settings/ingest banks are
    # cross-flush caches and are never donated.  Accelerators only: on
    # XLA:CPU donation buys nothing (host memory is not the scarce
    # resource) and measurably slows the fused executable (~4% at 256^2
    # -- input aliasing constrains its buffer assignment), so the CPU
    # async path keeps the donation-free executable.
    donate = ()
    if plan.ingest == "async" and jax.default_backend() != "cpu":
        donate = (num_args - 1,)
        _install_donation_warning_filter()
    return OverlayExecutable(plan, jax.jit(fn, donate_argnums=donate), mesh=mesh)


_DONATION_FILTER_INSTALLED = False


def _install_donation_warning_filter() -> None:
    """Donation is a best-effort memory hint, not a contract: backends
    that cannot alias the operand into an output warn on first lowering.
    Filter just that message, once, and only when donation is actually in
    play -- importing this module must not mute the diagnostic for
    unrelated user code, and repeat compiles must not pile duplicate
    entries onto the process-global filter list."""
    global _DONATION_FILTER_INSTALLED
    if not _DONATION_FILTER_INSTALLED:
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable"
        )
        _DONATION_FILTER_INSTALLED = True
