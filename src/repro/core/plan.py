"""OverlayPlan: the unified compile/dispatch pipeline for the overlay.

The paper's value proposition is ONE virtual overlay that many
applications reconfigure cheaply at runtime; the runtime realizes it, but
the "compile an overlay" surface had grown into a 2x2x2 matrix of factory
functions (``make_*_overlay_fn`` x ``backend``) that every layer
re-plumbed by hand.  This module collapses that matrix into a single
plan -> compile -> execute pipeline:

  OverlayPlan      a frozen, hashable description of one dispatch: grid
                   structure, fused-vs-channel ingest (+ tap radius),
                   single-vs-batched app axis, execution backend, device
                   placement.  It is THE cache key: the fleet's overlay
                   LRU, benchmark JSON and stats all name executables by
                   their plan.
  compile_plan     the one entrypoint: plan -> OverlayExecutable.  Looks
                   the executor builder up in a registry (XLA builders
                   registered here; the Pallas megakernels register
                   themselves from ``kernels/vcgra/ops.py``), wraps it
                   with app-axis mesh sharding when the plan asks for
                   devices > 1, and jits once.
  OverlayExecutable  the compiled artifact: callable with the plan-shaped
                   operands, carries its plan and (when sharded) mesh.

Device placement is a structured :class:`repro.parallel.axes.MeshSpec`:
``MeshSpec(app=k)`` shards the app (N) axis of a *batched* plan across k
local devices via shard_map (``parallel/axes.build_mesh`` /
``shard_apps``) -- the app axis is embarrassingly parallel (each tenant's
flat-gather offsets are local to its own rows), so the sharded result is
bitwise identical to the single-device run.  ``MeshSpec(app=k, rows=m)``
additionally shards fused frames *spatially* over a 2-D ``(app, rows)``
mesh: each row shard owns a contiguous band of pixel rows and exchanges
the radius-wide seam halo with its neighbours
(``parallel/axes.shard_apps_rows``), then runs the unchanged per-shard
executor -- still bitwise identical, because a haloed band reads exactly
like a short frame whose border pixels are real neighbour rows.  When
the host has fewer devices than the spec asks for, compilation falls
back to the single-device executable (same bits, same plan key).  N not
divisible by the app width -- and H not divisible into radius-deep row
bands -- is padded inside the executable and sliced back off.  The
deprecated bare device-count kwarg survives as a DeprecationWarning shim
meaning ``MeshSpec(app=k)``.

The legacy ``interpreter.make_*_overlay_fn`` factories survive as thin
deprecated shims delegating here.
"""

from __future__ import annotations

import dataclasses
import hashlib
import warnings
from functools import partial
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import interpreter
from repro.core.bitstream import VCGRAConfig
from repro.core.grid import GridSpec
from repro.core.ingest import INGEST_MODES, check_ingest  # noqa: F401
from repro.core.tiling import TILE_AUTO, check_tile_rows, row_band
from repro.parallel.axes import (
    MeshSpec, build_mesh, shard_apps, shard_apps_rows, shard_pipeline_rows,
)

#: Execution backends a plan may name (re-exported from the interpreter,
#: which owns the validation shared with the fleet and the front-end).
BACKENDS = interpreter.BACKENDS


# -- the pipeline axis ---------------------------------------------------------


def _config_digest(cfg: VCGRAConfig) -> str:
    """Canonical content digest of one stage's settings: everything that
    shapes the traced executable (grid structure name, opcodes, mux
    selects, output taps, ingest production rules, const coefficients).
    sha1 over raw bytes -- deterministic across processes, unlike
    ``hash()`` under PYTHONHASHSEED randomization -- because pipeline
    digests end up in plan keys that bench JSON and stats compare across
    runs.  ``VCGRAConfig`` itself stays an unfrozen builder object; the
    digest is what makes a stage *hashable* without freezing it."""
    h = hashlib.sha1()
    h.update(cfg.grid_name.encode())
    for ops_lvl in cfg.opcodes:
        h.update(np.asarray(ops_lvl, np.int32).tobytes())
    for sel_lvl in cfg.selects:
        h.update(np.asarray(sel_lvl, np.int32).tobytes())
    h.update(np.asarray(cfg.out_sel, np.int32).tobytes())
    h.update(repr(tuple(cfg.input_order)).encode())
    h.update(
        repr(sorted((str(k), float(v)) for k, v in cfg.const_values.items()))
        .encode()
    )
    ing = cfg.ingest
    if ing is not None:
        h.update(str(int(ing.radius)).encode())
        h.update(np.asarray(ing.tap_sel, np.int32).tobytes())
        h.update(np.asarray(ing.const_vals, np.float64).tobytes())
    return h.hexdigest()


@dataclasses.dataclass(frozen=True, eq=False)
class PipelineStage:
    """One stage of a pipeline chain: a mapped app config plus which of
    its output channels feeds the next stage's ingest taps.

    ``config`` must carry an :class:`~repro.core.ingest.IngestPlan` (every
    stage eats a raw frame -- the previous stage's device-resident
    intermediate); its radius IS the stage's tap radius.  Use
    :meth:`at_radius` to re-plan a stage at a different radius (e.g. a
    pointwise threshold stage on a radius-0 bank).  ``out_channel`` on the
    LAST stage is forwarding metadata with nothing to feed; the chain
    returns that stage's full ``[K, H*W]`` output like any fused dispatch.

    Hash/eq ride a content digest (:func:`_config_digest`), so stages slot
    into frozen plans without freezing ``VCGRAConfig``.
    """

    config: VCGRAConfig
    out_channel: int = 0

    def __post_init__(self):
        if self.config.ingest is None:
            raise ValueError(
                f"pipeline stage {self.config.app_name!r} has no ingest "
                "plan (a channel is neither a stencil tap nor a const); "
                "every stage must eat a raw frame"
            )
        object.__setattr__(self, "out_channel", int(self.out_channel))
        if not 0 <= self.out_channel < len(self.config.out_sel):
            raise ValueError(
                f"out_channel={self.out_channel} out of range for "
                f"{self.config.app_name!r} ({len(self.config.out_sel)} "
                "output channels)"
            )
        object.__setattr__(
            self,
            "_digest",
            hashlib.sha1(
                f"{_config_digest(self.config)}|out{self.out_channel}".encode()
            ).hexdigest(),
        )

    @property
    def digest(self) -> str:
        return self._digest

    @property
    def radius(self) -> int:
        return int(self.config.ingest.radius)

    def at_radius(self, radius: int) -> "PipelineStage":
        """The same stage re-planned against a different tap-bank radius
        (see :meth:`IngestPlan.at_radius`).  The returned config's
        ``cache_key`` is re-suffixed so the fleet's radius-keyed settings
        banks never alias the original."""
        if int(radius) == self.radius:
            return self
        cfg = dataclasses.replace(
            self.config, ingest=self.config.ingest.at_radius(radius)
        )
        if cfg.cache_key is not None:
            cfg.cache_key = f"{cfg.cache_key}@r{int(radius)}"
        return PipelineStage(cfg, self.out_channel)

    def __hash__(self):
        return hash(self._digest)

    def __eq__(self, other):
        return (
            isinstance(other, PipelineStage) and self._digest == other._digest
        )


@dataclasses.dataclass(frozen=True, eq=False)
class PipelineSpec:
    """A frozen, hashable ordered chain of :class:`PipelineStage`s: the
    pipeline axis of ONE app slot.  Stage *i*'s selected output channel
    feeds stage *i+1*'s ingest taps as a raw frame; intermediates never
    leave the device (no unpack/repack, no host hop).  Linear chains
    today -- the degenerate DAG; the stage tuple is the topological order
    a richer DAG would serialize to."""

    stages: Tuple[PipelineStage, ...]

    def __post_init__(self):
        stages = tuple(self.stages)
        if not stages:
            raise ValueError("a pipeline needs at least one stage")
        gname = stages[0].config.grid_name
        for s in stages[1:]:
            if s.config.grid_name != gname:
                raise ValueError(
                    "every stage of a pipeline runs on ONE overlay grid "
                    f"(reconfigured between stages): {s.config.grid_name!r} "
                    f"!= {gname!r}"
                )
        object.__setattr__(self, "stages", stages)
        h = hashlib.sha1()
        for s in stages:
            h.update(s.digest.encode())
        object.__setattr__(self, "_digest", h.hexdigest())

    @property
    def depth(self) -> int:
        return len(self.stages)

    @property
    def radii(self) -> Tuple[int, ...]:
        return tuple(s.radius for s in self.stages)

    @property
    def total_radius(self) -> int:
        """Sum of stage radii: the total row pad one output pixel's
        provenance reaches back through the whole chain -- what the Pallas
        megakernel pads its DMA slabs by."""
        return sum(self.radii)

    @property
    def digest(self) -> str:
        return self._digest

    @staticmethod
    def chain(
        configs: Sequence[VCGRAConfig],
        out_channels: Optional[Sequence[int]] = None,
    ) -> "PipelineSpec":
        """Build a linear chain from mapped configs (+ optional per-stage
        forwarded output channels, default 0)."""
        cfgs = list(configs)
        chans = list(out_channels) if out_channels is not None else [0] * len(cfgs)
        if len(chans) != len(cfgs):
            raise ValueError(
                f"{len(chans)} out_channels for {len(cfgs)} stages"
            )
        return PipelineSpec(
            tuple(PipelineStage(c, ch) for c, ch in zip(cfgs, chans))
        )

    def __hash__(self):
        return hash(self._digest)

    def __eq__(self, other):
        return (
            isinstance(other, PipelineSpec) and self._digest == other._digest
        )


def pipeline_digest(specs: Sequence[PipelineSpec]) -> str:
    """Combined digest of one dispatch's per-app-slot pipeline specs --
    the ``pipe{...}`` segment of the plan key."""
    h = hashlib.sha1()
    for s in specs:
        h.update(s.digest.encode())
    return h.hexdigest()


@dataclasses.dataclass(frozen=True)
class OverlayPlan:
    """A frozen, hashable description of one overlay dispatch.

    Axes (the former factory-function matrix, now data):

    * ``grid``     the overlay structure (trace-time constants);
    * ``batched``  single app (``[C, batch]`` channels / ``[H, W]``
      frame) vs N stacked tenants (leading app axis on every operand);
    * ``fused``    raw-frame ingest (line buffers formed inside the
      dispatch, tap bank of ``radius``) vs pre-packed channels;
    * ``backend``  "xla" (the hand-lowered interpreter, the bitwise
      oracle) or "pallas" (the VCGRA megakernels);
    * ``mesh``     the :class:`MeshSpec` device placement --
      ``MeshSpec()`` is single-device, ``app`` > 1 shards the app axis
      (requires ``batched``), ``rows`` > 1 row-bands fused frames with
      seam halo exchange (requires ``batched`` AND ``fused``; unfused
      channels carry no row structure to band).  The deprecated bare
      device-count kwarg still constructs (with a DeprecationWarning) and
      means ``MeshSpec(app=k)`` -- same plan, same key, same cache entry;
    * ``tile_rows``  pixel-axis row tiling of the fused executors: None
      (untiled -- the whole padded frame and tap bank are resident at
      once), an int (rows per tile, each tile carrying a radius-wide row
      halo) or ``tiling.TILE_AUTO`` (the VMEM budget heuristic picks at
      trace time from the static frame shape).  Fused plans only --
      the unfused path has no tap bank and already tiles its flat pixel
      axis.  All values are bitwise-identical.  On ``backend="pallas"``
      the tiling lowers to the in-kernel double-buffered HBM->VMEM DMA
      pipeline (kernels/vcgra/vcgra_kernel.py) -- a kernel-internal
      lowering choice, NOT a plan axis: keys and cache entries are
      unchanged from the pre-DMA layout;
    * ``ingest``   "sync" (pack, dispatch, materialize in order) or
      "async" (the dispatch's frame/channel operand is *donated*, so the
      fleet's double-buffered pipeline can ship pooled canvases with
      ``jax.device_put`` and overlap packing flush k+1 with executing
      flush k).  Bitwise-identical; only buffer lifetime differs.

    Two dispatches with equal plans share one compiled executable; any
    layer that caches executables keys on the plan itself.
    """

    grid: GridSpec
    batched: bool = False
    fused: bool = False
    radius: Optional[int] = None     # tap-bank radius; fused plans only
    backend: str = "xla"
    mesh: MeshSpec = MeshSpec()
    tile_rows: Union[int, str, None] = None  # fused plans only
    ingest: str = "sync"
    #: The pipeline axis: one :class:`PipelineSpec` per app slot of the
    #: batched dispatch (all sharing depth and per-stage radii -- that is
    #: executable shape; the per-stage *settings* differ per slot).
    #: Depth-1 "chains" canonicalize to ``pipeline=None`` + the stage's
    #: radius at construction, so they ARE the existing single-stage
    #: batched fused plan: same key, same hash, same cache entry.
    pipeline: Optional[Tuple[PipelineSpec, ...]] = None
    #: Deprecated spelling of ``mesh=MeshSpec(app=k)`` (the pre-2-D bare
    #: device-count kwarg).  Not a field: it maps onto ``mesh`` at
    #: construction, so both spellings are ONE plan and ONE cache entry.
    devices: dataclasses.InitVar[Optional[int]] = None

    def __post_init__(self, devices):
        if devices is not None:
            d = int(devices)
            if d < 1:
                raise ValueError(f"devices must be >= 1, got {devices}")
            if self.mesh != MeshSpec():
                raise ValueError(
                    "pass mesh=MeshSpec(...) or the deprecated bare device "
                    "count, not both"
                )
            warnings.warn(
                "the bare device-count kwarg of OverlayPlan is deprecated: "
                f"pass mesh=MeshSpec(app={d}) instead",
                DeprecationWarning,
                stacklevel=3,
            )
            object.__setattr__(self, "mesh", MeshSpec(app=d))
        interpreter.check_backend(self.backend)
        check_ingest(self.ingest)
        if self.pipeline is not None:
            specs = tuple(self.pipeline)
            if not specs or not all(
                isinstance(s, PipelineSpec) for s in specs
            ):
                raise ValueError(
                    "pipeline must be a non-empty sequence of PipelineSpec "
                    "(one per app slot)"
                )
            ref = specs[0]
            for s in specs[1:]:
                if s.radii != ref.radii:
                    raise ValueError(
                        "every app slot of a pipeline dispatch must share "
                        f"the stage structure: radii {s.radii} != {ref.radii} "
                        "(depth and per-stage radii are executable shape)"
                    )
            for s in specs:
                for st in s.stages:
                    if st.config.grid_name != self.grid.name:
                        raise ValueError(
                            "pipeline stage mapped on grid "
                            f"{st.config.grid_name!r} cannot run on plan "
                            f"grid {self.grid.name!r}"
                        )
            if not self.batched:
                raise ValueError(
                    "a pipeline plan is a batched fused dispatch (single "
                    "chains run as N=1); set batched=True"
                )
            if self.radius is not None:
                raise ValueError(
                    "radius is derived from the pipeline's stages; don't "
                    "pass both"
                )
            object.__setattr__(self, "fused", True)
            if ref.depth == 1:
                # Depth-1 canonicalization: a single-stage "chain" IS the
                # existing batched fused plan -- hash, key and cache entry
                # all land on the pre-pipeline population.
                object.__setattr__(self, "pipeline", None)
                object.__setattr__(self, "radius", ref.radii[0])
            else:
                object.__setattr__(self, "pipeline", specs)
                # The plan-level radius of a chain is the max stage radius:
                # it governs the rows-mesh band floor (every per-stage halo
                # exchange must stay single-hop).  Full identity lives in
                # the key's pipe{digest} segment.
                object.__setattr__(self, "radius", max(ref.radii))
        if self.fused:
            # Canonical key: a fused plan always names its radius.
            object.__setattr__(
                self, "radius", 1 if self.radius is None else int(self.radius)
            )
            if self.radius < 0:
                raise ValueError(f"fused plan needs radius >= 0, got {self.radius}")
        elif self.radius is not None:
            raise ValueError(
                f"radius={self.radius} is meaningless for an unfused plan "
                "(the tap bank only exists on the fused ingest path)"
            )
        if self.tile_rows is not None:
            if not self.fused:
                raise ValueError(
                    f"tile_rows={self.tile_rows!r} is meaningless for an "
                    "unfused plan (pre-packed channels carry no row "
                    "structure to halo-tile; the pixel axis is already "
                    "block-tiled by the executors)"
                )
            # Canonical key: explicit tile heights are ints.
            object.__setattr__(self, "tile_rows", check_tile_rows(self.tile_rows))
        if not isinstance(self.mesh, MeshSpec):
            raise ValueError(
                f"mesh must be a MeshSpec, got {self.mesh!r}"
            )
        if self.mesh.app > 1 and not self.batched:
            raise ValueError(
                "an app-axis mesh width > 1 shards the app (N) axis, which "
                "only batched plans have; set batched=True or app=1"
            )
        if self.mesh.rows > 1 and not (self.batched and self.fused):
            raise ValueError(
                "a rows-axis mesh width > 1 band-shards the pixel rows of "
                "fused frames, which only batched fused plans have (pre-"
                "packed channels carry no row structure); set fused=True "
                "or rows=1"
            )

    def key(self) -> str:
        """Compact human-readable identity, used by stats stamping and
        bench JSON (``FleetStats.dispatch_plans``).  The tile/ingest
        segments appear only off their defaults, and the rows segment only
        when the mesh is 2-D, so PR 4-era keys are stable --
        ``MeshSpec(app=2)`` stamps the exact old ``dev2`` key and reuses
        that executable population."""
        parts = [
            self.grid.name,
            "batched" if self.batched else "single",
            f"fused:r{self.radius}" if self.fused else "channels",
            self.backend,
            f"dev{self.mesh.app}",
        ]
        if self.pipeline is not None:
            # Depth>1 only (depth-1 canonicalized to pipeline=None), so
            # every pre-pipeline key -- and its cache entry -- survives.
            parts.append(f"pipe{pipeline_digest(self.pipeline)[:12]}")
        if self.mesh.rows > 1:
            parts.append(f"rows{self.mesh.rows}")
        if self.tile_rows is not None:
            parts.append(f"tile:{self.tile_rows}")
        if self.ingest != "sync":
            parts.append(self.ingest)
        return "|".join(parts)


def replace_plan(plan: OverlayPlan, **overrides: Any) -> OverlayPlan:
    """``dataclasses.replace`` that is safe for pipeline plans.

    ``__post_init__`` derives ``fused``/``radius`` from the pipeline
    stages and rejects passing both, so a naive ``replace`` (which
    re-passes every field) raises on any pipeline plan.  Reconstruct from
    the orthogonal axes instead; plain plans go through ``replace``."""
    if plan.pipeline is not None:
        fields = dict(
            grid=plan.grid, batched=True, pipeline=plan.pipeline,
            backend=plan.backend, mesh=plan.mesh,
            tile_rows=plan.tile_rows, ingest=plan.ingest,
        )
        fields.update(overrides)
        return OverlayPlan(**fields)
    return dataclasses.replace(plan, **overrides)


def fallback_chain(plan: OverlayPlan) -> Tuple[OverlayPlan, ...]:
    """The graceful-degradation ladder of ``plan``, most- to
    least-capable: each step strips ONE risky axis while preserving the
    request-shaped axes (grid, fusion, radius/pipeline, ingest), so any
    step can serve the exact same dispatch operands.

      1. ``backend="pallas"`` -> ``"xla"`` (the bitwise oracle);
      2. 2-D ``MeshSpec(app=a, rows=r)`` -> ``app_only()`` (drop the
         halo-exchanging rows axis);
      3. ``MeshSpec(app=a)`` -> single device;
      4. ``tile_rows`` -> ``None`` (untiled pixel axis).

    Every step is bitwise-equal to the primary by the parity guarantees
    each axis carries (enforced in CI), so a circuit breaker can degrade
    dispatch-by-dispatch without changing results.  Each entry is a
    distinct :class:`OverlayPlan` -- i.e. just another plan-cache key, so
    fallback executables cost one compile each, ever."""
    chain: List[OverlayPlan] = []
    cur = plan

    def step(**overrides: Any) -> None:
        nonlocal cur
        nxt = replace_plan(cur, **overrides)
        if nxt != cur:
            chain.append(nxt)
            cur = nxt

    if cur.backend != "xla":
        step(backend="xla")
    if cur.mesh.rows > 1:
        step(mesh=cur.mesh.app_only())
    if cur.mesh.app > 1:
        step(mesh=MeshSpec())
    if cur.tile_rows is not None:
        step(tile_rows=None)
    return tuple(chain)


class OverlayExecutable:
    """The compiled artifact of one :class:`OverlayPlan`.

    Callable with the plan-shaped operands:

      batched=False, fused=False   fn(config_arrays, x)
      batched=False, fused=True    fn(config_arrays, ingest_arrays, image)
      batched=True,  fused=False   fn(stacked_configs, xs)
      batched=True,  fused=True    fn(stacked_configs, stacked_ingests, images)
      pipeline (depth > 1)         fn(stage_settings, hw, images)

    Pipeline operands: ``stage_settings`` is one ``(stacked_configs,
    stacked_ingests, out_ch)`` triple per stage (``out_ch`` int32 [N]);
    ``hw`` is int32 [N, 2] of per-app true ``(rows, cols)`` inside the
    (possibly bucketed) canvas -- everything outside is zeroed between
    stages so the fused chain matches the staged oracle bitwise.  The
    single-device XLA executor is *specialized at trace time* from the
    plan's static configs and ignores the settings operands (the plan is
    the source of truth -- callers must pass settings matching it, which
    the fleet does by construction); mesh-sharded and Pallas executors
    consume them as runtime data.  One signature either way.

    ``mesh`` is the device mesh the dispatch is sharded over (1-D for
    app-only specs, 2-D for row-banded ones), or None for the
    single-device path (including the fallback when the host could not
    honor ``plan.mesh``).
    """

    def __init__(self, plan: OverlayPlan, fn: Callable, mesh=None):
        self.plan = plan
        self._fn = fn
        self.mesh = mesh
        # Forward jit-cache introspection when the running jax has it
        # (fleet.overlay_executable_count uses it for compile-once asserts).
        sizer = getattr(fn, "_cache_size", None)
        if callable(sizer):
            self._cache_size = sizer

    def __call__(self, *args):
        return self._fn(*args)

    def lower(self, *args):
        """AOT lowering passthrough (``Pixie.compile_overlay`` times it)."""
        return self._fn.lower(*args)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"OverlayExecutable({self.plan.key()})"


# -- executor registry ---------------------------------------------------------

ExecutorBuilder = Callable[[OverlayPlan], Callable]
_EXECUTOR_BUILDERS: Dict[Tuple[str, bool, bool], ExecutorBuilder] = {}


def register_executor(backend: str, *, batched: bool, fused: bool):
    """Register the executor builder for one (backend, batched, fused)
    cell of the plan matrix.  The builder takes the plan and returns an
    (unjitted or jitted) callable with the plan-shaped operands;
    ``compile_plan`` applies sharding and the outer jit.  The XLA cells
    are registered below; ``kernels/vcgra/ops.py`` registers the pallas
    cells on import so the kernel package owns its own dispatch wiring
    instead of being special-cased here."""

    def deco(builder: ExecutorBuilder) -> ExecutorBuilder:
        _EXECUTOR_BUILDERS[(interpreter.check_backend(backend), batched, fused)] = builder
        return builder

    return deco


@register_executor("xla", batched=False, fused=False)
def _xla_single(plan: OverlayPlan) -> Callable:
    return partial(interpreter.overlay_step, plan.grid)


@register_executor("xla", batched=False, fused=True)
def _xla_single_fused(plan: OverlayPlan) -> Callable:
    if plan.tile_rows is not None:
        # Single-app tiled execution rides the batched tiled twin with N=1
        # (mirrors the pallas single-app adapters in kernels/vcgra/ops.py).
        batched = partial(
            interpreter.tiled_batched_fused_overlay_step,
            plan.grid, plan.radius, plan.tile_rows,
        )

        def fn(config, ingest, image):
            lift = partial(jax.tree_util.tree_map, lambda a: a[None])
            return batched(lift(config), lift(ingest), image[None])[0]

        return fn
    return partial(interpreter.fused_overlay_step, plan.grid, plan.radius)


@register_executor("xla", batched=True, fused=False)
def _xla_batched(plan: OverlayPlan) -> Callable:
    return partial(interpreter.batched_overlay_step, plan.grid)


@register_executor("xla", batched=True, fused=True)
def _xla_batched_fused(plan: OverlayPlan) -> Callable:
    if plan.tile_rows is not None:
        return partial(
            interpreter.tiled_batched_fused_overlay_step,
            plan.grid, plan.radius, plan.tile_rows,
        )
    return partial(interpreter.batched_fused_overlay_step, plan.grid, plan.radius)


# -- pipeline executors --------------------------------------------------------


class _BankChannels:
    """Duck-typed ``[C, pixels]`` channel input for
    :func:`repro.core.specialize.build_specialized_fn`: channels are
    produced lazily from ONE app's tap bank by the stage's *static*
    ingest plan, so only channels the specialized trace actually fetches
    are ever formed -- dead taps cost nothing, exactly like the dead
    functional units the specializer already folds away."""

    def __init__(self, bank: jnp.ndarray, ingest, dtype):
        self._bank = bank            # [T+1, pixels]
        self._ingest = ingest
        self.shape = (int(ingest.tap_sel.shape[0]),) + bank.shape[1:]
        self.dtype = dtype

    def __getitem__(self, c: int) -> jnp.ndarray:
        t = int(self._ingest.tap_sel[c])
        if t == self._ingest.zero_row:
            # Const (or zero-pad) channel: a scalar; apply_op broadcasting
            # and the specializer's final broadcast_to widen it.
            return jnp.asarray(self._ingest.const_vals[c], self.dtype)
        return self._bank[t]


def _pipeline_specialized_fn(plan: "OverlayPlan") -> Callable:
    """Single-device XLA pipeline executor, specialized at trace time.

    The plan's :class:`PipelineSpec`s are static, so each (app, stage)
    pair traces through ``specialize.build_specialized_fn``: only the
    configured functional unit per PE is emitted (no all-units-plus-mux
    generic datapath) and every VC select folds to direct SSA wiring --
    the paper's parameterized-vs-conventional distinction, applied per
    stage of the chain.  This is where the pipeline bench's speedup over
    the staged generic dispatches comes from; the inter-stage hop is just
    a reshape + mask, never a host transfer.

    Bitwise equal to the generic path: per live PE both compute the same
    ``apply_op`` formula on the same operands, and channel production
    selects the same bank rows / consts.
    """
    from repro.core.specialize import build_specialized_fn

    grid = plan.grid
    specs = plan.pipeline
    radii = specs[0].radii
    depth = len(radii)
    stage_fns = [
        [build_specialized_fn(grid, spec.stages[si].config) for spec in specs]
        for si in range(depth)
    ]

    def fn(stage_settings, hw, images):
        del stage_settings  # identity lives in the plan (trace-time consts)
        x = jnp.asarray(images, grid.dtype)
        n, H, W = x.shape
        if n != len(specs):
            raise ValueError(
                f"pipeline plan carries {len(specs)} app slots, dispatch "
                f"has {n} frames"
            )
        valid = interpreter.valid_pixel_mask(hw, H, W)
        ys = None
        for si in range(depth):
            bank = interpreter.form_tap_bank(x, radii[si], grid.dtype)
            ys = jnp.stack(
                [
                    stage_fns[si][a](
                        _BankChannels(
                            bank[a], specs[a].stages[si].config.ingest,
                            grid.dtype,
                        )
                    )
                    for a in range(n)
                ],
                axis=0,
            )
            if si < depth - 1:
                # out_channel is static per app slot: a plain view, no
                # gather.
                y = jnp.stack(
                    [ys[a, specs[a].stages[si].out_channel] for a in range(n)],
                    axis=0,
                )
                x = jnp.where(valid, y.reshape(n, H, W), 0)
        return ys

    return fn


def _pipeline_stage_fn(plan: "OverlayPlan") -> Callable:
    """Per-stage executor ``stage_fn(radius, configs, ingests, x)`` for the
    operand-settings pipeline chain (mesh-sharded paths: SPMD traces once,
    so per-shard trace-time constants are impossible and settings stay
    runtime data, exactly like single-stage sharded dispatch)."""
    if plan.backend == "pallas":
        from repro.kernels.vcgra.ops import pallas_pipeline_stage_fn

        return pallas_pipeline_stage_fn(plan.grid, plan.tile_rows)
    if plan.tile_rows is not None:
        def stage(radius, configs, ingests, x):
            return interpreter.tiled_batched_fused_overlay_step(
                plan.grid, radius, plan.tile_rows, configs, ingests, x
            )

        return stage

    def stage(radius, configs, ingests, x):
        return interpreter.batched_fused_overlay_step(
            plan.grid, radius, configs, ingests, x
        )

    return stage


def _with_pipeline_mesh_padding(fn: Callable, spec: MeshSpec,
                                radius: int) -> Callable:
    """:func:`_with_mesh_padding` for the pipeline signature
    ``(stage_settings, hw, images)``: pad the app axis of every settings
    leaf (replaying the last slot) and the frame rows to ``row_band(H,
    rows, max_radius) * rows`` zeros, slice both back off.  ``hw`` keeps
    the true per-app sizes, so the in-chain mask also zeroes the pad rows
    between stages -- which is what makes replay-padding exact for chains
    (the padded slots' garbage never crosses a halo exchange)."""
    app, rows = spec.app, spec.rows

    def padded(stage_settings, hw, images):
        n, H, W = images.shape
        pad_n = (-n) % app
        if pad_n:
            stage_settings, hw, images = jax.tree_util.tree_map(
                lambda a: jnp.concatenate(
                    [a, jnp.broadcast_to(a[-1:], (pad_n,) + a.shape[1:])],
                    axis=0,
                ),
                (stage_settings, hw, images),
            )
        band = row_band(H, rows, radius)
        pad_h = band * rows - H
        if pad_h:
            images = jnp.pad(images, ((0, 0), (0, pad_h), (0, 0)))
        ys = fn(stage_settings, hw, images)
        if pad_h:
            ys = ys.reshape(ys.shape[0], ys.shape[1], band * rows, W)
            ys = ys[:, :, :H, :].reshape(ys.shape[0], ys.shape[1], H * W)
        return ys[:n] if pad_n else ys

    return padded


def _compile_pipeline(plan: "OverlayPlan") -> "OverlayExecutable":
    """Compile a depth>1 pipeline plan into ONE executable
    ``fn(stage_settings, hw, images)`` whose intermediates never leave the
    device.

    Single-device XLA: the trace-time-specialized chain
    (:func:`_pipeline_specialized_fn`).  Single-device Pallas: the
    multi-stage megakernel (stage loop over the same VMEM scratch slabs,
    total pad = sum of stage radii).  Mesh-sharded (either backend): the
    operand-settings chain, app-sharded via ``shard_apps`` or row-banded
    with per-stage halo exchange via ``shard_pipeline_rows``.  All paths
    are bitwise equal to the staged per-stage oracle.
    """
    radii = plan.pipeline[0].radii
    mesh = build_mesh(plan.mesh) if plan.mesh.size > 1 else None
    if mesh is None:
        if plan.backend == "pallas":
            from repro.kernels.vcgra.ops import pallas_pipeline_fn

            fn = pallas_pipeline_fn(plan.grid, radii, plan.tile_rows)
        else:
            fn = _pipeline_specialized_fn(plan)
    else:
        stage_fn = _pipeline_stage_fn(plan)
        if plan.mesh.rows > 1:
            fn = _with_pipeline_mesh_padding(
                shard_pipeline_rows(stage_fn, mesh, radii),
                plan.mesh, plan.radius,
            )
        else:
            chain = partial(
                interpreter.pipeline_batched_fused_step,
                plan.grid, radii, stage_fn,
            )
            fn = _with_app_padding(shard_apps(chain, mesh, 3), plan.mesh.app)
    donate = ()
    if plan.ingest == "async" and jax.default_backend() != "cpu":
        donate = (2,)
        _install_donation_warning_filter()
    return OverlayExecutable(plan, jax.jit(fn, donate_argnums=donate),
                             mesh=mesh)


# -- the compile pipeline ------------------------------------------------------


def _with_app_padding(fn: Callable, devices: int) -> Callable:
    """Pad the app axis of every operand to a multiple of the mesh size
    (replaying the last app -- always a valid config on valid inputs, so
    no NaN/garbage risk) and slice the output back.  Shapes are static
    under jit, so the pad amount is a trace-time constant and the padded
    executable is still compile-once per operand shape."""

    def padded(*args):
        n = jax.tree_util.tree_leaves(args[-1])[0].shape[0]
        pad = (-n) % devices
        if not pad:
            return fn(*args)
        args = jax.tree_util.tree_map(
            lambda a: jnp.concatenate(
                [a, jnp.broadcast_to(a[-1:], (pad,) + a.shape[1:])], axis=0
            ),
            args,
        )
        return fn(*args)[:n]

    return padded


def _with_mesh_padding(fn: Callable, spec: MeshSpec, radius: int) -> Callable:
    """The 2-D twin of :func:`_with_app_padding` for row-banded fused
    dispatch: pad the app axis to a multiple of ``spec.app`` (replaying
    the last app) AND the frame's row axis to ``row_band(H, rows, radius)
    * rows`` zero rows, then slice both back off the output.

    The row floor at ``radius`` guarantees every shard's band is at least
    as deep as the stencil reach, so the single-hop seam exchange of
    ``halo_exchange_rows`` is always sufficient.  Zero pad rows are read
    only as bottom-border zeros -- exactly ``form_tap_bank``'s border --
    and their outputs are discarded, so padding is bitwise exact.  Shapes
    are static under jit: both pad amounts are trace-time constants."""
    app, rows = spec.app, spec.rows

    def padded(configs, ingests, images):
        n, H, W = images.shape
        pad_n = (-n) % app
        if pad_n:
            configs, ingests, images = jax.tree_util.tree_map(
                lambda a: jnp.concatenate(
                    [a, jnp.broadcast_to(a[-1:], (pad_n,) + a.shape[1:])],
                    axis=0,
                ),
                (configs, ingests, images),
            )
        band = row_band(H, rows, radius)
        pad_h = band * rows - H
        if pad_h:
            images = jnp.pad(images, ((0, 0), (0, pad_h), (0, 0)))
        ys = fn(configs, ingests, images)
        if pad_h:
            ys = ys.reshape(ys.shape[0], ys.shape[1], band * rows, W)
            ys = ys[:, :, :H, :].reshape(ys.shape[0], ys.shape[1], H * W)
        return ys[:n] if pad_n else ys

    return padded


def compile_plan(plan: OverlayPlan) -> OverlayExecutable:
    """THE overlay compile entrypoint: plan -> jitted executable.

    Subsumes the former ``make_overlay_fn`` / ``make_batched_overlay_fn``
    / ``make_fused_overlay_fn`` / ``make_batched_fused_overlay_fn`` x
    backend matrix (those survive as deprecated shims delegating here).
    Builds the backend's executor, wraps it in ``shard_map`` over the
    plan's mesh when ``plan.mesh`` asks for more than one device and the
    host can grant it (single-device bitwise fallback otherwise -- 1-D
    app sharding via ``shard_apps``, 2-D app x rows sharding with seam
    halo exchange via ``shard_apps_rows``), and jits exactly once.
    """
    if plan.pipeline is not None:
        return _compile_pipeline(plan)
    if plan.backend == "pallas":
        # Importing the kernel package registers its plan executors.
        import repro.kernels.vcgra.ops  # noqa: F401

    builder = _EXECUTOR_BUILDERS.get((plan.backend, plan.batched, plan.fused))
    if builder is None:  # pragma: no cover - registry covers the full matrix
        raise ValueError(f"no executor registered for plan {plan.key()}")
    fn = builder(plan)

    num_args = 3 if plan.fused else 2
    mesh = None
    if plan.mesh.size > 1:
        mesh = build_mesh(plan.mesh)
        if mesh is not None and plan.mesh.rows > 1:
            fn = _with_mesh_padding(
                shard_apps_rows(fn, mesh, plan.radius), plan.mesh, plan.radius
            )
        elif mesh is not None:
            fn = _with_app_padding(
                shard_apps(fn, mesh, num_args), plan.mesh.app
            )
    # Async-ingest plans donate the trailing operand (the frames canvas /
    # channel stack): the double-buffered pipeline ships a fresh
    # device_put buffer per dispatch, so XLA may reuse its memory for the
    # outputs instead of holding both live.  The settings/ingest banks are
    # cross-flush caches and are never donated.  Accelerators only: on
    # XLA:CPU donation buys nothing (host memory is not the scarce
    # resource) and measurably slows the fused executable (~4% at 256^2
    # -- input aliasing constrains its buffer assignment), so the CPU
    # async path keeps the donation-free executable.
    donate = ()
    if plan.ingest == "async" and jax.default_backend() != "cpu":
        donate = (num_args - 1,)
        _install_donation_warning_filter()
    return OverlayExecutable(plan, jax.jit(fn, donate_argnums=donate), mesh=mesh)


_DONATION_FILTER_INSTALLED = False


def _install_donation_warning_filter() -> None:
    """Donation is a best-effort memory hint, not a contract: backends
    that cannot alias the operand into an output warn on first lowering.
    Filter just that message, once, and only when donation is actually in
    play -- importing this module must not mute the diagnostic for
    unrelated user code, and repeat compiles must not pile duplicate
    entries onto the process-global filter list."""
    global _DONATION_FILTER_INSTALLED
    if not _DONATION_FILTER_INSTALLED:
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable"
        )
        _DONATION_FILTER_INSTALLED = True
