"""Pixie: a heterogeneous Virtual CGRA overlay, reproduced in JAX for TPU.

The paper's primary contribution -- an overlay architecture (PE grid +
virtual channels) with a fast application-mapping tool flow and a
parameterized-configuration optimization -- implemented as a composable
JAX system:

  dfg          dataflow-graph IR (the toolchain input)
  synthesis    textual description -> PE netlist
  grid         grid specification + generator tool (Eq. 1-3 resource model)
  place        mapper/placer (BUF-carrier insertion, NONE fill)
  route        VC mux-select router
  bitstream    settings ("bitstream") assembly
  interpreter  conventional execution: compile-once overlay, settings as data
  specialize   parameterized execution: constant-propagated specialization
  pixie        the top-level accelerator facade (timed stages)
  analysis     HLO resource census (Table I analogue)
  applications Sobel & friends (paper Sec. IV demonstrator)
"""

from repro.core.bitstream import VCGRAConfig, assemble
from repro.core.dfg import DFG, InRef, NodeRef, reference_eval
from repro.core.grid import GridSpec, for_dfg, paper_4x4, rectangular, sobel_grid
from repro.core.ingest import IngestError, IngestPlan, plan_for, tap_offsets
from repro.core.ops import Op
from repro.core.pixie import Pixie, map_app, sobel_pixie
from repro.core.plan import OverlayExecutable, OverlayPlan, compile_plan, register_executor
from repro.parallel.axes import MeshSpec
from repro.core.place import Placement, PlacementError, level_demand, place
from repro.core.route import Routing, RoutingError, route
from repro.core.synthesis import SOBEL_SOURCE, synthesize

__all__ = [
    "DFG", "InRef", "NodeRef", "reference_eval",
    "GridSpec", "for_dfg", "paper_4x4", "rectangular", "sobel_grid",
    "IngestError", "IngestPlan", "plan_for", "tap_offsets",
    "MeshSpec",
    "Op", "OverlayExecutable", "OverlayPlan", "compile_plan", "register_executor",
    "Pixie", "map_app", "sobel_pixie",
    "Placement", "PlacementError", "level_demand", "place",
    "Routing", "RoutingError", "route",
    "VCGRAConfig", "assemble",
    "SOBEL_SOURCE", "synthesize",
]
