"""IngestPlan: how each memory-VC channel is *produced* from a raw frame.

The paper's hardware streams stencil taps from line buffers straight into
the top memory-interface VC; the software analogue used to be a two-step
host-side path (``applications.stencil_inputs`` + ``interpreter.pack_inputs``)
issuing ~20 small un-jitted device ops per frame.  This module records, at
map time, the *production rule* for every channel of an application:

  tap (dj, di)   gathered from the raw image by a shifted slice
                 (the line-buffer read)
  const          a burned-in coefficient value
  zero           an unused (padding) channel of the grid's memory VC

so the whole ingest can move inside the jitted overlay dispatch
(a fused :class:`repro.core.plan.OverlayPlan`).  Crucially the plan compiles to
**runtime settings arrays**, not trace-time structure: the fused executable
forms one tap bank per frame from trace-time-constant offsets (static
slices -- see DESIGN.md "Fused device-side ingest"), and each channel
*selects* its producer from that bank exactly like a VC mux select.  Any
application mapped on a grid therefore shares one executable, fused or not.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


#: Ingest pipelining modes an :class:`repro.core.plan.OverlayPlan` (and the
#: fleet scheduler) may name.  "sync" packs, dispatches and materializes in
#: strict order; "async" double-buffers: frames are embedded into a reused
#: canvas pool, shipped with ``jax.device_put`` into a donated operand, and
#: outputs are unpacked lazily so packing of flush k+1 overlaps the device
#: execution of flush k.  Both modes are bitwise-identical.
INGEST_MODES = ("sync", "async")


def check_ingest(mode: str) -> str:
    """Validate (and return) an ingest mode; shared by every layer that
    takes the ingest axis (plan, fleet, front-end)."""
    if mode not in INGEST_MODES:
        raise ValueError(
            f"unknown ingest mode {mode!r}; expected one of {INGEST_MODES}"
        )
    return mode


def _trust_is_ready(leaves) -> bool:
    """Is ``jax.Array.is_ready()`` a truthful completion signal for these
    arrays?  XLA:CPU's is optimistic -- it reports ready while the
    async-dispatched computation is still running -- so only non-CPU
    placements are trusted (and anything that is not a jax array at all,
    e.g. eager numpy, is trivially ready)."""
    for leaf in leaves:
        devices = getattr(leaf, "devices", None)
        if devices is None:
            continue
        try:
            if any(d.platform == "cpu" for d in devices()):
                return False
        except Exception:
            return False
    return True


class ReadinessProbe:
    """Truthful zero-timeout readiness check for an in-flight computation.

    ``FleetStats.ingest_overlap_s`` needs to know whether the previous
    dispatch was *actually* still executing while the next flush packed its
    inputs.  ``jax.Array.is_ready()`` cannot be trusted for that on every
    backend (see :func:`_trust_is_ready`), but ``jax.block_until_ready``
    is truthful everywhere -- so on untrusted platforms the probe parks a
    daemon watcher thread on the value and flips an event when the real
    wait returns; :meth:`ready` is then a zero-timeout event check.  On
    trusted platforms the thread is skipped and ``is_ready`` is consulted
    directly (no thread churn on the TPU serving path).

    The probe holds a reference to ``value`` until :meth:`ready` first
    observes completion, mirroring the buffer-pinning behavior of the old
    optimistic check; callers drop the probe once it reports ready.
    """

    def __init__(self, value, trust_is_ready: Optional[bool] = None):
        self._leaves = jax.tree_util.tree_leaves(value)
        if trust_is_ready is None:
            trust_is_ready = _trust_is_ready(self._leaves)
        self._event: Optional[threading.Event] = None
        if trust_is_ready:
            return
        self._event = threading.Event()
        watcher = threading.Thread(
            target=self._watch, name="pixie-readiness-probe", daemon=True
        )
        watcher.start()

    def _watch(self) -> None:
        try:
            jax.block_until_ready(self._leaves)
        except Exception:
            # A failed computation is "done" for overlap accounting; the
            # dispatch path re-raises the real error on its own read.
            pass
        self._event.set()

    def ready(self) -> bool:
        """Zero-timeout truthful poll: has the computation completed?"""
        if self._event is not None:
            done = self._event.is_set()
        else:
            done = True
            for leaf in self._leaves:
                is_ready = getattr(leaf, "is_ready", None)
                if callable(is_ready) and not is_ready():
                    done = False
                    break
        if done:
            self._leaves = ()  # release the pinned buffers
        return done

    def wait(self, timeout: Optional[float] = None) -> bool:
        """Block (at most ``timeout`` seconds) until completion; returns
        whether the computation finished within the wait."""
        if self._event is not None:
            done = self._event.wait(timeout)
        else:
            jax.block_until_ready(self._leaves)
            done = True
        if done:
            self._leaves = ()
        return done


def tap_offsets(radius: int) -> Tuple[Tuple[int, int], ...]:
    """Canonical tap-bank layout for a stencil radius: all (dj, di) offsets
    in row-major order.  Every plan built for the same radius indexes the
    same bank, which is what lets N different apps stack into one fused
    dispatch."""
    r = int(radius)
    return tuple(
        (dj, di) for dj in range(-r, r + 1) for di in range(-r, r + 1)
    )


def _tap_lookup(radius: int) -> Dict[str, int]:
    # Inverse of applications.tap_name without importing it (applications
    # imports nothing from here, but keep the dependency one-way anyway).
    return {
        f"p{dj + 1}{di + 1}": t
        for t, (dj, di) in enumerate(tap_offsets(radius))
    }


class IngestError(ValueError):
    """A channel cannot be produced from a raw image (not a tap, not a
    const) -- the app needs the unfused named-channel path."""


@dataclasses.dataclass
class IngestPlan:
    """Channel-production settings for one app on one grid.

    ``tap_sel[c]``: index into the fused tap bank for channel ``c``.  The
    bank holds ``num_taps`` shifted views plus one trailing zero row;
    channels selecting the zero row take ``const_vals[c]`` verbatim (0 for
    grid-padding channels).  Both arrays span the *grid's* full memory-VC
    width, so the fused path needs no separate ``pad_channels`` step.
    """

    radius: int
    tap_sel: np.ndarray      # int32 [num_inputs]
    const_vals: np.ndarray   # float64 [num_inputs]; cast to grid dtype at use
    channel_names: Tuple[str, ...] = ()

    @property
    def num_taps(self) -> int:
        return (2 * self.radius + 1) ** 2

    @property
    def zero_row(self) -> int:
        return self.num_taps

    def to_jax(self, dtype):
        return jnp.asarray(self.tap_sel), jnp.asarray(self.const_vals, dtype)

    @staticmethod
    def stack(plans: Sequence["IngestPlan"], dtype):
        """Stack N same-radius plans into batched settings arrays
        ``(tap_sel: [N, C], const_vals: [N, C])`` -- the ingest analogue of
        ``VCGRAConfig.stack``."""
        if not plans:
            raise ValueError("cannot stack an empty plan list")
        r0, w0 = plans[0].radius, plans[0].tap_sel.shape[0]
        for p in plans[1:]:
            if p.radius != r0 or p.tap_sel.shape[0] != w0:
                raise ValueError(
                    f"ingest plan (radius={p.radius}, width={p.tap_sel.shape[0]}) "
                    f"does not match the stack's (radius={r0}, width={w0})"
                )
        return (
            jnp.stack([jnp.asarray(p.tap_sel) for p in plans]),
            jnp.stack([jnp.asarray(p.const_vals, dtype) for p in plans]),
        )

    def at_radius(self, radius: int) -> "IngestPlan":
        """Re-plan the same channel production rules against a different
        tap-bank radius.

        Pipeline stages (``repro.core.plan.PipelineSpec``) may mix radii --
        a 3x3 blur feeding a pointwise threshold wants a radius-0 bank for
        the second stage, not a 9-tap bank it reads one row of.  Each tap
        channel is translated by its *(dj, di)* offset into the new bank's
        row-major layout; const and zero channels are radius-independent.
        Raises :class:`IngestError` when a channel reads a tap out of the
        new radius's reach (shrinking below the app's stencil is a mapping
        error, not something to silently zero-fill)."""
        r = int(radius)
        if r == self.radius:
            return self
        offsets = tap_offsets(self.radius)
        lookup = {off: t for t, off in enumerate(tap_offsets(r))}
        zero = len(lookup)
        tap_sel = np.full((self.tap_sel.shape[0],), zero, dtype=np.int32)
        for c, t in enumerate(self.tap_sel):
            if int(t) == self.zero_row:
                continue
            off = offsets[int(t)]
            if off not in lookup:
                name = (
                    self.channel_names[c]
                    if c < len(self.channel_names) else f"#{c}"
                )
                raise IngestError(
                    f"channel {name!r} reads tap {off}, out of reach of a "
                    f"radius-{r} bank"
                )
            tap_sel[c] = lookup[off]
        return IngestPlan(
            radius=r, tap_sel=tap_sel, const_vals=self.const_vals.copy(),
            channel_names=self.channel_names,
        )

    # -- (de)serialization (rides along inside VCGRAConfig.to_json) ---------

    def to_dict(self) -> dict:
        return {
            "radius": self.radius,
            "tap_sel": self.tap_sel.tolist(),
            "const_vals": self.const_vals.tolist(),
            "channel_names": list(self.channel_names),
        }

    @staticmethod
    def from_dict(d: dict) -> "IngestPlan":
        return IngestPlan(
            radius=int(d["radius"]),
            tap_sel=np.asarray(d["tap_sel"], dtype=np.int32),
            const_vals=np.asarray(d["const_vals"], dtype=np.float64),
            channel_names=tuple(d.get("channel_names", ())),
        )


def plan_for(
    input_order: Sequence[str],
    const_values: Dict[str, float],
    num_inputs: int,
    radius: int = 1,
) -> IngestPlan:
    """Build the production plan for an image-fed application.

    Mirrors ``pack_inputs``'s precedence exactly: a name that is a stencil
    tap is fed from the image (even if it also has a const default), a name
    with a const default is burned in, anything else raises
    :class:`IngestError` (the app needs named channels, not a frame).
    Channels beyond ``len(input_order)`` up to the grid's memory-VC width
    are zero rows.
    """
    if len(input_order) > num_inputs:
        raise ValueError(
            f"app uses {len(input_order)} input channels, grid has {num_inputs}"
        )
    lookup = _tap_lookup(radius)
    zero = len(lookup)
    tap_sel = np.full((num_inputs,), zero, dtype=np.int32)
    const_vals = np.zeros((num_inputs,), dtype=np.float64)
    for c, name in enumerate(input_order):
        if name in lookup:
            tap_sel[c] = lookup[name]
        elif name in const_values:
            const_vals[c] = float(const_values[name])
        else:
            raise IngestError(
                f"channel {name!r} is neither a radius-{radius} stencil tap "
                f"nor a const; it cannot be produced from a raw image"
            )
    names = tuple(input_order) + ("<pad>",) * (num_inputs - len(input_order))
    return IngestPlan(
        radius=radius, tap_sel=tap_sel, const_vals=const_vals,
        channel_names=names,
    )
