"""Dataflow-graph IR for Pixie applications.

The paper's toolchain input is "the data-flow graph of an application.
Nodes of a graph represent the processing element functions, while edges
show the dependencies and the dataflow between the processing elements"
(Sec. III).  External inputs are the pixel values (blue nodes in Fig. 4)
and the filter coefficients (red nodes); operations are gray nodes; the
green node is the output.

Coefficients are modelled as *const inputs*: they enter through the memory
interface VC like any input, but they carry a default value and change far
less often than pixel data — which makes them "parameters" in the
parameterized-configuration sense and therefore candidates for baking in
the specialized execution path (see ``core/specialize.py``).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple, Union

from repro.core.ops import Op, SCHEDULABLE_OPS, UNARY_OPS


@dataclasses.dataclass(frozen=True)
class InRef:
    """Reference to an external (memory-interface) input by name."""

    name: str


@dataclasses.dataclass(frozen=True)
class NodeRef:
    """Reference to the output of an op node by index."""

    idx: int


Ref = Union[InRef, NodeRef]


@dataclasses.dataclass(frozen=True)
class Node:
    op: Op
    a: Ref
    b: Optional[Ref]  # None only for unary ops


class DFG:
    """A Pixie application graph with a small builder API.

    >>> g = DFG("demo")
    >>> x, y = g.input("x"), g.input("y")
    >>> g.output(g.add(g.mul(x, x), y))
    """

    def __init__(self, name: str):
        self.name = name
        self.inputs: List[str] = []
        self.const_values: Dict[str, float] = {}
        self.nodes: List[Node] = []
        self.outputs: List[Ref] = []

    # -- builders ---------------------------------------------------------

    def input(self, name: str) -> InRef:
        if name in self.inputs:
            raise ValueError(f"duplicate input {name!r}")
        self.inputs.append(name)
        return InRef(name)

    def const(self, name: str, value: float) -> InRef:
        """A coefficient input: enters through the memory VC with a default
        value; infrequently changing, hence a specialization parameter."""
        ref = self.input(name)
        self.const_values[name] = float(value)
        return ref

    def add_node(self, op: Op, a: Ref, b: Optional[Ref] = None) -> NodeRef:
        op = Op(op)
        if op not in SCHEDULABLE_OPS:
            raise ValueError(f"{op.name} is not schedulable on the grid")
        if op in UNARY_OPS:
            b = a if b is None else b
        elif b is None:
            raise ValueError(f"{op.name} needs two operands")
        for r in (a, b):
            self._check_ref(r)
        self.nodes.append(Node(op, a, b))
        return NodeRef(len(self.nodes) - 1)

    def add(self, a: Ref, b: Ref) -> NodeRef:
        return self.add_node(Op.ADD, a, b)

    def sub(self, a: Ref, b: Ref) -> NodeRef:
        return self.add_node(Op.SUB, a, b)

    def mul(self, a: Ref, b: Ref) -> NodeRef:
        return self.add_node(Op.MUL, a, b)

    def div(self, a: Ref, b: Ref) -> NodeRef:
        return self.add_node(Op.DIV, a, b)

    def gt(self, a: Ref, b: Ref) -> NodeRef:
        return self.add_node(Op.GT, a, b)

    def eq(self, a: Ref, b: Ref) -> NodeRef:
        return self.add_node(Op.EQ, a, b)

    def buf(self, a: Ref) -> NodeRef:
        return self.add_node(Op.BUF, a)

    def maximum(self, a: Ref, b: Ref) -> NodeRef:
        return self.add_node(Op.MAX, a, b)

    def minimum(self, a: Ref, b: Ref) -> NodeRef:
        return self.add_node(Op.MIN, a, b)

    def absolute(self, a: Ref) -> NodeRef:
        return self.add_node(Op.ABS, a)

    def output(self, ref: Ref) -> None:
        self._check_ref(ref)
        self.outputs.append(ref)

    # -- queries ----------------------------------------------------------

    def _check_ref(self, r: Ref) -> None:
        if isinstance(r, InRef):
            if r.name not in self.inputs:
                raise ValueError(f"unknown input {r.name!r}")
        elif isinstance(r, NodeRef):
            if not (0 <= r.idx < len(self.nodes)):
                raise ValueError(f"unknown node {r.idx}")
        else:
            raise TypeError(f"bad ref {r!r}")

    def validate(self) -> None:
        if not self.outputs:
            raise ValueError(f"DFG {self.name!r}: no outputs")
        for n in self.nodes:
            self._check_ref(n.a)
            self._check_ref(n.b)
        # Builder order guarantees acyclicity (a node may only reference
        # earlier nodes), assert it anyway:
        for i, n in enumerate(self.nodes):
            for r in (n.a, n.b):
                if isinstance(r, NodeRef) and r.idx >= i:
                    raise ValueError(f"node {i} references later node {r.idx}")

    def asap_levels(self) -> List[int]:
        """ASAP levelization: level(node) = 1 + max(level(preds)); external
        inputs live at level -1 (the memory-interface VC feeds level 0).

        Data flows strictly top-to-bottom (paper Fig. 2), so this is the
        earliest pipeline stage each op can execute in.
        """
        levels: List[int] = []
        for n in self.nodes:
            lp = -1
            for r in (n.a, n.b):
                if isinstance(r, NodeRef):
                    lp = max(lp, levels[r.idx])
            levels.append(lp + 1)
        return levels

    def depth(self) -> int:
        lv = self.asap_levels()
        return (max(lv) + 1) if lv else 0

    def num_ops(self) -> int:
        return len(self.nodes)

    def op_histogram(self) -> Dict[str, int]:
        h: Dict[str, int] = {}
        for n in self.nodes:
            h[n.op.name] = h.get(n.op.name, 0) + 1
        return h

    def structural_hash(self) -> str:
        """Stable content hash of the graph (name, inputs, consts, nodes,
        outputs).  Two DFGs with equal hashes map to identical settings on
        a given grid, so the hash is the cache key that lets a multi-tenant
        runtime skip place/route for repeat tenants (see runtime/fleet.py).

        The preimage is JSON, not delimiter-joined strings: names may
        contain any character without creating cross-field collisions."""
        import hashlib
        import json

        def ref_key(r: Optional[Ref]):
            if r is None:
                return None
            if isinstance(r, InRef):
                return ["i", r.name]
            return ["n", r.idx]

        doc = {
            "name": self.name,
            "inputs": self.inputs,
            "consts": {k: self.const_values[k] for k in sorted(self.const_values)},
            "nodes": [[n.op.name, ref_key(n.a), ref_key(n.b)] for n in self.nodes],
            "outputs": [ref_key(r) for r in self.outputs],
        }
        blob = json.dumps(doc, sort_keys=True, separators=(",", ":"))
        return hashlib.sha256(blob.encode()).hexdigest()

    def consumers(self) -> Dict[Ref, List[int]]:
        out: Dict[Ref, List[int]] = {}
        for i, n in enumerate(self.nodes):
            for r in {n.a, n.b}:
                out.setdefault(r, []).append(i)
        return out

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (
            f"DFG({self.name!r}, inputs={len(self.inputs)}, "
            f"nodes={len(self.nodes)}, outputs={len(self.outputs)}, "
            f"depth={self.depth()})"
        )


def reference_eval(
    dfg: DFG, inputs: Dict[str, "object"]
) -> List["object"]:
    """Pure-Python/numpy oracle evaluation of a DFG (used by tests and as
    the semantic ground truth for the interpreter/specializer/kernels)."""
    import numpy as np

    env: Dict[str, object] = {}
    for name in dfg.inputs:
        if name in inputs:
            env[name] = np.asarray(inputs[name])
        elif name in dfg.const_values:
            env[name] = np.asarray(dfg.const_values[name])
        else:
            raise KeyError(f"missing input {name!r}")

    def get(r: Ref):
        if isinstance(r, InRef):
            return env[r.name]
        return vals[r.idx]

    vals: List[object] = []
    for n in dfg.nodes:
        a = get(n.a)
        b = get(n.b)
        if n.op == Op.ADD:
            v = a + b
        elif n.op == Op.SUB:
            v = a - b
        elif n.op == Op.MUL:
            v = a * b
        elif n.op == Op.DIV:
            if np.issubdtype(np.asarray(a).dtype, np.integer):
                v = np.where(b == 0, 0, a // np.where(b == 0, 1, b))
            else:
                v = np.where(b == 0, 0.0, a / np.where(b == 0, 1.0, b))
        elif n.op == Op.GT:
            v = (a > b).astype(np.asarray(a).dtype)
        elif n.op == Op.EQ:
            v = (a == b).astype(np.asarray(a).dtype)
        elif n.op == Op.BUF:
            v = a
        elif n.op == Op.MAX:
            v = np.maximum(a, b)
        elif n.op == Op.MIN:
            v = np.minimum(a, b)
        elif n.op == Op.ABS:
            v = np.abs(a)
        else:  # pragma: no cover
            raise ValueError(n.op)
        vals.append(v)
    return [get(r) for r in dfg.outputs]
