from repro.train.loop import LoopConfig, train_loop
from repro.train.step import init_train_state, make_train_step, train_step

__all__ = [
    "LoopConfig", "train_loop", "init_train_state", "make_train_step",
    "train_step",
]
