"""Train-step factory: value_and_grad -> clip -> AdamW, jitted with the
arch's sharding plan (params TP + ZeRO-1 moments), donated buffers, and
optional error-feedback int8 gradient compression on the DP axis."""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.lm import LM
from repro.optim import (
    AdamWConfig, adamw_update, compress, decompress, init_opt_state,
)
from repro.parallel.sharding import ShardingPlan


def make_loss_fn(lm: LM):
    def loss_fn(params, tokens, prefix_embeds):
        loss, metrics = lm.loss(params, tokens, prefix_embeds)
        return loss, metrics

    return loss_fn


def train_step(
    lm: LM,
    opt_cfg: AdamWConfig,
    params,
    opt_state,
    tokens,
    prefix_embeds=None,
    grad_compress: bool = False,
    err_state=None,
):
    """One full training step (pure; jitted by the factory below)."""
    (loss, metrics), grads = jax.value_and_grad(
        make_loss_fn(lm), has_aux=True
    )(params, tokens, prefix_embeds)

    if grad_compress:
        comp, err_state = compress(grads, err_state)
        grads = decompress(comp)

    params, opt_state, om = adamw_update(opt_cfg, params, grads, opt_state)
    out_metrics = {"loss": loss, **metrics, **om}
    if grad_compress:
        return params, opt_state, err_state, out_metrics
    return params, opt_state, out_metrics


def make_train_step(
    lm: LM,
    plan: ShardingPlan,
    opt_cfg: AdamWConfig,
    grad_compress: bool = False,
    with_shardings: bool = True,
):
    """Returns (jitted_step, in_shardings_tuple).

    jitted signature: (params, opt_state[, err_state], tokens[, prefix]) ->
    (params', opt_state'[, err'], metrics); params/opt donated.
    """
    cfg = lm.cfg
    mesh = plan.mesh
    abstract = lm.abstract_params()
    pspecs = plan.param_specs(abstract)
    ospecs = plan.opt_specs(abstract)

    def fn(params, opt_state, tokens, prefix_embeds=None, err_state=None):
        return train_step(
            lm, opt_cfg, params, opt_state, tokens, prefix_embeds,
            grad_compress=grad_compress, err_state=err_state,
        )

    if not with_shardings:
        return jax.jit(
            functools.partial(
                train_step, lm, opt_cfg, grad_compress=grad_compress
            ),
            static_argnames=(),
        ), None

    ns = lambda s: NamedSharding(mesh, s)
    in_sh = [
        jax.tree_util.tree_map(ns, pspecs, is_leaf=lambda x: isinstance(x, P)),
        jax.tree_util.tree_map(ns, ospecs, is_leaf=lambda x: isinstance(x, P)),
        ns(plan.batch_spec(2)),                       # tokens
    ]
    args = 3
    if cfg.modality == "vision_stub":
        in_sh.append(ns(plan.batch_spec(3)))          # prefix embeds
        args = 4
    if grad_compress:
        in_sh.append(in_sh[0])                        # err tree ~ param specs

    jitted = jax.jit(
        fn,
        in_shardings=tuple(in_sh),
        donate_argnums=(0, 1),
    )
    return jitted, tuple(in_sh)


def init_train_state(lm: LM, plan: Optional[ShardingPlan], seed: int = 0):
    """Initialise (params, opt_state), placed per plan when given."""
    params = lm.init(jax.random.PRNGKey(seed))
    opt_state = init_opt_state(params)
    if plan is not None:
        pspec = plan.param_shardings(params)
        params = jax.tree_util.tree_map(jax.device_put, params, pspec)
        ospec = plan.opt_specs(params)
        ns = lambda s: NamedSharding(plan.mesh, s)
        osh = jax.tree_util.tree_map(ns, ospec, is_leaf=lambda x: isinstance(x, P))
        opt_state = jax.tree_util.tree_map(jax.device_put, opt_state, osh)
    return params, opt_state
