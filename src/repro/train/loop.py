"""The training loop: data -> step -> heartbeat -> checkpoint -> resume.

Fault-tolerance behaviour (tested in tests/test_fault_tolerance.py):
* resumes from the newest committed checkpoint (crash-restart protocol);
* checkpoints asynchronously every ``ckpt_every`` steps;
* heartbeat monitor flags straggler steps and calls the mitigation hook;
* deterministic data pipeline keyed by the global step -- no loader state.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint import Checkpointer
from repro.data import TokenPipeline
from repro.models.lm import LM
from repro.optim import AdamWConfig, init_opt_state
from repro.runtime import HeartbeatMonitor, resume_or_init
from repro.train.step import make_train_step


@dataclasses.dataclass
class LoopConfig:
    steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    log_every: int = 10
    seed: int = 0
    straggler_hook: Optional[Callable[[int, float], None]] = None


def train_loop(
    lm: LM,
    loop_cfg: LoopConfig,
    opt_cfg: AdamWConfig,
    pipeline: TokenPipeline,
    plan=None,
    prefix_embed_fn: Optional[Callable[[int], np.ndarray]] = None,
) -> Dict[str, List[float]]:
    """Run `loop_cfg.steps` steps; returns the metric history."""
    step_fn, _ = make_train_step(lm, plan, opt_cfg) if plan is not None else (
        jax.jit(
            lambda p, o, t, pe=None: _plain_step(lm, opt_cfg, p, o, t, pe)
        ),
        None,
    )

    def init_fn():
        params = lm.init(jax.random.PRNGKey(loop_cfg.seed))
        return {"params": params, "opt": init_opt_state(params)}

    ckpt = Checkpointer(loop_cfg.ckpt_dir) if loop_cfg.ckpt_dir else None
    if ckpt is not None:
        state = resume_or_init(ckpt, init_fn)
        start, tree = state.step, state.tree
    else:
        start, tree = 0, init_fn()
    params, opt_state = tree["params"], tree["opt"]

    monitor = HeartbeatMonitor()
    history: Dict[str, List[float]] = {"loss": [], "step": [], "dt": []}
    tokens_per_step = pipeline.global_batch * pipeline.seq_len
    last_saved = start if ckpt is not None else None

    for step in range(start, loop_cfg.steps):
        batch = jax.numpy.asarray(pipeline.batch_at(step))
        pe = None
        if prefix_embed_fn is not None:
            pe = jax.numpy.asarray(prefix_embed_fn(step))
        monitor.start()
        if pe is not None:
            params, opt_state, metrics = step_fn(params, opt_state, batch, pe)
        else:
            params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        dt = monitor.stop(step)
        if monitor.stragglers and monitor.stragglers[-1][0] == step:
            if loop_cfg.straggler_hook:
                loop_cfg.straggler_hook(step, dt)
        history["loss"].append(loss)
        history["step"].append(step)
        history["dt"].append(dt)
        if loop_cfg.log_every and step % loop_cfg.log_every == 0:
            tps = tokens_per_step / max(dt, 1e-9)
            print(
                f"step {step:5d}  loss {loss:.4f}  "
                f"grad_norm {float(metrics['grad_norm']):.3f}  "
                f"{tps:,.0f} tok/s"
            )
        if ckpt is not None and (step + 1) % loop_cfg.ckpt_every == 0:
            ckpt.save(step + 1, {"params": params, "opt": opt_state}, blocking=False)
            last_saved = step + 1

    if ckpt is not None:
        ckpt.wait()  # drain the async writer before any final write
        if last_saved != loop_cfg.steps:
            ckpt.save(loop_cfg.steps, {"params": params, "opt": opt_state},
                      blocking=True)
    history["throughput_tok_s"] = [monitor.throughput(tokens_per_step)]
    history["_final"] = [float(history["loss"][-1]) if history["loss"] else float("nan")]
    return history


def _plain_step(lm, opt_cfg, params, opt_state, tokens, pe):
    from repro.train.step import train_step

    return train_step(lm, opt_cfg, params, opt_state, tokens, pe)
