"""Shared primitive layers: RMSNorm, RoPE, MLP variants, embeddings.

Plain-function + pytree-param style (no flax): every layer is an
``init_*(key, ...) -> params`` factory plus a pure ``apply`` function, so
``jax.eval_shape`` over the init gives allocation-free parameter specs for
the dry-run, and scan-stacking is a plain ``jax.vmap`` over init keys.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

Params = Dict[str, jnp.ndarray]


def truncated_normal(key, shape, stddev, dtype=jnp.float32):
    return stddev * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


# -- RMSNorm -------------------------------------------------------------------


def init_rmsnorm(d: int) -> Params:
    return {"scale": jnp.zeros((d,), jnp.float32)}


def rmsnorm(params: Params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * (1.0 + params["scale"])
    return y.astype(dtype)


# -- RoPE ----------------------------------------------------------------------


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [..., S, H, D]; positions: [..., S] (int).  Rotates pairs (d, d+D/2)."""
    D = x.shape[-1]
    half = D // 2
    freq = jnp.exp(-jnp.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq  # [..., S, half]
    cos = jnp.cos(ang)[..., None, :]                       # [..., S, 1, half]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# -- MLP variants ---------------------------------------------------------------


def init_mlp(key, d: int, f: int, mlp_type: str) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = d ** -0.5
    s_out = f ** -0.5
    if mlp_type in ("swiglu", "geglu"):
        return {
            "w_gate": truncated_normal(k1, (d, f), s_in),
            "w_up": truncated_normal(k2, (d, f), s_in),
            "w_down": truncated_normal(k3, (f, d), s_out),
        }
    if mlp_type == "gelu":  # non-gated (starcoder2, musicgen)
        return {
            "w_up": truncated_normal(k1, (d, f), s_in),
            "w_down": truncated_normal(k2, (f, d), s_out),
        }
    raise ValueError(f"unknown mlp_type {mlp_type!r}")


def mlp(params: Params, x: jnp.ndarray, mlp_type: str) -> jnp.ndarray:
    if mlp_type == "gelu":
        h = jax.nn.gelu(x @ params["w_up"])
        return h @ params["w_down"]
    act = jax.nn.silu if mlp_type == "swiglu" else jax.nn.gelu
    g = act(x @ params["w_gate"])
    u = x @ params["w_up"]
    return (g * u) @ params["w_down"]


def mlp_flops(d: int, f: int, mlp_type: str, tokens: int) -> float:
    mats = 2 if mlp_type == "gelu" else 3
    return 2.0 * mats * d * f * tokens


# -- Embedding -------------------------------------------------------------------


def init_embedding(key, vocab: int, d: int, tie: bool) -> Params:
    k1, k2 = jax.random.split(key)
    p = {"table": truncated_normal(k1, (vocab, d), 0.02)}
    if not tie:
        p["unembed"] = truncated_normal(k2, (d, vocab), d ** -0.5)
    return p


def embed(params: Params, tokens: jnp.ndarray, scale: bool, d: int) -> jnp.ndarray:
    x = jnp.take(params["table"], tokens, axis=0)
    if scale:
        x = x * jnp.asarray(d ** 0.5, x.dtype)
    return x


def unembed(params: Params, x: jnp.ndarray) -> jnp.ndarray:
    """Logits in f32 regardless of compute dtype (CE numerics)."""
    w = params.get("unembed")
    if w is not None:
        return jnp.einsum("...d,dv->...v", x, w, preferred_element_type=jnp.float32)
    return jnp.einsum(
        "...d,vd->...v", x, params["table"], preferred_element_type=jnp.float32
    )
