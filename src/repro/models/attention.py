"""GQA/MQA attention: query-chunked training/prefill path + cached decode.

Memory discipline: the training/prefill path never materialises the full
[S, S] score matrix -- queries are processed in ``chunk_q`` blocks via
``lax.scan`` (scores peak at [B, G, Hg, chunk_q, S] f32), which is what
makes 32k-token prefill of the assigned archs fit a 16 GB v5e alongside
remat.  Decode updates the cache with per-sequence dynamic slices and
attends over the full (possibly sequence-sharded) cache.

Masking supports: causal, sliding-window (``window > 0``), and
bidirectional-prefix (PaliGemma-style prefix-LM over ``prefix_len``
leading positions).
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import Params, rope, truncated_normal
from repro.parallel.axes import constrain

NEG_INF = -2.0e38


def pick_chunk(S: int, chunk: int) -> int:
    """Largest divisor of S that is <= chunk (handles meta-token-extended
    sequence lengths that are not powers of two)."""
    c = min(chunk, S)
    while S % c:
        c -= 1
    return c


def init_attention(key, d: int, num_heads: int, num_kv_heads: int, head_dim: int) -> Params:
    """3D weight layout: explicit (heads, head_dim) axes so the sharding
    plan can pick head-sharding (Megatron TP) or head_dim-sharding
    (contraction TP) per architecture without reshape barriers."""
    kq, kk, kv, ko = jax.random.split(key, 4)
    s = d ** -0.5
    so = (num_heads * head_dim) ** -0.5
    G = num_kv_heads
    Hg = num_heads // G
    return {
        "wq": truncated_normal(kq, (d, G, Hg, head_dim), s),
        "wk": truncated_normal(kk, (d, G, head_dim), s),
        "wv": truncated_normal(kv, (d, G, head_dim), s),
        "wo": truncated_normal(ko, (G, Hg, head_dim, d), so),
    }


def _project_qkv(params, x, G, Hg, head_dim, positions, rope_theta):
    """x: [B, S, D] -> q [B,S,G,Hg,hd] (roped), k, v [B,S,G,hd] (k roped)."""
    B, S, _ = x.shape
    q = jnp.einsum("bsd,dghk->bsghk", x, params["wq"])
    k = jnp.einsum("bsd,dgk->bsgk", x, params["wk"])
    v = jnp.einsum("bsd,dgk->bsgk", x, params["wv"])
    q = rope(
        q.reshape(B, S, G * Hg, head_dim), positions, rope_theta
    ).reshape(B, S, G, Hg, head_dim)
    k = rope(k, positions, rope_theta)
    return q, k, v


def _mask(
    pos_q: jnp.ndarray,   # [Sq]
    pos_k: jnp.ndarray,   # [Sk]
    window: int,
    prefix_len: int,
) -> jnp.ndarray:
    """[Sq, Sk] boolean allowed-attention mask."""
    causal = pos_k[None, :] <= pos_q[:, None]
    allowed = causal
    if prefix_len > 0:
        both_prefix = (pos_q[:, None] < prefix_len) & (pos_k[None, :] < prefix_len)
        allowed = allowed | both_prefix
    if window > 0:
        in_window = pos_q[:, None] - pos_k[None, :] < window
        if prefix_len > 0:
            both_prefix = (pos_q[:, None] < prefix_len) & (pos_k[None, :] < prefix_len)
            allowed = allowed & (in_window | both_prefix)
        else:
            allowed = allowed & in_window
    return allowed


def _sdpa(q, k, v, mask):
    """q: [B,Sq,G,Hg,D]  k,v: [B,Sk,G,D]  mask: [Sq,Sk] -> [B,Sq,G,Hg,D]."""
    D = q.shape[-1]
    scores = jnp.einsum(
        "bqghd,bkgd->bghqk", q, k, preferred_element_type=jnp.float32
    ) * (D ** -0.5)
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bghqk,bkgd->bqghd", p.astype(v.dtype), v)
    return out


def attention_train(
    params: Params,
    x: jnp.ndarray,             # [B, S, D]
    *,
    num_heads: int,
    num_kv_heads: int,
    head_dim: int,
    rope_theta: float,
    window: int = 0,
    prefix_len: int = 0,
    chunk_q: int = 512,
    return_kv: bool = False,
    seq_shard: bool = False,
) -> jnp.ndarray:
    """Full-sequence attention (training / prefill), query-chunked.

    ``seq_shard``: sequence-parallel attention for archs whose head counts
    don't divide the model axis (MQA/ragged GQA) -- queries are sharded
    along the sequence over 'model' (replicated weights, gathered K/V), so
    attention compute parallelises across the TP axis without the
    [Sq, Sk]-score all-reduce of contraction TP.  Costs one [B, S, D]
    gather per layer; see EXPERIMENTS.md §Perf.
    """
    B, S, _ = x.shape
    G = num_kv_heads
    Hg = num_heads // G
    positions = jnp.arange(S)

    q, k, v = _project_qkv(params, x, G, Hg, head_dim, positions[None], rope_theta)
    if seq_shard:
        # keys/values fully gathered (small: G*hd per token); queries
        # sequence-sharded -> scores sharded on Sq, no score collectives.
        k = constrain(k, "batch", None, None, None)
        v = constrain(v, "batch", None, None, None)
        q = constrain(q, "batch", "model", None, None, None)

    cq = pick_chunk(S, chunk_q)
    n_chunks = S // cq

    # banded K/V: a sliding-window chunk only sees the last (window + cq)
    # keys -- slicing the band cuts score compute/memory from O(cq*S) to
    # O(cq*(window+cq)) for the local layers (gemma3 5:1, hymba; §Perf)
    band = window + cq
    use_band = window > 0 and prefix_len == 0 and band < S and n_chunks > 1

    if n_chunks == 1:
        out = _sdpa(q, k, v, _mask(positions, positions, window, prefix_len))
    else:
        qc = q.reshape(B, n_chunks, cq, G, Hg, head_dim)

        def body(carry, inp):
            i, qb = inp
            pos_q = i * cq + jnp.arange(cq)
            if use_band:
                start = jnp.clip(i * cq - window, 0, S - band)
                kb = jax.lax.dynamic_slice_in_dim(k, start, band, axis=1)
                vb = jax.lax.dynamic_slice_in_dim(v, start, band, axis=1)
                pos_k = start + jnp.arange(band)
            else:
                kb, vb, pos_k = k, v, positions
            mask = _make_dynamic_mask(pos_q, pos_k, window, prefix_len)
            ob = _sdpa(qb, kb, vb, mask)
            return carry, ob

        # remat: recompute the per-chunk scores/softmax in backward instead
        # of saving [B, Hq, cq, S] f32 residuals per chunk (~8 GB/layer).
        body = jax.checkpoint(body, prevent_cse=False)
        _, out = jax.lax.scan(
            body, None, (jnp.arange(n_chunks), qc.swapaxes(0, 1))
        )
        out = out.swapaxes(0, 1).reshape(B, S, G, Hg, head_dim)

    y = jnp.einsum("bsghk,ghkd->bsd", out, params["wo"])
    if seq_shard:
        y = constrain(y, "batch", None, None)
    if return_kv:
        return y, (k, v)
    return y


def _make_dynamic_mask(pos_q, pos_k, window: int, prefix_len: int):
    """Same rule as `_mask` but with traced query positions (scan body)."""
    causal = pos_k[None, :] <= pos_q[:, None]
    allowed = causal
    if prefix_len > 0:
        both_prefix = (pos_q[:, None] < prefix_len) & (pos_k[None, :] < prefix_len)
        allowed = allowed | both_prefix
        if window > 0:
            in_window = pos_q[:, None] - pos_k[None, :] < window
            allowed = allowed & (in_window | both_prefix)
    elif window > 0:
        allowed = allowed & (pos_q[:, None] - pos_k[None, :] < window)
    return allowed


def attention_decode(
    params: Params,
    x: jnp.ndarray,                       # [B, 1, D] current-token activations
    cache: Tuple[jnp.ndarray, jnp.ndarray],  # k,v: [B, S, G, hd]
    lengths: jnp.ndarray,                 # [B] current cache fill (== position)
    *,
    num_heads: int,
    num_kv_heads: int,
    head_dim: int,
    rope_theta: float,
    window: int = 0,
) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """One-token decode over a KV cache; returns (y, updated cache)."""
    B, _, _ = x.shape
    G = num_kv_heads
    Hg = num_heads // G
    k_cache, v_cache = cache
    S = k_cache.shape[1]

    q, k_new, v_new = _project_qkv(
        params, x, G, Hg, head_dim, lengths[:, None], rope_theta
    )

    def upd(c, new, l):
        return jax.lax.dynamic_update_slice(c, new.astype(c.dtype), (l, 0, 0))

    k_cache = jax.vmap(upd)(k_cache, k_new, lengths)
    v_cache = jax.vmap(upd)(v_cache, v_new, lengths)

    pos_k = jnp.arange(S)
    scores = jnp.einsum(
        "bqghd,bkgd->bghqk", q, k_cache, preferred_element_type=jnp.float32
    ) * (head_dim ** -0.5)
    valid = pos_k[None, :] <= lengths[:, None]                  # [B, S]
    if window > 0:
        valid = valid & (lengths[:, None] - pos_k[None, :] < window)
    scores = jnp.where(valid[:, None, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bghqk,bkgd->bqghd", p.astype(v_cache.dtype), v_cache)
    y = jnp.einsum("bsghk,ghkd->bsd", out, params["wo"])
    return y, (k_cache, v_cache)


def attention_decode_ring(
    params: Params,
    x: jnp.ndarray,                          # [B, 1, D]
    cache: Tuple[jnp.ndarray, jnp.ndarray],  # k,v: [B, W, G, hd] ring buffers
    lengths: jnp.ndarray,                    # [B] absolute position
    *,
    num_heads: int,
    num_kv_heads: int,
    head_dim: int,
    rope_theta: float,
) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Sliding-window decode with an O(window) ring-buffer cache.

    The buffer always holds the last ``W`` positions (keys stored
    post-RoPE at absolute positions, so slot order is irrelevant to the
    attention math); the window constraint is enforced *structurally* by
    eviction rather than by masking.
    """
    B = x.shape[0]
    G = num_kv_heads
    Hg = num_heads // G
    k_cache, v_cache = cache
    W = k_cache.shape[1]

    q, k_new, v_new = _project_qkv(
        params, x, G, Hg, head_dim, lengths[:, None], rope_theta
    )

    slots = lengths % W

    def upd(c, new, s):
        return jax.lax.dynamic_update_slice(c, new.astype(c.dtype), (s, 0, 0))

    k_cache = jax.vmap(upd)(k_cache, k_new, slots)
    v_cache = jax.vmap(upd)(v_cache, v_new, slots)

    scores = jnp.einsum(
        "bqghd,bkgd->bghqk", q, k_cache, preferred_element_type=jnp.float32
    ) * (head_dim ** -0.5)
    # slots 0..min(length, W-1) are filled; once wrapped, all are valid.
    valid = jnp.arange(W)[None, :] <= lengths[:, None]
    scores = jnp.where(valid[:, None, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bghqk,bkgd->bqghd", p.astype(v_cache.dtype), v_cache)
    y = jnp.einsum("bsghk,ghkd->bsd", out, params["wo"])
    return y, (k_cache, v_cache)
