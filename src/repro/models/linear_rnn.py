"""Linear-recurrent sequence mixers: chunked gated linear attention (GLA)
core shared by xLSTM's mLSTM and Hymba's SSM heads, plus the sequential
sLSTM.

TPU adaptation (DESIGN.md): instead of porting a GPU selective-scan, the
recurrence

    S_t = f_t * S_{t-1} + i_t * k_t v_t^T        (matrix state per head)
    y_t = q_t . S_t   [optionally / max(|q_t . n_t|, 1)]

is evaluated **chunkwise**: within a chunk the contribution is a masked
quadratic form (two MXU matmuls), across chunks a short ``lax.scan``
carries the [dk, dv] state -- the Mamba-2/SSD & chunked-mLSTM structure,
which keeps the MXU busy and the VMEM working set at O(chunk^2 + dk*dv).

Gate conventions: ``log_f`` (log forget) <= 0 and ``i_gate`` in [0, 1]
(sigmoid), so every chunk weight exp(log-sum) stays in [0, 1] -- stable
without the running-max machinery (a simplification of xLSTM's
exponential gating; recorded in DESIGN.md).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import Params, truncated_normal

GLAState = Tuple[jnp.ndarray, jnp.ndarray]  # S: [B,H,dk,dv], n: [B,H,dk]


def gla_chunked(
    q: jnp.ndarray,        # [B, L, H, dk]
    k: jnp.ndarray,        # [B, L, H, dk]
    v: jnp.ndarray,        # [B, L, H, dv]
    log_f: jnp.ndarray,    # [B, L, H]  (<= 0)
    i_gate: jnp.ndarray,   # [B, L, H]  (in [0, 1])
    state: Optional[GLAState] = None,
    normalize: bool = False,
    chunk: int = 256,
) -> Tuple[jnp.ndarray, GLAState]:
    B, L, H, dk = q.shape
    dv = v.shape[-1]
    c = min(chunk, L)
    while L % c:  # largest divisor of L <= chunk (meta-token raggedness)
        c -= 1
    nc = L // c

    if state is None:
        S0 = jnp.zeros((B, H, dk, dv), jnp.float32)
        n0 = jnp.zeros((B, H, dk), jnp.float32)
    else:
        S0, n0 = state

    def to_chunks(x):
        return x.reshape(B, nc, c, *x.shape[2:]).swapaxes(0, 1)

    qs, ks, vs = to_chunks(q), to_chunks(k), to_chunks(v)
    fs, is_ = to_chunks(log_f), to_chunks(i_gate)

    def body(carry, inp):
        S, n = carry
        qb, kb, vb, fb, ib = inp                    # [B,c,H,*]
        qb = qb.astype(jnp.float32)
        kb = kb.astype(jnp.float32)
        vb = vb.astype(jnp.float32)
        P = jnp.cumsum(fb, axis=1)                  # [B,c,H] inclusive logs
        Ptot = P[:, -1]                             # [B,H]

        # inter-chunk: queries read the carried state, decayed to their slot
        q_dec = qb * jnp.exp(P)[..., None]
        y_inter = jnp.einsum("bthd,bhdv->bthv", q_dec, S)
        n_inter = jnp.einsum("bthd,bhd->bth", q_dec, n)

        # intra-chunk: masked decayed quadratic form
        gap = P[:, :, None, :] - P[:, None, :, :]   # [B,t,s,H]
        tril = jnp.tril(jnp.ones((c, c), bool))
        w = jnp.where(tril[None, :, :, None], jnp.exp(gap) * ib[:, None], 0.0)
        scores = jnp.einsum("bthd,bshd->btsh", qb, kb) * w
        y_intra = jnp.einsum("btsh,bshv->bthv", scores, vb)

        y = y_inter + y_intra

        if normalize:
            # n_t = decayed carry + intra contribution of k's
            kn = jnp.einsum("btsh,bshd->bthd", w, kb)          # sum_s w ks
            qn = jnp.einsum("bthd,bthd->bth", qb, kn) + n_inter
            denom = jnp.maximum(jnp.abs(qn), 1.0)
            y = y / denom[..., None]

        # state update to chunk end
        decay_to_end = jnp.exp(Ptot[:, None] - P) * ib          # [B,c,H]
        k_dec = kb * decay_to_end[..., None]
        S_new = jnp.exp(Ptot)[:, :, None, None] * S + jnp.einsum(
            "bshd,bshv->bhdv", k_dec, vb
        )
        n_new = jnp.exp(Ptot)[:, :, None] * n + k_dec.sum(axis=1)
        return (S_new, n_new), y

    (Sf, nf), ys = jax.lax.scan(body, (S0, n0), (qs, ks, vs, fs, is_))
    y = ys.swapaxes(0, 1).reshape(B, L, H, dv).astype(v.dtype)
    return y, (Sf, nf)


def gla_step(
    q: jnp.ndarray,       # [B, H, dk]
    k: jnp.ndarray,
    v: jnp.ndarray,       # [B, H, dv]
    log_f: jnp.ndarray,   # [B, H]
    i_gate: jnp.ndarray,  # [B, H]
    state: GLAState,
    normalize: bool = False,
) -> Tuple[jnp.ndarray, GLAState]:
    """Single decode step of the same recurrence."""
    S, n = state
    qf, kf, vf = (t.astype(jnp.float32) for t in (q, k, v))
    f = jnp.exp(log_f)[..., None]
    S_new = f[..., None] * S + (i_gate[..., None] * kf)[..., None] * vf[..., None, :]
    n_new = f * n + i_gate[..., None] * kf
    y = jnp.einsum("bhd,bhdv->bhv", qf, S_new)
    if normalize:
        denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n_new)), 1.0)
        y = y / denom[..., None]
    return y.astype(v.dtype), (S_new, n_new)


# -- causal depthwise conv (mLSTM / mamba front-end) ---------------------------


def causal_conv1d(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """x: [B, L, C]; w: [K, C] depthwise causal convolution."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for j in range(K):
        out = out + xp[:, j : j + x.shape[1], :] * w[j]
    return out


def causal_conv1d_step(
    x: jnp.ndarray, w: jnp.ndarray, buf: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Decode step: x [B, C], buf [B, K-1, C] (previous inputs)."""
    K = w.shape[0]
    window = jnp.concatenate([buf, x[:, None]], axis=1)      # [B, K, C]
    y = jnp.einsum("bkc,kc->bc", window, w)
    return y, window[:, 1:]


# -- sLSTM ----------------------------------------------------------------------


def init_slstm(key, d: int, num_heads: int) -> Params:
    kw, kr = jax.random.split(key)
    dh = d // num_heads
    return {
        "w": truncated_normal(kw, (d, 4 * d), d ** -0.5),
        "r": truncated_normal(kr, (num_heads, dh, 4 * dh), dh ** -0.5),
        "b": jnp.zeros((4 * d,), jnp.float32),
    }


def slstm_scan(
    params: Params, x: jnp.ndarray, num_heads: int, state=None
):
    """Sequential sLSTM (paper: not parallelizable by design).

    x: [B, L, D] -> y: [B, L, D]; per-head recurrent gates.
    State: (c, n, h) each [B, H, dh].
    """
    B, L, D = x.shape
    H = num_heads
    dh = D // H
    zx = x @ params["w"] + params["b"]                       # [B, L, 4D]
    zx = zx.reshape(B, L, H, 4 * dh)

    if state is None:
        z0 = jnp.zeros((B, H, dh), jnp.float32)
        state = (z0, z0, z0)

    def body(carry, zt):
        c, n, h = carry
        rec = jnp.einsum("bhd,hde->bhe", h, params["r"])     # [B,H,4dh]
        z, i, f, o = jnp.split(zt + rec, 4, axis=-1)
        z = jnp.tanh(z)
        i = jax.nn.sigmoid(i)
        f = jax.nn.sigmoid(f)
        o = jax.nn.sigmoid(o)
        c = f * c + i * z
        n = f * n + i
        h = o * c / jnp.maximum(n, 1e-6)
        return (c, n, h), h

    state, hs = jax.lax.scan(body, state, zx.swapaxes(0, 1))
    y = hs.swapaxes(0, 1).reshape(B, L, D).astype(x.dtype)
    return y, state


def slstm_step(params: Params, x: jnp.ndarray, num_heads: int, state):
    """x: [B, D] single step."""
    y, st = slstm_scan(params, x[:, None], num_heads, state)
    return y[:, 0], st
