"""Mixture-of-Experts FFN: shared experts + routed top-k with capacity.

DeepSeek-MoE / Qwen2-MoE style: ``num_shared`` always-active experts
(fused into one wide FFN) plus ``num_experts`` routed experts with top-k
token-choice routing.

Dispatch is scatter-based (no [T, E, C] one-hot tensor, no global sort):

  1. router logits -> top-k expert ids + softmaxed weights per token;
  2. position-in-expert via a cumsum over the flattened (token, k) choices;
  3. tokens scattered into an [E * C, D] expert buffer (capacity drop);
  4. batched expert FFN as einsum over the [E, C, D] buffer
     (expert dim sharded over the 'model'/'expert' mesh axis = EP);
  5. gather back + weighted combine; dropped tokens contribute zero.

An auxiliary load-balance loss (Switch-style) is returned for training.
"""

from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MoEConfig
from repro.models.layers import Params, truncated_normal
from repro.parallel.axes import _ambient_mesh, constrain


def init_moe(key, d: int, f: int, moe: MoEConfig, mlp_type: str) -> Params:
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    E = moe.num_experts
    s_in = d ** -0.5
    s_out = f ** -0.5
    p = {
        "router": truncated_normal(kr, (d, E), s_in),
        "w_gate": truncated_normal(kg, (E, d, f), s_in),
        "w_up": truncated_normal(ku, (E, d, f), s_in),
        "w_down": truncated_normal(kd, (E, f, d), s_out),
    }
    if moe.num_shared:
        from repro.models.layers import init_mlp

        p["shared"] = init_mlp(ks, d, f * moe.num_shared, mlp_type)
    return p


def moe_ffn(
    params: Params,
    x: jnp.ndarray,          # [B, S, D]
    moe: MoEConfig,
    mlp_type: str,
    dropless: bool = False,  # decode: capacity = T (no order-dependent drops)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y [B,S,D], aux_loss scalar)."""
    B, S, D = x.shape
    T = B * S
    E, k = moe.num_experts, moe.top_k
    xt = x.reshape(T, D)

    logits = (xt @ params["router"]).astype(jnp.float32)       # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)            # [T, k]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(axis=-1, keepdims=True), 1e-9
    )

    # Switch-style aux load-balance loss.
    me = probs.mean(axis=0)                                    # [E]
    ce = jnp.zeros((E,), jnp.float32)
    ce = ce.at[expert_ids.reshape(-1)].add(1.0) / (T * k)
    aux = moe.router_aux_weight * E * jnp.sum(me * ce)

    # Capacity per expert.
    if dropless:
        C = T  # decode-sized batches: never drop
    else:
        C = int(max(1, round(T * k / E * moe.capacity_factor)))

    # Position of each (token, slot) within its expert: cumsum over the
    # flattened choices of per-expert one-hot occupancy.
    flat_ids = expert_ids.reshape(T * k)                       # [T*k]
    onehot = jax.nn.one_hot(flat_ids, E, dtype=jnp.int32)      # [T*k, E]
    onehot = constrain(onehot, "batch", None)                  # rows ~ tokens
    pos_all = jnp.cumsum(onehot, axis=0) - 1                   # exclusive count
    pos = jnp.take_along_axis(pos_all, flat_ids[:, None], axis=1)[:, 0]
    keep = pos < C                                             # capacity drop

    slot = flat_ids * C + jnp.where(keep, pos, 0)              # [T*k]
    token_idx = jnp.repeat(jnp.arange(T), k)

    # Scatter token activations into the expert buffer [E*C, D].
    contrib = jnp.where(keep[:, None], xt[token_idx], 0.0)
    contrib = constrain(contrib, "batch", None)                # [T*k, D]
    buf = jnp.zeros((E * C, D), x.dtype)
    buf = buf.at[jnp.where(keep, slot, E * C)].add(contrib, mode="drop")
    buf = buf.reshape(E, C, D)

    # Shard the dispatch buffer: experts over 'model' (EP) when divisible,
    # capacity over 'data' always -- without this GSPMD replicates the
    # [E, C, D] buffer (90 GiB/device on qwen2-moe prefill_32k; §Perf).
    mesh = _ambient_mesh()
    if mesh is not None:
        e_axis = "model" if ("model" in mesh.axis_names
                             and E % mesh.shape["model"] == 0) else None
        buf = constrain(buf, e_axis, "batch", None)

    # Batched expert FFN (expert axis -> EP sharding).
    act = jax.nn.silu if mlp_type == "swiglu" else jax.nn.gelu
    g = act(jnp.einsum("ecd,edf->ecf", buf, params["w_gate"]))
    u = jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
    eo = jnp.einsum("ecf,efd->ecd", g * u, params["w_down"])   # [E, C, D]

    # Gather back and combine the k expert outputs per token.
    out_flat = jnp.where(
        keep[:, None], eo.reshape(E * C, D)[slot], 0.0
    )                                                          # [T*k, D]
    out_flat = constrain(out_flat, "batch", None)
    combined = (
        out_flat.reshape(T, k, D) * gate_vals[..., None].astype(x.dtype)
    ).sum(axis=1)

    if "shared" in params:
        from repro.models.layers import mlp

        combined = combined + mlp(params["shared"], xt, mlp_type)
    return combined.reshape(B, S, D), aux


# -- explicit-EP shard_map implementation --------------------------------------
#
# GSPMD's scatter partitioner replicates the [E, C, D] dispatch buffer
# (measured 43 GB f32/device on qwen2 prefill; EXPERIMENTS.md §Perf), so the
# production path dispatches *locally per data shard* under shard_map:
#
#   * routing + scatter run per data shard, replicated over 'model'
#     (identical cheap compute; the scatter is shard-local => no collective);
#   * expert FFN: experts sharded over 'model' when E % |model| == 0
#     (true EP: each rank owns E/|model| experts and masks the rest),
#     otherwise the FFN hidden dim is sharded (F-parallel fallback);
#   * one psum over 'model' combines the partial token outputs.
#
# Collectives per MoE layer: exactly one [T_local, D] all-reduce (+ tiny
# pmeans for the aux loss) -- versus the all-gather storm GSPMD emits.


def _moe_local(
    xt: jnp.ndarray,            # [T_loc, D] this data-shard's tokens
    router: jnp.ndarray,        # [D, E] replicated
    wg: jnp.ndarray,            # [E_loc, D, F] or [E, D, F_loc]
    wu: jnp.ndarray,
    wd: jnp.ndarray,            # [E_loc, F, D] or [E, F_loc, D]
    moe: MoEConfig,
    mlp_type: str,
    ep: bool,                   # True: experts sharded over 'model'
    dropless: bool,
    data_axes: Tuple[str, ...],
):
    T, D = xt.shape
    E, k = moe.num_experts, moe.top_k

    logits = (xt @ router).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    me = jax.lax.pmean(probs.mean(axis=0), data_axes)
    ce_loc = jnp.zeros((E,), jnp.float32).at[expert_ids.reshape(-1)].add(1.0) / (T * k)
    ce = jax.lax.pmean(ce_loc, data_axes)
    aux = moe.router_aux_weight * E * jnp.sum(me * ce)

    C = T if dropless else int(max(1, round(T * k / E * moe.capacity_factor)))

    if ep:
        E_loc = wg.shape[0]
        m_idx = jax.lax.axis_index("model")
        local = (expert_ids // E_loc) == m_idx             # my experts only
        eff_ids = jnp.where(local, expert_ids % E_loc, E_loc)  # E_loc = drop
        n_buckets = E_loc
    else:
        local = jnp.ones_like(expert_ids, dtype=bool)
        eff_ids = expert_ids
        n_buckets = E

    flat_ids = eff_ids.reshape(T * k)
    onehot = (flat_ids[:, None] == jnp.arange(n_buckets)[None, :]).astype(jnp.int32)
    pos = (jnp.cumsum(onehot, axis=0) - 1)
    pos = jnp.take_along_axis(
        pos, jnp.minimum(flat_ids, n_buckets - 1)[:, None], axis=1
    )[:, 0]
    keep = (pos < C) & local.reshape(T * k)

    slot = jnp.where(keep, flat_ids * C + pos, n_buckets * C)
    token_idx = jnp.repeat(jnp.arange(T), k)
    contrib = jnp.where(keep[:, None], xt[token_idx], 0.0)
    buf = jnp.zeros((n_buckets * C, D), xt.dtype)
    buf = buf.at[slot].add(contrib, mode="drop").reshape(n_buckets, C, D)

    act = jax.nn.silu if mlp_type == "swiglu" else jax.nn.gelu
    g = act(jnp.einsum("ecd,edf->ecf", buf, wg))
    u = jnp.einsum("ecd,edf->ecf", buf, wu)
    eo = jnp.einsum("ecf,efd->ecd", g * u, wd)              # [buckets, C, D]

    out_flat = jnp.where(keep[:, None], eo.reshape(-1, D)[slot], 0.0)
    combined = (
        out_flat.reshape(T, k, D) * gate_vals[..., None].astype(xt.dtype)
    ).sum(axis=1)
    combined = jax.lax.psum(combined, "model")
    return combined, aux


def moe_ffn_ep(
    params: Params,
    x: jnp.ndarray,
    moe: MoEConfig,
    mlp_type: str,
    dropless: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """shard_map-EP MoE; falls back to `moe_ffn` when no suitable mesh."""
    from jax.sharding import PartitionSpec as P

    mesh = _ambient_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return moe_ffn(params, x, moe, mlp_type, dropless=dropless)
    m = mesh.shape["model"]
    daxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    B, S, D = x.shape
    B_total = int(np.prod([mesh.shape[a] for a in daxes])) if daxes else 1
    if B % B_total != 0:
        return moe_ffn(params, x, moe, mlp_type, dropless=dropless)
    ep = moe.num_experts % m == 0
    F = params["w_gate"].shape[-1]
    if not ep and F % m != 0:
        return moe_ffn(params, x, moe, mlp_type, dropless=dropless)

    batch_spec = daxes if len(daxes) > 1 else (daxes[0] if daxes else None)
    w_spec = P("model", None, None) if ep else P(None, None, "model")
    wd_spec = P("model", None, None) if ep else P(None, "model", None)

    def per_shard(xb, router, wg, wu, wd):
        T_loc = xb.shape[0] * xb.shape[1]
        y, aux = _moe_local(
            xb.reshape(T_loc, D), router, wg, wu, wd,
            moe, mlp_type, ep, dropless, daxes or ("model",),
        )
        return y.reshape(xb.shape), aux

    if hasattr(jax, "shard_map"):
        smap = functools.partial(jax.shard_map, check_vma=False)
    else:  # jax < 0.5: experimental API, check_rep instead of check_vma
        from jax.experimental.shard_map import shard_map as _shard_map

        smap = functools.partial(_shard_map, check_rep=False)
    y, aux = smap(
        per_shard,
        mesh=mesh,
        in_specs=(
            P(batch_spec, None, None),
            P(None, None),
            w_spec, w_spec, wd_spec,
        ),
        out_specs=(P(batch_spec, None, None), P()),
    )(x, params["router"], params["w_gate"], params["w_up"], params["w_down"])

    if "shared" in params:
        from repro.models.layers import mlp

        y = y + mlp(params["shared"], x, mlp_type)
    return y, aux
