"""The language-model stack: heterogeneous layer patterns under lax.scan.

Parameters live in a plain pytree:

    params = {
      "embed":   embedding table (+ optional unembed),
      "meta":    learned meta tokens [M, D] (hymba), optional,
      "prefix":  tuple of per-layer params for cfg.prefix_pattern (unrolled),
      "blocks":  {f"{j}:{kind}": stacked [n_superblocks, ...] leaves},
      "final_norm": RMSNorm,
    }

Superblocks are scanned (`lax.scan`), so the compiled program contains one
superblock body regardless of depth; remat wraps the scan body.  The same
scan drives decode, carrying the per-superblock cache slices as scan
xs/ys.  Cross-entropy is evaluated in sequence chunks so the [B, S, V]
logit tensor is never materialised (V up to 262k here).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import blocks as blk
from repro.models.layers import (
    embed, init_embedding, init_rmsnorm, rmsnorm, truncated_normal, unembed,
)
from repro.parallel.axes import constrain


def _block_keys(cfg: ArchConfig):
    return [f"{j}:{kind}" for j, kind in enumerate(cfg.pattern)]


def _cast_params(params, dtype):
    """Matmul weights -> compute dtype; 1D scales/biases stay f32 (the
    optimizer keeps the f32 master copy; the cast lives inside the jitted
    step so grads flow back to f32)."""
    if dtype is None:
        return params
    return jax.tree_util.tree_map(
        lambda p: p.astype(dtype)
        if (p.dtype == jnp.float32 and p.ndim >= 2)
        else p,
        params,
    )


@dataclasses.dataclass(frozen=True)
class LM:
    cfg: ArchConfig
    remat: str = "full"          # none | full
    chunk_q: int = 512           # attention query chunk
    loss_chunk: int = 512        # CE vocab-chunking along sequence
    zloss: float = 0.0
    compute_dtype: Optional[object] = jnp.bfloat16  # None => keep f32
    attn_seq_shard: bool = False  # sequence-parallel attention (plan 'seq')
    seq_parallel: bool = True     # Megatron-SP residual stream: the scan
    # carry [B, S, D] (the dominant train-memory term: one per layer) is
    # sharded along S over 'model'; GSPMD inserts the AG/RS pairs at the
    # matmul boundaries (same bytes as the TP psums they replace).

    # -- init -------------------------------------------------------------

    def init(self, key) -> Dict:
        cfg = self.cfg
        k_emb, k_meta, k_pre, k_blk = jax.random.split(key, 4)
        params: Dict = {
            "embed": init_embedding(k_emb, cfg.vocab_size, cfg.d_model, cfg.tie_embeddings),
            "final_norm": init_rmsnorm(cfg.d_model),
        }
        if cfg.meta_tokens:
            params["meta"] = truncated_normal(
                k_meta, (cfg.meta_tokens, cfg.d_model), 0.02
            )
        if cfg.prefix_pattern:
            pre_keys = jax.random.split(k_pre, len(cfg.prefix_pattern))
            params["prefix"] = tuple(
                blk.init_block(k, cfg, kind)
                for k, kind in zip(pre_keys, cfg.prefix_pattern)
            )
        n_sb = cfg.n_superblocks
        sb_keys = jax.random.split(k_blk, len(cfg.pattern))
        blocks = {}
        for j, kind in enumerate(cfg.pattern):
            keys = jax.random.split(sb_keys[j], n_sb)
            blocks[f"{j}:{kind}"] = jax.vmap(
                lambda k, kind=kind: blk.init_block(k, self.cfg, kind)
            )(keys)
        params["blocks"] = blocks
        return params

    def abstract_params(self, seed: int = 0):
        """Allocation-free parameter specs (for the dry-run)."""
        return jax.eval_shape(lambda: self.init(jax.random.PRNGKey(seed)))

    # -- embedding frontend --------------------------------------------------

    def _embed_inputs(
        self,
        params: Dict,
        tokens: jnp.ndarray,                       # [B, S_tok]
        prefix_embeds: Optional[jnp.ndarray],      # [B, P, D] modality stub
    ) -> Tuple[jnp.ndarray, int]:
        cfg = self.cfg
        h = embed(params["embed"], tokens, cfg.scale_embed, cfg.d_model)
        n_prefix = 0
        if prefix_embeds is not None:
            h = jnp.concatenate([prefix_embeds.astype(h.dtype), h], axis=1)
            n_prefix += prefix_embeds.shape[1]
        if cfg.meta_tokens:
            B = tokens.shape[0]
            meta = jnp.broadcast_to(
                params["meta"][None], (B, cfg.meta_tokens, cfg.d_model)
            ).astype(h.dtype)
            h = jnp.concatenate([meta, h], axis=1)
            n_prefix += cfg.meta_tokens
        return h, n_prefix

    # -- full-sequence forward -------------------------------------------------

    def forward(
        self,
        params: Dict,
        tokens: jnp.ndarray,
        prefix_embeds: Optional[jnp.ndarray] = None,
    ) -> Tuple[jnp.ndarray, jnp.ndarray, int]:
        """Returns (hidden [B, S_total, D], aux_loss, n_prefix)."""
        cfg = self.cfg
        params = _cast_params(params, self.compute_dtype)
        h, n_prefix = self._embed_inputs(params, tokens, prefix_embeds)
        h = constrain(h, "batch", None, None)
        aux = jnp.zeros((), jnp.float32)

        prefix_len = n_prefix if cfg.modality == "vision_stub" else 0

        for p, kind in zip(params.get("prefix", ()), cfg.prefix_pattern):
            h, a = blk.block_train(
                p, cfg, kind, h, prefix_len, self.chunk_q, self.attn_seq_shard
            )
            aux = aux + a

        def one_block(hh, p, kind):
            hh, a = blk.block_train(
                p, cfg, kind, hh, prefix_len, self.chunk_q, self.attn_seq_shard
            )
            if self.seq_parallel:
                hh = constrain(hh, "batch", "model", None)
            return hh, a

        if self.remat == "full" and len(cfg.pattern) > 1:
            # per-layer remat inside the superblock: without it, backward
            # keeps a whole 6/8/16-layer body's residuals live at once
            # (hymba: 164 GiB/device measured; see EXPERIMENTS.md §Perf)
            one_block = jax.checkpoint(
                one_block, prevent_cse=False, static_argnums=(2,)
            )

        def sb_body(carry, sb_params):
            hh, ax = carry
            if self.seq_parallel:
                hh = constrain(hh, "batch", "model", None)
            for key, kind in zip(_block_keys(cfg), cfg.pattern):
                hh, a = one_block(hh, sb_params[key], kind)
                ax = ax + a
            return (hh, ax), None

        body = sb_body
        if self.remat == "full":
            body = jax.checkpoint(sb_body, prevent_cse=False)
        (h, aux), _ = jax.lax.scan(body, (h, aux), params["blocks"])
        h = constrain(h, "batch", None, None)
        h = rmsnorm(params["final_norm"], h)
        return h, aux, n_prefix

    # -- training loss -----------------------------------------------------------

    def loss(
        self,
        params: Dict,
        tokens: jnp.ndarray,                      # [B, S_tok]
        prefix_embeds: Optional[jnp.ndarray] = None,
    ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
        """Next-token CE over the token region (prefix/meta positions skipped)."""
        cfg = self.cfg
        h, aux, n_prefix = self.forward(params, tokens, prefix_embeds)
        h_tok = h[:, n_prefix:]                    # align with `tokens`
        B, S, D = h_tok.shape
        h_in = h_tok[:, :-1]
        labels = tokens[:, 1:]

        c = min(self.loss_chunk, S - 1)
        n_full = (S - 1) // c
        tail = (S - 1) - n_full * c

        def ce_chunk(hc, lc):
            logits = unembed(params["embed"], hc)           # f32 [B, c, V]
            # keep the vocab shard: without this constraint GSPMD may
            # all-gather the [B, c, V] logits (tens of GB at 256k vocab)
            logits = constrain(logits, "batch", None, "model")
            lse = jax.nn.logsumexp(logits, axis=-1)
            # one-hot pick (partial-sum friendly on the sharded vocab dim)
            vocab_iota = jnp.arange(logits.shape[-1], dtype=lc.dtype)
            onehot = (lc[..., None] == vocab_iota).astype(logits.dtype)
            gold = (logits * onehot).sum(axis=-1)
            ce = (lse - gold).sum()
            zl = (lse ** 2).sum() * self.zloss
            return ce + zl

        total = jnp.zeros((), jnp.float32)
        if n_full:
            hc = h_in[:, : n_full * c].reshape(B, n_full, c, D).swapaxes(0, 1)
            lc = labels[:, : n_full * c].reshape(B, n_full, c).swapaxes(0, 1)

            def body(acc, inp):
                return acc + ce_chunk(*inp), None

            # remat: recompute the [B, c, V] logits in backward instead of
            # saving them per chunk (V up to 262k => ~0.5 GB/chunk/device)
            body = jax.checkpoint(body, prevent_cse=False)
            total, _ = jax.lax.scan(body, total, (hc, lc))
        if tail:
            total = total + ce_chunk(h_in[:, n_full * c :], labels[:, n_full * c :])

        n_tokens = B * (S - 1)
        loss = total / n_tokens + aux
        return loss, {"ce": total / n_tokens, "aux": aux}

    # -- serving -----------------------------------------------------------------

    def init_cache(self, batch: int, seq: int) -> Dict:
        cfg = self.cfg
        cache: Dict = {}
        if cfg.prefix_pattern:
            cache["prefix"] = tuple(
                blk.init_block_cache(cfg, kind, batch, seq)
                for kind in cfg.prefix_pattern
            )
        n_sb = cfg.n_superblocks
        cache["blocks"] = {
            key: jax.tree_util.tree_map(
                lambda l: jnp.broadcast_to(l[None], (n_sb, *l.shape)).copy(),
                blk.init_block_cache(cfg, kind, batch, seq),
            )
            for key, kind in zip(_block_keys(cfg), cfg.pattern)
        }
        return cache

    def abstract_cache(self, batch: int, seq: int):
        return jax.eval_shape(lambda: self.init_cache(batch, seq))

    def prefill(
        self,
        params: Dict,
        tokens: jnp.ndarray,
        cache_len: int,
        prefix_embeds: Optional[jnp.ndarray] = None,
    ) -> Tuple[jnp.ndarray, Dict, jnp.ndarray]:
        """Run the prompt, build the cache.  Returns (last-token logits,
        cache, lengths)."""
        cfg = self.cfg
        params = _cast_params(params, self.compute_dtype)
        h, n_prefix = self._embed_inputs(params, tokens, prefix_embeds)
        prefix_len = n_prefix if cfg.modality == "vision_stub" else 0
        B, S, _ = h.shape
        cache: Dict = {}

        if cfg.prefix_pattern:
            pcs = []
            for p, kind in zip(params["prefix"], cfg.prefix_pattern):
                h, c = blk.block_prefill(
                    p, cfg, kind, h, cache_len, prefix_len, self.chunk_q,
                    self.attn_seq_shard,
                )
                pcs.append(c)
            cache["prefix"] = tuple(pcs)

        def sb_body(hh, sb_params):
            cs = {}
            for key, kind in zip(_block_keys(cfg), cfg.pattern):
                hh, c = blk.block_prefill(
                    sb_params[key], cfg, kind, hh, cache_len, prefix_len,
                    self.chunk_q, self.attn_seq_shard,
                )
                cs[key] = c
            return hh, cs

        h, cache["blocks"] = jax.lax.scan(sb_body, h, params["blocks"])
        h = rmsnorm(params["final_norm"], h[:, -1:])
        logits = unembed(params["embed"], h).astype(jnp.float32)
        lengths = jnp.full((B,), S, jnp.int32)
        return logits[:, 0], cache, lengths

    def decode_step(
        self,
        params: Dict,
        tokens: jnp.ndarray,       # [B, 1]
        cache: Dict,
        lengths: jnp.ndarray,      # [B] (position of the incoming token)
    ) -> Tuple[jnp.ndarray, Dict, jnp.ndarray]:
        cfg = self.cfg
        params = _cast_params(params, self.compute_dtype)
        h = embed(params["embed"], tokens, cfg.scale_embed, cfg.d_model)
        new_cache: Dict = {}

        if cfg.prefix_pattern:
            pcs = []
            for p, kind, c in zip(
                params["prefix"], cfg.prefix_pattern, cache["prefix"]
            ):
                h, c2 = blk.block_decode(p, cfg, kind, h, c, lengths)
                pcs.append(c2)
            new_cache["prefix"] = tuple(pcs)

        def sb_body(hh, xs):
            sb_params, sb_cache = xs
            cs = {}
            for key, kind in zip(_block_keys(cfg), cfg.pattern):
                hh, c2 = blk.block_decode(sb_params[key], cfg, kind, hh, sb_cache[key], lengths)
                cs[key] = c2
            return hh, cs

        h, new_cache["blocks"] = jax.lax.scan(
            sb_body, h, (params["blocks"], cache["blocks"])
        )
        h = rmsnorm(params["final_norm"], h)
        logits = unembed(params["embed"], h).astype(jnp.float32)
        return logits[:, 0], new_cache, lengths + 1
