"""Per-kind transformer blocks: init / train-apply / decode-apply / cache.

One module owns the layer-kind dispatch so the LM stack (`models/lm.py`)
can scan a *pattern* of heterogeneous kinds (dense, local, global, moe,
mlstm, slstm, hymba, hymba_g) with uniform plumbing:

    init_block(key, cfg, kind)                    -> params pytree
    block_train(params, cfg, kind, x)             -> (x', aux_loss)
    block_decode(params, cfg, kind, x, cache, l)  -> (x', cache')
    init_block_cache(cfg, kind, batch, seq)       -> zeroed cache pytree

Window ("local"/"hymba") kinds keep a **ring-buffer** KV cache of
``min(window, seq)`` slots -- for the ``long_500k`` shape this is what
turns a 500k-token context into an O(window) memory footprint on the
attention side (the SSM side is O(state) by construction).
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import linear_rnn as lrnn
from repro.models import moe as moe_lib
from repro.models.layers import (
    Params, init_mlp, init_rmsnorm, mlp, rmsnorm, truncated_normal,
)
from repro.parallel.axes import constrain, constrain_time_mixer

ATTN_KINDS = ("dense", "local", "global", "moe")
CONV_K = 4


def _window_for(cfg: ArchConfig, kind: str) -> int:
    if kind in ("local", "hymba"):
        return cfg.window
    return 0  # dense / global / moe / hymba_g: full attention


def _mlstm_dims(cfg: ArchConfig) -> Tuple[int, int, int]:
    inner = 2 * cfg.d_model                 # projection factor 2
    heads = cfg.num_heads
    return inner, heads, inner // heads


def _slstm_ff(cfg: ArchConfig) -> int:
    return ((int(cfg.d_model * 4 / 3) + 63) // 64) * 64


def _hymba_dims(cfg: ArchConfig) -> Tuple[int, int, int]:
    s = cfg.ssm
    return s.num_heads * s.head_dim, s.num_heads, s.head_dim  # inner, H, P


# -- init -----------------------------------------------------------------------


def init_block(key, cfg: ArchConfig, kind: str) -> Params:
    D = cfg.d_model
    keys = jax.random.split(key, 8)
    if kind in ATTN_KINDS:
        p: Params = {
            "ln_attn": init_rmsnorm(D),
            "attn": attn.init_attention(
                keys[0], D, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
            ),
            "ln_mlp": init_rmsnorm(D),
        }
        if kind == "moe":
            p["moe"] = moe_lib.init_moe(keys[1], D, cfg.d_ff, cfg.moe, cfg.mlp_type)
        else:
            p["mlp"] = init_mlp(keys[1], D, cfg.d_ff, cfg.mlp_type)
        return p

    if kind == "mlstm":
        inner, H, dh = _mlstm_dims(cfg)
        return {
            "ln": init_rmsnorm(D),
            "w_up": truncated_normal(keys[0], (D, 2 * inner), D ** -0.5),
            "conv_w": truncated_normal(keys[1], (CONV_K, inner), 0.1),
            "w_q": truncated_normal(keys[2], (H, dh, dh), dh ** -0.5),
            "w_k": truncated_normal(keys[3], (H, dh, dh), dh ** -0.5),
            "w_gates": truncated_normal(keys[4], (inner, 2 * H), inner ** -0.5),
            "b_gates": jnp.concatenate(
                [jnp.full((H,), 2.0), jnp.zeros((H,))]  # forget-gate bias +2
            ),
            "w_down": truncated_normal(keys[5], (inner, D), inner ** -0.5),
        }

    if kind == "slstm":
        return {
            "ln": init_rmsnorm(D),
            "slstm": lrnn.init_slstm(keys[0], D, cfg.num_heads),
            "ln_mlp": init_rmsnorm(D),
            "mlp": init_mlp(keys[1], D, _slstm_ff(cfg), "swiglu"),
        }

    if kind in ("hymba", "hymba_g"):
        inner, H, P = _hymba_dims(cfg)
        N = cfg.ssm.state_dim
        return {
            "ln": init_rmsnorm(D),
            "attn": attn.init_attention(
                keys[0], D, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
            ),
            "ssm_in": truncated_normal(keys[1], (D, 2 * inner), D ** -0.5),
            "ssm_bc": truncated_normal(keys[2], (D, 2 * H * N), D ** -0.5),
            "ssm_dt": truncated_normal(keys[3], (D, H), D ** -0.5),
            "ssm_dt_bias": jnp.zeros((H,)),
            "ssm_a_log": jnp.zeros((H,)),
            "ssm_out": truncated_normal(keys[4], (inner, D), inner ** -0.5),
            "norm_attn_out": init_rmsnorm(D),
            "norm_ssm_out": init_rmsnorm(inner),
            "mix_beta": jnp.zeros((2,)),            # learned branch scales
            "ln_mlp": init_rmsnorm(D),
            "mlp": init_mlp(keys[5], D, cfg.d_ff, cfg.mlp_type),
        }

    raise ValueError(f"unknown layer kind {kind!r}")


# -- train / prefill -------------------------------------------------------------


def block_train(
    params: Params,
    cfg: ArchConfig,
    kind: str,
    x: jnp.ndarray,
    prefix_len: int = 0,
    chunk_q: int = 512,
    seq_shard: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence block application.  Returns (x', aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if kind in ATTN_KINDS:
        h = rmsnorm(params["ln_attn"], x)
        h = attn.attention_train(
            params["attn"], h,
            num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
            head_dim=cfg.head_dim, rope_theta=cfg.rope_theta,
            window=_window_for(cfg, kind), prefix_len=prefix_len,
            chunk_q=chunk_q, seq_shard=seq_shard,
        )
        x = x + h
        h = rmsnorm(params["ln_mlp"], x)
        if kind == "moe":
            h, aux = moe_lib.moe_ffn_ep(params["moe"], h, cfg.moe, cfg.mlp_type)
        else:
            h = mlp(params["mlp"], h, cfg.mlp_type)
        return x + h, aux

    if kind == "mlstm":
        y, _ = _mlstm_seq(params, cfg, rmsnorm(params["ln"], x), state=None)
        return x + y, aux

    if kind == "slstm":
        h = rmsnorm(params["ln"], x)
        if x.shape[1] > 1:
            h = constrain_time_mixer(h)  # time scan: keep S local
        h, _ = lrnn.slstm_scan(params["slstm"], h, cfg.num_heads)
        x = x + h
        h = mlp(params["mlp"], rmsnorm(params["ln_mlp"], x), "swiglu")
        return x + h, aux

    if kind in ("hymba", "hymba_g"):
        h = rmsnorm(params["ln"], x)
        a = attn.attention_train(
            params["attn"], h,
            num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
            head_dim=cfg.head_dim, rope_theta=cfg.rope_theta,
            window=_window_for(cfg, kind), prefix_len=prefix_len,
            chunk_q=chunk_q, seq_shard=seq_shard,
        )
        s, _ = _hymba_ssm_seq(params, cfg, h, state=None)
        x = x + _hymba_mix(params, a, s)
        h = mlp(params["mlp"], rmsnorm(params["ln_mlp"], x), cfg.mlp_type)
        return x + h, aux

    raise ValueError(kind)


def _mlstm_seq(params, cfg: ArchConfig, h, state, return_state: bool = False):
    """mLSTM inner: up-proj, causal conv, per-head qk, chunked GLA, gate."""
    inner, H, dh = _mlstm_dims(cfg)
    B, L, _ = h.shape
    if L > 1:
        # recurrent chunk scan: keep S local, absorb idle axes into batch
        h = constrain_time_mixer(h)
    up = h @ params["w_up"]
    u, z = jnp.split(up, 2, axis=-1)
    if state is None:
        c = lrnn.causal_conv1d(u, params["conv_w"])
        conv_buf = None
    else:
        (gla_state, conv_buf) = state
        c, conv_buf = lrnn.causal_conv1d_step(u[:, 0], params["conv_w"], conv_buf)
        c = c[:, None]
    c = jax.nn.silu(c)
    ch = c.reshape(B, L, H, dh)
    q = jnp.einsum("blhd,hde->blhe", ch, params["w_q"])
    k = jnp.einsum("blhd,hde->blhe", ch, params["w_k"]) * (dh ** -0.5)
    v = u.reshape(B, L, H, dh)
    gates = u @ params["w_gates"] + params["b_gates"]          # [B,L,2H]
    f_raw, i_raw = jnp.split(gates, 2, axis=-1)
    log_f = jax.nn.log_sigmoid(f_raw)
    i_gate = jax.nn.sigmoid(i_raw)
    if state is None:
        y, gla_final = lrnn.gla_chunked(
            q, k, v, log_f, i_gate, normalize=True,
            chunk=min(cfg.ssm.chunk if cfg.ssm else 256, L),
        )
        new_state = None
        if return_state:
            pad = max(0, (CONV_K - 1) - L)
            tail = jnp.pad(u, ((0, 0), (pad, 0), (0, 0)))[:, -(CONV_K - 1):]
            new_state = (gla_final, tail.astype(jnp.float32))
    else:
        y1, new_gla = lrnn.gla_step(
            q[:, 0], k[:, 0], v[:, 0], log_f[:, 0], i_gate[:, 0],
            gla_state, normalize=True,
        )
        y = y1[:, None]
        new_state = (new_gla, conv_buf)
    y = y.reshape(B, L, inner) * jax.nn.silu(z)
    out = y @ params["w_down"]
    return out, new_state


def _hymba_ssm_seq(params, cfg: ArchConfig, h, state, return_state: bool = False):
    """Mamba2-style scalar-decay SSM branch (chunked GLA core)."""
    inner, H, P = _hymba_dims(cfg)
    N = cfg.ssm.state_dim
    B, L, _ = h.shape
    if L > 1:
        h = constrain_time_mixer(h)  # chunk scan: keep S local
    xz = h @ params["ssm_in"]
    xs, z = jnp.split(xz, 2, axis=-1)                           # [B,L,inner]
    bc = h @ params["ssm_bc"]
    bmat, cmat = jnp.split(bc.reshape(B, L, H, 2 * N), 2, axis=-1)
    dt = jax.nn.softplus(h @ params["ssm_dt"] + params["ssm_dt_bias"])  # [B,L,H]
    a = -jnp.exp(params["ssm_a_log"])                           # [H] (< 0)
    log_f = dt * a
    i_gate = dt
    v = xs.reshape(B, L, H, P)
    k = bmat * (N ** -0.5)
    q = cmat
    if state is None:
        y, final = lrnn.gla_chunked(
            q, k, v, log_f, i_gate, normalize=False, chunk=min(cfg.ssm.chunk, L)
        )
        new_state = final if return_state else None
    else:
        y1, new_state = lrnn.gla_step(
            q[:, 0], k[:, 0], v[:, 0], log_f[:, 0], i_gate[:, 0],
            state, normalize=False,
        )
        y = y1[:, None]
    y = y.reshape(B, L, inner) * jax.nn.silu(z)
    return y, new_state


def _hymba_mix(params, a, s):
    """Normalized, learned-scale fusion of attention and SSM branches.

    Cast back to the branch dtype: the f32 beta scalars would otherwise
    promote the residual stream to f32 for the whole rest of the stack
    (2x activation memory; caught by the dry-run §Perf log)."""
    beta = jax.nn.sigmoid(params["mix_beta"]) * 2.0
    an = rmsnorm(params["norm_attn_out"], a)
    sn = rmsnorm(params["norm_ssm_out"], s) @ params["ssm_out"]
    return (0.5 * (beta[0] * an + beta[1] * sn)).astype(a.dtype)


# -- prefill -----------------------------------------------------------------------


def _store_kv(k: jnp.ndarray, cache_len: int, window: int) -> jnp.ndarray:
    """Pack prefill keys/values into a decode cache buffer.

    Full-attention kinds: left-aligned into a [B, cache_len, ...] buffer.
    Window kinds: ring layout -- last min(W, S) positions at slot pos % W,
    matching `attention_decode_ring`'s indexing.
    """
    B, S, G, hd = k.shape
    k = k.astype(jnp.bfloat16)
    if window > 0:
        W = min(cache_len, window)
        Wv = min(W, S)
        slots = jnp.arange(S - Wv, S) % W
        buf = jnp.zeros((B, W, G, hd), jnp.bfloat16)
        return buf.at[:, slots].set(k[:, S - Wv :])
    buf = jnp.zeros((B, cache_len, G, hd), jnp.bfloat16)
    return jax.lax.dynamic_update_slice(buf, k, (0, 0, 0, 0))


def block_prefill(
    params: Params,
    cfg: ArchConfig,
    kind: str,
    x: jnp.ndarray,
    cache_len: int,
    prefix_len: int = 0,
    chunk_q: int = 512,
    seq_shard: bool = False,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Full-sequence application that also emits the decode cache."""
    window = _window_for(cfg, kind)
    if kind in ATTN_KINDS:
        h = rmsnorm(params["ln_attn"], x)
        h, (k, v) = attn.attention_train(
            params["attn"], h,
            num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
            head_dim=cfg.head_dim, rope_theta=cfg.rope_theta,
            window=window, prefix_len=prefix_len, chunk_q=chunk_q,
            return_kv=True, seq_shard=seq_shard,
        )
        x = x + h
        h = rmsnorm(params["ln_mlp"], x)
        if kind == "moe":
            h, _ = moe_lib.moe_ffn_ep(params["moe"], h, cfg.moe, cfg.mlp_type)
        else:
            h = mlp(params["mlp"], h, cfg.mlp_type)
        cache = {
            "k": _store_kv(k, cache_len, window),
            "v": _store_kv(v, cache_len, window),
        }
        return x + h, cache

    if kind == "mlstm":
        y, ((S, n), conv) = _mlstm_seq(
            params, cfg, rmsnorm(params["ln"], x), state=None, return_state=True
        )
        return x + y, {"S": S, "n": n, "conv": conv}

    if kind == "slstm":
        h = rmsnorm(params["ln"], x)
        if x.shape[1] > 1:
            h = constrain_time_mixer(h)
        h, (c, n, hs) = lrnn.slstm_scan(params["slstm"], h, cfg.num_heads)
        x = x + h
        h2 = mlp(params["mlp"], rmsnorm(params["ln_mlp"], x), "swiglu")
        return x + h2, {"c": c, "n": n, "h": hs}

    if kind in ("hymba", "hymba_g"):
        h = rmsnorm(params["ln"], x)
        a, (k, v) = attn.attention_train(
            params["attn"], h,
            num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
            head_dim=cfg.head_dim, rope_theta=cfg.rope_theta,
            window=window, prefix_len=prefix_len, chunk_q=chunk_q,
            return_kv=True, seq_shard=seq_shard,
        )
        s, (S, n) = _hymba_ssm_seq(params, cfg, h, state=None, return_state=True)
        x = x + _hymba_mix(params, a, s)
        h2 = mlp(params["mlp"], rmsnorm(params["ln_mlp"], x), cfg.mlp_type)
        cache = {
            "k": _store_kv(k, cache_len, window),
            "v": _store_kv(v, cache_len, window),
            "S": S,
            "n": n,
        }
        return x + h2, cache

    raise ValueError(kind)


# -- decode -----------------------------------------------------------------------


def block_decode(
    params: Params,
    cfg: ArchConfig,
    kind: str,
    x: jnp.ndarray,              # [B, 1, D]
    cache: Dict[str, jnp.ndarray],
    lengths: jnp.ndarray,        # [B]
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    if kind in ATTN_KINDS:
        h = rmsnorm(params["ln_attn"], x)
        h, kv = _attn_decode(params["attn"], cfg, kind, h, cache, lengths)
        x = x + h
        h = rmsnorm(params["ln_mlp"], x)
        if kind == "moe":
            h, _ = moe_lib.moe_ffn_ep(
                params["moe"], h, cfg.moe, cfg.mlp_type, dropless=True
            )
        else:
            h = mlp(params["mlp"], h, cfg.mlp_type)
        return x + h, kv

    if kind == "mlstm":
        state = ((cache["S"], cache["n"]), cache["conv"])
        y, ((S, n), conv) = _mlstm_seq(params, cfg, rmsnorm(params["ln"], x), state)
        return x + y, {"S": S, "n": n, "conv": conv}

    if kind == "slstm":
        h = rmsnorm(params["ln"], x)
        y, (c, n, hs) = lrnn.slstm_step(
            params["slstm"], h[:, 0], cfg.num_heads,
            (cache["c"], cache["n"], cache["h"]),
        )
        x = x + y[:, None]
        h2 = mlp(params["mlp"], rmsnorm(params["ln_mlp"], x), "swiglu")
        return x + h2, {"c": c, "n": n, "h": hs}

    if kind in ("hymba", "hymba_g"):
        h = rmsnorm(params["ln"], x)
        a, kv = _attn_decode(params["attn"], cfg, kind, h, cache, lengths)
        s, (S, n) = _hymba_ssm_seq(params, cfg, h, (cache["S"], cache["n"]))
        x = x + _hymba_mix(params, a, s)
        h2 = mlp(params["mlp"], rmsnorm(params["ln_mlp"], x), cfg.mlp_type)
        return x + h2, {**kv, "S": S, "n": n}

    raise ValueError(kind)


def _attn_decode(aparams, cfg: ArchConfig, kind: str, h, cache, lengths):
    window = _window_for(cfg, kind)
    if window > 0:  # ring cache sized min(seq, window); eviction == mask
        y, (k, v) = attn.attention_decode_ring(
            aparams, h, (cache["k"], cache["v"]), lengths,
            num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
            head_dim=cfg.head_dim, rope_theta=cfg.rope_theta,
        )
    else:
        y, (k, v) = attn.attention_decode(
            aparams, h, (cache["k"], cache["v"]), lengths,
            num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
            head_dim=cfg.head_dim, rope_theta=cfg.rope_theta,
            window=window,
        )
    return y, {"k": k, "v": v}


# -- cache specs -------------------------------------------------------------------


def init_block_cache(cfg: ArchConfig, kind: str, batch: int, seq: int):
    """Zeroed decode cache for one layer of `kind` (dtype bf16 for KV)."""

    def kv_len() -> int:
        w = _window_for(cfg, kind)
        return min(seq, w) if w > 0 else seq

    G, hd = cfg.num_kv_heads, cfg.head_dim
    if kind in ATTN_KINDS:
        s = kv_len()
        return {
            "k": jnp.zeros((batch, s, G, hd), jnp.bfloat16),
            "v": jnp.zeros((batch, s, G, hd), jnp.bfloat16),
        }
    if kind == "mlstm":
        inner, H, dh = _mlstm_dims(cfg)
        return {
            "S": jnp.zeros((batch, H, dh, dh), jnp.float32),
            "n": jnp.zeros((batch, H, dh), jnp.float32),
            "conv": jnp.zeros((batch, CONV_K - 1, inner), jnp.float32),
        }
    if kind == "slstm":
        H = cfg.num_heads
        dh = cfg.d_model // H
        z = jnp.zeros((batch, H, dh), jnp.float32)
        return {"c": z, "n": z, "h": z}
    if kind in ("hymba", "hymba_g"):
        inner, H, P = _hymba_dims(cfg)
        N = cfg.ssm.state_dim
        s = kv_len()
        return {
            "k": jnp.zeros((batch, s, G, hd), jnp.bfloat16),
            "v": jnp.zeros((batch, s, G, hd), jnp.bfloat16),
            "S": jnp.zeros((batch, H, N, P), jnp.float32),
            "n": jnp.zeros((batch, H, N), jnp.float32),
        }
    raise ValueError(kind)
