"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module must never
touch jax device state (the dry-run forces 512 placeholder host devices
*before* any jax initialisation; tests see the single real device).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; multi_pod adds the 2-pod axis (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1x1 mesh over the real local device (CPU tests/examples)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def mesh_desc(mesh) -> str:
    return "x".join(
        f"{mesh.shape[a]}{a}" for a in mesh.axis_names
    )
