"""Training CLI.

    PYTHONPATH=src python -m repro.launch.train --arch gemma-2b \
        --steps 200 --batch 8 --seq 256 --reduced --ckpt-dir /tmp/ckpt

On the CPU container this drives reduced configs end-to-end (the
quickstart example trains a ~100M model); on a TPU pod slice the same
entry point runs the full config over the production mesh.
"""

from __future__ import annotations

import argparse

import jax

from repro.configs import ARCHS, get_arch, reduced
from repro.data import TokenPipeline
from repro.models.lm import LM
from repro.optim import AdamWConfig
from repro.train import LoopConfig, train_loop


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--d-model", type=int, default=None,
                    help="override d_model (e.g. ~100M demo)")
    ap.add_argument("--layers", type=int, default=None)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        over = {}
        if args.d_model:
            over.update(
                d_model=args.d_model, head_dim=max(args.d_model // 8, 16),
                num_heads=4, num_kv_heads=2,
                d_ff=4 * args.d_model if cfg.d_ff else 0,
            )
        if args.layers:
            pat = len(cfg.pattern)
            over["num_layers"] = len(cfg.prefix_pattern) + pat * max(
                1, args.layers // pat
            )
        cfg = reduced(cfg, **over)

    lm = LM(cfg, remat="none", chunk_q=min(512, args.seq),
            loss_chunk=min(512, args.seq))
    pipeline = TokenPipeline(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch, seed=args.seed,
    )
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=min(100, args.steps // 10 + 1),
                          total_steps=args.steps)
    loop_cfg = LoopConfig(
        steps=args.steps, ckpt_every=args.ckpt_every,
        ckpt_dir=args.ckpt_dir, seed=args.seed,
    )
    pe_fn = None
    if cfg.modality == "vision_stub":
        import numpy as np

        def pe_fn(step):
            rng = np.random.default_rng(step)
            return rng.standard_normal(
                (args.batch, cfg.prefix_tokens, cfg.d_model)
            ).astype(np.float32) * 0.02

    hist = train_loop(lm, loop_cfg, opt_cfg, pipeline, prefix_embed_fn=pe_fn)
    print(
        f"final loss {hist['_final'][0]:.4f}  "
        f"median throughput {hist['throughput_tok_s'][0]:,.0f} tok/s"
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
