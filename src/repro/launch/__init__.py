# Launchers: mesh.py (production meshes), dryrun.py (multi-pod lower+compile
# matrix), train.py / serve.py CLIs.  dryrun must be executed as
# `python -m repro.launch.dryrun` so its XLA_FLAGS line runs first.
from repro.launch.mesh import make_host_mesh, make_production_mesh, mesh_desc

__all__ = ["make_host_mesh", "make_production_mesh", "mesh_desc"]
