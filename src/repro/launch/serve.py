"""Serving CLI: batched prefill + decode with the slot engine.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --reduced \
        --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_arch, reduced
from repro.models.lm import LM
from repro.serve import ServeConfig, ServeEngine


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    lm = LM(cfg, remat="none", chunk_q=64, loss_chunk=64)
    params = lm.init(jax.random.PRNGKey(args.seed))

    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len))
    )
    pe = None
    if cfg.modality == "vision_stub":
        pe = jnp.asarray(
            rng.standard_normal(
                (args.batch, cfg.prefix_tokens, cfg.d_model)
            ).astype(np.float32) * 0.02
        )

    engine = ServeEngine(
        lm, params,
        ServeConfig(max_batch=args.batch,
                    max_seq=args.max_seq + cfg.prefix_tokens + cfg.meta_tokens,
                    temperature=args.temperature, seed=args.seed),
    )
    t0 = time.perf_counter()
    out = engine.generate(prompts, args.gen, prefix_embeds=pe)
    dt = time.perf_counter() - t0
    print(f"generated [{out.shape[0]} x {out.shape[1]}] tokens in {dt:.2f}s "
          f"({out.shape[0]*out.shape[1]/dt:.1f} tok/s incl. compile)")
    print("first sequence:", out[0].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
