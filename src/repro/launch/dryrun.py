import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input shape x mesh) cell this lowers AND
compiles the real step function -- train_step for train shapes,
prefill/decode serve steps for inference shapes -- against 256 (single
pod, 16x16) or 512 (2 pods, 2x16x16) placeholder host devices, then
records:

  * memory_analysis()      -> bytes per device (does it fit 16 GB HBM?)
  * cost_analysis()        -> per-device HLO FLOPs / bytes
  * optimized HLO          -> per-device collective bytes by type
  * the 3-term roofline + MODEL_FLOPS ratio (see repro/roofline/model.py)

Artifacts: one JSON per cell under --out (default artifacts/dryrun/).
Inputs are ShapeDtypeStructs end to end -- no array is ever allocated.

NOTE: the XLA_FLAGS line above MUST run before any other import (jax
locks the device count on first init); do not move it, and do not set
this flag anywhere global (tests and benches must see 1 device).
"""

import argparse
import json
import sys
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, SHAPES, get_arch, param_count, shape_applicable
from repro.launch.mesh import make_production_mesh, mesh_desc
from repro.models.lm import LM
from repro.optim import AdamWConfig, init_opt_state
from repro.parallel.sharding import make_plan
from repro.roofline import (
    RooflineReport, collective_bytes, model_flops_estimate,
)
from repro.roofline.hlo_analysis import analyze as hlo_analyze
from repro.train.step import make_train_step


def _ns(mesh, spec):
    return NamedSharding(mesh, spec)


def _mem_analysis(compiled) -> Dict[str, Optional[float]]:
    out: Dict[str, Optional[float]] = {}
    try:
        ma = compiled.memory_analysis()
    except Exception:
        ma = None
    for attr in (
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "generated_code_size_in_bytes",
        "alias_size_in_bytes",
    ):
        out[attr] = float(getattr(ma, attr)) if ma is not None and hasattr(ma, attr) else None
    if out.get("argument_size_in_bytes") is not None:
        args = out["argument_size_in_bytes"] or 0.0
        tmp = out["temp_size_in_bytes"] or 0.0
        outb = out["output_size_in_bytes"] or 0.0
        alias = out["alias_size_in_bytes"] or 0.0
        out["peak_bytes_per_device"] = args + tmp + outb - alias
    else:
        out["peak_bytes_per_device"] = None
    return out


def _cost_analysis(compiled) -> Dict[str, float]:
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        return {k: float(v) for k, v in ca.items() if np.isscalar(v)}
    except Exception:
        return {}


def lower_cell(arch_name: str, shape_name: str, multi_pod: bool,
               variant: str = "baseline"):
    """Build + lower + compile one cell; returns (report_dict, compiled)."""
    cfg = get_arch(arch_name)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch_name, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "skipped": True, "reason": why}, None

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    remat = "full" if shape.kind == "train" else "none"
    plan = make_plan(cfg, mesh, kind=shape.kind)
    lm = LM(cfg, remat=remat, chunk_q=512, loss_chunk=512,
            attn_seq_shard=(plan.attn_mode == "seq"))

    B, S = shape.global_batch, shape.seq_len
    # patches/meta tokens count toward the seq budget: cache is exactly S
    n_text = S - cfg.prefix_tokens - cfg.meta_tokens
    tok_spec = jax.ShapeDtypeStruct((B, n_text), jnp.int32)
    pe_spec = None
    if cfg.modality == "vision_stub":
        pe_spec = jax.ShapeDtypeStruct(
            (B, cfg.prefix_tokens, cfg.d_model), jnp.float32
        )

    params_abs = lm.abstract_params()
    t0 = time.perf_counter()

    with mesh:
        if shape.kind == "train":
            opt_abs = jax.eval_shape(init_opt_state, params_abs)
            step, _ = make_train_step(lm, plan, AdamWConfig())
            args = [params_abs, opt_abs, tok_spec]
            if pe_spec is not None:
                args.append(pe_spec)
            lowered = step.lower(*args)
        elif shape.kind == "prefill":
            pspecs = plan.param_specs(params_abs)
            in_sh = [
                jax.tree_util.tree_map(
                    lambda s: _ns(mesh, s), pspecs,
                    is_leaf=lambda x: isinstance(x, P),
                ),
                _ns(mesh, plan.batch_spec(2)),
            ]
            args = [params_abs, tok_spec]
            if pe_spec is not None:
                in_sh.append(_ns(mesh, plan.batch_spec(3)))
                args.append(pe_spec)
            cache_abs = jax.eval_shape(
                lambda: lm.init_cache(B, S)
            )
            cache_sh = jax.tree_util.tree_map(
                lambda s: _ns(mesh, s), plan.cache_specs(cache_abs),
                is_leaf=lambda x: isinstance(x, P),
            )
            # pin the emitted KV cache to its serving layout (seq-sharded);
            # otherwise GSPMD may materialise it replicated (29 GiB/device
            # on musicgen prefill_32k; see §Perf)
            fn = jax.jit(
                lambda p, t, pe=None: lm.prefill(p, t, S, pe),
                in_shardings=tuple(in_sh),
                out_shardings=(None, cache_sh, None),
            )
            lowered = fn.lower(*args)
        else:  # decode
            cache_abs = lm.abstract_cache(B, S)
            pspecs = plan.param_specs(params_abs)
            cspecs = plan.cache_specs(cache_abs)
            tok1 = jax.ShapeDtypeStruct((B, 1), jnp.int32)
            len_spec = jax.ShapeDtypeStruct((B,), jnp.int32)
            in_sh = (
                jax.tree_util.tree_map(
                    lambda s: _ns(mesh, s), pspecs,
                    is_leaf=lambda x: isinstance(x, P),
                ),
                _ns(mesh, P(None, None)),
                jax.tree_util.tree_map(
                    lambda s: _ns(mesh, s), cspecs,
                    is_leaf=lambda x: isinstance(x, P),
                ),
                _ns(mesh, P(None)),
            )
            cache_sh = jax.tree_util.tree_map(
                lambda s: _ns(mesh, s), cspecs,
                is_leaf=lambda x: isinstance(x, P),
            )
            fn = jax.jit(
                lm.decode_step, in_shardings=in_sh, donate_argnums=(2,),
                out_shardings=(None, cache_sh, None),
            )
            lowered = fn.lower(params_abs, tok1, cache_abs, len_spec)

        t_lower = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0

    cost = _cost_analysis(compiled)
    mem = _mem_analysis(compiled)
    hlo = compiled.as_text()
    census = hlo_analyze(hlo)  # trip-count-aware (see hlo_analysis.py)

    counts = param_count(cfg)
    mf = model_flops_estimate(cfg, shape, counts["active"])
    report = RooflineReport(
        arch=arch_name, shape=shape_name,
        mesh="multi" if multi_pod else "single", chips=chips,
        flops_per_device=census.flops,
        bytes_per_device=census.hbm_bytes,
        coll_bytes_per_device=census.collective_bytes,
        model_flops=mf,
        peak_memory_per_device=mem.get("peak_bytes_per_device"),
        coll_breakdown={k: int(v) for k, v in census.coll_breakdown.items()},
    )
    out = report.to_dict()
    out.update({
        "variant": variant,
        "skipped": False,
        "attn_mode": plan.attn_mode,
        "t_lower_s": t_lower,
        "t_compile_s": t_compile,
        "memory_analysis": mem,
        # raw cost_analysis kept for reference; it counts while bodies
        # once, hence the trip-count-aware census above (EXPERIMENTS.md)
        "xla_cost_analysis_flops": cost.get("flops"),
        "xla_cost_analysis_bytes": cost.get("bytes accessed"),
        "while_trip_counts": census.while_trips,
        "params_total": counts["total"],
        "params_active": counts["active"],
        "hlo_bytes": len(hlo),
    })
    return out, compiled


def cell_id(arch: str, shape: str, mesh: str, variant: str) -> str:
    return f"{arch}__{shape}__{mesh}" + ("" if variant == "baseline" else f"__{variant}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="architecture id (or --all)")
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true", help="run the full matrix")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args(argv)

    os.makedirs(args.out, exist_ok=True)
    archs = sorted(ARCHS) if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mname = "multi" if mp else "single"
                cid = cell_id(arch, shape, mname, args.variant)
                path = os.path.join(args.out, cid + ".json")
                if args.skip_existing and os.path.exists(path):
                    print(f"[skip existing] {cid}")
                    continue
                print(f"[dryrun] {cid} ...", flush=True)
                try:
                    report, compiled = lower_cell(arch, shape, mp, args.variant)
                except Exception as e:
                    traceback.print_exc()
                    failures.append((cid, repr(e)))
                    report = {
                        "arch": arch, "shape": shape, "mesh": mname,
                        "variant": args.variant, "error": repr(e),
                    }
                    compiled = None
                with open(path, "w") as f:
                    json.dump(report, f, indent=1)
                if report.get("skipped"):
                    print(f"  -> SKIPPED: {report['reason']}")
                elif "error" in report:
                    print(f"  -> ERROR: {report['error']}")
                else:
                    print(
                        f"  -> ok  compile {report['t_compile_s']:.1f}s  "
                        f"bottleneck {report['bottleneck']}  "
                        f"t=({report['t_compute_s']:.2e},"
                        f"{report['t_memory_s']:.2e},"
                        f"{report['t_collective_s']:.2e})s  "
                        f"mem/dev "
                        f"{(report['memory_analysis']['peak_bytes_per_device'] or 0)/2**30:.2f}GiB"
                    )
                del compiled
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for cid, err in failures:
            print(f"  {cid}: {err}")
        return 1
    print("\nall requested cells passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
