"""Sharded, atomic, async checkpointing (tensorstore-free).

Layout per step:

    <dir>/step_<N>.tmp/          (written first)
        arrays.npz               flattened leaves, key = escaped tree path
        manifest.json            step, leaf paths/shapes/dtypes, wall time
    <dir>/step_<N>/              (atomic rename = commit)

Fault-tolerance contract (runtime/fault_tolerance.py builds on this):
* a checkpoint is valid iff its manifest is present in a committed dir --
  a crash mid-write leaves only a .tmp dir, which restore ignores and
  cleanup deletes;
* ``restore_latest`` walks committed steps newest-first and falls back if
  a dir is unreadable (torn disk), so a corrupted newest checkpoint costs
  one interval, never the run;
* arrays are saved from host RAM; the async path snapshots to host first
  (jax.device_get) then writes on a worker thread, overlapping I/O with
  the next training steps.
* on restore, leaves are re-placed with ``jax.device_put`` against the
  *current* sharding -- restoring onto a different mesh (elastic resize)
  reshards transparently.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        out.append((key, leaf))
    return out


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._last_error: Optional[BaseException] = None

    # -- write ------------------------------------------------------------

    def save(self, step: int, tree, blocking: bool = True) -> None:
        host_tree = jax.tree_util.tree_map(lambda x: np.asarray(jax.device_get(x)), tree)
        if blocking:
            self._write(step, host_tree)
        else:
            self.wait()
            self._thread = threading.Thread(
                target=self._write_safe, args=(step, host_tree), daemon=True
            )
            self._thread.start()

    def _write_safe(self, step: int, host_tree) -> None:
        try:
            self._write(step, host_tree)
        except BaseException as e:  # surfaced on next wait()
            self._last_error = e

    def _write(self, step: int, host_tree) -> None:
        tmp = os.path.join(self.dir, f"step_{step}.tmp")
        final = os.path.join(self.dir, f"step_{step}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        flat = _flatten(host_tree)
        arrays = {f"a{i}": leaf for i, (_, leaf) in enumerate(flat)}
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        manifest = {
            "step": step,
            "time": time.time(),
            "leaves": [
                {"key": k, "idx": i, "shape": list(np.shape(l)),
                 "dtype": str(np.asarray(l).dtype)}
                for i, (k, l) in enumerate(flat)
            ],
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # commit point
        self._gc()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._last_error is not None:
            err, self._last_error = self._last_error, None
            raise err

    def _gc(self) -> None:
        steps = sorted(self.committed_steps())
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)

    # -- read -------------------------------------------------------------

    def committed_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, name, "manifest.json")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def restore(self, step: int, like, shardings=None):
        """Restore into the structure of `like` (a pytree of arrays or
        ShapeDtypeStructs); `shardings` optionally re-places leaves."""
        d = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(d, "arrays.npz"))
        flat_like, treedef = jax.tree_util.tree_flatten(like)
        leaves = [data[f"a{i}"] for i in range(len(manifest["leaves"]))]
        if len(leaves) != len(flat_like):
            raise ValueError(
                f"checkpoint has {len(leaves)} leaves, target {len(flat_like)}"
            )
        if shardings is not None:
            flat_sh = jax.tree_util.tree_leaves(
                shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding)
            )
            leaves = [jax.device_put(l, s) for l, s in zip(leaves, flat_sh)]
        else:
            leaves = [
                jax.numpy.asarray(l, dtype=fl.dtype) for l, fl in zip(leaves, flat_like)
            ]
        return jax.tree_util.tree_unflatten(treedef, leaves)

    def restore_latest(self, like, shardings=None):
        """(step, tree) from the newest readable checkpoint, or (None, None)."""
        for step in reversed(self.committed_steps()):
            try:
                return step, self.restore(step, like, shardings)
            except Exception:
                continue  # torn checkpoint: fall back to the previous one
        return None, None

    def cleanup_tmp(self) -> int:
        n = 0
        for name in os.listdir(self.dir):
            if name.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.dir, name), ignore_errors=True)
                n += 1
        return n
