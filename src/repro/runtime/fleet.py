"""Pixie fleet: a multi-tenant batched scheduler for VCGRA overlays.

The paper's economics (Sec. V-E) are compile-once / reconfigure-in-ms:
one physical overlay amortizes its ~1200 s FPGA compile across every
application mapped onto it.  This module pushes the amortization one step
further: because every application mapped on a grid yields
identically-shaped settings arrays, N *different* tenants can be stacked
(``VCGRAConfig.stack``) and executed by one vmapped overlay executable in
a single dispatch (a batched :class:`repro.core.plan.OverlayPlan`
compiled once by ``compile_plan``) -- the serving-throughput analogue of
resident multi-context bitstreams.  With a
:class:`~repro.parallel.axes.MeshSpec` the plan additionally shards every
dispatch over local devices: ``MeshSpec(app=k)`` splits the app axis k
ways, ``MeshSpec(app=k, rows=m)`` also row-bands fused frames over a 2-D
mesh with seam halo exchange (both bitwise-equal to the single-device
run).

Scheduling model:

* requests name an application (a :class:`DFG` or a library app name) plus
  its pixel inputs (named channels or a whole image);
* requests are grouped by :class:`GridSpec` -- only same-structure overlays
  share an executable;
* image requests take the **fused-ingest** path: the raw frame is kept at
  submit time and line-buffer formation (stencil tap slices) happens
  *inside* the batched dispatch (a fused batched ``OverlayPlan``)
  -- pack + dispatch + unpack are one executable, with per-app
  :class:`repro.core.ingest.IngestPlan` settings selecting each channel's
  producer; named-channel requests keep the host-packed path;
* each group is padded to fixed tiles -- the app axis to ``batch_tile``,
  the pixel axis (frame canvas for fused, flat batch for unfused) to
  power-of-two buckets -- so repeated flushes hit the same compiled
  executable (no shape-driven recompiles);
* fused dispatches are row-tiled on the pixel axis (``tile_rows``, default
  ``TILE_AUTO``: a VMEM budget heuristic that degenerates to untiled at
  smoke sizes) and frames ride a reused canvas pool; with
  ``ingest="async"`` the pipeline double-buffers -- pooled canvases are
  shipped via ``jax.device_put`` into a donated operand and outputs are
  unpacked lazily, so packing of flush k+1 overlaps the device execution
  of flush k (``FleetStats.ingest_overlap_s`` accounts the overlap);
* mapped configs are cached by DFG structural hash: a repeat tenant costs
  zero place/route work;
* compiled batched overlays are cached per grid in a small LRU.

All padding is exact: padded app slots replay an already-valid config on
zero inputs and are discarded; padded pixels (for fused requests: the
zero canvas right/below the frame, which taps read exactly like
``stencil_inputs``'s zero border) are sliced off -- so fleet outputs are
bitwise identical to sequential `Pixie` runs.
"""

from __future__ import annotations

import dataclasses
import math
import time
import warnings
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import applications as app_lib
from repro.core import grid as gridlib
from repro.core import interpreter
from repro.core.bitstream import VCGRAConfig
from repro.core.dfg import DFG
from repro.core.grid import GridSpec
from repro.core.ingest import IngestPlan, ReadinessProbe, check_ingest
from repro.core.pixie import map_app
from repro.core.plan import (
    OverlayExecutable, OverlayPlan, PipelineSpec, compile_plan, fallback_chain,
)
from repro.core.tiling import (
    TILE_AUTO, check_tile_rows, pow2_bucket, round_up, row_band,
)
from repro.parallel.axes import APP_AXIS, ROW_AXIS, MeshSpec, build_mesh
from repro.runtime.chaos import FaultInjector
from repro.runtime.fault_tolerance import HeartbeatMonitor
from repro.runtime.resilience import (
    BreakerBoard, PoisonedOutputError, QuarantinedError, RetryPolicy,
)


class LRUCache:
    """Tiny ordered-dict LRU with hit/miss counters (no external deps)."""

    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self._d: "OrderedDict[Any, Any]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get(self, key: Any) -> Optional[Any]:
        if key in self._d:
            self._d.move_to_end(key)
            self.hits += 1
            return self._d[key]
        self.misses += 1
        return None

    def put(self, key: Any, value: Any) -> List[Any]:
        """Insert; returns the keys evicted to make room (callers that
        cache executables log them so eviction churn names the exact
        plan involved)."""
        self._d[key] = value
        self._d.move_to_end(key)
        evicted = []
        while len(self._d) > self.capacity:
            k, _ = self._d.popitem(last=False)
            evicted.append(k)
            self.evictions += 1
        return evicted

    def __len__(self) -> int:
        return len(self._d)

    def __contains__(self, key: Any) -> bool:
        return key in self._d


@dataclasses.dataclass
class FleetRequest:
    """One tenant's work item.

    ``app``: a DFG, a pre-mapped VCGRAConfig, or a library app name
    (``repro.core.applications.ALL_APPS``).
    ``inputs``: named memory-VC channels, or ``image``: an [H, W] array fed
    through the stencil line-buffer helper.  ``grid`` overrides the fleet's
    default overlay for this request.

    ``pipeline`` (instead of ``app``): an ordered chain of applications --
    stage i's selected output (``out_channels[i]``, default channel 0)
    feeds stage i+1's ingest taps.  The whole chain executes as ONE
    device-resident dispatch (a pipeline :class:`OverlayPlan`); a
    single-stage chain demotes to the plain fused path at submit, so it
    batches (and caches) exactly like an ``app=`` request.  Pipeline
    requests take ``image=`` frames only (every stage is fused ingest).
    """

    app: Union[DFG, VCGRAConfig, str, None] = None
    inputs: Optional[Dict[str, Any]] = None
    image: Optional[Any] = None
    grid: Optional[GridSpec] = None
    pipeline: Optional[Sequence[Union[DFG, VCGRAConfig, str]]] = None
    out_channels: Optional[Sequence[int]] = None


@dataclasses.dataclass
class FleetStats:
    backend: str = "xla"         # execution backend of every dispatch
    devices: int = 1             # app-axis mesh width of every dispatch
    ingest: str = "sync"         # ingest pipelining mode of every dispatch
    # Mesh truthfulness: the (app, rows) shape the fleet was ASKED for vs
    # the shape actually realized against the host's local devices.
    # build_mesh degrades to the single-device bitwise fallback instead of
    # erroring when the host is short, so without this stamp a serving
    # dashboard would happily report a "16-way" fleet running on one chip;
    # the bench JSON carries all three fields (see
    # benchmarks/fleet_throughput.py).
    mesh_requested: Tuple[int, int] = (1, 1)
    mesh_granted: Tuple[int, int] = (1, 1)
    mesh_degraded: bool = False
    # Host-side packing time that ran while a previous dispatch was still
    # executing on device (async ingest only): the double-buffer overlap
    # the sync path cannot have.  Completion is observed through
    # core.ingest.ReadinessProbe -- a truthful zero-timeout check even on
    # XLA:CPU, whose is_ready() is optimistic -- so serving dashboards can
    # trust this number on every platform.
    ingest_overlap_s: float = 0.0
    canvas_pool_hits: int = 0    # frame canvases reused instead of allocated
    # Per-device canvas reuse for sharded async fleets: the pool is keyed
    # by mesh device, so each shard's ingest fills (and ships) its own
    # host buffer instead of serializing through one whole-batch canvas.
    # Keyed by str(device.id) -> hit count; empty for unsharded fleets.
    canvas_pool_device_hits: Dict[str, int] = dataclasses.field(
        default_factory=dict
    )
    submitted: int = 0
    executed: int = 0
    dispatches: int = 0          # batched overlay launches
    fused_dispatches: int = 0    # of which took the fused-ingest path
    pipeline_dispatches: int = 0  # of which chained depth>1 pipeline specs
    # Streaming-scheduler preemptions: batches whose composition was
    # re-sorted mid-selection because an urgent-deadline request flipped
    # ahead of the staged (priority, arrival) order -- see
    # StreamingFrontend._select_batch.
    preempted_batches: int = 0
    # Dispatches launched with fewer real requests than the app tile --
    # the continuous-batching scheduler fires these when a deadline
    # approaches rather than waiting for a full tile, and the serving
    # bench asserts they actually happen under deadline pressure.
    partial_tile_dispatches: int = 0
    padded_app_slots: int = 0    # wasted N-axis slots from tile rounding
    map_calls: int = 0           # place/route runs (config-cache misses)
    config_cache_hits: int = 0
    overlay_builds: int = 0      # batched executables built (per OverlayPlan)
    overlay_cache_hits: int = 0
    stack_bank_hits: int = 0     # stacked settings banks reused across flushes
    # Full plan-key stamp of every dispatch: "<plan.key()>|<padded tile>"
    # -> dispatch count.  Bench JSON and assertion/eviction messages name
    # the exact executable involved, not just the backend.
    dispatch_plans: Dict[str, int] = dataclasses.field(default_factory=dict)
    evicted_plans: List[str] = dataclasses.field(default_factory=list)
    # -- resilience telemetry (PR 10) ------------------------------------
    retries: int = 0             # re-dispatch attempts after a transient failure
    quarantined_requests: int = 0  # tickets isolated by bisection + failed
    # Dispatches served by a degraded plan from the fallback chain
    # (pallas->xla, 2-D mesh->app-only->single device, tiled->untiled)
    # because the primary plan failed or its breaker was open.
    fallback_dispatches: int = 0
    guard_failures: int = 0      # outputs rejected by the NaN/Inf guard
    straggler_flushes: int = 0   # flushes the HeartbeatMonitor flagged
    # Every circuit-breaker transition, in order: {"plan", "event", "t",
    # "consecutive_failures"}.  The list is SHARED with the fleet's
    # BreakerBoard, so it is always current without copying.
    breaker_events: List[Dict[str, Any]] = dataclasses.field(
        default_factory=list
    )

    def stamp_dispatch(self, plan: OverlayPlan, tile: str) -> None:
        key = f"{plan.key()}|{tile}"
        self.dispatch_plans[key] = self.dispatch_plans.get(key, 0) + 1

    def as_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class _PooledCanvas:
    """One reusable frame canvas plus the device_put still reading it.

    ``pending`` is the device array the async path last shipped from
    ``buf``: the host buffer may not be rewritten until that transfer
    completes, so :meth:`PixieFleet._canvas` blocks on it at *reuse* time
    (when it is long done) instead of on the ship's critical path -- the
    depth-2 rotation is what makes the deferred block almost always free.
    """

    buf: np.ndarray
    pending: Optional[Any] = None


@dataclasses.dataclass
class _Prepared:
    """A submit-time-validated work item awaiting flush."""

    grid: GridSpec
    cfg: VCGRAConfig
    kind: str          # "image" (fused ingest) | "channels" | "pipeline"
    payload: Any                 # np [H, W] raw frame | jnp [C, batch]
    hw: Optional[Tuple[int, int]]
    # Depth>1 chain spec for kind="pipeline" (depth-1 chains demote to
    # kind="image" at submit, so they share the single-stage plan cache).
    spec: Optional[PipelineSpec] = None


class PixieFleet:
    """Accepts per-app requests and serves them in vmapped batches.

    >>> fleet = PixieFleet()
    >>> t1 = fleet.submit(FleetRequest(app="sobel_x", image=img))
    >>> t2 = fleet.submit(FleetRequest(app="threshold", image=img))
    >>> outs = fleet.flush()          # ONE overlay dispatch for both
    >>> outs[t1].shape
    (32, 32)
    """

    def __init__(
        self,
        default_grid: Optional[GridSpec] = None,
        batch_tile: int = 8,
        min_pixel_batch: int = 256,
        max_overlays: int = 8,
        max_configs: int = 256,
        max_retained_results: int = 1024,
        backend: str = "xla",
        mesh: Optional[MeshSpec] = None,
        ingest: str = "sync",
        tile_rows: Union[int, str, None] = TILE_AUTO,
        devices: Optional[int] = None,
        faults: Optional[FaultInjector] = None,
        retry: Optional[RetryPolicy] = None,
        breakers: Optional[BreakerBoard] = None,
        heartbeat: Optional[HeartbeatMonitor] = None,
        output_guard: Optional[bool] = None,
    ):
        self.default_grid = default_grid or gridlib.sobel_grid()
        # Execution backend for every dispatch: "xla" (the hand-lowered
        # jnp interpreter, the bitwise oracle) or "pallas" (the batched
        # VCGRA megakernels, interpreted off-TPU / compiled on TPU).
        self.backend = interpreter.check_backend(backend)
        # Device placement of every dispatch, as a structured MeshSpec:
        # app=k shards the N axis of every batched dispatch over k local
        # devices, rows=m additionally row-bands fused frames over a 2-D
        # (app, rows) mesh with seam halo exchange.  Both are
        # bitwise-equal to single-device and degrade to it when the host
        # has fewer devices -- see core/plan.py; the degradation is
        # recorded in FleetStats below.  The bare device-count kwarg is
        # the deprecated spelling of MeshSpec(app=k).
        if devices is not None:
            d = int(devices)
            if d < 1:
                raise ValueError(f"devices must be >= 1, got {devices}")
            if mesh is not None:
                raise ValueError(
                    "pass mesh=MeshSpec(...) or the deprecated bare device "
                    "count, not both"
                )
            warnings.warn(
                "the bare device-count kwarg of PixieFleet is deprecated: "
                f"pass mesh=MeshSpec(app={d}) instead",
                DeprecationWarning,
                stacklevel=2,
            )
            mesh = MeshSpec(app=d)
        if mesh is not None and not isinstance(mesh, MeshSpec):
            raise ValueError(f"mesh must be a MeshSpec, got {mesh!r}")
        self.mesh = mesh or MeshSpec()
        # Ingest pipelining: "sync" packs, dispatches and materializes in
        # strict order; "async" double-buffers -- pooled canvases shipped
        # with device_put into a donated operand, outputs unpacked lazily
        # so the *next* flush's packing overlaps this flush's device
        # execution.  Bitwise-identical; async results are jax arrays
        # (forced on first host read) instead of eager numpy.
        self.ingest = check_ingest(ingest)
        # Pixel-axis row tiling of the fused dispatch: TILE_AUTO (default)
        # lets the VMEM budget heuristic pick per frame shape (single slab
        # == untiled at smoke sizes), an int fixes the tile height, None
        # disables tiling.  All values are bitwise-identical.
        self.tile_rows = check_tile_rows(tile_rows)
        # Reused zero canvases for fused frame embedding, keyed by padded
        # tile shape; depth 2 under async ingest (flush k+1 packs one
        # buffer while flush k's device_put of the other completes).
        # LRU-bounded like every other fleet cache: a service whose group
        # sizes / frame buckets drift would otherwise pin two full
        # canvases per distinct shape forever.
        self._canvas_pool = LRUCache(8)
        # Readiness probe on the most recent dispatch output (async):
        # overlap accounting checks whether it is still in flight when the
        # next pack starts -- truthfully, even on XLA:CPU.
        self._inflight: Optional[ReadinessProbe] = None
        # Jitted group unpackers for the async fused path, keyed by the
        # item shapes: ONE lazy dispatch slices every tenant's [H, W]
        # window out of the canvas outputs (per-item eager slicing costs
        # ~25 tiny host-dispatched ops per flush -- the async tax that
        # used to eat the overlap win at smoke sizes).
        self._unpack_fns = LRUCache(64)
        self.batch_tile = int(batch_tile)
        # App-axis tiles must also divide evenly across the mesh so the
        # plan executable never has to re-pad internally (padded_app_slots
        # then accounts for ALL padding).
        self._app_tile = math.lcm(self.batch_tile, self.mesh.app)
        self.min_pixel_batch = int(min_pixel_batch)
        # Fused frame canvases bucket H and W separately; the floor keeps
        # the same ~min_pixel_batch pixels per tile as the unfused path.
        self.min_image_side = max(1, int(math.isqrt(self.min_pixel_batch)))
        # Keyed by OverlayPlan (the one cache key of the plan pipeline).
        self._overlays = LRUCache(max_overlays)
        self._configs = LRUCache(max_configs)
        # Stacked settings banks: a repeat flush of the same tenant set
        # skips re-stacking N configs (keyed by their cache identities).
        self._banks = LRUCache(4 * max_overlays)
        # Truthful mesh stamping: probe what the host can actually grant
        # once, here, so dashboards never mistake the requested shape for
        # the effective one (build_mesh silently falls back to
        # single-device when local devices run short).
        granted = self.mesh
        if self.mesh.size > 1 and build_mesh(self.mesh) is None:
            granted = MeshSpec()
        self.stats = FleetStats(
            self.backend, self.mesh.app, self.ingest,
            mesh_requested=self.mesh.shape(), mesh_granted=granted.shape(),
            mesh_degraded=granted != self.mesh,
        )
        self._pending: List[Tuple[int, Tuple]] = []
        # Bounded: unredeemed tickets are evicted oldest-first so a service
        # that only consumes flush()'s return value cannot leak memory.
        self._results: "OrderedDict[int, np.ndarray]" = OrderedDict()
        self.max_retained_results = int(max_retained_results)
        self._next_ticket = 0
        # -- resilience (PR 10) ----------------------------------------------
        # Dispatch is ALWAYS resilient: transient failures retry with a
        # deterministic backoff, a persistently failing plan degrades down
        # its fallback chain behind a per-plan-key circuit breaker, and a
        # request no plan can serve is isolated by bisection and fails
        # ONLY its own ticket (stored in _failures, raised by result()).
        # The policy objects are pure host control flow -- on the happy
        # path they cost a dict lookup per flush group.
        self.faults = faults
        self.retry = retry or RetryPolicy()
        self.breakers = breakers or BreakerBoard()
        # Flush wall times feed the seed HeartbeatMonitor; a flagged
        # straggler flush counts as a breaker failure for every plan it
        # dispatched -- but only when the caller opted into chaos/breaker
        # tuning (faults= or breakers=), so CI noise can never degrade a
        # vanilla fleet's plans.
        self.heartbeat = heartbeat if heartbeat is not None else HeartbeatMonitor()
        self._straggler_trips_breaker = (
            faults is not None or breakers is not None or heartbeat is not None
        )
        # NaN/Inf output guard (inexact dtypes only -- integer fabrics
        # cannot encode NaN).  Defaults on exactly when faults are
        # installed: the guard forces async outputs eagerly, which would
        # tax the happy path's ingest overlap.
        self._guard = bool(faults is not None if output_guard is None
                           else output_guard)
        # Per-ticket failures awaiting redemption: result() raises them,
        # front-ends drain them via pop_failures().  Bounded like _results.
        self._failures: "OrderedDict[int, BaseException]" = OrderedDict()
        # Per-flush scratch: breakers owed a success at flush end (the
        # success is deferred so a straggler flush can convert it into a
        # breaker failure), and the memoized fallback chains.
        self._flush_successes: List[Tuple[Any, str]] = []
        self._chain_cache = LRUCache(64)
        self.stats.breaker_events = self.breakers.events
        # pack_s accumulates host-side input preparation (submit time);
        # dispatch_s accumulates time inside overlay executions; flush_s is
        # the wall time of the most recent flush.
        self.timings: Dict[str, float] = {"pack_s": 0.0, "dispatch_s": 0.0}

    @property
    def devices(self) -> int:
        """App-axis mesh width (the reading side of the deprecated bare
        device-count surface; front-ends and stats consume it)."""
        return self.mesh.app

    # -- caches ---------------------------------------------------------------

    def config_for(self, app: Union[DFG, VCGRAConfig, str], grid: GridSpec) -> VCGRAConfig:
        """Mapped settings for (app, grid); place/route runs at most once
        per distinct DFG structure (the repeat-tenant fast path).

        Library-name requests additionally cache on (name, grid): a repeat
        tenant submitted by name skips even the DFG construction and
        structural hash (~0.1 ms/request -- the dominant per-request pack
        cost at smoke frame sizes, see BENCH pack_fraction_fused)."""
        if isinstance(app, str):
            key = (app, grid)
            cfg = self._configs.get(key)
            if cfg is not None:
                self.stats.config_cache_hits += 1
                return cfg
            cfg = self.config_for(app_lib.ALL_APPS[app](), grid)
            self._configs.put(key, cfg)
            return cfg
        if isinstance(app, VCGRAConfig):
            expected = (
                tuple((p,) for p in grid.pes_per_level),
                tuple((p, 2) for p in grid.pes_per_level),
                (grid.num_outputs,),
            )
            if app.config_shapes() != expected:
                raise ValueError(
                    f"config {app.app_name!r} was mapped on grid "
                    f"{app.grid_name!r}, which does not match {grid.name!r}"
                )
            return app
        dfg = app
        key = (dfg.structural_hash(), grid)
        cfg = self._configs.get(key)
        if cfg is not None:
            self.stats.config_cache_hits += 1
            return cfg
        cfg = map_app(dfg, grid)
        cfg.cache_key = f"{key[0]}@{grid.name}"
        self.stats.map_calls += 1
        self._configs.put(key, cfg)
        return cfg

    def plan_for_dispatch(self, grid: GridSpec, *, fused: bool,
                          radius: Optional[int] = None,
                          pipeline: Optional[Tuple[PipelineSpec, ...]] = None,
                          ) -> OverlayPlan:
        """The :class:`OverlayPlan` of one dispatch on this fleet: the
        fleet contributes its backend, mesh, tiling and ingest axes, the
        request group contributes grid/fusion/radius (or, for chained
        dispatches, the per-tenant pipeline specs -- radius then derives
        from the stages).  Unfused dispatches project the mesh to its app
        axis (pre-packed channels carry no row structure to band-shard)."""
        if pipeline is not None:
            return OverlayPlan(
                grid=grid, batched=True, pipeline=pipeline,
                backend=self.backend, mesh=self.mesh,
                tile_rows=self.tile_rows, ingest=self.ingest,
            )
        return OverlayPlan(
            grid=grid, batched=True, fused=fused, radius=radius,
            backend=self.backend,
            mesh=self.mesh if fused else self.mesh.app_only(),
            tile_rows=self.tile_rows if fused else None,
            ingest=self.ingest,
        )

    def overlay_executable(self, plan: OverlayPlan) -> OverlayExecutable:
        """The compiled executable for ``plan``, through the fleet's LRU:
        built once per distinct plan (THE cache key -- backend, fusion,
        radius, devices and grid all live in it), shared by every padded
        tile shape via XLA's own shape-keyed jit cache."""
        fn = self._overlays.get(plan)
        if fn is not None:
            self.stats.overlay_cache_hits += 1
            return fn
        if self.faults is not None:
            # Compile faults fire on cache MISSES only: a cached plan
            # cannot fail to compile.  A failing build is never cached,
            # so the spec keeps firing until exhausted -- exactly like a
            # real deterministic compile error.
            self.faults.fire("compile", (f"plan:{plan.key()}",))
        fn = compile_plan(plan)
        self.stats.overlay_builds += 1
        for evicted in self._overlays.put(plan, fn):
            self.stats.evicted_plans.append(evicted.key())
        return fn

    def overlay_for(self, grid: GridSpec) -> OverlayExecutable:
        """The batched (pre-packed channels) executable for ``grid``."""
        return self.overlay_executable(self.plan_for_dispatch(grid, fused=False))

    def fused_overlay_for(self, grid: GridSpec, radius: int) -> OverlayExecutable:
        """The batched *fused-ingest* executable for ``grid``: raw frames
        in, line buffers formed inside the dispatch.  Ingest plans are
        runtime settings, so every app shares it."""
        return self.overlay_executable(
            self.plan_for_dispatch(grid, fused=True, radius=radius)
        )

    def overlay_executable_count(self, grid: Optional[GridSpec] = None) -> int:
        """Number of XLA executables compiled for a grid's batched overlays
        (fused and unfused combined; one per distinct padded tile shape, so
        1 when one path is in use and tiling is doing its job).  Returns -1
        when the running jax has no jit cache introspection (``_cache_size``
        is not public API); ``stats.overlay_builds`` is the version-stable
        counter."""
        grid = grid or self.default_grid
        counts = []
        for plan, fn in self._overlays._d.items():
            if plan.grid == grid:
                sizer = getattr(fn, "_cache_size", None)
                counts.append(int(sizer()) if callable(sizer) else -1)
        if not counts:
            return 0
        if any(c == -1 for c in counts):
            return -1
        return sum(counts)

    # -- request intake -------------------------------------------------------

    def submit(self, request: FleetRequest) -> int:
        """Queue one request; returns a ticket redeemed by :meth:`flush`.

        Mapping and input packing happen HERE, not at flush time: an
        unmappable app or a missing input raises immediately to its own
        submitter and can never poison a batch of other tenants' queued
        work.
        """
        if request.pipeline is not None:
            if request.app is not None:
                raise ValueError("give app= or pipeline=, not both")
            if request.image is None or request.inputs is not None:
                raise ValueError(
                    "pipeline requests take image= frames (every stage is "
                    "fused ingest), not inputs="
                )
        elif request.app is None:
            raise ValueError("exactly one of app= or pipeline= must be given")
        elif (request.inputs is None) == (request.image is None):
            raise ValueError("exactly one of inputs= or image= must be given")
        prepared = self._prepare(request)
        ticket = self._next_ticket
        self._next_ticket += 1
        self._pending.append((ticket, prepared))
        self.stats.submitted += 1
        return ticket

    def result(self, ticket: int) -> np.ndarray:
        """Redeem a flushed ticket (pops it from the retained results).
        A quarantined ticket raises its stored failure -- the typed
        QuarantinedError carrying the ticket and underlying cause."""
        if ticket in self._failures:
            raise self._failures.pop(ticket)
        try:
            return self._results.pop(ticket)
        except KeyError:
            raise KeyError(
                f"no retained result for ticket {ticket}: it was never "
                f"flushed, was already redeemed, or was evicted by the "
                f"retention bound (max_retained_results="
                f"{self.max_retained_results}); redeem tickets promptly or "
                f"raise the bound"
            ) from None

    def discard(self, ticket: int) -> None:
        """Drop a retained result without redeeming it (callers that consume
        flush()'s return value directly use this to release retention)."""
        self._results.pop(ticket, None)

    def _stacked_bank(self, grid: GridSpec, configs: List[VCGRAConfig],
                      fused: bool = False):
        """Stacked settings for a tenant set, cached across flushes when
        every config carries a cache identity (i.e. came through
        :meth:`config_for`).  For fused dispatches the bank also carries
        the stacked ingest-plan arrays (tap selects + const values)."""

        def build():
            stacked = VCGRAConfig.stack(configs)
            if not fused:
                return stacked
            plans = [c.ingest for c in configs]
            return stacked, IngestPlan.stack(plans, grid.dtype)

        keys = tuple(c.cache_key for c in configs)
        if any(k is None for k in keys):
            return build()
        bkey = (grid, keys, fused)
        stacked = self._banks.get(bkey)
        if stacked is not None:
            self.stats.stack_bank_hits += 1
            return stacked
        stacked = build()
        self._banks.put(bkey, stacked)
        return stacked

    def _canvas(self, shape: Tuple[int, ...], dtype,
                device=None) -> _PooledCanvas:
        """A zeroed frame canvas from the reuse pool (no per-flush numpy
        allocation in steady state).  Pool depth 2 under async ingest --
        the double buffer: flush k+1 packs one buffer while flush k's
        device_put of the other may still be copying; any pending ship is
        blocked on here, at reuse time, when it is long complete (sync
        mode materializes outputs before the next flush, so depth 1 and
        no pending ships).

        ``device`` keys the pool per mesh device for sharded async fleets
        (:meth:`_ship_sharded_frames`): each device's shard rotates its own
        depth-2 buffer pair, so one shard's still-copying ship never blocks
        another shard's fill.  Per-device reuse is counted separately in
        ``stats.canvas_pool_device_hits``."""
        key = (shape, np.dtype(dtype).str,
               None if device is None else device.id)
        pool = self._canvas_pool.get(key)
        if pool is None:
            pool = []
            self._canvas_pool.put(key, pool)
        depth = 2 if self.ingest == "async" else 1
        if len(pool) < depth:
            entry = _PooledCanvas(np.zeros(shape, dtype))
            pool.append(entry)
            return entry
        entry = pool.pop(0)
        pool.append(entry)
        self.stats.canvas_pool_hits += 1
        if device is not None:
            dkey = str(device.id)
            self.stats.canvas_pool_device_hits[dkey] = (
                self.stats.canvas_pool_device_hits.get(dkey, 0) + 1
            )
        if entry.pending is not None:
            try:
                jax.block_until_ready(entry.pending)
            except RuntimeError:
                # Donated and already consumed: execution only starts
                # once its operands materialize, so the transfer out of
                # this host buffer necessarily completed.
                pass
            entry.pending = None
        entry.buf.fill(0)
        return entry

    def _ship_sharded_frames(self, mesh, n_tile: int, Hb: int, Wb: int,
                             dtype, items) -> jnp.ndarray:
        """Per-device canvas embed + ship for sharded async fused
        dispatches: each mesh device gets its OWN pooled host buffer
        (keyed by the device -- i.e. by its 2-D ``(app, rows)`` placement
        -- in :meth:`_canvas`), its shard of the tenant frames is embedded
        there, and the shards are shipped independently with
        ``jax.device_put`` -- so per-shard ingest overlaps across devices
        instead of serializing through one whole-batch canvas whose
        single pending transfer gates every shard's next fill.  On a 1-D
        mesh the buffer is ``[n_tile/k, Hb, Wb]`` (the app shard); on a
        2-D mesh it is ``[n_tile/app, Hb/rows, Wb]`` -- device ``(i, j)``
        fills app shard i's j-th row band, the row split the dispatch
        executable shards over (``Hb`` was pre-rounded to a band
        multiple, see :meth:`_dispatch_fused`).  The shards are assembled
        into ONE mesh-sharded global array
        (``make_array_from_single_device_arrays`` over the plan's mesh,
        spec ``P(app)`` / ``P(app, rows)`` -- exactly the layout the
        shard_map executable expects, so jit inserts no resharding copy).
        Bitwise-identical to the single-canvas path.

        CPU devices ship a private copy (``jnp.array(copy=True)``) for the
        same reason :meth:`_dispatch_fused`'s unsharded path does: a
        zero-copy aliased device_put would let the pooled buffer's next
        ``fill(0)`` race still-unforced lazy outputs.  Real accelerators
        copy host->HBM by construction and skip the extra hop."""
        from repro.parallel.sharding import frame_sharding
        grid2d = mesh.devices if mesh.devices.ndim == 2 else (
            mesh.devices[:, None]
        )
        app_n, rows_n = grid2d.shape
        shard_n = n_tile // app_n
        band = Hb // rows_n
        entries = [[self._canvas((shard_n, band, Wb), dtype, device=d)
                    for d in row] for row in grid2d]
        for i, (_, p) in enumerate(items):
            H, W = p.hw
            ai, slot = i // shard_n, i % shard_n
            for rj in range(rows_n):
                h = min(H - rj * band, band)
                if h > 0:
                    entries[ai][rj].buf[slot, :h, :W] = (
                        p.payload[rj * band:rj * band + h]
                    )
        shards = []
        for ai in range(app_n):
            for rj in range(rows_n):
                e, d = entries[ai][rj], grid2d[ai, rj]
                if d.platform == "cpu":
                    shard = jax.device_put(jnp.array(e.buf, copy=True), d)
                else:
                    shard = jax.device_put(e.buf, d)
                e.pending = shard
                shards.append(shard)
        return jax.make_array_from_single_device_arrays(
            (n_tile, Hb, Wb), frame_sharding(mesh), shards,
        )

    def _fused_unpack(self, hws: Tuple[Tuple[int, int], ...], Hb: int, Wb: int):
        """Jit-once group unpack for async fused dispatches:
        ``ys [n_tile, K, Hb*Wb] -> tuple of [H, W] / [K, H, W]`` lazy
        outputs in item order, as a single device computation."""
        key = (hws, Hb, Wb)
        fn = self._unpack_fns.get(key)
        if fn is None:
            def unpack(ys):
                outs = []
                for i, (H, W) in enumerate(hws):
                    y = ys[i].reshape(-1, Hb, Wb)[:, :H, :W]
                    outs.append(y[0] if y.shape[0] == 1 else y)
                return tuple(outs)

            fn = jax.jit(unpack)
            self._unpack_fns.put(key, fn)
        return fn

    def _packed_unpack(self, batches: Tuple[int, ...],
                       hws: Tuple[Optional[Tuple[int, int]], ...]):
        """Jit-once group unpack for async unfused dispatches:
        ``ys [n_tile, K, batch] -> tuple`` of per-item ``[K, b]`` (or
        ``[H, W]`` / ``[K, H, W]`` for imaged items) lazy outputs -- one
        device computation, same rationale as :meth:`_fused_unpack`."""
        key = ("packed", batches, hws)
        fn = self._unpack_fns.get(key)
        if fn is None:
            def unpack(ys):
                outs = []
                for i, (b, hw) in enumerate(zip(batches, hws)):
                    y = ys[i, :, :b]
                    if hw is not None:
                        H, W = hw
                        y = y[:, : H * W].reshape(-1, H, W)
                        y = y[0] if y.shape[0] == 1 else y
                    outs.append(y)
                return tuple(outs)

            fn = jax.jit(unpack)
            self._unpack_fns.put(key, fn)
        return fn

    def _note_overlap(self, pack_started: float) -> None:
        """Credit host-side pack time to ``ingest_overlap_s`` when it ran
        concurrently with a still-executing previous dispatch -- and drop
        the in-flight probe once it observes completion, so a past flush's
        output buffers are not pinned for the sake of a stats probe.  The
        probe is truthful on every platform (see
        :class:`repro.core.ingest.ReadinessProbe`)."""
        if self._inflight is None:
            return
        if self._inflight.ready():
            self._inflight = None
        else:
            self.stats.ingest_overlap_s += time.perf_counter() - pack_started

    # -- batched execution ----------------------------------------------------

    def _prepare(self, request: FleetRequest) -> _Prepared:
        t0 = time.perf_counter()
        grid = request.grid or self.default_grid
        if request.pipeline is not None:
            prepared = self._prepare_pipeline(request, grid)
            self.timings["pack_s"] += time.perf_counter() - t0
            return prepared
        cfg = self.config_for(request.app, grid)
        if request.image is not None:
            image = np.asarray(request.image)
            if image.ndim != 2:
                raise ValueError(f"image must be [H, W], got shape {image.shape}")
            hw = tuple(image.shape)
            if cfg.ingest is not None:
                # Fused path: keep the RAW frame; line-buffer formation
                # happens inside the batched dispatch at flush time.
                prepared = _Prepared(grid, cfg, "image", image, hw)
                self.timings["pack_s"] += time.perf_counter() - t0
                return prepared
            # No ingest plan (a channel is neither tap nor const): fall
            # back to host-side tap packing so the request still runs.
            taps = app_lib.stencil_inputs(jnp.asarray(image))
            feed = {k: v for k, v in taps.items() if k in cfg.input_order}
        else:
            hw = None
            feed = request.inputs
        x = interpreter.pack_inputs(cfg, feed, grid.dtype)
        if x.ndim != 2:
            raise ValueError(f"fleet needs flat [channels, batch] inputs, got {x.shape}")
        prepared = _Prepared(
            grid, cfg, "channels", interpreter.pad_channels(x, grid.num_inputs), hw
        )
        self.timings["pack_s"] += time.perf_counter() - t0
        return prepared

    def _prepare_pipeline(self, request: FleetRequest,
                          grid: GridSpec) -> _Prepared:
        """Validate + map a chained request at submit time.  Every stage
        must carry an ingest plan (the chain is fused ingest end to end);
        a depth-1 chain demotes to the plain "image" kind so it batches
        and caches exactly like an ``app=`` request."""
        chain = list(request.pipeline)
        if not chain:
            raise ValueError("pipeline= must name at least one stage")
        image = np.asarray(request.image)
        if image.ndim != 2:
            raise ValueError(f"image must be [H, W], got shape {image.shape}")
        hw = tuple(image.shape)
        cfgs = [self.config_for(app, grid) for app in chain]
        for cfg in cfgs:
            if cfg.ingest is None:
                raise ValueError(
                    f"pipeline stage {cfg.app_name!r} has no ingest plan "
                    f"(a channel is neither stencil tap nor const); chains "
                    f"need fused-ingest stages end to end"
                )
        spec = PipelineSpec.chain(cfgs, request.out_channels)
        if spec.depth == 1:
            # The final stage's out_channel never selects anything (every
            # executor returns all K output channels), so a depth-1 chain
            # IS a plain fused request -- same plan key, same caches.
            return _Prepared(grid, cfgs[0], "image", image, hw)
        return _Prepared(grid, cfgs[0], "pipeline", image, hw, spec=spec)

    def _dispatch_fused(
        self, plan: OverlayPlan,
        items: List[Tuple[int, _Prepared]], out: Dict[int, np.ndarray],
    ) -> None:
        """One fused dispatch: raw frames -> outputs, line buffers inside.

        ``plan`` carries the execution axes (backend/mesh/tiling): the
        resilient flush passes the fleet's primary plan normally and a
        degraded sibling from :func:`repro.core.plan.fallback_chain` when
        the primary's circuit breaker is open -- same operands, same
        bitwise outputs, different executable.

        Frames are embedded top-left into one zero canvas [n_tile, Hb, Wb]
        (pow-2-bucketed sides, app axis rounded to batch_tile; reused from
        the canvas pool) on the HOST -- the dispatch is the only device
        operation.  The zero canvas right/below a frame is read by edge
        taps exactly like ``stencil_inputs``'s zero border, so the [H, W]
        slice of the output is bitwise identical to the unfused path.

        Under async ingest the canvas is shipped with ``jax.device_put``
        (NOT blocked on: the pool's depth-2 rotation defers that wait to
        the buffer's next reuse, by which time the copy is long done --
        see :class:`_PooledCanvas`), the executable *donates* it, and
        outputs are sliced lazily by one jitted group computation instead
        of materialized: the caller's first host read forces them, so
        packing of the next flush overlaps this flush's device execution.
        """
        t0 = time.perf_counter()
        fn = self.overlay_executable(plan)
        grid, radius = plan.grid, plan.radius
        n = len(items)
        n_tile = round_up(n, self._app_tile)
        Hb = pow2_bucket(max(p.hw[0] for _, p in items), self.min_image_side)
        Wb = pow2_bucket(max(p.hw[1] for _, p in items), self.min_image_side)
        if plan.mesh.rows > 1:
            # Row-sharded plans band-split Hb across the rows axis: round
            # it to a whole number of radius-floored bands so the sharded
            # ship path and the executable's in-spec agree on the band
            # split and the executable's own row padding is a no-op.
            Hb = row_band(Hb, plan.mesh.rows, radius) * plan.mesh.rows
        configs = [p.cfg for _, p in items]
        # Tile padding on the app axis: replay config[0] on a zero frame.
        configs += [configs[0]] * (n_tile - n)
        self.stats.padded_app_slots += n_tile - n
        self.stats.partial_tile_dispatches += 1 if n < n_tile else 0

        stacked, ingests = self._stacked_bank(grid, configs, fused=True)
        if self.ingest == "async" and fn.mesh is not None:
            # Sharded async: per-device pooled canvases, shipped shard by
            # shard and assembled app-sharded (see _ship_sharded_frames).
            frames = self._ship_sharded_frames(
                fn.mesh, n_tile, Hb, Wb, grid.dtype, items
            )
        elif self.ingest == "async":
            entry = self._canvas((n_tile, Hb, Wb), grid.dtype)
            for i, (_, p) in enumerate(items):
                H, W = p.hw
                entry.buf[i, :H, :W] = p.payload
            # copy=True by API contract (plain device_put MAY zero-copy
            # aligned numpy on CPU in some jax versions, which would let
            # the pooled buffer's next fill(0) race still-unforced lazy
            # outputs); the pending record defers the transfer wait to
            # the buffer's reuse two flushes later.
            frames = jnp.array(entry.buf, copy=True)
            entry.pending = frames
        else:
            entry = self._canvas((n_tile, Hb, Wb), grid.dtype)
            for i, (_, p) in enumerate(items):
                H, W = p.hw
                entry.buf[i, :H, :W] = p.payload
            frames = jnp.asarray(entry.buf)
        # The canvas embed + bank build + ship above are host-side pack
        # work; only the overlay execution below counts as dispatch.
        self._note_overlap(t0)
        self.timings["pack_s"] += time.perf_counter() - t0
        t0 = time.perf_counter()
        self._pre_dispatch(plan, items)
        ys = fn(stacked, ingests, frames)
        ys = self._corrupt_outputs(plan, items, ys)
        self.stats.dispatches += 1
        self.stats.fused_dispatches += 1
        self.stats.stamp_dispatch(fn.plan, f"n{n_tile}x{Hb}x{Wb}")
        self.stats.executed += n
        if self.ingest == "async":
            unpack = self._fused_unpack(tuple(p.hw for _, p in items), Hb, Wb)
            for (ticket, _), y in zip(items, unpack(ys)):
                out[ticket] = y
            self._inflight = ReadinessProbe(ys)
        else:
            for i, (ticket, p) in enumerate(items):
                H, W = p.hw
                y = np.asarray(ys[i]).reshape((-1, Hb, Wb))[:, :H, :W]
                out[ticket] = y[0] if y.shape[0] == 1 else y
        self.timings["dispatch_s"] += time.perf_counter() - t0

    def _dispatch_pipeline(
        self, plan: OverlayPlan,
        items: List[Tuple[int, _Prepared]], out: Dict[int, np.ndarray],
    ) -> None:
        """One chained dispatch: raw frames -> final-stage outputs, every
        intermediate device-resident.

        Frames embed, bucket and tile exactly like :meth:`_dispatch_fused`
        (same pow-2 canvas, same app-tile rounding, same async canvas
        pool/ship/lazy-unpack machinery) -- the chain only changes the
        executable (a pipeline :class:`OverlayPlan` keyed ``pipe{hash}``)
        and adds two operands: the per-stage settings banks (stacked per
        stage through the same bank cache single-stage dispatches use) and
        the per-app true frame extents ``hw`` that executors use to
        re-mask intermediates.  Padded app slots replay item 0's chain on
        a zero frame and are sliced off -- outputs are bitwise identical
        to per-stage sequential flushes.

        ``plan`` arrives pre-built (the app-tile-padded spec tuple IS a
        plan axis), normally the primary from :meth:`_primary_plan`, or a
        degraded fallback sibling when the primary's breaker is open."""
        t0 = time.perf_counter()
        grid = plan.grid
        fn = self.overlay_executable(plan)
        n = len(items)
        n_tile = len(plan.pipeline)
        specs = list(plan.pipeline)
        radii = specs[0].radii
        Hb = pow2_bucket(max(p.hw[0] for _, p in items), self.min_image_side)
        Wb = pow2_bucket(max(p.hw[1] for _, p in items), self.min_image_side)
        if plan.mesh.rows > 1:
            Hb = row_band(Hb, plan.mesh.rows, plan.radius) * plan.mesh.rows
        self.stats.padded_app_slots += n_tile - n
        self.stats.partial_tile_dispatches += 1 if n < n_tile else 0

        stage_settings = []
        for si in range(len(radii)):
            stacked, ingests = self._stacked_bank(
                grid, [s.stages[si].config for s in specs], fused=True
            )
            out_ch = jnp.asarray(
                [s.stages[si].out_channel for s in specs], jnp.int32
            )
            stage_settings.append((stacked, ingests, out_ch))
        stage_settings = tuple(stage_settings)
        hw = np.full((n_tile, 2), (Hb, Wb), np.int32)
        for i, (_, p) in enumerate(items):
            hw[i] = p.hw
        hw = jnp.asarray(hw)

        if self.ingest == "async" and fn.mesh is not None:
            frames = self._ship_sharded_frames(
                fn.mesh, n_tile, Hb, Wb, grid.dtype, items
            )
        elif self.ingest == "async":
            entry = self._canvas((n_tile, Hb, Wb), grid.dtype)
            for i, (_, p) in enumerate(items):
                H, W = p.hw
                entry.buf[i, :H, :W] = p.payload
            frames = jnp.array(entry.buf, copy=True)
            entry.pending = frames
        else:
            entry = self._canvas((n_tile, Hb, Wb), grid.dtype)
            for i, (_, p) in enumerate(items):
                H, W = p.hw
                entry.buf[i, :H, :W] = p.payload
            frames = jnp.asarray(entry.buf)
        self._note_overlap(t0)
        self.timings["pack_s"] += time.perf_counter() - t0
        t0 = time.perf_counter()
        self._pre_dispatch(plan, items)
        ys = fn(stage_settings, hw, frames)
        ys = self._corrupt_outputs(plan, items, ys)
        self.stats.dispatches += 1
        self.stats.fused_dispatches += 1
        self.stats.pipeline_dispatches += 1
        self.stats.stamp_dispatch(fn.plan, f"n{n_tile}x{Hb}x{Wb}")
        self.stats.executed += n
        if self.ingest == "async":
            unpack = self._fused_unpack(tuple(p.hw for _, p in items), Hb, Wb)
            for (ticket, _), y in zip(items, unpack(ys)):
                out[ticket] = y
            self._inflight = ReadinessProbe(ys)
        else:
            for i, (ticket, p) in enumerate(items):
                H, W = p.hw
                y = np.asarray(ys[i]).reshape((-1, Hb, Wb))[:, :H, :W]
                out[ticket] = y[0] if y.shape[0] == 1 else y
        self.timings["dispatch_s"] += time.perf_counter() - t0

    def _dispatch_packed(
        self, plan: OverlayPlan,
        items: List[Tuple[int, _Prepared]], out: Dict[int, np.ndarray],
    ) -> None:
        """One unfused dispatch over host-packed [channels, batch] inputs
        (named-channel requests and image apps without an ingest plan).
        Async ingest donates the channel stack and unpacks lazily, same as
        the fused path (the stack is rebuilt per flush, so donation is
        always safe).  ``plan`` carries the execution axes, exactly like
        :meth:`_dispatch_fused`."""
        t0 = time.perf_counter()
        grid = plan.grid
        fn = self.overlay_executable(plan)
        n = len(items)
        n_tile = round_up(n, self._app_tile)
        batch = pow2_bucket(max(p.payload.shape[-1] for _, p in items),
                            self.min_pixel_batch)
        configs = [p.cfg for _, p in items]
        xs = interpreter.pad_batches([p.payload for _, p in items], batch)
        # Tile padding on the app axis: replay config[0] on zero pixels.
        configs += [configs[0]] * (n_tile - n)
        xs += [jnp.zeros_like(xs[0])] * (n_tile - n)
        self.stats.padded_app_slots += n_tile - n
        self.stats.partial_tile_dispatches += 1 if n < n_tile else 0
        stacked = self._stacked_bank(grid, configs)
        xstack = jnp.stack(xs)
        self._note_overlap(t0)
        self.timings["pack_s"] += time.perf_counter() - t0

        t0 = time.perf_counter()
        self._pre_dispatch(plan, items)
        ys = fn(stacked, xstack)
        ys = self._corrupt_outputs(plan, items, ys)
        self.stats.dispatches += 1
        self.stats.stamp_dispatch(fn.plan, f"n{n_tile}xb{batch}")
        self.stats.executed += n
        if self.ingest == "async":
            unpack = self._packed_unpack(
                tuple(p.payload.shape[-1] for _, p in items),
                tuple(p.hw for _, p in items),
            )
            for (ticket, _), y in zip(items, unpack(ys)):
                out[ticket] = y
            self._inflight = ReadinessProbe(ys)
        else:
            for i, (ticket, p) in enumerate(items):
                y = np.asarray(ys[i, :, : p.payload.shape[-1]])
                if p.hw is not None:
                    H, W = p.hw
                    y = y[:, : H * W].reshape((-1, H, W))
                    y = y[0] if y.shape[0] == 1 else y
                out[ticket] = y
        self.timings["dispatch_s"] += time.perf_counter() - t0

    # -- resilient dispatch (PR 10) -------------------------------------------

    def _primary_plan(self, key: Tuple,
                      items: List[Tuple[int, _Prepared]]) -> OverlayPlan:
        """The fleet-configured plan of one flush group.  Pipeline groups
        bake their app-tile-padded spec tuple into the plan (padding is
        executable shape), so the plan is recomputed per work set during
        bisection."""
        grid = key[0]
        if key[1] == "image":
            return self.plan_for_dispatch(grid, fused=True, radius=key[2])
        if key[1] == "pipe":
            n_tile = round_up(len(items), self._app_tile)
            specs = [p.spec for _, p in items]
            specs += [specs[0]] * (n_tile - len(items))
            return self.plan_for_dispatch(grid, fused=True,
                                          pipeline=tuple(specs))
        return self.plan_for_dispatch(grid, fused=False)

    def _dispatch_plan(self, plan: OverlayPlan, kind: str,
                       items: List[Tuple[int, _Prepared]],
                       out: Dict[int, np.ndarray]) -> None:
        if kind == "image":
            self._dispatch_fused(plan, items, out)
        elif kind == "pipe":
            self._dispatch_pipeline(plan, items, out)
        else:
            self._dispatch_packed(plan, items, out)

    def _candidates(self, plan: OverlayPlan) -> Tuple[OverlayPlan, ...]:
        """``(primary, *fallback_chain)`` with the chain memoized per plan
        (plans are frozen/hashable; building the chain costs a few
        dataclass constructions we don't want per flush)."""
        chain = self._chain_cache.get(plan)
        if chain is None:
            chain = (plan, *fallback_chain(plan))
            self._chain_cache.put(plan, chain)
        return chain

    def _fault_tokens(self, plan: OverlayPlan,
                      items: List[Tuple[int, _Prepared]]) -> List[str]:
        """Context tokens a FaultSpec's ``match=`` is tested against:
        the plan key plus every rider's ticket and app name (bracketed so
        ``<ticket:1>`` never substring-matches ``<ticket:12>``)."""
        tokens = [f"plan:{plan.key()}"]
        for ticket, p in items:
            tokens.append(f"<ticket:{ticket}>")
            tokens.append(f"<app:{p.cfg.app_name}>")
        return tokens

    def _pre_dispatch(self, plan: OverlayPlan,
                      items: List[Tuple[int, _Prepared]]) -> None:
        """Fire the stall and dispatch hook points (no-op without an
        injector: one attribute check, the zero-overhead contract)."""
        if self.faults is None:
            return
        tokens = self._fault_tokens(plan, items)
        self.faults.fire("transfer_stall", tokens)
        self.faults.fire("dispatch", tokens)

    def _corrupt_outputs(self, plan: OverlayPlan,
                         items: List[Tuple[int, _Prepared]], ys):
        """Apply armed ``nan_output`` corruption to the dispatch's output
        batch (inexact dtypes only: integer fabrics cannot encode NaN, so
        the output guard scopes itself the same way)."""
        if self.faults is None:
            return ys
        if not jnp.issubdtype(jnp.asarray(ys).dtype, jnp.inexact):
            return ys
        slots = self.faults.corrupt_slots(
            [[f"<ticket:{t}>", f"<app:{p.cfg.app_name}>"] for t, p in items]
        )
        for i in slots:
            ys = ys.at[i].set(jnp.nan)
        return ys

    def _guard_outputs(self, got: Dict[int, Any],
                       items: List[Tuple[int, _Prepared]],
                       ) -> List[Tuple[int, _Prepared]]:
        """The NaN/Inf output guard: pops poisoned tickets out of ``got``
        and returns their work items (the resilient loop re-dispatches
        just those).  Float outputs only; forces async lazy outputs, which
        is why the guard defaults on only when faults are installed."""
        if not self._guard:
            return []
        bad = []
        for ticket, prep in items:
            y = got.get(ticket)
            if y is None:
                continue
            arr = np.asarray(y)
            if (np.issubdtype(arr.dtype, np.floating)
                    and not np.isfinite(arr).all()):
                bad.append((ticket, prep))
                del got[ticket]
        return bad

    def _quarantine(self, ticket: int, prep: _Prepared,
                    cause: Optional[BaseException]) -> None:
        """Fail ONE isolated request: record a QuarantinedError against
        its ticket (raised by result(), drained by front-ends via
        pop_failures) -- the batch it rode dispatches on without it."""
        self.stats.quarantined_requests += 1
        exc = QuarantinedError(ticket, app=prep.cfg.app_name, cause=cause)
        if cause is not None:
            exc.__cause__ = cause
        self._failures[ticket] = exc
        while len(self._failures) > self.max_retained_results:
            self._failures.popitem(last=False)

    def _dispatch_resilient(self, key: Tuple,
                            items: List[Tuple[int, _Prepared]],
                            out: Dict[int, np.ndarray]) -> None:
        """One flush group through the self-healing ladder:

        1. the primary plan, retried with deterministic backoff on
           *transient* failures (``RetryPolicy.should_retry``);
        2. on exhaustion/non-transient failure -- or when the primary's
           circuit breaker is open -- each plan of the fallback chain in
           turn (every step bitwise-equal by construction, each behind
           its own breaker);
        3. outputs through the NaN/Inf guard: clean tickets commit, and
           only the poisoned ones go around again;
        4. if EVERY plan fails the whole work set, bisect: halves recurse
           independently, so poison is isolated to exactly the offending
           request(s), whose tickets fail with QuarantinedError while all
           survivors dispatch normally.

        Breaker successes are deferred to flush end (_settle_flush): a
        straggler flush converts them into breaker failures when the
        fleet is armed for it."""
        kind = key[1]
        primary = self._primary_plan(key, items)
        candidates = self._candidates(primary)
        last_exc: Optional[BaseException] = None
        tried_any = False
        for ci, cand in enumerate(candidates):
            br = self.breakers.breaker(cand.key())
            last_resort = ci == len(candidates) - 1 and not tried_any
            if not br.allow() and not last_resort:
                continue
            tried_any = True
            for attempt in range(self.retry.max_attempts):
                if attempt:
                    self.stats.retries += 1
                    time.sleep(self.retry.backoff_s(attempt - 1))
                got: Dict[int, Any] = {}
                try:
                    self._dispatch_plan(cand, kind, items, got)
                    bad = self._guard_outputs(got, items)
                except Exception as exc:  # noqa: BLE001 -- routed: retried here, then degraded down the fallback chain or quarantined to the offending ticket below
                    last_exc = exc
                    br.record_failure()
                    if self.retry.should_retry(exc):
                        continue
                    break
                if bad:
                    out.update(got)
                    self.stats.guard_failures += len(bad)
                    br.record_failure("nan_guard")
                    last_exc = PoisonedOutputError(
                        f"{len(bad)}/{len(items)} outputs of plan "
                        f"{cand.key()} failed the NaN/Inf guard"
                    )
                    if len(bad) < len(items):
                        # Survivors committed; the poisoned subset takes
                        # the whole ladder again from the primary.
                        self._dispatch_resilient(key, bad, out)
                        return
                    continue  # whole batch poisoned: burn a retry
                out.update(got)
                self._flush_successes.append((br, cand.key()))
                if ci:   # not the primary (by position: the memoized
                    # chain returns value-equal but distinct plan objects)
                    self.stats.fallback_dispatches += 1
                return
        if len(items) == 1:
            ticket, prep = items[0]
            self._quarantine(ticket, prep, last_exc)
            return
        mid = len(items) // 2
        self._dispatch_resilient(key, items[:mid], out)
        self._dispatch_resilient(key, items[mid:], out)

    def _settle_flush(self, dispatched: bool, flush_s: float) -> None:
        """Flush epilogue: feed the wall time to the HeartbeatMonitor and
        settle the deferred breaker successes -- a straggler flush counts
        against every plan it dispatched (when armed: faults/breakers/
        heartbeat explicitly installed), otherwise each plan records its
        success."""
        straggler = False
        if dispatched and self.heartbeat is not None:
            straggler = self.heartbeat.record(self.stats.dispatches, flush_s)
            if straggler:
                self.stats.straggler_flushes += 1
        punish = straggler and self._straggler_trips_breaker
        for br, _key in self._flush_successes:
            if punish:
                br.record_failure("straggler")
            else:
                br.record_success()
        self._flush_successes = []

    def pop_failures(self) -> Dict[int, BaseException]:
        """Drain per-ticket failures (QuarantinedError etc.) recorded by
        resilient flushes -- front-ends route each to its own JobHandle.
        Tickets not drained here raise from :meth:`result`."""
        if not self._failures:
            return {}
        failures = dict(self._failures)
        self._failures.clear()
        return failures

    def install_faults(self, faults) -> None:
        """Arm an injector after construction (the streaming front-end
        installs its injector into the fleet it owns).  Installing faults
        also arms the NaN/Inf output guard and the straggler->breaker
        coupling, same as passing ``faults=`` at construction."""
        self.faults = faults
        self._guard = True
        self._straggler_trips_breaker = True

    def cancel_pending(self) -> int:
        """Drop every submitted-but-unflushed request (no results, no
        failures recorded); returns how many were dropped.  The streaming
        supervisor calls this after a worker crash so a restarted worker
        never re-serves tickets whose handles were already failed."""
        n = len(self._pending)
        self._pending.clear()
        return n

    def pending_count(self) -> int:
        """Requests submitted but not yet flushed (the continuous-batching
        scheduler polls this to decide between waiting for a full tile and
        launching a partial one)."""
        return len(self._pending)

    def flush(self, limit: Optional[int] = None) -> Dict[int, np.ndarray]:
        """Run pending requests; one overlay dispatch per grid group
        (two when a group mixes fused image requests with named-channel
        requests).

        ``limit`` is the partial-tile hook for continuous-batching
        schedulers: only the oldest ``limit`` pending requests are
        dispatched (in submit order) and the rest stay queued for a later
        flush -- a deadline-pressed scheduler launches a partially-filled
        tile now without dragging every newly-arrived request into it.
        ``None`` keeps the drain-everything behavior.

        Per-flush latency stamps land in ``timings``: ``flush_started``
        (perf_counter at dispatch start, shared by every request in the
        flush -- front-ends split per-request queue wait from flush time
        with it) and ``flush_s`` (wall duration of this flush).

        Returns {ticket: output}; image requests come back as [H, W] (or
        [num_outputs, H, W]), channel requests as [num_outputs, batch].
        Sync ingest returns eager numpy; async ingest returns lazy jax
        arrays (bitwise-identical values, forced on first host read) so
        the device keeps executing while the caller packs its next batch.
        """
        if limit is None or limit >= len(self._pending):
            pending, self._pending = self._pending, []
        else:
            if limit < 1:
                raise ValueError(f"flush limit must be >= 1, got {limit}")
            pending, self._pending = self._pending[:limit], self._pending[limit:]
        # Group by (grid, path): fused image groups additionally key on the
        # stencil radius, which fixes the tap-bank layout of the executable.
        groups: Dict[Tuple, List[Tuple[int, _Prepared]]] = {}
        for ticket, p in pending:
            if p.kind == "image":
                key = (p.grid, "image", p.cfg.ingest.radius)
            elif p.kind == "pipeline":
                # Chains batch together when their per-stage radii agree
                # (depth and radii are executable shape; the specs
                # themselves ride the plan as per-tenant settings).
                key = (p.grid, "pipe", p.spec.radii)
            else:
                key = (p.grid, "channels")
            groups.setdefault(key, []).append((ticket, p))

        out: Dict[int, np.ndarray] = {}
        t0 = time.perf_counter()
        self.timings["flush_started"] = t0
        self._flush_successes = []
        for key, items in groups.items():
            self._dispatch_resilient(key, items, out)
        flush_s = time.perf_counter() - t0
        self.timings["flush_s"] = flush_s
        self._settle_flush(bool(groups), flush_s)
        self._results.update(out)
        while len(self._results) > self.max_retained_results:
            self._results.popitem(last=False)
        return out

    def run_many(self, requests: Sequence[FleetRequest]) -> List[np.ndarray]:
        """submit() + flush() convenience; outputs in request order (and
        released from retention, so nothing stays behind).  Consumes the
        flush() return value directly -- correct for any batch size, even
        beyond ``max_retained_results``."""
        tickets = [self.submit(r) for r in requests]
        outs = self.flush()
        failures = self.pop_failures()
        for t in tickets:
            self.discard(t)
        for t in tickets:
            if t in failures:
                raise failures[t]
        return [outs[t] for t in tickets]
