"""Self-healing primitives for the serving stack: typed errors, retry
policy, and per-plan circuit breakers.

The fleet's plan-cache architecture (``OverlayPlan`` -> ``compile_plan``,
one frozen hashable key per executable) is what makes *graceful
degradation* cheap: when a plan keeps failing, the fleet re-dispatches
the same work on a degraded sibling plan (``pallas -> xla``, 2-D mesh ->
app-only -> single device, tiled -> untiled; see
:func:`repro.core.plan.fallback_chain`) and the degraded executable is
just another cache entry -- every step of the chain is bitwise-equal to
the primary by the parity guarantees each axis already carries.  This
module contributes the three policy pieces the fleet threads around that
chain:

* a typed exception hierarchy (:class:`ServiceError` and friends) shared
  by the runtime and serving layers -- defined HERE, at the bottom of the
  import graph, because ``runtime.fleet`` raises them and
  ``serve.service`` re-exports them as its public surface (serve imports
  runtime, never the reverse);
* :class:`RetryPolicy` -- bounded attempts with a *deterministic*
  exponential backoff schedule, retrying only transient failure classes;
* :class:`CircuitBreaker` / :class:`BreakerBoard` -- per-plan-key
  CLOSED -> OPEN -> HALF_OPEN state machines with an injectable clock,
  recording every transition for ``FleetStats.breaker_events``.

Nothing here imports jax: the policies are pure host-side control flow,
cheap enough to sit on the dispatch path unconditionally.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple


# -- typed exception hierarchy ------------------------------------------------
#
# ServiceError is the base every serving-path failure derives from, so a
# caller can catch one class and still tell admission-time rejections
# (AdmissionError, raised before a ticket exists) from post-admission
# losses (DispatchError and subclasses, always routed to the ticket or
# JobHandle that owns them -- never to an unrelated tenant).


class ServiceError(RuntimeError):
    """Base of every typed serving failure (admission, dispatch, timeout)."""


class DispatchError(ServiceError):
    """An admitted request was lost or failed after submit: the batch it
    rode crashed, the worker serving it died mid-dispatch, or the fleet
    exhausted its plans.  Always delivered to the owning ticket/handle."""


class QuarantinedError(DispatchError):
    """A request isolated by bisection quarantine: every plan in the
    fallback chain failed on it (alone, in a batch of one), so the fleet
    fails THIS ticket and serves the survivors.  Carries the quarantined
    ticket and the last underlying cause."""

    def __init__(self, ticket: int, app: str = "", cause: Optional[BaseException] = None):
        self.ticket = int(ticket)
        self.app = app
        self.cause = cause
        detail = f" (app {app!r})" if app else ""
        why = f": {cause!r}" if cause is not None else ""
        super().__init__(
            f"request {ticket}{detail} quarantined after exhausting the "
            f"retry budget on every plan in the fallback chain{why}"
        )


class JobTimeout(ServiceError, TimeoutError):
    """A JobHandle.result(timeout=) expired, or a request blew its
    per-request hard timeout while queued.  Subclasses TimeoutError so
    pre-hierarchy callers catching the stdlib class keep working."""


class TransientError(RuntimeError):
    """Marker base: failures of this class may succeed on retry (the
    retry policy's default transient classification)."""


class PoisonedOutputError(DispatchError, TransientError):
    """The NaN/Inf output guard rejected a dispatch's result for one or
    more requests.  Transient by default: a re-dispatch re-rolls
    rate-based corruption; persistent poison ends in quarantine."""

    transient = True


def _check_positive(name: str, value: float) -> None:
    if value <= 0:
        raise ValueError(f"{name} must be > 0, got {value}")


# -- retry policy -------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded retries with a deterministic exponential backoff schedule.

    ``backoff_s(i)`` is a pure function of the retry index ``i`` (0 for
    the first retry): ``min(base * multiplier**i, max)``.  No jitter --
    determinism is a feature here (the chaos suite asserts exact
    schedules), and the fleet's retries are per-flush serialized so
    thundering herds cannot form.

    ``should_retry`` gates WHICH failures burn attempts: only transient
    classes (:class:`TransientError` subclasses, or any exception carrying
    an explicit boolean ``transient`` attribute, e.g. an injected fault).
    Everything else fails over to the next plan in the fallback chain
    immediately -- retrying a deterministic error is pure added latency.
    """

    max_attempts: int = 3
    backoff_base_s: float = 0.005
    backoff_multiplier: float = 2.0
    backoff_max_s: float = 0.1

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError(f"max_attempts must be >= 1, got {self.max_attempts}")
        _check_positive("backoff_base_s", self.backoff_base_s)
        _check_positive("backoff_multiplier", self.backoff_multiplier)
        _check_positive("backoff_max_s", self.backoff_max_s)

    def backoff_s(self, retry_index: int) -> float:
        return min(
            self.backoff_base_s * self.backoff_multiplier ** retry_index,
            self.backoff_max_s,
        )

    def schedule(self) -> Tuple[float, ...]:
        """The full deterministic backoff schedule (one entry per retry)."""
        return tuple(self.backoff_s(i) for i in range(self.max_attempts - 1))

    def should_retry(self, exc: BaseException) -> bool:
        explicit = getattr(exc, "transient", None)
        if explicit is not None:
            return bool(explicit)
        return isinstance(exc, TransientError)


# -- circuit breaker ----------------------------------------------------------

CLOSED = "closed"
OPEN = "open"
HALF_OPEN = "half_open"


class CircuitBreaker:
    """One plan key's CLOSED -> OPEN -> HALF_OPEN state machine.

    CLOSED counts *consecutive* failures; at ``failure_threshold`` the
    breaker opens (the fleet stops offering the plan traffic).  After
    ``cooldown_s`` the next :meth:`allow` admits exactly ONE half-open
    probe; its outcome closes the breaker (recovered) or re-opens it for
    another cooldown.  The clock is injectable so transition tests never
    sleep.  Every transition is appended to ``events`` (a list shared
    with the owning :class:`BreakerBoard`, which ``FleetStats`` exposes).
    """

    def __init__(
        self,
        key: str,
        failure_threshold: int = 3,
        cooldown_s: float = 0.25,
        clock: Callable[[], float] = time.monotonic,
        events: Optional[List[Dict[str, Any]]] = None,
    ):
        if failure_threshold < 1:
            raise ValueError(f"failure_threshold must be >= 1, got {failure_threshold}")
        _check_positive("cooldown_s", cooldown_s)
        self.key = key
        self.failure_threshold = int(failure_threshold)
        self.cooldown_s = float(cooldown_s)
        self._clock = clock
        self.events = events if events is not None else []
        self.state = CLOSED
        self.consecutive_failures = 0
        self._opened_at = 0.0

    def _transition(self, state: str, event: str) -> None:
        self.state = state
        self.events.append({
            "plan": self.key,
            "event": event,
            "t": self._clock(),
            "consecutive_failures": self.consecutive_failures,
        })

    def allow(self) -> bool:
        """May this plan take traffic right now?  OPEN breakers admit one
        half-open probe per cooldown window."""
        if self.state == CLOSED:
            return True
        if self.state == OPEN:
            if self._clock() - self._opened_at >= self.cooldown_s:
                self._transition(HALF_OPEN, "half_open")
                return True
            return False
        # HALF_OPEN: the single probe is already in flight this window.
        return False

    def record_success(self) -> None:
        if self.state == HALF_OPEN:
            self.consecutive_failures = 0
            self._transition(CLOSED, "close")
        else:
            self.consecutive_failures = 0

    def record_failure(self, reason: str = "dispatch") -> None:
        self.consecutive_failures += 1
        if self.state == HALF_OPEN:
            self._opened_at = self._clock()
            self._transition(OPEN, f"reopen:{reason}")
        elif self.state == CLOSED and (
            self.consecutive_failures >= self.failure_threshold
        ):
            self._opened_at = self._clock()
            self._transition(OPEN, f"open:{reason}")


class BreakerBoard:
    """Lazily-built registry of per-plan-key breakers sharing one event
    log and one (injectable) clock.  The fleet keys breakers by
    ``OverlayPlan.key()``, so every candidate in a fallback chain trips
    and recovers independently."""

    def __init__(
        self,
        failure_threshold: int = 3,
        cooldown_s: float = 0.25,
        clock: Callable[[], float] = time.monotonic,
    ):
        self.failure_threshold = int(failure_threshold)
        self.cooldown_s = float(cooldown_s)
        self._clock = clock
        self._breakers: Dict[str, CircuitBreaker] = {}
        self.events: List[Dict[str, Any]] = []

    def breaker(self, key: str) -> CircuitBreaker:
        br = self._breakers.get(key)
        if br is None:
            br = CircuitBreaker(
                key, self.failure_threshold, self.cooldown_s,
                clock=self._clock, events=self.events,
            )
            self._breakers[key] = br
        return br

    def states(self) -> Dict[str, str]:
        return {key: br.state for key, br in self._breakers.items()}

    def all_closed(self) -> bool:
        return all(br.state == CLOSED for br in self._breakers.values())
