"""Crash-restart state and straggler detection for single-process runs.

Honest scope (this module long claimed "1000+-node runs"; it has never
been more than the local building blocks):

* ``RunState`` + ``resume_or_init``: crash-restart protocol on top of the
  atomic checkpointer -- a restarted job resumes from the newest committed
  step; torn/partial checkpoints are skipped and garbage-collected.
  Exercised in-process only; there is no multi-host coordinator here.
* ``HeartbeatMonitor``: wall-clock duration tracker with a robust
  (median * k) straggler threshold.  PR 10 wired it into the serving
  path: ``PixieFleet._settle_flush`` feeds every flush's wall time in,
  and a flagged straggler counts as a circuit-breaker failure against
  the plans that flush dispatched (when the fleet is armed for
  resilience) -- see :mod:`repro.runtime.resilience`.
* ``ElasticPlan``: DEPRECATED.  It predates the serving stack and plans
  LM-style (data, model) meshes that nothing here dispatches.  For
  degrading a *serving* plan when capacity changes, use the bitwise-safe
  ladder in :func:`repro.core.plan.fallback_chain` (which steps
  ``MeshSpec`` down the same way a breaker fallback does).
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.checkpoint import Checkpointer


@dataclasses.dataclass
class RunState:
    step: int
    tree: object            # {"params": ..., "opt": ...}
    resumed: bool


def resume_or_init(
    ckpt: Checkpointer,
    init_fn: Callable[[], object],
    like=None,
    shardings=None,
) -> RunState:
    """Restart protocol: newest committed checkpoint wins; otherwise init."""
    ckpt.cleanup_tmp()
    template = like
    if template is None:
        template = init_fn()
        step, tree = ckpt.restore_latest(template, shardings)
        if step is None:
            return RunState(step=0, tree=template, resumed=False)
        return RunState(step=step, tree=tree, resumed=True)
    step, tree = ckpt.restore_latest(template, shardings)
    if step is None:
        return RunState(step=0, tree=init_fn(), resumed=False)
    return RunState(step=step, tree=tree, resumed=True)


class HeartbeatMonitor:
    """Step-time heartbeats with straggler detection.

    In a real deployment each host reports its step barrier time; here the
    same statistics run over whatever durations are fed in.  A step (or
    host) is a straggler when its duration exceeds ``factor`` x the
    rolling median of the last ``window`` samples.
    """

    def __init__(self, window: int = 32, factor: float = 3.0):
        self.window = window
        self.factor = factor
        self.durations: List[float] = []
        self.stragglers: List[Tuple[int, float, float]] = []
        self._t0: Optional[float] = None

    def start(self) -> None:
        self._t0 = time.perf_counter()

    def stop(self, step: int) -> float:
        assert self._t0 is not None, "start() not called"
        dt = time.perf_counter() - self._t0
        self._t0 = None
        self.record(step, dt)
        return dt

    def record(self, step: int, duration: float) -> bool:
        """Returns True if `duration` is flagged as a straggler."""
        hist = self.durations[-self.window :]
        self.durations.append(duration)
        if len(hist) >= 8:
            med = float(np.median(hist))
            if duration > self.factor * med:
                self.stragglers.append((step, duration, med))
                return True
        return False

    def throughput(self, tokens_per_step: int) -> float:
        if not self.durations:
            return 0.0
        return tokens_per_step / float(np.median(self.durations))


@dataclasses.dataclass
class ElasticPlan:
    """DEPRECATED re-mesh decision when the healthy device count changes.

    Plans LM-style (data, model) meshes that no longer match anything the
    overlay runtime dispatches.  Use
    :func:`repro.core.plan.fallback_chain` /
    :class:`repro.parallel.axes.MeshSpec` for serving-plan degradation.
    """

    old_shape: Tuple[int, ...]
    new_devices: int
    axis_names: Tuple[str, ...]

    def __post_init__(self):
        warnings.warn(
            "ElasticPlan is deprecated: it plans LM-style (data, model) "
            "meshes the overlay runtime never dispatches; use "
            "repro.core.plan.fallback_chain / MeshSpec degradation instead",
            DeprecationWarning, stacklevel=2,
        )

    def plan(self) -> Optional[Tuple[int, ...]]:
        """Largest mesh of the same rank that fits `new_devices`, keeping
        the model axis fixed (TP degree is a property of the weights) and
        shrinking data-parallel axes.  None if impossible."""
        model = self.old_shape[-1]
        if self.new_devices < model:
            return None
        data_total = self.new_devices // model
        if len(self.old_shape) == 2:
            return (data_total, model)
        # (pod, data, model): fold pods into data if pods no longer full
        pods = min(self.old_shape[0], max(1, data_total // self.old_shape[1]))
        data = data_total // pods
        return (pods, data, model)

    def can_restore(self) -> bool:
        return self.plan() is not None
