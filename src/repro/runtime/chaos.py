"""Deterministic fault injection for the serving stack.

A :class:`FaultInjector` is installed with ``PixieFleet(faults=...)`` /
``StreamingFrontend(faults=...)`` and fires at five named hook points at
layer boundaries:

========================  ====================================================
hook point                where it fires
========================  ====================================================
``"compile"``             ``PixieFleet.overlay_executable`` on a plan-cache
                          miss, before ``compile_plan`` runs (a cached plan
                          cannot fail to compile, so hits never fire)
``"dispatch"``            inside each ``PixieFleet._dispatch_*``, immediately
                          before the overlay executable is invoked
``"nan_output"``          after a dispatch returns: matched app slots of the
                          output batch are overwritten with NaN (inexact
                          dtypes only -- integer fabrics cannot encode NaN,
                          so the spec is a no-op there)
``"transfer_stall"``      same site as ``"dispatch"``, but sleeps
                          ``delay_s`` instead of raising -- the straggler
                          that ``HeartbeatMonitor`` exists to catch
``"worker_death"``        top of the ``StreamingFrontend`` worker loop --
                          the supervisor must restart the thread and strand
                          no ``JobHandle``
========================  ====================================================

Specs are *deterministic and seedable*: all randomness comes from one
``random.Random(seed)``, so a chaos run replays exactly given the same
dispatch schedule.  ``match=`` restricts a spec to dispatches whose
context tokens contain one of the given substrings; the fleet stamps
tokens ``plan:<OverlayPlan.key()>``, ``<ticket:N>`` and ``<app:name>``
(tickets/apps are bracket-delimited so ``<ticket:1>`` never
substring-matches ``<ticket:12>``).

Zero overhead when absent: callers hold ``faults=None`` and skip every
hook behind a single attribute check; no injector objects exist on the
happy path.
"""

from __future__ import annotations

import dataclasses
import random
import time
from typing import Dict, List, Optional, Sequence, Tuple

from repro.runtime.resilience import TransientError

HOOK_POINTS = (
    "compile", "dispatch", "nan_output", "transfer_stall", "worker_death",
)


class InjectedFault(TransientError):
    """Raised by a firing fault spec.  ``transient`` mirrors the spec:
    the retry policy retries transient injections and fails over
    immediately on persistent ones (exactly like real faults)."""

    def __init__(self, point: str, detail: str = "", transient: bool = True):
        self.point = point
        self.transient = bool(transient)
        kind = "transient" if transient else "persistent"
        super().__init__(
            f"injected {kind} fault at hook point {point!r}"
            + (f": {detail}" if detail else "")
        )


@dataclasses.dataclass
class FaultSpec:
    """One armed fault: fires at ``point`` with probability ``rate`` per
    eligible event, at most ``max_fires`` times, only on events whose
    tokens contain a ``match`` substring (None = every event)."""

    point: str
    rate: float = 1.0
    max_fires: Optional[int] = None
    transient: bool = True
    match: Optional[Tuple[str, ...]] = None
    delay_s: float = 0.05
    detail: str = ""
    fires: int = 0

    def exhausted(self) -> bool:
        return self.max_fires is not None and self.fires >= self.max_fires

    def matches(self, tokens: Sequence[str]) -> bool:
        if self.match is None:
            return True
        return any(m in tok for tok in tokens for m in self.match)


class FaultInjector:
    """A seeded bundle of fault specs; see the module docstring for the
    hook-point map.  Single-owner by design: the streaming worker thread
    (or the caller's flush loop) is the only consumer, so draws stay
    deterministic without locking.

    >>> faults = (FaultInjector(seed=7)
    ...           .inject("dispatch", rate=1.0, max_fires=2)
    ...           .inject("nan_output", match=("<app:threshold>",)))
    """

    def __init__(self, seed: int = 0):
        self._rng = random.Random(int(seed))
        self._specs: Dict[str, List[FaultSpec]] = {}
        self.fired: Dict[str, int] = {}

    def inject(
        self,
        point: str,
        *,
        rate: float = 1.0,
        max_fires: Optional[int] = None,
        transient: bool = True,
        match: Optional[Sequence[str]] = None,
        delay_s: float = 0.05,
        detail: str = "",
    ) -> "FaultInjector":
        """Arm one fault spec; returns self so specs chain."""
        if point not in HOOK_POINTS:
            raise ValueError(
                f"unknown hook point {point!r}; one of {HOOK_POINTS}"
            )
        if not 0.0 <= rate <= 1.0:
            raise ValueError(f"rate must be in [0, 1], got {rate}")
        self._specs.setdefault(point, []).append(FaultSpec(
            point=point, rate=rate, max_fires=max_fires, transient=transient,
            match=None if match is None else tuple(match),
            delay_s=delay_s, detail=detail,
        ))
        return self

    def _draw(self, spec: FaultSpec) -> bool:
        return spec.rate >= 1.0 or self._rng.random() < spec.rate

    def _count(self, spec: FaultSpec) -> None:
        spec.fires += 1
        self.fired[spec.point] = self.fired.get(spec.point, 0) + 1

    def fire(self, point: str, tokens: Sequence[str] = ()) -> None:
        """Evaluate every armed spec at ``point``.  Stall specs sleep;
        any other firing spec raises :class:`InjectedFault`."""
        for spec in self._specs.get(point, ()):
            if spec.exhausted() or not spec.matches(tokens):
                continue
            if not self._draw(spec):
                continue
            self._count(spec)
            if point == "transfer_stall":
                time.sleep(spec.delay_s)
                continue
            raise InjectedFault(point, spec.detail, transient=spec.transient)

    def corrupt_slots(self, item_tokens: Sequence[Sequence[str]]) -> List[int]:
        """Which app slots of the current dispatch get NaN-poisoned.
        Matched specs poison every matching item; unmatched specs draw
        once per dispatch and poison one seeded-random slot."""
        out: set = set()
        for spec in self._specs.get("nan_output", ()):
            if spec.exhausted():
                continue
            if spec.match is not None:
                hit = [i for i, toks in enumerate(item_tokens)
                       if spec.matches(toks)]
                if hit and self._draw(spec):
                    self._count(spec)
                    out.update(hit)
            elif item_tokens and self._draw(spec):
                self._count(spec)
                out.add(self._rng.randrange(len(item_tokens)))
        return sorted(out)
