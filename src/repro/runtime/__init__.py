from repro.runtime.chaos import FaultInjector, FaultSpec, InjectedFault
from repro.runtime.fault_tolerance import (
    ElasticPlan, HeartbeatMonitor, RunState, resume_or_init,
)
from repro.runtime.fleet import FleetRequest, FleetStats, LRUCache, PixieFleet
from repro.runtime.resilience import (
    BreakerBoard, CircuitBreaker, DispatchError, JobTimeout,
    PoisonedOutputError, QuarantinedError, RetryPolicy, ServiceError,
    TransientError,
)

__all__ = [
    "ElasticPlan", "HeartbeatMonitor", "RunState", "resume_or_init",
    "FleetRequest", "FleetStats", "LRUCache", "PixieFleet",
    "FaultInjector", "FaultSpec", "InjectedFault",
    "BreakerBoard", "CircuitBreaker", "RetryPolicy",
    "ServiceError", "DispatchError", "QuarantinedError", "JobTimeout",
    "PoisonedOutputError", "TransientError",
]
