from repro.runtime.fault_tolerance import (
    ElasticPlan, HeartbeatMonitor, RunState, resume_or_init,
)

__all__ = ["ElasticPlan", "HeartbeatMonitor", "RunState", "resume_or_init"]
