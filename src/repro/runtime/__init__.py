from repro.runtime.fault_tolerance import (
    ElasticPlan, HeartbeatMonitor, RunState, resume_or_init,
)
from repro.runtime.fleet import FleetRequest, FleetStats, LRUCache, PixieFleet

__all__ = [
    "ElasticPlan", "HeartbeatMonitor", "RunState", "resume_or_init",
    "FleetRequest", "FleetStats", "LRUCache", "PixieFleet",
]
