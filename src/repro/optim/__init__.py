from repro.optim.adamw import (
    AdamWConfig, adamw_update, global_norm, init_opt_state, schedule_lr,
)
from repro.optim.grad_compression import compress, decompress, init_error_state

__all__ = [
    "AdamWConfig", "adamw_update", "global_norm", "init_opt_state",
    "schedule_lr", "compress", "decompress", "init_error_state",
]
