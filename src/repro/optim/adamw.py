"""AdamW with global-norm clipping and decay masking, as a plain pytree
transform (no optax dependency -- the container is offline).

State layout mirrors the param tree: ``{"m": tree, "v": tree, "count": i32}``.
Under the ZeRO-1 sharding plan the m/v trees carry an extra 'data'-axis
sharding on top of the parameter TP sharding (see parallel/sharding.py);
this module is sharding-agnostic -- GSPMD inserts the reduce-scatter /
all-gather around the update.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    schedule: str = "cosine"        # constant | cosine
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def _decay_mask(path: Tuple, leaf) -> bool:
    """Weight decay on matrices only (no norms/biases/gates/embedding-scale)."""
    names = [getattr(p, "key", getattr(p, "name", "")) for p in path]
    if any(str(n).startswith(("ln", "norm", "final_norm", "b_", "scale")) for n in names):
        return False
    return getattr(leaf, "ndim", 0) >= 2


def schedule_lr(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        return cfg.lr * warm
    prog = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init_opt_state(params) -> Dict[str, Any]:
    zeros = jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return {
        "m": zeros,
        "v": jax.tree_util.tree_map(jnp.copy, zeros),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def adamw_update(
    cfg: AdamWConfig,
    params,
    grads,
    state: Dict[str, Any],
) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    """One optimizer step.  Returns (new_params, new_state, metrics)."""
    count = state["count"] + 1
    lr = schedule_lr(cfg, count)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree_util.tree_map(lambda g: g * scale, grads)

    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    flat_p, treedef = jax.tree_util.tree_flatten_with_path(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state["m"])
    flat_v = jax.tree_util.tree_leaves(state["v"])

    new_p, new_m, new_v = [], [], []
    for (path, p), g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        gf = g.astype(jnp.float32)
        m2 = cfg.b1 * m + (1 - cfg.b1) * gf
        v2 = cfg.b2 * v + (1 - cfg.b2) * gf * gf
        upd = (m2 / b1c) / (jnp.sqrt(v2 / b2c) + cfg.eps)
        if cfg.weight_decay and _decay_mask(path, p):
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        new_p.append((p.astype(jnp.float32) - lr * upd).astype(p.dtype))
        new_m.append(m2)
        new_v.append(v2)

    params2 = jax.tree_util.tree_unflatten(treedef, new_p)
    state2 = {
        "m": jax.tree_util.tree_unflatten(_treedef(state["m"]), new_m),
        "v": jax.tree_util.tree_unflatten(_treedef(state["v"]), new_v),
        "count": count,
    }
    return params2, state2, {"lr": lr, "grad_norm": gnorm, "clip_scale": scale}


def _treedef(tree):
    return jax.tree_util.tree_structure(tree)
