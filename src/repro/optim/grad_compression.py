"""Error-feedback int8 gradient compression for the data-parallel axis.

Distributed-optimization trick for 1000+-node scale: before the DP
all-reduce, gradients are quantised to int8 with a per-tensor scale; the
quantisation error is kept locally and added back into the next step's
gradient (error feedback), which keeps SGD/Adam convergence intact in
expectation.  Under pjit the quantised tree is what crosses the 'data'
axis, cutting DP collective bytes 4x (f32) / 2x (bf16).

The transform is pure-pytree so it composes with any optimizer:

    comp, new_err = compress(grads, err)      # int8 tree + carried error
    grads2        = decompress(comp)          # dequantised, post-allreduce
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


def init_error_state(params) -> Any:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros_like(p, jnp.float32), params
    )


def _quantise(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress(grads, err_state) -> Tuple[Dict[str, Any], Any]:
    """Returns ({'q': int8 tree, 'scale': f32 tree}, new_error_tree)."""
    gs = jax.tree_util.tree_map(
        lambda g, e: g.astype(jnp.float32) + e, grads, err_state
    )
    qs = jax.tree_util.tree_map(_quantise, gs)
    q = jax.tree_util.tree_map(lambda t: t[0], qs, is_leaf=lambda t: isinstance(t, tuple))
    scale = jax.tree_util.tree_map(lambda t: t[1], qs, is_leaf=lambda t: isinstance(t, tuple))
    deq = jax.tree_util.tree_map(
        lambda qq, ss: qq.astype(jnp.float32) * ss, q, scale
    )
    new_err = jax.tree_util.tree_map(lambda g, d: g - d, gs, deq)
    return {"q": q, "scale": scale}, new_err


def decompress(comp: Dict[str, Any]):
    return jax.tree_util.tree_map(
        lambda q, s: q.astype(jnp.float32) * s, comp["q"], comp["scale"]
    )
