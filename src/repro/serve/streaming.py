"""Threaded continuous-batching streaming front-end with SLO scheduling.

The synchronous :class:`~repro.serve.fleet_frontend.FleetFrontend` only
dispatches when a caller drives it, so nothing overlaps request arrival
with device execution and nothing bounds tail latency.  This module is
the serving loop the paper's economics actually ask for (cheap
reconfiguration is only worth something if work keeps arriving while the
fabric runs): a worker thread owns a :class:`~repro.runtime.fleet.
PixieFleet` and continuously batches arrivals -- the maxtext
``OfflineInference`` shape (worker thread + bounded queues +
backpressure), adapted from token slots to overlay tiles.

Scheduling model:

* ``submit`` validates on the caller's thread, then enqueues into a
  BOUNDED arrival queue.  A full queue sheds the request with a typed
  :class:`~repro.serve.service.AdmissionError` (admission control: reject
  loudly, never grow without bound).
* Requests carry an optional **deadline** (``deadline_s``, relative
  seconds -- the request's SLO) and a **priority** (higher is served
  first).  The worker drains arrivals into a pending set and launches one
  fleet flush when any of three triggers fires:

    full tile      pending >= target_batch (the fleet's batch tile)
    deadline       the most urgent pending deadline is within
                   est_flush_s + deadline_margin_s of expiring -- launch a
                   PARTIALLY-FILLED tile now rather than miss the SLO
                   waiting for a full one (``FleetStats.
                   partial_tile_dispatches`` counts these)
    linger         the oldest pending request has waited max_linger_s with
                   no new arrivals -- deadline-less traffic must not starve

  The flush-duration estimate is a per-(grid, frame-bucket) EWMA of
  observed flush wall times (seeded pessimistically so the first
  post-compile flushes do not teach the scheduler that flushes are
  free).  Keying by the fleet's own canvas bucket means a 256^2 tenant's
  slow flushes never inflate deadline urgency for 32^2 traffic sharing
  the server -- each (grid, bucket) population plans with its own recent
  reality, and an unseen population starts from the pessimistic seed.
* The batch is chosen by (priority desc, arrival order) and capped at
  ``target_batch``; the remainder stays pending for the next trigger --
  continuous batching, not drain-everything.
* Per-request ``queue_s`` / ``flush_s`` / ``total_s`` land in a
  :class:`~repro.serve.service.LatencyStats` (p50/p95/p99 + deadline-miss
  counters) alongside the fleet's own :class:`FleetStats`.

Outputs are bitwise identical to the synchronous front-end on the same
request trace: batch composition never changes values (the fleet pads
tiles exactly), only latency.  ``tests/test_streaming.py`` asserts it on
ragged mixed-app traces over both backends.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Dict, List, Optional, Union

import numpy as np

from repro.core import applications as app_lib
from repro.core.dfg import DFG
from repro.core.grid import GridSpec
from repro.core.tiling import pow2_bucket
from repro.parallel.axes import MeshSpec
from repro.runtime.chaos import FaultInjector
from repro.runtime.fleet import FleetRequest, PixieFleet
from repro.serve.fleet_frontend import build_fleet, resolve_frontend_mesh
from repro.serve.service import (
    AdmissionError, DispatchError, ImageJob, ImageService, JobHandle,
    JobTimeout, LatencyStats, resolve_app,
)

_STOP = object()   # arrival-queue sentinel: close() wakes the worker with it


@dataclasses.dataclass
class _PendingRequest:
    """One accepted request, between arrival queue and fleet dispatch."""

    seq: int                      # arrival order (FIFO tiebreak)
    name: str
    work: Union[str, DFG, List]   # a list means a pipeline chain of stages
    image: np.ndarray
    grid: Optional[GridSpec]
    priority: int
    t_arrival: float              # perf_counter at submit
    deadline_at: Optional[float]  # absolute perf_counter target, or None
    deadline_s: Optional[float]   # the relative SLO as submitted
    handle: JobHandle


class StreamingFrontend(ImageService):
    """Continuous-batching streaming server over a :class:`PixieFleet`.

    >>> with StreamingFrontend() as svc:
    ...     h = svc.submit("sobel_x", img, deadline_s=0.05, priority=1)
    ...     edge = h.result(timeout=5.0)

    The fleet is owned by the worker thread exclusively -- do not share a
    fleet instance between a streaming front-end and other callers.

    ``target_batch`` defaults to the fleet's ``batch_tile``; ``max_queue``
    bounds accepted-but-unserved requests (arrival queue + pending set)
    and is the admission-control knob; ``autostart=False`` leaves the
    worker stopped until :meth:`start` -- tests use it to stage
    deterministic contention.
    """

    def __init__(
        self,
        fleet: Optional[PixieFleet] = None,
        registry: Optional[Dict[str, object]] = None,
        *,
        target_batch: Optional[int] = None,
        max_queue: int = 256,
        est_flush_s: float = 0.05,
        deadline_margin_s: float = 0.002,
        max_linger_s: float = 0.002,
        backend: Optional[str] = None,
        mesh: Optional[MeshSpec] = None,
        ingest: Optional[str] = None,
        devices: Optional[int] = None,
        autostart: bool = True,
        faults: Optional[FaultInjector] = None,
        request_timeout_s: Optional[float] = None,
        max_worker_restarts: int = 8,
    ):
        mesh = resolve_frontend_mesh(mesh, devices, "StreamingFrontend")
        self.fleet = build_fleet(fleet, backend, mesh, ingest)
        if faults is not None:
            # One injector serves BOTH layers: the fleet's hook points
            # (compile/dispatch/nan_output/transfer_stall) and the
            # worker loop's "worker_death" -- a single seeded schedule.
            self.fleet.install_faults(faults)
        # Per-request hard timeout: a request that has waited this long
        # without being served fails its handle with JobTimeout (the
        # worker sweeps expiries every wakeup, so no client waits on work
        # the server has silently given up on).
        if request_timeout_s is not None and request_timeout_s <= 0:
            raise ValueError(
                f"request_timeout_s must be > 0, got {request_timeout_s}"
            )
        self.request_timeout_s = request_timeout_s
        self.max_worker_restarts = int(max_worker_restarts)
        self.registry = dict(registry) if registry is not None else dict(app_lib.ALL_APPS)
        self.target_batch = int(target_batch or self.fleet.batch_tile)
        if self.target_batch < 1:
            raise ValueError(f"target_batch must be >= 1, got {target_batch}")
        self.max_queue = int(max_queue)
        if self.max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        self.deadline_margin_s = float(deadline_margin_s)
        self.max_linger_s = float(max_linger_s)
        # Per-(grid, frame-bucket) EWMAs of observed flush wall times,
        # used by the deadline trigger to decide how late a launch can
        # start and still meet the SLO.  Keyed by the fleet's own pow-2
        # canvas bucket so big-frame tenants never inflate urgency for
        # small-frame traffic; populations the server has not flushed yet
        # fall back to the pessimistic seed (until real flushes are
        # observed the scheduler assumes they are slow and launches
        # early).
        self._est_flush_seed = float(est_flush_s)
        self._est_flush: Dict[tuple, float] = {}
        self.latency = LatencyStats()
        self._queue: "queue.Queue" = queue.Queue(maxsize=self.max_queue)
        self._seq = 0
        self._seq_lock = threading.Lock()
        self._flush_seq = 0
        self._closed = False
        # Lifecycle lock: close() flips _closed and submit() enqueues
        # under the SAME lock, so no submit can slip its request into the
        # queue after close() has begun draining (the pre-PR 10 race that
        # could strand a handle behind the _STOP sentinel).
        self._lifecycle = threading.Lock()
        self._worker: Optional[threading.Thread] = None
        # Worker state lives on the INSTANCE (not _run locals) so the
        # supervisor can restart a crashed worker without losing accepted
        # work: _pending_reqs survives the crash and is re-served, while
        # _inflight_reqs (mid-dispatch when the worker died) is failed
        # with a typed DispatchError -- no JobHandle ever hangs.
        self._pending_reqs: List[_PendingRequest] = []
        self._inflight_reqs: List[_PendingRequest] = []
        self._stopping = False
        self.worker_restarts = 0
        if autostart:
            self.start()

    # -- lifecycle ----------------------------------------------------------

    def start(self) -> "StreamingFrontend":
        """Start the worker thread (idempotent)."""
        if self._closed:
            raise RuntimeError("streaming front-end already closed")
        if self._worker is None:
            self._worker = threading.Thread(
                target=self._run_supervised,
                name="pixie-streaming-worker", daemon=True,
            )
            self._worker.start()
        return self

    def close(self, timeout: Optional[float] = 30.0) -> None:
        """Drain everything already accepted, then stop the worker.
        Safe to call twice; new submits after close are rejected."""
        with self._lifecycle:
            if self._closed:
                return
            self._closed = True
        if self._worker is None:
            # Never started: fail the accepted-but-unserved handles so no
            # client blocks forever on a server that will not run.
            self._drain_failed(RuntimeError("streaming front-end closed before start"))
            return
        self._queue.put(_STOP)   # blocking put: the sentinel must arrive
        self._worker.join(timeout)
        if self._worker.is_alive():
            raise RuntimeError(
                f"streaming worker did not drain within {timeout} s"
            )

    def __enter__(self) -> "StreamingFrontend":
        return self.start()

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    def _drain_failed(self, exc: BaseException) -> None:
        while True:
            try:
                item = self._queue.get_nowait()
            except queue.Empty:
                return
            if item is not _STOP:
                item.handle._fail(exc)

    # -- client surface -----------------------------------------------------

    def available_apps(self) -> List[str]:
        return sorted(self.registry)

    def submit(
        self,
        app: Union[str, DFG],
        image: np.ndarray,
        grid: Optional[GridSpec] = None,
        *,
        deadline_s: Optional[float] = None,
        priority: int = 0,
        **kwargs,
    ) -> JobHandle:
        """Accept one frame for streaming service.

        ``deadline_s`` is the request's SLO in relative seconds: the
        scheduler will launch a partial tile rather than let it expire
        waiting for a full one, and :class:`LatencyStats` counts it as a
        miss if total latency still exceeds it.  ``priority`` breaks
        batching ties (higher is served first).  ``app`` may be a
        list/tuple of stages -- the chain runs as ONE device-resident
        pipeline dispatch (job named ``"a+b+c"``).  Raises
        :class:`AdmissionError` when the bounded queue is full.
        """
        if kwargs:
            raise TypeError(f"unsupported submit options {sorted(kwargs)}")
        if self._closed:
            raise RuntimeError("streaming front-end is closed")
        if deadline_s is not None and deadline_s <= 0:
            raise ValueError(f"deadline_s must be > 0, got {deadline_s}")
        # Cheap validation on the CALLER's thread (unknown app, bad shape)
        # so obviously-bad requests fail to their submitter immediately;
        # mapping/grid validation happens on the worker and fails the
        # handle instead.
        if isinstance(app, (list, tuple)):
            resolved = [resolve_app(self.registry, a) for a in app]
            name = "+".join(n for n, _ in resolved)
            work: Union[str, DFG, List] = [w for _, w in resolved]
        else:
            name, work = resolve_app(self.registry, app)
        image = np.asarray(image)
        if image.ndim != 2:
            raise ValueError(f"image must be [H, W], got shape {image.shape}")
        t_arrival = time.perf_counter()
        with self._seq_lock:
            seq = self._seq
            self._seq += 1
        handle = JobHandle(seq, name)
        pending = _PendingRequest(
            seq=seq, name=name, work=work, image=image, grid=grid,
            priority=int(priority), t_arrival=t_arrival,
            deadline_at=None if deadline_s is None else t_arrival + deadline_s,
            deadline_s=deadline_s, handle=handle,
        )
        # Enqueue ATOMICALLY with the closed check: close() flips _closed
        # under the same lock before it inserts the _STOP sentinel, so an
        # accepted request always precedes the sentinel in the FIFO and is
        # drained -- a submit racing close can no longer strand its handle
        # behind a queue the worker has already finished.
        with self._lifecycle:
            if self._closed:
                raise RuntimeError("streaming front-end is closed")
            try:
                self._queue.put_nowait(pending)
            except queue.Full:
                self.latency.record_shed()
                raise AdmissionError(queued=self._queue.qsize(),
                                     bound=self.max_queue) from None
        return handle

    @property
    def backend(self) -> str:
        return self.fleet.backend

    @property
    def mesh(self) -> MeshSpec:
        return self.fleet.mesh

    @property
    def devices(self) -> int:
        return self.fleet.devices

    @property
    def ingest(self) -> str:
        return self.fleet.ingest

    @property
    def stats(self):
        """The owned fleet's :class:`FleetStats` (read-only use; the
        worker thread is the writer)."""
        return self.fleet.stats

    @property
    def est_flush_s(self) -> float:
        """Most pessimistic current flush-duration estimate across the
        (grid, frame-bucket) populations the server has flushed (the
        seed before any flush) -- the scalar the serving bench records;
        the deadline trigger itself plans with each request's own
        population estimate (:meth:`_estimate`)."""
        return max(self._est_flush.values(), default=self._est_flush_seed)

    def _flush_key(self, p: _PendingRequest) -> tuple:
        """The EWMA population of one request: its grid and the padded
        canvas bucket its frame lands in -- the SAME pow-2 bucketing the
        fleet's dispatch uses, so requests that share a compiled
        executable shape (and therefore a flush-duration profile) share
        an estimate."""
        grid = p.grid or self.fleet.default_grid
        H, W = p.image.shape
        return (
            grid,
            pow2_bucket(H, self.fleet.min_image_side),
            pow2_bucket(W, self.fleet.min_image_side),
        )

    def _estimate(self, p: _PendingRequest) -> float:
        """Flush-duration estimate for one request's population."""
        return self._est_flush.get(self._flush_key(p), self._est_flush_seed)

    # -- worker -------------------------------------------------------------

    def _run_supervised(self) -> None:
        """The worker's supervisor: :meth:`_run` is the mortal body.  Any
        crash -- a fleet bug, an injected ``worker_death``, even a
        BaseException -- lands here; in-flight jobs are reconciled (failed
        with a typed DispatchError, never stranded), accepted-but-unflushed
        work survives in ``_pending_reqs``, and the loop restarts.  A
        worker that cannot stay alive (``max_worker_restarts`` exceeded)
        surrenders: the front-end closes and every queued handle fails."""
        while True:
            try:
                self._run()
                return
            except BaseException as exc:  # noqa: BLE001 -- routed: in-flight handles fail typed, queued work re-serves after restart
                if not self._reconcile_crash(exc):
                    return

    def _reconcile_crash(self, exc: BaseException) -> bool:
        """Crash bookkeeping; returns False when the supervisor gives up."""
        self.worker_restarts += 1
        lost, self._inflight_reqs = self._inflight_reqs, []
        for p in lost:
            if not p.handle.done():
                self.latency.record_failure()
                p.handle._fail(DispatchError(
                    f"request {p.name!r} (seq {p.seq}) was in flight when "
                    f"the streaming worker crashed ({exc!r}); resubmit"
                ))
        # Their fleet submissions (if any) died with the dispatch: drop
        # them so a restarted worker never re-serves failed tickets.
        self.fleet.cancel_pending()
        if self.worker_restarts <= self.max_worker_restarts:
            return True
        err = DispatchError(
            f"streaming worker died {self.worker_restarts} times "
            f"(max_worker_restarts={self.max_worker_restarts}); "
            f"front-end closed: {exc!r}"
        )
        with self._lifecycle:
            self._closed = True
        for p in self._pending_reqs:
            if not p.handle.done():
                self.latency.record_failure()
                p.handle._fail(err)
        self._pending_reqs = []
        self._drain_failed(err)
        return False

    def _run(self) -> None:
        pending = self._pending_reqs
        while True:
            faults = self.fleet.faults
            if faults is not None:
                # The worker-death hook: fires between dispatches (never
                # mid-flight), so an injected kill exercises the restart
                # path without fabricating lost work.
                faults.fire("worker_death")
            # 1. Pull arrivals: block only as long as the launch triggers
            # allow (deadline slack / linger / hard timeout), then drain
            # without blocking.
            timeout = self._wake_in(pending)
            try:
                item = self._queue.get(timeout=timeout)
                if item is _STOP:
                    self._stopping = True
                else:
                    pending.append(item)
                while True:   # opportunistically drain the burst
                    item = self._queue.get_nowait()
                    if item is _STOP:
                        self._stopping = True
                    else:
                        pending.append(item)
            except queue.Empty:
                pass

            # 2. Launch decision.
            now = time.perf_counter()
            self._expire_timeouts(pending, now)
            if pending and (
                self._stopping
                or len(pending) >= self.target_batch
                or self._deadline_urgent(pending, now)
                or self._lingered(pending, now)
            ):
                batch = self._select_batch(pending)
                self._inflight_reqs = batch
                self._dispatch(batch)
                self._inflight_reqs = []
            if self._stopping and not pending and self._queue.empty():
                return

    def _expire_timeouts(self, pending: List[_PendingRequest],
                         now: float) -> None:
        """Sweep the per-request hard timeout: expired requests fail
        their own handle with :class:`JobTimeout` and leave the queue."""
        if self.request_timeout_s is None:
            return
        expired = [p for p in pending
                   if now - p.t_arrival > self.request_timeout_s]
        for p in expired:
            pending.remove(p)
            self.latency.record_failure()
            p.handle._fail(JobTimeout(
                f"request {p.name!r} (seq {p.seq}) exceeded the "
                f"per-request hard timeout ({self.request_timeout_s} s) "
                f"while queued"
            ))

    def _wake_in(self, pending: List[_PendingRequest]) -> float:
        """How long the worker may block on the arrival queue before a
        trigger needs re-evaluation."""
        if not pending:
            return 0.1   # idle: wake periodically (sentinel wakes us too)
        now = time.perf_counter()
        horizon = min(
            (p.t_arrival + self.max_linger_s for p in pending),
            default=now,
        ) - now
        slack = min(
            (p.deadline_at - self._estimate(p) - self.deadline_margin_s
             for p in pending if p.deadline_at is not None),
            default=float("inf"),
        ) - now
        return float(min(max(min(horizon, slack), 1e-4), 0.05))

    def _deadline_urgent(self, pending: List[_PendingRequest], now: float) -> bool:
        """Would waiting any longer risk the most urgent pending SLO?
        (The partial-tile trigger: launch when the estimated flush no
        longer fits inside the tightest remaining deadline budget.)
        Each request is judged against ITS population's estimate: a 32^2
        request next to 256^2 traffic keeps its own cheap budget."""
        return any(
            p.deadline_at is not None
            and p.deadline_at - now
            <= self._estimate(p) + self.deadline_margin_s
            for p in pending
        )

    def _lingered(self, pending: List[_PendingRequest], now: float) -> bool:
        return (
            self._queue.empty()
            and now - min(p.t_arrival for p in pending) >= self.max_linger_s
        )

    def _select_batch(self, pending: List[_PendingRequest]) -> List[_PendingRequest]:
        """Pop up to ``target_batch`` requests; the rest stay pending --
        continuous batching, not drain-all.

        Staged order is (priority desc, arrival), but an URGENT request --
        one whose remaining deadline budget no longer covers its
        population's estimated flush -- preempts the staged set
        mid-selection: urgency outranks priority, so a low-priority
        request about to blow its SLO jumps a staged batch of
        high-priority deadline-less work.  Each preemption that actually
        changes the launched composition is counted in
        ``FleetStats.preempted_batches`` (the contention test asserts
        it)."""
        now = time.perf_counter()
        staged = sorted(pending, key=lambda p: (-p.priority, p.seq))

        def urgent(p: _PendingRequest) -> bool:
            return (
                p.deadline_at is not None
                and p.deadline_at - now
                <= self._estimate(p) + self.deadline_margin_s
            )

        pending.sort(key=lambda p: (not urgent(p), -p.priority, p.seq))
        batch = pending[: self.target_batch]
        del pending[: self.target_batch]
        if {p.seq for p in batch} != {p.seq for p in staged[: self.target_batch]}:
            self.fleet.stats.preempted_batches += 1
        return batch

    def _dispatch(self, batch: List[_PendingRequest]) -> None:
        """One fleet flush for the selected batch.  Per-request fleet
        submit failures (unmappable app, grid mismatch) fail only their
        own handle -- they can never poison the rest of the batch."""
        tickets: Dict[int, _PendingRequest] = {}
        for p in batch:
            try:
                if isinstance(p.work, list):
                    req = FleetRequest(pipeline=p.work, image=p.image,
                                       grid=p.grid)
                else:
                    req = FleetRequest(app=p.work, image=p.image, grid=p.grid)
                t = self.fleet.submit(req)
            except Exception as exc:    # noqa: BLE001 -- handed to the handle
                p.handle._fail(exc)
                continue
            tickets[t] = p
        if not tickets:
            return
        seq = self._flush_seq
        self._flush_seq += 1
        try:
            outs = self.fleet.flush()
        except Exception as exc:        # noqa: BLE001 -- handed to the handles
            for p in tickets.values():
                p.handle._fail(exc)
            return
        flush_started = self.fleet.timings.get("flush_started", time.perf_counter())
        flush_s = self.fleet.timings.get("flush_s", 0.0)
        # EWMA update, per population present in this flush: the deadline
        # trigger plans with recent reality for the shapes it just served
        # (a mixed flush credits its wall time to every population in it
        # -- pessimistic for the small ones, and exactly why homogeneous
        # batches keep their own key).
        for key in {self._flush_key(p) for p in tickets.values()}:
            self._est_flush[key] = (
                0.7 * self._est_flush.get(key, self._est_flush_seed)
                + 0.3 * flush_s
            )
        t_done = time.perf_counter()
        failures = self.fleet.pop_failures()
        for ticket, p in tickets.items():
            if ticket not in outs:
                # Quarantined (or otherwise lost) by the resilient flush:
                # fail exactly this handle, typed; batchmates are served.
                exc = failures.get(ticket) or DispatchError(
                    f"ticket {ticket} ({p.name!r}) was not served by its "
                    f"flush and recorded no failure"
                )
                self.latency.record_failure()
                p.handle._fail(exc)
                continue
            self.fleet.discard(ticket)
            queue_s = max(0.0, flush_started - p.t_arrival)
            total_s = t_done - p.t_arrival
            missed = p.deadline_s is not None and total_s > p.deadline_s
            job = ImageJob(
                ticket=p.seq, app=p.name, output=outs[ticket],
                queue_s=queue_s, flush_s=flush_s, latency_s=total_s,
                priority=p.priority, deadline_s=p.deadline_s,
                deadline_missed=missed, flush_seq=seq,
            )
            self.latency.record(queue_s, flush_s, total_s,
                                deadline_s=p.deadline_s)
            p.handle._complete(job)
