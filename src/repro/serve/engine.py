"""Batched serving engine: prefill + decode with slot-based continuous
batching.

The engine owns a fixed [max_batch, max_seq] cache; requests claim slots,
prefill fills them, and the decode step advances every active slot each
tick (inactive slots are masked from sampling).  Greedy or temperature
sampling; deterministic under a fixed seed.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.lm import LM


@dataclasses.dataclass
class ServeConfig:
    max_batch: int
    max_seq: int
    temperature: float = 0.0     # 0 => greedy
    seed: int = 0


class ServeEngine:
    def __init__(self, lm: LM, params, cfg: ServeConfig):
        self.lm = lm
        self.params = params
        self.cfg = cfg
        self._decode = jax.jit(lm.decode_step)
        self._prefill = jax.jit(
            lm.prefill, static_argnames=("cache_len",)
        )

    # -- one-shot batch generation -------------------------------------------

    def generate(
        self,
        prompts: jnp.ndarray,          # [B, S_prompt] int32
        num_steps: int,
        prefix_embeds: Optional[jnp.ndarray] = None,
    ) -> np.ndarray:
        """Prefill the batch, then decode `num_steps` tokens greedily."""
        B = prompts.shape[0]
        assert B <= self.cfg.max_batch
        logits, cache, lengths = self._prefill(
            self.params, prompts, cache_len=self.cfg.max_seq,
            prefix_embeds=prefix_embeds,
        )
        out = []
        key = jax.random.PRNGKey(self.cfg.seed)
        tok = self._sample(logits, key)
        out.append(tok)
        for i in range(num_steps - 1):
            logits, cache, lengths = self._decode(
                self.params, tok[:, None], cache, lengths
            )
            key, sub = jax.random.split(key)
            tok = self._sample(logits, sub)
            out.append(tok)
        return np.stack([np.asarray(t) for t in out], axis=1)  # [B, steps]

    def _sample(self, logits: jnp.ndarray, key) -> jnp.ndarray:
        if self.cfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.cfg.temperature, axis=-1
        ).astype(jnp.int32)


class SlotServer:
    """Continuous-batching skeleton: requests arrive/finish independently;
    every tick decodes all active slots in one batched step."""

    def __init__(self, lm: LM, params, cfg: ServeConfig):
        self.lm = lm
        self.params = params
        self.cfg = cfg
        self.cache = lm.init_cache(cfg.max_batch, cfg.max_seq)
        self.lengths = jnp.zeros((cfg.max_batch,), jnp.int32)
        self.active = np.zeros((cfg.max_batch,), bool)
        self.last_token = jnp.zeros((cfg.max_batch,), jnp.int32)
        self._decode = jax.jit(lm.decode_step)
        self.outputs: Dict[int, List[int]] = {}

    def add_request(self, slot: int, prompt: np.ndarray) -> None:
        """Single-slot prefill (production would batch these too)."""
        assert not self.active[slot]
        logits, cache1, lengths1 = self.lm.prefill(
            self.params, jnp.asarray(prompt)[None], cache_len=self.cfg.max_seq
        )
        # splice slot-0 of the single-request cache into the shared cache
        self.cache = jax.tree_util.tree_map(
            lambda full, one: _splice(full, one, slot), self.cache, cache1
        )
        self.lengths = self.lengths.at[slot].set(int(lengths1[0]))
        self.last_token = self.last_token.at[slot].set(
            int(jnp.argmax(logits[0]))
        )
        self.active[slot] = True
        self.outputs[slot] = [int(jnp.argmax(logits[0]))]

    def tick(self) -> None:
        if not self.active.any():
            return
        logits, self.cache, new_lengths = self._decode(
            self.params, self.last_token[:, None], self.cache, self.lengths
        )
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        mask = jnp.asarray(self.active)
        self.lengths = jnp.where(mask, new_lengths, self.lengths)
        self.last_token = jnp.where(mask, tok, self.last_token)
        for slot in np.nonzero(self.active)[0]:
            self.outputs[slot].append(int(tok[slot]))

    def finish(self, slot: int) -> List[int]:
        self.active[slot] = False
        self.lengths = self.lengths.at[slot].set(0)
        return self.outputs.pop(slot)


def _splice(full: jnp.ndarray, one: jnp.ndarray, slot: int) -> jnp.ndarray:
    """Write a batch-1 cache leaf into batch slot `slot` of the full cache.
    Batch is axis 0 for unstacked leaves and axis 1 for scan-stacked ones;
    identified by matching trailing dims."""
    if full.shape[1:] == one.shape[1:]:          # [B, ...] leaf
        return jax.lax.dynamic_update_slice(
            full, one.astype(full.dtype), (slot,) + (0,) * (full.ndim - 1)
        )
    # stacked leaf: [n_sb, B, ...]
    return jax.lax.dynamic_update_slice(
        full, one.astype(full.dtype), (0, slot) + (0,) * (full.ndim - 2)
    )
