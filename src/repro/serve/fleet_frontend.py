"""Serving front-end for the Pixie fleet: an image-processing service.

The LM serving stack (``serve/engine.py``) batches token requests into one
decode step; this is the same pattern for the VCGRA overlay: clients ask
for *named image operations* ("sobel_x on this frame"), the front-end
queues them, and each service tick drains the queue through
:class:`repro.runtime.fleet.PixieFleet` -- one vmapped overlay dispatch
for every distinct grid, regardless of how many different applications
are in flight.  Frames ride the fused-ingest path end to end: the raw
image is handed to the fleet at submit and line-buffer formation happens
inside the batched dispatch, so a service tick is one device operation
per grid group.

Deliberately transport-agnostic (no HTTP server in the core library): an
RPC layer would call :meth:`submit` on arrival and :meth:`tick` on a
timer, exactly like ``SlotServer.tick``.
"""

from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core import applications as app_lib
from repro.core.dfg import DFG
from repro.core.grid import GridSpec
from repro.core.ingest import check_ingest
from repro.core.interpreter import check_backend
from repro.runtime.fleet import FleetRequest, PixieFleet


@dataclasses.dataclass
class ImageJob:
    """A completed unit of service work (returned by ``tick``)."""

    ticket: int
    app: str
    output: np.ndarray
    latency_s: float


class FleetFrontend:
    """Queue + drain service loop over a :class:`PixieFleet`.

    >>> svc = FleetFrontend()
    >>> t = svc.submit("sobel_x", img)
    >>> done = svc.tick()           # drains the queue in one dispatch
    >>> edge = svc.take(t)
    """

    def __init__(
        self,
        fleet: Optional[PixieFleet] = None,
        registry: Optional[Dict[str, object]] = None,
        max_done: int = 1024,
        backend: Optional[str] = None,
        devices: Optional[int] = None,
        ingest: Optional[str] = None,
    ):
        if backend is not None:
            check_backend(backend)
            if fleet is not None and fleet.backend != backend:
                raise ValueError(
                    f"backend={backend!r} conflicts with the provided fleet's "
                    f"backend {fleet.backend!r}; configure the PixieFleet instead"
                )
        if devices is not None and fleet is not None and fleet.devices != devices:
            raise ValueError(
                f"devices={devices!r} conflicts with the provided fleet's "
                f"devices {fleet.devices!r}; configure the PixieFleet instead"
            )
        if ingest is not None:
            check_ingest(ingest)
            if fleet is not None and fleet.ingest != ingest:
                raise ValueError(
                    f"ingest={ingest!r} conflicts with the provided fleet's "
                    f"ingest {fleet.ingest!r}; configure the PixieFleet instead"
                )
        self.fleet = fleet or PixieFleet(backend=backend or "xla",
                                         devices=devices,
                                         ingest=ingest or "sync")
        # Name -> DFG factory; defaults to the paper's application library.
        self.registry = dict(registry) if registry is not None else dict(app_lib.ALL_APPS)
        self._arrivals: Dict[int, Tuple[str, float]] = {}
        # Bounded: clients that read outputs from tick()'s ImageJob list and
        # never take() must not leak; oldest unredeemed jobs are evicted.
        self._done: "OrderedDict[int, ImageJob]" = OrderedDict()
        self.max_done = int(max_done)

    def available_apps(self) -> List[str]:
        return sorted(self.registry)

    def submit(
        self,
        app: Union[str, DFG],
        image: np.ndarray,
        grid: Optional[GridSpec] = None,
    ) -> int:
        """Enqueue one frame; returns a ticket for :meth:`take`."""
        if isinstance(app, str):
            if app not in self.registry:
                raise KeyError(
                    f"unknown app {app!r}; known: {self.available_apps()}"
                )
            # Library-default entries pass the NAME through so the fleet's
            # (name, grid) config cache applies -- no per-request DFG
            # rebuild + structural hash (~0.1 ms/request on the serving
            # hot path).  Custom registry factories still build: the fleet
            # only knows the library by name.
            factory = self.registry[app]
            name = app
            work = app if factory is app_lib.ALL_APPS.get(app) else factory()
        else:
            name, work = app.name, app
        ticket = self.fleet.submit(FleetRequest(app=work, image=image, grid=grid))
        self._arrivals[ticket] = (name, time.perf_counter())
        return ticket

    def tick(self) -> List[ImageJob]:
        """Drain the queue: one batched dispatch per grid group."""
        outs = self.fleet.flush()
        now = time.perf_counter()
        jobs = []
        for ticket, output in outs.items():
            self.fleet.discard(ticket)  # the job owns the output now
            name, t_arrival = self._arrivals.pop(ticket)
            job = ImageJob(ticket, name, output, now - t_arrival)
            self._done[ticket] = job
            jobs.append(job)
        while len(self._done) > self.max_done:
            self._done.popitem(last=False)
        return jobs

    def take(self, ticket: int) -> np.ndarray:
        """Redeem a ticket (after the tick that served it)."""
        return self._done.pop(ticket).output

    def process(self, app: Union[str, DFG], image: np.ndarray) -> np.ndarray:
        """Synchronous single-frame convenience (still goes through the
        batched path, so repeat calls reuse the compiled overlay)."""
        t = self.submit(app, image)
        self.tick()
        return self.take(t)

    def process_batch(
        self, requests: Sequence[Tuple[Union[str, DFG], np.ndarray]]
    ) -> List[np.ndarray]:
        """Many (app, image) pairs in one dispatch; outputs in order."""
        tickets = [self.submit(app, image) for app, image in requests]
        self.tick()
        return [self.take(t) for t in tickets]

    @property
    def backend(self) -> str:
        """Execution backend of the underlying fleet ("xla" or "pallas")."""
        return self.fleet.backend

    @property
    def devices(self) -> int:
        """App-axis mesh width of the underlying fleet's dispatch plans."""
        return self.fleet.devices

    @property
    def ingest(self) -> str:
        """Ingest pipelining mode of the underlying fleet ("sync" or
        "async" -- async jobs carry lazy jax arrays as outputs)."""
        return self.fleet.ingest

    @property
    def stats(self):
        return self.fleet.stats

    @property
    def timings(self):
        """Fleet timing split: cumulative ``pack_s`` (host-side input prep)
        vs ``dispatch_s`` (device execution) plus last ``flush_s``."""
        return self.fleet.timings
