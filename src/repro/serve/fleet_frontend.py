"""Synchronous serving front-end for the Pixie fleet.

The LM serving stack (``serve/engine.py``) batches token requests into one
decode step; this is the same pattern for the VCGRA overlay: clients ask
for *named image operations* ("sobel_x on this frame"), the front-end
queues them, and each flush drains the queue through
:class:`repro.runtime.fleet.PixieFleet` -- one vmapped overlay dispatch
for every distinct grid, regardless of how many different applications
are in flight.  Frames ride the fused-ingest path end to end: the raw
image is handed to the fleet at submit and line-buffer formation happens
inside the batched dispatch, so a flush is one device operation per grid
group.

The service surface is the futures API of
:class:`repro.serve.service.ImageService`: ``submit`` returns a
:class:`~repro.serve.service.JobHandle`, and ``result()`` on an
undispatched handle drives the flush itself -- there is no worker thread
here.  For a server that overlaps request arrival with dispatch and
schedules against deadlines, use
:class:`repro.serve.streaming.StreamingFrontend`, which implements the
same API on the same fleet.

Deliberately transport-agnostic (no HTTP server in the core library): an
RPC layer would call :meth:`submit` on arrival and :meth:`flush` on a
timer, exactly like ``SlotServer``'s decode step.
"""

from __future__ import annotations

import time
import warnings
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core import applications as app_lib
from repro.core.dfg import DFG
from repro.core.grid import GridSpec
from repro.core.ingest import check_ingest
from repro.core.interpreter import check_backend
from repro.parallel.axes import MeshSpec
from repro.runtime.fleet import FleetRequest, PixieFleet
from repro.serve.service import (
    ImageJob, ImageService, JobHandle, LatencyStats, resolve_app,
)


def resolve_frontend_mesh(
    mesh: Optional[MeshSpec], devices: Optional[int], owner: str,
) -> Optional[MeshSpec]:
    """Shared deprecation shim for the front-ends' bare device-count
    kwarg: folds it into ``mesh=MeshSpec(app=k)`` with a warning, and
    rejects giving both spellings at once."""
    if devices is None:
        return mesh
    d = int(devices)
    if d < 1:
        raise ValueError(f"devices must be >= 1, got {devices}")
    if mesh is not None:
        raise ValueError(
            "pass mesh=MeshSpec(...) or the deprecated bare device count, "
            "not both"
        )
    warnings.warn(
        f"the bare device-count kwarg of {owner} is deprecated: pass "
        f"mesh=MeshSpec(app={d}) instead",
        DeprecationWarning, stacklevel=3,
    )
    return MeshSpec(app=d)


def build_fleet(
    fleet: Optional[PixieFleet],
    backend: Optional[str],
    mesh: Optional[MeshSpec],
    ingest: Optional[str],
) -> PixieFleet:
    """Resolve a front-end's fleet: pass-through with axis-conflict checks
    when one is provided, else a fresh fleet on the requested axes.
    Shared by the synchronous and streaming front-ends."""
    if backend is not None:
        check_backend(backend)
        if fleet is not None and fleet.backend != backend:
            raise ValueError(
                f"backend={backend!r} conflicts with the provided fleet's "
                f"backend {fleet.backend!r}; configure the PixieFleet instead"
            )
    if mesh is not None and fleet is not None and fleet.mesh != mesh:
        raise ValueError(
            f"mesh={mesh} conflicts with the provided fleet's "
            f"mesh {fleet.mesh}; configure the PixieFleet instead"
        )
    if ingest is not None:
        check_ingest(ingest)
        if fleet is not None and fleet.ingest != ingest:
            raise ValueError(
                f"ingest={ingest!r} conflicts with the provided fleet's "
                f"ingest {fleet.ingest!r}; configure the PixieFleet instead"
            )
    return fleet or PixieFleet(backend=backend or "xla", mesh=mesh,
                               ingest=ingest or "sync")


class FleetFrontend(ImageService):
    """Queue + drain service loop over a :class:`PixieFleet`.

    >>> svc = FleetFrontend()
    >>> h = svc.submit("sobel_x", img)     # a JobHandle, not a bare ticket
    >>> edge = h.result()                  # drains the queue in one dispatch
    """

    def __init__(
        self,
        fleet: Optional[PixieFleet] = None,
        registry: Optional[Dict[str, object]] = None,
        max_done: int = 1024,
        backend: Optional[str] = None,
        mesh: Optional[MeshSpec] = None,
        ingest: Optional[str] = None,
        devices: Optional[int] = None,
    ):
        mesh = resolve_frontend_mesh(mesh, devices, "FleetFrontend")
        self.fleet = build_fleet(fleet, backend, mesh, ingest)
        # Name -> DFG factory; defaults to the paper's application library.
        self.registry = dict(registry) if registry is not None else dict(app_lib.ALL_APPS)
        self._arrivals: Dict[int, Tuple[str, float]] = {}
        self._handles: Dict[int, JobHandle] = {}
        # Bounded: clients that read outputs from handles and never take()
        # must not leak the legacy done-map; oldest unredeemed jobs are
        # evicted (handles keep their own completed job regardless).
        self._done: "OrderedDict[int, ImageJob]" = OrderedDict()
        self.max_done = int(max_done)
        self.latency = LatencyStats()
        self._flush_seq = 0

    def available_apps(self) -> List[str]:
        return sorted(self.registry)

    def submit(
        self,
        app: Union[str, DFG, Sequence[Union[str, DFG]]],
        image: np.ndarray,
        grid: Optional[GridSpec] = None,
        **kwargs,
    ) -> JobHandle:
        """Enqueue one frame; returns a :class:`JobHandle` whose
        ``result()`` drives the flush if it has not happened yet.

        ``app`` may be a list/tuple of stages -- the chain runs as ONE
        device-resident pipeline dispatch (stage i's output feeds stage
        i+1's taps; the job is named ``"a+b+c"``)."""
        if kwargs:
            raise TypeError(
                f"unsupported submit options {sorted(kwargs)}; deadline_s/"
                f"priority scheduling needs the streaming front-end "
                f"(repro.serve.StreamingFrontend)"
            )
        if isinstance(app, (list, tuple)):
            resolved = [resolve_app(self.registry, a) for a in app]
            name = "+".join(n for n, _ in resolved)
            ticket = self.fleet.submit(FleetRequest(
                pipeline=[w for _, w in resolved], image=image, grid=grid
            ))
        else:
            name, work = resolve_app(self.registry, app)
            ticket = self.fleet.submit(
                FleetRequest(app=work, image=image, grid=grid)
            )
        handle = JobHandle(ticket, name, kick=self.flush)
        self._arrivals[ticket] = (name, time.perf_counter())
        self._handles[ticket] = handle
        return handle

    def flush(self) -> List[ImageJob]:
        """Drain the queue: one batched dispatch per grid group.  Resolves
        every pending handle and records the queue/flush latency split.
        Tickets quarantined by the fleet's resilient flush fail their own
        handle with the stored :class:`QuarantinedError`; batchmates are
        served normally."""
        outs = self.fleet.flush()
        for ticket, exc in self.fleet.pop_failures().items():
            self._arrivals.pop(ticket, None)
            self.latency.record_failure()
            handle = self._handles.pop(ticket, None)
            if handle is not None:
                handle._fail(exc)
        flush_started = self.fleet.timings.get("flush_started", time.perf_counter())
        flush_s = self.fleet.timings.get("flush_s", 0.0)
        seq = self._flush_seq
        self._flush_seq += 1
        jobs = []
        for ticket, output in outs.items():
            self.fleet.discard(ticket)  # the job owns the output now
            name, t_arrival = self._arrivals.pop(ticket)
            queue_s = max(0.0, flush_started - t_arrival)
            job = ImageJob(
                ticket, name, output,
                queue_s=queue_s, flush_s=flush_s,
                latency_s=queue_s + flush_s, flush_seq=seq,
            )
            self.latency.record(queue_s, flush_s, job.latency_s)
            self._done[ticket] = job
            handle = self._handles.pop(ticket, None)
            if handle is not None:
                handle._complete(job)
            jobs.append(job)
        while len(self._done) > self.max_done:
            self._done.popitem(last=False)
        return jobs

    # -- deprecated three-call protocol (PR 6: futures API) -----------------

    def tick(self) -> List[ImageJob]:
        """Deprecated alias of :meth:`flush` (the old queue/tick/take
        protocol); delegates bitwise to the new path."""
        warnings.warn(
            "FleetFrontend tick() is deprecated: hold the JobHandle from "
            "submit() and call result() on it, or call flush() to drain "
            "explicitly",
            DeprecationWarning,
            stacklevel=2,
        )
        return self.flush()

    def take(self, ticket: Union[int, JobHandle]) -> np.ndarray:
        """Deprecated ticket redemption (the old queue/tick/take
        protocol); accepts a bare ticket or a handle and delegates to the
        retained-job map the futures path also fills."""
        warnings.warn(
            "FleetFrontend take() is deprecated: call result() on the "
            "JobHandle returned by submit()",
            DeprecationWarning,
            stacklevel=2,
        )
        if isinstance(ticket, JobHandle):
            ticket = ticket.ticket
        return self._done.pop(ticket).output

    @property
    def backend(self) -> str:
        """Execution backend of the underlying fleet ("xla" or "pallas")."""
        return self.fleet.backend

    @property
    def mesh(self) -> MeshSpec:
        """Device-placement :class:`MeshSpec` of the underlying fleet's
        dispatch plans."""
        return self.fleet.mesh

    @property
    def devices(self) -> int:
        """App-axis mesh width of the underlying fleet's dispatch plans
        (the reading side of the deprecated bare device-count surface)."""
        return self.fleet.devices

    @property
    def ingest(self) -> str:
        """Ingest pipelining mode of the underlying fleet ("sync" or
        "async" -- async jobs carry lazy jax arrays as outputs)."""
        return self.fleet.ingest

    @property
    def stats(self):
        return self.fleet.stats

    @property
    def timings(self):
        """Fleet timing split: cumulative ``pack_s`` (host-side input prep)
        vs ``dispatch_s`` (device execution) plus last ``flush_s`` /
        ``flush_started``."""
        return self.fleet.timings
