from repro.serve.engine import ServeConfig, ServeEngine, SlotServer
from repro.serve.fleet_frontend import FleetFrontend, ImageJob

__all__ = ["ServeConfig", "ServeEngine", "SlotServer", "FleetFrontend", "ImageJob"]
