from repro.serve.engine import ServeConfig, ServeEngine, SlotServer

__all__ = ["ServeConfig", "ServeEngine", "SlotServer"]
