from repro.serve.engine import ServeConfig, ServeEngine, SlotServer
from repro.serve.fleet_frontend import FleetFrontend
from repro.serve.service import (
    AdmissionError, DispatchError, ImageJob, ImageService, JobHandle,
    JobTimeout, LatencyStats, QuarantinedError, ServiceError,
)
from repro.serve.streaming import StreamingFrontend

__all__ = [
    "ServeConfig", "ServeEngine", "SlotServer",
    "FleetFrontend", "StreamingFrontend",
    "ImageService", "ImageJob", "JobHandle",
    "LatencyStats", "AdmissionError",
    "ServiceError", "DispatchError", "QuarantinedError", "JobTimeout",
]
