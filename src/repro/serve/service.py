"""The futures-based service surface shared by every Pixie image front-end.

PR 6 replaced the three-call ``submit``/``tick``/``take`` protocol with a
futures-style API: ``submit(...)`` returns a :class:`JobHandle` the caller
polls (``done()``) or blocks on (``result(timeout=...)``), and the two
front-ends -- the legacy synchronous :class:`~repro.serve.fleet_frontend.
FleetFrontend` and the threaded continuous-batching
:class:`~repro.serve.streaming.StreamingFrontend` -- implement the SAME
surface (:class:`ImageService`), so a client written against handles is
indifferent to whether a worker thread or its own ``result()`` call drives
the dispatch.

This module also owns the serving telemetry: :class:`LatencyStats` keeps
windowed per-request ``queue_s`` / ``flush_s`` / ``total_s`` samples
(p50/p95/p99) plus cumulative deadline-miss and shed counters, riding
alongside the fleet's :class:`~repro.runtime.fleet.FleetStats`; and the
typed exception hierarchy every serving failure derives from:

    ServiceError                the base clients catch wholesale
    +-- AdmissionError          shed before a ticket existed (backpressure)
    +-- DispatchError           admitted, then lost/failed after submit
    |   +-- QuarantinedError    isolated by bisection quarantine
    |                           (carries .ticket / .app / .cause)
    +-- JobTimeout              result(timeout=) or per-request hard
                                timeout expired (also a TimeoutError)

``DispatchError``/``QuarantinedError``/``JobTimeout`` are *defined* in
:mod:`repro.runtime.resilience` (the runtime layer raises them; serve
imports runtime, never the reverse) and re-exported here as the public
serving surface.
"""

from __future__ import annotations

import dataclasses
import threading
from collections import deque
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core import applications as app_lib
from repro.core.dfg import DFG
from repro.core.grid import GridSpec
from repro.runtime.resilience import (  # noqa: F401  (re-exported surface)
    DispatchError, JobTimeout, QuarantinedError, ServiceError,
)


class AdmissionError(ServiceError):
    """A request was shed by admission control: the service's bounded
    arrival queue was full.  Typed (rather than a bare queue.Full or --
    worse -- unbounded growth) so clients can distinguish overload
    shedding from bad requests and apply their own retry/backoff."""

    def __init__(self, queued: int, bound: int):
        self.queued = queued
        self.bound = bound
        super().__init__(
            f"request shed by admission control: {queued} requests already "
            f"queued (max_queue={bound}); retry with backoff or raise the "
            f"bound"
        )


@dataclasses.dataclass
class ImageJob:
    """The completed record of one served frame.

    ``queue_s`` is the wait from submit until its flush *started*;
    ``flush_s`` is the wall duration of the flush that served it (shared
    by every job in that flush); ``latency_s`` is the end-to-end total.
    The old single ``latency_s``-stamped-after-flush conflated the two --
    every job in a batch inherited the full flush time inside its queue
    wait -- so schedulers could not tell queueing delay from execution.
    """

    ticket: int
    app: str
    output: np.ndarray
    queue_s: float
    flush_s: float
    latency_s: float
    priority: int = 0
    deadline_s: Optional[float] = None   # relative SLO the submitter asked for
    deadline_missed: bool = False
    flush_seq: int = 0                   # which service flush served it


class JobHandle:
    """Future for one submitted frame: the one-call replacement for the
    ``tick``/``take`` protocol.

    ``done()`` is a non-blocking poll; ``result(timeout=...)`` blocks until
    the frame is served (raising ``TimeoutError`` on expiry) and returns
    the output array; ``job(timeout=...)`` returns the full
    :class:`ImageJob` record including the latency split.  A synchronous
    front-end wires ``kick`` to its own flush so ``result()`` on an
    undispatched handle drives the dispatch itself; the streaming
    front-end leaves it unset and lets the worker thread resolve handles.
    """

    def __init__(self, ticket: int, app: str, *, kick=None):
        self.ticket = ticket
        self.app = app
        self._event = threading.Event()
        self._job: Optional[ImageJob] = None
        self._exc: Optional[BaseException] = None
        self._kick = kick

    def done(self) -> bool:
        """Has the frame been served (or the request failed)?"""
        return self._event.is_set()

    def job(self, timeout: Optional[float] = None) -> ImageJob:
        """The full :class:`ImageJob` record (blocks like :meth:`result`)."""
        if not self._event.is_set() and self._kick is not None:
            self._kick()
        if not self._event.wait(timeout):
            raise JobTimeout(
                f"ticket {self.ticket} ({self.app!r}) not served within "
                f"{timeout} s"
            )
        if self._exc is not None:
            raise self._exc
        return self._job

    def result(self, timeout: Optional[float] = None) -> np.ndarray:
        """The output frame; blocks until served.  ``timeout=None`` waits
        forever, a float raises :class:`JobTimeout` (a ``TimeoutError``
        subclass) on expiry."""
        return self.job(timeout).output

    # -- resolution (called by the owning front-end) ------------------------
    # First resolution wins: the streaming supervisor may race a crash
    # reconciliation against a dispatch that already completed the handle,
    # and a late _fail must never overwrite a delivered result.

    def _complete(self, job: ImageJob) -> None:
        if self._event.is_set():
            return
        self._job = job
        self._event.set()

    def _fail(self, exc: BaseException) -> None:
        if self._event.is_set():
            return
        self._exc = exc
        self._event.set()

    def __repr__(self) -> str:
        state = "done" if self.done() else "pending"
        return f"JobHandle(ticket={self.ticket}, app={self.app!r}, {state})"


def _percentiles(samples: Sequence[float]) -> Dict[str, float]:
    if not samples:
        return {"p50": 0.0, "p95": 0.0, "p99": 0.0, "mean": 0.0, "max": 0.0}
    arr = np.asarray(samples, dtype=np.float64)
    p50, p95, p99 = np.percentile(arr, [50.0, 95.0, 99.0])
    return {
        "p50": float(p50), "p95": float(p95), "p99": float(p99),
        "mean": float(arr.mean()), "max": float(arr.max()),
    }


class LatencyStats:
    """Windowed per-request latency percentiles + SLO accounting.

    Per-request samples are split three ways (see :class:`ImageJob`):
    ``queue_s`` (submit -> flush start), ``flush_s`` (flush duration) and
    ``total_s`` (submit -> served).  Samples live in bounded deques (a
    long-running server must not grow without bound) while the SLO
    counters -- ``completed``, ``deadline_misses``, ``with_deadline``,
    ``shed`` -- are cumulative.  Thread-safe: the streaming worker records
    while clients read summaries.
    """

    def __init__(self, window: int = 65536):
        self._lock = threading.Lock()
        self.window = int(window)
        self._queue_s: deque = deque(maxlen=self.window)
        self._flush_s: deque = deque(maxlen=self.window)
        self._total_s: deque = deque(maxlen=self.window)
        self.completed = 0
        self.with_deadline = 0
        self.deadline_misses = 0
        self.shed = 0
        self.failed = 0

    def record(self, queue_s: float, flush_s: float, total_s: float,
               deadline_s: Optional[float] = None) -> None:
        with self._lock:
            self._queue_s.append(queue_s)
            self._flush_s.append(flush_s)
            self._total_s.append(total_s)
            self.completed += 1
            if deadline_s is not None:
                self.with_deadline += 1
                if total_s > deadline_s:
                    self.deadline_misses += 1

    def record_shed(self) -> None:
        with self._lock:
            self.shed += 1

    def record_failure(self) -> None:
        """One admitted request that failed post-submit (quarantined,
        lost to a crash, or hard-timed-out) -- the availability
        denominator the chaos bench reports against."""
        with self._lock:
            self.failed += 1

    def reset(self) -> None:
        """Clear samples AND counters (benches call this after warmup so
        compile-time flushes don't pollute the measured percentiles)."""
        with self._lock:
            self._queue_s.clear()
            self._flush_s.clear()
            self._total_s.clear()
            self.completed = 0
            self.with_deadline = 0
            self.deadline_misses = 0
            self.shed = 0
            self.failed = 0

    def summary(self) -> Dict[str, Any]:
        """p50/p95/p99/mean/max per latency component + the SLO counters
        (the serving bench writes this dict into BENCH_serving.json)."""
        with self._lock:
            return {
                "completed": self.completed,
                "failed": self.failed,
                "shed": self.shed,
                "with_deadline": self.with_deadline,
                "deadline_misses": self.deadline_misses,
                "queue_s": _percentiles(self._queue_s),
                "flush_s": _percentiles(self._flush_s),
                "total_s": _percentiles(self._total_s),
            }


def resolve_app(registry: Dict[str, Any], app: Union[str, DFG]):
    """Resolve a submitted app spec against a front-end registry into
    ``(name, work)`` where ``work`` is what the fleet receives.

    Library-default entries pass the NAME through so the fleet's
    (name, grid) config cache applies -- no per-request DFG rebuild +
    structural hash (~0.1 ms/request on the serving hot path).  Custom
    registry factories still build: the fleet only knows the library by
    name.  Shared by the synchronous and streaming front-ends so both
    validate unknown apps on the *submitter's* thread.
    """
    if isinstance(app, str):
        if app not in registry:
            raise KeyError(
                f"unknown app {app!r}; known: {sorted(registry)}"
            )
        factory = registry[app]
        work = app if factory is app_lib.ALL_APPS.get(app) else factory()
        return app, work
    return app.name, app


class ImageService:
    """The one service API both front-ends implement: futures all the way.

    Subclasses provide ``submit(app, image, grid=None, ...)`` returning a
    :class:`JobHandle`; ``process`` / ``process_batch`` are rebuilt on
    handles here, so they behave identically whether a worker thread
    (streaming) or the first ``result()`` call (synchronous) drives the
    dispatch.
    """

    def submit(self, app: Union[str, DFG], image: np.ndarray,
               grid: Optional[GridSpec] = None, **kwargs) -> JobHandle:
        raise NotImplementedError

    def process(self, app: Union[str, DFG], image: np.ndarray,
                **kwargs) -> np.ndarray:
        """Synchronous single-frame convenience (still goes through the
        batched path, so repeat calls reuse the compiled overlay)."""
        return self.submit(app, image, **kwargs).result()

    def process_batch(
        self, requests: Sequence[Tuple[Union[str, DFG], np.ndarray]],
        **kwargs,
    ) -> List[np.ndarray]:
        """Many (app, image) pairs; outputs in request order.  On the
        synchronous front-end the first ``result()`` drains the whole
        queue in one dispatch; on the streaming front-end the scheduler
        batches them behind the scenes."""
        handles = [self.submit(app, image, **kwargs) for app, image in requests]
        return [h.result() for h in handles]
