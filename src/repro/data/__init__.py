from repro.data.tokens import TokenPipeline
from repro.data.imaging import PixiePreprocessor, patch_embed_stub, synthetic_images

__all__ = [
    "TokenPipeline", "PixiePreprocessor", "patch_embed_stub", "synthetic_images",
]
