"""Image pipeline with Pixie-overlay preprocessing.

This is where the paper's technique is a *first-class framework feature*:
the preprocessing chain of the vision pipeline (edge maps, blur,
threshold, ...) is expressed as Pixie dataflow graphs, mapped once onto a
compiled-once overlay, and re-targeted per dataset/augmentation policy by
settings swap -- no retrace, no recompile (the overlay's raison d'etre).

Used by the PaliGemma example to produce the stubbed 'patch embedding'
inputs, and by examples/image_pipeline.py.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import applications as apps
from repro.core import for_dfg, map_app
from repro.core.grid import GridSpec, rectangular
from repro.core.interpreter import pack_inputs
from repro.core.plan import OverlayPlan, compile_plan


def synthetic_images(batch: int, hw, seed: int = 0) -> np.ndarray:
    """Deterministic pseudo-images [batch, H, W] float32 in [0, 256)."""
    H, W = hw
    rng = np.random.default_rng(seed)
    base = rng.random((batch, H, W)).astype(np.float32) * 255.0
    yy, xx = np.mgrid[0:H, 0:W]
    pattern = 64 * np.sin(yy / 7.0)[None] + 64 * np.cos(xx / 11.0)[None]
    return (base * 0.5 + pattern + 96).astype(np.float32)


@dataclasses.dataclass
class PixiePreprocessor:
    """A compiled-once overlay hosting a switchable preprocessing filter."""

    filters: Sequence[str] = ("sobel_mag", "gauss3", "sharpen", "laplace")
    float_pe: bool = True

    def __post_init__(self):
        dfgs = {name: apps.ALL_APPS[name]() for name in self.filters}
        # One grid large enough for every filter => one overlay executable.
        demands = []
        for g in dfgs.values():
            from repro.core.place import level_demand

            demands.append(level_demand(g))
        depth = max(len(d) for d in demands)
        width = max(max(d) for d in demands)
        n_in = max(len(g.inputs) for g in dfgs.values())
        self.grid: GridSpec = rectangular(
            "preproc", n_in, depth, width, num_outputs=1, float_pe=self.float_pe
        )
        # Fused ingest: line-buffer formation + pack + dispatch are ONE
        # jitted executable; reconfigure swaps settings (config + ingest
        # plan arrays), never recompiles.  The unfused overlay stays
        # available for apps without an ingest plan.
        self.overlay = compile_plan(OverlayPlan(grid=self.grid))
        self.fused_overlay = compile_plan(
            OverlayPlan(grid=self.grid, fused=True, radius=1)
        )
        self.configs = {name: map_app(g, self.grid) for name, g in dfgs.items()}
        self.active = self.filters[0]

    def reconfigure(self, name: str) -> None:
        """Settings swap -- never recompiles (tested)."""
        if name not in self.configs:
            raise KeyError(f"unknown filter {name!r}")
        self.active = name

    def __call__(self, image: jnp.ndarray) -> jnp.ndarray:
        """[H, W] -> [H, W] filtered, through the overlay."""
        cfg = self.configs[self.active]
        if cfg.ingest is not None and cfg.ingest.radius == 1:
            y = self.fused_overlay(
                cfg.to_jax(), cfg.ingest.to_jax(self.grid.dtype), image
            )
            return y[0].reshape(image.shape)
        taps = apps.stencil_inputs(image)
        feed = {k: v for k, v in taps.items() if k in cfg.input_order}
        x = pack_inputs(cfg, feed, self.grid.dtype)
        if x.shape[0] < self.grid.num_inputs:
            # pad to the memory-VC width: every app sees the same overlay
            # executable regardless of how many taps it uses
            x = jnp.pad(x, ((0, self.grid.num_inputs - x.shape[0]), (0, 0)))
        y = self.overlay(cfg.to_jax(), x)
        return y[0].reshape(image.shape)

    def batch(self, images: jnp.ndarray) -> jnp.ndarray:
        return jax.vmap(self.__call__)(images)


def patch_embed_stub(
    images: np.ndarray, num_patches: int, d_model: int
) -> np.ndarray:
    """SigLIP-stub: filtered image -> [B, num_patches, d_model] embeddings
    via patch-mean pooling + fixed random projection (deterministic)."""
    B, H, W = images.shape
    side = int(np.sqrt(num_patches))
    ph, pw = H // side, W // side
    pooled = images[:, : side * ph, : side * pw]
    pooled = pooled.reshape(B, side, ph, side, pw).mean(axis=(2, 4))
    pooled = pooled.reshape(B, side * side, 1)
    rng = np.random.default_rng(42)
    proj = rng.standard_normal((1, d_model)).astype(np.float32) * 0.02
    return (pooled / 255.0) @ proj
