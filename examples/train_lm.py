"""End-to-end training driver: a ~100M-parameter gemma-family model for a
few hundred steps on CPU, with checkpoint/restart fault tolerance.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

The same `repro.train` stack drives full-size archs over the production
mesh (see repro/launch/train.py and the dry-run).
"""

import argparse
import dataclasses
import shutil
import tempfile

import jax

from repro.configs import get_arch
from repro.data import TokenPipeline
from repro.models import LM
from repro.optim import AdamWConfig
from repro.train import LoopConfig, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()

    # ~100M params: gemma-family, 8 layers, d=768, vocab 32768
    base = get_arch("gemma-2b")
    cfg = dataclasses.replace(
        base, name="gemma-100m", num_layers=8, d_model=768, num_heads=8,
        num_kv_heads=1, head_dim=96, d_ff=3072, vocab_size=32_768,
    )
    lm = LM(cfg, remat="none", chunk_q=128, loss_chunk=128)
    n_params = sum(
        x.size for x in jax.tree_util.tree_leaves(
            jax.eval_shape(lambda: lm.init(jax.random.PRNGKey(0)))
        )
    )
    print(f"model: {cfg.name}  params={n_params/1e6:.1f}M")

    cycle = max(1, min(16, args.steps // 4))

    class CyclingPipeline(TokenPipeline):
        """Cycle over a fixed batch set so the demo has learnable signal
        (the raw hash stream is uniform => CE would flatline at ln V)."""

        def batch_at(self, step):
            return super().batch_at(step % cycle)

    pipeline = CyclingPipeline(
        vocab_size=cfg.vocab_size, seq_len=args.seq, global_batch=args.batch
    )
    ckpt_dir = tempfile.mkdtemp(prefix="pixie_train_")
    try:
        hist = train_loop(
            lm,
            LoopConfig(steps=args.steps, ckpt_every=100, ckpt_dir=ckpt_dir,
                       log_every=20),
            AdamWConfig(lr=3e-4, warmup_steps=30, total_steps=args.steps),
            pipeline,
        )
        first, last = hist["loss"][0], hist["loss"][-1]
        print(f"\nloss: {first:.3f} -> {last:.3f} over {args.steps} steps "
              f"({hist['throughput_tok_s'][0]:,.0f} tok/s median)")
        assert last < first, "training did not reduce the loss"
        print("training reduced the loss  [ok]")
    finally:
        shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
