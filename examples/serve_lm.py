"""Batched serving example: prefill + decode with continuous batching.

    PYTHONPATH=src python examples/serve_lm.py

Runs a reduced gemma-family model through the ServeEngine (one-shot
batch generation) and the SlotServer (requests joining mid-stream), and
cross-checks that both produce identical greedy continuations.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, reduced
from repro.models import LM
from repro.serve import ServeConfig, ServeEngine, SlotServer


def main():
    cfg = reduced(ARCHS["gemma-2b"])
    lm = LM(cfg, remat="none", chunk_q=64, loss_chunk=64)
    params = lm.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    prompts = rng.integers(0, cfg.vocab_size, (4, 12))
    engine = ServeEngine(lm, params, ServeConfig(max_batch=4, max_seq=96))

    t0 = time.perf_counter()
    out = engine.generate(jnp.asarray(prompts), 16)
    dt = time.perf_counter() - t0
    print(f"batch generate: {out.shape[0]}x{out.shape[1]} tokens "
          f"in {dt:.2f}s (incl. compile)")
    for i, row in enumerate(out):
        print(f"  seq{i}: {row[:10].tolist()}...")

    # continuous batching: second request joins two ticks late
    srv = SlotServer(lm, params, ServeConfig(max_batch=2, max_seq=96))
    srv.add_request(0, prompts[0])
    srv.tick(); srv.tick()
    srv.add_request(1, prompts[1])
    for _ in range(6):
        srv.tick()
    out0, out1 = srv.finish(0), srv.finish(1)
    np.testing.assert_array_equal(out0[:16], out[0][:len(out0)][:16])
    print("slot-server continuations match batch engine  [ok]")


if __name__ == "__main__":
    main()
