"""The Pixie overlay as a first-class data-pipeline feature.

A VLM preprocessing pipeline where the image filter bank runs on the
compiled-once VCGRA overlay: switching augmentation/filter policy is a
settings swap (never a recompile), exactly the overlay's value
proposition transplanted into a production data path.  The filtered
images feed the SigLIP-stub patch embedder used by the paligemma-3b
config.

    PYTHONPATH=src python examples/image_pipeline.py
"""

import time

import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.data import PixiePreprocessor, patch_embed_stub, synthetic_images


def main():
    cfg = get_arch("paligemma-3b")
    pre = PixiePreprocessor(filters=("sobel_mag", "gauss3", "sharpen", "laplace"))
    print(f"overlay grid: {pre.grid}")

    images = synthetic_images(8, (64, 64))
    t0 = time.perf_counter()
    feats = {}
    for name in pre.filters:
        pre.reconfigure(name)           # settings swap, no re-jit
        feats[name] = np.asarray(pre.batch(jnp.asarray(images)))
    dt = time.perf_counter() - t0
    print(f"4 filter policies x 8 images through one overlay executable "
          f"in {dt:.2f}s (cache size {pre.overlay._cache_size()} executable)")

    # stub patch embeddings for the VLM (dry-run feeds these shapes)
    emb = patch_embed_stub(feats["sobel_mag"], cfg.prefix_tokens, cfg.d_model)
    print(f"patch embeddings for {cfg.name}: {emb.shape} "
          f"(prefix_tokens={cfg.prefix_tokens}, d_model={cfg.d_model})")
    assert emb.shape == (8, cfg.prefix_tokens, cfg.d_model)
    print("pipeline complete  [ok]")


if __name__ == "__main__":
    main()
