"""Fleet quickstart: many tenants, one overlay dispatch.

Where `examples/quickstart.py` shows the paper's story for ONE application
at a time (map < 1 s, reconfigure in ms), this example shows the
multi-tenant extension: a mixed stream of image-processing requests —
different applications, different frame sizes — served by one compiled
overlay executable via the batched fleet runtime, behind the futures
service API (``submit`` returns a ``JobHandle``; ``result()`` drives the
dispatch). A streaming epilogue serves the same mix with per-request
deadlines through the continuous-batching front-end, and a resilience
epilogue replays it under seeded fault injection (transient faults
retried, a poisoned tenant quarantined by bisection).

    PYTHONPATH=src python examples/fleet_quickstart.py
"""

import time

import numpy as np

from repro.core import MeshSpec, sobel_grid
from repro.core import applications as apps
from repro.runtime import FaultInjector, RetryPolicy
from repro.runtime.fleet import PixieFleet
from repro.serve import FleetFrontend, QuarantinedError, StreamingFrontend


def main():
    print("=== Pixie fleet quickstart: multi-tenant overlay serving ===\n")
    rng = np.random.default_rng(0)
    # Device placement is a structured MeshSpec: `app` shards tenants,
    # `rows` shards each frame into pixel-row bands (halo-exchanged).
    # Hosts with too few devices degrade to the bitwise single-device
    # fallback and the stats say so -- the request below is safe anywhere.
    fleet = PixieFleet(default_grid=sobel_grid(), mesh=MeshSpec(app=2))
    stats = fleet.stats
    print(f"mesh: requested {stats.mesh_requested[0]}x"
          f"{stats.mesh_requested[1]}, granted {stats.mesh_granted[0]}x"
          f"{stats.mesh_granted[1]}"
          + (" (degraded: single-device fallback, bitwise identical)"
             if stats.mesh_degraded else ""))
    svc = FleetFrontend(fleet=fleet)
    print(f"service apps: {svc.available_apps()}")

    # A mixed request stream: 12 frames across 4 tenants, ragged sizes.
    tenants = ["sobel_x", "sobel_y", "threshold", "laplace"]
    frames = [
        rng.integers(0, 256, (h, w)).astype(np.int32)
        for h, w in [(64, 64), (48, 80), (32, 32)] * 4
    ]
    handles = [
        svc.submit(tenants[i % len(tenants)], frame)
        for i, frame in enumerate(frames)
    ]

    t0 = time.perf_counter()
    svc.flush()                            # ONE dispatch drains the queue
    dt = time.perf_counter() - t0
    assert all(h.done() for h in handles)
    print(f"\nserved {len(handles)} requests in one flush: {1e3*dt:.1f} ms "
          f"({len(handles)/dt:.0f} apps/s, first flush includes the jit)")

    # Spot-check one output against the numpy oracle.
    edge = handles[0].result()
    ref = apps.conv2d_reference(np.asarray(frames[0]), apps.SOBEL_X)
    assert np.array_equal(edge, ref), "fleet output mismatch!"
    print("fleet output == numpy oracle  [ok]")

    # A second wave: repeat tenants hit every cache.  No explicit flush —
    # asking any pending handle for its result kicks the dispatch.
    handles = [
        svc.submit(tenants[i % len(tenants)], frame)
        for i, frame in enumerate(frames)
    ]
    t0 = time.perf_counter()
    outs = [h.result() for h in handles]
    dt = time.perf_counter() - t0
    print(f"second wave (all caches warm): {1e3*dt:.1f} ms "
          f"({len(outs)/dt:.0f} apps/s)")
    job = handles[0].job()
    print(f"latency split: queue {1e3*job.queue_s:.2f} ms + "
          f"flush {1e3*job.flush_s:.2f} ms")

    s = svc.stats.as_dict()
    print(f"\nfleet stats: {s}")
    assert s["overlay_builds"] == 1, "overlay must compile once per grid"
    assert s["config_cache_hits"] > 0, "repeat tenants must skip place/route"
    print("compile-once + repeat-tenant fast path  [ok]")

    # Streaming epilogue: the same mix through the continuous-batching
    # front-end, each request carrying a deadline.  The worker thread
    # batches arrivals and launches a partial tile rather than miss.
    print("\n--- streaming front-end (deadlines, worker thread) ---")
    with StreamingFrontend(fleet=PixieFleet(default_grid=sobel_grid()),
                           target_batch=4) as stream:
        warm = stream.process("sobel_x", frames[0])   # absorb the jit
        assert np.array_equal(warm, ref)
        stream.latency.reset()
        hs = [
            stream.submit(tenants[i % len(tenants)], frame, deadline_s=5.0)
            for i, frame in enumerate(frames)
        ]
        outs = [h.result(timeout=30.0) for h in hs]
    for h, frame in zip(hs, frames):
        kernel = {"sobel_x": apps.SOBEL_X, "sobel_y": apps.SOBEL_Y,
                  "laplace": apps.LAPLACE}.get(h.app)
        if kernel is not None:
            assert np.array_equal(h.result(), apps.conv2d_reference(
                np.asarray(frame), kernel))
    lat = stream.latency.summary()
    print(f"streaming p99 total: {1e3*lat['total_s']['p99']:.1f} ms, "
          f"deadline misses: {lat['deadline_misses']}")
    assert lat["deadline_misses"] == 0
    print("streaming serving under deadline  [ok]")

    # Resilience epilogue: the same mix with a seeded fault injector.  A
    # transient dispatch blip is retried invisibly; a permanently
    # poisoned tenant is isolated by bisection and surfaces as a typed
    # QuarantinedError on ITS handles only -- batchmates still get
    # bitwise-correct outputs.
    print("\n--- self-healing serving (seeded fault injection) ---")
    faults = (FaultInjector(seed=0)
              .inject("dispatch", max_fires=2)            # transient blip
              .inject("dispatch", transient=False,
                      match=("<app:threshold>",)))        # poisoned tenant
    chaos_fleet = PixieFleet(default_grid=sobel_grid(), faults=faults,
                             retry=RetryPolicy(backoff_base_s=1e-3))
    with StreamingFrontend(fleet=chaos_fleet, target_batch=4) as stream:
        hs = [stream.submit(tenants[i % len(tenants)], frame)
              for i, frame in enumerate(frames)]
        served = quarantined = 0
        for h, frame in zip(hs, frames):
            try:
                out = h.result(timeout=30.0)
            except QuarantinedError as e:
                assert e.app == "threshold" and e.ticket is not None
                quarantined += 1
                continue
            served += 1
            kernel = {"sobel_x": apps.SOBEL_X, "sobel_y": apps.SOBEL_Y,
                      "laplace": apps.LAPLACE}.get(h.app)
            if kernel is not None:
                assert np.array_equal(out, apps.conv2d_reference(
                    np.asarray(frame), kernel))
    s = chaos_fleet.stats
    print(f"served {served}, quarantined {quarantined} "
          f"(retries {s.retries}, fallbacks {s.fallback_dispatches})")
    assert quarantined == sum(1 for i in range(len(frames))
                              if tenants[i % len(tenants)] == "threshold")
    assert served == len(frames) - quarantined
    print("poison isolated, batchmates served bitwise  [ok]")
    print("\nfleet quickstart complete.")


if __name__ == "__main__":
    main()
