"""Fleet quickstart: many tenants, one overlay dispatch.

Where `examples/quickstart.py` shows the paper's story for ONE application
at a time (map < 1 s, reconfigure in ms), this example shows the
multi-tenant extension: a mixed stream of image-processing requests —
different applications, different frame sizes — served by one compiled
overlay executable via the batched fleet runtime.

    PYTHONPATH=src python examples/fleet_quickstart.py
"""

import time

import numpy as np

from repro.core import sobel_grid
from repro.core import applications as apps
from repro.runtime.fleet import PixieFleet
from repro.serve import FleetFrontend


def main():
    print("=== Pixie fleet quickstart: multi-tenant overlay serving ===\n")
    rng = np.random.default_rng(0)
    svc = FleetFrontend(fleet=PixieFleet(default_grid=sobel_grid()))
    print(f"service apps: {svc.available_apps()}")

    # A mixed request stream: 12 frames across 4 tenants, ragged sizes.
    tenants = ["sobel_x", "sobel_y", "threshold", "laplace"]
    frames = [
        rng.integers(0, 256, (h, w)).astype(np.int32)
        for h, w in [(64, 64), (48, 80), (32, 32)] * 4
    ]
    tickets = [
        svc.submit(tenants[i % len(tenants)], frame)
        for i, frame in enumerate(frames)
    ]

    t0 = time.perf_counter()
    jobs = svc.tick()                      # ONE dispatch drains the queue
    dt = time.perf_counter() - t0
    print(f"\nserved {len(jobs)} requests in one tick: {1e3*dt:.1f} ms "
          f"({len(jobs)/dt:.0f} apps/s, first tick includes the jit)")

    # Spot-check one output against the numpy oracle.
    edge = svc.take(tickets[0])
    ref = apps.conv2d_reference(np.asarray(frames[0]), apps.SOBEL_X)
    assert np.array_equal(edge, ref), "fleet output mismatch!"
    print("fleet output == numpy oracle  [ok]")

    # A second wave: repeat tenants hit every cache.
    tickets = [
        svc.submit(tenants[i % len(tenants)], frame)
        for i, frame in enumerate(frames)
    ]
    t0 = time.perf_counter()
    svc.tick()
    dt = time.perf_counter() - t0
    print(f"second wave (all caches warm): {1e3*dt:.1f} ms "
          f"({len(tickets)/dt:.0f} apps/s)")

    s = svc.stats.as_dict()
    print(f"\nfleet stats: {s}")
    assert s["overlay_builds"] == 1, "overlay must compile once per grid"
    assert s["config_cache_hits"] > 0, "repeat tenants must skip place/route"
    print("compile-once + repeat-tenant fast path  [ok]")
    print("\nfleet quickstart complete.")


if __name__ == "__main__":
    main()
