"""Quickstart: the paper's demonstrator end to end.

Builds the 45-PE/4-VC Sobel grid (paper Fig. 5), runs the full VCGRA tool
flow (synthesis -> place -> route -> settings), executes on both the
compile-once conventional overlay and the parameterized (specialized)
path, validates against the numpy convolution oracle, and shows the
compile-gap numbers the paper is about.

    PYTHONPATH=src python examples/quickstart.py
"""

import time

import jax.numpy as jnp
import numpy as np

from repro.core import Pixie, SOBEL_SOURCE, map_app, sobel_grid, synthesize, for_dfg
from repro.core import applications as apps
from repro.core.grid import rectangular
from repro.core.place import level_demand


def main():
    print("=== Pixie quickstart: Sobel on the 45-PE VCGRA (paper Sec. IV) ===\n")

    # 1. the application, synthesized from its textual description
    dfg = synthesize("sobel_mag", SOBEL_SOURCE)
    print(f"synthesized netlist: {dfg.num_ops()} PE ops, depth {dfg.depth()}, "
          f"{len(dfg.inputs)} memory inputs")

    # 2. the overlay grid + tool flow (map < 1 s is the paper's headline).
    #    Size the grid to host every app we'll reconfigure onto it.
    blur_dfg = apps.gaussian_blur()
    d1, d2 = level_demand(dfg), level_demand(blur_dfg)
    grid = rectangular(
        "demo",
        num_inputs=max(len(dfg.inputs), len(blur_dfg.inputs)),
        levels=max(len(d1), len(d2)),
        width=max(max(d1), max(d2)),
        num_outputs=1,
    )
    pix = Pixie(grid, mode="conventional")
    t0 = time.perf_counter()
    config = pix.map(dfg)
    print(f"map (synth+place+route+settings): {1e3*(time.perf_counter()-t0):.1f} ms "
          f"(paper: < 1 s)")
    print(f"settings: {config.settings_words()} words "
          f"({config.settings_bits(grid)} bits)")

    # 3. compile the overlay ONCE (the '1200 s FPGA compile' analogue)
    img = jnp.asarray(np.random.default_rng(0).integers(0, 256, (256, 256)).astype(np.int32))
    t = pix.compile_overlay(batch=img.size)
    print(f"overlay compile (once per grid): {t:.2f} s")

    # 4. load + run, check against the oracle
    pix.load(config)
    out = np.asarray(pix.run_image(img))
    ref = apps.sobel_magnitude_reference(np.asarray(img))
    assert np.array_equal(out, ref), "overlay output mismatch!"
    print("conventional overlay == numpy oracle  [ok]")

    # 5. reconfigure to a different app WITHOUT recompiling
    blur = pix.map(blur_dfg)
    t_sw = pix.load(blur)
    out2 = np.asarray(pix.run_image(img))
    ref2 = apps.conv2d_reference(np.asarray(img), apps.GAUSS3, divisor=16.0)
    assert np.array_equal(out2, ref2)
    print(f"reconfigured to gauss3 in {1e3*t_sw:.2f} ms (settings swap, no re-jit)  [ok]")

    # 6. the parameterized path (paper's TLUT/TCON optimization)
    pixp = Pixie(grid, mode="parameterized")
    t_r = pixp.load(config, batch=img.size)
    out3 = np.asarray(pixp.run_image(img))
    assert np.array_equal(out3, ref)
    print(f"parameterized (specialized) path: micro-reconfig {t_r:.2f} s, "
          f"output identical  [ok]")

    print("\nquickstart complete.")


if __name__ == "__main__":
    main()
