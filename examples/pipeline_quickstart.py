"""Pipeline quickstart: a chained overlay as ONE device-resident dispatch.

A real image pipeline is a chain -- blur -> edge detect -> binarize.
Run naively, each stage is its own dispatch with a HOST HOP between:
the intermediate leaves the device, is re-embedded into a canvas, and
its line buffers are re-formed from scratch. The pipeline plan axis
(PR 9) folds the whole chain into one `OverlayExecutable`: stage i's
selected output channel re-feeds stage i+1's ingest taps on device, so
intermediates never leave it. This example runs the same depth-3 chain
three ways -- staged (the old reality), `Pixie.run_pipeline`, and the
fleet/front-end chain spelling -- and checks all outputs are bitwise
identical.

    PYTHONPATH=src python examples/pipeline_quickstart.py
"""

import time

import jax.numpy as jnp
import numpy as np

from repro.core import Pixie, map_app
from repro.core import applications as apps
from repro.core.grid import custom
from repro.core.place import level_demand
from repro.serve import FleetFrontend

CHAIN = ["gauss3", "sobel_x", "threshold"]


def chain_grid():
    """One overlay grid sized for every stage (per-level width = max
    demand across the chain's DFGs + slack), so the whole chain runs on
    one compiled executable."""
    dfgs = [apps.ALL_APPS[n]() for n in CHAIN]
    demands = [level_demand(g) for g in dfgs]
    depth = max(len(d) for d in demands)
    demands = [list(d) + [1] * (depth - len(d)) for d in demands]
    widths = [max(d[lvl] for d in demands) + 1 for lvl in range(depth)]
    return custom("pipe-demo", max(len(g.inputs) for g in dfgs), widths, 1)


def main():
    print("=== Pixie pipeline quickstart: device-resident chains ===\n")
    rng = np.random.default_rng(0)
    grid = chain_grid()
    img = rng.integers(0, 256, (256, 256)).astype(np.int32)
    print(f"chain: {' -> '.join(CHAIN)} on grid {grid.name}, "
          f"{img.shape[0]}x{img.shape[1]} px\n")

    # -- staged: one dispatch per stage, intermediate via the host -------
    pix = Pixie(grid, mode="conventional")
    cfgs = [map_app(apps.ALL_APPS[n](), grid) for n in CHAIN]

    def staged():
        cur = img
        for cfg in cfgs:
            pix.load(cfg)
            cur = np.asarray(pix.run_image(jnp.asarray(cur)))  # host hop
        return cur

    staged_out = staged()  # warm (compiles the single-stage executable)
    t0 = time.perf_counter()
    staged_out = staged()
    t_staged = time.perf_counter() - t0
    print(f"staged   {len(CHAIN)} dispatches, "
          f"{len(CHAIN) - 1} host round trips: {1e3 * t_staged:7.1f} ms")

    # -- fused: the whole chain is ONE executable ------------------------
    fused_out = np.asarray(pix.run_pipeline(CHAIN, jnp.asarray(img)))  # warm
    t0 = time.perf_counter()
    fused_out = np.asarray(pix.run_pipeline(CHAIN, jnp.asarray(img)))
    t_fused = time.perf_counter() - t0
    print(f"fused    1 dispatch,  0 host round trips: "
          f"{1e3 * t_fused:7.1f} ms   (x{t_staged / t_fused:.1f})")
    np.testing.assert_array_equal(fused_out, staged_out)
    print("bitwise: fused chain == staged per-stage oracle\n")

    # -- served: a list of stages IS the chain spelling ------------------
    svc = FleetFrontend(fleet=None, backend="xla")
    handle = svc.submit(CHAIN, img, grid=grid)
    np.testing.assert_array_equal(np.asarray(handle.result()), staged_out)
    print(f"served:  svc.submit({CHAIN!r}, img) -> "
          f"job {handle.job().app!r}, bitwise identical")
    print(f"         pipeline dispatches: {svc.stats.pipeline_dispatches}")


if __name__ == "__main__":
    main()
